"""Shared helpers for the Pallas benchmark kernels.

All four paper kernels are 1-D/2-D/3-D *streaming* kernels.  On TPU a long
vector is processed as a (rows, 128k) 2-D array so every DMA moves whole
(8,128) tiles -- this reshape+pad is itself an instance of the paper's
alignment rule and is centralized here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.layout import LANES, SUBLANES, cdiv, round_up

# interpret=True on CPU; real TPUs compile the same kernels natively.
INTERPRET = jax.default_backend() == "cpu"


def to_tiles(x: jax.Array, width: int = 1024) -> tuple[jax.Array, int]:
    """Reshape a 1-D array to (rows, width), zero-padding the tail.

    ``width`` must be a multiple of 128 lanes; rows are padded to a multiple
    of 8 sublanes so the result is exactly tileable.  Returns (tiled, n) with
    n the logical length for the inverse.
    """
    if width % LANES:
        raise ValueError(f"width must be a multiple of {LANES}")
    (n,) = x.shape
    rows = round_up(cdiv(max(n, 1), width), SUBLANES)
    pad = rows * width - n
    x2 = jnp.pad(x, (0, pad)) if pad else x
    return x2.reshape(rows, width), n


def from_tiles(x2: jax.Array, n: int) -> jax.Array:
    return x2.reshape(-1)[:n]


def block_rows(rows: int, target: int = 256) -> int:
    """Rows per VMEM block: a sublane multiple that divides the padded rows."""
    b = min(rows, round_up(target, SUBLANES))
    while rows % b:
        b -= SUBLANES
    return max(b, SUBLANES)
