"""Shared helpers for the Pallas benchmark kernels.

All four paper kernels are 1-D/2-D/3-D *streaming* kernels.  On TPU a long
vector is processed as a (rows, 128k) 2-D array so every DMA moves whole
(8,128) tiles -- this reshape+pad is itself an instance of the paper's
alignment rule and is centralized here.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.core.layout import LANES, SUBLANES, cdiv, round_up
from repro.core.planner import KernelPlan

# interpret=True on CPU; real TPUs compile the same kernels natively.
INTERPRET = jax.default_backend() == "cpu"


def to_tiles(x: jax.Array, width: int | None = None, *,
             plan: KernelPlan | None = None) -> tuple[jax.Array, int]:
    """Reshape a 1-D array to (rows, width), zero-padding the tail.

    The width comes from a ``KernelPlan`` (the planner's analytic choice) or
    an explicit override; it must be a multiple of 128 lanes.  Rows are
    padded to a multiple of 8 sublanes so the result is exactly tileable.
    Returns (tiled, n) with n the logical length for the inverse.
    """
    (n,) = x.shape
    if plan is not None:
        # A plan is only valid for the logical shape it was derived from;
        # a mismatched plan would silently drop tail rows from the grid.
        if plan.logical_shape != (n,):
            raise ValueError(
                f"plan {plan.kernel} is for shape {plan.logical_shape}, "
                f"got array of shape {(n,)}"
            )
        # Honor the plan's row count (rows may exceed the minimal sublane
        # padding when rounded up to a whole block).
        rows, width = plan.padded_shape
    else:
        if width is None:
            raise TypeError("to_tiles requires either width= or plan=")
        rows = round_up(cdiv(max(n, 1), width), SUBLANES)
    if width % LANES:
        raise ValueError(f"width must be a multiple of {LANES}")
    pad = rows * width - n
    x2 = jnp.pad(x, (0, pad)) if pad else x
    return x2.reshape(rows, width), n


def from_tiles(x2: jax.Array, n: int) -> jax.Array:
    return x2.reshape(-1)[:n]


def plan_args_1d(a: jax.Array, *_rest, **_scalars):
    """Registry ``plan_args`` for 1-D streaming kernels: plan on the first
    array's logical length and dtype (all streams share one layout)."""
    if a.ndim != 1:
        raise ValueError(f"1-D stream kernel got rank-{a.ndim} array")
    return tuple(a.shape), a.dtype


def plan_args_rows(x: jax.Array, *_rest, **_scalars):
    """Registry ``plan_args`` for row-wise 2-D kernels over (..., d) inputs:
    leading dims flatten into rows, the minor dim is the lane axis."""
    *lead, d = x.shape
    rows = 1
    for s in lead:
        rows *= s
    return (rows, d), x.dtype


def block_rows(rows: int, target: int = 256) -> int:
    """Rows per VMEM block: a sublane multiple that divides the padded rows."""
    b = min(rows, round_up(target, SUBLANES))
    while rows % b:
        b -= SUBLANES
    return max(b, SUBLANES)
