"""Pure-jnp D3Q19 lattice-Boltzmann oracle (paper SS2.4).

BGK single-relaxation-time collision, pull-scheme propagation on a periodic
cubic domain, optional fluid mask (non-fluid cells hold their distributions,
matching the paper's ``if fluidCell`` guard).

The state is kept in the *SoA / "IJKv"* layout ``f[v, x, y, z]`` here; layout
transforms live in ops.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# D3Q19 velocity set: rest, 6 faces, 12 edges.
C = np.array(
    [
        [0, 0, 0],
        [1, 0, 0], [-1, 0, 0], [0, 1, 0], [0, -1, 0], [0, 0, 1], [0, 0, -1],
        [1, 1, 0], [-1, -1, 0], [1, -1, 0], [-1, 1, 0],
        [1, 0, 1], [-1, 0, -1], [1, 0, -1], [-1, 0, 1],
        [0, 1, 1], [0, -1, -1], [0, 1, -1], [0, -1, 1],
    ],
    dtype=np.int32,
)
W = np.array([1 / 3] + [1 / 18] * 6 + [1 / 36] * 12, dtype=np.float64)
Q = 19


def equilibrium(rho: jax.Array, u: jax.Array) -> jax.Array:
    """f_eq[v, ...] for density rho[...] and velocity u[3, ...]."""
    dt = rho.dtype
    c = jnp.asarray(C, dt)          # (Q, 3)
    w = jnp.asarray(W, dt)          # (Q,)
    cu = jnp.tensordot(c, u, axes=(1, 0))            # (Q, ...)
    usq = jnp.sum(u * u, axis=0)                     # (...)
    one, three, f45, f15 = (jnp.asarray(v, dt) for v in (1.0, 3.0, 4.5, 1.5))
    return w.reshape((Q,) + (1,) * rho.ndim) * rho * (
        one + three * cu + f45 * cu * cu - f15 * usq
    )


def moments(f: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(rho, u) from f[v, ...]."""
    rho = jnp.sum(f, axis=0)
    c = jnp.asarray(C, f.dtype)
    mom = jnp.tensordot(c.T, f, axes=(1, 0))         # (3, ...)
    return rho, mom / rho


def collide(f: jax.Array, omega: float) -> jax.Array:
    rho, u = moments(f)
    feq = equilibrium(rho, u)
    return f - jnp.asarray(omega, f.dtype) * (f - feq)


def propagate(f: jax.Array) -> jax.Array:
    """Pull: f'[v](x) = f[v](x - c_v), periodic."""
    parts = [
        jnp.roll(f[v], shift=tuple(int(s) for s in C[v]), axis=(0, 1, 2))
        for v in range(Q)
    ]
    return jnp.stack(parts, axis=0)


def lbm_step(f: jax.Array, omega: float, mask: jax.Array | None = None) -> jax.Array:
    """One pull-scheme step on f[v, X, Y, Z]."""
    fprop = propagate(f)
    fpost = collide(fprop, omega)
    if mask is not None:
        fpost = jnp.where(mask[None], fpost, f)
    return fpost
