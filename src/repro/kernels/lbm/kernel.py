"""Pallas D3Q19 BGK collision kernel with selectable stream layout.

The paper's Fig. 7 result: the interleaved ``IvJK`` layout doubles LBM
throughput over plain SoA ``IJKv`` on T2 because interleaving the 19
distribution functions mid-axis *automatically skews* the 19+19 streams
across the memory controllers.

TPU port of the two layouts for the site-local collision hot loop
(propagation is lax-roll in ops.py; collision is the 38-stream kernel):

  * ``soa``  (IJKv analog): f stored (Q, S) -- every direction is its own
    contiguous HBM stream; a block is (Q, bs): 19 separate row DMAs.
  * ``ivjk`` (IvJK analog): f stored (S/128, Q, 128) -- directions
    interleaved at 128-lane granularity; a block is (bs/128, Q, 128): one
    fully contiguous DMA, the fine-grained skew of the paper realized as a
    single linear stream.

Both kernels share the same arithmetic; ops.py owns the layout transforms
and the conflict-model scoring that predicts which layout balances channels.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.lbm.ref import C, Q, W
from repro.kernels.util import INTERPRET


def _collide_block(f: jax.Array, c: jax.Array, w: jax.Array, omega: jax.Array,
                   v_axis: int) -> jax.Array:
    """BGK collision with the direction axis at ``v_axis``."""
    dt = f.dtype
    rho = jnp.sum(f, axis=v_axis, keepdims=True)
    mom = jnp.tensordot(f, c, axes=(v_axis, 0))          # (..., 3), v axis gone
    mom = jnp.moveaxis(mom, -1, v_axis)                  # (..., 3 at v_axis, ...)
    u = mom / rho
    cu = jnp.tensordot(u, c, axes=(v_axis, 1))           # (..., Q)
    cu = jnp.moveaxis(cu, -1, v_axis)
    usq = jnp.sum(u * u, axis=v_axis, keepdims=True)
    shape = [1] * f.ndim
    shape[v_axis] = Q
    wb = w.reshape(shape)
    one, three, f45, f15 = (jnp.asarray(v, dt) for v in (1.0, 3.0, 4.5, 1.5))
    feq = wb * rho * (one + three * cu + f45 * cu * cu - f15 * usq)
    return f - omega * (f - feq)


def _soa_kernel(f_ref, c_ref, w_ref, om_ref, o_ref):
    o_ref[...] = _collide_block(
        f_ref[...], c_ref[...], w_ref[...], om_ref[0], v_axis=0
    )


def _ivjk_kernel(f_ref, c_ref, w_ref, om_ref, o_ref):
    o_ref[...] = _collide_block(
        f_ref[...], c_ref[...], w_ref[...], om_ref[0], v_axis=1
    )


def _const_args(dtype, omega):
    """The D3Q19 constants as kernel operands (Pallas kernels may not
    capture array constants)."""
    return (
        jnp.asarray(C, dtype),
        jnp.asarray(W, dtype),
        jnp.asarray([omega], dtype),
    )


_CONST_SPECS = [pl.BlockSpec(memory_space=pl.ANY)] * 3


def collide_soa(f: jax.Array, omega: float, *, bs: int = 2048) -> jax.Array:
    """f: (Q, S) with S a multiple of bs (bs a lane multiple)."""
    q, s = f.shape
    assert q == Q and s % bs == 0, (q, s, bs)
    spec = pl.BlockSpec((Q, bs), lambda i: (0, i))
    return pl.pallas_call(
        _soa_kernel,
        grid=(s // bs,),
        in_specs=[spec, *_CONST_SPECS],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((q, s), f.dtype),
        interpret=INTERPRET,
    )(f, *_const_args(f.dtype, omega))


def collide_ivjk(f: jax.Array, omega: float, *, bsb: int = 16) -> jax.Array:
    """f: (S/128, Q, 128) with the super-block count a multiple of bsb."""
    sb, q, lanes = f.shape
    assert q == Q and lanes == 128 and sb % bsb == 0, (f.shape, bsb)
    spec = pl.BlockSpec((bsb, Q, lanes), lambda i: (i, 0, 0))
    return pl.pallas_call(
        _ivjk_kernel,
        grid=(sb // bsb,),
        in_specs=[spec, *_CONST_SPECS],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(f.shape, f.dtype),
        interpret=INTERPRET,
    )(f, *_const_args(f.dtype, omega))
