"""LBM D3Q19 step: registry entries per layout, traffic accounting.

``lbm.soa`` and ``lbm.ivjk`` register as separate kernels (the paper's Fig. 7
layout comparison is a *planning* decision, so it lives in the kernel name).
Pad multiples and block shapes come from the planner's VMEM-budget analysis
of the 19+19 streams; the flatten/pad helper routes through the plan's
padded shape, so the lattice is padded exactly once even when the plan has
widened the minor dim beyond the block multiple (e.g. for a mesh).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.api import dispatch
from repro.api.registry import register_kernel
from repro.api.spmd import replicated
from repro.core.aliasing import InterleavedMemoryModel
from repro.core.autotune import StreamSignature, choose_layout
from repro.kernels._shims import deprecated_wrapper
from repro.kernels.lbm import kernel, ref
from repro.kernels.lbm.ref import Q

LAYOUTS = ("soa", "ivjk")

_SIG = StreamSignature(n_read=19, n_write=19)


def _plan_args(f, **_scalars):
    return tuple(f.shape), f.dtype


def _flatten_pad(f: jax.Array, plan) -> tuple[jax.Array, int]:
    """(Q, X, Y, Z) -> (Q, S_pad) with S_pad taken from the *plan's* padded
    shape -- never recomputed from a block multiple, so the lattice cannot be
    double-padded (or under-padded) relative to the grid the plan derived."""
    q = f.shape[0]
    s = int(f[0].size)
    if len(plan.padded_shape) == 2:          # soa: (Q, S_pad)
        spad = plan.padded_shape[1]
    else:                                    # ivjk: (S_pad/128, Q, 128)
        spad = plan.padded_shape[0] * plan.padded_shape[2]
    if spad < s:
        raise ValueError(
            f"plan {plan.kernel} pads {spad} sites < logical {s}"
        )
    flat = f.reshape(q, s)
    if spad != s:
        flat = jnp.pad(flat, ((0, 0), (0, spad - s)))
    return flat, s


@functools.partial(jax.jit, static_argnames=("plan",))
def _step_soa(f, omega, mask, *, plan):
    fprop = ref.propagate(f)
    flat, s = _flatten_pad(fprop, plan)
    post = kernel.collide_soa(flat, omega, bs=plan.block_cols)
    post = post[:, :s].reshape(f.shape)
    return post if mask is None else jnp.where(mask[None], post, f)


@functools.partial(jax.jit, static_argnames=("plan",))
def _step_ivjk(f, omega, mask, *, plan):
    fprop = ref.propagate(f)
    flat, s = _flatten_pad(fprop, plan)
    ivjk = flat.reshape(Q, -1, 128).transpose(1, 0, 2)  # (S/128, Q, 128)
    post = kernel.collide_ivjk(ivjk, omega, bsb=plan.block_rows)
    post = post.transpose(1, 0, 2).reshape(Q, -1)[:, :s].reshape(f.shape)
    return post if mask is None else jnp.where(mask[None], post, f)


def _lbm_ref(f, *, omega, mask=None):
    post = ref.lbm_step(f, omega)
    return post if mask is None else jnp.where(mask[None], post, f)


# Streaming (propagate) shifts every site into its neighbors each step:
# a lattice split would need halo exchanges, so both layouts run
# replicated under the SPMD path.
@register_kernel("lbm.soa", signature=_SIG, ref=_lbm_ref,
                 plan_args=_plan_args, partitioning=replicated(1))
def _launch_soa(plan, f, *, omega, mask=None):
    """Propagate (lax roll) + Pallas BGK collision, f stored (Q, S)."""
    return _step_soa(f, omega, mask, plan=plan)


@register_kernel("lbm.ivjk", signature=_SIG, ref=_lbm_ref,
                 plan_args=_plan_args, partitioning=replicated(1))
def _launch_ivjk(plan, f, *, omega, mask=None):
    """Collision with directions interleaved at lane granularity
    (the paper's auto-skewed IvJK layout)."""
    return _step_ivjk(f, omega, mask, plan=plan)


@deprecated_wrapper("lbm.ivjk",
                    resolver=lambda *a, **kw: f"lbm.{kw.get('layout', 'ivjk')}")
def lbm_step(
    f: jax.Array,
    omega: float,
    mask: jax.Array | None = None,
    *,
    layout: str = "ivjk",
) -> jax.Array:
    if layout not in LAYOUTS:
        raise ValueError(f"layout must be one of {LAYOUTS}")
    return dispatch.launch(f"lbm.{layout}", f, omega=omega, mask=mask)


@functools.partial(jax.jit, static_argnames=("iters", "layout", "plan"))
def _run(f, omega, *, iters, layout, plan):
    return jax.lax.fori_loop(
        0, iters,
        lambda _, x: dispatch.launch(f"lbm.{layout}", x, omega=omega,
                                     plan=plan), f,
    )


def lbm_run(f: jax.Array, omega: float, iters: int, *,
            layout: str = "ivjk") -> jax.Array:
    if layout not in LAYOUTS:
        raise ValueError(f"layout must be one of {LAYOUTS}")
    # Plan outside the jitted loop so an ambient plan_context change shows
    # up as a new static plan instead of being masked by jit's trace cache.
    plan = dispatch.plan_for(f"lbm.{layout}", tuple(f.shape), f.dtype)
    return _run(f, omega, iters=iters, layout=layout, plan=plan)


def init_equilibrium(n: int, dtype=jnp.float32) -> jax.Array:
    """Unit-density fluid at rest with a small sinusoidal shear (gives the
    tests a non-trivial but stable flow)."""
    rho = jnp.ones((n, n, n), dtype)
    x = jnp.linspace(0, 2 * jnp.pi, n, endpoint=False, dtype=dtype)
    ux = 0.02 * jnp.sin(x)[None, None, :] * jnp.ones((n, n, n), dtype)
    u = jnp.stack([ux, jnp.zeros_like(ux), jnp.zeros_like(ux)])
    return ref.equilibrium(rho, u)


# ---- accounting (paper numbers) -------------------------------------------

def site_bytes(elem_bytes: int = 8, *, rfo: bool = True) -> int:
    """Paper: 19 reads + 19 writes (+19 RFO) = 456 B/site at 8 B elems."""
    return (3 if rfo else 2) * Q * elem_bytes


def site_flops() -> int:
    """~180 flops/site for D3Q19 BGK (paper's ~2.5 B/flop at 456 B)."""
    return 180


def layout_balance_scores(
    model: InterleavedMemoryModel | None = None,
    *,
    n: int = 100,
    elem_bytes: int = 8,
) -> tuple[str, dict[str, float]]:
    """Conflict-model comparison of the two layouts (paper Fig. 7 analysis).

    Stream bases for the 19 write streams of one thread on a cubic N^3
    domain (Fortran notation, i fastest):
      soa  (IJKv, f(i,j,k,v)) -- direction v starts at v * N^3 * elem_bytes:
           for any N with 64 | N^3 the bases all alias onto one channel,
      ivjk (f(i,v,j,k))       -- direction v starts at v * N * elem_bytes:
           for generic N the 19 odd-count streams spread over the channels
           ("the fortunate number of 19 distribution functions leads to an
           automatic skew"), collapsing only when N % 64 == 0 -- the paper's
           residual "ruinous" cache-thrashing sizes, removable by padding.
    """
    s = n ** 3
    soa_bases = [v * s * elem_bytes for v in range(Q)]
    ivjk_bases = [v * n * elem_bytes for v in range(Q)]
    mask = [True] * Q
    return choose_layout(
        {"soa": (soa_bases, mask), "ivjk": (ivjk_bases, mask)},
        model or InterleavedMemoryModel(),
    )
