"""LBM D3Q19 step: registry entries per layout, traffic accounting.

``lbm.soa`` and ``lbm.ivjk`` register as separate kernels (the paper's Fig. 7
layout comparison is a *planning* decision, so it lives in the kernel name).
Pad multiples and block shapes come from the planner's VMEM-budget analysis
of the 19+19 streams; the flatten/pad helper routes through the plan's
padded shape, so the lattice is padded exactly once even when the plan has
widened the minor dim beyond the block multiple (e.g. for a mesh).

Under an SPMD mesh the lattice shards its X axis over the data axis with
*per-direction* halo depths: of D3Q19's 19 directions, 5 have c_x = +1,
5 have c_x = -1 and 9 never cross an X cut, so one streaming step
ppermutes two (5, 1, Y, Z) slabs around the (periodic) ring instead of
replicating the whole lattice.  The shard body is overlapped
(docs/OVERLAP.md): slabs are issued first, the interior planes (which pull
only from locally-resident planes) propagate+collide while they fly, and
only the two boundary planes read the arriving slabs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.api import dispatch
from repro.api import spmd as spmd_lib
from repro.api.registry import register_kernel
from repro.api.spmd import Partitioning
from repro.core.aliasing import InterleavedMemoryModel
from repro.core.autotune import StreamSignature, choose_layout
from repro.core.layout import LANES, round_up
from repro.kernels._shims import deprecated_wrapper
from repro.kernels.lbm import kernel, ref
from repro.kernels.lbm.ref import Q

LAYOUTS = ("soa", "ivjk")

_SIG = StreamSignature(n_read=19, n_write=19)

# Direction indices by x-component: the per-direction halo depth |c_x| is 1
# for the 5+5 directions crossing an X cut and 0 for the rest (the planner's
# _comm_lbm prices exactly these two 5-plane slabs).
_PLUS_X = tuple(v for v in range(Q) if int(ref.C[v][0]) == 1)
_MINUS_X = tuple(v for v in range(Q) if int(ref.C[v][0]) == -1)
_ZERO_X = tuple(v for v in range(Q) if int(ref.C[v][0]) == 0)


def _plan_args(f, **_scalars):
    return tuple(f.shape), f.dtype


def _flatten_pad(f: jax.Array, plan) -> tuple[jax.Array, int]:
    """(Q, X, Y, Z) -> (Q, S_pad) with S_pad taken from the *plan's* padded
    shape -- never recomputed from a block multiple, so the lattice cannot be
    double-padded (or under-padded) relative to the grid the plan derived."""
    q = f.shape[0]
    s = int(f[0].size)
    if len(plan.padded_shape) == 2:          # soa: (Q, S_pad)
        spad = plan.padded_shape[1]
    else:                                    # ivjk: (S_pad/128, Q, 128)
        spad = plan.padded_shape[0] * plan.padded_shape[2]
    if spad < s:
        raise ValueError(
            f"plan {plan.kernel} pads {spad} sites < logical {s}"
        )
    flat = f.reshape(q, s)
    if spad != s:
        flat = jnp.pad(flat, ((0, 0), (0, spad - s)))
    return flat, s


@functools.partial(jax.jit, static_argnames=("plan",))
def _step_soa(f, omega, mask, *, plan):
    fprop = ref.propagate(f)
    flat, s = _flatten_pad(fprop, plan)
    post = kernel.collide_soa(flat, omega, bs=plan.block_cols)
    post = post[:, :s].reshape(f.shape)
    return post if mask is None else jnp.where(mask[None], post, f)


@functools.partial(jax.jit, static_argnames=("plan",))
def _step_ivjk(f, omega, mask, *, plan):
    fprop = ref.propagate(f)
    flat, s = _flatten_pad(fprop, plan)
    ivjk = flat.reshape(Q, -1, 128).transpose(1, 0, 2)  # (S/128, Q, 128)
    post = kernel.collide_ivjk(ivjk, omega, bsb=plan.block_rows)
    post = post.transpose(1, 0, 2).reshape(Q, -1)[:, :s].reshape(f.shape)
    return post if mask is None else jnp.where(mask[None], post, f)


def _lbm_ref(f, *, omega, mask=None):
    post = ref.lbm_step(f, omega)
    return post if mask is None else jnp.where(mask[None], post, f)


# ---- SPMD: X-sharded lattice with per-direction halos ----------------------

def _roll_yz(a, v: int):
    """The y/z part of direction ``v``'s pull shift (the x part is handled
    by plane selection / the halo slab)."""
    cy, cz = int(ref.C[v][1]), int(ref.C[v][2])
    return jnp.roll(a, shift=(cy, cz), axis=(-2, -1))


def _halo_exchange_x(f, x_axes, n_shards, idx):
    """Issue the per-direction halo transfers for one streaming step.

    Only the 10 directions with nonzero c_x cross the X cut, at depth
    |c_x| = 1: the last local plane of the 5 +x-moving populations goes
    down-ring (arriving as ``halo_lo``, what my x=0 plane pulls) and the
    first plane of the 5 -x-moving populations goes up-ring (``halo_hi``).
    The ring wraps because the global propagate is periodic -- edge shards
    exchange across the domain boundary, not zeros.
    """
    plus_last = f[jnp.array(_PLUS_X)][:, -1:]      # (5, 1, Y, Z)
    minus_first = f[jnp.array(_MINUS_X)][:, :1]    # (5, 1, Y, Z)
    if len(x_axes) == 1:
        ax = x_axes[0]
        down = [(j, (j + 1) % n_shards) for j in range(n_shards)]
        up = [(j, (j - 1) % n_shards) for j in range(n_shards)]
        halo_lo = jax.lax.ppermute(plus_last, ax, down)
        halo_hi = jax.lax.ppermute(minus_first, ax, up)
    else:  # multi-axis X sharding: gather the boundary slabs instead
        edges = jnp.concatenate([plus_last, minus_first], axis=1)
        gathered = jax.lax.all_gather(edges, x_axes, tiled=False)
        gathered = gathered.reshape((n_shards,) + edges.shape)
        halo_lo = gathered[(idx - 1) % n_shards][:, :1]
        halo_hi = gathered[(idx + 1) % n_shards][:, 1:]
    return halo_lo, halo_hi


def _propagate_interior(f):
    """Pull-propagated planes 1..XL-2 of this shard's (Q, XL, Y, Z) stripe
    -- every pull source is locally resident, so this work is independent
    of the in-flight halo slabs."""
    parts = [None] * Q
    for v in _ZERO_X:
        parts[v] = _roll_yz(f[v][1:-1], v)
    for v in _PLUS_X:
        parts[v] = _roll_yz(f[v][:-2], v)
    for v in _MINUS_X:
        parts[v] = _roll_yz(f[v][2:], v)
    return jnp.stack(parts, axis=0)


def _propagate_boundary(f, halo_lo, halo_hi):
    """The two boundary planes of the pull propagate -- the only planes
    that read the arriving halo slabs.  Valid for XL >= 2."""
    lo = [None] * Q
    hi = [None] * Q
    for v in _ZERO_X:
        lo[v] = _roll_yz(f[v][:1], v)
        hi[v] = _roll_yz(f[v][-1:], v)
    for k, v in enumerate(_PLUS_X):
        lo[v] = _roll_yz(halo_lo[k], v)
        hi[v] = _roll_yz(f[v][-2:-1], v)
    for k, v in enumerate(_MINUS_X):
        lo[v] = _roll_yz(f[v][1:2], v)
        hi[v] = _roll_yz(halo_hi[k], v)
    return jnp.stack(lo, axis=0), jnp.stack(hi, axis=0)


def _collide_planes(fprop, omega):
    """BGK collision of a small (Q, planes, Y, Z) boundary slab, through
    the same Pallas kernel as the interior (one whole-slab block).  Plain
    jnp here is *almost* right but lets XLA contract the collision's
    multiply-adds differently depending on what it fuses with, which
    breaks last-ulp parity with the single-device path; one more
    pallas_call keeps the arithmetic identical.  SoA layout regardless of
    the interior layout -- the slab is a few planes, the layout choice is
    a bandwidth decision that doesn't apply at this size."""
    flat = fprop.reshape(Q, -1)
    s = flat.shape[1]
    spad = round_up(s, LANES)
    if spad != s:
        flat = jnp.pad(flat, ((0, 0), (0, spad - s)))
    post = kernel.collide_soa(flat, omega, bs=spad)[:, :s]
    return post.reshape(fprop.shape)


def _collide_planes_planned(fprop, omega, layout: str):
    """Collide a propagated (Q, planes, Y, Z) slab through the layout's
    Pallas kernel on a locally planned block shape."""
    plan = dispatch.plan_for(f"lbm.{layout}", tuple(fprop.shape),
                             fprop.dtype, local=True)
    flat, s = _flatten_pad(fprop, plan)
    if layout == "soa":
        post = kernel.collide_soa(flat, omega, bs=plan.block_cols)[:, :s]
    else:
        ivjk = flat.reshape(Q, -1, 128).transpose(1, 0, 2)
        post = kernel.collide_ivjk(ivjk, omega, bsb=plan.block_rows)
        post = post.transpose(1, 0, 2).reshape(Q, -1)[:, :s]
    return post.reshape(fprop.shape)


def _spmd_lbm_step(ctx, x_axes, f, layout, omega, mask):
    """Overlapped shard body shared by both layouts: issue the halo slabs,
    propagate+collide the interior planes while they fly, then finish the
    two boundary planes from the arrived slabs (docs/OVERLAP.md)."""
    n_shards = ctx.size(x_axes)
    if n_shards <= 1:
        # X whole on this shard (divisibility fallback or size-1 data
        # axis): the single-device step on a locally planned block.
        shape, dtype = _plan_args(f)
        plan = dispatch.plan_for(f"lbm.{layout}", shape, dtype, local=True)
        step = _step_soa if layout == "soa" else _step_ivjk
        return step(f, omega, mask, plan=plan)
    q, xl, y, z = f.shape
    idx = ctx.index(x_axes)
    # The mask rides along replicated (scalars close over the body); each
    # shard slices its own X planes.
    mask_l = None
    if mask is not None:
        mask_l = jax.lax.dynamic_slice_in_dim(mask, idx * xl, xl, axis=0)
    # 1) issue the halo exchange for this step ...
    halo_lo, halo_hi = _halo_exchange_x(f, x_axes, n_shards, idx)
    if xl > 2:
        # 2) ... propagate+collide the interior planes while it is in
        # flight (plan cell: the interior slab this shard actually sweeps)
        post_int = _collide_planes_planned(_propagate_interior(f), omega,
                                           layout)
        # 3) boundary planes last: the only reads of the arrived slabs.
        flo, fhi = _propagate_boundary(f, halo_lo, halo_hi)
        out = jnp.concatenate(
            [_collide_planes(flo, omega), post_int,
             _collide_planes(fhi, omega)], axis=1)
    elif xl == 2:
        # Degenerate stripe: both planes are boundary planes, nothing to
        # hide the exchange behind (predicted_exposed_comm_bytes agrees).
        flo, fhi = _propagate_boundary(f, halo_lo, halo_hi)
        out = _collide_planes(jnp.concatenate([flo, fhi], axis=1), omega)
    else:
        parts = [None] * Q
        for v in _ZERO_X:
            parts[v] = _roll_yz(f[v], v)
        for k, v in enumerate(_PLUS_X):
            parts[v] = _roll_yz(halo_lo[k], v)
        for k, v in enumerate(_MINUS_X):
            parts[v] = _roll_yz(halo_hi[k], v)
        out = _collide_planes(jnp.stack(parts, axis=0), omega)
    return out if mask_l is None else jnp.where(mask_l[None], out, f)


def _spmd_lbm_soa(ctx, f, *, omega, mask=None):
    """shard_map body: X-sharded SoA lattice with per-direction halos."""
    x_axes = ctx.axes(0, 1)
    return _spmd_lbm_step(ctx, x_axes, f, "soa", omega, mask)


def _spmd_lbm_ivjk(ctx, f, *, omega, mask=None):
    """shard_map body: X-sharded IvJK lattice with per-direction halos."""
    x_axes = ctx.axes(0, 1)
    return _spmd_lbm_step(ctx, x_axes, f, "ivjk", omega, mask)


# The lattice shards its X axis ("batch" -> the data mesh axis); streaming
# across the cut travels as the two 5-direction halo slabs the spmd_body
# exchanges, so the lattice is no longer replicated per device.
_LBM_PART = Partitioning(in_axes=((None, "batch", None, None),),
                         out_axes=(None, "batch", None, None))


@register_kernel("lbm.soa", signature=_SIG, ref=_lbm_ref,
                 plan_args=_plan_args, partitioning=_LBM_PART,
                 spmd_body=_spmd_lbm_soa)
def _launch_soa(plan, f, *, omega, mask=None):
    """Propagate (lax roll) + Pallas BGK collision, f stored (Q, S)."""
    return _step_soa(f, omega, mask, plan=plan)


@register_kernel("lbm.ivjk", signature=_SIG, ref=_lbm_ref,
                 plan_args=_plan_args, partitioning=_LBM_PART,
                 spmd_body=_spmd_lbm_ivjk)
def _launch_ivjk(plan, f, *, omega, mask=None):
    """Collision with directions interleaved at lane granularity
    (the paper's auto-skewed IvJK layout)."""
    return _step_ivjk(f, omega, mask, plan=plan)


@deprecated_wrapper("lbm.ivjk",
                    resolver=lambda *a, **kw: f"lbm.{kw.get('layout', 'ivjk')}")
def lbm_step(
    f: jax.Array,
    omega: float,
    mask: jax.Array | None = None,
    *,
    layout: str = "ivjk",
) -> jax.Array:
    if layout not in LAYOUTS:
        raise ValueError(f"layout must be one of {LAYOUTS}")
    return dispatch.launch(f"lbm.{layout}", f, omega=omega, mask=mask)


@functools.partial(jax.jit, static_argnames=("iters", "layout", "plan"))
def _run(f, omega, *, iters, layout, plan):
    return jax.lax.fori_loop(
        0, iters,
        lambda _, x: dispatch.launch(f"lbm.{layout}", x, omega=omega,
                                     plan=plan), f,
    )


def lbm_run(f: jax.Array, omega: float, iters: int, *,
            layout: str = "ivjk") -> jax.Array:
    if layout not in LAYOUTS:
        raise ValueError(f"layout must be one of {LAYOUTS}")
    # Under an ambient multi-device mesh, route every step through the
    # shard_map path (a pinned plan would force the single-device body);
    # consecutive steps pipeline -- step k+1's halo slabs fly while step
    # k's interior planes are still colliding.
    if spmd_lib.spmd_mesh() is not None:
        return jax.jit(
            lambda f0: jax.lax.fori_loop(
                0, iters,
                lambda _, x: dispatch.launch(f"lbm.{layout}", x,
                                             omega=omega), f0,
            )
        )(f)
    # Plan outside the jitted loop so an ambient plan_context change shows
    # up as a new static plan instead of being masked by jit's trace cache.
    plan = dispatch.plan_for(f"lbm.{layout}", tuple(f.shape), f.dtype)
    return _run(f, omega, iters=iters, layout=layout, plan=plan)


def init_equilibrium(n: int, dtype=jnp.float32) -> jax.Array:
    """Unit-density fluid at rest with a small sinusoidal shear (gives the
    tests a non-trivial but stable flow)."""
    rho = jnp.ones((n, n, n), dtype)
    x = jnp.linspace(0, 2 * jnp.pi, n, endpoint=False, dtype=dtype)
    ux = 0.02 * jnp.sin(x)[None, None, :] * jnp.ones((n, n, n), dtype)
    u = jnp.stack([ux, jnp.zeros_like(ux), jnp.zeros_like(ux)])
    return ref.equilibrium(rho, u)


# ---- accounting (paper numbers) -------------------------------------------

def site_bytes(elem_bytes: int = 8, *, rfo: bool = True) -> int:
    """Paper: 19 reads + 19 writes (+19 RFO) = 456 B/site at 8 B elems."""
    return (3 if rfo else 2) * Q * elem_bytes


def site_flops() -> int:
    """~180 flops/site for D3Q19 BGK (paper's ~2.5 B/flop at 456 B)."""
    return 180


def layout_balance_scores(
    model: InterleavedMemoryModel | None = None,
    *,
    n: int = 100,
    elem_bytes: int = 8,
) -> tuple[str, dict[str, float]]:
    """Conflict-model comparison of the two layouts (paper Fig. 7 analysis).

    Stream bases for the 19 write streams of one thread on a cubic N^3
    domain (Fortran notation, i fastest):
      soa  (IJKv, f(i,j,k,v)) -- direction v starts at v * N^3 * elem_bytes:
           for any N with 64 | N^3 the bases all alias onto one channel,
      ivjk (f(i,v,j,k))       -- direction v starts at v * N * elem_bytes:
           for generic N the 19 odd-count streams spread over the channels
           ("the fortunate number of 19 distribution functions leads to an
           automatic skew"), collapsing only when N % 64 == 0 -- the paper's
           residual "ruinous" cache-thrashing sizes, removable by padding.
    """
    s = n ** 3
    soa_bases = [v * s * elem_bytes for v in range(Q)]
    ivjk_bases = [v * n * elem_bytes for v in range(Q)]
    mask = [True] * Q
    return choose_layout(
        {"soa": (soa_bases, mask), "ivjk": (ivjk_bases, mask)},
        model or InterleavedMemoryModel(),
    )
