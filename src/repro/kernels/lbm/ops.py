"""LBM step wrappers: layout transforms, full step, traffic accounting."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.aliasing import InterleavedMemoryModel
from repro.core.autotune import choose_layout
from repro.core.layout import round_up
from repro.core.planner import plan_kernel
from repro.kernels.lbm import kernel, ref
from repro.kernels.lbm.ref import Q

LAYOUTS = ("soa", "ivjk")


def _flatten_pad(f: jax.Array, multiple: int) -> tuple[jax.Array, int]:
    """(Q, X, Y, Z) -> (Q, S_pad)."""
    q = f.shape[0]
    s = int(f[0].size)
    spad = round_up(s, multiple)
    flat = f.reshape(q, s)
    if spad != s:
        flat = jnp.pad(flat, ((0, 0), (0, spad - s)))
    return flat, s


@functools.partial(jax.jit, static_argnames=("layout",))
def lbm_step(
    f: jax.Array,
    omega: float,
    mask: jax.Array | None = None,
    *,
    layout: str = "ivjk",
) -> jax.Array:
    """One D3Q19 step on f[v, X, Y, Z]: lax-roll propagation + Pallas
    collision in the chosen stream layout.  Pad multiples and block shapes
    come from the planner's VMEM-budget analysis of the 19+19 streams."""
    if layout not in LAYOUTS:
        raise ValueError(f"layout must be one of {LAYOUTS}")
    shape = f.shape
    fprop = ref.propagate(f)
    if layout == "soa":
        plan = plan_kernel("lbm.soa", shape, f.dtype)
        flat, s = _flatten_pad(fprop, plan.block_cols)
        post = kernel.collide_soa(flat, omega, bs=plan.block_cols)
        post = post[:, :s].reshape(shape)
    else:
        plan = plan_kernel("lbm.ivjk", shape, f.dtype)
        flat, s = _flatten_pad(fprop, plan.block_rows * 128)
        ivjk = flat.reshape(Q, -1, 128).transpose(1, 0, 2)  # (S/128, Q, 128)
        post = kernel.collide_ivjk(ivjk, omega, bsb=plan.block_rows)
        post = post.transpose(1, 0, 2).reshape(Q, -1)[:, :s].reshape(shape)
    if mask is not None:
        post = jnp.where(mask[None], post, f)
    return post


@functools.partial(jax.jit, static_argnames=("iters", "layout"))
def lbm_run(f: jax.Array, omega: float, iters: int, *, layout: str = "ivjk") -> jax.Array:
    return jax.lax.fori_loop(0, iters, lambda _, x: lbm_step(x, omega, layout=layout), f)


def init_equilibrium(n: int, dtype=jnp.float32) -> jax.Array:
    """Unit-density fluid at rest with a small sinusoidal shear (gives the
    tests a non-trivial but stable flow)."""
    rho = jnp.ones((n, n, n), dtype)
    x = jnp.linspace(0, 2 * jnp.pi, n, endpoint=False, dtype=dtype)
    ux = 0.02 * jnp.sin(x)[None, None, :] * jnp.ones((n, n, n), dtype)
    u = jnp.stack([ux, jnp.zeros_like(ux), jnp.zeros_like(ux)])
    return ref.equilibrium(rho, u)


# ---- accounting (paper numbers) -------------------------------------------

def site_bytes(elem_bytes: int = 8, *, rfo: bool = True) -> int:
    """Paper: 19 reads + 19 writes (+19 RFO) = 456 B/site at 8 B elems."""
    return (3 if rfo else 2) * Q * elem_bytes


def site_flops() -> int:
    """~180 flops/site for D3Q19 BGK (paper's ~2.5 B/flop at 456 B)."""
    return 180


def layout_balance_scores(
    model: InterleavedMemoryModel | None = None,
    *,
    n: int = 100,
    elem_bytes: int = 8,
) -> tuple[str, dict[str, float]]:
    """Conflict-model comparison of the two layouts (paper Fig. 7 analysis).

    Stream bases for the 19 write streams of one thread on a cubic N^3
    domain (Fortran notation, i fastest):
      soa  (IJKv, f(i,j,k,v)) -- direction v starts at v * N^3 * elem_bytes:
           for any N with 64 | N^3 the bases all alias onto one channel,
      ivjk (f(i,v,j,k))       -- direction v starts at v * N * elem_bytes:
           for generic N the 19 odd-count streams spread over the channels
           ("the fortunate number of 19 distribution functions leads to an
           automatic skew"), collapsing only when N % 64 == 0 -- the paper's
           residual "ruinous" cache-thrashing sizes, removable by padding.
    """
    s = n ** 3
    soa_bases = [v * s * elem_bytes for v in range(Q)]
    ivjk_bases = [v * n * elem_bytes for v in range(Q)]
    mask = [True] * Q
    return choose_layout(
        {"soa": (soa_bases, mask), "ivjk": (ivjk_bases, mask)},
        model or InterleavedMemoryModel(),
    )
