"""Deprecation shims for the pre-registry kernel wrappers.

The hand-rolled per-family wrappers (``stream_triad``, ``jacobi_step``, ...)
are kept importable for one release but now forward to the unified
``repro.api.launch`` path.  Each call emits a ``FutureWarning`` naming the
replacement -- FutureWarning (unlike DeprecationWarning) is shown by
Python's default filters even from library frames, so callers actually see
the one-release migration signal; the filters still de-duplicate repeats
per call site.
"""
from __future__ import annotations

import functools
import warnings


def deprecated_wrapper(kernel_name: str, *, resolver=None):
    """Mark a wrapper as a deprecated shim for registered ``kernel_name``.

    ``resolver(*args, **kwargs)`` may compute the replacement kernel name
    from the actual call (e.g. ``lbm_step``'s ``layout=`` argument picks
    between ``lbm.soa`` and ``lbm.ivjk``); ``kernel_name`` is the default.
    """

    def deco(fn):
        @functools.wraps(fn)
        def shim(*args, **kwargs):
            target = resolver(*args, **kwargs) if resolver else kernel_name
            warnings.warn(
                f"{fn.__name__}() is deprecated; "
                f"use repro.api.launch({target!r}, ...) "
                f"(migration table: docs/API.md)",
                FutureWarning,
                stacklevel=2,
            )
            return fn(*args, **kwargs)

        shim.__deprecated_for__ = kernel_name
        return shim

    return deco
