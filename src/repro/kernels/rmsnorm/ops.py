"""Jitted wrappers: flatten leading dims, lane-pad the feature dim."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.layout import LANES, SUBLANES, round_up
from repro.kernels.rmsnorm import kernel


def _prep(x: jax.Array):
    *lead, d = x.shape
    rows = 1
    for s in lead:
        rows *= s
    x2 = x.reshape(rows, d)
    wp = round_up(d, LANES)
    rp = round_up(rows, SUBLANES)
    x2 = jnp.pad(x2, ((0, rp - rows), (0, wp - d)))
    return x2, lead, rows, d, wp


@functools.partial(jax.jit, static_argnames=("eps",))
def rmsnorm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    x2, lead, rows, d, wp = _prep(x)
    s = jnp.pad(scale, (0, wp - d))
    y = kernel.rmsnorm2d(x2, s, d_logical=d, eps=eps)
    return y[:rows, :d].reshape(*lead, d)


@functools.partial(jax.jit, static_argnames=("eps",))
def gated_rmsnorm(x: jax.Array, z: jax.Array, scale: jax.Array, *,
                  eps: float = 1e-6) -> jax.Array:
    x2, lead, rows, d, wp = _prep(x)
    z2 = _prep(z)[0]
    s = jnp.pad(scale, (0, wp - d))
    y = kernel.gated_rmsnorm2d(x2, z2, s, d_logical=d, eps=eps)
    return y[:rows, :d].reshape(*lead, d)
