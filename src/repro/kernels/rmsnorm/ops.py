"""Jitted wrappers: flatten leading dims, planner-derived lane padding."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.planner import plan_kernel
from repro.kernels.rmsnorm import kernel


def _prep(x: jax.Array, family: str):
    *lead, d = x.shape
    rows = 1
    for s in lead:
        rows *= s
    plan = plan_kernel(family, (rows, d), x.dtype)
    rp, wp = plan.padded_shape
    x2 = x.reshape(rows, d)
    x2 = jnp.pad(x2, ((0, rp - rows), (0, wp - d)))
    return x2, lead, rows, d, wp, plan


@functools.partial(jax.jit, static_argnames=("eps",))
def rmsnorm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    x2, lead, rows, d, wp, plan = _prep(x, "rmsnorm")
    s = jnp.pad(scale, (0, wp - d))
    y = kernel.rmsnorm2d(x2, s, d_logical=d, eps=eps, brows=plan.block_rows)
    return y[:rows, :d].reshape(*lead, d)


@functools.partial(jax.jit, static_argnames=("eps",))
def gated_rmsnorm(x: jax.Array, z: jax.Array, scale: jax.Array, *,
                  eps: float = 1e-6) -> jax.Array:
    x2, lead, rows, d, wp, plan = _prep(x, "rmsnorm.gated")
    z2 = _prep(z, "rmsnorm.gated")[0]
    s = jnp.pad(scale, (0, wp - d))
    y = kernel.gated_rmsnorm2d(x2, z2, s, d_logical=d, eps=eps,
                               brows=plan.block_rows)
    return y[:rows, :d].reshape(*lead, d)
