"""RMSNorm (plain + gated): registry entries, planner-derived lane padding.

Leading dims flatten into rows; the planner pads rows to the dtype's sublane
tile and the feature dim to a lane multiple (x TP when a mesh is ambient).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.api import dispatch
from repro.api.registry import register_kernel
from repro.api.spmd import Partitioning
from repro.core.autotune import StreamSignature
from repro.kernels._shims import deprecated_wrapper
from repro.kernels.rmsnorm import kernel, ref
from repro.kernels.util import plan_args_rows


def _plan_args_plain(x, scale, **_scalars):
    if scale.shape != x.shape[-1:]:
        raise ValueError(
            f"scale shape {scale.shape} must match minor dim of {x.shape}"
        )
    return plan_args_rows(x)


def _plan_args_gated(x, z, scale, **_scalars):
    # z is padded with the plan derived from x; a mismatched z would
    # otherwise be silently zero-padded into wrong output rows.
    if z.shape != x.shape:
        raise ValueError(f"z shape {z.shape} must match x shape {x.shape}")
    return _plan_args_plain(x, scale)


def _pad_rows(x: jax.Array, plan) -> tuple[jax.Array, tuple[int, ...], int, int]:
    *lead, d = x.shape
    rows = 1
    for s in lead:
        rows *= s
    rp, wp = plan.padded_shape
    x2 = jnp.pad(x.reshape(rows, d), ((0, rp - rows), (0, wp - d)))
    return x2, tuple(lead), rows, d


@functools.partial(jax.jit, static_argnames=("plan", "eps"))
def _rmsnorm(x, scale, *, plan, eps):
    x2, lead, rows, d = _pad_rows(x, plan)
    s = jnp.pad(scale, (0, plan.width - d))
    y = kernel.rmsnorm2d(x2, s, d_logical=d, eps=eps, brows=plan.block_rows)
    return y[:rows, :d].reshape(*lead, d)


@functools.partial(jax.jit, static_argnames=("plan", "eps"))
def _gated(x, z, scale, *, plan, eps):
    x2, lead, rows, d = _pad_rows(x, plan)
    z2 = _pad_rows(z, plan)[0]
    s = jnp.pad(scale, (0, plan.width - d))
    y = kernel.gated_rmsnorm2d(x2, z2, s, d_logical=d, eps=eps,
                               brows=plan.block_rows)
    return y[:rows, :d].reshape(*lead, d)


# Row statistics are per-row: shard the leading (token/batch) axis, keep
# the feature dim whole and the scale vector replicated.  The ``...`` lets
# one template serve both the 2-D (rows, d) kernel call and the 3-D
# (B, S, d) model call.
_ROWWISE = Partitioning(in_axes=(("batch", ..., None), (None,)),
                        out_axes=("batch", ..., None))
_ROWWISE_GATED = Partitioning(
    in_axes=(("batch", ..., None), ("batch", ..., None), (None,)),
    out_axes=("batch", ..., None))


@register_kernel("rmsnorm", signature=StreamSignature(n_read=2, n_write=1),
                 ref=lambda x, scale, *, eps=1e-6: ref.rmsnorm(x, scale, eps),
                 plan_args=_plan_args_plain, partitioning=_ROWWISE)
def _launch_rmsnorm(plan, x, scale, *, eps: float = 1e-6):
    """y = x * rsqrt(mean(x^2) + eps) * scale, fused over row blocks."""
    return _rmsnorm(x, scale, plan=plan, eps=eps)


@register_kernel("rmsnorm.gated",
                 signature=StreamSignature(n_read=3, n_write=1),
                 ref=lambda x, z, scale, *, eps=1e-6:
                     ref.gated_rmsnorm(x, z, scale, eps),
                 plan_args=_plan_args_gated, partitioning=_ROWWISE_GATED)
def _launch_gated(plan, x, z, scale, *, eps: float = 1e-6):
    """Gated variant: normalize x * silu(z) (mamba2/xlstm norm path)."""
    return _gated(x, z, scale, plan=plan, eps=eps)


@deprecated_wrapper("rmsnorm")
def rmsnorm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    return dispatch.launch("rmsnorm", x, scale, eps=eps)


@deprecated_wrapper("rmsnorm.gated")
def gated_rmsnorm(x: jax.Array, z: jax.Array, scale: jax.Array, *,
                  eps: float = 1e-6) -> jax.Array:
    return dispatch.launch("rmsnorm.gated", x, z, scale, eps=eps)
