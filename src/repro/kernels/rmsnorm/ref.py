"""Pure-jnp oracles for the RMSNorm kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    ms = (xf * xf).mean(-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(
        x.dtype
    )


def gated_rmsnorm(x: jax.Array, z: jax.Array, scale: jax.Array,
                  eps: float = 1e-6) -> jax.Array:
    g = (x.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))).astype(
        x.dtype
    )
    return rmsnorm(g, scale, eps)
