"""Fused RMSNorm Pallas kernel (plain and gated variants).

One (block_rows, d) VMEM tile per grid step: the row statistics, scaling and
(for the gated form) the silu-gate multiply all happen in one pass -- the
unfused jnp form reads x three times (square-mean, normalize, scale) from
HBM when XLA declines to fuse across the fp32 cast boundary.  d is padded to
a lane multiple by ops.py; statistics are computed in fp32 over the logical
columns only (index-masked, the layout-policy rule again).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.util import INTERPRET, block_rows


def _rms(x: jax.Array, d_logical: int, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    col = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    xf = jnp.where(col < d_logical, xf, 0.0)
    ms = jnp.sum(xf * xf, axis=-1, keepdims=True) / d_logical
    return xf * jax.lax.rsqrt(ms + eps)


def _plain_kernel(x_ref, s_ref, o_ref, *, d_logical: int, eps: float):
    y = _rms(x_ref[...], d_logical, eps) * s_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def _gated_kernel(x_ref, z_ref, s_ref, o_ref, *, d_logical: int, eps: float):
    xf = x_ref[...].astype(jnp.float32)
    zf = z_ref[...].astype(jnp.float32)
    g = xf * (zf * jax.nn.sigmoid(zf))           # x * silu(z)
    y = _rms(g.astype(x_ref.dtype), d_logical, eps) * s_ref[...].astype(
        jnp.float32
    )
    o_ref[...] = y.astype(o_ref.dtype)


def _call(kernel, args, rows, width, dtype, brows):
    brows = brows or block_rows(rows)
    spec = pl.BlockSpec((brows, width), lambda i: (i, 0))
    svec = pl.BlockSpec((width,), lambda i: (0,))
    in_specs = [spec] * (len(args) - 1) + [svec]
    return pl.pallas_call(
        kernel,
        grid=(rows // brows,),
        in_specs=in_specs,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, width), dtype),
        interpret=INTERPRET,
    )(*args)


def rmsnorm2d(x: jax.Array, scale: jax.Array, *, d_logical: int,
              eps: float = 1e-6, brows: int | None = None) -> jax.Array:
    rows, width = x.shape
    k = functools.partial(_plain_kernel, d_logical=d_logical, eps=eps)
    return _call(k, [x, scale], rows, width, x.dtype, brows)


def gated_rmsnorm2d(x: jax.Array, z: jax.Array, scale: jax.Array, *,
                    d_logical: int, eps: float = 1e-6,
                    brows: int | None = None) -> jax.Array:
    rows, width = x.shape
    k = functools.partial(_gated_kernel, d_logical=d_logical, eps=eps)
    return _call(k, [x, z, scale], rows, width, x.dtype, brows)
