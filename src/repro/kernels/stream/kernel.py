"""McCalpin STREAM kernels (paper SS2.1) as Pallas TPU kernels.

copy:  C = A          scale: B = s*C
add:   C = A + B      triad: A = B + s*C

Each kernel streams (block_rows, width) VMEM tiles over a 1-D grid.  The
BlockSpec tiling *is* the alignment policy: every DMA is whole (8,128)
tiles, so no stream can start at a misaligned phase -- the TPU equivalent of
the paper's 512 B segment alignment.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.util import INTERPRET, block_rows


def _copy_kernel(a_ref, c_ref):
    c_ref[...] = a_ref[...]


def _scale_kernel(c_ref, s_ref, b_ref):
    b_ref[...] = s_ref[0] * c_ref[...]


def _add_kernel(a_ref, b_ref, c_ref):
    c_ref[...] = a_ref[...] + b_ref[...]


def _triad_kernel(b_ref, c_ref, s_ref, a_ref):
    a_ref[...] = b_ref[...] + s_ref[0] * c_ref[...]


def _call(kernel, inputs, scalar, out_dtype, *, brows=None):
    rows, width = inputs[0].shape
    brows = brows or block_rows(rows)
    grid = (rows // brows,)
    spec = pl.BlockSpec((brows, width), lambda i: (i, 0))
    in_specs = [spec] * len(inputs)
    args = list(inputs)
    if scalar is not None:
        in_specs.append(pl.BlockSpec(memory_space=pl.ANY))
        args.append(jnp.asarray([scalar], dtype=out_dtype))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, width), out_dtype),
        interpret=INTERPRET,
    )(*args)


def copy2d(a: jax.Array, *, brows: int | None = None) -> jax.Array:
    return _call(_copy_kernel, [a], None, a.dtype, brows=brows)


def scale2d(c: jax.Array, s: float, *, brows: int | None = None) -> jax.Array:
    return _call(_scale_kernel, [c], s, c.dtype, brows=brows)


def add2d(a: jax.Array, b: jax.Array, *, brows: int | None = None) -> jax.Array:
    return _call(_add_kernel, [a, b], None, a.dtype, brows=brows)


def triad2d(b: jax.Array, c: jax.Array, s: float, *, brows: int | None = None) -> jax.Array:
    return _call(_triad_kernel, [b, c], s, b.dtype, brows=brows)
