"""STREAM kernels as registry entries (1-D API).

Each kernel declares its stream signature, oracle, and Pallas body via
``@register_kernel``; the unified ``repro.api.launch`` path resolves the
analytic plan (padded 2-D shape, VMEM block) under the ambient
``PlanContext`` and calls the body.  The old public wrappers
(``stream_copy`` etc.) remain as deprecated shims forwarding to the
registry.  ``bytes_moved`` reports STREAM-convention traffic (no RFO) and
``bytes_moved_rfo`` the true traffic, mirroring the paper's 4/3 remark.
"""
from __future__ import annotations

import functools

import jax

from repro.api import dispatch
from repro.api.registry import register_kernel
from repro.api.spmd import Partitioning
from repro.core.autotune import StreamSignature
from repro.core.planner import KernelPlan
from repro.kernels._shims import deprecated_wrapper
from repro.kernels.stream import kernel, ref
from repro.kernels.util import from_tiles, plan_args_1d, to_tiles


@functools.partial(jax.jit, static_argnames=("plan",))
def _copy(a, *, plan):
    a2, n = to_tiles(a, plan=plan)
    return from_tiles(kernel.copy2d(a2, brows=plan.block_rows), n)


@functools.partial(jax.jit, static_argnames=("plan",))
def _scale(c, s, *, plan):
    c2, n = to_tiles(c, plan=plan)
    return from_tiles(kernel.scale2d(c2, s, brows=plan.block_rows), n)


@functools.partial(jax.jit, static_argnames=("plan",))
def _add(a, b, *, plan):
    a2, n = to_tiles(a, plan=plan)
    b2, _ = to_tiles(b, plan=plan)
    return from_tiles(kernel.add2d(a2, b2, brows=plan.block_rows), n)


@functools.partial(jax.jit, static_argnames=("plan",))
def _triad(b, c, s, *, plan):
    b2, n = to_tiles(b, plan=plan)
    c2, _ = to_tiles(c, plan=plan)
    return from_tiles(kernel.triad2d(b2, c2, s, brows=plan.block_rows), n)


# 1-D streams are embarrassingly batch-parallel: shard the vector over
# the data axis, each device runs the planned kernel on its slice.
_ELEMENTWISE_1D = lambda n: Partitioning(
    in_axes=(("batch",),) * n, out_axes=("batch",))


@register_kernel("stream.copy", signature=StreamSignature(n_read=1, n_write=1),
                 ref=lambda a: ref.copy(a), plan_args=plan_args_1d,
                 partitioning=_ELEMENTWISE_1D(1))
def _launch_copy(plan, a):
    """C = A, streamed as whole (sublane, 128) tiles."""
    return _copy(a, plan=plan)


@register_kernel("stream.scale",
                 signature=StreamSignature(n_read=1, n_write=1),
                 ref=lambda c, *, s: ref.scale(c, s), plan_args=plan_args_1d,
                 partitioning=_ELEMENTWISE_1D(1))
def _launch_scale(plan, c, *, s):
    """B = s * C."""
    return _scale(c, s, plan=plan)


@register_kernel("stream.add", signature=StreamSignature(n_read=2, n_write=1),
                 ref=lambda a, b: ref.add(a, b), plan_args=plan_args_1d,
                 partitioning=_ELEMENTWISE_1D(2))
def _launch_add(plan, a, b):
    """C = A + B."""
    return _add(a, b, plan=plan)


@register_kernel("stream.triad",
                 signature=StreamSignature(n_read=2, n_write=1),
                 ref=lambda b, c, *, s: ref.triad(b, c, s),
                 plan_args=plan_args_1d,
                 partitioning=_ELEMENTWISE_1D(2))
def _launch_triad(plan, b, c, *, s):
    """A = B + s * C (the paper's bandwidth headline)."""
    return _triad(b, c, s, plan=plan)


# ---- deprecated shims (one release): forward to the registry --------------

@deprecated_wrapper("stream.copy")
def stream_copy(a: jax.Array, *, plan: KernelPlan | None = None) -> jax.Array:
    return dispatch.launch("stream.copy", a, plan=plan)


@deprecated_wrapper("stream.scale")
def stream_scale(c: jax.Array, s: float, *,
                 plan: KernelPlan | None = None) -> jax.Array:
    return dispatch.launch("stream.scale", c, s=s, plan=plan)


@deprecated_wrapper("stream.add")
def stream_add(a: jax.Array, b: jax.Array, *,
               plan: KernelPlan | None = None) -> jax.Array:
    return dispatch.launch("stream.add", a, b, plan=plan)


@deprecated_wrapper("stream.triad")
def stream_triad(b: jax.Array, c: jax.Array, s: float, *,
                 plan: KernelPlan | None = None) -> jax.Array:
    return dispatch.launch("stream.triad", b, c, s=s, plan=plan)


def bytes_moved(op: str, n: int, elem_bytes: int = 8) -> int:
    """STREAM-reported bytes (store not counted as RFO read)."""
    streams = {"copy": 2, "scale": 2, "add": 3, "triad": 3}[op]
    return streams * n * elem_bytes


def bytes_moved_rfo(op: str, n: int, elem_bytes: int = 8) -> int:
    """True traffic including read-for-ownership on the store stream."""
    streams = {"copy": 3, "scale": 3, "add": 4, "triad": 4}[op]
    return streams * n * elem_bytes
