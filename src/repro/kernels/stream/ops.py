"""Jitted public wrappers for the STREAM kernels (1-D API).

The wrapper owns the layout decision: pad+reshape the 1-D array to whole
(8,128)-tileable 2-D form (``to_tiles``), run the Pallas kernel, and slice
the logical result back out.  ``bytes_moved`` reports STREAM-convention
traffic (no RFO) and ``bytes_moved_rfo`` the true traffic, mirroring the
paper's 4/3 remark.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.stream import kernel
from repro.kernels.util import from_tiles, to_tiles


@functools.partial(jax.jit, static_argnames=("width",))
def stream_copy(a: jax.Array, *, width: int = 1024) -> jax.Array:
    a2, n = to_tiles(a, width)
    return from_tiles(kernel.copy2d(a2), n)


@functools.partial(jax.jit, static_argnames=("width",))
def stream_scale(c: jax.Array, s: float, *, width: int = 1024) -> jax.Array:
    c2, n = to_tiles(c, width)
    return from_tiles(kernel.scale2d(c2, s), n)


@functools.partial(jax.jit, static_argnames=("width",))
def stream_add(a: jax.Array, b: jax.Array, *, width: int = 1024) -> jax.Array:
    a2, n = to_tiles(a, width)
    b2, _ = to_tiles(b, width)
    return from_tiles(kernel.add2d(a2, b2), n)


@functools.partial(jax.jit, static_argnames=("width",))
def stream_triad(b: jax.Array, c: jax.Array, s: float, *, width: int = 1024) -> jax.Array:
    b2, n = to_tiles(b, width)
    c2, _ = to_tiles(c, width)
    return from_tiles(kernel.triad2d(b2, c2, s), n)


def bytes_moved(op: str, n: int, elem_bytes: int = 8) -> int:
    """STREAM-reported bytes (store not counted as RFO read)."""
    streams = {"copy": 2, "scale": 2, "add": 3, "triad": 3}[op]
    return streams * n * elem_bytes


def bytes_moved_rfo(op: str, n: int, elem_bytes: int = 8) -> int:
    """True traffic including read-for-ownership on the store stream."""
    streams = {"copy": 3, "scale": 3, "add": 4, "triad": 4}[op]
    return streams * n * elem_bytes
