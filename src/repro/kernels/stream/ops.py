"""Jitted public wrappers for the STREAM kernels (1-D API).

The wrapper owns the layout decision, but no longer hard-codes it: the
analytic planner (``core/planner``) derives the padded 2-D shape and the
VMEM block from each kernel's stream signature, memoized per
``(kernel, shape, dtype)``.  The wrapper pads+reshapes the 1-D array to the
planned whole-tile form (``to_tiles``), runs the Pallas kernel over the
planned blocks, and slices the logical result back out.  ``bytes_moved``
reports STREAM-convention traffic (no RFO) and ``bytes_moved_rfo`` the true
traffic, mirroring the paper's 4/3 remark.
"""
from __future__ import annotations

import functools

import jax

from repro.core.planner import KernelPlan, plan_kernel
from repro.kernels.stream import kernel
from repro.kernels.util import from_tiles, to_tiles


@functools.partial(jax.jit, static_argnames=("plan",))
def _copy(a, *, plan):
    a2, n = to_tiles(a, plan=plan)
    return from_tiles(kernel.copy2d(a2, brows=plan.block_rows), n)


@functools.partial(jax.jit, static_argnames=("plan",))
def _scale(c, s, *, plan):
    c2, n = to_tiles(c, plan=plan)
    return from_tiles(kernel.scale2d(c2, s, brows=plan.block_rows), n)


@functools.partial(jax.jit, static_argnames=("plan",))
def _add(a, b, *, plan):
    a2, n = to_tiles(a, plan=plan)
    b2, _ = to_tiles(b, plan=plan)
    return from_tiles(kernel.add2d(a2, b2, brows=plan.block_rows), n)


@functools.partial(jax.jit, static_argnames=("plan",))
def _triad(b, c, s, *, plan):
    b2, n = to_tiles(b, plan=plan)
    c2, _ = to_tiles(c, plan=plan)
    return from_tiles(kernel.triad2d(b2, c2, s, brows=plan.block_rows), n)


def stream_copy(a: jax.Array, *, plan: KernelPlan | None = None) -> jax.Array:
    plan = plan or plan_kernel("stream.copy", a.shape, a.dtype)
    return _copy(a, plan=plan)


def stream_scale(c: jax.Array, s: float, *,
                 plan: KernelPlan | None = None) -> jax.Array:
    plan = plan or plan_kernel("stream.scale", c.shape, c.dtype)
    return _scale(c, s, plan=plan)


def stream_add(a: jax.Array, b: jax.Array, *,
               plan: KernelPlan | None = None) -> jax.Array:
    plan = plan or plan_kernel("stream.add", a.shape, a.dtype)
    return _add(a, b, plan=plan)


def stream_triad(b: jax.Array, c: jax.Array, s: float, *,
                 plan: KernelPlan | None = None) -> jax.Array:
    plan = plan or plan_kernel("stream.triad", b.shape, b.dtype)
    return _triad(b, c, s, plan=plan)


def bytes_moved(op: str, n: int, elem_bytes: int = 8) -> int:
    """STREAM-reported bytes (store not counted as RFO read)."""
    streams = {"copy": 2, "scale": 2, "add": 3, "triad": 3}[op]
    return streams * n * elem_bytes


def bytes_moved_rfo(op: str, n: int, elem_bytes: int = 8) -> int:
    """True traffic including read-for-ownership on the store stream."""
    streams = {"copy": 3, "scale": 3, "add": 4, "triad": 4}[op]
    return streams * n * elem_bytes
