"""Pure-jnp oracles for the STREAM kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def copy(a: jax.Array) -> jax.Array:
    return a + 0  # force a materialized copy under jit


def scale(c: jax.Array, s: float) -> jax.Array:
    return jnp.asarray(s, c.dtype) * c


def add(a: jax.Array, b: jax.Array) -> jax.Array:
    return a + b


def triad(b: jax.Array, c: jax.Array, s: float) -> jax.Array:
    return b + jnp.asarray(s, b.dtype) * c
