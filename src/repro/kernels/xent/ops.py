"""Jitted wrapper: planner-derived padding policy + mean reduction."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.layout import round_up
from repro.core.planner import plan_kernel
from repro.kernels.xent import kernel


@functools.partial(jax.jit, static_argnames=("logical_v", "bt", "bv"))
def xent_mean(logits: jax.Array, labels: jax.Array, *, logical_v: int = 0,
              bt: int | None = None, bv: int | None = None) -> jax.Array:
    """Mean NLL over (T,) tokens; pads T and V to (bt, bv) tile multiples.

    The (bt, bv) tile defaults to the planner's choice for this (T, V) and
    dtype (one online-softmax working set per VMEM budget); explicit bt/bv
    remain as overrides.  Padded *tokens* get label 0 against a -inf-masked
    row contribution of exactly lse-only... they are excluded by weighting
    instead.
    """
    t, v = logits.shape
    logical_v = logical_v or v
    if bt is None or bv is None:
        plan = plan_kernel("xent", (t, v), logits.dtype)
        bt = bt or plan.block_rows
        bv = bv or plan.block_cols
    tp = round_up(t, bt)
    vp = round_up(v, bv)
    lg = jnp.pad(logits, ((0, tp - t), (0, vp - v)))
    lb = jnp.pad(labels.astype(jnp.int32), (0, tp - t))
    nll = kernel.xent_tiled(lg, lb, logical_v=logical_v, bt=bt, bv=bv)
    return nll[:t].mean()
