"""Tiled cross-entropy: registry entry, planner-derived online-softmax tile.

Padded *tokens* get label 0 against a -inf-masked row contribution of
exactly lse-only; they are excluded by slicing before the mean.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.api import dispatch
from repro.api.registry import register_kernel
from repro.api.spmd import SCALAR, Partitioning
from repro.core.autotune import StreamSignature
from repro.core.layout import round_up
from repro.kernels._shims import deprecated_wrapper
from repro.kernels.xent import kernel, ref


def _plan_args(logits, labels=None, **_scalars):
    return tuple(logits.shape), logits.dtype


def _ref(logits, labels, *, logical_v: int = 0):
    lv = logical_v or logits.shape[-1]
    return ref.xent(logits, labels, logical_v=lv).mean()


@functools.partial(jax.jit, static_argnames=("logical_v", "tp", "vp",
                                             "bt", "bv"))
def _xent_padded(logits, labels, *, logical_v, tp, vp, bt, bv):
    t, v = logits.shape
    lg = jnp.pad(logits, ((0, tp - t), (0, vp - v)))
    lb = jnp.pad(labels.astype(jnp.int32), (0, tp - t))
    nll = kernel.xent_tiled(lg, lb, logical_v=logical_v, bt=bt, bv=bv)
    return nll[:t].mean()


@register_kernel("xent", signature=StreamSignature(n_read=2, n_write=1),
                 ref=_ref, plan_args=_plan_args, col_tiled=True,
                 # Tokens shard over the batch axes; the vocab dim stays
                 # whole per shard (the online softmax needs the full row).
                 # Each shard's mean NLL covers its own tokens, so equal
                 # shards combine exactly with a pmean.
                 partitioning=Partitioning(
                     in_axes=(("batch", None), ("batch",)),
                     out_axes=SCALAR, reduce="mean"))
def _launch_xent(plan, logits, labels, *, logical_v: int = 0):
    """Mean NLL over (T,) tokens; the plan's (block_rows, block_cols) is the
    online-softmax working set, (T, V) padded to the planned physical
    shape."""
    t, v = logits.shape
    tp, vp = plan.padded_shape
    return _xent_padded(logits, labels, logical_v=logical_v or v,
                        tp=tp, vp=vp, bt=plan.block_rows, bv=plan.block_cols)


@deprecated_wrapper("xent")
def xent_mean(logits: jax.Array, labels: jax.Array, *, logical_v: int = 0,
              bt: int | None = None, bv: int | None = None) -> jax.Array:
    """Deprecated shim.  Explicit ``bt``/``bv`` remain as overrides of the
    planned tile; without them this is ``api.launch("xent", ...)``."""
    if bt is None and bv is None:
        return dispatch.launch("xent", logits, labels, logical_v=logical_v)
    t, v = logits.shape
    if bt is None or bv is None:  # plan only for the tile not given
        plan = dispatch.plan_for("xent", (t, v), logits.dtype)
        bt = bt or plan.block_rows
        bv = bv or plan.block_cols
    return _xent_padded(logits, labels, logical_v=logical_v or v,
                        tp=round_up(t, bt), vp=round_up(v, bv), bt=bt, bv=bv)
