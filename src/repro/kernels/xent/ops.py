"""Jitted wrapper: padding policy + mean reduction for the xent kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.layout import round_up
from repro.kernels.xent import kernel


@functools.partial(jax.jit, static_argnames=("logical_v", "bt", "bv"))
def xent_mean(logits: jax.Array, labels: jax.Array, *, logical_v: int = 0,
              bt: int = 256, bv: int = 2048) -> jax.Array:
    """Mean NLL over (T,) tokens; pads T to bt and V to bv multiples.

    Padded *tokens* get label 0 against a -inf-masked row contribution of
    exactly lse-only... they are excluded by weighting instead.
    """
    t, v = logits.shape
    logical_v = logical_v or v
    tp = round_up(t, bt)
    vp = round_up(v, bv)
    lg = jnp.pad(logits, ((0, tp - t), (0, vp - v)))
    lb = jnp.pad(labels.astype(jnp.int32), (0, tp - t))
    nll = kernel.xent_tiled(lg, lb, logical_v=logical_v, bt=bt, bv=bv)
    return nll[:t].mean()
