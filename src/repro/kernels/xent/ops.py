"""Tiled cross-entropy: registry entry, planner-derived online-softmax tile.

Padded *tokens* get label 0 against a -inf-masked row contribution of
exactly lse-only; they are excluded by slicing before the mean.

Under an SPMD mesh the kernel is *vocab-parallel* (Megatron layout): the
logits' vocab axis shards over the mesh's model axis, each shard folds its
own vocab slice with the Pallas online-softmax partial kernel, and the
shard_map body combines the per-shard (max, sumexp, label-logit) with a
cross-shard log-sum-exp -- ``pmax`` of the max, ``psum`` of the rescaled
sumexp and of the locally-gathered target logit:

    m   = pmax_k(m_k)
    lse = log(psum_k(l_k * exp(m_k - m))) + m
    nll = lse - psum_k(ll_k)

Three token-length fp32 vectors cross the wire instead of a replicated
(T, V) logits array.  ``xent_grad`` is the matching vocab-parallel
backward (softmax - onehot against the globally-combined lse), so the
fused ``lm_loss`` keeps the layout end to end.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.api import dispatch
from repro.api.registry import register_kernel
from repro.api.spmd import SCALAR, Partitioning
from repro.core.autotune import StreamSignature
from repro.core.layout import round_up
from repro.kernels._shims import deprecated_wrapper
from repro.kernels.xent import kernel, ref


def _plan_args(logits, labels=None, **_scalars):
    return tuple(logits.shape), logits.dtype


def _ref(logits, labels, *, logical_v: int = 0):
    lv = logical_v or logits.shape[-1]
    return ref.xent(logits, labels, logical_v=lv).mean()


@functools.partial(jax.jit, static_argnames=("logical_v", "tp", "vp",
                                             "bt", "bv"))
def _xent_padded(logits, labels, *, logical_v, tp, vp, bt, bv):
    t, v = logits.shape
    lg = jnp.pad(logits, ((0, tp - t), (0, vp - v)))
    lb = jnp.pad(labels.astype(jnp.int32), (0, tp - t))
    nll = kernel.xent_tiled(lg, lb, logical_v=logical_v, bt=bt, bv=bv)
    return nll[:t].mean()


@functools.partial(jax.jit, static_argnames=("vl", "logical_v", "tp", "vp",
                                             "bt", "bv"))
def _xent_partial_padded(logits, labels, offset, *, vl, logical_v, tp, vp,
                         bt, bv):
    """Per-token (m, l, ll) partials for one padded vocab shard, sliced back
    to the logical token count."""
    t, v = logits.shape
    lg = jnp.pad(logits, ((0, tp - t), (0, vp - v)))
    lb = jnp.pad(labels.astype(jnp.int32), (0, tp - t))
    m, l, ll = kernel.xent_partial_tiled(
        lg, lb, jnp.reshape(offset.astype(jnp.int32), (1,)),
        vl=vl, logical_v=logical_v, bt=bt, bv=bv)
    return m[:t], l[:t], ll[:t]


def _spmd_xent(ctx, logits, labels, *, logical_v: int = 0):
    """shard_map body: vocab-parallel fused cross-entropy.

    ``logits`` is this shard's (T_local, V_local) slice.  When the vocab
    axis actually sharded (divisible vocab, model axis > 1), the Pallas
    partial kernel folds the local slice and the lse combine crosses shards
    with pmax/psum; otherwise this degrades to the full-vocab fused NLL
    per token shard.  Either way the scalar mean crosses the batch axes
    with a pmean of equal-sized shard means.
    """
    t, vl = logits.shape
    vocab_axes = ctx.axes(0, 1)
    batch_axes = ctx.axes(0, 0)
    n_vocab = ctx.size(vocab_axes)
    if n_vocab <= 1:
        # Vocab whole on this shard (declared replication fallback, or a
        # size-1 model axis): the fused single-shard NLL path.
        plan = dispatch.plan_for("xent", (t, vl), logits.dtype, local=True)
        out = _launch_xent(plan, logits, labels, logical_v=logical_v)
        if batch_axes:
            out = jax.lax.pmean(out, batch_axes)
        return out
    lv = logical_v or vl * n_vocab
    off = ctx.index(vocab_axes) * vl
    plan = dispatch.plan_for("xent", (t, vl), logits.dtype, local=True)
    tp, vp = plan.padded_shape
    m, l, ll = _xent_partial_padded(
        logits, labels, off, vl=vl, logical_v=lv,
        tp=tp, vp=vp, bt=plan.block_rows, bv=plan.block_cols)
    # Cross-shard log-sum-exp: rescale each shard's sumexp to the global
    # max before summing; the target logit lives in exactly one shard, the
    # others contribute zero.
    mg = jax.lax.pmax(m, vocab_axes)
    l = jax.lax.psum(l * jnp.exp(m - mg), vocab_axes)
    ll = jax.lax.psum(ll, vocab_axes)
    nll = jnp.log(jnp.maximum(l, 1e-30)) + mg - ll
    out = nll.mean()
    if batch_axes:
        out = jax.lax.pmean(out, batch_axes)
    return out


@register_kernel("xent", signature=StreamSignature(n_read=2, n_write=1),
                 ref=_ref, plan_args=_plan_args, col_tiled=True,
                 # Tokens shard over the batch axes AND the vocab dim
                 # shards over the model axis (Megatron layout); the
                 # spmd_body owns the cross-shard lse combine.  SCALAR +
                 # reduce="mean" stays declared for the semantics: each
                 # shard's mean NLL covers its own tokens, so equal token
                 # shards combine exactly with a pmean.
                 partitioning=Partitioning(
                     in_axes=(("batch", "vocab"), ("batch",)),
                     out_axes=SCALAR, reduce="mean"),
                 spmd_body=_spmd_xent)
def _launch_xent(plan, logits, labels, *, logical_v: int = 0):
    """Mean NLL over (T,) tokens; the plan's (block_rows, block_cols) is the
    online-softmax working set, (T, V) padded to the planned physical
    shape."""
    t, v = logits.shape
    tp, vp = plan.padded_shape
    return _xent_padded(logits, labels, logical_v=logical_v or v,
                        tp=tp, vp=vp, bt=plan.block_rows, bv=plan.block_cols)


def xent_grad(logits: jax.Array, labels: jax.Array, g: jax.Array, *,
              logical_v: int = 0) -> jax.Array:
    """d(mean NLL)/d(logits) -- the backward half of the fused loss.

    Under an ambient SPMD mesh this is the *vocab-parallel* gradient: a
    shard_map over the same (batch, vocab) partitioning as the forward,
    each shard computing ``(softmax - onehot) * g / T`` against the
    globally-combined lse (pmax/psum over the vocab axes) -- so the fused
    ``lm_loss`` keeps the Megatron layout through the backward pass instead
    of replicating a (T, V) softmax per device.  Without a mesh it is the
    plain jnp vjp of the reference math.
    """
    from repro.api import spmd as spmd_lib

    mesh = spmd_lib.spmd_mesh()
    if mesh is None:
        _, vjp = jax.vjp(
            lambda l: _ref(l, labels, logical_v=logical_v), logits)
        return vjp(g)[0]

    from repro.api.registry import resolve
    from repro.parallel.shardmap_compat import NO_CHECK, shard_map

    g = jnp.asarray(g, jnp.float32)
    # Same partitioning as the registered forward (plus the replicated
    # cotangent scalar), derived from the declaration so the two can
    # never shard differently.
    templates = resolve("xent").partitioning.in_axes + ((),)
    in_specs, operand_axes, sizes, _ = spmd_lib.shard_specs(
        mesh, templates, (logits, labels, g))
    ctx = spmd_lib.ShardContext(operand_axes=operand_axes, axis_sizes=sizes)
    out_spec = in_specs[0]

    def _grad_body(lg, lb, gg):
        t, vl = lg.shape
        vocab_axes = ctx.axes(0, 1)
        batch_axes = ctx.axes(0, 0)
        n_vocab = ctx.size(vocab_axes)
        lv = logical_v or vl * n_vocab
        off = ctx.index(vocab_axes) * vl if vocab_axes else 0
        x = lg.astype(jnp.float32)
        col = off + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
        x = jnp.where(col < lv, x, -1e30)
        m = jnp.max(x, axis=-1)
        if n_vocab > 1:
            m = jax.lax.pmax(m, vocab_axes)
        l = jnp.sum(jnp.where(x <= -1e29, 0.0, jnp.exp(x - m[:, None])),
                    axis=-1)
        if n_vocab > 1:
            l = jax.lax.psum(l, vocab_axes)
        lse = jnp.log(jnp.maximum(l, 1e-30)) + m
        p = jnp.where(x <= -1e29, 0.0, jnp.exp(x - lse[:, None]))
        onehot = (col == lb[:, None].astype(jnp.int32)).astype(jnp.float32)
        t_total = t * ctx.size(batch_axes)
        return ((p - onehot) * (gg / t_total)).astype(logits.dtype)

    fn = shard_map(_grad_body, mesh=mesh, in_specs=in_specs,
                   out_specs=out_spec, **NO_CHECK)
    return fn(logits, labels.astype(jnp.int32), g)


@deprecated_wrapper("xent")
def xent_mean(logits: jax.Array, labels: jax.Array, *, logical_v: int = 0,
              bt: int | None = None, bv: int | None = None) -> jax.Array:
    """Deprecated shim.  Explicit ``bt``/``bv`` remain as overrides of the
    planned tile; without them this is ``api.launch("xent", ...)``."""
    if bt is None and bv is None:
        return dispatch.launch("xent", logits, labels, logical_v=logical_v)
    t, v = logits.shape
    if bt is None or bv is None:  # plan only for the tile not given
        plan = dispatch.plan_for("xent", (t, v), logits.dtype)
        bt = bt or plan.block_rows
        bv = bv or plan.block_cols
    return _xent_padded(logits, labels, logical_v=logical_v or v,
                        tp=round_up(t, bt), vp=round_up(v, bv), bt=bt, bv=bv)
