"""Tiled cross-entropy kernel (beyond-paper: the loss-layer layout fix of
EXPERIMENTS.md P0.1 as a TPU kernel).

Online-softmax over vocab tiles: for each (token-block, vocab-block) grid
cell the kernel folds the tile into running (max, sumexp, label-logit)
scratch; the final vocab tile emits per-token NLL.  The full (T, V) logits
row never needs to be resident -- the working set is one (bt, bv) tile,
exactly the paper's rule of sizing segments to the transfer resource.

Padded vocab columns (layout-policy padding) are masked by index, so the
kernel is correct for physical vocab > logical vocab.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.util import INTERPRET


def _xent_kernel(lab_ref, lg_ref, out_ref, m_ref, l_ref, ll_ref, *,
                 nv: int, bv: int, logical_v: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref[...], -1e30)
        l_ref[...] = jnp.zeros_like(l_ref[...])
        ll_ref[...] = jnp.zeros_like(ll_ref[...])

    x = lg_ref[...].astype(jnp.float32)                    # (bt, bv)
    col = j * bv + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    x = jnp.where(col < logical_v, x, -1e30)
    m_old = m_ref[...]
    m_new = jnp.maximum(m_old, jnp.max(x, axis=-1))
    p = jnp.where(x <= -1e29, 0.0, jnp.exp(x - m_new[:, None]))
    l_ref[...] = l_ref[...] * jnp.exp(m_old - m_new) + jnp.sum(p, axis=-1)
    m_ref[...] = m_new
    lab = lab_ref[...]                                     # (bt,)
    ll_ref[...] = ll_ref[...] + jnp.sum(
        jnp.where(col == lab[:, None], x, 0.0), axis=-1
    )

    @pl.when(j == nv - 1)
    def _fin():
        lse = jnp.log(jnp.maximum(l_ref[...], 1e-30)) + m_ref[...]
        out_ref[...] = -(ll_ref[...] - lse)


def _xent_partial_kernel(off_ref, lab_ref, lg_ref, m_out, l_out, ll_out,
                         m_ref, l_ref, ll_ref, *,
                         nv: int, bv: int, vl: int, logical_v: int):
    """Per-token online-softmax *partials* over one vocab shard.

    Identical fold to ``_xent_kernel``, but the final vocab tile emits the
    running (max, sumexp, label-logit) instead of the finished NLL -- the
    cross-shard lse combine (pmax/psum over the mesh's vocab axis) happens
    in the shard_map body that launched us.  ``off_ref`` holds this shard's
    global column offset (traced: it comes from ``axis_index``), so masking
    against the *global* logical vocab and the label match both work on
    local column indices: global col = local col + off.
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref[...], -1e30)
        l_ref[...] = jnp.zeros_like(l_ref[...])
        ll_ref[...] = jnp.zeros_like(ll_ref[...])

    off = off_ref[0]
    x = lg_ref[...].astype(jnp.float32)                    # (bt, bv)
    col = j * bv + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    # Local padding (col >= vl) and global logical-vocab padding
    # (col + off >= logical_v) are both masked out of the partials.
    valid = (col < vl) & (col + off < logical_v)
    x = jnp.where(valid, x, -1e30)
    m_old = m_ref[...]
    m_new = jnp.maximum(m_old, jnp.max(x, axis=-1))
    p = jnp.where(x <= -1e29, 0.0, jnp.exp(x - m_new[:, None]))
    l_ref[...] = l_ref[...] * jnp.exp(m_old - m_new) + jnp.sum(p, axis=-1)
    m_ref[...] = m_new
    lab = lab_ref[...]                                     # (bt,)
    # The label match must stay inside the valid columns: a *padded* local
    # column's global index (col + off) can alias another shard's label
    # range, and matching there would fold the -1e30 mask into ll.
    ll_ref[...] = ll_ref[...] + jnp.sum(
        jnp.where(valid & (col + off == lab[:, None]), x, 0.0), axis=-1
    )

    @pl.when(j == nv - 1)
    def _fin():
        m_out[...] = m_ref[...]
        l_out[...] = l_ref[...]
        ll_out[...] = ll_ref[...]


def xent_partial_tiled(logits: jax.Array, labels: jax.Array,
                       offset: jax.Array, *, vl: int, logical_v: int,
                       bt: int = 256, bv: int = 2048
                       ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Per-token (max, sumexp, label-logit) partials for one vocab shard.

    logits: (T, Vp) local shard (possibly padded), labels: (T,) int32
    *global* labels, offset: (1,) int32 global column offset of this shard;
    ``vl`` is the shard's logical vocab width (<= Vp), ``logical_v`` the
    *global* logical vocab.  T % bt == 0, Vp % bv == 0 (ops.py pads).
    """
    t, v = logits.shape
    assert t % bt == 0 and v % bv == 0, (logits.shape, bt, bv)
    nt, nv = t // bt, v // bv
    out = jax.ShapeDtypeStruct((t,), jnp.float32)
    return pl.pallas_call(
        functools.partial(_xent_partial_kernel, nv=nv, bv=bv, vl=vl,
                          logical_v=logical_v),
        grid=(nt, nv),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((bt,), lambda i, j: (i,)),
            pl.BlockSpec((bt, bv), lambda i, j: (i, j)),
        ],
        out_specs=[pl.BlockSpec((bt,), lambda i, j: (i,))] * 3,
        out_shape=[out, out, out],
        scratch_shapes=[
            pltpu.VMEM((bt,), jnp.float32),
            pltpu.VMEM((bt,), jnp.float32),
            pltpu.VMEM((bt,), jnp.float32),
        ],
        interpret=INTERPRET,
    )(offset, labels, logits)


def xent_tiled(logits: jax.Array, labels: jax.Array, *, logical_v: int,
               bt: int = 256, bv: int = 2048) -> jax.Array:
    """Per-token NLL. logits: (T, V), labels: (T,) int32; T % bt == 0,
    V % bv == 0 (ops.py owns the padding policy)."""
    t, v = logits.shape
    assert t % bt == 0 and v % bv == 0, (logits.shape, bt, bv)
    nt, nv = t // bt, v // bv
    return pl.pallas_call(
        functools.partial(_xent_kernel, nv=nv, bv=bv, logical_v=logical_v),
        grid=(nt, nv),
        in_specs=[
            pl.BlockSpec((bt,), lambda i, j: (i,)),
            pl.BlockSpec((bt, bv), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bt,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((t,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bt,), jnp.float32),
            pltpu.VMEM((bt,), jnp.float32),
            pltpu.VMEM((bt,), jnp.float32),
        ],
        interpret=INTERPRET,
    )(labels, logits)
