"""Tiled cross-entropy kernel (beyond-paper: the loss-layer layout fix of
EXPERIMENTS.md P0.1 as a TPU kernel).

Online-softmax over vocab tiles: for each (token-block, vocab-block) grid
cell the kernel folds the tile into running (max, sumexp, label-logit)
scratch; the final vocab tile emits per-token NLL.  The full (T, V) logits
row never needs to be resident -- the working set is one (bt, bv) tile,
exactly the paper's rule of sizing segments to the transfer resource.

Padded vocab columns (layout-policy padding) are masked by index, so the
kernel is correct for physical vocab > logical vocab.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.util import INTERPRET


def _xent_kernel(lab_ref, lg_ref, out_ref, m_ref, l_ref, ll_ref, *,
                 nv: int, bv: int, logical_v: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref[...], -1e30)
        l_ref[...] = jnp.zeros_like(l_ref[...])
        ll_ref[...] = jnp.zeros_like(ll_ref[...])

    x = lg_ref[...].astype(jnp.float32)                    # (bt, bv)
    col = j * bv + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    x = jnp.where(col < logical_v, x, -1e30)
    m_old = m_ref[...]
    m_new = jnp.maximum(m_old, jnp.max(x, axis=-1))
    p = jnp.where(x <= -1e29, 0.0, jnp.exp(x - m_new[:, None]))
    l_ref[...] = l_ref[...] * jnp.exp(m_old - m_new) + jnp.sum(p, axis=-1)
    m_ref[...] = m_new
    lab = lab_ref[...]                                     # (bt,)
    ll_ref[...] = ll_ref[...] + jnp.sum(
        jnp.where(col == lab[:, None], x, 0.0), axis=-1
    )

    @pl.when(j == nv - 1)
    def _fin():
        lse = jnp.log(jnp.maximum(l_ref[...], 1e-30)) + m_ref[...]
        out_ref[...] = -(ll_ref[...] - lse)


def xent_tiled(logits: jax.Array, labels: jax.Array, *, logical_v: int,
               bt: int = 256, bv: int = 2048) -> jax.Array:
    """Per-token NLL. logits: (T, V), labels: (T,) int32; T % bt == 0,
    V % bv == 0 (ops.py owns the padding policy)."""
    t, v = logits.shape
    assert t % bt == 0 and v % bv == 0, (logits.shape, bt, bv)
    nt, nv = t // bt, v // bv
    return pl.pallas_call(
        functools.partial(_xent_kernel, nv=nv, bv=bv, logical_v=logical_v),
        grid=(nt, nv),
        in_specs=[
            pl.BlockSpec((bt,), lambda i, j: (i,)),
            pl.BlockSpec((bt, bv), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bt,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((t,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bt,), jnp.float32),
            pltpu.VMEM((bt,), jnp.float32),
            pltpu.VMEM((bt,), jnp.float32),
        ],
        interpret=INTERPRET,
    )(labels, logits)
