"""Pure-jnp oracle for the tiled cross-entropy kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def xent(logits: jax.Array, labels: jax.Array, *, logical_v: int) -> jax.Array:
    """Per-token NLL with padded-vocab masking. logits (T, V), labels (T,)."""
    lf = logits.astype(jnp.float32)
    v = lf.shape[-1]
    if logical_v < v:
        col = jnp.arange(v)
        lf = jnp.where(col[None, :] < logical_v, lf, -1e30)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    lab = jnp.take_along_axis(lf, labels[:, None], axis=-1)[:, 0]
    return lse - lab
