"""Vector-triad: registry entry plus phased/segmented experiment variants.

``repro.api.launch("triad", b, c, d)`` is the planner-driven aligned case.
``vector_triad``            -- deprecated shim forwarding to the registry.
``vector_triad_phased``     -- per-stream element phases, reproducing the
                               paper's offset experiment: each array lives at
                               ``phase[k]`` elements into a padded buffer, so
                               stream k starts at a different lane phase.
``vector_triad_segmented``  -- SegmentedArray inputs, one Pallas call per
                               segment (the segmented-iterator port), each
                               segment planned on its own logical length.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.api import dispatch
from repro.api.registry import register_kernel
from repro.api.spmd import Partitioning
from repro.core.autotune import StreamSignature
from repro.core.planner import KernelPlan
from repro.core.segmented import SegmentedArray, seg_map
from repro.kernels._shims import deprecated_wrapper
from repro.kernels.triad import kernel, ref
from repro.kernels.util import from_tiles, plan_args_1d, to_tiles


@functools.partial(jax.jit, static_argnames=("plan",))
def _triad(b, c, d, *, plan):
    b2, n = to_tiles(b, plan=plan)
    c2, _ = to_tiles(c, plan=plan)
    d2, _ = to_tiles(d, plan=plan)
    return from_tiles(kernel.triad2d(b2, c2, d2, brows=plan.block_rows), n)


@register_kernel("triad", signature=StreamSignature(n_read=3, n_write=1),
                 ref=lambda b, c, d: ref.triad(b, c, d),
                 plan_args=plan_args_1d,
                 # elementwise over the vector: shard it over the data
                 # axis, each device triads its own slice
                 partitioning=Partitioning(in_axes=(("batch",),) * 3,
                                           out_axes=("batch",)))
def _launch_triad(plan, b, c, d):
    """Schoenauer vector triad A = B + C * D (paper SS2.2)."""
    return _triad(b, c, d, plan=plan)


@deprecated_wrapper("triad")
def vector_triad(b: jax.Array, c: jax.Array, d: jax.Array, *,
                 plan: KernelPlan | None = None) -> jax.Array:
    return dispatch.launch("triad", b, c, d, plan=plan)


@functools.partial(jax.jit, static_argnames=("phases", "plan"))
def _triad_phased(b, c, d, *, phases, plan):
    outs = []
    for x, p in zip((b, c, d), phases):
        buf = jnp.pad(x, (p, 0))  # stream starts p elements in
        outs.append(buf[p:])      # logical view back at the data
    b2, n = to_tiles(outs[0], plan=plan)
    c2, _ = to_tiles(outs[1], plan=plan)
    d2, _ = to_tiles(outs[2], plan=plan)
    return from_tiles(kernel.triad2d(b2, c2, d2, brows=plan.block_rows), n)


def vector_triad_phased(
    b: jax.Array,
    c: jax.Array,
    d: jax.Array,
    *,
    phases: tuple[int, int, int] = (0, 0, 0),
    plan: KernelPlan | None = None,
) -> jax.Array:
    """Embed stream k at element phase[k]; the kernel then reads shifted
    views.  With non-tile-multiple phases the compiler must materialize
    re-alignment copies -- the cost shows up in HLO bytes (see
    benchmarks/vector_triad.py), which is the dry-run observable for the
    paper's offset sweep."""
    plan = plan or dispatch.plan_for("triad", b.shape, b.dtype)
    return _triad_phased(b, c, d, phases=tuple(phases), plan=plan)


def vector_triad_segmented(
    a: SegmentedArray, b: SegmentedArray, c: SegmentedArray, d: SegmentedArray
) -> SegmentedArray:
    """Segmented-iterator port: per-segment Pallas triad calls, each segment
    planned on its own logical length (short segments get narrow tiles)."""

    def _one(bb: jax.Array, cc: jax.Array, dd: jax.Array) -> jax.Array:
        return dispatch.launch("triad", bb, cc, dd)

    return seg_map(_one, a, b, c, d)


def triad_bytes(n: int, elem_bytes: int = 8, *, rfo: bool = True) -> int:
    """Application traffic: 3 reads + 1 write (+1 RFO read) per element --
    the paper's 16 B/flop balance at 8-byte elements without RFO."""
    return (5 if rfo else 4) * n * elem_bytes


def triad_flops(n: int) -> int:
    return 2 * n  # one mul + one add per element
