"""Vector-triad wrappers: aligned, phased, and segmented variants.

``vector_triad``            -- tile-aligned layout (the optimized case).
``vector_triad_phased``     -- per-stream element phases, reproducing the
                               paper's offset experiment: each array lives at
                               ``phase[k]`` elements into a padded buffer, so
                               stream k starts at a different lane phase.
``vector_triad_segmented``  -- SegmentedArray inputs, one Pallas call per
                               segment (the segmented-iterator port).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.segmented import SegmentedArray, seg_map
from repro.kernels.triad import kernel
from repro.kernels.util import from_tiles, to_tiles


@functools.partial(jax.jit, static_argnames=("width",))
def vector_triad(b: jax.Array, c: jax.Array, d: jax.Array, *, width: int = 1024) -> jax.Array:
    b2, n = to_tiles(b, width)
    c2, _ = to_tiles(c, width)
    d2, _ = to_tiles(d, width)
    return from_tiles(kernel.triad2d(b2, c2, d2), n)


@functools.partial(jax.jit, static_argnames=("phases", "width"))
def vector_triad_phased(
    b: jax.Array,
    c: jax.Array,
    d: jax.Array,
    *,
    phases: tuple[int, int, int] = (0, 0, 0),
    width: int = 1024,
) -> jax.Array:
    """Embed stream k at element phase[k]; the kernel then reads shifted
    views.  With non-tile-multiple phases the compiler must materialize
    re-alignment copies -- the cost shows up in HLO bytes (see
    benchmarks/vector_triad.py), which is the dry-run observable for the
    paper's offset sweep."""
    (n,) = b.shape
    outs = []
    for x, p in zip((b, c, d), phases):
        buf = jnp.pad(x, (p, 0))  # stream starts p elements in
        outs.append(buf[p:])      # logical view back at the data
    b2, n = to_tiles(outs[0], width)
    c2, _ = to_tiles(outs[1], width)
    d2, _ = to_tiles(outs[2], width)
    return from_tiles(kernel.triad2d(b2, c2, d2), n)


def vector_triad_segmented(
    a: SegmentedArray, b: SegmentedArray, c: SegmentedArray, d: SegmentedArray
) -> SegmentedArray:
    """Segmented-iterator port: per-segment Pallas triad calls."""

    def _one(bb: jax.Array, cc: jax.Array, dd: jax.Array) -> jax.Array:
        b2, n = to_tiles(bb, 128)
        c2, _ = to_tiles(cc, 128)
        d2, _ = to_tiles(dd, 128)
        return from_tiles(kernel.triad2d(b2, c2, d2), n)

    return seg_map(_one, a, b, c, d)


def triad_bytes(n: int, elem_bytes: int = 8, *, rfo: bool = True) -> int:
    """Application traffic: 3 reads + 1 write (+1 RFO read) per element --
    the paper's 16 B/flop balance at 8-byte elements without RFO."""
    return (5 if rfo else 4) * n * elem_bytes


def triad_flops(n: int) -> int:
    return 2 * n  # one mul + one add per element
