"""Pure-jnp oracle for the vector triad."""
from __future__ import annotations

import jax


def triad(b: jax.Array, c: jax.Array, d: jax.Array) -> jax.Array:
    return b + c * d
