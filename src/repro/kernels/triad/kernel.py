"""Schoenauer vector triad A = B + C * D (paper SS2.2) as a Pallas kernel.

Three read streams + one write stream -- the paper's workhorse for exposing
controller aliasing.  The kernel itself is trivially bandwidth-bound; what
matters is the *layout* of its four streams, owned by ops.py:

  * aligned   -- each array padded/reshaped to whole (8,128) tiles
                 (the analytic-skew equivalent: on TPU, tile alignment of
                 every stream is the balanced case),
  * phased    -- each array embedded at a per-stream element phase inside a
                 padded buffer (the paper's deliberate mis-/re-alignment
                 experiment), which forces ragged leading/trailing DMAs.

The kernel also supports a fori_loop *multi-pass* mode so wall-clock
microbenchmarks on small arrays are not dominated by dispatch overhead
(the paper repeats each sweep ``ntimes``).
"""
from __future__ import annotations

import jax
from jax.experimental import pallas as pl

from repro.kernels.util import INTERPRET, block_rows


def _triad_kernel(b_ref, c_ref, d_ref, a_ref):
    a_ref[...] = b_ref[...] + c_ref[...] * d_ref[...]


def triad2d(b: jax.Array, c: jax.Array, d: jax.Array, *, brows: int | None = None) -> jax.Array:
    rows, width = b.shape
    brows = brows or block_rows(rows)
    spec = pl.BlockSpec((brows, width), lambda i: (i, 0))
    return pl.pallas_call(
        _triad_kernel,
        grid=(rows // brows,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, width), b.dtype),
        interpret=INTERPRET,
    )(b, c, d)
