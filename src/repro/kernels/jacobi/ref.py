"""Pure-jnp oracle for the 2-D Jacobi sweep."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def jacobi_step(src: jax.Array) -> jax.Array:
    """One 5-point sweep; boundary cells are copied through."""
    inner = (
        src[:-2, 1:-1] + src[2:, 1:-1] + src[1:-1, :-2] + src[1:-1, 2:]
    ) * jnp.asarray(0.25, src.dtype)
    return src.at[1:-1, 1:-1].set(inner)


def jacobi_sweeps(src: jax.Array, iters: int) -> jax.Array:
    return jax.lax.fori_loop(0, iters, lambda _, x: jacobi_step(x), src)
