"""Jacobi wrappers: padding policy + multi-sweep driver."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.planner import plan_kernel
from repro.kernels.jacobi import kernel


@jax.jit
def jacobi_step(src: jax.Array) -> jax.Array:
    """One aligned Pallas sweep on an (N, M) grid (boundaries copied).

    Layout policy (the paper's SS2.3 parameters, TPU form) comes from the
    planner: columns padded to a 128-lane multiple, interior row count padded
    to a sublane multiple, block rows sized to the VMEM budget; the three
    shifted views give each block its halo without overlap reads.
    """
    n, m = src.shape
    rows = n - 2
    plan = plan_kernel("jacobi", (rows, m), src.dtype)
    prow, width = plan.padded_shape
    padded = jnp.pad(src, ((0, prow - rows), (0, width - m)))
    sa = padded[:-2][:prow]
    sb = padded[2:][:prow]
    sl = padded[1:-1][:prow]
    out = kernel.jacobi_rows(sa, sb, sl, n_cols=m, brows=plan.block_rows)
    return src.at[1:-1, :].set(out[:rows, :m])


@functools.partial(jax.jit, static_argnames=("iters",))
def jacobi_sweeps(src: jax.Array, iters: int) -> jax.Array:
    return jax.lax.fori_loop(0, iters, lambda _, x: jacobi_step(x), src)


def jacobi_bytes(n: int, m: int, elem_bytes: int = 8, *, rfo: bool = True) -> int:
    """Per-sweep traffic when two rows fit in cache/VMEM: read each source
    row once, write each destination row (+RFO) -- 4 (6) B/flop."""
    sites = (n - 2) * (m - 2)
    return (3 if rfo else 2) * sites * elem_bytes


def jacobi_flops(n: int, m: int) -> int:
    return 4 * (n - 2) * (m - 2)


def mlups(n: int, m: int, seconds: float, iters: int = 1) -> float:
    return (n - 2) * (m - 2) * iters / seconds / 1e6
