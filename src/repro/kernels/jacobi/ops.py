"""Jacobi: registry entry + multi-sweep driver.

Layout policy (the paper's SS2.3 parameters, TPU form) comes from the
planner: columns padded to a 128-lane multiple, interior row count padded to
a sublane multiple, block rows sized to the VMEM budget; the three shifted
views give each block its halo without overlap reads.

Under an SPMD mesh the grid *rows* shard over the data axis and each shard
exchanges one-row halos with its neighbors via ``ppermute`` -- the paper's
domain-decomposition move (each thread's working set pinned to its own
controller, only the boundary rows travel).  Two (1, cols) rows per sweep
cross the wire instead of every device sweeping the full grid.

The shard body is *overlapped* (docs/OVERLAP.md): the halo ppermutes are
issued first and the interior stripe (which reads only locally-resident
rows) is swept while they are in flight; only the two boundary rows touch
the arriving halo slabs.  ``KernelPlan.predicted_exposed_comm_bytes``
prices what is left on the critical path and
``repro.measure.validate --comm --exposed`` checks the lowered program
keeps the collective independent of the interior sweep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.api import dispatch
from repro.api import spmd as spmd_lib
from repro.api.registry import register_kernel
from repro.api.spmd import Partitioning
from repro.core.autotune import StreamSignature
from repro.kernels._shims import deprecated_wrapper
from repro.kernels.jacobi import kernel, ref


def _plan_args(src, **_scalars):
    """Jacobi plans on its *interior* rows (boundaries are copied through)."""
    n, m = src.shape
    return (n - 2, m), src.dtype


@functools.partial(jax.jit, static_argnames=("plan",))
def _step(src, *, plan):
    n, m = src.shape
    rows = n - 2
    prow, width = plan.padded_shape
    padded = jnp.pad(src, ((0, prow - rows), (0, width - m)))
    sa = padded[:-2][:prow]
    sb = padded[2:][:prow]
    sl = padded[1:-1][:prow]
    out = kernel.jacobi_rows(sa, sb, sl, n_cols=m, brows=plan.block_rows)
    return src.at[1:-1, :].set(out[:rows, :m])


def _row_stencil(sa, sb, sl, n_cols: int):
    """One stencil row in plain jnp, op-for-op the Pallas kernel body
    (``kernel._jacobi_kernel``), so boundary rows computed outside the grid
    are bit-exact with interior rows computed inside it."""
    left = jnp.roll(sl, 1, axis=1)
    right = jnp.roll(sl, -1, axis=1)
    inner = (sa + sb + left + right) * jnp.asarray(0.25, sl.dtype)
    j = jax.lax.broadcasted_iota(jnp.int32, sl.shape, 1)
    interior = (j >= 1) & (j <= n_cols - 2)
    return jnp.where(interior, inner, sl)


def _halo_exchange(src, row_axes, n_shards, idx):
    """Issue the one-row halo transfers.  My up-neighbor's last row arrives
    as ``above``, my down-neighbor's first row as ``below``; shard 0 /
    n-1 receive zeros they never read (their edge rows are the global
    boundary and are copied through)."""
    nl, m = src.shape
    if len(row_axes) == 1:
        axis = row_axes[0]
        down_perm = [(i, i + 1) for i in range(n_shards - 1)]
        up_perm = [(i, i - 1) for i in range(1, n_shards)]
        above = jax.lax.ppermute(src[-1:], axis, down_perm)
        below = jax.lax.ppermute(src[:1], axis, up_perm)
    else:  # multi-axis row sharding: gather the boundary rows instead
        edges = jnp.concatenate([src[:1], src[-1:]], axis=0)
        gathered = jax.lax.all_gather(edges, row_axes, tiled=False)
        gathered = gathered.reshape(n_shards, 2, m)
        above = jnp.where(idx > 0, gathered[idx - 1, 1:2], 0.0)
        below = jnp.where(idx < n_shards - 1,
                          gathered[(idx + 1) % n_shards, 0:1], 0.0)
    return above, below


def _spmd_jacobi(ctx, src):
    """shard_map body: *overlapped* halo-exchange Jacobi on a row stripe.

    ``src`` is this shard's (N_local, M) horizontal stripe.  The stripe
    splits into an interior (output rows 1..N_local-2, which read only
    locally-resident rows) and the two boundary rows that need a neighbor
    halo.  The halo ``ppermute`` is issued *first* and nothing the interior
    Pallas sweep reads depends on it, so the lowered program is free to run
    the collective-permute start/done pair concurrently with the interior
    sweep -- the wire time hides behind the interior compute window
    (docs/OVERLAP.md) instead of serializing ahead of it like the PR-5
    exchange-then-compute body (kept as ``_spmd_jacobi_blocking`` for
    parity tests).  The halo slabs are buffers distinct from ``src``: the
    body only reads them in the final boundary-row stitch.
    """
    row_axes = ctx.axes(0, 0)
    n_shards = ctx.size(row_axes)
    if n_shards <= 1:
        # Rows whole on this shard (divisibility fallback, or a size-1
        # data axis): the single-device step on a locally planned block.
        shape, dtype = _plan_args(src)
        plan = dispatch.plan_for("jacobi", shape, dtype, local=True)
        return _step(src, plan=plan)
    nl, m = src.shape
    idx = ctx.index(row_axes)
    # 1) issue the halo exchange for this sweep ...
    above, below = _halo_exchange(src, row_axes, n_shards, idx)
    if nl > 2:
        # 2) ... sweep the interior stripe while it is in flight: output
        # rows 1..nl-2 read src rows 0..nl-1 only, on the locally planned
        # block shape (the plan cell is the full stripe, so the memo key
        # matches what validate --comm prices for this shard).
        plan = dispatch.plan_for("jacobi", (nl, m), src.dtype, local=True)
        prow, width = plan.padded_shape

        def pad(a):
            return jnp.pad(a, ((0, prow - a.shape[0]), (0, width - m)))

        interior = kernel.jacobi_rows(
            pad(src[:-2]), pad(src[2:]), pad(src[1:-1]),
            n_cols=m, brows=plan.block_rows)[:nl - 2, :m]
        # 3) boundary rows last: the only reads of the arrived halo slabs.
        top = _row_stencil(above, src[1:2], src[0:1], m)
        bot = _row_stencil(src[-2:-1], below, src[-1:], m)
        out = jnp.concatenate([top, interior, bot], axis=0)
    else:
        # Degenerate stripe: every row is a boundary row, nothing to hide
        # the exchange behind (predicted_exposed_comm_bytes says the same).
        ext = jnp.concatenate([above, src, below], axis=0)
        out = _row_stencil(ext[:-2], ext[2:], ext[1:-1], m)
    # Global boundary rows pass through: shard 0's first row and the last
    # shard's last row are the grid edge, not interior sites.
    r = jax.lax.broadcasted_iota(jnp.int32, (nl, 1), 0)
    edge = ((idx == 0) & (r == 0)) | ((idx == n_shards - 1) & (r == nl - 1))
    return jnp.where(edge, src, out)


def _spmd_jacobi_blocking(ctx, src):
    """The PR-5 exchange-then-compute shard body, retained as the parity
    oracle for the overlapped body above (and as the counter-example
    ``api.spmd.overlap_report`` classifies as blocking): the whole stripe
    waits for the halo before any site is swept."""
    row_axes = ctx.axes(0, 0)
    n_shards = ctx.size(row_axes)
    if n_shards <= 1:
        shape, dtype = _plan_args(src)
        plan = dispatch.plan_for("jacobi", shape, dtype, local=True)
        return _step(src, plan=plan)
    nl, m = src.shape
    idx = ctx.index(row_axes)
    above, below = _halo_exchange(src, row_axes, n_shards, idx)
    plan = dispatch.plan_for("jacobi", (nl, m), src.dtype, local=True)
    prow, width = plan.padded_shape
    ext = jnp.concatenate([above, src, below], axis=0)      # (nl + 2, m)
    padded = jnp.pad(ext, ((0, prow - nl), (0, width - m)))
    sa = padded[:-2][:prow]
    sb = padded[2:][:prow]
    sl = padded[1:-1][:prow]
    out = kernel.jacobi_rows(sa, sb, sl, n_cols=m,
                             brows=plan.block_rows)[:nl, :m]
    r = jax.lax.broadcasted_iota(jnp.int32, (nl, 1), 0)
    edge = ((idx == 0) & (r == 0)) | ((idx == n_shards - 1) & (r == nl - 1))
    return jnp.where(edge, src, out)


@register_kernel("jacobi", signature=StreamSignature(n_read=1, n_write=1),
                 ref=lambda src: ref.jacobi_step(src), plan_args=_plan_args,
                 vmem_buffers=4,
                 # the 5-point stencil couples neighboring rows, so the
                 # row-block split carries its halo exchange in the
                 # spmd_body (one ppermuted row up and down per sweep)
                 partitioning=Partitioning(in_axes=(("batch", None),),
                                           out_axes=("batch", None)),
                 spmd_body=_spmd_jacobi)
def _launch_jacobi(plan, src):
    """One aligned 5-point sweep on an (N, M) grid (boundaries copied).
    Rows stream once from HBM; the 3 shifted row views are distinct Pallas
    operands, hence the 4-buffer VMEM geometry."""
    return _step(src, plan=plan)


@deprecated_wrapper("jacobi")
def jacobi_step(src: jax.Array) -> jax.Array:
    return dispatch.launch("jacobi", src)


@functools.partial(jax.jit, static_argnames=("iters", "plan"))
def _sweeps(src, *, iters, plan):
    return jax.lax.fori_loop(
        0, iters, lambda _, x: dispatch.launch("jacobi", x, plan=plan), src
    )


def jacobi_sweeps(src: jax.Array, iters: int) -> jax.Array:
    # Under an ambient multi-device mesh, route every sweep through the
    # shard_map path (a pinned plan would force the single-device body):
    # the overlapped body issues sweep k's halo before its interior
    # compute, so consecutive sweeps pipeline -- while sweep k's boundary
    # stitch waits on its halo, sweep k-1's interior work is still
    # draining.  Re-launching per iteration keeps the plan resolution
    # inside the loop body, where each shard plans its local stripe.
    if spmd_lib.spmd_mesh() is not None:
        return jax.jit(
            lambda x0: jax.lax.fori_loop(
                0, iters, lambda _, x: dispatch.launch("jacobi", x), x0
            )
        )(src)
    # Resolve the plan outside the jitted loop: jit's trace cache keys on
    # shapes/statics only, so an ambient plan_context change must surface
    # here (as a new static plan), not be masked by a stale trace.
    plan = dispatch.plan_for("jacobi", _plan_args(src)[0], src.dtype)
    return _sweeps(src, iters=iters, plan=plan)


def jacobi_bytes(n: int, m: int, elem_bytes: int = 8, *, rfo: bool = True) -> int:
    """Per-sweep traffic when two rows fit in cache/VMEM: read each source
    row once, write each destination row (+RFO) -- 4 (6) B/flop."""
    sites = (n - 2) * (m - 2)
    return (3 if rfo else 2) * sites * elem_bytes


def jacobi_flops(n: int, m: int) -> int:
    return 4 * (n - 2) * (m - 2)


def mlups(n: int, m: int, seconds: float, iters: int = 1) -> float:
    return (n - 2) * (m - 2) * iters / seconds / 1e6
