"""Jacobi: registry entry + multi-sweep driver.

Layout policy (the paper's SS2.3 parameters, TPU form) comes from the
planner: columns padded to a 128-lane multiple, interior row count padded to
a sublane multiple, block rows sized to the VMEM budget; the three shifted
views give each block its halo without overlap reads.

Under an SPMD mesh the grid *rows* shard over the data axis and each shard
exchanges one-row halos with its neighbors via ``ppermute`` before
launching the same Pallas stencil on its locally planned block shape --
the paper's domain-decomposition move (each thread's working set pinned to
its own controller, only the boundary rows travel).  Two (1, cols) rows
per sweep cross the wire instead of every device sweeping the full grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.api import dispatch
from repro.api.registry import register_kernel
from repro.api.spmd import Partitioning
from repro.core.autotune import StreamSignature
from repro.kernels._shims import deprecated_wrapper
from repro.kernels.jacobi import kernel, ref


def _plan_args(src, **_scalars):
    """Jacobi plans on its *interior* rows (boundaries are copied through)."""
    n, m = src.shape
    return (n - 2, m), src.dtype


@functools.partial(jax.jit, static_argnames=("plan",))
def _step(src, *, plan):
    n, m = src.shape
    rows = n - 2
    prow, width = plan.padded_shape
    padded = jnp.pad(src, ((0, prow - rows), (0, width - m)))
    sa = padded[:-2][:prow]
    sb = padded[2:][:prow]
    sl = padded[1:-1][:prow]
    out = kernel.jacobi_rows(sa, sb, sl, n_cols=m, brows=plan.block_rows)
    return src.at[1:-1, :].set(out[:rows, :m])


def _spmd_jacobi(ctx, src):
    """shard_map body: halo-exchange Jacobi on a row-block shard.

    ``src`` is this shard's (N_local, M) horizontal stripe of the grid.
    One-row halos arrive from the neighbors via ``ppermute`` (the edge
    shards' missing halo is zeros -- harmless, their edge rows are the
    global boundary and are copied through), the local block shape is
    re-planned on the stripe (``plan_for(..., local=True)``), and the
    existing three-shifted-views Pallas stencil sweeps it.
    """
    row_axes = ctx.axes(0, 0)
    n_shards = ctx.size(row_axes)
    if n_shards <= 1:
        # Rows whole on this shard (divisibility fallback, or a size-1
        # data axis): the single-device step on a locally planned block.
        shape, dtype = _plan_args(src)
        plan = dispatch.plan_for("jacobi", shape, dtype, local=True)
        return _step(src, plan=plan)
    nl, m = src.shape
    idx = ctx.index(row_axes)
    if len(row_axes) == 1:
        axis = row_axes[0]
        down_perm = [(i, i + 1) for i in range(n_shards - 1)]
        up_perm = [(i, i - 1) for i in range(1, n_shards)]
        # halo above my first row = my up-neighbor's last row, and vice
        # versa; shard 0 / n-1 receive zeros they never read.
        above = jax.lax.ppermute(src[-1:], axis, down_perm)
        below = jax.lax.ppermute(src[:1], axis, up_perm)
    else:  # multi-axis row sharding: gather the boundary rows instead
        edges = jnp.concatenate([src[:1], src[-1:]], axis=0)
        gathered = jax.lax.all_gather(edges, row_axes, tiled=False)
        gathered = gathered.reshape(n_shards, 2, m)
        above = jnp.where(idx > 0, gathered[idx - 1, 1:2], 0.0)
        below = jnp.where(idx < n_shards - 1,
                          gathered[(idx + 1) % n_shards, 0:1], 0.0)
    plan = dispatch.plan_for("jacobi", (nl, m), src.dtype, local=True)
    prow, width = plan.padded_shape
    ext = jnp.concatenate([above, src, below], axis=0)      # (nl + 2, m)
    padded = jnp.pad(ext, ((0, prow - nl), (0, width - m)))
    sa = padded[:-2][:prow]
    sb = padded[2:][:prow]
    sl = padded[1:-1][:prow]
    out = kernel.jacobi_rows(sa, sb, sl, n_cols=m,
                             brows=plan.block_rows)[:nl, :m]
    # Global boundary rows pass through: shard 0's first row and the last
    # shard's last row are the grid edge, not interior sites.
    r = jax.lax.broadcasted_iota(jnp.int32, (nl, 1), 0)
    edge = ((idx == 0) & (r == 0)) | ((idx == n_shards - 1) & (r == nl - 1))
    return jnp.where(edge, src, out)


@register_kernel("jacobi", signature=StreamSignature(n_read=1, n_write=1),
                 ref=lambda src: ref.jacobi_step(src), plan_args=_plan_args,
                 vmem_buffers=4,
                 # the 5-point stencil couples neighboring rows, so the
                 # row-block split carries its halo exchange in the
                 # spmd_body (one ppermuted row up and down per sweep)
                 partitioning=Partitioning(in_axes=(("batch", None),),
                                           out_axes=("batch", None)),
                 spmd_body=_spmd_jacobi)
def _launch_jacobi(plan, src):
    """One aligned 5-point sweep on an (N, M) grid (boundaries copied).
    Rows stream once from HBM; the 3 shifted row views are distinct Pallas
    operands, hence the 4-buffer VMEM geometry."""
    return _step(src, plan=plan)


@deprecated_wrapper("jacobi")
def jacobi_step(src: jax.Array) -> jax.Array:
    return dispatch.launch("jacobi", src)


@functools.partial(jax.jit, static_argnames=("iters", "plan"))
def _sweeps(src, *, iters, plan):
    return jax.lax.fori_loop(
        0, iters, lambda _, x: dispatch.launch("jacobi", x, plan=plan), src
    )


def jacobi_sweeps(src: jax.Array, iters: int) -> jax.Array:
    # Resolve the plan outside the jitted loop: jit's trace cache keys on
    # shapes/statics only, so an ambient plan_context change must surface
    # here (as a new static plan), not be masked by a stale trace.
    plan = dispatch.plan_for("jacobi", _plan_args(src)[0], src.dtype)
    return _sweeps(src, iters=iters, plan=plan)


def jacobi_bytes(n: int, m: int, elem_bytes: int = 8, *, rfo: bool = True) -> int:
    """Per-sweep traffic when two rows fit in cache/VMEM: read each source
    row once, write each destination row (+RFO) -- 4 (6) B/flop."""
    sites = (n - 2) * (m - 2)
    return (3 if rfo else 2) * sites * elem_bytes


def jacobi_flops(n: int, m: int) -> int:
    return 4 * (n - 2) * (m - 2)


def mlups(n: int, m: int, seconds: float, iters: int = 1) -> float:
    return (n - 2) * (m - 2) * iters / seconds / 1e6
