"""Jacobi: registry entry + multi-sweep driver.

Layout policy (the paper's SS2.3 parameters, TPU form) comes from the
planner: columns padded to a 128-lane multiple, interior row count padded to
a sublane multiple, block rows sized to the VMEM budget; the three shifted
views give each block its halo without overlap reads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.api import dispatch
from repro.api.registry import register_kernel
from repro.api.spmd import replicated
from repro.core.autotune import StreamSignature
from repro.kernels._shims import deprecated_wrapper
from repro.kernels.jacobi import kernel, ref


def _plan_args(src, **_scalars):
    """Jacobi plans on its *interior* rows (boundaries are copied through)."""
    n, m = src.shape
    return (n - 2, m), src.dtype


@functools.partial(jax.jit, static_argnames=("plan",))
def _step(src, *, plan):
    n, m = src.shape
    rows = n - 2
    prow, width = plan.padded_shape
    padded = jnp.pad(src, ((0, prow - rows), (0, width - m)))
    sa = padded[:-2][:prow]
    sb = padded[2:][:prow]
    sl = padded[1:-1][:prow]
    out = kernel.jacobi_rows(sa, sb, sl, n_cols=m, brows=plan.block_rows)
    return src.at[1:-1, :].set(out[:rows, :m])


@register_kernel("jacobi", signature=StreamSignature(n_read=1, n_write=1),
                 ref=lambda src: ref.jacobi_step(src), plan_args=_plan_args,
                 vmem_buffers=4,
                 # the 5-point stencil couples neighboring rows: a row
                 # split would need a halo exchange per sweep, so the
                 # SPMD path runs the grid replicated on every device
                 partitioning=replicated(1))
def _launch_jacobi(plan, src):
    """One aligned 5-point sweep on an (N, M) grid (boundaries copied).
    Rows stream once from HBM; the 3 shifted row views are distinct Pallas
    operands, hence the 4-buffer VMEM geometry."""
    return _step(src, plan=plan)


@deprecated_wrapper("jacobi")
def jacobi_step(src: jax.Array) -> jax.Array:
    return dispatch.launch("jacobi", src)


@functools.partial(jax.jit, static_argnames=("iters", "plan"))
def _sweeps(src, *, iters, plan):
    return jax.lax.fori_loop(
        0, iters, lambda _, x: dispatch.launch("jacobi", x, plan=plan), src
    )


def jacobi_sweeps(src: jax.Array, iters: int) -> jax.Array:
    # Resolve the plan outside the jitted loop: jit's trace cache keys on
    # shapes/statics only, so an ambient plan_context change must surface
    # here (as a new static plan), not be masked by a stale trace.
    plan = dispatch.plan_for("jacobi", _plan_args(src)[0], src.dtype)
    return _sweeps(src, iters=iters, plan=plan)


def jacobi_bytes(n: int, m: int, elem_bytes: int = 8, *, rfo: bool = True) -> int:
    """Per-sweep traffic when two rows fit in cache/VMEM: read each source
    row once, write each destination row (+RFO) -- 4 (6) B/flop."""
    sites = (n - 2) * (m - 2)
    return (3 if rfo else 2) * sites * elem_bytes


def jacobi_flops(n: int, m: int) -> int:
    return 4 * (n - 2) * (m - 2)


def mlups(n: int, m: int, seconds: float, iters: int = 1) -> float:
    return (n - 2) * (m - 2) * iters / seconds / 1e6
