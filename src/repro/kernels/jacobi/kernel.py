"""2-D 5-point Jacobi sweep (paper SS2.3) as a Pallas kernel.

The paper's optimal parameters -- every row (segment) aligned to a 512 B
boundary, consecutive rows shifted by 128 B, ``static,1`` scheduling -- map
onto TPU as:

  * rows padded to whole 128-lane multiples (wrapper, LayoutPolicy),
  * three *shifted row views* (above / below / center) passed as separate
    operands so each output block's halo arrives as clean blocked DMAs
    (the segmented-iterator structure: ``relax_line(dl, sa, sb, sl, N)``),
  * a 1-D grid over row blocks = the ``static`` schedule; block row count is
    the chunk size.

Column neighbours are formed *inside* VMEM via lane rolls -- on T2 they came
from registers/L1 ("three of the four source operands can be obtained from
cache"), on TPU they never touch HBM either, so the kernel's memory traffic
is 1 row read + 1 row write (+RFO) exactly as the paper's 4 (6) B/flop
accounting demands.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.util import INTERPRET, block_rows


def _jacobi_kernel(sa_ref, sb_ref, sl_ref, out_ref, *, n_cols: int):
    sa = sa_ref[...]
    sb = sb_ref[...]
    sl = sl_ref[...]
    left = jnp.roll(sl, 1, axis=1)    # sl[j-1]
    right = jnp.roll(sl, -1, axis=1)  # sl[j+1]
    inner = (sa + sb + left + right) * jnp.asarray(0.25, sl.dtype)
    j = jax.lax.broadcasted_iota(jnp.int32, sl.shape, 1)
    interior = (j >= 1) & (j <= n_cols - 2)
    out_ref[...] = jnp.where(interior, inner, sl)


def jacobi_rows(
    sa: jax.Array, sb: jax.Array, sl: jax.Array, *, n_cols: int, brows: int | None = None
) -> jax.Array:
    """One sweep over the interior rows.

    sa/sb/sl are the rows above / below / at the output rows, all shaped
    (rows, width) with width a 128-multiple and rows a sublane multiple.
    ``n_cols`` is the logical column count (<= width); columns outside
    [1, n_cols-2] are passed through from sl.
    """
    rows, width = sl.shape
    brows = brows or block_rows(rows, 128)
    spec = pl.BlockSpec((brows, width), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_jacobi_kernel, n_cols=n_cols),
        grid=(rows // brows,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, width), sl.dtype),
        interpret=INTERPRET,
    )(sa, sb, sl)
