"""Continuous batching scheduler (vLLM-style slot machine, jit-friendly).

A fixed batch of decode *slots* advances in lockstep through one jitted
serve_step per tick; requests of ragged lengths stream through the slots:

  * admit  -- a free slot takes the next queued request; the slot's cache
    rows are reset from a pristine template (per-slot idx -> 0, SSM/mLSTM
    states -> init), so no state leaks across tenants,
  * prefill -- the request's prompt is teacher-forced through serve_step
    (one token/tick, exactly the decode path the dry-run lowers),
  * decode -- the model's greedy token feeds back until max_new_tokens or
    EOS, then the slot retires and re-admits.

The per-slot cache index (models/blocks._cache_put) is what makes ragged
co-residency correct: every slot attends over exactly its own prefix.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.params import init_params
from repro.parallel import steps as steps_lib


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    generated: list[int] = dataclasses.field(default_factory=list)
    fed: int = 0                      # prompt tokens fed so far

    @property
    def prefilling(self) -> bool:
        return self.fed < len(self.prompt)

    def done(self, eos_id: int | None) -> bool:
        if len(self.generated) >= self.max_new_tokens:
            return True
        return eos_id is not None and self.generated and (
            self.generated[-1] == eos_id
        )


class ContinuousBatcher:
    def __init__(self, model, params, *, slots: int, max_len: int,
                 eos_id: int | None = None, seed: int = 0):
        self.model = model
        self.params = params
        self.slots = slots
        self.eos_id = eos_id
        self.decode = jax.jit(steps_lib.make_decode_step(model))
        key = jax.random.PRNGKey(seed)
        self.cache = init_params(key, model.cache_defs(slots, max_len))
        self._template = jax.tree.map(jnp.copy, self.cache)
        self.slot_req: list[Request | None] = [None] * slots
        self.queue: deque[Request] = deque()
        self.ticks = 0
        self.completed: dict[int, list[int]] = {}

    # ------------------------------------------------------------------
    def submit(self, reqs: Iterable[Request]) -> None:
        self.queue.extend(reqs)
        self._admit()

    def _reset_slot(self, cache, slot: int):
        """Copy pristine template rows into ``slot`` for every cache leaf.
        The batch axis is axis 0 for 'idx' and axis 1 (after the stacked
        layer axis) for every state/KV leaf."""

        def reset(path, c, t):
            name = str(getattr(path[-1], "key", ""))
            if name == "idx":
                return c.at[slot].set(0)
            if c.ndim >= 2 and c.shape[1] == self.slots:
                return c.at[:, slot].set(t[:, slot])
            if c.ndim >= 1 and c.shape[0] == self.slots:
                return c.at[slot].set(t[slot])
            return c

        return jax.tree_util.tree_map_with_path(reset, cache, self._template)

    def _admit(self) -> None:
        for s in range(self.slots):
            if self.slot_req[s] is None and self.queue:
                self.slot_req[s] = self.queue.popleft()
                self.cache = self._reset_slot(self.cache, s)

    # ------------------------------------------------------------------
    def step(self) -> None:
        feed = np.zeros((self.slots, 1), np.int32)
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            if req.prefilling:
                feed[s, 0] = req.prompt[req.fed]
            else:
                feed[s, 0] = req.generated[-1]
        nxt, self.cache = self.decode(self.params, self.cache,
                                      jnp.asarray(feed))
        nxt = np.asarray(nxt)[:, 0]
        self.ticks += 1
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            if req.prefilling:
                req.fed += 1
                if not req.prefilling:      # last prompt token: first output
                    req.generated.append(int(nxt[s]))
            else:
                req.generated.append(int(nxt[s]))
            if req.done(self.eos_id):
                self.completed[req.rid] = req.generated[: req.max_new_tokens]
                self.slot_req[s] = None
        self._admit()

    @property
    def busy(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slot_req)

    def run(self, reqs: Iterable[Request], *, max_ticks: int = 100_000
            ) -> dict[int, list[int]]:
        self.submit(reqs)
        while self.busy and self.ticks < max_ticks:
            self.step()
        return self.completed
