"""Continuous batching scheduler (vLLM-style slot machine, jit-friendly).

A fixed batch of decode *slots* advances in lockstep through one jitted
serve_step per tick; requests of ragged lengths stream through the slots:

  * admit  -- a free slot takes the next queued request; the slot's cache
    rows are reset from a pristine template (per-slot idx -> 0, SSM/mLSTM
    states -> init), so no state leaks across tenants,
  * prefill -- the request's prompt is teacher-forced through serve_step
    (one token/tick, exactly the decode path the dry-run lowers),
  * decode -- the model's greedy token feeds back until max_new_tokens or
    EOS, then the slot retires and re-admits.

The per-slot cache index (models/blocks._cache_put) is what makes ragged
co-residency correct: every slot attends over exactly its own prefix.

Layout planning (paper SS2.3, serving form): the batcher asks the kernel
registry for the decode/prefill plans of each admitted batch shape under
the ambient ``plan_context`` mesh, and packs the physical slot axis (cache
batch dim + per-tick feed) to the planned sublane tile -- so the decode
batch the model actually sees is always whole-tile, never raggedly padded
by XLA behind our back.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro import obs
from repro.models.params import init_params
from repro.parallel import steps as steps_lib


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    generated: list[int] = dataclasses.field(default_factory=list)
    fed: int = 0                      # prompt tokens fed so far

    @property
    def prefilling(self) -> bool:
        return self.fed < len(self.prompt)

    def done(self, eos_id: int | None) -> bool:
        if len(self.generated) >= self.max_new_tokens:
            return True
        return eos_id is not None and self.generated and (
            self.generated[-1] == eos_id
        )


class ContinuousBatcher:
    def __init__(self, model, params, *, slots: int, max_len: int,
                 eos_id: int | None = None, seed: int = 0, mesh=None):
        self.model = model
        self.params = params
        self.slots = slots
        self.eos_id = eos_id
        # Layout planning: the batch axis of every decode tick is the row
        # axis of the per-token kernels, so the *physical* slot count comes
        # from the registry's plan for the decode batch shape -- the cache
        # (and each tick's feed) is packed to the planned sublane tile
        # instead of the raw requested slot count.  Extra physical slots
        # simply idle.  An explicit ``mesh`` wins; otherwise the ambient
        # plan_context is consulted at each planning call, so both
        # construct-inside-context and construct-then-context launchers
        # reach the planner with their mesh (slot *geometry* is fixed at
        # construction from the plan made here).
        self.mesh = mesh
        cfg = getattr(model, "cfg", None)
        self._d_model = int(getattr(cfg, "d_model", 0))
        self._adtype = getattr(cfg, "adtype", jnp.float32)
        self.decode_plan = self._batch_plan(slots)
        self.padded_slots = (
            self.decode_plan.rows if self.decode_plan is not None else slots
        )
        self.plans: dict[tuple[str, int], object] = {}
        self.decode = jax.jit(steps_lib.make_decode_step(model))
        key = jax.random.PRNGKey(seed)
        self.cache = init_params(key,
                                 model.cache_defs(self.padded_slots, max_len))
        self._template = jax.tree.map(jnp.copy, self.cache)
        self.slot_req: list[Request | None] = [None] * slots
        self.queue: deque[Request] = deque()
        self.ticks = 0
        self.completed: dict[int, list[int]] = {}

    # ---- layout planning ---------------------------------------------------
    def _batch_plan(self, rows: int):
        """Registry plan for a decode/prefill batch of ``rows`` sequences:
        the per-token norm kernel over (rows, d_model) under this batcher's
        mesh.  Memoized by the planner, so per-admission calls are free."""
        if not self._d_model or rows <= 0:
            return None
        ctx = api.current_context()
        if self.mesh is not None:
            ctx = ctx.evolve(mesh=self.mesh)
        return api.plan_for("rmsnorm", (rows, self._d_model), self._adtype,
                            ctx=ctx)

    def _note_admitted_plans(self) -> None:
        """Record the plans of the currently *admitted* batch shapes
        (ROADMAP: serving-path planning).  Called on admission and on every
        tick -- slots move from prefill to decode without a new admission,
        and the memoized plan cache makes the repeat calls free.  Keyed by
        (phase, occupied count); each value is the plan the admitted batch
        *needs* (its ``rows`` is the smallest tile-aligned batch that could
        serve it -- the packing signal for shrinking the physical batch),
        while ``decode_plan`` remains the plan of the (padded_slots,
        d_model) batch every tick actually executes."""
        n_prefill = sum(r is not None and r.prefilling for r in self.slot_req)
        n_decode = sum(r is not None and not r.prefilling
                       for r in self.slot_req)
        for phase, n in (("prefill", n_prefill), ("decode", n_decode)):
            if n:
                plan = self._batch_plan(n)
                if plan is not None:
                    self.plans[(phase, n)] = plan

    # ------------------------------------------------------------------
    def submit(self, reqs: Iterable[Request]) -> None:
        self.queue.extend(reqs)
        self._admit()

    def _reset_slot(self, cache, slot: int):
        """Copy pristine template rows into ``slot`` for every cache leaf.
        The batch axis is axis 0 for 'idx' and axis 1 (after the stacked
        layer axis) for every state/KV leaf."""

        def reset(path, c, t):
            name = str(getattr(path[-1], "key", ""))
            if name == "idx":
                return c.at[slot].set(0)
            if c.ndim >= 2 and c.shape[1] == self.padded_slots:
                return c.at[:, slot].set(t[:, slot])
            if c.ndim >= 1 and c.shape[0] == self.padded_slots:
                return c.at[slot].set(t[slot])
            return c

        return jax.tree_util.tree_map_with_path(reset, cache, self._template)

    def _admit(self) -> None:
        admitted = False
        for s in range(self.slots):
            if self.slot_req[s] is None and self.queue:
                req = self.queue.popleft()
                self.slot_req[s] = req
                self.cache = self._reset_slot(self.cache, s)
                admitted = True
                if obs.enabled():
                    obs.emit(obs.AdmissionEvent(
                        rid=req.rid, slot=s, queue_depth=len(self.queue)))
        if admitted:
            self._note_admitted_plans()

    # ------------------------------------------------------------------
    def step(self) -> None:
        self._note_admitted_plans()
        feed = np.zeros((self.padded_slots, 1), np.int32)
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            if req.prefilling:
                feed[s, 0] = req.prompt[req.fed]
            else:
                feed[s, 0] = req.generated[-1]
        nxt, self.cache = self.decode(self.params, self.cache,
                                      jnp.asarray(feed))
        nxt = np.asarray(nxt)[:, 0]
        self.ticks += 1
        if obs.enabled():
            # Packing waste is the tick's dead rows: slots with no tenant
            # (free) plus the tile padding the planner chose (pad).  Both
            # rows run through the decode step anyway -- the signal the
            # report aggregates into a mean waste fraction.
            n_prefill = sum(r is not None and r.prefilling
                            for r in self.slot_req)
            n_decode = sum(r is not None and not r.prefilling
                           for r in self.slot_req)
            obs.emit(obs.BatcherTickEvent(
                tick=self.ticks, n_prefill=n_prefill, n_decode=n_decode,
                slots=self.slots, padded_slots=self.padded_slots,
                free_slots=self.slots - n_prefill - n_decode,
                pad_slots=self.padded_slots - self.slots,
                queue_depth=len(self.queue)))
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            if req.prefilling:
                req.fed += 1
                if not req.prefilling:      # last prompt token: first output
                    req.generated.append(int(nxt[s]))
            else:
                req.generated.append(int(nxt[s]))
            if req.done(self.eos_id):
                self.completed[req.rid] = req.generated[: req.max_new_tokens]
                self.slot_req[s] = None
        self._admit()

    @property
    def busy(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slot_req)

    def run(self, reqs: Iterable[Request], *, max_ticks: int = 100_000
            ) -> dict[int, list[int]]:
        self.submit(reqs)
        while self.busy and self.ticks < max_ticks:
            self.step()
        return self.completed
