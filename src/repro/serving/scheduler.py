"""Continuous batching scheduler (vLLM-style slot machine, jit-friendly).

A fixed batch of decode *slots* advances in lockstep through one jitted
serve_step per tick; requests of ragged lengths stream through the slots:

  * admit  -- a free slot takes the next queued request; the slot's cache
    rows are reset from a pristine template (per-slot idx -> 0, SSM/mLSTM
    states -> init), so no state leaks across tenants,
  * prefill -- the request's prompt is teacher-forced through serve_step
    (``prefill_chunk`` tokens/tick via the masked chunk step, or one
    token/tick on the legacy path -- numerically identical either way),
  * decode -- the model's greedy token feeds back until max_new_tokens or
    EOS, then the slot retires and re-admits.

The per-slot cache index (models/blocks._cache_put) is what makes ragged
co-residency correct: every slot attends over exactly its own prefix.

Layout planning (paper SS2.3, serving form): the batcher asks the kernel
registry for the decode/prefill plans of each admitted batch shape under
the ambient ``plan_context`` mesh, and packs the physical slot axis (cache
batch dim + per-tick feed) to the planned sublane tile -- so the decode
batch the model actually sees is always whole-tile, never raggedly padded
by XLA behind our back.

KV memory (``kv_cache="paged"``): instead of the dense
``(layers, slots, max_len, ...)`` slab, attention KV lives in a shared
page pool whose page length is the planner's sublane tile for the KV
stream (``serving.paged_cache``).  Slots hold pages only for positions
they have actually written; a retired or preempted slot's pages return to
the free pool immediately.  Admission applies backpressure when the pool
cannot cover a request's prompt, and a decoding slot that needs a page
may preempt a prefilling one (decode priority): the victim is requeued
and replayed -- greedy decode makes the replay token-identical, so
preemption is invisible in the output stream.  See docs/SERVING.md.
"""
from __future__ import annotations

import dataclasses
import logging
from collections import deque
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro import obs
from repro.models import params as params_lib
from repro.parallel import steps as steps_lib
from repro.serving.paged_cache import PageManager, plan_page_geometry

log = logging.getLogger("repro.serving")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    generated: list[int] = dataclasses.field(default_factory=list)
    fed: int = 0                      # replay tokens fed so far
    restart_target: int = 0           # replay horizon after a preemption
    preemptions: int = 0

    @property
    def replay_len(self) -> int:
        """Tokens to teacher-force before new decoding starts: the prompt,
        or -- after a preemption -- the prompt plus everything already
        generated (greedy decode reproduces the evicted state exactly)."""
        return max(len(self.prompt), self.restart_target)

    def replay_token(self, i: int) -> int:
        p = len(self.prompt)
        return self.prompt[i] if i < p else self.generated[i - p]

    @property
    def prefilling(self) -> bool:
        return self.fed < self.replay_len

    def done(self, eos_id: int | None) -> bool:
        if len(self.generated) >= self.max_new_tokens:
            return True
        return bool(
            eos_id is not None and self.generated
            and self.generated[-1] == eos_id
        )


class TruncatedRun(RuntimeError):
    """``run()`` hit ``max_ticks`` with work still in flight.

    ``completed`` holds every finished request's tokens (the partial
    result); ``abandoned`` the unfinished ``Request`` objects, with their
    partial ``generated`` state intact for inspection or resubmission.
    """

    def __init__(self, completed: dict[int, list[int]],
                 abandoned: list[Request], max_ticks: int):
        self.completed = completed
        self.abandoned = abandoned
        rids = [r.rid for r in abandoned]
        super().__init__(
            f"run() exhausted max_ticks={max_ticks} with "
            f"{len(abandoned)} request(s) unfinished (rids {rids}); "
            f"{len(completed)} completed. Pass on_truncation='return' to "
            f"accept partial results (check .busy afterwards)."
        )


class ContinuousBatcher:
    def __init__(self, model, params, *, slots: int, max_len: int,
                 eos_id: int | None = None, seed: int = 0, mesh=None,
                 kv_cache: str = "dense", page_len: int | None = None,
                 n_pages: int | None = None, page_banks: int = 4,
                 prefill_chunk: int = 1):
        if kv_cache not in ("dense", "paged"):
            raise ValueError(f"kv_cache must be 'dense' or 'paged', "
                             f"got {kv_cache!r}")
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.kv_cache = kv_cache
        self.prefill_chunk = max(1, int(prefill_chunk))
        # Layout planning: the batch axis of every decode tick is the row
        # axis of the per-token kernels, so the *physical* slot count comes
        # from the registry's plan for the decode batch shape -- the cache
        # (and each tick's feed) is packed to the planned sublane tile
        # instead of the raw requested slot count.  Extra physical slots
        # simply idle.  An explicit ``mesh`` wins; otherwise the ambient
        # plan_context is consulted at each planning call, so both
        # construct-inside-context and construct-then-context launchers
        # reach the planner with their mesh (slot *geometry* is fixed at
        # construction from the plan made here).
        self.mesh = mesh
        cfg = getattr(model, "cfg", None)
        self._d_model = int(getattr(cfg, "d_model", 0))
        self._adtype = getattr(cfg, "adtype", jnp.float32)
        self.decode_plan = self._batch_plan(slots)
        self.padded_slots = (
            self.decode_plan.rows if self.decode_plan is not None else slots
        )
        self.plans: dict[tuple[str, int], object] = {}
        if kv_cache == "paged":
            # Page geometry comes from the planner: one page is one planned
            # sublane tile of the per-slot KV stream (paged_cache module).
            self.geometry, self.page_plan = plan_page_geometry(
                cfg, max_len, page_len=page_len, n_pages=n_pages,
                slots=slots, banks=page_banks, mesh=mesh)
            self.pages = PageManager(self.geometry, self.padded_slots)
            defs = model.paged_cache_defs(
                self.padded_slots, max_len,
                self.geometry.n_pages, self.geometry.page_len)
        else:
            self.geometry = self.page_plan = self.pages = None
            defs = model.cache_defs(self.padded_slots, max_len)
        # Per-leaf batch axis from the defs tree's declared logical axes
        # (-1: no batch axis, e.g. the shared paged KV pools).  This is the
        # metadata _reset_slot and the chunk step restore along -- never
        # guessed from array shapes, which collide when max_len or a layer
        # count happens to equal padded_slots.
        self._batch_axes = params_lib.map_tree(
            lambda d: d.axes.index("batch") if "batch" in d.axes else -1,
            defs)
        self.decode = jax.jit(steps_lib.make_decode_step(model))
        self._chunk = jax.jit(
            steps_lib.make_chunk_step(model, self._batch_axes))
        key = jax.random.PRNGKey(seed)
        self.cache = params_lib.init_params(key, defs)
        # Pristine per-slot rows for admission resets; leaves with no batch
        # axis (shared pools) are never reset row-wise, so share storage.
        self._template = jax.tree.map(
            lambda c, ax: c if ax < 0 else jnp.copy(c),
            self.cache, self._batch_axes)
        self.slot_req: list[Request | None] = [None] * slots
        self._slot_pos = [0] * slots      # host mirror of each slot's idx
        self._slot_seq = [0] * slots      # admission order (for preemption)
        self._seq = 0
        self.queue: deque[Request] = deque()
        self.ticks = 0
        self.completed: dict[int, list[int]] = {}

    # ---- layout planning ---------------------------------------------------
    def _batch_plan(self, rows: int):
        """Registry plan for a decode/prefill batch of ``rows`` sequences:
        the per-token norm kernel over (rows, d_model) under this batcher's
        mesh.  Memoized by the planner, so per-admission calls are free."""
        if not self._d_model or rows <= 0:
            return None
        ctx = api.current_context()
        if self.mesh is not None:
            ctx = ctx.evolve(mesh=self.mesh)
        return api.plan_for("rmsnorm", (rows, self._d_model), self._adtype,
                            ctx=ctx)

    def _note_admitted_plans(self) -> None:
        """Record the plans of the currently *admitted* batch shapes
        (ROADMAP: serving-path planning).  Called on admission and on every
        tick -- slots move from prefill to decode without a new admission,
        and the memoized plan cache makes the repeat calls free.  Keyed by
        (phase, occupied count); each value is the plan the admitted batch
        *needs* (its ``rows`` is the smallest tile-aligned batch that could
        serve it -- the packing signal for shrinking the physical batch),
        while ``decode_plan`` remains the plan of the (padded_slots,
        d_model) batch every tick actually executes."""
        n_prefill = sum(r is not None and r.prefilling for r in self.slot_req)
        n_decode = sum(r is not None and not r.prefilling
                       for r in self.slot_req)
        for phase, n in (("prefill", n_prefill), ("decode", n_decode)):
            if n:
                plan = self._batch_plan(n)
                if plan is not None:
                    self.plans[(phase, n)] = plan

    # ------------------------------------------------------------------
    def submit(self, reqs: Iterable[Request]) -> None:
        for req in reqs:
            if not req.prompt:
                # An empty prompt has no token to feed and no position for
                # the first output -- reject loudly instead of crashing
                # mid-tick on prompt[fed].
                raise ValueError(
                    f"request {req.rid}: empty prompt (serving needs at "
                    f"least one prompt token)")
            self.queue.append(req)
        self._admit()

    def _reset_slot(self, cache, slot: int):
        """Copy pristine template rows into ``slot`` for every cache leaf,
        indexing each leaf along its *declared* batch axis (ParamDef.axes).
        Leaves without a batch axis -- the shared paged KV pools -- are
        left alone; the zeroed page-table row already unmaps the slot."""

        def reset(c, t, ax):
            if ax < 0:
                return c
            i = (slice(None),) * ax + (slot,)
            return c.at[i].set(t[i])

        return jax.tree.map(reset, cache, self._template, self._batch_axes)

    # ---- paged-pool bookkeeping --------------------------------------
    def _release_slot_pages(self, slot: int) -> list[int]:
        """Return ``slot``'s pages to the pool and unmap its device page
        table *immediately* -- idle slots still write every tick, and a
        stale table row would corrupt whoever the pages go to next."""
        freed = self.pages.release(slot)
        if freed:
            self.cache["pages"] = self.cache["pages"].at[slot].set(0)
        return freed

    def _preempt(self, victim: int, reason: str) -> int:
        """Evict ``victim``: pages back to the pool, request to the head of
        the queue with its replay horizon recorded.  Returns pages freed."""
        req = self.slot_req[victim]
        req.restart_target = len(req.prompt) + len(req.generated)
        req.fed = 0
        req.preemptions += 1
        freed = self._release_slot_pages(victim)
        self.slot_req[victim] = None
        self._slot_pos[victim] = 0
        self.queue.appendleft(req)
        if obs.enabled():
            obs.emit(obs.PreemptionEvent(
                rid=req.rid, slot=victim, reason=reason,
                pages_freed=len(freed), queue_depth=len(self.queue)))
        return len(freed)

    def _preempt_one(self, *, exclude: int, allow_decode: bool,
                     reason: str) -> bool:
        """Pick and evict one victim: prefilling slots first (newest
        admission first), then -- only for a decoding claimant -- the
        youngest decoding slot.  Decode priority: a prefill never steals
        pages from a decoder."""
        pre = [s for s, r in enumerate(self.slot_req)
               if r is not None and r.prefilling and s != exclude
               and self.pages.slot_pages(s)]
        if pre:
            victim = max(pre, key=lambda s: self._slot_seq[s])
            self._preempt(victim, reason)
            return True
        if allow_decode:
            dec = [s for s, r in enumerate(self.slot_req)
                   if r is not None and not r.prefilling and s != exclude
                   and self.pages.slot_pages(s)]
            if dec:
                victim = max(dec, key=lambda s: self._slot_seq[s])
                self._preempt(victim, reason)
                return True
        return False

    def _ensure_pages(self, slot: int, upto_pos: int, *,
                      decoding: bool) -> bool:
        """Grow ``slot``'s page table to cover ``upto_pos``, preempting if
        the pool is dry.  A decoding slot may evict prefillers then younger
        decoders; a prefilling slot may only displace newer prefillers and
        otherwise *stalls* (returns False -- the tick skips it)."""
        reason = "decode_pressure" if decoding else "prefill_pressure"
        while True:
            got = self.pages.alloc(slot, upto_pos)
            if got is not None:
                if got:
                    pages_leaf = self.cache["pages"]
                    for lp, phys in got:
                        pages_leaf = pages_leaf.at[slot, lp].set(phys)
                    self.cache["pages"] = pages_leaf
                return True
            if not self._preempt_one(exclude=slot, allow_decode=decoding,
                                     reason=reason):
                if decoding:
                    need = self.pages.needed(slot, upto_pos)
                    raise RuntimeError(
                        f"page pool too small: decoding slot {slot} needs "
                        f"{need} more page(s) of {self.geometry.page_len} "
                        f"with nothing left to preempt "
                        f"(n_pages={self.geometry.n_pages})")
                return False

    def _can_admit(self, req: Request) -> bool:
        """Paged admission backpressure: the pool must cover the request's
        replay plus one decode page, after reserving one growth page per
        already-decoding slot -- so admitting a prompt can't starve the
        decoders it would later be preempted for."""
        if self.pages is None:
            return True
        need = self.geometry.pages_for(min(req.replay_len + 1, self.max_len))
        if need > self.pages.live_pages:
            raise RuntimeError(
                f"page pool too small: request {req.rid} needs {need} "
                f"page(s) of {self.geometry.page_len} but the pool only "
                f"has {self.pages.live_pages} "
                f"(n_pages={self.geometry.n_pages})")
        reserve = sum(r is not None and not r.prefilling
                      for r in self.slot_req)
        return need + reserve <= self.pages.free_pages

    def shrink_pool(self, live_pages: int) -> int:
        """Graceful degradation on capacity loss: shrink the allocatable
        page pool to ``live_pages``, preempting tenants (decode included)
        through the replay path until enough pages are free to retire --
        the batcher keeps serving at reduced capacity instead of raising.
        Returns how many tenants were preempted.  Chaos harness entry
        point: ``runtime.faults.FaultInjector.tick`` calls this for
        ``PoolShrink`` faults."""
        if self.pages is None:
            raise RuntimeError(
                "shrink_pool requires kv_cache='paged' (a dense cache has "
                "no page pool to shrink)")
        before = self.pages.live_pages
        preempted = 0
        deficit = self.pages.shrink(live_pages)
        while deficit > 0:
            if not self._preempt_one(exclude=-1, allow_decode=True,
                                     reason="pool_shrink"):
                raise RuntimeError(
                    f"cannot shrink page pool to {live_pages} live "
                    f"page(s): {deficit} still to retire with no tenant "
                    f"left to preempt")
            preempted += 1
            deficit = self.pages.shrink(live_pages)
        log.warning("page pool shrunk %d -> %d live page(s); %d tenant(s) "
                    "preempted to the replay queue", before,
                    self.pages.live_pages, preempted)
        if obs.enabled():
            obs.emit(obs.DegradedEvent(
                reason="pool_shrink",
                detail=f"live pages {before} -> {self.pages.live_pages}, "
                       f"{preempted} tenant(s) preempted for replay"))
        return preempted

    def _admit(self) -> None:
        admitted = False
        for s in range(self.slots):
            if self.slot_req[s] is None and self.queue:
                if not self._can_admit(self.queue[0]):
                    break        # FIFO: no head-of-line bypass
                req = self.queue.popleft()
                self.slot_req[s] = req
                self._slot_pos[s] = 0
                self._seq += 1
                self._slot_seq[s] = self._seq
                self.cache = self._reset_slot(self.cache, s)
                admitted = True
                if obs.enabled():
                    obs.emit(obs.AdmissionEvent(
                        rid=req.rid, slot=s, queue_depth=len(self.queue)))
        if admitted:
            self._note_admitted_plans()

    # ------------------------------------------------------------------
    def step(self) -> None:
        self._note_admitted_plans()
        width = 1
        if self.prefill_chunk > 1 and any(
                r is not None and r.prefilling for r in self.slot_req):
            width = self.prefill_chunk
        # Per-slot advance this tick; paged slots must hold pages for every
        # position they will write *before* the device call.  Decoders
        # claim first (decode priority), then prefillers oldest-first; a
        # prefiller that cannot get pages stalls (advance 0) this tick.
        advance = [0] * self.slots
        order = sorted(
            (s for s, r in enumerate(self.slot_req) if r is not None),
            key=lambda s: (self.slot_req[s].prefilling, self._slot_seq[s]))
        for s in order:
            req = self.slot_req[s]
            if req is None:       # evicted by an earlier claimant this tick
                continue
            n = (min(width, req.replay_len - req.fed) if req.prefilling
                 else 1)
            if self.pages is not None:
                upto = min(self._slot_pos[s] + n, self.max_len) - 1
                if not self._ensure_pages(s, upto,
                                          decoding=not req.prefilling):
                    continue
            advance[s] = n
        feed = np.zeros((self.padded_slots, width), np.int32)
        nvalid = np.zeros((self.padded_slots,), np.int32)
        for s, req in enumerate(self.slot_req):
            if req is None or not advance[s]:
                continue
            nvalid[s] = advance[s]
            if req.prefilling:
                for j in range(advance[s]):
                    feed[s, j] = req.replay_token(req.fed + j)
            else:
                feed[s, 0] = req.generated[-1]
        # The chunk step is only needed when rows advance unevenly (chunked
        # prefill, or a stalled slot under page pressure); the uniform case
        # keeps the legacy single-token decode program.
        active = [n for n in advance if n]
        uniform = width == 1 and len(active) == sum(
            r is not None for r in self.slot_req)
        if uniform:
            nxt, self.cache = self.decode(self.params, self.cache,
                                          jnp.asarray(feed))
        else:
            nxt, self.cache = self._chunk(self.params, self.cache,
                                          jnp.asarray(feed),
                                          jnp.asarray(nvalid))
        nxt = np.asarray(nxt)[:, 0]
        self.ticks += 1
        if obs.enabled():
            # Packing waste is the tick's dead rows: slots with no tenant
            # (free) plus the tile padding the planner chose (pad).  Both
            # rows run through the decode step anyway -- the signal the
            # report aggregates into a mean waste fraction.
            n_prefill = sum(r is not None and r.prefilling
                            for r in self.slot_req)
            n_decode = sum(r is not None and not r.prefilling
                           for r in self.slot_req)
            obs.emit(obs.BatcherTickEvent(
                tick=self.ticks, n_prefill=n_prefill, n_decode=n_decode,
                slots=self.slots, padded_slots=self.padded_slots,
                free_slots=self.slots - n_prefill - n_decode,
                pad_slots=self.padded_slots - self.slots,
                queue_depth=len(self.queue)))
            if self.pages is not None:
                obs.emit(obs.PagePoolEvent(
                    tick=self.ticks, used_pages=self.pages.used_pages,
                    free_pages=self.pages.free_pages,
                    live_pages=self.pages.live_pages,
                    page_len=self.geometry.page_len))
        for s, req in enumerate(self.slot_req):
            if req is None or not advance[s]:
                continue
            self._slot_pos[s] += advance[s]
            if req.prefilling:
                req.fed += advance[s]
                if not req.prefilling:      # replay boundary: first new token
                    req.generated.append(int(nxt[s]))
            else:
                req.generated.append(int(nxt[s]))
            if req.done(self.eos_id):
                self.completed[req.rid] = req.generated[: req.max_new_tokens]
                self.slot_req[s] = None
                self._slot_pos[s] = 0
                if self.pages is not None:
                    self._release_slot_pages(s)
        self._admit()

    @property
    def busy(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slot_req)

    def run(self, reqs: Iterable[Request], *, max_ticks: int = 100_000,
            on_truncation: str = "raise",
            fault_injector=None) -> dict[int, list[int]]:
        """Drive submitted requests to completion (or ``max_ticks``).

        Hitting the tick budget with work in flight is never silent: the
        default raises :class:`TruncatedRun` (carrying both the completed
        results and the abandoned requests); ``on_truncation='return'``
        returns the partial ``completed`` dict instead -- callers opting
        in can check ``self.busy``.  Either way every abandoned request
        is reported on the obs bus.

        ``fault_injector`` (a ``runtime.faults.FaultInjector``) is
        consulted before each tick, so ``PoolShrink`` faults land at their
        chosen tick via :meth:`shrink_pool`."""
        if on_truncation not in ("raise", "return"):
            raise ValueError(
                f"on_truncation must be 'raise' or 'return', "
                f"got {on_truncation!r}")
        self.submit(reqs)
        while self.busy and self.ticks < max_ticks:
            if fault_injector is not None:
                fault_injector.tick(self, self.ticks)
            self.step()
        if self.busy:
            abandoned = [r for r in self.slot_req if r is not None]
            abandoned += list(self.queue)
            if obs.enabled():
                for r in abandoned:
                    stage = ("queued" if r in self.queue
                             else "prefill" if r.prefilling else "decode")
                    obs.emit(obs.RequestAbandonedEvent(
                        rid=r.rid, stage=stage, fed=r.fed,
                        generated=len(r.generated)))
            if on_truncation == "raise":
                raise TruncatedRun(dict(self.completed), abandoned,
                                   max_ticks)
        return self.completed
