"""Serving: continuous batching over the serve_step decode path."""
from repro.serving.scheduler import ContinuousBatcher, Request

__all__ = ["ContinuousBatcher", "Request"]
