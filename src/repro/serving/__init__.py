"""Serving: continuous batching over the serve_step decode path.

``ContinuousBatcher`` streams ragged requests through a fixed slot batch;
``kv_cache="paged"`` swaps the dense KV slab for the planner-packed page
pool (``serving.paged_cache``) with SLO-aware admission, chunked prefill,
and decode-priority preemption.  See docs/SERVING.md.
"""
from repro.serving.paged_cache import (
    DEFAULT_PAGE_VMEM,
    PageManager,
    plan_page_geometry,
)
from repro.serving.scheduler import ContinuousBatcher, Request, TruncatedRun

__all__ = [
    "ContinuousBatcher", "Request", "TruncatedRun",
    "PageManager", "plan_page_geometry", "DEFAULT_PAGE_VMEM",
]
