"""Planner-packed paged KV cache for the continuous batcher.

The dense serving cache is one ``(layers, slots, max_len, ...)`` slab whose
geometry nothing chose: every slot pre-pays ``max_len`` positions and a
retired request's memory is stranded until the slot is re-admitted.  This
module replaces the slab with the paper's segmentation discipline applied to
serving (docs/SERVING.md):

  * **pages are planner tiles** -- :func:`plan_page_geometry` asks the
    kernel registry for the plan of the per-slot KV stream
    ``(max_len, n_kv_heads * head_dim)`` under the ambient ``PlanContext``
    (mesh, sublane policy, VMEM budget) and uses the plan's VMEM block rows
    as the page length, so every physical page is exactly one planned
    sublane tile (§2.3's alignment rule);
  * **placement is skewed** -- free pages are handed out round-robin across
    ``banks`` interleave groups (``core.segmented.PageGeometry.alloc_order``),
    so the consecutive logical pages of one sequence land on different
    banks, the paper's per-segment phase shift at page granularity;
  * **memory returns immediately** -- a retired or preempted slot's pages go
    back to the free pool the moment it retires, instead of idling until
    the next admission resets the slot.

The pool itself lives in the model cache tree (``models.transformer
.paged_cache_defs``); this class owns the *host-side* bookkeeping: the free
list, each slot's allocated pages, and the admission arithmetic the
scheduler's backpressure/preemption policy is built on.
"""
from __future__ import annotations

from collections import deque

from repro import api
from repro.core.segmented import PageGeometry

__all__ = ["PageManager", "plan_page_geometry", "DEFAULT_PAGE_VMEM"]

# Default per-page VMEM budget handed to the planner when no explicit page
# length is requested: small enough that a long context spans many pages
# (the interesting regime), large enough that a page is several sublane
# tiles.  Like every planner knob it can be overridden via the ambient
# PlanContext or the ``page_len`` argument.
DEFAULT_PAGE_VMEM = 1 << 13


def plan_page_geometry(cfg, max_len: int, *, page_len: int | None = None,
                       n_pages: int | None = None, slots: int = 1,
                       banks: int = 4, mesh=None):
    """Derive the page geometry for a model's KV stream from the planner.

    Returns ``(PageGeometry, KernelPlan)``.  With ``page_len=None`` the page
    length IS the planner's chosen VMEM block-row tile for the
    ``(max_len, kv_width)`` stream under a page-sized VMEM budget; an
    explicit ``page_len`` must still be a whole number of planner sublane
    tiles (the alignment rule is not optional).  ``n_pages`` defaults to
    enough pages for ``slots`` full-length sequences plus the reserved null
    page -- shrink it to exercise backpressure/preemption.
    """
    kv_width = max(1, int(cfg.n_kv_heads) * int(cfg.hd))
    if page_len is None:
        plan = api.plan_tile("rmsnorm", (max_len, kv_width), cfg.adtype,
                             vmem_budget=DEFAULT_PAGE_VMEM, mesh=mesh)
        page_len = plan.block_rows
    else:
        plan = api.plan_tile("rmsnorm", (max_len, kv_width), cfg.adtype,
                             mesh=mesh)
        if page_len % plan.sublanes:
            raise ValueError(
                f"page_len {page_len} is not a multiple of the planner's "
                f"sublane tile {plan.sublanes} for dtype {plan.dtype}")
    max_pages = -(-max_len // page_len)
    if n_pages is None:
        n_pages = 1 + max(1, slots) * max_pages
    geom = PageGeometry(page_len=int(page_len), n_pages=int(n_pages),
                        banks=max(1, int(banks)))
    return geom, plan


class PageManager:
    """Host-side free-page pool + per-slot page tables.

    All methods are O(pages touched); allocation is all-or-nothing so a
    half-admitted request never strands pages.  The scheduler mirrors every
    ``alloc``/``release`` into the device-side ``pages`` leaf of the cache
    tree (``assignments`` returns the updates to apply).
    """

    def __init__(self, geometry: PageGeometry, n_slots: int):
        self.geometry = geometry
        self._free: deque[int] = deque(geometry.alloc_order())
        self._slot_pages: list[list[int]] = [[] for _ in range(n_slots)]
        # Pages withdrawn from service by shrink() -- capacity loss (a host
        # behind the pool went away) modelled without re-allocating the
        # device pool.  Never handed out again.
        self._retired: list[int] = []

    # ---- accounting ------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def live_pages(self) -> int:
        """Allocatable pages: the geometry's live pool minus any retired by
        :meth:`shrink`.  Admission/backpressure arithmetic must use this,
        not ``geometry.live_pages``, or a shrunken pool over-admits."""
        return self.geometry.live_pages - len(self._retired)

    @property
    def used_pages(self) -> int:
        return self.live_pages - len(self._free)

    # ---- capacity loss ---------------------------------------------------
    def shrink(self, live_pages: int) -> int:
        """Retire pages until at most ``live_pages`` remain in service,
        taking them from the *free* pool only.  Returns the remaining
        deficit: pages still to retire once the caller frees some (by
        preempting tenants) and calls again.  Never touches a page a slot
        currently holds."""
        target = max(0, int(live_pages))
        while self.live_pages > target and self._free:
            self._retired.append(self._free.pop())
        return max(0, self.live_pages - target)

    def slot_pages(self, slot: int) -> tuple[int, ...]:
        return tuple(self._slot_pages[slot])

    def needed(self, slot: int, upto_pos: int) -> int:
        """Pages ``slot`` is missing to cover logical position ``upto_pos``."""
        want = self.geometry.pages_for(upto_pos + 1)
        return max(0, want - len(self._slot_pages[slot]))

    def can_fit(self, length: int) -> bool:
        """Admission check: could a fresh sequence of ``length`` positions
        be paged in right now?"""
        return self.geometry.pages_for(length) <= len(self._free)

    # ---- allocation ------------------------------------------------------
    def alloc(self, slot: int, upto_pos: int) -> list[tuple[int, int]] | None:
        """Grow ``slot``'s table to cover ``upto_pos``.  Returns the new
        ``(logical_page, physical_page)`` assignments to mirror into the
        device page table, or ``None`` (and allocates nothing) if the free
        pool cannot supply them all."""
        need = self.needed(slot, upto_pos)
        if need > len(self._free):
            return None
        out = []
        table = self._slot_pages[slot]
        for _ in range(need):
            pid = self._free.popleft()
            out.append((len(table), pid))
            table.append(pid)
        return out

    def release(self, slot: int) -> list[int]:
        """Return all of ``slot``'s pages to the free pool (retire or
        preempt).  Freed pages are re-queued in bank-skewed order relative
        to each other so reuse keeps the interleave discipline."""
        pages = self._slot_pages[slot]
        self._slot_pages[slot] = []
        pages.sort(key=lambda pid: (pid % self.geometry.banks, pid))
        self._free.extend(pages)
        return pages
