"""Deterministic synthetic data pipeline.

Every batch is a pure function of (seed, step), so a restarted/elastically
resized job regenerates exactly the same stream from its checkpointed step --
the data-side half of fault tolerance.  Per-host sharding follows the JAX
multi-process convention: each process materializes only its addressable
shard via ``jax.make_array_from_callback`` when a sharding is supplied.

The generator is a tiny LCG-mixed Markov stream (not iid uniform) so the
cross-entropy actually *decreases* during the example runs.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_img_tokens: int = 0
    n_frames: int = 0
    d_model: int = 0


def _tokens_for(cfg: DataConfig, step: int, rows: np.ndarray) -> np.ndarray:
    """Markov-ish tokens for the given global row indices, shape (len(rows), S+1)."""
    rng = np.random.default_rng(np.uint64(cfg.seed * 1_000_003 + step))
    base = rng.integers(0, cfg.vocab_size, size=(len(rows), 1), dtype=np.int64)
    drift = (np.arange(cfg.seq_len + 1, dtype=np.int64) * 7) % 13
    toks = (base + drift[None, :] + rows[:, None] % 5) % cfg.vocab_size
    # inject noise on 10% of positions
    noise = rng.integers(0, cfg.vocab_size, size=toks.shape)
    mask = rng.random(toks.shape) < 0.1
    return np.where(mask, noise, toks).astype(np.int32)


def make_batch(cfg: DataConfig, step: int, sharding=None) -> dict:
    """Global batch for ``step`` (host-sharded when a sharding is given)."""

    def tokens_cb(index) -> np.ndarray:
        rows = np.arange(cfg.global_batch)[index[0]]
        block = _tokens_for(cfg, step, rows)
        cols = index[1] if len(index) > 1 else slice(None)
        return block[:, :-1][:, cols]

    def labels_cb(index) -> np.ndarray:
        rows = np.arange(cfg.global_batch)[index[0]]
        block = _tokens_for(cfg, step, rows)
        cols = index[1] if len(index) > 1 else slice(None)
        return block[:, 1:][:, cols]

    shape = (cfg.global_batch, cfg.seq_len)
    if sharding is not None:
        batch = {
            "tokens": jax.make_array_from_callback(shape, sharding, tokens_cb),
            "labels": jax.make_array_from_callback(shape, sharding, labels_cb),
        }
    else:
        full = _tokens_for(cfg, step, np.arange(cfg.global_batch))
        batch = {
            "tokens": jnp.asarray(full[:, :-1]),
            "labels": jnp.asarray(full[:, 1:]),
        }
    if cfg.n_img_tokens and cfg.d_model:
        rng = np.random.default_rng(np.uint64(cfg.seed * 7 + step))
        batch["img_embeds"] = jnp.asarray(
            rng.standard_normal((cfg.global_batch, cfg.n_img_tokens,
                                 cfg.d_model), dtype=np.float32)
        )
    if cfg.n_frames and cfg.d_model:
        rng = np.random.default_rng(np.uint64(cfg.seed * 11 + step))
        batch["frames"] = jnp.asarray(
            rng.standard_normal((cfg.global_batch, cfg.n_frames, cfg.d_model),
                                dtype=np.float32)
        )
    return batch


def stream(cfg: DataConfig, start_step: int = 0, sharding=None) -> Iterator[dict]:
    step = start_step
    while True:
        yield make_batch(cfg, step, sharding)
        step += 1
