"""Checkpointing: atomic step directories, async writer, per-process shards.

Layout:  <dir>/step_<N>/shard_<process>.npz + meta.json, written to a tmp
directory and renamed on completion (a crash mid-write never corrupts the
latest checkpoint).  Restore picks the newest complete step.  On a real
multi-host pod each process saves only its addressable shards and restore
reassembles per device; in this single-process container that degenerates to
one shard file, but the path layout and the (path -> array) flattening are
the production ones.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.kind not in "biufc":  # ml_dtypes (bf16/f8): store as f32
            arr = arr.astype(np.float32)   # lossless widening; restore re-casts
        out[key] = arr
    return out


def _unflatten_into(tree, flat: dict[str, np.ndarray]):
    def rebuild(path, leaf):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = flat[key]
        return jax.numpy.asarray(arr, dtype=leaf.dtype).reshape(leaf.shape)

    return jax.tree_util.tree_map_with_path(rebuild, tree)


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: threading.Thread | None = None
        # A failure on the async writer thread is captured here and
        # re-raised from the next wait()/save() on the caller thread --
        # a checkpoint silently lost to a daemon-thread exception would
        # only surface as an unexplainably old restore much later.
        self._error: BaseException | None = None
        # Chaos hook (runtime/faults.py): called inside _write after the
        # tmp dir is populated but before the atomic rename, so a raising
        # hook leaves exactly the torn state a mid-write crash would.
        self.fault_hook = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def save(self, step: int, state: Any, *, meta: dict | None = None) -> None:
        flat = _flatten(state)  # device_get happens on the caller thread
        if self.async_write:
            self.wait()  # raises if the previous async write failed
            self._thread = threading.Thread(
                target=self._write_async, args=(step, flat, meta or {}),
                daemon=True,
            )
            self._thread.start()
        else:
            self._write(step, flat, meta or {})

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(
                f"async checkpoint write failed: {err!r} (the step was "
                f"never completed; its torn tmp dir is invisible to "
                f"restore)"
            ) from err

    def _write_async(self, step: int, flat: dict, meta: dict) -> None:
        try:
            self._write(step, flat, meta)
        except BaseException as e:  # noqa: BLE001 -- re-raised from wait()
            self._error = e

    def _write(self, step: int, flat: dict, meta: dict) -> None:
        proc = jax.process_index()
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + f".tmp{proc}"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, f"shard_{proc}.npz"), **flat)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, **meta}, f)
        if self.fault_hook is not None:
            self.fault_hook(step, tmp)
        if os.path.isdir(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name, "meta.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any) -> Any:
        """Restore into the structure/dtypes/shapes of ``like``."""
        self.wait()
        proc = jax.process_index()
        path = os.path.join(self.dir, f"step_{step:08d}", f"shard_{proc}.npz")
        with np.load(path) as z:
            flat = {k: z[k] for k in z.files}
        return _unflatten_into(like, flat)

    def restore_latest(self, like: Any) -> tuple[int, Any] | None:
        # Settle any in-flight async save first: a save() scheduled before
        # this call must be selectable, not invisibly racing the directory
        # listing (the trainer's failure path restores right after saves).
        self.wait()
        step = self.latest_step()
        if step is None:
            return None
        return step, self.restore(step, like)
