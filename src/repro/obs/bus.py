"""The event bus: an ambient, nestable sink scope mirroring PlanContext.

Instrumented code (the launch path, the trainer, the batcher, the
validator) never holds a sink; it asks the *ambient* bus:

    from repro.obs import bus, events

    if bus.enabled():
        bus.emit(events.PlanEvent(...))

and callers decide where events go by entering a session:

    with obs.session(obs.JsonlSink("run.jsonl")):
        trainer.train(...)        # every event inside streams to the file

Sessions nest exactly like ``api.plan_context``: an inner session
*inherits* the enclosing scope's sinks and adds its own (an inner ring
buffer observes without detaching the outer JSONL stream); pass
``inherit=False`` to isolate a scope, and ``session(NullSink(),
inherit=False)`` silences one explicitly.  The stack is thread-local --
concurrent serving threads can stream to different sinks -- and a
process-wide default (``set_default_sinks``) serves launchers that
configure the stream once at startup.

The default is a single ``NullSink``: ``enabled()`` is False, so every
instrumentation site skips event construction entirely.  That guard is
the subsystem's zero-overhead contract -- tests count sink calls under
the default and assert zero (tests/test_obs.py).
"""
from __future__ import annotations

import contextlib
import logging
import threading

from repro.obs.sinks import NullSink, Sink

__all__ = [
    "enabled",
    "emit",
    "session",
    "current_sinks",
    "set_default_sinks",
    "reset_default_sinks",
]

_log = logging.getLogger("repro.obs")

_NULL = NullSink()
_DEFAULT_LOCK = threading.Lock()
_default_sinks: tuple[Sink, ...] = (_NULL,)
_tls = threading.local()


def _stack() -> list[tuple[Sink, ...]]:
    st = getattr(_tls, "stack", None)
    if st is None:
        st = _tls.stack = []
    return st


def current_sinks() -> tuple[Sink, ...]:
    """The sinks an ``emit`` in this thread would deliver to right now."""
    st = _stack()
    return st[-1] if st else _default_sinks


def enabled() -> bool:
    """True when any active sink actually listens (is not a NullSink).

    Producers gate on this before *building* an event, so the default
    (NullSink-only) configuration costs one tuple scan and nothing else.
    """
    return any(not isinstance(s, NullSink) for s in current_sinks())


def emit(event) -> None:
    """Deliver ``event`` to every active sink.

    A failing sink is logged and skipped -- observability must never take
    down the training step or the serving tick it observes.
    """
    for sink in current_sinks():
        try:
            sink.emit(event)
        except Exception:  # noqa: BLE001 -- a sink must not kill the host
            _log.exception("obs sink %r failed; event dropped",
                           type(sink).__name__)


def set_default_sinks(*sinks: Sink) -> None:
    """Install the process-wide default sinks (what threads with no active
    session emit to).  Launchers call this once at startup; no sinks
    restores the built-in NullSink default."""
    global _default_sinks
    for s in sinks:
        if not hasattr(s, "emit"):
            raise TypeError(f"not a sink (no emit): {type(s).__name__}")
    with _DEFAULT_LOCK:
        _default_sinks = tuple(sinks) if sinks else (_NULL,)


def reset_default_sinks() -> None:
    """Restore the built-in NullSink default (tests)."""
    set_default_sinks()


@contextlib.contextmanager
def session(*sinks: Sink, inherit: bool = True):
    """Enter an observability scope delivering to ``sinks``.

    With ``inherit=True`` (default) the scope *adds* its sinks to the
    enclosing scope's -- nesting a ring buffer inside a JSONL session
    delivers every event to both, mirroring ``plan_context``'s
    field-inheritance semantics.  ``inherit=False`` makes ``sinks`` the
    whole scope.  Yields the active sink tuple.
    """
    for s in sinks:
        if not hasattr(s, "emit"):
            raise TypeError(f"not a sink (no emit): {type(s).__name__}")
    base = current_sinks() if inherit else ()
    # Inherited NullSinks are dropped: they carry no behavior, and keeping
    # them would make an enabled() scan linger over dead entries.
    active = tuple(s for s in base if not isinstance(s, NullSink)) + sinks
    if not active:
        active = (_NULL,)
    st = _stack()
    st.append(active)
    try:
        yield active
    finally:
        st.pop()
