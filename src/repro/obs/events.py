"""Typed observability events: the vocabulary of the bus.

Every event is a small frozen dataclass with a class-level ``kind`` tag
and a wall-clock timestamp.  The taxonomy mirrors the repo's existing
*offline* checks, promoted to streaming form (docs/OBS.md):

  * ``PlanEvent``            -- one ``plan_for`` resolution: plan-cache
                                hit/miss plus where the layout decision
                                came from (analytic / override / profile).
  * ``SpmdFallbackEvent``    -- a declared sharding degraded to
                                replication (``rules.spec_report``'s
                                divisibility fallback), with the reasons.
  * ``SpmdOverrideShadowEvent`` -- plan overrides keyed at a global shape
                                under an SPMD launch: inert cells.
  * ``ValidationEvent``      -- one measured-vs-predicted record
                                (``measure.validate``): HBM bytes or
                                comm wire bytes against the plan's model.
  * ``TrainStepEvent``       -- one trainer step's metrics.
  * ``CheckpointEvent``      -- a checkpoint save/restore.
  * ``AdmissionEvent``       -- the batcher admitted a request to a slot.
  * ``BatcherTickEvent``     -- one decode tick's occupancy/packing state.
  * ``PagePoolEvent``        -- the paged KV cache's pool occupancy after
                                a tick (paged batcher only).
  * ``PreemptionEvent``      -- the batcher evicted a slot to reclaim its
                                pages (the request is requeued for replay).
  * ``RequestAbandonedEvent`` -- ``run()`` hit its tick budget with this
                                request still queued or in flight.
  * ``ProfileDriftEvent``    -- a swept profile cell no longer reproduces
                                its recorded geometry (planner drift).
  * ``MeshChangeEvent``      -- the elastic runtime rebuilt the mesh after
                                a topology change (device loss / gain).
  * ``ResumeEvent``          -- the elastic runtime restored a checkpoint
                                onto the (new) mesh and resumed training.
  * ``DegradedEvent``        -- the system kept running in a degraded
                                mode: a straggling step, a transient-step
                                retry, retired surplus devices, or a
                                serving page-pool shrink.

Events serialize with :meth:`Event.to_record` -- a flat JSON-safe dict
with ``kind`` and ``ts`` first -- which is exactly what ``JsonlSink``
writes and ``python -m repro.obs.report`` aggregates.  Producers build
events only when the bus is enabled (``repro.obs.bus.enabled``), so the
taxonomy costs nothing when no sink is listening.
"""
from __future__ import annotations

import dataclasses
import time
from typing import ClassVar

__all__ = [
    "Event",
    "PlanEvent",
    "SpmdFallbackEvent",
    "SpmdOverrideShadowEvent",
    "ValidationEvent",
    "TrainStepEvent",
    "CheckpointEvent",
    "AdmissionEvent",
    "BatcherTickEvent",
    "PagePoolEvent",
    "PreemptionEvent",
    "RequestAbandonedEvent",
    "ProfileDriftEvent",
    "MeshChangeEvent",
    "ResumeEvent",
    "DegradedEvent",
    "EVENT_KINDS",
]


def _jsonable(v):
    """Tuples -> lists (recursively) so records round-trip through JSON."""
    if isinstance(v, (tuple, list)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    return v


@dataclasses.dataclass(frozen=True)
class Event:
    """Base event: a ``kind`` tag plus the emission wall-clock time."""

    kind: ClassVar[str] = "event"

    ts: float = dataclasses.field(default_factory=time.time, kw_only=True)

    def to_record(self) -> dict:
        """Flat JSON-safe dict: ``{"kind": ..., "ts": ..., <fields>}``."""
        rec = {"kind": self.kind, "ts": self.ts}
        for f in dataclasses.fields(self):
            if f.name == "ts":
                continue
            rec[f.name] = _jsonable(getattr(self, f.name))
        return rec


@dataclasses.dataclass(frozen=True)
class PlanEvent(Event):
    """One ``api.plan_for`` resolution, with provenance.

    ``cache`` is "hit"/"miss" for planner-derived plans and "override"
    when a ``plan_overrides`` pin short-circuited the planner; ``source``
    is the plan's provenance ("analytic", "profile:<path>", ...).
    """

    kind: ClassVar[str] = "plan"

    kernel: str
    shape: tuple
    dtype: str
    cache: str
    source: str = "analytic"
    local: bool = False
    mesh: tuple = ()


@dataclasses.dataclass(frozen=True)
class SpmdFallbackEvent(Event):
    """A declared sharding fell back to replication on this launch."""

    kind: ClassVar[str] = "spmd_fallback"

    kernel: str
    mesh: tuple
    reasons: tuple


@dataclasses.dataclass(frozen=True)
class SpmdOverrideShadowEvent(Event):
    """Plan-override cells keyed at the global shape of an SPMD launch --
    they can never match the per-shard local shapes, so the pin is inert."""

    kind: ClassVar[str] = "spmd_override_shadow"

    kernel: str
    mesh: tuple
    global_shape: tuple
    cells: tuple


@dataclasses.dataclass(frozen=True)
class ValidationEvent(Event):
    """One measured-vs-predicted record (``repro.measure.validate``).

    ``check`` is "hbm" (compiled bytes-accessed vs predicted_hbm_bytes)
    or "comm" (collective-census wire bytes vs predicted_comm_bytes).
    """

    kind: ClassVar[str] = "validation"

    kernel: str
    family: str
    check: str
    predicted_bytes: float
    measured_bytes: float
    ratio: float
    status: str
    mesh: tuple = ()


@dataclasses.dataclass(frozen=True)
class TrainStepEvent(Event):
    """One optimizer step's metrics (the structured form of the trainer's
    legacy ``metrics`` list-of-dicts)."""

    kind: ClassVar[str] = "train_step"

    step: int
    loss: float
    grad_norm: float
    step_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class CheckpointEvent(Event):
    """A checkpoint transition: ``action`` is "save" or "restore"."""

    kind: ClassVar[str] = "checkpoint"

    step: int
    action: str


@dataclasses.dataclass(frozen=True)
class AdmissionEvent(Event):
    """The continuous batcher admitted a request into a decode slot."""

    kind: ClassVar[str] = "admission"

    rid: int
    slot: int
    queue_depth: int


@dataclasses.dataclass(frozen=True)
class BatcherTickEvent(Event):
    """One serve tick's slot occupancy and packing state.

    ``pad_slots`` is the tile-padding overhead the planner chose
    (physical minus requested slots); ``free_slots`` is requested slots
    with no tenant.  Together they are the tick's packing waste: rows the
    decode batch computes that serve no request.
    """

    kind: ClassVar[str] = "batcher_tick"

    tick: int
    n_prefill: int
    n_decode: int
    slots: int
    padded_slots: int
    free_slots: int
    pad_slots: int
    queue_depth: int


@dataclasses.dataclass(frozen=True)
class PagePoolEvent(Event):
    """Paged-KV pool occupancy after one tick (paged batcher only).

    ``live_pages`` excludes the reserved null page; utilization is
    ``used_pages / live_pages``.  A pool pinned at full is the
    backpressure/preemption regime; a pool near empty means the page
    budget (``n_pages``) is oversized for the offered load.
    """

    kind: ClassVar[str] = "page_pool"

    tick: int
    used_pages: int
    free_pages: int
    live_pages: int
    page_len: int


@dataclasses.dataclass(frozen=True)
class PreemptionEvent(Event):
    """The batcher evicted a slot's request to reclaim its pages.

    ``reason`` is "decode_pressure" (a decoding slot needed a page) or
    "prefill_pressure" (an older prefill displaced a newer one).  The
    request is requeued at the head of the queue and replays from scratch
    on re-admission (greedy decode makes the replay token-identical).
    """

    kind: ClassVar[str] = "preemption"

    rid: int
    slot: int
    reason: str
    pages_freed: int
    queue_depth: int


@dataclasses.dataclass(frozen=True)
class RequestAbandonedEvent(Event):
    """``run()`` exhausted ``max_ticks`` with this request unfinished.

    ``stage`` is "queued", "prefill", or "decode"; ``fed``/``generated``
    record how far it got.  Paired with :class:`~repro.serving.scheduler
    .TruncatedRun` so truncation is never silent.
    """

    kind: ClassVar[str] = "request_abandoned"

    rid: int
    stage: str
    fed: int
    generated: int


@dataclasses.dataclass(frozen=True)
class ProfileDriftEvent(Event):
    """A swept profile cell no longer reproduces its recorded geometry."""

    kind: ClassVar[str] = "profile_drift"

    path: str
    cell: str
    detail: str


@dataclasses.dataclass(frozen=True)
class MeshChangeEvent(Event):
    """The elastic runtime rebuilt the mesh after a topology change.

    ``old_mesh``/``new_mesh`` are ``(axis, size)`` pairs; ``failed_ids``
    are the devices reported lost, ``retired_ids`` the *surviving*
    devices the new mesh could not use (surplus after preserving the TP
    axis -- a partial TP group, or a remainder that does not divide).
    ``step`` is the training step at which the change was observed."""

    kind: ClassVar[str] = "mesh_change"

    old_mesh: tuple
    new_mesh: tuple
    failed_ids: tuple = ()
    retired_ids: tuple = ()
    reason: str = "device_loss"
    step: int = -1


@dataclasses.dataclass(frozen=True)
class ResumeEvent(Event):
    """The elastic runtime resumed training on a (re-built) mesh.

    ``step`` is the checkpoint step training resumes from (0 on a cold
    start with no checkpoint); ``batch_chunks`` the per-DP-group batch
    sizes after ``rebalance_batch``; ``invalidated_plans`` how many
    plan-cache cells keyed to the old mesh were dropped;
    ``spec_fallbacks`` the ``rules.spec_report`` reasons for any batch
    dimension that fell back to replication on the new mesh."""

    kind: ClassVar[str] = "resume"

    step: int
    mesh: tuple
    batch_chunks: tuple = ()
    invalidated_plans: int = 0
    restored: bool = True
    spec_fallbacks: tuple = ()


@dataclasses.dataclass(frozen=True)
class DegradedEvent(Event):
    """The system kept running in a degraded mode instead of failing.

    ``reason`` is one of "straggler" (a step exceeded the straggler
    threshold over the step-time EMA), "transient_retry" (a step raised a
    transient error and was retried with backoff), "surplus_devices"
    (``surviving_mesh`` retired alive devices it could not place), or
    "pool_shrink" (the serving page pool lost capacity and tenants were
    re-admitted via preemption-by-replay)."""

    kind: ClassVar[str] = "degraded"

    reason: str
    detail: str = ""
    step: int = -1


EVENT_KINDS: dict[str, type[Event]] = {
    cls.kind: cls
    for cls in (
        PlanEvent,
        SpmdFallbackEvent,
        SpmdOverrideShadowEvent,
        ValidationEvent,
        TrainStepEvent,
        CheckpointEvent,
        AdmissionEvent,
        BatcherTickEvent,
        PagePoolEvent,
        PreemptionEvent,
        RequestAbandonedEvent,
        ProfileDriftEvent,
        MeshChangeEvent,
        ResumeEvent,
        DegradedEvent,
    )
}
