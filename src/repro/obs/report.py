"""Aggregate a JSONL event stream into a health summary.

    python -m repro.obs.report run.jsonl [more.jsonl ...] [--json] \
        [--fail-on-validation]

Reads the record-per-line stream a ``JsonlSink`` wrote (docs/OBS.md) and
reports, per section:

  * plan cache -- hit/miss/override counts and the hit rate, split by
    kernel, plus where decisions came from (analytic vs profile pins);
  * SPMD health -- declared shardings that fell back to replication
    (with reasons) and override cells shadowed by per-shard planning;
  * validation -- worst measured/predicted ratio per (family, check)
    and any out-of-envelope records, for both HBM bytes and comm wire
    bytes;
  * trainer -- steps, loss trajectory, mean step wall time, checkpoints;
  * batcher -- admissions, peak queue depth, mean packing waste (free +
    tile-pad slots as a fraction of the physical decode batch), plus the
    paged-KV signals: mean/peak page-pool utilization, preemptions (by
    reason), and requests abandoned at a run's tick budget;
  * elastic -- mesh changes (with the surviving topology), elastic
    resumes (restore step, re-chunked batch), and degraded-mode events
    by reason (stragglers, transient retries, retired surplus devices,
    serving pool shrinks);
  * profile drift -- swept cells the planner no longer reproduces.

Sections with no events still print (zeroed), so the summary shape is
stable for scraping.  ``--json`` emits the aggregate as one JSON object
instead.  Exit status: 0 on success, 1 with ``--fail-on-validation``
when any validation event is out of envelope, 2 on unreadable input.
"""
from __future__ import annotations

import argparse
import json
import sys

__all__ = ["aggregate", "render", "main"]


def _read_records(paths) -> tuple[list[dict], int]:
    """All parseable records across ``paths`` plus the malformed-line
    count (a torn final line from a crashed run is data, not an error)."""
    records: list[dict] = []
    bad = 0
    for path in paths:
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    bad += 1
                    continue
                if isinstance(rec, dict) and "kind" in rec:
                    records.append(rec)
                else:
                    bad += 1
    return records, bad


def _mesh_str(mesh) -> str:
    if not mesh:
        return "-"
    return ",".join(f"{a}={n}" for a, n in mesh)


def aggregate(records: list[dict]) -> dict:
    """Fold a record stream into the summary dict ``render`` prints."""
    plan = {"total": 0, "hits": 0, "misses": 0, "overrides": 0,
            "by_kernel": {}, "sources": {}}
    fallbacks = {"total": 0, "by_site": {}}
    shadows = {"total": 0, "cells": []}
    validation: dict[str, dict] = {}
    train = {"steps": 0, "first_loss": None, "last_loss": None,
             "sum_step_s": 0.0, "checkpoint_saves": 0,
             "checkpoint_restores": 0}
    batcher = {"admissions": 0, "max_queue_depth": 0, "ticks": 0,
               "sum_waste_frac": 0.0, "page_ticks": 0,
               "sum_page_util": 0.0, "peak_page_util": None,
               "preemptions": 0, "preempt_reasons": {},
               "abandoned": 0}
    elastic = {"mesh_changes": 0, "last_mesh": None, "resumes": 0,
               "last_resume_step": None, "invalidated_plans": 0,
               "degraded": 0, "degraded_reasons": {}}
    drift = {"total": 0, "cells": []}

    for rec in records:
        kind = rec["kind"]
        if kind == "plan":
            plan["total"] += 1
            cache = rec.get("cache", "miss")
            bucket = {"hit": "hits", "miss": "misses"}.get(cache, "overrides")
            plan[bucket] += 1
            k = plan["by_kernel"].setdefault(
                rec.get("kernel", "?"),
                {"hits": 0, "misses": 0, "overrides": 0})
            k[bucket] += 1
            src = rec.get("source", "analytic")
            plan["sources"][src] = plan["sources"].get(src, 0) + 1
        elif kind == "spmd_fallback":
            fallbacks["total"] += 1
            site = (f"{rec.get('kernel', '?')}@"
                    f"{_mesh_str(rec.get('mesh', ()))}")
            s = fallbacks["by_site"].setdefault(
                site, {"count": 0, "reasons": []})
            s["count"] += 1
            for r in rec.get("reasons", ()):
                if r not in s["reasons"]:
                    s["reasons"].append(r)
        elif kind == "spmd_override_shadow":
            shadows["total"] += 1
            for c in rec.get("cells", ()):
                if c not in shadows["cells"]:
                    shadows["cells"].append(c)
        elif kind == "validation":
            key = f"{rec.get('family', '?')}/{rec.get('check', 'hbm')}"
            v = validation.setdefault(
                key, {"n": 0, "fails": 0, "min_ratio": None,
                      "max_ratio": None, "worst": None})
            v["n"] += 1
            if rec.get("status") != "ok":
                v["fails"] += 1
            try:
                ratio = float(rec.get("ratio", 0.0))
            except (TypeError, ValueError):  # "inf" etc.
                ratio = float("inf")
            if v["min_ratio"] is None or ratio < v["min_ratio"]:
                v["min_ratio"] = ratio
            if v["max_ratio"] is None or ratio > v["max_ratio"]:
                v["max_ratio"] = ratio
            # Worst = farthest from the model's prediction (ratio 1.0).
            prev = v["worst"]
            if prev is None or abs(ratio - 1.0) > abs(prev - 1.0):
                v["worst"] = ratio
        elif kind == "train_step":
            train["steps"] += 1
            loss = rec.get("loss")
            if train["first_loss"] is None:
                train["first_loss"] = loss
            train["last_loss"] = loss
            train["sum_step_s"] += float(rec.get("step_s", 0.0) or 0.0)
        elif kind == "checkpoint":
            if rec.get("action") == "save":
                train["checkpoint_saves"] += 1
            else:
                train["checkpoint_restores"] += 1
        elif kind == "admission":
            batcher["admissions"] += 1
            batcher["max_queue_depth"] = max(
                batcher["max_queue_depth"], int(rec.get("queue_depth", 0)))
        elif kind == "batcher_tick":
            batcher["ticks"] += 1
            padded = int(rec.get("padded_slots", 0)) or 1
            waste = int(rec.get("free_slots", 0)) + int(
                rec.get("pad_slots", 0))
            batcher["sum_waste_frac"] += waste / padded
            batcher["max_queue_depth"] = max(
                batcher["max_queue_depth"], int(rec.get("queue_depth", 0)))
        elif kind == "page_pool":
            batcher["page_ticks"] += 1
            live = int(rec.get("live_pages", 0)) or 1
            util = int(rec.get("used_pages", 0)) / live
            batcher["sum_page_util"] += util
            if (batcher["peak_page_util"] is None
                    or util > batcher["peak_page_util"]):
                batcher["peak_page_util"] = util
        elif kind == "preemption":
            batcher["preemptions"] += 1
            reason = rec.get("reason", "?")
            batcher["preempt_reasons"][reason] = (
                batcher["preempt_reasons"].get(reason, 0) + 1)
        elif kind == "request_abandoned":
            batcher["abandoned"] += 1
        elif kind == "mesh_change":
            elastic["mesh_changes"] += 1
            elastic["last_mesh"] = _mesh_str(rec.get("new_mesh", ()))
        elif kind == "resume":
            elastic["resumes"] += 1
            elastic["last_resume_step"] = rec.get("step")
            elastic["invalidated_plans"] += int(
                rec.get("invalidated_plans", 0))
        elif kind == "degraded":
            elastic["degraded"] += 1
            reason = rec.get("reason", "?")
            elastic["degraded_reasons"][reason] = (
                elastic["degraded_reasons"].get(reason, 0) + 1)
        elif kind == "profile_drift":
            drift["total"] += 1
            cell = rec.get("cell", "?")
            if cell not in drift["cells"]:
                drift["cells"].append(cell)

    planned = plan["hits"] + plan["misses"]
    plan["hit_rate"] = plan["hits"] / planned if planned else None
    train["mean_step_s"] = (
        train["sum_step_s"] / train["steps"] if train["steps"] else None)
    batcher["mean_waste_frac"] = (
        batcher["sum_waste_frac"] / batcher["ticks"]
        if batcher["ticks"] else None)
    batcher["mean_page_util"] = (
        batcher["sum_page_util"] / batcher["page_ticks"]
        if batcher["page_ticks"] else None)
    return {
        "events": len(records),
        "plan": plan,
        "spmd_fallbacks": fallbacks,
        "spmd_override_shadows": shadows,
        "validation": validation,
        "train": train,
        "batcher": batcher,
        "elastic": elastic,
        "profile_drift": drift,
    }


def _fmt(v, spec: str = ".3g") -> str:
    return "-" if v is None else format(v, spec)


def render(summary: dict) -> str:
    """Human-readable health summary (one stable section per subsystem)."""
    plan = summary["plan"]
    lines = [f"events: {summary['events']}"]
    rate = plan["hit_rate"]
    lines.append(
        f"plan cache: {plan['total']} plan(s) -- {plan['hits']} hit / "
        f"{plan['misses']} miss / {plan['overrides']} override"
        + (f", hit rate {rate:.1%}" if rate is not None else ""))
    for kernel in sorted(plan["by_kernel"]):
        k = plan["by_kernel"][kernel]
        lines.append(f"  {kernel}: {k['hits']} hit / {k['misses']} miss / "
                     f"{k['overrides']} override")
    for src in sorted(plan["sources"]):
        lines.append(f"  source {src}: {plan['sources'][src]}")

    fb = summary["spmd_fallbacks"]
    lines.append(f"spmd fallbacks: {fb['total']}")
    for site in sorted(fb["by_site"]):
        s = fb["by_site"][site]
        lines.append(f"  {site}: x{s['count']} ({'; '.join(s['reasons'])})")
    sh = summary["spmd_override_shadows"]
    lines.append(f"spmd shadowed overrides: {sh['total']}"
                 + (f" (cells: {', '.join(sh['cells'])})"
                    if sh["cells"] else ""))

    val = summary["validation"]
    lines.append(f"validation: {sum(v['n'] for v in val.values())} record(s)")
    for key in sorted(val):
        v = val[key]
        lines.append(
            f"  {key}: worst ratio {_fmt(v['worst'])} "
            f"(range {_fmt(v['min_ratio'])}..{_fmt(v['max_ratio'])}, "
            f"{v['fails']} fail / {v['n']})")

    tr = summary["train"]
    lines.append(
        f"trainer: {tr['steps']} step(s), loss "
        f"{_fmt(tr['first_loss'], '.4g')} -> {_fmt(tr['last_loss'], '.4g')}, "
        f"mean step {_fmt(tr['mean_step_s'], '.3g')}s, "
        f"ckpt {tr['checkpoint_saves']} save / "
        f"{tr['checkpoint_restores']} restore")

    ba = summary["batcher"]
    waste = ba["mean_waste_frac"]
    lines.append(
        f"batcher: {ba['admissions']} admission(s), {ba['ticks']} tick(s), "
        f"peak queue {ba['max_queue_depth']}, mean packing waste "
        + (f"{waste:.1%}" if waste is not None else "-"))
    util = ba["mean_page_util"]
    reasons = "; ".join(f"{r}: {n}" for r, n in
                        sorted(ba["preempt_reasons"].items()))
    lines.append(
        "  paged kv: "
        + (f"mean pool util {util:.1%}, peak {ba['peak_page_util']:.1%}"
           if util is not None else "no page-pool events")
        + f", {ba['preemptions']} preemption(s)"
        + (f" ({reasons})" if reasons else "")
        + f", {ba['abandoned']} abandoned request(s)")

    el = summary["elastic"]
    reasons = "; ".join(f"{r}: {n}" for r, n in
                        sorted(el["degraded_reasons"].items()))
    lines.append(
        f"elastic: {el['mesh_changes']} mesh change(s)"
        + (f" (now {el['last_mesh']})" if el["last_mesh"] else "")
        + f", {el['resumes']} resume(s)"
        + (f" (last from step {el['last_resume_step']}, "
           f"{el['invalidated_plans']} plan(s) invalidated)"
           if el["last_resume_step"] is not None else "")
        + f", {el['degraded']} degraded event(s)"
        + (f" ({reasons})" if reasons else ""))

    dr = summary["profile_drift"]
    lines.append(f"profile drift: {dr['total']}"
                 + (f" (cells: {', '.join(dr['cells'])})"
                    if dr["cells"] else ""))
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="aggregate a repro.obs JSONL event stream into a "
                    "health summary")
    ap.add_argument("paths", nargs="+", help="JSONL event stream(s)")
    ap.add_argument("--json", action="store_true",
                    help="emit the aggregate as JSON instead of text")
    ap.add_argument("--fail-on-validation", action="store_true",
                    help="exit 1 if any validation event is out of its "
                         "envelope")
    args = ap.parse_args(argv)

    try:
        records, bad = _read_records(args.paths)
    except OSError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    summary = aggregate(records)
    if bad:
        summary["malformed_lines"] = bad
    if args.json:
        print(json.dumps(summary, indent=1))
    else:
        print(render(summary))
        if bad:
            print(f"({bad} malformed line(s) skipped)")
    if args.fail_on_validation and any(
            v["fails"] for v in summary["validation"].values()):
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
