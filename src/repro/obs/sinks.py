"""Pluggable event sinks: where the observability bus delivers events.

A sink is anything with ``emit(event)`` (and optionally ``close()``).
Shipped sinks:

  * ``NullSink``       -- drops everything; the process default.  The bus
                          treats a scope whose sinks are all NullSinks as
                          *disabled*, so instrumentation sites skip event
                          construction entirely (zero-cost default).
  * ``RingBufferSink`` -- last-N events in memory, with per-kind counts;
                          what tests and in-process health probes read.
  * ``JsonlSink``      -- one JSON record per line (``Event.to_record``),
                          the stream ``python -m repro.obs.report``
                          aggregates.
  * ``LoggingSink``    -- renders each event onto a stdlib logger.

Sinks must never raise into the instrumented hot path: the bus catches
and logs a failing sink (``repro.obs.bus``), but a sink that can fail
routinely (disk full) should handle its own errors too.
"""
from __future__ import annotations

import collections
import json
import logging
import threading
from typing import IO

__all__ = ["Sink", "NullSink", "RingBufferSink", "JsonlSink", "LoggingSink"]


class Sink:
    """Base sink: subclass and override :meth:`emit`."""

    def emit(self, event) -> None:
        raise NotImplementedError

    def close(self) -> None:
        """Release resources; further emits are undefined."""

    def __enter__(self) -> "Sink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class NullSink(Sink):
    """Drops every event.  Scopes whose sinks are all NullSinks count as
    disabled (``bus.enabled()`` is False), so producers never even build
    the event -- the zero-cost default the launch path relies on."""

    def emit(self, event) -> None:
        pass


class RingBufferSink(Sink):
    """Keeps the last ``capacity`` events in memory.

    Thread-safe; ``events()`` snapshots the buffer and ``counts()``
    returns ``{kind: n}`` over everything ever emitted (not just what is
    still buffered), so hit-rate style assertions survive wraparound.
    """

    def __init__(self, capacity: int = 4096):
        self._buf: collections.deque = collections.deque(maxlen=int(capacity))
        self._counts: collections.Counter = collections.Counter()
        self._lock = threading.Lock()

    def emit(self, event) -> None:
        with self._lock:
            self._buf.append(event)
            self._counts[event.kind] += 1

    def events(self, kind: str | None = None) -> list:
        with self._lock:
            evs = list(self._buf)
        return evs if kind is None else [e for e in evs if e.kind == kind]

    def counts(self) -> dict[str, int]:
        with self._lock:
            return dict(self._counts)

    def __len__(self) -> int:
        return len(self._buf)


class JsonlSink(Sink):
    """Appends one JSON record per event to ``path`` (or a file object).

    The format is one ``Event.to_record()`` dict per line -- exactly what
    ``python -m repro.obs.report`` consumes.  The file opens lazily on
    the first emit (constructing the sink never touches the filesystem)
    and flushes per record so a crashed run still leaves a usable stream.
    """

    def __init__(self, path_or_file, *, append: bool = False):
        if hasattr(path_or_file, "write"):
            self._file: IO | None = path_or_file
            self._owns = False
            self._path = None
        else:
            self._file = None
            self._owns = True
            self._path = str(path_or_file)
        self._append = append
        self._lock = threading.Lock()
        self.emitted = 0

    def _open(self) -> IO:
        if self._file is None:
            self._file = open(self._path, "a" if self._append else "w")
        return self._file

    def emit(self, event) -> None:
        line = json.dumps(event.to_record())
        with self._lock:
            f = self._open()
            f.write(line + "\n")
            f.flush()
            self.emitted += 1

    def close(self) -> None:
        with self._lock:
            if self._file is not None and self._owns:
                self._file.close()
                self._file = None


class LoggingSink(Sink):
    """Renders each event onto a stdlib logger (default
    ``repro.obs.events`` at INFO)."""

    def __init__(self, logger: logging.Logger | str | None = None,
                 level: int = logging.INFO):
        if logger is None:
            logger = logging.getLogger("repro.obs.events")
        elif isinstance(logger, str):
            logger = logging.getLogger(logger)
        self._log = logger
        self._level = level

    def emit(self, event) -> None:
        rec = event.to_record()
        kind = rec.pop("kind")
        rec.pop("ts", None)
        self._log.log(self._level, "%s %s", kind,
                      " ".join(f"{k}={v}" for k, v in rec.items()))
