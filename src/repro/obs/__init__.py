"""Observability bus: streaming planner provenance, SPMD comm health, and
trainer/serving metrics.

    from repro import obs

    with obs.session(obs.JsonlSink("run.jsonl")):
        trainer.train(...)      # plan-cache, fallback, step events stream

    python -m repro.obs.report run.jsonl

The repo's measured-vs-predicted discipline runs *offline* in
``repro.measure`` and ``repro.analyze``; this package is the same
discipline online: typed events (``obs.events``) emitted at the natural
seams of the launch path, the trainer, the batcher, and the validator,
delivered to pluggable sinks (``obs.sinks``) through an ambient nestable
session (``obs.bus``) that mirrors ``api.plan_context``.  The default
sink is a ``NullSink`` and producers gate on ``obs.enabled()``, so an
uninstrumented process pays nothing.  See docs/OBS.md.
"""
from repro.obs.bus import (
    current_sinks,
    emit,
    enabled,
    reset_default_sinks,
    session,
    set_default_sinks,
)
from repro.obs.events import (
    EVENT_KINDS,
    AdmissionEvent,
    BatcherTickEvent,
    CheckpointEvent,
    DegradedEvent,
    Event,
    MeshChangeEvent,
    PagePoolEvent,
    PlanEvent,
    PreemptionEvent,
    ProfileDriftEvent,
    RequestAbandonedEvent,
    ResumeEvent,
    SpmdFallbackEvent,
    SpmdOverrideShadowEvent,
    TrainStepEvent,
    ValidationEvent,
)
from repro.obs.sinks import (
    JsonlSink,
    LoggingSink,
    NullSink,
    RingBufferSink,
    Sink,
)

__all__ = [
    "session", "emit", "enabled", "current_sinks",
    "set_default_sinks", "reset_default_sinks",
    "Sink", "NullSink", "RingBufferSink", "JsonlSink", "LoggingSink",
    "Event", "PlanEvent", "SpmdFallbackEvent", "SpmdOverrideShadowEvent",
    "ValidationEvent", "TrainStepEvent", "CheckpointEvent",
    "AdmissionEvent", "BatcherTickEvent", "PagePoolEvent",
    "PreemptionEvent", "RequestAbandonedEvent", "ProfileDriftEvent",
    "MeshChangeEvent", "ResumeEvent", "DegradedEvent",
    "EVENT_KINDS",
]
