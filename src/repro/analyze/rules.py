"""The rule catalog (see docs/ANALYZE.md for the prose version).

Five families, all computed from planner/registry declarations alone:

* ALIAS -- aliasing hazards from padded strides vs. the interleave period
  (the paper's thrashing condition, paper SS2.2/Fig. 2).
* PAD   -- padding regressions against per-family waste budgets and the
  narrow-dtype guarantee (PR-3 invariant).
* DRIFT -- SPMD declaration vs. what the ``spmd_body`` actually consults,
  and collectives with no ``COMM_MODEL`` price.
* CACHE -- plan-override profile hygiene (orphan / stale cells).
* REG   -- registry hygiene (ref, partitioning, golden coverage, cells).
"""
from __future__ import annotations

from typing import Iterable

from repro.analyze.engine import AnalysisContext, Finding, cell_label, rule
from repro.core.planner import COMM_MODEL, KernelPlan, stream_stride_facts

# A leading-dim stride this large that is also a power of two walks one
# controller per row *and* one cache/bank set -- the classic 2^k critical
# stride.  Smaller powers of two (one or two interleave periods) are the
# unavoidable cost of lane alignment and are not worth flagging.
POW2_STRIDE_MIN_BYTES = 4096

# Per-family padding budget: fraction of the physical footprint the plan
# may spend on padding at its representative cells.  Streams reshaped from
# awkward 1-D lengths legitimately pay up to ~25%; 2-D kernels plan much
# tighter.  Keyed by family prefix ("stream", "lbm", ...).
WASTE_BUDGET_FRAC = {"lbm": 0.10, "rmsnorm": 0.15, "xent": 0.15}
WASTE_BUDGET_DEFAULT = 0.30


def _family(kernel: str) -> str:
    return kernel.split(".")[0]


# ---------------------------------------------------------------------------
# ALIAS -- aliasing hazards
# ---------------------------------------------------------------------------

@rule("ALIAS001", "aliasing")
def alias_pow2_stride(ctx: AnalysisContext) -> Iterable[Finding]:
    """Planned leading-dim stride is a large power of two: every row of a
    stream lands on the same controller, so channel coverage rests entirely
    on the planned skews staying applied at launch."""
    for entry, shape, dtype, knobs, plan, _ in ctx.planned_cells():
        if plan is None:
            continue
        facts = stream_stride_facts(plan, ctx.model)
        stride = facts["leading_stride_bytes"]
        if facts["stride_pow2"] and stride >= POW2_STRIDE_MIN_BYTES:
            yield Finding(
                rule="ALIAS001", severity="warning", subject=entry.name,
                cell=cell_label(shape, dtype, knobs),
                message=(
                    f"leading-dim stride {stride} B is a power of two >= "
                    f"{POW2_STRIDE_MIN_BYTES} B ({stride // facts['period_bytes']}"
                    f"x the {facts['period_bytes']} B interleave period): a "
                    f"row walk revisits one controller per stream; balance "
                    f"relies on the planned skews "
                    f"(predicted {facts['predicted_balance']:.2f} vs naive "
                    f"{facts['naive_balance']:.2f})"
                ),
                hint=(
                    "keep the LayoutPlan skews on the launch path, or pad "
                    "the minor dim by one extra lane tile to break the "
                    "power-of-two stride"
                ),
            )


@rule("ALIAS002", "aliasing")
def alias_stream_collision(ctx: AnalysisContext) -> Iterable[Finding]:
    """A kernel's hot streams share a critical modulus *and* their planned
    base offsets collide on the same controller -- the paper's thrashing
    condition (all streams hammer one memory controller every tick)."""
    for entry, shape, dtype, knobs, plan, _ in ctx.planned_cells():
        if plan is None:
            continue
        yield from check_stream_collision(
            plan, ctx.model, cell=cell_label(shape, dtype, knobs))


def check_stream_collision(plan: KernelPlan, model,
                           cell: str = "") -> Iterable[Finding]:
    """ALIAS002 on one plan (exposed for tests and ad-hoc plan audits)."""
    facts = stream_stride_facts(plan, model)
    n = facts["n_streams"]
    if n <= 1:
        return
    coverable = min(n, model.n_channels)
    distinct = facts["distinct_start_channels"]
    if (facts["stride_gcd_period"] == facts["period_bytes"]
            and distinct < coverable):
        yield Finding(
            rule="ALIAS002", severity="error", subject=plan.kernel,
            cell=cell or cell_label(plan.logical_shape, plan.dtype),
            message=(
                f"{n} streams with period-aliased stride "
                f"(gcd(stride, period) = {facts['period_bytes']} B) start on "
                f"only {distinct} of {coverable} coverable controllers "
                f"(offsets {facts['offsets_bytes']} B): concurrent streams "
                f"thrash the same controller "
                f"(predicted balance {facts['predicted_balance']:.2f})"
            ),
            hint=(
                "skew stream bases by one channel step each "
                "(core.autotune.plan_streams) instead of page-aligning "
                "them all"
            ),
        )


# ---------------------------------------------------------------------------
# PAD -- padding regressions
# ---------------------------------------------------------------------------

@rule("PAD001", "padding")
def pad_over_budget(ctx: AnalysisContext) -> Iterable[Finding]:
    """A cell's padding exceeds its family's waste budget."""
    for entry, shape, dtype, knobs, plan, _ in ctx.planned_cells():
        if plan is None:
            continue
        budget = WASTE_BUDGET_FRAC.get(_family(entry.name),
                                       WASTE_BUDGET_DEFAULT)
        if plan.waste > budget:
            yield Finding(
                rule="PAD001", severity="warning", subject=entry.name,
                cell=cell_label(shape, dtype, knobs),
                message=(
                    f"padding is {plan.waste:.1%} of the physical footprint "
                    f"({plan.waste_bytes} B), over the "
                    f"{_family(entry.name)!r} family budget of {budget:.0%} "
                    f"(logical {plan.logical_shape} -> "
                    f"physical {plan.padded_shape})"
                ),
                hint=(
                    "pick a representative shape nearer a tile multiple, or "
                    "raise the family budget in analyze.rules with a "
                    "comment justifying the waste"
                ),
            )


@rule("PAD002", "padding")
def pad_narrow_dtype_regression(ctx: AnalysisContext) -> Iterable[Finding]:
    """A narrow dtype pays more padding bytes than fp32 would -- the PR-3
    invariant the planner enforces for native sublane tiles, re-checked
    here so explicit sublane overrides cannot smuggle the regression in."""
    import numpy as np

    for entry, shape, dtype, knobs, plan, _ in ctx.planned_cells():
        if plan is None:
            continue
        itemsize = np.dtype(dtype).itemsize
        if itemsize >= 4:
            # fp32 cell: probe the native bf16 plan of the same logical
            # shape so every kernel gets narrow-dtype coverage even when
            # its declared cells are all fp32.
            try:
                narrow = ctx.plan(entry.name, shape, "bfloat16")
                wide = plan
            except Exception:  # noqa: BLE001 -- REG004 reports plan failures
                continue
            probe_label = cell_label(shape, "bfloat16")
        else:
            narrow = plan
            try:
                wide_knobs = ({"vmem_budget": knobs["vmem_budget"]}
                              if knobs and "vmem_budget" in knobs else None)
                wide = ctx.plan(entry.name, shape, "float32", wide_knobs)
            except Exception:  # noqa: BLE001
                continue
            probe_label = cell_label(shape, dtype, knobs)
        n_item = np.dtype(narrow.dtype).itemsize
        if narrow.waste_bytes * 4 > wide.waste_bytes * n_item:
            yield Finding(
                rule="PAD002", severity="error", subject=entry.name,
                cell=probe_label,
                message=(
                    f"{narrow.dtype} plan pays {narrow.waste_bytes} B of "
                    f"padding where fp32 pays {wide.waste_bytes} B -- more "
                    f"than the {n_item}/4 byte ratio the narrow-dtype "
                    f"guarantee allows (sublanes {narrow.sublanes} vs "
                    f"{wide.sublanes})"
                ),
                hint=(
                    "drop the explicit sublane override (the planner falls "
                    "back to fp32 geometry when the native tile pads "
                    "worse), or shrink the row tile"
                ),
            )


# ---------------------------------------------------------------------------
# DRIFT -- declaration drift
# ---------------------------------------------------------------------------

def _declared_sharded_dims(part) -> set[tuple[int, int]]:
    """(operand, dim) pairs the Partitioning declares sharded.  Dims after
    an Ellipsis have no static index, so only the head of such templates
    is considered."""
    out: set[tuple[int, int]] = set()
    for i, template in enumerate(part.in_axes):
        for d, ax in enumerate(template):
            if ax is Ellipsis:
                break
            if isinstance(ax, str):
                out.add((i, d))
    return out


@rule("DRIFT001", "drift")
def drift_consulted_axes(ctx: AnalysisContext) -> Iterable[Finding]:
    """``Partitioning`` axes vs. the axes the ``spmd_body`` consults via
    ``ShardContext.axes``: a declared-sharded dim the body never consults
    means the body cannot be handling that split; a consulted dim never
    declared sharded is dead placement logic."""
    from repro.api.spmd import consulted_operand_dims

    for entry in ctx.entries:
        if entry.spmd_body is None or entry.partitioning is None:
            continue
        consulted = consulted_operand_dims(entry.spmd_body)
        if consulted is None:
            yield Finding(
                rule="DRIFT001", severity="info", subject=entry.name,
                message=(
                    "spmd_body's ShardContext.axes usage is not statically "
                    "introspectable (no source or non-literal arguments); "
                    "declaration drift cannot be checked"
                ),
                cell="",
                hint="call ctx.axes with literal (operand, dim) arguments",
            )
            continue
        declared = _declared_sharded_dims(entry.partitioning)
        for op, dim in sorted(declared - consulted):
            ax = entry.partitioning.in_axes[op][dim]
            yield Finding(
                rule="DRIFT001", severity="warning", subject=entry.name,
                cell=f"operand {op} dim {dim}",
                message=(
                    f"partitioning declares operand {op} dim {dim} sharded "
                    f"over {ax!r} but the spmd_body never consults "
                    f"ctx.axes({op}, {dim}) -- the body cannot be combining "
                    f"across that split"
                ),
                hint=(
                    "consult the axes in the body (and handle the split), "
                    "or declare the dim None/replicated"
                ),
            )
        for op, dim in sorted(consulted - declared):
            in_range = op < len(entry.partitioning.in_axes)
            yield Finding(
                rule="DRIFT001", severity="error", subject=entry.name,
                cell=f"operand {op} dim {dim}",
                message=(
                    f"spmd_body consults ctx.axes({op}, {dim}) but the "
                    f"partitioning "
                    + (f"declares that dim replicated"
                       if in_range else
                       f"has no operand {op} at all")
                    + " -- the consulted axes are always empty"
                ),
                hint=(
                    "declare the logical axis in Partitioning.in_axes, or "
                    "delete the dead consultation"
                ),
            )


@rule("DRIFT002", "drift")
def drift_unpriced_collectives(ctx: AnalysisContext) -> Iterable[Finding]:
    """A kernel-owned ``spmd_body`` communicates by construction, so a
    kernel with one but no ``COMM_MODEL`` price means
    ``predicted_comm_bytes`` silently reports zero and ``validate --comm``
    has nothing to check."""
    for entry in ctx.entries:
        if entry.spmd_body is not None and entry.name not in COMM_MODEL:
            yield Finding(
                rule="DRIFT002", severity="warning", subject=entry.name,
                cell="",
                message=(
                    "kernel owns an spmd_body (cross-shard communication) "
                    "but has no COMM_MODEL entry: predicted_comm_bytes is 0 "
                    "and the collective census has no prediction to check"
                ),
                hint=(
                    "add a ring-cost formula to core.planner.COMM_MODEL "
                    "(see _comm_jacobi/_comm_xent)"
                ),
            )
    # The reverse direction checks the *full* registry, not the analysis
    # subset: pricing jacobi is not "dead" just because this run only
    # looked at xent.
    from repro.api import registry

    all_registered = set(registry.list_kernels())
    for kernel in sorted(COMM_MODEL):
        if kernel not in all_registered:
            yield Finding(
                rule="DRIFT002", severity="warning", subject=kernel,
                cell="",
                message=(
                    f"COMM_MODEL prices kernel {kernel!r} but no such "
                    f"kernel is registered -- the price is dead and drifts "
                    f"unchecked"
                ),
                hint="remove the stale COMM_MODEL entry",
            )


# ---------------------------------------------------------------------------
# CACHE -- plan-cache / override hygiene
# ---------------------------------------------------------------------------

@rule("CACHE001", "cache")
def cache_orphan_overrides(ctx: AnalysisContext) -> Iterable[Finding]:
    """Profile override cells that no registered kernel can ever consume."""
    from repro.measure.profile import audit_profile

    for path in ctx.profile_paths:
        for issue in audit_profile(path):
            if issue["kind"] != "orphan":
                continue
            yield Finding(
                rule="CACHE001", severity="warning",
                subject=f"profile:{path}", cell=issue["cell"],
                message=issue["detail"],
                hint=(
                    "delete the cell from the profile, or restore the "
                    "kernel registration it was swept for"
                ),
            )


@rule("CACHE002", "cache")
def cache_stale_overrides(ctx: AnalysisContext) -> Iterable[Finding]:
    """Profile cells whose recorded geometry the planner no longer
    reproduces under the recorded knobs -- a strict ``load_profile`` of the
    file will fail at use time; surface it at lint time instead."""
    from repro.measure.profile import audit_profile

    for path in ctx.profile_paths:
        for issue in audit_profile(path):
            if issue["kind"] not in ("stale", "invalid"):
                continue
            yield Finding(
                rule="CACHE002", severity="error",
                subject=f"profile:{path}", cell=issue["cell"],
                message=f"{issue['kind']} override: {issue['detail']}",
                hint=(
                    "re-run the sweep to regenerate the profile "
                    "(python -m repro.measure.sweep), or delete the cell"
                ),
            )


# ---------------------------------------------------------------------------
# REG -- registry hygiene
# ---------------------------------------------------------------------------

@rule("REG001", "registry")
def reg_missing_partitioning(ctx: AnalysisContext) -> Iterable[Finding]:
    """Kernels registered without any SPMD placement rule run fully
    replicated under a mesh -- legal, but worth knowing."""
    for entry in ctx.entries:
        if entry.partitioning is None:
            yield Finding(
                rule="REG001", severity="info", subject=entry.name, cell="",
                message=(
                    "no Partitioning declared: every device computes the "
                    "full array under an SPMD mesh"
                ),
                hint=(
                    "declare partitioning=replicated(n) to make the choice "
                    "explicit, or a real axis template to shard"
                ),
            )


@rule("REG002", "registry")
def reg_missing_ref(ctx: AnalysisContext) -> Iterable[Finding]:
    """Kernels without a reference oracle cannot be parity-tested."""
    for entry in ctx.entries:
        if not callable(entry.ref):
            yield Finding(
                rule="REG002", severity="error", subject=entry.name, cell="",
                message=(
                    "registered without a callable ref oracle: parity tests "
                    "and the jnp fallback path are impossible"
                ),
                hint="register a pure-jnp reference with the same signature",
            )


@rule("REG003", "registry")
def reg_missing_golden(ctx: AnalysisContext) -> Iterable[Finding]:
    """Kernels with no golden-snapshot coverage: planner drift on their
    cells goes unnoticed until a measured run."""
    covered = ctx.golden_kernels()
    if covered is None:
        return
    for entry in ctx.entries:
        if entry.name not in covered:
            yield Finding(
                rule="REG003", severity="warning", subject=entry.name,
                cell="",
                message=(
                    "no cell in tests/golden/plans.json snapshots this "
                    "kernel's plans"
                ),
                hint=(
                    "add shapes to tests/test_golden_plans.py SHAPES and "
                    "bless with --update-golden"
                ),
            )


@rule("REG004", "registry")
def reg_analysis_cells(ctx: AnalysisContext) -> Iterable[Finding]:
    """Analysis-cell coverage: every kernel needs at least one plannable
    representative cell for the other rules to judge."""
    seen: set[str] = set()
    for entry, shape, dtype, knobs, plan, err in ctx.planned_cells():
        seen.add(entry.name)
        if err is not None:
            yield Finding(
                rule="REG004", severity="error", subject=entry.name,
                cell=cell_label(shape, dtype, knobs),
                message=f"analysis cell cannot be planned: {err}",
                hint=(
                    "fix the declared analysis_cells shape/dtype, or the "
                    "planner rule it trips"
                ),
            )
    for entry in ctx.entries:
        if entry.name not in seen:
            yield Finding(
                rule="REG004", severity="info", subject=entry.name, cell="",
                message=(
                    "no analysis cells: not in measure.validate CASES and "
                    "no analysis_cells declared, so per-cell rules "
                    "(ALIAS/PAD) cannot judge this kernel"
                ),
                hint=(
                    "declare analysis_cells=[(shape, dtype)] at "
                    "registration, or add a validation case"
                ),
            )
