"""CLI: ``python -m repro.analyze --all``.

Exit codes: 0 = no new gating findings, 1 = new findings (or --fixture
proving the gate fires), 2 = usage error.
"""
from __future__ import annotations

import argparse
import sys

from repro.analyze import engine, report


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analyze",
        description=(
            "Static layout-hazard and declaration-consistency analysis of "
            "the kernel registry (see docs/ANALYZE.md)."
        ),
    )
    p.add_argument("--all", action="store_true",
                   help="analyze every registered kernel")
    p.add_argument("--kernel", action="append", default=[],
                   help="restrict to one kernel (repeatable)")
    p.add_argument("--profile", action="append", default=[],
                   help="also audit a plan-override profile (repeatable)")
    p.add_argument("--rule", action="append", default=[],
                   help="run only this rule id (repeatable)")
    p.add_argument("--baseline", default=report.DEFAULT_BASELINE,
                   help="baseline file (default: the committed one)")
    p.add_argument("--no-baseline", action="store_true",
                   help="gate on every finding, ignoring the baseline")
    p.add_argument("--update-baseline", action="store_true",
                   help="bless the current gating findings into --baseline")
    p.add_argument("--fixture", action="store_true",
                   help="register the seeded-hazard fixtures first "
                        "(CI self-test: the run must then fail)")
    p.add_argument("--format", choices=("text", "json"), default="text")
    p.add_argument("--out", default=None,
                   help="also write the JSON report to this path")
    return p


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    if not args.all and not args.kernel and not args.profile:
        build_parser().print_usage(sys.stderr)
        print("error: nothing to analyze (pass --all, --kernel, or "
              "--profile)", file=sys.stderr)
        return 2

    if args.fixture:
        from repro.analyze import fixtures  # noqa: F401 -- registers hazards

    from repro.api import registry

    entries = registry.entries()
    if args.kernel:
        known = {e.name for e in entries}
        missing = [k for k in args.kernel if k not in known]
        if missing:
            print(f"error: unknown kernel(s) {missing}; known: "
                  f"{sorted(known)}", file=sys.stderr)
            return 2
        entries = [e for e in entries if e.name in args.kernel]

    ctx = engine.AnalysisContext(entries, profile_paths=args.profile)
    findings = engine.run(ctx, only=args.rule or None)

    if args.update_baseline:
        n = report.save_baseline(args.baseline, findings)
        print(f"blessed {n} finding(s) into {args.baseline}")
        return 0

    baseline = set() if args.no_baseline else report.load_baseline(args.baseline)
    if args.format == "json":
        print(report.render_json(findings, baseline))
    else:
        print(report.render_text(findings, baseline))
    if args.out:
        with open(args.out, "w") as f:
            f.write(report.render_json(findings, baseline))
            f.write("\n")
    new, _ = report.split_new(findings, baseline)
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
