"""Seeded-hazard fixtures: deliberately bad registrations for the analyzer.

Importing this module registers two analysis-only kernels under the
``hazard.`` prefix.  They have no executable body -- they exist so every
rule family has a known-positive target: CI runs the analyzer once with
the fixtures registered and asserts it exits non-zero, proving the gate
can actually fail.  ``tests/test_golden_plans.py`` excludes the prefix
from the shipped-kernel snapshot; nothing else ever resolves these names.
"""
from __future__ import annotations

from repro.api.registry import register_kernel
from repro.api.spmd import Partitioning
from repro.core.autotune import StreamSignature

FIXTURE_PREFIX = "hazard"

FIXTURE_KERNELS = ("hazard.pow2", "hazard.drift")


def _plan_args(a, **scalars):
    return tuple(a.shape), str(a.dtype)


def _no_body(plan, *arrays, **scalars):
    raise NotImplementedError("hazard fixtures are analysis-only")


# Aliasing + padding hazards, all from the declared analysis cells:
#   (8, 8192)  fp32 -> 32 KiB power-of-two row stride        (ALIAS001)
#   (16,)      fp32 -> one tile of data, 98% padding         (PAD001)
#   (8, 1111)  bf16 with a forced 32-sublane tile (an explicit
#              override, so the planner's narrow-dtype guarantee
#              does not rewrite it) -> pays more padding bytes
#              than the fp32 plan                            (PAD002)
# plus ref=None (REG002), no partitioning (REG001), and no golden
# coverage (REG003).
register_kernel(
    "hazard.pow2",
    signature=StreamSignature(n_read=2, n_write=1),
    ref=None,
    plan_args=_plan_args,
    analysis_cells=(
        ((8, 8192), "float32"),
        ((16,), "float32"),
        ((8, 1111), "bfloat16", {"sublanes": 32}),
    ),
    doc="seeded aliasing/padding hazard (analysis fixture)",
)(_no_body)


def _spmd_drift(ctx, x):
    # Consults operand 0 dim 0 (declared) and a phantom operand 1 (never
    # declared), while ignoring the declared vocab split of dim 1.
    rows = ctx.axes(0, 0)
    phantom = ctx.axes(1, 0)
    return x if (rows or phantom) else x


register_kernel(
    "hazard.drift",
    signature=StreamSignature(n_read=1, n_write=1),
    ref=lambda x: x,
    plan_args=_plan_args,
    partitioning=Partitioning(in_axes=(("batch", "vocab"),)),
    spmd_body=_spmd_drift,
    analysis_cells=(((64, 256), "float32"),),
    doc="seeded declaration-drift hazard (analysis fixture)",
)(_no_body)


def register_fixtures() -> tuple[str, ...]:
    """Idempotent: importing this module registered the fixtures; calling
    this just names them for callers that want the list."""
    return FIXTURE_KERNELS
