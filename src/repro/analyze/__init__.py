"""Static analysis of the planner/registry/SPMD stack.

    python -m repro.analyze --all

walks the kernel registry, plans each kernel's representative cells in
closed form (nothing is executed or lowered), and checks five rule
families -- aliasing hazards, padding regressions, SPMD declaration
drift, plan-override hygiene, registry hygiene -- against a committed
baseline (``src/repro/analyze/baseline.json``).  CI fails only on *new*
findings; deliberate ones are blessed with ``--update-baseline``.
See docs/ANALYZE.md for the rule catalog.
"""
from repro.analyze.engine import (
    AnalysisContext,
    Finding,
    GATING,
    RULES,
    SEVERITIES,
    run,
)
from repro.analyze.report import (
    DEFAULT_BASELINE,
    load_baseline,
    render_text,
    save_baseline,
    split_new,
)

__all__ = [
    "AnalysisContext", "Finding", "RULES", "SEVERITIES", "GATING", "run",
    "DEFAULT_BASELINE", "load_baseline", "save_baseline", "split_new",
    "render_text",
]
