"""Analysis engine: findings, the rule registry, and the registry walk.

The engine is deliberately dumb: it plans every kernel's representative
cells once (closed-form arithmetic -- nothing is traced, lowered, or
executed), hands the resulting ``AnalysisContext`` to each registered rule,
and collects ``Finding``s.  All layout judgment lives in ``rules``; all
baseline/report plumbing lives in ``report``.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, Iterable

from repro.core.aliasing import InterleavedMemoryModel
from repro.core.planner import KernelPlan, plan_kernel

SEVERITIES = ("error", "warning", "info")

# Severities that gate CI: a *new* (non-baselined) finding at one of these
# levels makes the CLI exit non-zero.  ``info`` findings are advisory and
# never gate or enter the baseline.
GATING = ("error", "warning")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One hazard the static analysis surfaced.

    ``fingerprint`` identifies the finding across runs for the baseline
    diff: rule + subject + cell, but *not* the message, so rewording a
    rule's output never un-blesses a baselined hazard.
    """

    rule: str       # "ALIAS001", ...
    severity: str   # "error" | "warning" | "info"
    subject: str    # kernel name, or "profile:<path>"
    cell: str       # "(300, 1111) float32" (empty for kernel-level findings)
    message: str
    hint: str = ""

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(
                f"severity must be one of {SEVERITIES}, got {self.severity!r}"
            )

    @property
    def fingerprint(self) -> str:
        return f"{self.rule}|{self.subject}|{self.cell}"

    @property
    def gating(self) -> bool:
        return self.severity in GATING

    def to_dict(self) -> dict:
        return {
            "rule": self.rule,
            "severity": self.severity,
            "subject": self.subject,
            "cell": self.cell,
            "message": self.message,
            "hint": self.hint,
            "fingerprint": self.fingerprint,
        }


@dataclasses.dataclass(frozen=True)
class Rule:
    """One registered rule: id, family, and the check itself."""

    id: str
    family: str     # "aliasing" | "padding" | "drift" | "cache" | "registry"
    doc: str
    fn: Callable    # (AnalysisContext) -> Iterable[Finding]


RULES: dict[str, Rule] = {}


def rule(rule_id: str, family: str, doc: str = ""):
    """Decorator: register a rule under ``rule_id``.

    Rules are pure functions of the :class:`AnalysisContext`; they yield
    :class:`Finding`s and must not execute or lower anything.
    """

    def deco(fn: Callable) -> Callable:
        if rule_id in RULES and RULES[rule_id].fn is not fn:
            raise ValueError(f"rule {rule_id!r} already registered")
        RULES[rule_id] = Rule(id=rule_id, family=family,
                              doc=doc or (fn.__doc__ or "").strip(), fn=fn)
        return fn

    return deco


def _default_golden_path() -> str | None:
    p = os.path.join("tests", "golden", "plans.json")
    return p if os.path.exists(p) else None


class AnalysisContext:
    """Everything the rules look at: entries, planned cells, profiles.

    ``entries`` defaults to the full registry (fixtures excluded unless
    their module was imported and registered them).  Cells come from each
    entry's ``analysis_cells`` declaration, falling back to the validation
    suite's representative cells -- the same cells the measured-vs-predicted
    envelope pins, so the analyzer and the validator judge the same plans.
    """

    def __init__(self, entries=None, *, model: InterleavedMemoryModel | None = None,
                 profile_paths: Iterable[str] = (),
                 golden_path: str | None = None):
        if entries is None:
            from repro.api import registry

            entries = registry.entries()
        self.entries = list(entries)
        self.model = model or InterleavedMemoryModel()
        self.profile_paths = tuple(profile_paths)
        self.golden_path = (golden_path if golden_path is not None
                            else _default_golden_path())
        self._planned: list[tuple] | None = None
        self._golden_kernels: frozenset[str] | None = None

    # ---- cells -----------------------------------------------------------
    def cells_for(self, entry) -> list[tuple[tuple[int, ...], str, dict | None]]:
        """Representative ``(shape, dtype, knobs)`` cells for one entry."""
        declared = getattr(entry, "analysis_cells", ()) or ()
        if declared:
            out = []
            for cell in declared:
                shape, dtype = cell[0], cell[1]
                knobs = dict(cell[2]) if len(cell) > 2 and cell[2] else None
                out.append((tuple(int(s) for s in shape), str(dtype), knobs))
            return out
        from repro.measure.validate import CASES

        case = CASES.get(entry.name)
        if case is None:
            return []
        shape, dtype = case
        return [(tuple(int(s) for s in shape), str(dtype), None)]

    def plan(self, kernel: str, shape, dtype,
             knobs: dict | None = None) -> KernelPlan:
        knobs = knobs or {}
        return plan_kernel(kernel, shape, dtype,
                           sublanes=knobs.get("sublanes"),
                           vmem_budget=knobs.get("vmem_budget"))

    def planned_cells(self):
        """``(entry, shape, dtype, knobs, plan | None, error | None)`` for
        every analysis cell, planned once and shared by all rules."""
        if self._planned is None:
            out = []
            for entry in self.entries:
                for shape, dtype, knobs in self.cells_for(entry):
                    try:
                        plan = self.plan(entry.name, shape, dtype, knobs)
                        err = None
                    except Exception as e:  # noqa: BLE001 -- becomes a finding
                        plan, err = None, f"{type(e).__name__}: {e}"
                    out.append((entry, shape, dtype, knobs, plan, err))
            self._planned = out
        return self._planned

    # ---- coverage --------------------------------------------------------
    def golden_kernels(self) -> frozenset[str] | None:
        """Kernel names with golden-snapshot coverage, or ``None`` when the
        golden file is unavailable (rule REG003 then stays silent)."""
        if self.golden_path is None:
            return None
        if self._golden_kernels is None:
            import json

            try:
                with open(self.golden_path) as f:
                    golden = json.load(f)
            except (OSError, ValueError):
                return None
            self._golden_kernels = frozenset(
                key.split("|", 1)[0] for key in golden
            )
        return self._golden_kernels


def cell_label(shape, dtype, knobs: dict | None = None) -> str:
    """Stable cell string for findings/fingerprints."""
    label = f"{tuple(shape)} {dtype}"
    if knobs:
        label += " " + ",".join(f"{k}={v}" for k, v in sorted(knobs.items()))
    return label


def run(ctx: AnalysisContext, only: Iterable[str] | None = None) -> list[Finding]:
    """Run every registered rule (or the ``only`` subset) over ``ctx``."""
    import repro.analyze.rules  # noqa: F401 -- registers the rules

    wanted = set(only) if only is not None else None
    findings: list[Finding] = []
    for rule_id in sorted(RULES):
        if wanted is not None and rule_id not in wanted:
            continue
        findings.extend(RULES[rule_id].fn(ctx))
    order = {s: i for i, s in enumerate(SEVERITIES)}
    findings.sort(key=lambda f: (order[f.severity], f.rule, f.subject, f.cell))
    return findings
