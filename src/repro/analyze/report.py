"""Baseline bookkeeping and report rendering for ``repro.analyze``.

The baseline mirrors the golden-plan workflow: known findings live in a
committed JSON file keyed by fingerprint, CI gates only on findings *not*
in it, and deliberate changes are blessed with ``--update-baseline``
(the exact ``--update-golden`` bless shape).  Only gating severities
(error/warning) enter the baseline -- info findings are advisory.
"""
from __future__ import annotations

import json
import os

from repro.analyze.engine import Finding

BASELINE_FORMAT = "repro.analyze_baseline"
BASELINE_VERSION = 1

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baseline.json")


def load_baseline(path: str) -> set[str]:
    """Fingerprints blessed at ``path`` (empty set when the file is absent:
    a repo without a baseline gates on every finding)."""
    if not os.path.exists(path):
        return set()
    with open(path) as f:
        doc = json.load(f)
    if doc.get("format") != BASELINE_FORMAT:
        raise ValueError(
            f"{path}: not an analyze baseline (format={doc.get('format')!r})"
        )
    if int(doc.get("version", 0)) > BASELINE_VERSION:
        raise ValueError(
            f"{path}: baseline version {doc.get('version')} is newer than "
            f"supported {BASELINE_VERSION}"
        )
    return set(doc.get("fingerprints", ()))


def save_baseline(path: str, findings: list[Finding]) -> int:
    """Bless the gating findings into ``path``; returns the count."""
    fps = sorted({f.fingerprint for f in findings if f.gating})
    doc = {
        "format": BASELINE_FORMAT,
        "version": BASELINE_VERSION,
        "fingerprints": fps,
    }
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    return len(fps)


def split_new(findings: list[Finding],
              baseline: set[str]) -> tuple[list[Finding], list[Finding]]:
    """``(new_gating, known_or_info)`` under ``baseline``."""
    new = [f for f in findings
           if f.gating and f.fingerprint not in baseline]
    rest = [f for f in findings
            if not f.gating or f.fingerprint in baseline]
    return new, rest


def render_text(findings: list[Finding], baseline: set[str]) -> str:
    """Human-readable report: new findings first, then baselined/info."""
    new, rest = split_new(findings, baseline)
    lines: list[str] = []

    def block(f: Finding, tag: str) -> None:
        where = f.subject + (f" [{f.cell}]" if f.cell else "")
        lines.append(f"{f.severity.upper():>7} {f.rule} {where}{tag}")
        lines.append(f"        {f.message}")
        if f.hint:
            lines.append(f"        fix: {f.hint}")

    if new:
        lines.append(f"-- {len(new)} new finding(s) (not in baseline) --")
        for f in new:
            block(f, "")
    for f in rest:
        tag = " (baselined)" if f.gating else ""
        block(f, tag)
    n_err = sum(1 for f in findings if f.severity == "error")
    n_warn = sum(1 for f in findings if f.severity == "warning")
    n_info = sum(1 for f in findings if f.severity == "info")
    lines.append(
        f"{len(findings)} finding(s): {n_err} error, {n_warn} warning, "
        f"{n_info} info; {len(new)} new vs baseline "
        f"({len(baseline)} blessed)"
    )
    return "\n".join(lines)


def render_json(findings: list[Finding], baseline: set[str]) -> str:
    new, _ = split_new(findings, baseline)
    new_fps = {f.fingerprint for f in new}
    doc = {
        "format": "repro.analyze_report",
        "version": 1,
        "findings": [
            {**f.to_dict(), "new": f.fingerprint in new_fps}
            for f in findings
        ],
        "new_count": len(new),
    }
    return json.dumps(doc, indent=1)
