"""Model configuration: one dataclass covers the whole assigned pool.

``ModelConfig`` carries the *logical* (paper-exact) dimensions.  The layout
engine (``padded_for_mesh``) derives the *physical* dimensions for a given
tensor-parallel degree -- the framework's port of the paper's analytic
padding.  Both variants are lowerable so EXPERIMENTS.md SSPerf can report
baseline (raw, GSPMD-handled uneven sharding) vs optimized (tile/mesh-padded)
side by side.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax.numpy as jnp

from repro.core.layout import LayoutPolicy

Family = Literal["dense", "moe", "hybrid", "ssm", "encdec", "vlm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None          # explicit (pixtral/nemo); else d_model//n_heads
    # attention options
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    attn_softcap: float | None = None    # grok
    logit_softcap: float | None = None   # grok
    kv_cache_layout: Literal["bhsd", "bshd"] = "bhsd"  # paper SS2.4 layout knob
    # mlp
    act: Literal["silu", "gelu"] = "silu"
    # scaling tricks (minicpm mup-like)
    tie_embeddings: bool = False
    embed_scale: float = 1.0
    residual_scale: float = 1.0
    logit_scale: float = 1.0
    # norm
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    norm_eps: float = 1e-6
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_groups: int = 1                  # GShard dispatch groups (= DP shards)
    skewed_experts: bool = True          # paper-derived rotation (core.sharding_skew)
    # SSM / Mamba2 (zamba2)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    shared_attn_period: int = 0          # zamba2: shared attn block every k mamba layers
    # xLSTM
    slstm_every: int = 0                 # one sLSTM per this many blocks (0 = none)
    # enc-dec (whisper)
    n_enc_layers: int = 0
    n_frames: int = 1500                 # stub audio frontend: precomputed frames
    # vlm (pixtral)
    n_img_tokens: int = 0                # stub vision frontend: precomputed patches
    # numerics
    dtype: str = "bfloat16"
    remat: bool = True
    unroll: bool = False                 # unroll layer stages (cost accounting)
    vocab_logical: int = 0               # logical vocab when vocab_size is padded
    # distribution hints (consumed by launch/parallel)
    fsdp: bool = False
    expert_tp: bool = False              # grok: TP inside few big experts
    parallelism: str = "tp"              # "tp" | "zero3" (train cells only)

    # ---- derived ---------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def adtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm" and self.shared_attn_period == 0

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k decode (SSM/hybrid families)."""
        return self.family in ("ssm", "hybrid")

    def stages(self) -> list[tuple[str, int]]:
        """Homogeneous layer runs, each scanned as one stacked stage."""
        if self.family in ("dense", "vlm"):
            return [("dense", self.n_layers)]
        if self.family == "moe":
            return [("moe", self.n_layers)]
        if self.family == "hybrid":
            out: list[tuple[str, int]] = []
            period = self.shared_attn_period or self.n_layers
            remaining = self.n_layers
            while remaining > 0:
                run = min(period, remaining)
                out.append(("mamba", run))
                remaining -= run
                if remaining > 0 or run == period:
                    out.append(("shared_attn", 1))
            return out
        if self.family == "ssm":
            if not self.slstm_every:
                return [("mlstm", self.n_layers)]
            out = []
            remaining = self.n_layers
            while remaining > 0:
                run = min(self.slstm_every - 1, remaining)
                if run:
                    out.append(("mlstm", run))
                    remaining -= run
                if remaining > 0:
                    out.append(("slstm", 1))
                    remaining -= 1
            return out
        if self.family == "encdec":
            return [("dense", self.n_layers)]  # decoder; encoder handled separately
        raise ValueError(self.family)

    # ---- layout engine ----------------------------------------------------
    def padded_for_mesh(self, tp: int) -> tuple["ModelConfig", dict[str, tuple[int, int]]]:
        """Physical config for a tp-way model axis (the paper's technique).

        Returns (new_config, changes) where changes[name] = (logical, physical).
        """
        pol = LayoutPolicy(tp=tp)
        changes: dict[str, tuple[int, int]] = {}

        def upd(name: str, val: int, kind: str) -> int:
            if val == 0:
                return val
            d = pol.plan({name: (val, kind)})[name]
            if d.physical != d.logical:
                changes[name] = (d.logical, d.physical)
            return d.physical

        kw: dict = {}
        kw["d_ff"] = upd("d_ff", self.d_ff, "minor_sharded")
        kw["vocab_size"] = upd("vocab_size", self.vocab_size, "vocab")
        if kw["vocab_size"] != self.vocab_size:
            kw["vocab_logical"] = self.vocab_size
        # Attention heads.  SSM families keep their head structure (head
        # count is architectural state granularity, not a layout choice).
        if self.family != "ssm":
            heads = pol.pad_count(self.n_heads, sharded=True).physical
            if self.n_kv_heads == self.n_heads:       # MHA: pad jointly
                kv = heads
            elif self.n_kv_heads >= tp:               # GQA, shardable KV
                kv = pol.pad_count(self.n_kv_heads, sharded=True).physical
            else:                                      # GQA, replicated KV
                kv = self.n_kv_heads
            while heads % kv:                          # keep GQA ratio integral
                heads += tp
            if heads != self.n_heads:
                changes["n_heads"] = (self.n_heads, heads)
                kw["n_heads"] = heads
            if kv != self.n_kv_heads:
                changes["n_kv_heads"] = (self.n_kv_heads, kv)
                kw["n_kv_heads"] = kv
        if self.n_experts:
            if self.expert_tp:
                kw["moe_d_ff"] = upd("moe_d_ff", self.moe_d_ff, "minor_sharded")
            else:
                kw["n_experts"] = upd("n_experts", self.n_experts, "count_sharded")
                kw["moe_d_ff"] = upd("moe_d_ff", self.moe_d_ff, "minor")
        # keep per-head width stable: head_dim becomes explicit when heads pad
        if "n_heads" in changes and self.head_dim is None:
            kw["head_dim"] = self.d_model // self.n_heads
        return dataclasses.replace(self, **kw), changes
