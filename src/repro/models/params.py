"""Parameter definition trees: one source of truth for shapes, init, and
logical sharding axes.

Every model builds a nested dict of ``ParamDef``s.  From that single tree we
derive (a) materialized parameters, (b) abstract ShapeDtypeStructs for the
dry-run (no allocation -- mandatory for the 314 B-param configs), and
(c) PartitionSpecs via the logical-axis rules of ``repro.parallel.rules``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

Tree = dict  # nested dict[str, ParamDef | Tree]


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """Declarative parameter: shape + logical axes + init recipe."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]          # logical axis names, len == ndim
    init: str = "normal"                  # normal | zeros | ones | embed
    scale: float | None = None            # stddev override (normal/embed)
    dtype: Any = jnp.float32

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes}")

    @property
    def fan_in(self) -> int:
        return self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]

    def materialize(self, key: jax.Array) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        if self.init == "neg_inf":
            return jnp.full(self.shape, -1e30, self.dtype)
        std = self.scale
        if std is None:
            std = 0.02 if self.init == "embed" else 1.0 / math.sqrt(self.fan_in)
        return (std * jax.random.normal(key, self.shape, jnp.float32)).astype(self.dtype)

    def abstract(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)


def is_def(x: Any) -> bool:
    return isinstance(x, ParamDef)


def map_tree(fn: Callable[[ParamDef], Any], tree: Tree) -> Tree:
    """Map a function over every ParamDef in a nested dict."""
    return {
        k: fn(v) if is_def(v) else map_tree(fn, v)
        for k, v in tree.items()
    }


def init_params(key: jax.Array, tree: Tree) -> Tree:
    """Materialize every ParamDef with a key folded from its path hash."""

    def rec(t: Tree, path: tuple[str, ...]) -> Tree:
        out = {}
        for k, v in t.items():
            p = path + (k,)
            if is_def(v):
                sub = jax.random.fold_in(key, hash(p) & 0x7FFFFFFF)
                out[k] = v.materialize(sub)
            else:
                out[k] = rec(v, p)
        return out

    return rec(tree, ())


def abstract_params(tree: Tree) -> Tree:
    return map_tree(lambda d: d.abstract(), tree)


def param_count(tree: Tree) -> int:
    total = 0

    def rec(t: Tree):
        nonlocal total
        for v in t.values():
            if is_def(v):
                total += math.prod(v.shape)
            else:
                rec(v)

    rec(tree)
    return total


def logical_axes(tree: Tree) -> Tree:
    return map_tree(lambda d: d.axes, tree)


def stack_defs(tree: Tree, n: int, axis_name: str | None = "layers") -> Tree:
    """Prepend a stacked-layer dimension to every ParamDef (scan-over-layers)."""
    return map_tree(
        lambda d: dataclasses.replace(
            d, shape=(n, *d.shape), axes=(axis_name, *d.axes)
        ),
        tree,
    )
