"""Whisper-style encoder-decoder backbone (audio family, conv frontend
stubbed per assignment: ``input_specs()`` supplies precomputed frame
embeddings).

Encoder: bidirectional self-attention over frames.  Decoder: causal
self-attention + cross-attention.  Positions are sinusoidal (deviation from
Whisper's learned decoder positions, noted in DESIGN.md: the assigned decode
shapes exceed Whisper's native 448 positions, and a parameter-free encoding
keeps the position table out of the cache-length configs).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import blocks
from repro.models.config import ModelConfig
from repro.models.params import (
    ParamDef, Tree, abstract_params, init_params, logical_axes, stack_defs,
)
from repro.parallel.rules import shard


def sinusoid(positions: jax.Array, d: int, dtype) -> jax.Array:
    """positions: (B, S) -> (B, S, d)."""
    half = d // 2
    freqs = jnp.exp(-jnp.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def _enc_block_defs(cfg: ModelConfig) -> Tree:
    return {
        "ln1": blocks.norm_defs(cfg),
        "attn": blocks.attention_defs(cfg),
        "ln2": blocks.norm_defs(cfg),
        "mlp": blocks.mlp_defs(cfg),
    }


def _dec_block_defs(cfg: ModelConfig) -> Tree:
    return {
        "ln1": blocks.norm_defs(cfg),
        "attn": blocks.attention_defs(cfg),
        "lnx": blocks.norm_defs(cfg),
        "cross": blocks.attention_defs(cfg),
        "ln2": blocks.norm_defs(cfg),
        "mlp": blocks.mlp_defs(cfg),
    }


def param_defs(cfg: ModelConfig) -> Tree:
    tree = {
        "embed": ParamDef((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                          init="embed", dtype=cfg.adtype),
        "enc": stack_defs(_enc_block_defs(cfg), cfg.n_enc_layers),
        "enc_norm": blocks.norm_defs(cfg),
        "dec": stack_defs(_dec_block_defs(cfg), cfg.n_layers),
        "final_norm": blocks.norm_defs(cfg),
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = ParamDef((cfg.d_model, cfg.vocab_size),
                                   ("embed", "vocab"), dtype=cfg.adtype)
    return tree


def encode(params: Tree, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    """frames: (B, T, d) precomputed embeddings (stub frontend)."""
    b, t, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    x = frames.astype(cfg.adtype) + sinusoid(pos, cfg.d_model, cfg.adtype)
    x = shard(x, "batch", None, None)

    def body(h, lp):
        a = blocks.apply_norm(lp["ln1"], h, cfg)
        h = h + blocks.attention(lp["attn"], a, cfg, positions=pos,
                                 causal=False, use_rope=False)
        a = blocks.apply_norm(lp["ln2"], h, cfg)
        return h + blocks.apply_mlp(lp["mlp"], a, cfg), None

    if cfg.remat:
        body = jax.checkpoint(body)
    if cfg.unroll:
        for i in range(cfg.n_enc_layers):
            x, _ = body(x, jax.tree.map(lambda a: a[i], params["enc"]))
    else:
        x, _ = jax.lax.scan(body, x, params["enc"])
    return blocks.apply_norm(params["enc_norm"], x, cfg)


def decode_train(params: Tree, tokens: jax.Array, enc_out: jax.Array,
                 cfg: ModelConfig) -> jax.Array:
    b, s = tokens.shape
    t = enc_out.shape[1]
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    epos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    x = params["embed"][tokens] + sinusoid(pos, cfg.d_model, cfg.adtype)
    x = shard(x, "batch", None, None)

    def body(h, lp):
        a = blocks.apply_norm(lp["ln1"], h, cfg)
        h = h + blocks.attention(lp["attn"], a, cfg, positions=pos,
                                 causal=True, use_rope=False)
        a = blocks.apply_norm(lp["lnx"], h, cfg)
        h = h + blocks.attention(lp["cross"], a, cfg, positions=pos,
                                 x_kv=enc_out, kv_positions=epos,
                                 causal=False, use_rope=False)
        a = blocks.apply_norm(lp["ln2"], h, cfg)
        return h + blocks.apply_mlp(lp["mlp"], a, cfg), None

    if cfg.remat:
        body = jax.checkpoint(body)
    if cfg.unroll:
        for i in range(cfg.n_layers):
            x, _ = body(x, jax.tree.map(lambda a: a[i], params["dec"]))
    else:
        x, _ = jax.lax.scan(body, x, params["dec"])
    x = blocks.apply_norm(params["final_norm"], x, cfg)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head).astype(jnp.float32)
    return shard(logits, "batch", None, "vocab")


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def cache_defs(cfg: ModelConfig, batch: int, max_len: int) -> Tree:
    """Self-attention KV cache + precomputed cross K/V."""
    kh, hd, L = cfg.n_kv_heads, cfg.hd, cfg.n_layers
    t = cfg.n_frames
    dt = cfg.adtype
    tree = {
        "idx": ParamDef((batch,), ("batch",), init="zeros", dtype=jnp.int32),
        "self": blocks.init_kv_cache(cfg, batch, max_len, L),
        "cross_k": ParamDef((L, batch, t, kh, hd),
                            ("layers", "batch", "frames", "kv_heads", None),
                            init="zeros", dtype=dt),
        "cross_v": ParamDef((L, batch, t, kh, hd),
                            ("layers", "batch", "frames", "kv_heads", None),
                            init="zeros", dtype=dt),
    }
    return tree


def prefill_cross(params: Tree, frames: jax.Array, cfg: ModelConfig
                  ) -> tuple[jax.Array, jax.Array]:
    """Encoder pass + per-layer cross K/V: (L, B, T, KH, hd) each."""
    enc = encode(params, frames, cfg)

    def kv(lp):
        k = jnp.einsum("bsd,dhk->bshk", enc, lp["cross"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc, lp["cross"]["wv"])
        if cfg.qkv_bias:
            k = k + lp["cross"]["bk"]
            v = v + lp["cross"]["bv"]
        return k, v

    ks, vs = jax.vmap(kv)(params["dec"])
    return ks, vs


def _cross_decode(lp: dict, x: jax.Array, ck: jax.Array, cv: jax.Array,
                  cfg: ModelConfig) -> jax.Array:
    """Single-token cross attention; ck/cv: (B, T, KH, hd)."""
    q = jnp.einsum("bsd,dhk->bshk", x, lp["wq"])
    if cfg.qkv_bias:
        q = q + lp["bq"]
    if cfg.qk_norm:
        q = blocks.rms_head_norm(lp["q_norm"], q, cfg.norm_eps)
    scores = blocks._gqa_scores(q, ck, cfg)
    probs = jax.nn.softmax(scores, axis=-1)
    return blocks._gqa_out(probs, cv, lp, x.dtype)


def decode_step(params: Tree, cache: Tree, tokens: jax.Array, cfg: ModelConfig
                ) -> tuple[jax.Array, Tree]:
    """One decoder token with self cache + fixed cross K/V."""
    idx = jnp.broadcast_to(jnp.asarray(cache["idx"], jnp.int32),
                           (tokens.shape[0],))
    pos = idx[:, None]
    x = params["embed"][tokens] + sinusoid(pos, cfg.d_model, cfg.adtype)

    def body(h, inp):
        lp, sk, sv, ck, cv = inp
        a = blocks.apply_norm(lp["ln1"], h, cfg)
        a, nk, nv = blocks.decode_attention(lp["attn"], a, sk, sv, idx, cfg,
                                            use_rope=False)
        h = h + a
        a = blocks.apply_norm(lp["lnx"], h, cfg)
        h = h + _cross_decode(lp["cross"], a, ck, cv, cfg)
        a = blocks.apply_norm(lp["ln2"], h, cfg)
        h = h + blocks.apply_mlp(lp["mlp"], a, cfg)
        return h, (nk, nv)

    xs = (params["dec"], cache["self"]["k"], cache["self"]["v"],
          cache["cross_k"], cache["cross_v"])
    if cfg.unroll:
        nks, nvs = [], []
        for i in range(cfg.n_layers):
            x, (nk_i, nv_i) = body(x, jax.tree.map(lambda a: a[i], xs))
            nks.append(nk_i)
            nvs.append(nv_i)
        nk, nv = jnp.stack(nks), jnp.stack(nvs)
    else:
        x, (nk, nv) = jax.lax.scan(body, x, xs)
    x = blocks.apply_norm(params["final_norm"], x, cfg)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head).astype(jnp.float32)
    new_cache = dict(cache)
    new_cache["idx"] = idx + 1
    new_cache["self"] = {"k": nk, "v": nv}
    return logits, new_cache


# ---------------------------------------------------------------------------
# Facade (same interface as transformer.LM)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EncDecLM:
    cfg: ModelConfig

    def param_defs(self) -> Tree:
        return param_defs(self.cfg)

    def init(self, key: jax.Array) -> Tree:
        return init_params(key, self.param_defs())

    def abstract_params(self) -> Tree:
        return abstract_params(self.param_defs())

    def param_axes(self) -> Tree:
        return logical_axes(self.param_defs())

    def forward(self, params, tokens, frames):
        enc = encode(params, frames, self.cfg)
        return decode_train(params, tokens, enc, self.cfg), jnp.zeros((), jnp.float32)

    def loss(self, params, batch) -> jax.Array:
        from repro.models.transformer import lm_loss

        logits, _ = self.forward(params, batch["tokens"], batch["frames"])
        return lm_loss(logits, batch["labels"], self.cfg, batch.get("mask"))

    def cache_defs(self, batch: int, max_len: int) -> Tree:
        return cache_defs(self.cfg, batch, max_len)

    def prefill_cross(self, params, frames):
        return prefill_cross(params, frames, self.cfg)

    def decode_step(self, params, cache, tokens):
        return decode_step(params, cache, tokens, self.cfg)
