"""Shared transformer building blocks: norms, RoPE, GQA attention, MLP.

Pure functions over ParamDef-described dicts.  Activation sharding is
annotated with logical axes (repro.parallel.rules); weight sharding comes
from the ParamDef axes.  Softmax and norm statistics are computed in fp32
regardless of the activation dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.params import ParamDef
from repro.parallel.rules import shard

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_defs(cfg: ModelConfig, d: int | None = None) -> dict:
    d = d or cfg.d_model
    out = {"scale": ParamDef((d,), (None,), init="ones", dtype=cfg.adtype)}
    if cfg.norm == "layernorm":
        out["bias"] = ParamDef((d,), (None,), init="zeros", dtype=cfg.adtype)
    return out


def use_fused_kernels() -> bool:
    """Whether model hot paths route through ``repro.api.launch``.

    Single-device programs always launch the registered Pallas kernels, so
    the ambient ``PlanContext`` (mesh, sublane policy, swept
    ``plan_overrides``) governs the model forward pass too.  Multi-device
    programs launch them when the ambient context carries a real
    multi-device ``jax.sharding.Mesh``: ``api.launch`` then partitions the
    kernel over the mesh via shard_map using its registered
    ``Partitioning``, with each shard planning its own local block shape
    (``repro.api.spmd``).  Without such a mesh -- or inside an existing
    shard_map body (pipeline stages), or under ``plan_context(spmd=False)``
    -- the pure-jnp path keeps the program partitionable, since a bare
    ``pallas_call`` carries no partitioning rule.  The answer is resolved
    at trace time, so one process can trace both paths under different
    contexts."""
    if jax.device_count() == 1:
        return True
    from repro.api import spmd  # lazy, mirroring the _rms_fused imports

    return spmd.spmd_mesh() is not None


def _rms_ref(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    ms = (xf * xf).mean(-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(
        x.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _rms_fused(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    """RMSNorm via the registry kernel, differentiable: the forward pass is
    the planned Pallas launch (so plans, profiles, and the mesh policy all
    apply), the backward pass is the vjp of the identical jnp math --
    Pallas bodies define no autodiff rule."""
    from repro.api import dispatch

    return dispatch.launch("rmsnorm", x, scale, eps=eps)


def _rms_fused_fwd(x, scale, eps):
    from repro.api import dispatch

    return dispatch.launch("rmsnorm", x, scale, eps=eps), (x, scale)


def _rms_fused_bwd(eps, res, g):
    x, scale = res
    _, vjp = jax.vjp(lambda xx, ss: _rms_ref(xx, ss, eps), x, scale)
    return vjp(g)


_rms_fused.defvjp(_rms_fused_fwd, _rms_fused_bwd)


def apply_norm(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        if use_fused_kernels():
            return _rms_fused(x, p["scale"], cfg.norm_eps)
        ms = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_head_norm(scale: jax.Array, x: jax.Array, eps: float) -> jax.Array:
    """Per-head RMSNorm over the last (head_dim) axis (qwen3 qk_norm)."""
    xf = x.astype(jnp.float32)
    ms = (xf * xf).mean(-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Llama-style rotary embedding. x: (..., S, H, D), positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(
        -jnp.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, optional qk-norm / bias / softcap / cross)
# ---------------------------------------------------------------------------

def attention_defs(cfg: ModelConfig) -> dict:
    d, h, kh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = cfg.adtype
    defs = {
        "wq": ParamDef((d, h, hd), ("embed", "heads", "head_dim"), dtype=dt),
        "wk": ParamDef((d, kh, hd), ("embed", "kv_heads", "head_dim"), dtype=dt),
        "wv": ParamDef((d, kh, hd), ("embed", "kv_heads", "head_dim"), dtype=dt),
        "wo": ParamDef((h, hd, d), ("heads", "head_dim", "embed"), dtype=dt),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((h, hd), ("heads", "head_dim"), init="zeros", dtype=dt)
        defs["bk"] = ParamDef((kh, hd), ("kv_heads", "head_dim"), init="zeros", dtype=dt)
        defs["bv"] = ParamDef((kh, hd), ("kv_heads", "head_dim"), init="zeros", dtype=dt)
    if cfg.qk_norm:
        defs["q_norm"] = ParamDef((hd,), (None,), init="ones", dtype=dt)
        defs["k_norm"] = ParamDef((hd,), (None,), init="ones", dtype=dt)
    return defs


def _project_qkv(p: dict, x: jax.Array, x_kv: jax.Array, cfg: ModelConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x_kv, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x_kv, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if cfg.qk_norm:
        q = rms_head_norm(p["q_norm"], q, cfg.norm_eps)
        k = rms_head_norm(p["k_norm"], k, cfg.norm_eps)
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)
    return q, k, v


def _gqa_scores(q: jax.Array, k: jax.Array, cfg: ModelConfig) -> jax.Array:
    """q: (B,Sq,H,D), k: (B,Sk,KH,D) -> scores (B,KH,G,Sq,Sk) in fp32."""
    b, sq, h, dhd = q.shape
    kh = k.shape[2]
    g = h // kh
    qg = q.reshape(b, sq, kh, g, dhd)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.asarray(dhd, jnp.float32))
    if cfg.attn_softcap:
        cap = cfg.attn_softcap
        scores = cap * jnp.tanh(scores / cap)
    return scores


def _gqa_out(probs: jax.Array, v: jax.Array, p: dict, dtype) -> jax.Array:
    """probs: (B,KH,G,Sq,Sk), v: (B,Sk,KH,D) -> (B,Sq,d_model)."""
    ctx = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    b, sq, kh, g, dhd = ctx.shape
    ctx = ctx.reshape(b, sq, kh * g, dhd)
    out = jnp.einsum("bqhd,hdm->bqm", ctx, p["wo"])
    return shard(out.astype(dtype), "batch", None, None)


ATTN_BLOCK = 512  # KV tile length for the chunked (online-softmax) path


def _chunked_gqa(q: jax.Array, k: jax.Array, v: jax.Array, cfg: ModelConfig,
                 q_pos: jax.Array, kv_pos: jax.Array, causal: bool,
                 block: int = ATTN_BLOCK) -> jax.Array:
    """Flash-style attention: scan over KV tiles with running (m, l, acc).

    Never materializes (Sq, Sk) scores -- the working set is one
    (B, KH, G, Sq, block) tile, which is what makes the 32k prefill cells
    (and zamba2's unscanned shared blocks) fit.  This is the jnp form of the
    kernel a Pallas flash-attention would implement; block size is the
    VMEM-tile knob (a multiple of 128 lanes, per the layout policy).
    """
    b, sq, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    sk = k.shape[1]
    pad = (-sk) % block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
    nk = (sk + pad) // block
    qg = q.reshape(b, sq, kh, g, d).transpose(0, 2, 3, 1, 4).astype(jnp.float32)
    qg = qg / jnp.sqrt(jnp.asarray(d, jnp.float32))
    kb = k.reshape(b, nk, block, kh, d).transpose(1, 0, 3, 2, 4)  # (nk,B,KH,L,D)
    vb = v.reshape(b, nk, block, kh, d).transpose(1, 0, 3, 2, 4)
    pb = kv_pos.reshape(b, nk, block).transpose(1, 0, 2)          # (nk,B,L)

    def body(carry, inp):
        m, l, acc = carry
        kt, vt, pt = inp
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kt.astype(jnp.float32))
        if cfg.attn_softcap:
            cap = cfg.attn_softcap
            s = cap * jnp.tanh(s / cap)
        valid = (pt >= 0)[:, None, None, None, :]
        if causal:
            valid = valid & (
                q_pos[:, None, None, :, None] >= pt[:, None, None, None, :]
            )
        s = jnp.where(valid, s, -1e30)
        mn = jnp.maximum(m, jnp.max(s, axis=-1))
        pmat = jnp.where(s <= -1e29, 0.0, jnp.exp(s - mn[..., None]))
        alpha = jnp.exp(m - mn)
        l = l * alpha + jnp.sum(pmat, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", pmat, vt.astype(jnp.float32)
        )
        return (mn, l, acc), None

    init = (
        jnp.full((b, kh, g, sq), -1e30, jnp.float32),
        jnp.zeros((b, kh, g, sq), jnp.float32),
        jnp.zeros((b, kh, g, sq, d), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(body), init, (kb, vb, pb))
    out = acc / jnp.maximum(l, 1e-9)[..., None]                   # (B,KH,G,Sq,D)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d)


def attention(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    causal: bool = True,
    x_kv: jax.Array | None = None,
    kv_positions: jax.Array | None = None,
    use_rope: bool = True,
) -> jax.Array:
    """Full-sequence attention (training / prefill / encoder / cross)."""
    cross = x_kv is not None
    x_kv = x if x_kv is None else x_kv
    kv_positions = positions if kv_positions is None else kv_positions
    q, k, v = _project_qkv(p, x, x_kv, cfg)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, kv_positions, cfg.rope_theta)
    if k.shape[1] > ATTN_BLOCK:  # chunked path: anything beyond one tile
        ctx = _chunked_gqa(q, k, v, cfg, positions, kv_positions,
                           causal and not cross)
        b, sq, h, d = ctx.shape
        out = jnp.einsum("bqhd,hdm->bqm", ctx.astype(x.dtype), p["wo"])
        return shard(out, "batch", None, None)
    scores = _gqa_scores(q, k, cfg)
    if causal and not cross:
        mask = positions[:, None, :, None] >= kv_positions[:, None, None, :]
        scores = jnp.where(mask[:, :, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return _gqa_out(probs, v, p, x.dtype)


# ---- decode with KV cache -------------------------------------------------

def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, n: int) -> dict:
    """Stacked (n-layer) KV cache in the configured layout."""
    kh, hd = cfg.n_kv_heads, cfg.hd
    if cfg.kv_cache_layout == "bhsd":
        shape = (n, batch, kh, max_len, hd)
        axes = ("layers", "batch", "kv_heads", "cache_seq", None)
    else:  # bshd
        shape = (n, batch, max_len, kh, hd)
        axes = ("layers", "batch", "cache_seq", "kv_heads", None)
    return {
        "k": ParamDef(shape, axes, init="zeros", dtype=cfg.adtype),
        "v": ParamDef(shape, axes, init="zeros", dtype=cfg.adtype),
    }


def _cache_put(cache_kv: jax.Array, new: jax.Array, idx: jax.Array, layout: str) -> jax.Array:
    """Insert (B, 1, KH, D) at per-row position idx.

    idx is (B,) int32 -- each batch slot writes at its own depth
    (continuous batching: requests in one batch are at different positions).
    A scalar idx broadcasts (the single-stream case).
    """
    idx = jnp.broadcast_to(jnp.asarray(idx, jnp.int32), (new.shape[0],))
    if layout == "bhsd":
        upd = new.transpose(0, 2, 1, 3)  # (B, KH, 1, D)
        return jax.vmap(
            lambda c, u, i: jax.lax.dynamic_update_slice(c, u, (0, i, 0))
        )(cache_kv, upd, idx)
    return jax.vmap(
        lambda c, u, i: jax.lax.dynamic_update_slice(c, u, (i, 0, 0))
    )(cache_kv, new, idx)


def _cache_kv_view(cache_kv: jax.Array, layout: str) -> jax.Array:
    """Return (B, S, KH, D) view of one layer's cache."""
    if layout == "bhsd":
        return cache_kv.transpose(0, 2, 1, 3)
    return cache_kv


def decode_attention(
    p: dict,
    x: jax.Array,
    cache_k: jax.Array,
    cache_v: jax.Array,
    idx: jax.Array,
    cfg: ModelConfig,
    *,
    use_rope: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode step.  x: (B, 1, d); idx scalar or per-slot (B,).
    Returns (out, new_k, new_v)."""
    b = x.shape[0]
    layout = cfg.kv_cache_layout
    idx = jnp.broadcast_to(jnp.asarray(idx, jnp.int32), (b,))
    pos = idx[:, None]
    q, k, v = _project_qkv(p, x, x, cfg)
    if use_rope:
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
    cache_k = _cache_put(cache_k, k, idx, layout)
    cache_v = _cache_put(cache_v, v, idx, layout)
    kv_k = _cache_kv_view(cache_k, layout)
    kv_v = _cache_kv_view(cache_v, layout)
    scores = _gqa_scores(q, kv_k, cfg)  # (B,KH,G,1,S)
    s = kv_k.shape[1]
    valid = (jnp.arange(s, dtype=jnp.int32)[None, :]
             <= idx[:, None])[:, None, None, None, :]
    scores = jnp.where(valid, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(probs, kv_v, p, x.dtype)
    return out, cache_k, cache_v


# ---- paged KV cache (serving) ---------------------------------------------
#
# The serving scheduler stores KV in a shared physical pool of fixed-size
# pages instead of one dense (slots, max_len) slab: each batch row owns a
# page *table* mapping logical position p to physical page table[p // P] at
# offset p % P (core.segmented.PageGeometry -- the 2-D generalization of the
# paper's segmented container).  Page 0 is the reserved null page: empty
# table rows point at it and masked writes land in it, so a scatter over a
# partially occupied batch never touches live data.


def paged_kv_pool_defs(cfg: ModelConfig, n_pages: int, page_len: int,
                       n: int) -> dict:
    """Stacked (n-layer) paged KV pool: pages are physical (page_len, KH, D)
    tiles shared by all slots; there is no batch axis -- placement is the
    page table's job.  Pages are stored position-major regardless of
    ``cfg.kv_cache_layout`` (the dense-slab layout knob does not apply: page
    geometry is the planner's choice, see serving.paged_cache)."""
    shape = (n, n_pages, page_len, cfg.n_kv_heads, cfg.hd)
    axes = ("layers", None, None, "kv_heads", None)
    return {
        "k": ParamDef(shape, axes, init="zeros", dtype=cfg.adtype),
        "v": ParamDef(shape, axes, init="zeros", dtype=cfg.adtype),
    }


def _paged_put(pool: jax.Array, new: jax.Array, pages: jax.Array,
               idx: jax.Array, act: jax.Array) -> jax.Array:
    """Insert (B, 1, KH, D) at per-row logical position ``idx`` through the
    page table.  ``act`` (B,) masks the write: inactive rows are routed to
    the null page (physical page 0), so a frozen slot's pool state is
    bit-identical to not having stepped at all."""
    p = pool.shape[1]
    b = new.shape[0]
    idx = jnp.broadcast_to(jnp.asarray(idx, jnp.int32), (b,))
    lp = jnp.clip(idx // p, 0, pages.shape[1] - 1)
    phys = jnp.take_along_axis(pages, lp[:, None], axis=1)[:, 0]
    live = act > 0
    phys = jnp.where(live, phys, 0)
    off = jnp.where(live, idx % p, 0)
    return pool.at[phys, off].set(new[:, 0])


def _paged_view(pool: jax.Array, pages: jax.Array) -> jax.Array:
    """Gather (B, max_pages * page_len, KH, D): the dense bshd view of each
    row's page table.  Unmapped table entries read the null page; their
    positions sit beyond the row's written prefix and are masked by the
    caller's ``<= idx`` validity test."""
    g = pool[pages]                         # (B, MP, P, KH, D)
    b, mp, p = g.shape[:3]
    return g.reshape(b, mp * p, *g.shape[3:])


def paged_decode_attention(
    p: dict,
    x: jax.Array,
    pool_k: jax.Array,
    pool_v: jax.Array,
    pages: jax.Array,
    idx: jax.Array,
    act: jax.Array,
    cfg: ModelConfig,
    *,
    use_rope: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode against the paged pool: same math as
    ``decode_attention``, with the cache write scattered through the page
    table and the KV view gathered from it.  Returns (out, new_pool_k,
    new_pool_v)."""
    b = x.shape[0]
    idx = jnp.broadcast_to(jnp.asarray(idx, jnp.int32), (b,))
    pos = idx[:, None]
    q, k, v = _project_qkv(p, x, x, cfg)
    if use_rope:
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
    pool_k = _paged_put(pool_k, k, pages, idx, act)
    pool_v = _paged_put(pool_v, v, pages, idx, act)
    kv_k = _paged_view(pool_k, pages)
    kv_v = _paged_view(pool_v, pages)
    scores = _gqa_scores(q, kv_k, cfg)      # (B,KH,G,1,S)
    s = kv_k.shape[1]
    valid = (jnp.arange(s, dtype=jnp.int32)[None, :]
             <= idx[:, None])[:, None, None, None, :]
    scores = jnp.where(valid, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(probs, kv_v, p, x.dtype)
    return out, pool_k, pool_v


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def mlp_defs(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = cfg.adtype
    return {
        "wi": ParamDef((d, f), ("embed", "mlp"), dtype=dt),
        "wg": ParamDef((d, f), ("embed", "mlp"), dtype=dt),
        "wo": ParamDef((f, d), ("mlp", "embed"), dtype=dt),
    }


def apply_mlp(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    h = jnp.einsum("bsd,df->bsf", x, p["wi"])
    g = jnp.einsum("bsd,df->bsf", x, p["wg"])
    h = shard(act(g) * h, "batch", None, "mlp")
    out = jnp.einsum("bsf,fd->bsd", h, p["wo"])
    return shard(out, "batch", None, None)
