"""Mamba2 (SSD) block -- chunked parallel training form + O(1) decode.

Used by zamba2 (hybrid).  Dimensions: d_inner = expand * d_model, H heads of
width P = ssm_head_dim, state width N = ssm_state, single B/C group.

Training uses the chunked state-space-dual form: within a chunk of length L
the output is an attention-like einsum with a causal decay mask; across
chunks only the (B, H, N, P) boundary states are scanned.  All decay
exponents are non-positive (A < 0, dt > 0) so exp() is stable; decay math is
fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.params import ParamDef
from repro.parallel.rules import shard

CHUNK = 256


def mamba_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    dinner = cfg.ssm_expand * d
    h = dinner // cfg.ssm_head_dim
    n = cfg.ssm_state
    k = cfg.ssm_conv
    dt = cfg.adtype
    return {
        "wz": ParamDef((d, dinner), ("embed", "mlp"), dtype=dt),
        "wx": ParamDef((d, dinner), ("embed", "mlp"), dtype=dt),
        "wbc": ParamDef((d, 2 * n), ("embed", None), dtype=dt),
        "wdt": ParamDef((d, h), ("embed", "heads"), dtype=dt),
        "conv_x": ParamDef((k, dinner), ("conv", "mlp"), scale=0.5, dtype=dt),
        "conv_x_b": ParamDef((dinner,), ("mlp",), init="zeros", dtype=dt),
        "conv_bc": ParamDef((k, 2 * n), ("conv", None), scale=0.5, dtype=dt),
        "conv_bc_b": ParamDef((2 * n,), (None,), init="zeros", dtype=dt),
        "A_log": ParamDef((h,), ("heads",), init="zeros", dtype=jnp.float32),
        "D": ParamDef((h,), ("heads",), init="ones", dtype=jnp.float32),
        "dt_bias": ParamDef((h,), ("heads",), init="zeros", dtype=jnp.float32),
        "gnorm": ParamDef((dinner,), ("mlp",), init="ones", dtype=dt),
        "wo": ParamDef((dinner, d), ("mlp", "embed"), dtype=dt),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv along seq. x: (B,S,C), w: (K,C)."""
    k = w.shape[0]
    out = jnp.zeros_like(x)
    for i in range(k):
        shift = k - 1 - i
        xi = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1], :]
        out = out + xi * w[i]
    return out + b


def _proj(p: dict, u: jax.Array, cfg: ModelConfig):
    """Shared projection path for train and decode-step inputs."""
    z = jnp.einsum("bsd,di->bsi", u, p["wz"])
    x = jnp.einsum("bsd,di->bsi", u, p["wx"])
    bc = jnp.einsum("bsd,dn->bsn", u, p["wbc"])
    dt_pre = jnp.einsum("bsd,dh->bsh", u, p["wdt"]).astype(jnp.float32)
    return z, x, bc, dt_pre


def _split_heads(x: jax.Array, cfg: ModelConfig) -> jax.Array:
    b, s, dinner = x.shape
    return x.reshape(b, s, dinner // cfg.ssm_head_dim, cfg.ssm_head_dim)


def mamba_forward(p: dict, u: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Full-sequence chunked SSD. u: (B, S, d_model)."""
    b, s, d = u.shape
    n = cfg.ssm_state
    pdim = cfg.ssm_head_dim
    l = min(CHUNK, s)
    pad = (-s) % l
    z, x, bc, dt_pre = _proj(p, u, cfg)
    x = _causal_conv(x, p["conv_x"], p["conv_x_b"])
    x = jax.nn.silu(x)
    bc = jax.nn.silu(_causal_conv(bc, p["conv_bc"], p["conv_bc_b"]))
    x = shard(x, "batch", None, "mlp")
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        bc = jnp.pad(bc, ((0, 0), (0, pad), (0, 0)))
        dt_pre = jnp.pad(dt_pre, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    nc = sp // l
    xh = _split_heads(x, cfg).reshape(b, nc, l, -1, pdim)       # (B,nc,L,H,P)
    bmat = bc[..., :n].reshape(b, nc, l, n).astype(jnp.float32)
    cmat = bc[..., n:].reshape(b, nc, l, n).astype(jnp.float32)
    dt = jax.nn.softplus(dt_pre + p["dt_bias"]).reshape(b, nc, l, -1)  # (B,nc,L,H)
    a = -jnp.exp(p["A_log"])                                     # (H,) negative
    da = dt * a                                                   # (B,nc,L,H) <= 0
    cum = jnp.cumsum(da, axis=2)                                  # (B,nc,L,H)
    xw = (xh.astype(jnp.float32) * dt[..., None])                 # dt_j * x_j
    nheads = xh.shape[3]

    ii = jnp.arange(l)
    causal = (ii[:, None] >= ii[None, :]).astype(jnp.float32)     # (L,L)

    # One chunk at a time (lax.scan over chunks, rematted): the decay
    # "attention" tile (B,L,L,H) never exists for more than one chunk --
    # the VMEM-sized working set a TPU SSD kernel would use.
    def chunk_fn(state, inp):
        xw_c, b_c, c_c, cum_c = inp                               # (B,L,...)
        cb = jnp.einsum("bin,bjn->bij", c_c, b_c)                 # (B,L,L)
        dec = jnp.exp(cum_c[:, :, None, :] - cum_c[:, None, :, :])
        att = cb[..., None] * dec * causal[None, :, :, None]      # (B,L,L,H)
        y = jnp.einsum("bijh,bjhp->bihp", att, xw_c)
        y = y + jnp.einsum("bin,bhnp->bihp", c_c, state) * jnp.exp(
            cum_c
        )[..., None]
        dec_last = jnp.exp(cum_c[:, -1:, :] - cum_c)              # (B,L,H)
        new_state = jnp.exp(cum_c[:, -1, :])[:, :, None, None] * state + (
            jnp.einsum("bjn,bjh,bjhp->bhnp", b_c, dec_last, xw_c)
        )
        return new_state, y

    chunk_fn = jax.checkpoint(chunk_fn)
    init = jnp.zeros((b, nheads, n, pdim), jnp.float32)
    xs = (
        xw.transpose(1, 0, 2, 3, 4),
        bmat.transpose(1, 0, 2, 3),
        cmat.transpose(1, 0, 2, 3),
        cum.transpose(1, 0, 2, 3),
    )
    _, ys = jax.lax.scan(chunk_fn, init, xs)
    y_sc = ys.transpose(1, 0, 2, 3, 4)                            # (B,nc,L,H,P)

    y = y_sc + p["D"][None, None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, sp, -1)[:, :s, :].astype(u.dtype)            # (B,S,d_inner)

    # ---- gate + norm + out ---------------------------------------------------
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt((yf * yf).mean(-1, keepdims=True) + cfg.norm_eps)).astype(
        u.dtype
    ) * p["gnorm"]
    return shard(jnp.einsum("bsi,id->bsd", y, p["wo"]), "batch", None, None)


# ---------------------------------------------------------------------------
# Decode (single token, O(1) state)
# ---------------------------------------------------------------------------

def mamba_cache_defs(cfg: ModelConfig, batch: int, n_stack: int) -> dict:
    d = cfg.d_model
    dinner = cfg.ssm_expand * d
    h = dinner // cfg.ssm_head_dim
    n = cfg.ssm_state
    k = cfg.ssm_conv
    dt = cfg.adtype
    return {
        "conv_x": ParamDef((n_stack, batch, k - 1, dinner),
                           ("layers", "batch", None, "mlp"), init="zeros", dtype=dt),
        "conv_bc": ParamDef((n_stack, batch, k - 1, 2 * n),
                            ("layers", "batch", None, None), init="zeros", dtype=dt),
        "ssm": ParamDef((n_stack, batch, h, n, cfg.ssm_head_dim),
                        ("layers", "batch", "heads", "state", None),
                        init="zeros", dtype=jnp.float32),
    }


def _conv_step(xt: jax.Array, state: jax.Array, w: jax.Array, b: jax.Array):
    """xt: (B,1,C), state: (B,K-1,C) of previous inputs. Returns (y, new_state)."""
    window = jnp.concatenate([state, xt], axis=1)                 # (B,K,C)
    y = jnp.einsum("bkc,kc->bc", window, w)[:, None, :] + b
    return y, window[:, 1:, :]


def mamba_decode_step(p: dict, cache: dict, u: jax.Array, cfg: ModelConfig):
    """u: (B,1,d). Returns (y, new_cache)."""
    n = cfg.ssm_state
    z, x, bc, dt_pre = _proj(p, u, cfg)
    x, conv_x = _conv_step(x, cache["conv_x"], p["conv_x"], p["conv_x_b"])
    x = jax.nn.silu(x)
    bc, conv_bc = _conv_step(bc, cache["conv_bc"], p["conv_bc"], p["conv_bc_b"])
    bc = jax.nn.silu(bc)
    xh = _split_heads(x, cfg)[:, 0].astype(jnp.float32)           # (B,H,P)
    bmat = bc[:, 0, :n].astype(jnp.float32)                       # (B,N)
    cmat = bc[:, 0, n:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_pre[:, 0] + p["dt_bias"])             # (B,H)
    a = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * a)                                       # (B,H)
    h = cache["ssm"]                                              # (B,H,N,P)
    h = decay[:, :, None, None] * h + jnp.einsum(
        "bn,bh,bhp->bhnp", bmat, dt, xh
    )
    y = jnp.einsum("bn,bhnp->bhp", cmat, h) + p["D"][None, :, None] * xh
    y = y.reshape(u.shape[0], 1, -1).astype(u.dtype)
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt((yf * yf).mean(-1, keepdims=True) + cfg.norm_eps)).astype(
        u.dtype
    ) * p["gnorm"]
    out = jnp.einsum("bsi,id->bsd", y, p["wo"])
    return out, {"conv_x": conv_x, "conv_bc": conv_bc, "ssm": h}
