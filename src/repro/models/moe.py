"""Mixture-of-Experts block: top-k router, capacity dispatch, EP sharding,
and the paper-derived *skewed expert placement*.

Dispatch is scatter-based (GShard capacity semantics without the (T, E, C)
one-hot): tokens are ranked within their expert by a cumsum over the token
axis, dropped beyond capacity, scattered into an (E, C, d) buffer, run
through the stacked expert FFNs, and combined back with router weights.

Skewed placement (core.sharding_skew): layer l's expert->device map is
rotated by l, so a persistently hot expert index does not pin the same
device in every layer -- the all-to-all analogue of the paper's one
channel-step segment shift.  The rotation enters as a per-layer permutation
vector carried in the scanned parameters (zero FLOPs, pure layout).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sharding_skew import expert_permutation
from repro.models.config import ModelConfig
from repro.models.params import ParamDef
from repro.parallel.rules import shard


def moe_defs(cfg: ModelConfig) -> dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    dt = cfg.adtype
    return {
        "router": ParamDef((d, e), ("embed", None), dtype=jnp.float32),
        "wi": ParamDef((e, d, f), ("expert", "embed", "expert_mlp"), dtype=dt),
        "wg": ParamDef((e, d, f), ("expert", "embed", "expert_mlp"), dtype=dt),
        "wo": ParamDef((e, f, d), ("expert", "expert_mlp", "embed"), dtype=dt),
        # static, non-learned: layer's expert->slot permutation (skew)
        "perm": ParamDef((e,), (None,), init="zeros", dtype=jnp.int32),
    }


def make_perms(cfg: ModelConfig, n_layers: int, n_expert_shards: int) -> np.ndarray:
    """(L, E) permutation table: identity if skew disabled."""
    e = cfg.n_experts
    if not cfg.skewed_experts or n_expert_shards <= 1:
        return np.tile(np.arange(e, dtype=np.int32), (n_layers, 1))
    return np.stack(
        [
            expert_permutation(e, n_expert_shards, l).astype(np.int32)
            for l in range(n_layers)
        ]
    )


def apply_moe(p: dict, x: jax.Array, cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss).

    GShard-style *grouped* dispatch: tokens are split into ``cfg.moe_groups``
    groups aligned with the data shards.  Ranking, scatter and combine are
    vmapped per group (no cross-shard data dependency -- the scatters stay
    local to a shard), and the only cross-device traffic is the explicit
    group-major <-> expert-major reshard of the (G, E, C_g, d) buffer: the
    all-to-all this architecture is supposed to pay, and nothing else.
    A global-capacity variant (G=1) costs ~20x more wire (EXPERIMENTS.md
    SSPerf, moe iteration 2: GSPMD replicates global scatter contributions).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    g = max(cfg.moe_groups, 1)
    assert t % g == 0, (t, g)
    tg = t // g
    xf = shard(x.reshape(t, d), "batch", None)

    logits = (xf.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                # (T, k)
    weights = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # ---- skewed placement: map logical expert -> storage slot -------------
    inv = jnp.argsort(p["perm"])            # logical -> slot
    slot = inv[top_e]                        # (T, k)

    # ---- per-group capacity ranking (sort-based, O(n log n)) ---------------
    # position-in-expert = rank among equal expert ids.  A global (T*k, E)
    # one-hot cumsum is O(T^2 E) in XLA's reduce-window lowering and
    # serializes across shards (SSPerf moe iteration 1); stable argsort +
    # per-expert offsets per group is the MegaBlocks-style dispatch.
    cap = int(np.ceil(cfg.capacity_factor * tg * k / e))
    cap += (-cap) % 8  # sublane-align the capacity axis (layout policy)
    slot_g = slot.reshape(g, tg * k)
    w_g = weights.reshape(g, tg * k)
    token_of = jnp.repeat(jnp.arange(tg, dtype=jnp.int32), k)        # (tg*k,)

    def rank_group(slots: jax.Array) -> jax.Array:
        counts = jnp.zeros((e,), jnp.int32).at[slots].add(1)
        starts = jnp.cumsum(counts) - counts                         # (E,)
        order = jnp.argsort(slots, stable=True)
        rank_sorted = jnp.arange(tg * k, dtype=jnp.int32) - starts[slots[order]]
        return jnp.zeros((tg * k,), jnp.int32).at[order].set(rank_sorted)

    pos = jax.vmap(rank_group)(slot_g)                               # (G, tg*k)
    keep = pos < cap
    idx = slot_g * cap + jnp.where(keep, pos, cap - 1)               # (G, tg*k)

    # ---- dispatch: local scatter per group, then ONE reshard ---------------
    xg = xf.reshape(g, tg, d)

    def scatter_group(xg_i, idx_i, keep_i):
        contrib = jnp.where(keep_i[:, None], xg_i[token_of], 0).astype(x.dtype)
        return jnp.zeros((e * cap, d), x.dtype).at[idx_i].add(contrib)

    buf = jax.vmap(scatter_group)(xg, idx, keep)                     # (G, E*cap, d)
    buf = shard(buf.reshape(g, e, cap, d), "batch", None, None, None)
    # Group-major -> expert-major reshard.  Empirically the best plan of SIX
    # candidates (EXPERIMENTS.md SSPerf m2-m6): constraint flips, two-step
    # slice+a2a, unconstrained propagation, and a custom-VJP symmetric a2a
    # all regressed -- the a2a itself reaches its analytic optimum but a
    # residual constraint-materialization all-gather dominates regardless,
    # so the simple transpose (same AG, no extra a2a) wins on net.
    eb = buf.transpose(1, 0, 2, 3).reshape(e, g * cap, d)
    eb = shard(eb, "expert", "expert_cap", None)

    # ---- expert FFNs --------------------------------------------------------
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    h = jnp.einsum("ecd,edf->ecf", eb, p["wi"])
    gate = jnp.einsum("ecd,edf->ecf", eb, p["wg"])
    h = shard(act(gate) * h, "expert", "expert_cap", "expert_mlp")
    y = jnp.einsum("ecf,efd->ecd", h, p["wo"])
    y = shard(y, "expert", "expert_cap", None)

    # ---- combine: reshard back, local gather per group ----------------------
    yg = y.reshape(e, g, cap, d).transpose(1, 0, 2, 3)
    yg = shard(yg, "batch", None, None, None).reshape(g, e * cap, d)

    def combine_group(y_i, idx_i, keep_i, w_i):
        gathered = y_i[idx_i] * jnp.where(keep_i, w_i, 0)[:, None].astype(
            x.dtype
        )
        return jnp.zeros((tg, d), x.dtype).at[token_of].add(gathered)

    out = jax.vmap(combine_group)(yg, idx, keep, w_g)                # (G, tg, d)
    out = shard(out.reshape(t, d), "batch", None)

    # ---- aux load-balance loss (switch-style, on logical experts) ----------
    me = jnp.mean(probs, axis=0)                                     # (E,)
    ce = jnp.zeros(e, jnp.float32).at[top_e.reshape(-1)].add(
        jnp.ones(t * k, jnp.float32)
    ) / (t * k)
    aux = e * jnp.sum(me * ce) * cfg.router_aux_weight
    return out.reshape(b, s, d), aux
