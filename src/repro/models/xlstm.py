"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, recurrent scan).

mLSTM recurrence (per head, stabilized in log space):
    m_t = max(lf_t + m_{t-1}, li_t)
    C_t = exp(lf_t + m_{t-1} - m_t) C_{t-1} + exp(li_t - m_t) v_t k_t^T
    n_t = exp(lf_t + m_{t-1} - m_t) n_{t-1} + exp(li_t - m_t) k_t
    h_t = (q_t C_t) / max(|q_t . n_t|, exp(-m_t))

Chunkwise closed form used for training: with B_i = sum_{s<=i} lf_s inside a
chunk and u_i = max(m_0, cummax_{j<=i}(li_j - B_j)) the stabilizer is
m_i = B_i + u_i, giving a causal attention-like intra term plus a carry term
from (C_0, n_0, m_0).  Cross-chunk state is carried by lax.scan over chunks.

sLSTM is inherently sequential (recurrent connection through h_{t-1}); it is
implemented as a lax.scan over time with block-diagonal (per-head) recurrent
weights, exactly as the architecture prescribes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.params import ParamDef
from repro.parallel.rules import shard

CHUNK = 256


def _dims(cfg: ModelConfig) -> tuple[int, int, int]:
    dinner = 2 * cfg.d_model
    h = cfg.n_heads
    p = dinner // h
    return dinner, h, p


def mlstm_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    dinner, h, p = _dims(cfg)
    dt = cfg.adtype
    k = 4  # causal conv width on the q/k path
    return {
        "wup_x": ParamDef((d, dinner), ("embed", "mlp"), dtype=dt),
        "wup_z": ParamDef((d, dinner), ("embed", "mlp"), dtype=dt),
        "conv": ParamDef((k, dinner), ("conv", "mlp"), scale=0.5, dtype=dt),
        "conv_b": ParamDef((dinner,), ("mlp",), init="zeros", dtype=dt),
        # block-diagonal (per-head) projections, as in the reference mLSTM
        "wq": ParamDef((h, p, p), ("heads", None, "head_dim"), dtype=dt),
        "wk": ParamDef((h, p, p), ("heads", None, "head_dim"), dtype=dt),
        "wv": ParamDef((h, p, p), ("heads", None, "head_dim"), dtype=dt),
        "wi": ParamDef((dinner, h), ("mlp", "heads"), dtype=jnp.float32),
        "wf": ParamDef((dinner, h), ("mlp", "heads"), dtype=jnp.float32),
        "bi": ParamDef((h,), ("heads",), init="zeros", dtype=jnp.float32),
        "bf": ParamDef((h,), ("heads",), init="ones", dtype=jnp.float32),
        "gnorm": ParamDef((dinner,), ("mlp",), init="ones", dtype=dt),
        "wo": ParamDef((dinner, d), ("mlp", "embed"), dtype=dt),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    k = w.shape[0]
    out = jnp.zeros_like(x)
    for i in range(k):
        shift = k - 1 - i
        xi = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1], :]
        out = out + xi * w[i]
    return out + b


def _mlstm_qkvif(p: dict, xin: jax.Array, cfg: ModelConfig):
    """Common pre-cell path. xin: (B,S,d_model)."""
    x = jnp.einsum("bsd,di->bsi", xin, p["wup_x"])
    z = jnp.einsum("bsd,di->bsi", xin, p["wup_z"])
    xc = jax.nn.silu(_causal_conv(x, p["conv"], p["conv_b"]))
    nh = p["wq"].shape[0]
    xch = xc.reshape(*xc.shape[:2], nh, -1)
    xh = x.reshape(*x.shape[:2], nh, -1)
    q = jnp.einsum("bshp,hpq->bshq", xch, p["wq"])
    k = jnp.einsum("bshp,hpq->bshq", xch, p["wk"])
    v = jnp.einsum("bshp,hpq->bshq", xh, p["wv"])
    li = (jnp.einsum("bsi,ih->bsh", xc.astype(jnp.float32), p["wi"]) + p["bi"])
    lf = jax.nn.log_sigmoid(
        jnp.einsum("bsi,ih->bsh", xc.astype(jnp.float32), p["wf"]) + p["bf"]
    )
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "heads", None)
    v = shard(v, "batch", None, "heads", None)
    return x, z, q, k, v, li, lf


def _mlstm_out(p: dict, h: jax.Array, z: jax.Array, cfg: ModelConfig, dtype):
    """h: (B,S,H,P) cell output; gate, norm, down-project."""
    b, s = h.shape[:2]
    y = h.reshape(b, s, -1).astype(dtype) * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt((yf * yf).mean(-1, keepdims=True) + cfg.norm_eps)).astype(
        dtype
    ) * p["gnorm"]
    return shard(jnp.einsum("bsi,id->bsd", y, p["wo"]), "batch", None, None)


def mlstm_forward(p: dict, xin: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Full-sequence chunkwise mLSTM. xin: (B,S,d_model)."""
    b, s, _ = xin.shape
    dinner, nh, pd = _dims(cfg)
    x, z, q, k, v, li, lf = _mlstm_qkvif(p, xin, cfg)
    l = min(CHUNK, s)
    pad = (-s) % l
    if pad:
        q, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0))) for t in (q, k, v))
        li = jnp.pad(li, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
        lf = jnp.pad(lf, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    nc = sp // l
    qc = q.reshape(b, nc, l, nh, pd).astype(jnp.float32) / jnp.sqrt(float(pd))
    kc = k.reshape(b, nc, l, nh, pd).astype(jnp.float32)
    vc = v.reshape(b, nc, l, nh, pd).astype(jnp.float32)
    lic = li.reshape(b, nc, l, nh)
    lfc = lf.reshape(b, nc, l, nh)
    bcum = jnp.cumsum(lfc, axis=2)                                  # B_i

    def chunk_fn(carry, inp):
        c0, n0, m0 = carry                                          # (B,H,P,P),(B,H,P),(B,H)
        qi, ki, vi, lii, bci = inp                                   # (B,L,H,*)
        u = jnp.maximum(
            m0[:, None, :], jax.lax.cummax(lii - bci, axis=1)
        )                                                            # (B,L,H)
        m = bci + u                                                  # m_i
        # intra: D_ij = (B_i - B_j) + li_j - m_i  (j <= i)
        dmat = (
            bci[:, :, None, :] - bci[:, None, :, :]
            + lii[:, None, :, :]
            - m[:, :, None, :]
        )                                                            # (B,L,L,H)
        ii = jnp.arange(l)
        causal = ii[:, None] >= ii[None, :]
        w = jnp.where(causal[None, :, :, None], jnp.exp(dmat), 0.0)
        qk = jnp.einsum("bihp,bjhp->bijh", qi, ki)                   # (B,L,L,H)
        num_intra = jnp.einsum("bijh,bjhp->bihp", w * qk, vi)
        den_intra = jnp.einsum("bijh,bjhp->bihp", w, ki)             # sum w*k
        # inter: exp(B_i + m0 - m_i) q_i C_0
        winter = jnp.exp(bci + m0[:, None, :] - m)                   # (B,L,H)
        num_inter = jnp.einsum("bihp,bhpq->bihq", qi, c0) * winter[..., None]
        den_inter = n0[:, None, :, :] * winter[..., None]
        num = num_intra + num_inter
        den = jnp.einsum("bihp,bihp->bih", qi, den_intra + den_inter)
        hmax = jnp.maximum(jnp.abs(den), jnp.exp(-m))
        hout = num / hmax[..., None]                                 # (B,L,H,P)
        # carry update to end of chunk
        mL = m[:, -1, :]
        wlast = jnp.exp(bci[:, -1:, :] - bci + lii - mL[:, None, :]) # (B,L,H)
        wmask = jnp.exp(bci[:, -1, :] + m0 - mL)                     # (B,H)
        cL = wmask[:, :, None, None] * c0 + jnp.einsum(
            "bjh,bjhp,bjhq->bhpq", wlast, ki, vi
        )
        nL = wmask[:, :, None] * n0 + jnp.einsum("bjh,bjhp->bhp", wlast, ki)
        return (cL, nL, mL), hout

    chunk_fn = jax.checkpoint(chunk_fn)
    init = (
        jnp.zeros((b, nh, pd, pd), jnp.float32),
        jnp.zeros((b, nh, pd), jnp.float32),
        jnp.full((b, nh), -1e30, jnp.float32),
    )
    xs = tuple(
        t.transpose(1, 0, 2, 3, 4) if t.ndim == 5 else t.transpose(1, 0, 2, 3)
        for t in (qc, kc, vc, lic, bcum)
    )
    _, hs = jax.lax.scan(chunk_fn, init, xs)
    h = hs.transpose(1, 0, 2, 3, 4).reshape(b, sp, nh, pd)[:, :s]
    return _mlstm_out(p, h, z, cfg, xin.dtype)


def mlstm_cache_defs(cfg: ModelConfig, batch: int, n_stack: int) -> dict:
    dinner, h, p = _dims(cfg)
    return {
        "c": ParamDef((n_stack, batch, h, p, p),
                      ("layers", "batch", "heads", None, None),
                      init="zeros", dtype=jnp.float32),
        "n": ParamDef((n_stack, batch, h, p),
                      ("layers", "batch", "heads", None), init="zeros",
                      dtype=jnp.float32),
        "m": ParamDef((n_stack, batch, h), ("layers", "batch", "heads"),
                      init="neg_inf", dtype=jnp.float32),
        "conv": ParamDef((n_stack, batch, 3, dinner),
                         ("layers", "batch", None, "mlp"), init="zeros",
                         dtype=cfg.adtype),
    }


def mlstm_decode_step(p: dict, cache: dict, xin: jax.Array, cfg: ModelConfig):
    """xin: (B,1,d_model). Single recurrent step."""
    dinner, nh, pd = _dims(cfg)
    x = jnp.einsum("bsd,di->bsi", xin, p["wup_x"])
    z = jnp.einsum("bsd,di->bsi", xin, p["wup_z"])
    window = jnp.concatenate([cache["conv"], x], axis=1)             # (B,4,C)
    xc = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", window, p["conv"])[:, None, :] + p["conv_b"]
    )
    xch = xc.reshape(*xc.shape[:2], nh, -1)
    xh2 = x.reshape(*x.shape[:2], nh, -1)
    q = jnp.einsum("bshp,hpq->bshq", xch, p["wq"])[:, 0].astype(jnp.float32)
    k = jnp.einsum("bshp,hpq->bshq", xch, p["wk"])[:, 0].astype(jnp.float32)
    v = jnp.einsum("bshp,hpq->bshq", xh2, p["wv"])[:, 0].astype(jnp.float32)
    q = q / jnp.sqrt(float(pd))
    li = (jnp.einsum("bsi,ih->bsh", xc.astype(jnp.float32), p["wi"]) + p["bi"])[:, 0]
    lf = jax.nn.log_sigmoid(
        jnp.einsum("bsi,ih->bsh", xc.astype(jnp.float32), p["wf"]) + p["bf"]
    )[:, 0]
    c0, n0, m0 = cache["c"], cache["n"], cache["m"]
    m = jnp.maximum(lf + m0, li)
    wf_ = jnp.exp(lf + m0 - m)
    wi_ = jnp.exp(li - m)
    c1 = wf_[:, :, None, None] * c0 + wi_[:, :, None, None] * jnp.einsum(
        "bhp,bhq->bhpq", k, v
    )
    n1 = wf_[:, :, None] * n0 + wi_[:, :, None] * k
    num = jnp.einsum("bhp,bhpq->bhq", q, c1)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", q, n1)), jnp.exp(-m))
    h = (num / den[..., None])[:, None]                              # (B,1,H,P)
    out = _mlstm_out(p, h, z, cfg, xin.dtype)
    return out, {"c": c1, "n": n1, "m": m, "conv": window[:, 1:, :]}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    p = d // h
    dt = cfg.adtype
    return {
        # input projections for gates i, f, z, o
        "wx": ParamDef((d, 4, d), ("embed", None, "mlp"), dtype=jnp.float32),
        # block-diagonal recurrent weights per head
        "r": ParamDef((4, h, p, p), (None, "heads", None, None), dtype=jnp.float32),
        "b": ParamDef((4, d), (None, "mlp"), init="zeros", dtype=jnp.float32),
        "gnorm": ParamDef((d,), ("mlp",), init="ones", dtype=dt),
        "wo": ParamDef((d, d), ("mlp", "embed"), dtype=dt),
    }


def _slstm_cell(p, carry, gx, nh, pd):
    """One sLSTM step. carry: (c, n, m, h); gx: (B,4,d) precomputed x-part."""
    c, n, m, h = carry
    hh = h.reshape(h.shape[0], nh, pd)
    gr = jnp.einsum("ghpq,bhq->gbhp", p["r"], hh).reshape(
        4, h.shape[0], nh * pd
    ).transpose(1, 0, 2)                                             # (B,4,d)
    g = gx + gr + p["b"]
    gi, gf, gz, go = g[:, 0], g[:, 1], g[:, 2], g[:, 3]
    lf = jax.nn.log_sigmoid(gf)
    mn = jnp.maximum(lf + m, gi)
    wf_ = jnp.exp(lf + m - mn)
    wi_ = jnp.exp(gi - mn)
    c1 = wf_ * c + wi_ * jnp.tanh(gz)
    n1 = wf_ * n + wi_
    h1 = jax.nn.sigmoid(go) * c1 / jnp.maximum(n1, 1.0)
    return (c1, n1, mn, h1), h1


def slstm_forward(p: dict, xin: jax.Array, cfg: ModelConfig) -> jax.Array:
    b, s, d = xin.shape
    nh = cfg.n_heads
    pd = d // nh
    gx = jnp.einsum("bsd,dgi->bsgi", xin.astype(jnp.float32), p["wx"])  # (B,S,4,d)
    init = (
        jnp.zeros((b, d), jnp.float32),           # c
        jnp.zeros((b, d), jnp.float32),           # n
        jnp.full((b, d), -1e30, jnp.float32),     # m (no history)
        jnp.zeros((b, d), jnp.float32),           # h
    )

    def step(carry, g):
        return _slstm_cell(p, carry, g, nh, pd)

    _, hs = jax.lax.scan(step, init, gx.transpose(1, 0, 2, 3))
    h = hs.transpose(1, 0, 2)                                        # (B,S,d)
    hf = h.astype(jnp.float32)
    h = (hf * jax.lax.rsqrt((hf * hf).mean(-1, keepdims=True) + cfg.norm_eps)).astype(
        xin.dtype
    ) * p["gnorm"]
    return shard(jnp.einsum("bsi,id->bsd", h, p["wo"]), "batch", None, None)


def slstm_cache_defs(cfg: ModelConfig, batch: int, n_stack: int) -> dict:
    d = cfg.d_model
    return {
        name: ParamDef((n_stack, batch, d), ("layers", "batch", "mlp"),
                       init=("neg_inf" if name == "m" else "zeros"),
                       dtype=jnp.float32)
        for name in ("c", "n", "m", "h")
    }


def slstm_decode_step(p: dict, cache: dict, xin: jax.Array, cfg: ModelConfig):
    b, _, d = xin.shape
    nh = cfg.n_heads
    pd = d // nh
    gx = jnp.einsum("bsd,dgi->bsgi", xin.astype(jnp.float32), p["wx"])[:, 0]
    carry = (cache["c"], cache["n"], cache["m"], cache["h"])
    (c1, n1, m1, h1), h = _slstm_cell(p, carry, gx, nh, pd)
    hf = h.astype(jnp.float32)
    hn = (hf * jax.lax.rsqrt((hf * hf).mean(-1, keepdims=True) + cfg.norm_eps)).astype(
        xin.dtype
    ) * p["gnorm"]
    out = jnp.einsum("bi,id->bd", hn, p["wo"])[:, None, :]
    return out, {"c": c1, "n": n1, "m": m1, "h": h1}
