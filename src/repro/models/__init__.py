"""Model zoo: decoder-only LM families + whisper-style enc-dec."""
from repro.models.config import ModelConfig
from repro.models.encdec import EncDecLM
from repro.models.transformer import LM


def build_model(cfg: ModelConfig):
    """Facade constructor: same interface for every family."""
    return EncDecLM(cfg) if cfg.family == "encdec" else LM(cfg)
