"""Decoder-only LM covering dense / MoE / hybrid (zamba2) / ssm (xlstm) /
vlm (prefix-embed) families.

Layers are grouped into homogeneous *stages* (cfg.stages()); each stage is a
jax.lax.scan over stacked per-layer parameters with an optional remat policy.
Zamba2's shared attention block is a single parameter set applied at every
('shared_attn', 1) stage with its own per-application KV cache.

The module exposes stage-level callables so the roofline harness can lower
one stage body and multiply by its trip count (XLA's cost_analysis counts a
while-loop body once -- see EXPERIMENTS.md SSRoofline methodology).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import blocks, mamba2, moe, xlstm
from repro.models.config import ModelConfig
from repro.models.params import (
    ParamDef, Tree, abstract_params, init_params, logical_axes, stack_defs,
)
from repro.parallel.rules import shard

# ---------------------------------------------------------------------------
# Parameter trees
# ---------------------------------------------------------------------------

def block_defs(cfg: ModelConfig, kind: str) -> Tree:
    if kind == "dense":
        return {
            "ln1": blocks.norm_defs(cfg),
            "attn": blocks.attention_defs(cfg),
            "ln2": blocks.norm_defs(cfg),
            "mlp": blocks.mlp_defs(cfg),
        }
    if kind == "moe":
        return {
            "ln1": blocks.norm_defs(cfg),
            "attn": blocks.attention_defs(cfg),
            "ln2": blocks.norm_defs(cfg),
            "moe": moe.moe_defs(cfg),
        }
    if kind == "mamba":
        return {"ln1": blocks.norm_defs(cfg), "mamba": mamba2.mamba_defs(cfg)}
    if kind == "shared_attn":
        return {
            "win": ParamDef((2 * cfg.d_model, cfg.d_model), ("embed", "embed"),
                            dtype=cfg.adtype),
            "ln1": blocks.norm_defs(cfg),
            "attn": blocks.attention_defs(cfg),
            "ln2": blocks.norm_defs(cfg),
            "mlp": blocks.mlp_defs(cfg),
        }
    if kind == "mlstm":
        return {"ln1": blocks.norm_defs(cfg), "mlstm": xlstm.mlstm_defs(cfg)}
    if kind == "slstm":
        return {"ln1": blocks.norm_defs(cfg), "slstm": xlstm.slstm_defs(cfg)}
    raise ValueError(kind)


def stage_name(i: int, kind: str) -> str:
    return f"s{i:02d}_{kind}"


def param_defs(cfg: ModelConfig) -> Tree:
    tree: Tree = {
        "embed": ParamDef((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                          init="embed", dtype=cfg.adtype),
        "final_norm": blocks.norm_defs(cfg),
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = ParamDef((cfg.d_model, cfg.vocab_size),
                                   ("embed", "vocab"), dtype=cfg.adtype)
    has_shared = False
    for i, (kind, count) in enumerate(cfg.stages()):
        if kind == "shared_attn":
            has_shared = True
            continue  # single shared subtree added below
        tree[stage_name(i, kind)] = stack_defs(block_defs(cfg, kind), count)
    if has_shared:
        tree["shared_attn"] = block_defs(cfg, "shared_attn")
    return tree


def init(cfg: ModelConfig, key: jax.Array) -> Tree:
    params = init_params(key, param_defs(cfg))
    # skew permutations are structural, not random
    for i, (kind, count) in enumerate(cfg.stages()):
        if kind == "moe":
            perms = moe.make_perms(cfg, count, _expert_shards(cfg))
            params[stage_name(i, kind)]["moe"]["perm"] = jnp.asarray(perms)
    return params


def _expert_shards(cfg: ModelConfig) -> int:
    """Number of devices the expert axis is sharded over (for skew maps).
    Resolved at launch from the mesh; default 16 documents the single-pod
    model-axis width so skew tables are deterministic."""
    return 16 if (cfg.n_experts and not cfg.expert_tp) else 1


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------

def _apply_block(kind: str, p: Tree, x: jax.Array, cfg: ModelConfig,
                 positions: jax.Array, h0: jax.Array | None):
    """One layer. Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    rs = jnp.asarray(cfg.residual_scale, x.dtype)
    if kind in ("dense", "moe", "shared_attn"):
        if kind == "shared_attn":
            xin = jnp.concatenate([x, h0], axis=-1)
            xin = jnp.einsum("bse,ed->bsd", xin, p["win"])
        else:
            xin = x
        h = blocks.apply_norm(p["ln1"], xin, cfg)
        h = blocks.attention(p["attn"], h, cfg, positions=positions)
        x = x + rs * h
        h = blocks.apply_norm(p["ln2"], x, cfg)
        if kind == "moe":
            h, aux = moe.apply_moe(p["moe"], h, cfg)
        else:
            h = blocks.apply_mlp(p["mlp"], h, cfg)
        x = x + rs * h
    elif kind == "mamba":
        h = blocks.apply_norm(p["ln1"], x, cfg)
        x = x + rs * mamba2.mamba_forward(p["mamba"], h, cfg)
    elif kind == "mlstm":
        h = blocks.apply_norm(p["ln1"], x, cfg)
        x = x + rs * xlstm.mlstm_forward(p["mlstm"], h, cfg)
    elif kind == "slstm":
        h = blocks.apply_norm(p["ln1"], x, cfg)
        x = x + rs * xlstm.slstm_forward(p["slstm"], h, cfg)
    else:
        raise ValueError(kind)
    return x, aux


def _stage_scan(kind: str, stage_params: Tree, x: jax.Array, cfg: ModelConfig,
                positions: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Scan one homogeneous stage over its stacked layers."""

    def body(carry, lp):
        h, aux = carry
        h = shard(h, "batch", None, None)
        h, a = _apply_block(kind, lp, h, cfg, positions, None)
        return (h, aux + a), None

    if cfg.remat:
        body = jax.checkpoint(body)
    carry = (x, jnp.zeros((), jnp.float32))
    if cfg.unroll:
        count = jax.tree_util.tree_leaves(stage_params)[0].shape[0]
        for i in range(count):
            carry, _ = body(carry, jax.tree.map(lambda a: a[i], stage_params))
        return carry
    (x, aux), _ = jax.lax.scan(body, carry, stage_params)
    return x, aux


def embed_tokens(params: Tree, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = params["embed"][tokens] * jnp.asarray(cfg.embed_scale, cfg.adtype)
    return shard(x, "batch", None, None)


def unembed(params: Tree, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = blocks.apply_norm(params["final_norm"], x, cfg)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head) * jnp.asarray(
        cfg.logit_scale, x.dtype
    )
    logits = logits.astype(jnp.float32)
    if cfg.logit_softcap:
        cap = cfg.logit_softcap
        logits = cap * jnp.tanh(logits / cap)
    return shard(logits, "batch", None, "vocab")


def forward(params: Tree, tokens: jax.Array, cfg: ModelConfig,
            prefix_embeds: jax.Array | None = None
            ) -> tuple[jax.Array, jax.Array]:
    """Returns (logits, aux_loss).  prefix_embeds: (B, P, d) (vlm stub)."""
    x = embed_tokens(params, tokens, cfg)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    h0 = x
    aux_total = jnp.zeros((), jnp.float32)
    for i, (kind, count) in enumerate(cfg.stages()):
        if kind == "shared_attn":
            fn = functools.partial(_apply_block, kind, cfg=cfg,
                                   positions=positions)
            if cfg.remat:
                fn = jax.checkpoint(
                    lambda pp, hh, hh0: _apply_block(
                        "shared_attn", pp, hh, cfg, positions, hh0
                    )
                )
                x, aux = fn(params["shared_attn"], x, h0)
            else:
                x, aux = _apply_block(kind, params["shared_attn"], x, cfg,
                                      positions, h0)
        else:
            x, aux = _stage_scan(kind, params[stage_name(i, kind)], x, cfg,
                                 positions)
        aux_total = aux_total + aux
    logits = unembed(params, x, cfg)
    if prefix_embeds is not None:
        logits = logits[:, prefix_embeds.shape[1]:]
    return logits, aux_total


def _xent_ref(logits: jax.Array, labels: jax.Array, logical_v: int
              ) -> jax.Array:
    """Mean NLL over rows, the jnp math the xent kernel fuses (padded vocab
    columns masked with an elementwise iota, label logit extracted by a
    fused iota==label reduction)."""
    lf = logits.astype(jnp.float32)
    viota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    if logical_v < lf.shape[-1]:
        lf = lf + jnp.where(viota >= logical_v, -1e30, 0.0)
    m = jnp.max(lf, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    label_logit = jnp.sum(
        jnp.where(viota == labels[..., None], lf, 0.0), axis=-1
    )
    return (lse - label_logit).mean()


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _xent_fused(logits: jax.Array, labels: jax.Array,
                logical_v: int) -> jax.Array:
    """Cross-entropy via the registry kernel (tiled online softmax), with
    a hand-written vjp for the backward pass -- Pallas bodies define no
    autodiff rule.  ``kernels.xent.ops.xent_grad`` keeps the backward
    vocab-parallel under an SPMD mesh (softmax - onehot against the
    psum-combined lse, same Megatron layout as the forward) and is the
    plain jnp vjp otherwise."""
    from repro.api import dispatch

    return dispatch.launch("xent", logits, labels, logical_v=logical_v)


def _xent_fused_fwd(logits, labels, logical_v):
    from repro.api import dispatch

    out = dispatch.launch("xent", logits, labels, logical_v=logical_v)
    return out, (logits, labels)


def _xent_fused_bwd(logical_v, res, g):
    from repro.kernels.xent import ops as xent_ops

    logits, labels = res
    d_logits = xent_ops.xent_grad(logits, labels, g, logical_v=logical_v)
    return d_logits, np.zeros(labels.shape, jax.dtypes.float0)


_xent_fused.defvjp(_xent_fused_fwd, _xent_fused_bwd)


def lm_loss(logits: jax.Array, labels: jax.Array, cfg: ModelConfig,
            mask: jax.Array | None = None) -> jax.Array:
    """Vocab-parallel mean CE.

    The vocab axis stays sharded end to end (Megatron-style): padding rows
    are suppressed with an elementwise iota mask (never an ``at[].set`` on
    the gathered array), the label logit is extracted with a fused
    iota==label reduction (never take_along_axis over a sharded axis), and
    only (B, S) statistics cross shards.  Materializing full per-device
    logits for a 152k vocab would cost ~40 GB/device -- this is the layout
    policy applied to the loss.

    The unmasked case launches the registered ``xent`` Pallas kernel
    through ``repro.api`` (tiled online softmax under the ambient plan
    policy; ``Trainer.plan_hot_kernels`` pins its plan) -- on one device
    directly, and on a multi-device program whenever the ambient context
    carries a real Mesh: ``api.launch`` then shard_maps the kernel with
    tokens split over the batch mesh axes AND the vocab axis split over
    the model axis (``kernels.xent.ops._spmd_xent``): each shard folds its
    own vocab slice at a locally planned block shape, the per-shard
    (max, sumexp, label-logit) partials combine with a cross-shard
    log-sum-exp (pmax/psum), and a ``pmean`` combines the equal-sized
    token-shard means.  The backward (``xent_grad``) keeps the same
    layout, so the fused SPMD path *is* the Megatron vocab-parallel loss
    -- a non-divisible vocab falls back to whole-vocab shards with a
    logged reason.  The masked case (and a meshless multi-device program)
    keeps the jnp path -- a masked mean cannot be recovered from the
    kernel's all-token mean (see ``blocks.use_fused_kernels``).
    """
    v = logits.shape[-1]
    logical = getattr(cfg, "vocab_logical", 0) or cfg.vocab_size
    if mask is None and blocks.use_fused_kernels():
        return _xent_fused(logits.reshape(-1, v),
                           labels.reshape(-1).astype(jnp.int32), logical)
    lf = logits.astype(jnp.float32)
    viota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    if logical < v:
        lf = lf + jnp.where(viota >= logical, -1e30, 0.0)
    m = jnp.max(lf, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    label_logit = jnp.sum(
        jnp.where(viota == labels[..., None], lf, 0.0), axis=-1
    )
    ll = label_logit - lse
    if mask is None:
        return -ll.mean()
    mask = mask.astype(jnp.float32)
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# Decode (serve_step)
# ---------------------------------------------------------------------------

def cache_defs(cfg: ModelConfig, batch: int, max_len: int) -> Tree:
    """Cache tree matching cfg.stages(); plus per-slot write indices
    (continuous batching: each request sits at its own depth)."""
    tree: Tree = {"idx": ParamDef((batch,), ("batch",), init="zeros",
                                  dtype=jnp.int32)}
    for i, (kind, count) in enumerate(cfg.stages()):
        nm = stage_name(i, kind)
        if kind in ("dense", "moe", "shared_attn"):
            tree[nm] = blocks.init_kv_cache(cfg, batch, max_len, count)
        elif kind == "mamba":
            tree[nm] = mamba2.mamba_cache_defs(cfg, batch, count)
        elif kind == "mlstm":
            tree[nm] = xlstm.mlstm_cache_defs(cfg, batch, count)
        elif kind == "slstm":
            tree[nm] = xlstm.slstm_cache_defs(cfg, batch, count)
    return tree


def paged_cache_defs(cfg: ModelConfig, batch: int, max_len: int,
                     n_pages: int, page_len: int) -> Tree:
    """Paged serving cache (serving.paged_cache): attention stages share a
    physical page pool; per-slot state is the page table plus the O(1) SSM
    states.  Extra leaves vs :func:`cache_defs`:

      * ``pages`` -- (batch, max_pages) int32 page table, 0 = null page;
      * ``act``   -- (batch,) int32 row-active mask consumed by the paged
        cache write (inactive rows scatter into the null page), the lever
        the chunked-prefill step uses to freeze rows mid-chunk.
    """
    max_pages = -(-max_len // page_len)
    tree: Tree = {
        "idx": ParamDef((batch,), ("batch",), init="zeros", dtype=jnp.int32),
        "act": ParamDef((batch,), ("batch",), init="ones", dtype=jnp.int32),
        "pages": ParamDef((batch, max_pages), ("batch", None), init="zeros",
                          dtype=jnp.int32),
    }
    for i, (kind, count) in enumerate(cfg.stages()):
        nm = stage_name(i, kind)
        if kind in ("dense", "moe", "shared_attn"):
            tree[nm] = blocks.paged_kv_pool_defs(cfg, n_pages, page_len, count)
        elif kind == "mamba":
            tree[nm] = mamba2.mamba_cache_defs(cfg, batch, count)
        elif kind == "mlstm":
            tree[nm] = xlstm.mlstm_cache_defs(cfg, batch, count)
        elif kind == "slstm":
            tree[nm] = xlstm.slstm_cache_defs(cfg, batch, count)
    return tree


def _decode_block(kind: str, p: Tree, cache: Tree, x: jax.Array, idx: jax.Array,
                  cfg: ModelConfig, h0: jax.Array | None,
                  pages: jax.Array | None = None,
                  act: jax.Array | None = None):
    rs = jnp.asarray(cfg.residual_scale, x.dtype)
    if kind in ("dense", "moe", "shared_attn"):
        if kind == "shared_attn":
            xin = jnp.concatenate([x, h0], axis=-1)
            xin = jnp.einsum("bse,ed->bsd", xin, p["win"])
        else:
            xin = x
        h = blocks.apply_norm(p["ln1"], xin, cfg)
        if pages is not None:
            h, ck, cv = blocks.paged_decode_attention(
                p["attn"], h, cache["k"], cache["v"], pages, idx, act, cfg
            )
        else:
            h, ck, cv = blocks.decode_attention(
                p["attn"], h, cache["k"], cache["v"], idx, cfg
            )
        x = x + rs * h
        h = blocks.apply_norm(p["ln2"], x, cfg)
        if kind == "moe":
            h, _ = moe.apply_moe(p["moe"], h, cfg)
        else:
            h = blocks.apply_mlp(p["mlp"], h, cfg)
        x = x + rs * h
        return x, {"k": ck, "v": cv}
    if kind == "mamba":
        h = blocks.apply_norm(p["ln1"], x, cfg)
        h, nc = mamba2.mamba_decode_step(p["mamba"], cache, h, cfg)
        return x + rs * h, nc
    if kind == "mlstm":
        h = blocks.apply_norm(p["ln1"], x, cfg)
        h, nc = xlstm.mlstm_decode_step(p["mlstm"], cache, h, cfg)
        return x + rs * h, nc
    if kind == "slstm":
        h = blocks.apply_norm(p["ln1"], x, cfg)
        h, nc = xlstm.slstm_decode_step(p["slstm"], cache, h, cfg)
        return x + rs * h, nc
    raise ValueError(kind)


def decode_step(params: Tree, cache: Tree, tokens: jax.Array, cfg: ModelConfig
                ) -> tuple[jax.Array, Tree]:
    """One-token decode. tokens: (B, 1). Returns (logits, new_cache).

    A cache built by :func:`paged_cache_defs` (a ``pages`` leaf present)
    routes attention stages through the page table: writes scatter into the
    shared pool (``act`` masks frozen rows into the null page) and the KV
    view is gathered per row.  SSM/mLSTM state stages are identical on both
    paths."""
    idx = cache["idx"]
    pages = cache.get("pages")
    act = cache.get("act")
    x = embed_tokens(params, tokens, cfg)
    h0 = x
    new_cache: Tree = {"idx": idx + 1}
    if pages is not None:
        new_cache["pages"] = pages
        new_cache["act"] = act
    for i, (kind, count) in enumerate(cfg.stages()):
        nm = stage_name(i, kind)
        if kind == "shared_attn":
            # single-layer stage: strip the stacked axis of its cache
            c1 = jax.tree.map(lambda a: a[0], cache[nm])
            x, nc = _decode_block(kind, params["shared_attn"], c1, x, idx,
                                  cfg, h0, pages, act)
            new_cache[nm] = jax.tree.map(lambda a: a[None], nc)
        else:
            def body(carry, inp):
                lp, lc = inp
                h = carry
                h, nc = _decode_block(kind, lp, lc, h, idx, cfg, None,
                                      pages, act)
                return h, nc

            if cfg.unroll:
                n = jax.tree_util.tree_leaves(cache[nm])[0].shape[0]
                ncs = []
                for l in range(n):
                    x, nc_l = body(
                        x,
                        (jax.tree.map(lambda a: a[l], params[nm]),
                         jax.tree.map(lambda a: a[l], cache[nm])),
                    )
                    ncs.append(nc_l)
                nc = jax.tree.map(lambda *a: jnp.stack(a), *ncs)
            else:
                x, nc = jax.lax.scan(body, x, (params[nm], cache[nm]))
            new_cache[nm] = nc
    logits = unembed(params, x, cfg)
    return logits, new_cache


# ---------------------------------------------------------------------------
# Facade
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LM:
    cfg: ModelConfig

    def param_defs(self) -> Tree:
        return param_defs(self.cfg)

    def init(self, key: jax.Array) -> Tree:
        return init(self.cfg, key)

    def abstract_params(self) -> Tree:
        return abstract_params(self.param_defs())

    def param_axes(self) -> Tree:
        return logical_axes(self.param_defs())

    def forward(self, params, tokens, prefix_embeds=None):
        return forward(params, tokens, self.cfg, prefix_embeds)

    def loss(self, params, batch) -> jax.Array:
        logits, aux = forward(
            params, batch["tokens"], self.cfg, batch.get("img_embeds")
        )
        return lm_loss(logits, batch["labels"], self.cfg, batch.get("mask")) + aux

    def cache_defs(self, batch: int, max_len: int) -> Tree:
        return cache_defs(self.cfg, batch, max_len)

    def paged_cache_defs(self, batch: int, max_len: int, n_pages: int,
                         page_len: int) -> Tree:
        return paged_cache_defs(self.cfg, batch, max_len, n_pages, page_len)

    def decode_step(self, params, cache, tokens):
        return decode_step(params, cache, tokens, self.cfg)
