"""Core: the paper's contribution (analytic data-layout optimization),
generalized for TPU. See DESIGN.md SS2-3."""
from repro.core.aliasing import InterleavedMemoryModel, Stream, analytic_skews
from repro.core.autotune import LayoutPlan, StreamSignature, plan_streams
from repro.core.layout import LANES, SUBLANES, LayoutPolicy, PaddedDim, round_up
from repro.core.planner import (
    KernelPlan,
    clear_plan_cache,
    explain,
    plan_cache_info,
    plan_cache_keys,
    plan_kernel,
    register_family,
    sublanes_for_dtype,
)
from repro.core.segmented import SegmentedArray, seg_map, seg_triad

__all__ = [
    "InterleavedMemoryModel", "Stream", "analytic_skews",
    "LayoutPlan", "StreamSignature", "plan_streams",
    "LANES", "SUBLANES", "LayoutPolicy", "PaddedDim", "round_up",
    "KernelPlan", "plan_kernel", "plan_cache_info", "plan_cache_keys",
    "clear_plan_cache", "explain", "register_family", "sublanes_for_dtype",
    "SegmentedArray", "seg_map", "seg_triad",
]
