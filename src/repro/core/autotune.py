"""Analytic layout autotuner -- the paper's "no trial and error" claim.

Given a kernel's *stream signature* (how many read/write streams, their
element size and lengths) and a memory model (the address->channel map), the
tuner derives alignment, per-stream offsets and per-segment shifts in closed
form, then verifies them against the model.  This mirrors the paper's SS2.3:

    "Note that these parameters are the same for all problem sizes and can be
     obtained by analyzing the data access properties of the loop kernel,
     together with some knowledge about the mapping between addresses and
     memory controllers.  No trial and error is required."
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core import aliasing
from repro.core.aliasing import InterleavedMemoryModel, Stream


@dataclasses.dataclass(frozen=True)
class StreamSignature:
    """Data-access properties of a loop kernel."""

    n_read: int
    n_write: int
    elem_bytes: int = 8

    @property
    def n_streams(self) -> int:
        return self.n_read + self.n_write

    @property
    def balance_bytes_per_flop(self) -> float | None:
        return None  # kernels attach their own flop counts


@dataclasses.dataclass(frozen=True)
class LayoutPlan:
    """The tuner's output: how to lay the kernel's arrays out."""

    align_bytes: int            # align every array/segment base to this
    offsets_bytes: tuple[int, ...]   # per-stream additional offset (skew)
    segment_shift_bytes: int    # extra shift between consecutive segments
    predicted_balance: float    # model-predicted channel balance in (0,1]

    def offset_elems(self, elem_bytes: int) -> tuple[int, ...]:
        return tuple(o // elem_bytes for o in self.offsets_bytes)


def plan_streams(
    sig: StreamSignature,
    model: InterleavedMemoryModel | None = None,
    *,
    n_threads: int = 1,
    chunk_bytes: int | None = None,
) -> LayoutPlan:
    """Closed-form plan: align to the interleave period, skew stream k by
    k * channel-step, shift consecutive segments by one channel step.

    For >= n_channels streams this provably reaches balance 1.0 under the
    model (each channel gets streams k = c, c+n, ...); for fewer streams the
    *segment* shift takes over (the paper's Jacobi case: only 2 effective
    streams, so rows are shifted 128 B against each other).
    """
    model = model or InterleavedMemoryModel()
    step = 1 << model.channel_shift
    offsets = tuple(k * step for k in range(sig.n_streams))
    plan = LayoutPlan(
        align_bytes=model.period_bytes,
        offsets_bytes=offsets,
        segment_shift_bytes=step,
        predicted_balance=_score(offsets, sig, model, n_threads, chunk_bytes),
    )
    return plan


def _score(
    offsets: Sequence[int],
    sig: StreamSignature,
    model: InterleavedMemoryModel,
    n_threads: int,
    chunk_bytes: int | None,
) -> float:
    streams = [
        Stream(base=o, kind=("write" if k < sig.n_write else "read"))
        for k, o in enumerate(offsets)
    ]
    kw = {"n_threads": n_threads}
    if chunk_bytes is not None:
        kw["chunk_bytes"] = chunk_bytes
    return model.balance(streams, **kw)


def verify_plan_optimal(
    sig: StreamSignature,
    model: InterleavedMemoryModel | None = None,
) -> tuple[LayoutPlan, float]:
    """Check the analytic plan against exhaustive search over one period.

    Returns (plan, exhaustive_best_balance).  Tests assert
    ``plan.predicted_balance >= exhaustive_best - eps`` -- i.e. the paper's
    analytic offsets are as good as anything brute force finds.
    """
    model = model or InterleavedMemoryModel()
    plan = plan_streams(sig, model)
    _, best = aliasing.exhaustive_best_skews(
        model, sig.n_streams, write_idx=0
    )
    return plan, best


def choose_layout(
    candidates: dict[str, tuple[Sequence[int], Sequence[bool]]],
    model: InterleavedMemoryModel | None = None,
    **kw,
) -> tuple[str, dict[str, float]]:
    """Pick the best data layout by model balance (paper SS2.4, LBM).

    ``candidates[name] = (stream_base_addresses, write_mask)``.  Returns the
    argmax name and all scores, e.g. IvJK vs IJKv for D3Q19.
    """
    model = model or InterleavedMemoryModel()
    scores = {
        name: aliasing.layout_balance(model, bases, mask, **kw)
        for name, (bases, mask) in candidates.items()
    }
    best = max(scores, key=scores.__getitem__)
    return best, scores
