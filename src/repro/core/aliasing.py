"""Interleaved-memory conflict model (the paper's address->controller map).

The UltraSPARC T2 maps a physical address to one of four memory controllers
via bits 8:7 (and to one of two L2 banks per controller via bit 6), so
consecutive 64 B cache lines round-robin through the banks/controllers with a
512 B period.  The paper's whole diagnosis -- period-64 (DP words) bandwidth
collapse, 2x recovery at odd multiples of 32, full recovery under analytic
skew -- follows from this map.

``InterleavedMemoryModel`` keeps that map verbatim (default: 4 channels,
shift 7, 64 B lines) and generalizes it (n_channels, shift) so the same class
models any power-of-two interleaved resource: HBM channel hashing, VMEM
banks, or ICI links round-robined by shard index.  It is used three ways:

  1. ``benchmarks/``: reproduce Figs. 2/4/6/7 analytically (bandwidth vs
     offset / N / layout) and validate the paper's claims in tests,
  2. ``core/autotune.py``: derive optimal skews *analytically* ("no trial and
     error" -- the paper's headline remedy),
  3. as a documentation artifact for the TPU port: the same balance metric is
     applied to shard->link maps in the distribution layer.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Iterable, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Stream:
    """One load or store stream of a kernel."""

    base: int                 # byte address of first element touched
    kind: str = "read"        # "read" | "write"
    stride: int = 0           # extra bytes to skip per line (0 = contiguous)

    def __post_init__(self):
        if self.kind not in ("read", "write"):
            raise ValueError(f"kind must be read|write, got {self.kind!r}")


@dataclasses.dataclass(frozen=True)
class InterleavedMemoryModel:
    """Address-interleaved multi-channel memory.

    channel(addr) = (addr >> channel_shift) % n_channels  -- T2: bits 8:7.
    The interleave *period* is ``n_channels << channel_shift`` bytes (512 B on
    T2 = 64 DP words, the paper's observed offset periodicity).
    """

    n_channels: int = 4
    channel_shift: int = 7
    line_bytes: int = 64
    peak_bw: float = 16.0       # balanced-envelope bandwidth, GB/s (Fig. 4 top)
    rfo: bool = True            # write streams read-for-ownership first

    # L2 banks: the paper's second interleave level ("bit 6 determines the
    # L2 bank" -- two banks per controller on T2).
    banks_per_channel: int = 2
    bank_shift: int = 6

    @property
    def period_bytes(self) -> int:
        return self.n_channels << self.channel_shift

    @property
    def bank_period_bytes(self) -> int:
        """Full channel x bank rotation period (512 B on T2 either way,
        since banks interleave below the channel bits)."""
        return max(self.period_bytes,
                   self.n_channels * self.banks_per_channel << self.bank_shift)

    def channel(self, addr: int) -> int:
        return (addr >> self.channel_shift) % self.n_channels

    def bank(self, addr: int) -> int:
        """Global bank id: (channel, bank-within-channel)."""
        return self.channel(addr) * self.banks_per_channel + (
            (addr >> self.bank_shift) % self.banks_per_channel
        )

    def bank_balance(self, streams: Sequence[Stream], **kw) -> float:
        """Same lockstep metric at bank granularity (2x the resources, so a
        single contiguous stream sustains at most 1 / (channels*banks))."""
        n_banks = self.n_channels * self.banks_per_channel
        n_ticks = kw.pop("n_ticks", None) or max(
            1, self.bank_period_bytes // self.line_bytes
        )
        chunk = kw.pop("chunk_bytes", None) or n_ticks * self.line_bytes
        n_threads = kw.pop("n_threads", 1)
        counts = np.zeros((n_ticks, n_banks), dtype=np.int64)
        for s in streams:
            weight = 2 if (s.kind == "write" and self.rfo) else 1
            step = self.line_bytes + s.stride
            for t in range(n_threads):
                start = s.base + t * chunk
                for i in range(n_ticks):
                    counts[i, self.bank(start + i * step)] += weight
        total = counts.sum()
        if total == 0:
            return 1.0
        return float(total / n_banks / counts.max(axis=1).sum())

    # ------------------------------------------------------------------
    def tick_histograms(
        self,
        streams: Sequence[Stream],
        *,
        n_threads: int = 1,
        chunk_bytes: int | None = None,
        n_ticks: int | None = None,
    ) -> np.ndarray:
        """Per-tick channel request counts, shape (n_ticks, n_channels).

        The T2 execution model is *lockstep*: an in-order thread has a single
        outstanding miss, so at tick i every (thread, stream) pair requests
        line i of its own range -- base + t * chunk_bytes + i * line step
        (static OpenMP split / per-device shard).  Writes count double under
        RFO (the line is read for ownership, then written back).  The window
        defaults to one interleave period, which is exact for contiguous
        streams (the pattern repeats with period_bytes / line_bytes ticks).
        """
        if n_ticks is None:
            n_ticks = max(1, self.period_bytes // self.line_bytes)
        if chunk_bytes is None:
            chunk_bytes = n_ticks * self.line_bytes
        counts = np.zeros((n_ticks, self.n_channels), dtype=np.int64)
        for s in streams:
            weight = 2 if (s.kind == "write" and self.rfo) else 1
            step = self.line_bytes + s.stride
            for t in range(n_threads):
                start = s.base + t * chunk_bytes
                for i in range(n_ticks):
                    counts[i, self.channel(start + i * step)] += weight
        return counts

    def balance(self, streams: Sequence[Stream], **kw) -> float:
        """Fraction of peak bandwidth the channel system can sustain.

        At each lockstep tick the channels drain their queues in parallel, so
        the tick costs ``max_c requests_c(i)`` channel cycles; a perfectly
        balanced system would spend ``total(i) / n_channels``.  The sustained
        fraction over the window is

            sum_i total(i) / n_channels  /  sum_i max_c requests_c(i)

        which is 1/n_channels when every stream aliases onto one controller
        (the paper's zero-offset collapse) and 1.0 under full skew.
        """
        ticks = self.tick_histograms(streams, **kw)
        total = ticks.sum()
        if total == 0:
            return 1.0
        serial = ticks.max(axis=1).sum()
        return float(total / self.n_channels / serial)

    def mean_channels_hit(self, streams: Sequence[Stream], **kw) -> float:
        """Average number of distinct controllers addressed per tick -- the
        paper's own back-of-envelope metric ("two controllers are addressed,
        leading to an expected performance improvement of 100%")."""
        ticks = self.tick_histograms(streams, **kw)
        return float((ticks > 0).sum(axis=1).mean())

    def bandwidth(self, streams: Sequence[Stream], **kw) -> float:
        """Model bandwidth in GB/s: balance x balanced envelope."""
        return self.balance(streams, **kw) * self.peak_bw

    # ------------------------------------------------------------------
    def stream_triad_curve(
        self,
        *,
        n_elements: int,
        elem_bytes: int = 8,
        offsets: Iterable[int],
        n_threads: int = 64,
        n_arrays: int = 3,
        write_idx: int = 0,
    ) -> dict[int, float]:
        """Paper Fig. 2 generator: bandwidth vs COMMON-block offset.

        Arrays are laid out back to back (Fortran COMMON): array k starts at
        k * (n_elements + offset) * elem_bytes.  ``write_idx`` marks the
        store stream (A for triad, C for copy ... the caller decides).
        """
        out: dict[int, float] = {}
        for off in offsets:
            ndim = (n_elements + off) * elem_bytes
            streams = [
                Stream(base=k * ndim, kind=("write" if k == write_idx else "read"))
                for k in range(n_arrays)
            ]
            chunk = (n_elements // max(n_threads, 1)) * elem_bytes
            out[off] = self.bandwidth(streams, n_threads=n_threads, chunk_bytes=chunk)
        return out


# ---- analytic skew derivation (the "no trial and error" claim) ------------

def analytic_skews(model: InterleavedMemoryModel, n_streams: int) -> list[int]:
    """Offsets that place stream k on channel (c0 + k) mod n_channels.

    On T2 this yields 0, 128, 256, 384 B for the four vector-triad streams --
    exactly the paper's optimal offsets -- because one channel step is
    ``1 << channel_shift`` bytes.
    """
    step = 1 << model.channel_shift
    return [k * step for k in range(n_streams)]


def exhaustive_best_skews(
    model: InterleavedMemoryModel,
    n_streams: int,
    *,
    write_idx: int = 0,
    granularity: int | None = None,
) -> tuple[list[int], float]:
    """Brute-force the best per-stream offsets over one interleave period.

    Exists to *verify* ``analytic_skews`` in tests (the paper's point is that
    the analytic answer matches the exhaustive one).  Stream 0 is pinned at
    offset 0; the rest scan the period at line granularity.
    """
    gran = granularity or model.line_bytes
    period = model.period_bytes
    choices = range(0, period, gran)
    best: tuple[list[int], float] = ([0] * n_streams, -1.0)
    for combo in itertools.product(choices, repeat=n_streams - 1):
        offs = [0, *combo]
        streams = [
            Stream(base=o, kind=("write" if k == write_idx else "read"))
            for k, o in enumerate(offs)
        ]
        b = model.balance(streams, chunk_bytes=period)
        if b > best[1]:
            best = (offs, b)
    return best


def layout_balance(
    model: InterleavedMemoryModel,
    stream_bases: Sequence[int],
    write_mask: Sequence[bool],
    **kw,
) -> float:
    """Balance score for an arbitrary set of stream base addresses -- used to
    compare data layouts (e.g. LBM IJKv vs IvJK) where the layout, not an
    explicit pad, determines the bases."""
    streams = [
        Stream(base=b, kind=("write" if w else "read"))
        for b, w in zip(stream_bases, write_mask)
    ]
    return model.balance(streams, **kw)
