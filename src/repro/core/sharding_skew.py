"""Skewed placement at the distribution layer.

The paper's shift=128 rule -- consecutive segments start one channel-step
apart so concurrent accesses spread over all controllers -- has a direct
analogue one level up: when the *same* logical resource index is mapped to
the *same* device in every layer, persistent hot spots serialize on one
device chain.  Canonical case: MoE expert parallelism.  Routers are biased
toward low-index experts early in training; with the naive map
``expert e -> device e % D`` every layer's hot expert lands on device 0 and
the all-to-all into it becomes the single-controller bottleneck of Fig. 2.

``skewed_expert_map`` rotates the expert->device assignment by one device per
layer (the paper's one-channel-step shift), so layer l's expert e sits on
device (e + l) % D.  The rotation is a static permutation folded into the
dispatch one-hot -- zero runtime cost, exactly like the paper's padding.

The same helper skews KV-cache sequence shards and data-parallel batch
rotation for straggler smoothing.
"""
from __future__ import annotations

import numpy as np


def skewed_expert_map(n_experts: int, n_devices: int, layer: int) -> np.ndarray:
    """expert -> device map for one layer, rotated by ``layer`` steps."""
    if n_experts <= 0 or n_devices <= 0:
        raise ValueError("n_experts and n_devices must be positive")
    return (np.arange(n_experts) + layer) % n_devices


def expert_permutation(n_experts: int, n_devices: int, layer: int) -> np.ndarray:
    """Permutation of expert indices so that contiguous blocks of the
    permuted axis shard onto the rotated device map.

    Experts are stored as one stacked (E, ...) tensor sharded E/D per device;
    permuting the expert axis by this permutation makes device d hold exactly
    the experts whose skewed map is d.  The permutation is its own static
    metadata: apply it to router logits at dispatch, and its inverse when
    publishing per-expert stats.
    """
    dev = skewed_expert_map(n_experts, n_devices, layer)
    # stable sort by device, preserving expert order within a device
    return np.argsort(dev, kind="stable")


def inverse_permutation(perm: np.ndarray) -> np.ndarray:
    inv = np.empty_like(perm)
    inv[perm] = np.arange(perm.size)
    return inv


def placement_imbalance(load_per_expert: np.ndarray, expert_to_device: np.ndarray,
                        n_devices: int) -> float:
    """Max-over-mean device load -- the controller-histogram metric of
    ``core.aliasing`` applied to expert placement.  1.0 = perfectly balanced.
    """
    loads = np.zeros(n_devices, dtype=np.float64)
    np.add.at(loads, expert_to_device, load_per_expert)
    mean = loads.mean()
    return float(loads.max() / mean) if mean > 0 else 1.0


def layer_skew_gain(load_per_expert: np.ndarray, n_devices: int,
                    n_layers: int) -> tuple[float, float]:
    """Aggregate (naive, skewed) cross-layer worst-device load for a fixed
    per-expert load profile repeated over layers.

    Naive placement accumulates the same hot device every layer; skewed
    placement rotates it.  Returns max-over-mean for both schemes -- the
    EXPERIMENTS.md MoE table reports the ratio.
    """
    E = load_per_expert.size
    naive = np.zeros(n_devices)
    skew = np.zeros(n_devices)
    for l in range(n_layers):
        np.add.at(naive, skewed_expert_map(E, n_devices, 0), load_per_expert)
        np.add.at(skew, skewed_expert_map(E, n_devices, l), load_per_expert)
    return (
        float(naive.max() / naive.mean()),
        float(skew.max() / skew.mean()),
    )
