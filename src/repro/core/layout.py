"""Layout policy: tile- and mesh-aware padding, alignment math, waste accounting.

This is the TPU port of the paper's central remedy: *analytic* padding and
alignment derived from the hardware's address->resource map, not trial and
error.  On the UltraSPARC T2 the map was ``controller = phys_addr bits 8:7``
(512 B interleave period); on TPU the controllable analogues are

  * the (8, 128) sublane x lane VREG tile: trailing-two-dim shapes that are
    not multiples of (8, 128) are implicitly padded by XLA -- implicit pad is
    wasted bandwidth *and* wasted MXU occupancy,
  * the mesh: a dimension sharded N-ways that is not divisible by N forces
    GSPMD to materialize ragged shards (internally padded, with extra
    collective traffic),
  * VMEM blocks: Pallas BlockSpec shapes must tile the (padded) array.

``LayoutPolicy`` turns a *logical* model dimension into a *padded physical*
dimension and accounts for the waste so the roofline analysis can report the
"useful compute" ratio.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping

# TPU v5e hardware tiling constants (the "address map" of this machine).
LANES = 128          # minor-most dim of a VREG tile / MXU systolic edge
SUBLANES = 8         # second-minor dim of a VREG tile (fp32); bf16 packs 16
MXU_EDGE = 128       # MXU matmul tile edge
VMEM_BYTES = 128 * 1024 * 1024 // 8  # ~16 MiB usable VMEM per core (v5e)


def round_up(n: int, multiple: int) -> int:
    """Smallest m >= n with m % multiple == 0 (multiple >= 1)."""
    if multiple <= 0:
        raise ValueError(f"multiple must be positive, got {multiple}")
    if n < 0:
        raise ValueError(f"n must be non-negative, got {n}")
    return ((n + multiple - 1) // multiple) * multiple


def round_down(n: int, multiple: int) -> int:
    if multiple <= 0:
        raise ValueError(f"multiple must be positive, got {multiple}")
    return (n // multiple) * multiple


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


@dataclasses.dataclass(frozen=True)
class PaddedDim:
    """A logical dimension and the physical size the policy chose for it."""

    logical: int
    physical: int
    reason: str = ""

    @property
    def pad(self) -> int:
        return self.physical - self.logical

    @property
    def waste(self) -> float:
        """Fraction of the physical extent that is padding."""
        return self.pad / self.physical if self.physical else 0.0


@dataclasses.dataclass(frozen=True)
class LayoutPolicy:
    """Analytic padding policy for model dimensions.

    Parameters
    ----------
    lane_tile:
        minor-most hardware tile (128 on all current TPUs).
    sublane_tile:
        second-minor tile (8 for fp32; callers may pass 16 for bf16-major
        layouts).
    tp:
        tensor-parallel degree of the target mesh ("model" axis size).  A
        dimension sharded over the model axis must be divisible by ``tp`` and
        each *shard* must be lane-aligned, i.e. divisible by ``tp * lane_tile``
        when it is a minor dim.
    pad_to_mesh:
        if False, produce the *paper-naive* layout (logical sizes untouched)
        so the baseline/optimized comparison in EXPERIMENTS.md SSPerf has a
        faithful "plain malloc()" analogue.
    """

    lane_tile: int = LANES
    sublane_tile: int = SUBLANES
    tp: int = 1
    pad_to_mesh: bool = True

    # ---- dimension rules -------------------------------------------------
    def pad_minor(self, n: int, *, sharded: bool = False) -> PaddedDim:
        """Pad a minor (lane) dimension: multiples of 128, and of tp*128 when
        sharded over the model axis so every shard stays lane-aligned."""
        if not self.pad_to_mesh:
            return PaddedDim(n, n, "plain")
        m = self.lane_tile * (self.tp if sharded else 1)
        return PaddedDim(n, round_up(n, m), f"lane{'xTP' if sharded else ''}={m}")

    def pad_sublane(self, n: int, *, sharded: bool = False) -> PaddedDim:
        """Pad a second-minor (sublane) dimension."""
        if not self.pad_to_mesh:
            return PaddedDim(n, n, "plain")
        m = self.sublane_tile * (self.tp if sharded else 1)
        return PaddedDim(n, round_up(n, m), f"sublane{'xTP' if sharded else ''}={m}")

    def pad_count(self, n: int, *, sharded: bool = False) -> PaddedDim:
        """Pad a 'count' dimension (heads, experts): only mesh divisibility
        matters, there is no lane constraint (each unit is itself tiled)."""
        if not self.pad_to_mesh or not sharded or self.tp <= 1:
            return PaddedDim(n, n, "plain")
        return PaddedDim(n, round_up(n, self.tp), f"count%TP={self.tp}")

    def pad_vocab(self, n: int) -> PaddedDim:
        """Vocab is sharded minor-most over TP for the output projection."""
        return self.pad_minor(n, sharded=True)

    # ---- model-level convenience ----------------------------------------
    def plan(self, dims: Mapping[str, tuple[int, str]]) -> dict[str, PaddedDim]:
        """Plan a set of named dims.  ``dims[name] = (logical, kind)`` where
        kind in {minor, minor_sharded, sublane, count, count_sharded, vocab}.
        """
        out: dict[str, PaddedDim] = {}
        for name, (n, kind) in dims.items():
            if kind == "minor":
                out[name] = self.pad_minor(n)
            elif kind == "minor_sharded":
                out[name] = self.pad_minor(n, sharded=True)
            elif kind == "sublane":
                out[name] = self.pad_sublane(n)
            elif kind == "count":
                out[name] = self.pad_count(n)
            elif kind == "count_sharded":
                out[name] = self.pad_count(n, sharded=True)
            elif kind == "vocab":
                out[name] = self.pad_vocab(n)
            else:
                raise ValueError(f"unknown dim kind {kind!r} for {name!r}")
        return out

    @staticmethod
    def total_waste(plan: Mapping[str, PaddedDim]) -> float:
        """Aggregate padding fraction over a plan (unweighted mean)."""
        if not plan:
            return 0.0
        return sum(d.waste for d in plan.values()) / len(plan)


# ---- Pallas block-shape chooser ------------------------------------------

def choose_block_shape(
    rows: int,
    cols: int,
    *,
    bytes_per_el: int = 4,
    n_buffers: int = 3,
    vmem_budget: int = VMEM_BYTES,
    max_block_rows: int = 1024,
    max_block_cols: int = 4096,
    sublane_tile: int = SUBLANES,
) -> tuple[int, int]:
    """Pick an (rows, cols) VMEM block for a streaming 2-D kernel.

    The paper's rule "align each segment to the controller period" becomes:
    the block minor dim is a multiple of 128 lanes (full lines per DMA), the
    block major dim a multiple of ``sublane_tile`` sublanes (8 for fp32, 16
    for 2-byte dtypes, 32 for fp8), and ``n_buffers`` blocks (double-buffered
    in/out streams) must fit the VMEM budget.  Kernels that stream full-width
    row blocks pass ``max_block_cols=cols`` so the row budget is charged
    against the columns they actually keep resident.
    """
    bcols = round_up(min(cols, max_block_cols), LANES)
    # rows: as many sublane-multiples as fit the budget
    per_row = bcols * bytes_per_el * n_buffers
    brows = max(sublane_tile, round_down(
        min(vmem_budget // max(per_row, 1), max_block_rows, rows),
        sublane_tile,
    ))
    brows = max(brows, min(rows, sublane_tile))
    return int(brows), int(bcols)
