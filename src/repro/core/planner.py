"""Layout planner: close the loop from stream analysis to Pallas execution.

The paper's headline claim (SS2.3) is that optimal padding/skew parameters
"can be obtained by analyzing the data access properties of the loop kernel,
together with some knowledge about the mapping between addresses and memory
controllers.  No trial and error is required."  This module is that claim
made executable for the TPU port: each kernel family declares its
``StreamSignature`` (how many read/write streams of what element size), and
the planner derives -- in closed form, no search --

  * the padded *physical* shape (lane/sublane tileable, optionally widened
    for a tensor-parallel mesh axis),
  * the Pallas block shape (``choose_block_shape``: whole-line DMAs that fit
    the VMEM budget with one buffer per resident stream),
  * the per-stream skews and segment shift (``plan_streams``), scored under
    the interleaved-memory conflict model.

``predicted_balance`` evaluates the *whole* plan: stream k skewed by
k x channel-step AND concurrent segments shifted by one channel step, which
is what guarantees full channel coverage for any stream count (the paper's
Jacobi case: 2 streams alone cover only 2 of 4 controllers; the segment
shift supplies the rest).  ``naive_balance`` scores the same streams with no
skew and period-aliased segments -- the paper's 4x collapse -- so
``explain()`` reports the analytically-predicted gain.

Plans are memoized in a process-level cache keyed on
``(kernel, shape, dtype, mesh, model)`` so repeated wrapper calls (and
re-traces under jit) reuse the same ``KernelPlan`` object.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Mapping

import numpy as np

from repro.core.aliasing import InterleavedMemoryModel, Stream
from repro.core.autotune import LayoutPlan, StreamSignature, plan_streams
from repro.core.layout import (
    LANES,
    SUBLANES,
    VMEM_BYTES,
    cdiv,
    choose_block_shape,
    round_down,
    round_up,
)

# Widest 1-D reshape width the planner will choose: long enough that every
# DMA moves whole VREG tiles with low per-transfer overhead, small enough
# that n_streams blocks of any planned kernel fit VMEM comfortably.
MAX_WIDTH = 4096

# Sublane tile height per element size: fp32 packs (8, 128) VREG tiles,
# 2-byte dtypes (bf16/fp16) pack (16, 128), fp8/int8 pack (32, 128).  Using
# the dtype's native tile keeps the physical footprint equal to what XLA
# would materialize anyway -- and at 2 (or 1) bytes per element the padding
# the plan *pays* shrinks accordingly.
SUBLANES_BY_ITEMSIZE: dict[int, int] = {1: 32, 2: 16}


def sublanes_for_dtype(dtype) -> int:
    """Native sublane tile height for ``dtype`` (8 fp32 / 16 bf16 / 32 fp8)."""
    return SUBLANES_BY_ITEMSIZE.get(np.dtype(dtype).itemsize, SUBLANES)


# The paper's per-kernel "data access properties" table: how many read and
# write streams each kernel family drives against HBM.  Element size is
# rebound to the actual dtype at planning time.
FAMILIES: dict[str, StreamSignature] = {
    "stream.copy": StreamSignature(n_read=1, n_write=1),
    "stream.scale": StreamSignature(n_read=1, n_write=1),
    "stream.add": StreamSignature(n_read=2, n_write=1),
    "stream.triad": StreamSignature(n_read=2, n_write=1),
    "triad": StreamSignature(n_read=3, n_write=1),          # Schoenauer B+C*D
    "jacobi": StreamSignature(n_read=1, n_write=1),         # rows stream once
    "lbm.soa": StreamSignature(n_read=19, n_write=19),      # D3Q19 collide
    "lbm.ivjk": StreamSignature(n_read=19, n_write=19),
    "rmsnorm": StreamSignature(n_read=2, n_write=1),        # x, scale -> y
    "rmsnorm.gated": StreamSignature(n_read=3, n_write=1),  # x, z, scale -> y
    "xent": StreamSignature(n_read=2, n_write=1),           # logits, labels
}

# D3Q19 direction count, needed for the LBM block geometry.  Kept local so
# core never imports the kernels package.
_LBM_Q = 19

# VMEM-resident buffer count per family when it differs from the HBM stream
# count + 1: jacobi's three shifted row views are distinct Pallas operands
# even though they stream each source row from HBM only once.
VMEM_BUFFERS: dict[str, int] = {"jacobi": 4}

# Families whose kernels tile the minor dim too (blocked columns).  All
# other 2-D kernels stream full-width row blocks, so their row budget must
# be charged against the whole padded width.
COL_TILED = {"xent"}

# ---------------------------------------------------------------------------
# Traffic accounting (measured-vs-predicted validation, paper Fig. 4)
# ---------------------------------------------------------------------------
# How many of a family's streams move a *full planned array* each launch.
# The balance model above treats every stream as equal-weight when scoring
# channel conflicts; traffic prediction must not -- jacobi's three shifted
# row views stream each source row from HBM once, the LBM lattice already
# contains all 19 direction rows, and rmsnorm/xent carry small side operands.
# Families absent here move one full array per signature stream.
MAJOR_STREAMS: dict[str, int] = {
    "jacobi": 2,         # grid in + grid out; shifted views hit cached rows
    "lbm.soa": 2,        # lattice read + written once (19+19 direction rows)
    "lbm.ivjk": 2,
    "rmsnorm": 2,        # x in + y out; scale is a width-sized minor stream
    "rmsnorm.gated": 3,  # x, z in + y out
    "xent": 1,           # logits; labels and per-token nll are row-sized
}

# Minor side-operand bytes per launch: (rows, width, elem_bytes) -> bytes.
# labels are int32 and nll is fp32 regardless of the logits dtype.
MINOR_STREAM_BYTES: dict[str, Callable[[int, int, int], int]] = {
    "rmsnorm": lambda rows, width, eb: width * eb,
    "rmsnorm.gated": lambda rows, width, eb: width * eb,
    "xent": lambda rows, width, eb: rows * 4 + rows * 4,
}

# ---------------------------------------------------------------------------
# Predicted interconnect traffic (communication-minimal SPMD launches)
# ---------------------------------------------------------------------------
# Per-device wire bytes one SPMD launch of a *local* (per-shard) plan moves,
# under the standard ring cost model (the same formulas
# ``launch.lowering.collective_census`` applies to measured HLO):
#
#     all-reduce           2 (N-1)/N x payload
#     collective-permute               payload
#
# The mesh-axis names are the ``parallel.rules.DEFAULT_RULES`` targets the
# kernels' partitioning declarations resolve to ("batch" -> data, "vocab" ->
# model); a launcher that renames its axes should keep the rule table in
# sync.  The model assumes the declared partitioning engaged -- a
# divisibility fallback to replication moves fewer bytes than predicted,
# which the validation envelope absorbs.  Families absent here communicate
# nothing (batch-parallel shards are independent).


def _ring_all_reduce_bytes(payload: int, n: int) -> int:
    return int(2 * (n - 1) / n * payload) if n > 1 else 0


def _comm_jacobi(plan: "KernelPlan", sizes: Mapping[str, int]) -> int:
    # One (1, cols) halo row ppermuted up and one down per sweep; the halo
    # is exchanged at the logical column count (padding happens after the
    # exchange, inside the shard).
    d = sizes.get("data", 1)
    if d <= 1:
        return 0
    return 2 * int(plan.logical_shape[-1]) * plan.elem_bytes


def _comm_xent(plan: "KernelPlan", sizes: Mapping[str, int]) -> int:
    # Vocab-parallel lse combine: pmax(m) + psum(l) + psum(label_logit),
    # three fp32 vectors over the local token rows, all-reduced across the
    # model axis; plus the 4-byte scalar pmean of the per-shard NLL over the
    # batch axes.
    mv = sizes.get("model", 1)
    d = sizes.get("data", 1)
    rows = int(plan.logical_shape[0])
    total = _ring_all_reduce_bytes(3 * rows * 4, mv)
    total += _ring_all_reduce_bytes(4, d)
    return total


# Of D3Q19's 19 directions, 5 have c_x = +1 and 5 have c_x = -1 (one face
# + four edges each way); the other 9 never cross an X cut.  Hardcoded so
# core never imports the kernels package (same rule as _LBM_Q above).
_LBM_X_DIRS = 5


def _comm_lbm(plan: "KernelPlan", sizes: Mapping[str, int]) -> int:
    # X-sharded lattice (Q, X, Y, Z): per streaming step each shard
    # ppermutes one (5, 1, Y, Z) slab of +x-moving populations down-ring
    # and one slab of -x-moving populations up-ring -- only the 10
    # directions with nonzero c_x cross the cut, at depth |c_x| = 1.
    d = sizes.get("data", 1)
    if d <= 1:
        return 0
    y, z = (int(s) for s in plan.logical_shape[2:4])
    return 2 * _LBM_X_DIRS * y * z * plan.elem_bytes


COMM_MODEL: dict[str, Callable[["KernelPlan", Mapping[str, int]], int]] = {
    "jacobi": _comm_jacobi,
    "xent": _comm_xent,
    "lbm.soa": _comm_lbm,
    "lbm.ivjk": _comm_lbm,
}

# ---------------------------------------------------------------------------
# Exposed communication (the overlap term)
# ---------------------------------------------------------------------------
# Halo-exchange geometry per family: (sharded logical dim, halo depth).
# These are the families whose SPMD bodies are *overlapped* -- the halo
# ppermute is issued before interior-stripe compute, so the wire time can
# hide behind the interior memory stream.  The hideable fraction is the
# classic overlap bound: while the interior stripe streams
# ``MAJOR_STREAMS x interior_elems x elem_bytes`` through HBM, the link can
# move that window scaled by ICI_BW / HBM_BW; anything beyond that stays
# exposed on the critical path.  Families with a COMM_MODEL entry but no
# halo spec (xent's lse combine) block on their collective -- the compute
# that could hide it depends on the collective's result -- so their comm is
# fully exposed.  Bandwidths are the v5e roofline constants (also in
# benchmarks/roofline.py and launch/lowering.py, which core cannot import).
HALO_MODEL: dict[str, tuple[int, int]] = {
    "jacobi": (0, 1),     # one row up + one row down over the data axis
    "lbm.soa": (1, 1),    # X planes; 2 x 5 direction-slabs of depth 1
    "lbm.ivjk": (1, 1),
}
_HBM_BW = 819e9
_ICI_BW = 50e9


def register_family(
    name: str,
    signature: StreamSignature,
    *,
    vmem_buffers: int | None = None,
    col_tiled: bool = False,
) -> None:
    """Declare (or re-assert) a kernel family's stream signature.

    The registry (``repro.api.registry``) calls this when a kernel registers,
    so the planner's table and the registered kernels can never drift: a
    second declaration with a *different* signature or VMEM-buffer count is
    a shadowed name and raises instead of silently replacing the analysis.
    A declaration that introduces new block geometry (first ``vmem_buffers``
    or newly ``col_tiled``) drops the family's cached plans, so earlier
    plans made under the defaults cannot linger alongside new ones.
    """
    cur = FAMILIES.get(name)
    if cur is not None and (cur.n_read, cur.n_write) != (
            signature.n_read, signature.n_write):
        raise ValueError(
            f"kernel family {name!r} already declared with "
            f"{cur.n_read}R+{cur.n_write}W; refusing shadow declaration "
            f"{signature.n_read}R+{signature.n_write}W"
        )
    geometry_changed = False
    if vmem_buffers is not None:
        prev = VMEM_BUFFERS.get(name)
        if prev is not None and prev != vmem_buffers:
            raise ValueError(
                f"kernel family {name!r} already declared with "
                f"{prev} VMEM buffers; refusing shadow declaration "
                f"{vmem_buffers}"
            )
        geometry_changed = prev is None
    FAMILIES[name] = signature
    if vmem_buffers is not None:
        VMEM_BUFFERS[name] = vmem_buffers
    if col_tiled and name not in COL_TILED:
        COL_TILED.add(name)
        geometry_changed = True
    if geometry_changed:
        with _LOCK:
            for key in [k for k in _CACHE if k[0] == name]:
                del _CACHE[key]


@dataclasses.dataclass(frozen=True)
class KernelPlan:
    """Everything a kernel wrapper needs to lay its arrays out.

    Frozen and hashable so wrappers can pass it as a jit-static argument;
    identical logical problems therefore share both the plan *and* the
    compiled executable.
    """

    kernel: str
    logical_shape: tuple[int, ...]
    dtype: str
    padded_shape: tuple[int, ...]
    block_shape: tuple[int, ...]
    signature: StreamSignature
    layout: LayoutPlan
    naive_balance: float
    mesh: tuple[tuple[str, int], ...] = ()
    sublanes: int = SUBLANES
    # True for a per-shard plan made by the SPMD launch path
    # (``plan_for(..., local=True)``): the shape is one device's slice, the
    # minor dim was not TP-re-widened, and ``predicted_comm_bytes`` below
    # describes the shard's collectives.
    local: bool = False
    # Where this plan came from: "analytic" (the planner's closed form) or a
    # measured source such as "sweep" / "profile:<path>" (see repro.measure).
    # Excluded from eq/hash: plans are jit-static arguments, and a
    # profile-loaded plan with analytic-identical geometry must share the
    # compiled executable, not force a recompile over a label.
    provenance: str = dataclasses.field(default="analytic", compare=False)

    # ---- geometry --------------------------------------------------------
    @property
    def rows(self) -> int:
        return self.padded_shape[0]

    @property
    def width(self) -> int:
        return self.padded_shape[-1]

    @property
    def block_rows(self) -> int:
        return self.block_shape[0]

    @property
    def block_cols(self) -> int:
        return self.block_shape[-1]

    @property
    def grid(self) -> tuple[int, ...]:
        return tuple(cdiv(p, b) for p, b in zip(self.padded_shape, self.block_shape))

    # ---- accounting ------------------------------------------------------
    @property
    def logical_elems(self) -> int:
        n = 1
        for s in self.logical_shape:
            n *= s
        return n

    @property
    def padded_elems(self) -> int:
        n = 1
        for s in self.padded_shape:
            n *= s
        return n

    @property
    def waste(self) -> float:
        """Fraction of the physical footprint that is padding."""
        p = self.padded_elems
        return (p - self.logical_elems) / p if p else 0.0

    @property
    def elem_bytes(self) -> int:
        return np.dtype(self.dtype).itemsize

    @property
    def padded_bytes(self) -> int:
        """Physical HBM footprint of one planned stream."""
        return self.padded_elems * self.elem_bytes

    @property
    def waste_bytes(self) -> int:
        """Padding overhead in bytes -- the hardware-meaningful waste metric
        (a bf16 plan with wider sublane tiles can pad more *elements* than
        the fp32 plan of the same logical shape yet cost fewer bytes)."""
        return (self.padded_elems - self.logical_elems) * self.elem_bytes

    @property
    def predicted_balance(self) -> float:
        return self.layout.predicted_balance

    @property
    def leading_stride_bytes(self) -> int:
        """Bytes between consecutive leading-dim slices of the padded array
        -- the row stride whose residue class modulo the interleave period
        decides which controllers a strided walk can reach (paper SS2.2)."""
        n = self.elem_bytes
        for s in self.padded_shape[1:]:
            n *= s
        return n

    # ---- predicted traffic ----------------------------------------------
    def _traffic_bytes(self, elems: int, shape: tuple[int, ...]) -> int:
        major = MAJOR_STREAMS.get(self.kernel, self.signature.n_streams)
        total = major * elems * self.elem_bytes
        minor = MINOR_STREAM_BYTES.get(self.kernel)
        if minor is not None:
            total += minor(int(shape[0]), int(shape[-1]), self.elem_bytes)
        return total

    @property
    def predicted_hbm_bytes(self) -> int:
        """Analytic HBM traffic per launch at the planned *physical*
        footprint: every major stream moves one padded array, plus the
        family's minor side operands.  This is the number the conflict model
        scores -- what ``repro.measure.validate`` checks against compiled
        HLO bytes-accessed (the paper's measured-vs-predicted envelope)."""
        return self._traffic_bytes(self.padded_elems, self.padded_shape)

    @property
    def predicted_logical_bytes(self) -> int:
        """Lower bound on the same traffic: the streams at their *logical*
        footprint (what a perfect compiler with no padding would move).
        ``predicted_hbm_bytes - predicted_logical_bytes`` is the traffic the
        plan pays for whole-tile DMAs -- the per-launch cost of
        ``waste_bytes``."""
        return self._traffic_bytes(self.logical_elems, self.logical_shape)

    @property
    def predicted_comm_bytes(self) -> int:
        """Analytic per-device interconnect traffic one SPMD launch of this
        plan moves (ring cost model; see ``COMM_MODEL``).  Nonzero only for
        *local* plans under a multi-axis mesh: a global plan describes the
        single-device direct path, which communicates nothing.  This is the
        number ``repro.measure.validate --comm`` checks against the
        collective census of the lowered shard_map program."""
        if not self.local or not self.mesh:
            return 0
        fn = COMM_MODEL.get(self.kernel)
        if fn is None:
            return 0
        return fn(self, dict(self.mesh))

    @property
    def predicted_exposed_comm_bytes(self) -> int:
        """The part of ``predicted_comm_bytes`` left on the critical path
        after overlap: total wire bytes minus what the interior-stripe
        compute window can hide (``HALO_MODEL``).  The overlapped shard
        bodies issue the halo ppermute before interior compute, so the link
        moves halo bytes while ``MAJOR_STREAMS x interior_elems`` stream
        through HBM; the hideable window is that HBM time converted to wire
        bytes at ICI_BW / HBM_BW.  Families without a halo spec (xent's
        blocking lse combine) expose everything.  This is the number
        ``repro.measure.validate --comm --exposed`` checks against the
        overlap structure of the lowered program."""
        total = self.predicted_comm_bytes
        if total == 0:
            return 0
        spec = HALO_MODEL.get(self.kernel)
        if spec is None:
            return total
        dim, depth = spec
        interior = [int(s) for s in self.logical_shape]
        interior[dim] = max(interior[dim] - 2 * depth, 0)
        elems = 1
        for s in interior:
            elems *= s
        major = MAJOR_STREAMS.get(self.kernel, self.signature.n_streams)
        window = major * elems * self.elem_bytes
        hidden = min(total, int(window * _ICI_BW / _HBM_BW))
        return total - hidden

    def explain(self) -> str:
        """Human-readable report: predicted balance, waste, block geometry."""
        sig = self.signature
        grid = "x".join(str(g) for g in self.grid)
        block = "x".join(str(b) for b in self.block_shape)
        return (
            f"plan[{self.kernel}] logical={self.logical_shape} {self.dtype}"
            f" -> physical {self.padded_shape}, block {block}, grid {grid},"
            f" sublanes {self.sublanes}\n"
            f"  streams: {sig.n_read}R+{sig.n_write}W x {sig.elem_bytes}B"
            f"  align={self.layout.align_bytes}B"
            f" offsets={self.layout.offsets_bytes}B"
            f" segment-shift={self.layout.segment_shift_bytes}B\n"
            f"  predicted balance {self.predicted_balance:.2f}"
            f" (naive {self.naive_balance:.2f}),"
            f" waste {self.waste:.1%}"
            f" ({self.padded_elems - self.logical_elems} pad elems)\n"
            f"  predicted traffic {self.predicted_hbm_bytes}B"
            f" (logical {self.predicted_logical_bytes}B,"
            f" comm {self.predicted_comm_bytes}B,"
            f" exposed {self.predicted_exposed_comm_bytes}B)"
            + ("" if not self.local
               else f"\n  local shard plan for mesh "
                    f"{dict(self.mesh) or '(none)'}")
            + ("" if self.provenance == "analytic"
               else f"\n  source: {self.provenance}")
        )


# ---------------------------------------------------------------------------
# Plan cache
# ---------------------------------------------------------------------------

_CACHE: dict[tuple, KernelPlan] = {}
_STATS = {"hits": 0, "misses": 0}
_LOCK = threading.RLock()
_DEFAULT_MODEL = InterleavedMemoryModel()


def _mesh_key(mesh) -> tuple[tuple[str, int], ...]:
    if mesh is None:
        return ()
    if hasattr(mesh, "axis_names") and hasattr(mesh, "devices"):
        return tuple(zip(tuple(mesh.axis_names), tuple(mesh.devices.shape)))
    if isinstance(mesh, Mapping):
        return tuple(sorted((str(k), int(v)) for k, v in mesh.items()))
    return tuple((str(k), int(v)) for k, v in mesh)


def plan_kernel(
    kernel: str,
    shape,
    dtype,
    *,
    mesh=None,
    model: InterleavedMemoryModel | None = None,
    sublanes: int | None = None,
    vmem_budget: int | None = None,
    local: bool = False,
) -> KernelPlan:
    """Memoized analytic plan for ``kernel`` on a logical ``shape``/``dtype``.

    ``mesh`` (a jax Mesh, a mapping, or ``(axis, size)`` pairs) widens the
    minor-dim padding so every model-axis shard stays lane-aligned.
    ``sublanes`` overrides the dtype-derived sublane tile (8 fp32 / 16 bf16 /
    32 fp8); ``vmem_budget`` caps the per-core VMEM bytes the block chooser
    may assume.  Both default from the dtype / hardware and are normally
    supplied by the ambient ``repro.api.PlanContext``.

    ``local=True`` plans one *shard's* launch under the SPMD path
    (``repro.api.spmd``): ``shape`` is already a per-device slice, so the
    minor dim is padded only to the lane tile, not widened again by the
    mesh's tensor-parallel width -- the global array was split there, the
    local array was not.  The mesh still participates in the memo key, so
    per-shard plans are cached as ``(kernel, local_shape, dtype, mesh)``
    without colliding with global plans of the same shape.
    """
    if kernel not in FAMILIES:
        raise KeyError(
            f"unknown kernel family {kernel!r}; known: {sorted(FAMILIES)}"
        )
    dt = np.dtype(dtype)
    mesh_key = _mesh_key(mesh)
    model = model or _DEFAULT_MODEL
    sub = sublanes_for_dtype(dt) if sublanes is None else int(sublanes)
    budget = VMEM_BYTES if vmem_budget is None else int(vmem_budget)
    if sub <= 0:
        raise ValueError(f"sublanes must be positive, got {sublanes}")
    if budget <= 0:
        raise ValueError(f"vmem_budget must be positive, got {vmem_budget}")
    key = (kernel, tuple(int(s) for s in shape), dt.name, mesh_key, model,
           sub, budget, bool(local))
    with _LOCK:
        plan = _CACHE.get(key)
        if plan is not None:
            _STATS["hits"] += 1
            return plan
        _STATS["misses"] += 1
        plan = _plan_uncached(kernel, key[1], dt, mesh_key, model, sub,
                              budget, local=bool(local))
        _CACHE[key] = plan
        return plan


def plan_cache_info() -> dict[str, int]:
    with _LOCK:
        return {"hits": _STATS["hits"], "misses": _STATS["misses"],
                "size": len(_CACHE)}


def plan_cache_keys() -> list[tuple]:
    """Snapshot of the memo keys ``(kernel, shape, dtype, mesh, model,
    sublanes, vmem_budget)`` -- lets tests and audits assert *which* mesh and
    sublane policy actually reached the planner at a call site."""
    with _LOCK:
        return list(_CACHE)


def clear_plan_cache() -> None:
    with _LOCK:
        _CACHE.clear()
        _STATS["hits"] = _STATS["misses"] = 0


def invalidate_mesh_plans(mesh) -> int:
    """Drop every memoized plan keyed to ``mesh``; returns the count.

    The elastic runtime calls this on a topology change: plans derived
    under the old mesh (global shard-aligned padding *and* per-shard
    ``local=True`` cells) describe a machine that no longer exists, and a
    stale cell silently re-used after a re-mesh is exactly the "fixed
    layout on an asymmetric machine" hazard the paper warns about.  Plans
    for other meshes (and the mesh-free single-device cells) survive.
    """
    if mesh is None:
        return 0
    mesh_key = _mesh_key(mesh)
    with _LOCK:
        stale = [k for k in _CACHE if k[3] == mesh_key]
        for k in stale:
            del _CACHE[k]
        return len(stale)


def stream_stride_facts(
    plan: KernelPlan,
    model: InterleavedMemoryModel | None = None,
) -> dict:
    """Static layout facts ``repro.analyze`` scores without executing anything.

    Everything here is closed-form arithmetic on the plan's padded geometry
    and its ``LayoutPlan`` under ``model``'s address->controller map:

    * ``leading_stride_bytes`` / ``stride_gcd_period`` -- the row stride and
      its gcd with the interleave period.  A stride whose gcd *is* the period
      (every power of two >= period qualifies) pins a strided walk to one
      channel: the paper's thrashing condition.
    * ``start_channels`` -- the controller each planned stream's base address
      hits at tick zero.  Skewed streams land on distinct channels; a
      degenerate layout (no skews, no segment shift) piles every stream onto
      channel 0.
    * the plan's own balance scores, so rules can report predicted impact.
    """
    model = model or _DEFAULT_MODEL
    stride = plan.leading_stride_bytes
    period = model.period_bytes
    gcd = int(np.gcd(stride, period)) if stride else period
    offsets = plan.layout.offsets_bytes
    starts = tuple(model.channel(o) for o in offsets)
    return {
        "kernel": plan.kernel,
        "n_streams": plan.signature.n_streams,
        "leading_stride_bytes": stride,
        "stride_pow2": stride >= period and (stride & (stride - 1)) == 0,
        "stride_gcd_period": gcd,
        "period_bytes": period,
        "offsets_bytes": offsets,
        "start_channels": starts,
        "distinct_start_channels": len(set(starts)),
        "segment_shift_bytes": plan.layout.segment_shift_bytes,
        "predicted_balance": plan.predicted_balance,
        "naive_balance": plan.naive_balance,
    }


def explain(kernel: str, shape, dtype, *, mesh=None,
            model: InterleavedMemoryModel | None = None,
            sublanes: int | None = None,
            vmem_budget: int | None = None) -> str:
    """Convenience: plan and render the report in one call."""
    return plan_kernel(kernel, shape, dtype, mesh=mesh, model=model,
                       sublanes=sublanes, vmem_budget=vmem_budget).explain()


# ---------------------------------------------------------------------------
# Closed-form planning rules
# ---------------------------------------------------------------------------

def _plan_uncached(kernel: str, shape: tuple[int, ...], dt: np.dtype,
                   mesh_key, model: InterleavedMemoryModel,
                   sublanes: int, budget: int, *,
                   local: bool = False) -> KernelPlan:
    sig = dataclasses.replace(FAMILIES[kernel], elem_bytes=dt.itemsize)
    n_buffers = VMEM_BUFFERS.get(kernel, sig.n_streams + 1)
    if kernel.startswith("lbm."):
        padded, block = _plan_lbm(kernel, shape, sig, sublanes, budget)
    elif len(shape) == 1:
        padded, block = _plan_1d(shape[0], sig, n_buffers, sublanes, budget)
    elif len(shape) == 2:
        # A shard-local plan pads the minor dim to the plain lane tile: the
        # tensor-parallel widening aligns *global* arrays to their shard
        # boundaries, and a per-device slice has no shard boundary in it.
        tp = 1 if local else dict(mesh_key).get("model", 1)
        padded, block = _plan_2d(shape, sig, tp, n_buffers, sublanes, budget,
                                 col_tiled=kernel in COL_TILED)
    else:
        raise ValueError(
            f"{kernel}: cannot plan rank-{len(shape)} shape {shape}"
        )
    layout = _plan_layout(sig, model)
    naive = _naive_balance(sig, model)
    plan = KernelPlan(
        kernel=kernel,
        logical_shape=shape,
        dtype=dt.name,
        padded_shape=padded,
        block_shape=block,
        signature=sig,
        layout=layout,
        naive_balance=naive,
        mesh=mesh_key,
        sublanes=sublanes,
        local=local,
    )
    # Narrow-dtype waste guarantee: a bf16/fp8 plan must never pay more
    # padding *bytes* than the fp32 plan of the same logical shape.  The
    # native wide-sublane tile usually pads fewer bytes (more pad elements
    # at half/quarter price), but its taller row tile can lose badly when
    # `_fit_block` rounds the row count up a whole block.  The fp32 plan's
    # geometry is always legal at a narrower dtype (rows stay
    # 8-sublane-tileable, blocks shrink under the same VMEM budget), and
    # costs exactly itemsize/4 of the fp32 padding bytes -- so take the
    # cheaper of the two, still in closed form.  Explicit sublane overrides
    # (context sublane_policy) are honored untouched.
    if dt.itemsize < 4 and sublanes == sublanes_for_dtype(dt):
        f32 = plan_kernel(kernel, shape, np.float32, mesh=mesh_key,
                          model=model, vmem_budget=budget, local=local)
        if plan.waste_bytes * 4 > f32.waste_bytes * dt.itemsize:
            plan = dataclasses.replace(
                plan, padded_shape=f32.padded_shape,
                block_shape=f32.block_shape, sublanes=f32.sublanes,
            )
    return plan


def _plan_layout(sig: StreamSignature, model: InterleavedMemoryModel) -> LayoutPlan:
    """The analytic skew plan, scored as deployed: n_channels concurrent
    segments whose chunk stride is congruent to one channel step, so skewed
    streams + shifted segments jointly cover every channel each tick."""
    step = 1 << model.channel_shift
    return plan_streams(
        sig, model,
        n_threads=model.n_channels,
        chunk_bytes=model.period_bytes + step,
    )


def _naive_balance(sig: StreamSignature, model: InterleavedMemoryModel) -> float:
    """Score of the *unplanned* layout: page-aligned streams, period-aliased
    segments -- every request lands on one controller (paper Fig. 2, offset
    zero)."""
    streams = [
        Stream(base=0, kind="write" if k < sig.n_write else "read")
        for k in range(sig.n_streams)
    ]
    return model.balance(streams, n_threads=model.n_channels,
                         chunk_bytes=model.period_bytes)


def _fit_block(rows: int, width: int, sig: StreamSignature, n_buffers: int,
               sublanes: int, budget: int,
               *, col_tiled: bool = False) -> tuple[int, int, int]:
    """VMEM block for (rows, width): ``n_buffers`` resident blocks, whole
    lines per DMA, sublane-multiple rows.  Full-width kernels charge the row
    budget against the whole width (their blocks are (brows, width));
    col-tiled kernels (online-softmax style) also tile the minor dim.

    A divisor of the row count within half the budgeted block is preferred
    (zero extra padding at a small block-size cost); failing that, rows are
    padded *up* to a block multiple (returned as the first element) rather
    than the block shrunk further: an awkward row count (e.g. a large prime
    x 8) costs at most one extra block of padding instead of collapsing
    every DMA to one sublane tile."""
    brows, bcols = choose_block_shape(
        rows, width,
        bytes_per_el=sig.elem_bytes,
        n_buffers=n_buffers,
        vmem_budget=budget,
        max_block_cols=MAX_WIDTH if col_tiled else width,
        sublane_tile=sublanes,
    )
    bcols = min(bcols, width)
    while width % bcols:
        bcols -= LANES
    bcols = max(bcols, LANES)
    brows = max(min(brows, rows), sublanes)
    for cand in range(brows, max(brows // 2, sublanes) - 1, -sublanes):
        if rows % cand == 0:
            return rows, cand, bcols
    return round_up(rows, brows), brows, bcols


def _plan_1d(n: int, sig: StreamSignature, n_buffers: int, sublanes: int,
             budget: int) -> tuple[tuple[int, ...], tuple[int, ...]]:
    """1-D stream of n elements -> (rows, width) whole-tile 2-D layout.

    The width is the smallest lane multiple that keeps the sublane-padded
    row count minimal (waste shrinks toward one tile), capped at MAX_WIDTH
    so blocks stay within the VMEM budget for any stream count.
    """
    n = max(int(n), 1)
    width = round_up(min(max(cdiv(n, sublanes), LANES), MAX_WIDTH), LANES)
    rows = round_up(cdiv(n, width), sublanes)
    rows, brows, bcols = _fit_block(rows, width, sig, n_buffers, sublanes,
                                    budget)
    return (rows, width), (brows, bcols)


def _plan_2d(shape: tuple[int, ...], sig: StreamSignature, tp: int,
             n_buffers: int, sublanes: int, budget: int, *,
             col_tiled: bool) -> tuple[tuple[int, ...], tuple[int, ...]]:
    """(rows, cols) kernel: sublane-pad rows, lane-pad cols (x tp when the
    minor dim is sharded over a model axis)."""
    r, c = shape
    rows = round_up(max(int(r), 1), sublanes)
    width = round_up(max(int(c), 1), LANES * max(int(tp), 1))
    rows, brows, bcols = _fit_block(rows, width, sig, n_buffers, sublanes,
                                    budget, col_tiled=col_tiled)
    return (rows, width), (brows, bcols)


def _plan_lbm(kernel: str, shape: tuple[int, ...], sig: StreamSignature,
              sublanes: int,
              budget: int) -> tuple[tuple[int, ...], tuple[int, ...]]:
    """D3Q19 collision layouts.  ``shape`` is the lattice (Q, X, Y, Z).

    soa : f stored (Q, S)        -- block (Q, bs), bs sized so 2 buffers of
                                    all Q direction rows fit VMEM.
    ivjk: f stored (S/128, Q, L) -- directions interleaved at lane
                                    granularity; block is bsb super-rows.
    """
    q = int(shape[0])
    if q != _LBM_Q:
        raise ValueError(f"{kernel}: leading dim must be Q={_LBM_Q}, got {q}")
    s = 1
    for d in shape[1:]:
        s *= int(d)
    s = max(s, 1)
    elem = sig.elem_bytes
    if kernel == "lbm.soa":
        cap = round_down(
            min(budget // max(q * elem * 2, 1), MAX_WIDTH), LANES
        )
        bs = max(min(cap, round_up(s, LANES)), LANES)
        spad = round_up(s, bs)
        return (q, spad), (q, bs)
    # ivjk: super-block rows of (Q, 128) slabs
    cap = round_down(
        min(budget // max(q * LANES * elem * 2, 1), 64), sublanes
    )
    bsb = max(min(cap, round_up(cdiv(s, LANES), sublanes)), sublanes)
    spad = round_up(s, bsb * LANES)
    return (spad // LANES, q, LANES), (bsb, q, LANES)
