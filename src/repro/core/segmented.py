"""Segmented arrays: the paper's segmented container + iterators, in JAX.

The paper splits each array into per-thread segments, aligns every segment to
a controller-period boundary, then shifts segment ``t`` by ``t * shift``
bytes so concurrent threads land on different memory controllers; STL-style
*segmented iterators* keep the inner loops at plain-C speed (Fig. 5 shows
zero overhead).

The JAX port: a ``SegmentedArray`` is a pytree of per-segment blocks.  Each
segment has a *logical* length and a *physical* (padded) length; the pad is
the alignment analogue (on TPU it keeps every segment lane/sublane aligned so
per-segment kernels and per-device shards never see ragged tails).  The
"shift" survives as ``phase``: a per-segment element offset into the physical
block, so segment k's data starts at a different lane phase -- exactly the
paper's skew, re-targeted at the (8,128) tile instead of the 512 B period.

``seg_map`` is the segmented-iterator equivalent: it applies a flat kernel
per segment (unrolled, static segment count) -- under ``jit`` XLA fuses the
per-segment calls, and the overhead benchmark (benchmarks/segmented_overhead)
reproduces the paper's Fig. 5 "negligible overhead" claim.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.layout import round_up


def split_lengths(n: int, n_segments: int) -> list[int]:
    """Paper's manual schedule: floor(N/t)+1 for the first N%t segments."""
    if n_segments <= 0:
        raise ValueError("n_segments must be positive")
    base, rem = divmod(n, n_segments)
    return [base + 1 if s < rem else base for s in range(n_segments)]


@jax.tree_util.register_pytree_node_class
class SegmentedArray:
    """1-D array stored as padded, phase-shifted segments.

    segments[k] has physical length P_k; the logical data of segment k lives
    at segments[k][phase_k : phase_k + L_k].  All structural metadata is
    static (hashable aux data) so SegmentedArray traces cleanly under jit.
    """

    def __init__(
        self,
        segments: Sequence[jax.Array],
        lengths: Sequence[int],
        phases: Sequence[int],
    ):
        if not (len(segments) == len(lengths) == len(phases)):
            raise ValueError("segments/lengths/phases must align")
        for seg, L, p in zip(segments, lengths, phases):
            if hasattr(seg, "ndim") and seg.ndim != 1:
                raise ValueError("segments must be 1-D")
        self.segments = tuple(segments)
        self.lengths = tuple(int(x) for x in lengths)
        self.phases = tuple(int(x) for x in phases)

    # ---- pytree protocol -------------------------------------------------
    def tree_flatten(self):
        return self.segments, (self.lengths, self.phases)

    @classmethod
    def tree_unflatten(cls, aux, children):
        lengths, phases = aux
        return cls(children, lengths, phases)

    # ---- construction ----------------------------------------------------
    @classmethod
    def from_flat(
        cls,
        x: jax.Array,
        n_segments: int,
        *,
        align: int = 128,
        shift: int = 0,
    ) -> "SegmentedArray":
        """Split ``x`` into near-equal segments; pad each physical block to a
        multiple of ``align`` elements; give segment k a phase of
        ``(k * shift) % align`` elements (the paper's per-segment skew).
        """
        (n,) = x.shape
        lengths = split_lengths(n, n_segments)
        phases = [(k * shift) % align if align else 0 for k in range(n_segments)]
        segs = []
        start = 0
        for L, p in zip(lengths, phases):
            phys = round_up(p + L, align) if align else p + L
            block = jnp.zeros((phys,), dtype=x.dtype)
            block = jax.lax.dynamic_update_slice(block, x[start : start + L], (p,))
            segs.append(block)
            start += L
        return cls(segs, lengths, phases)

    def to_flat(self) -> jax.Array:
        """Concatenate the logical contents (inverse of from_flat)."""
        parts = [
            jax.lax.dynamic_slice(seg, (p,), (L,))
            for seg, L, p in zip(self.segments, self.lengths, self.phases)
        ]
        if parts:
            return jnp.concatenate(parts)
        dtype = self.segments[0].dtype if self.segments else jnp.float32
        return jnp.zeros((0,), dtype)

    # ---- metadata ----------------------------------------------------------
    @property
    def n_segments(self) -> int:
        return len(self.segments)

    @property
    def logical_size(self) -> int:
        return sum(self.lengths)

    @property
    def physical_size(self) -> int:
        return sum(int(np.prod(s.shape)) for s in self.segments)

    @property
    def waste(self) -> float:
        ps = self.physical_size
        return (ps - self.logical_size) / ps if ps else 0.0

    def like(self, segments: Sequence[jax.Array]) -> "SegmentedArray":
        return SegmentedArray(segments, self.lengths, self.phases)

    # ---- segmented "iterators" --------------------------------------------
    def seg_view(self, k: int) -> jax.Array:
        """Logical view of segment k (a dynamic slice -- jit friendly)."""
        return jax.lax.dynamic_slice(
            self.segments[k], (self.phases[k],), (self.lengths[k],)
        )


def seg_map(
    fn: Callable[..., jax.Array],
    out: SegmentedArray,
    *ins: SegmentedArray,
) -> SegmentedArray:
    """Apply ``fn(*segment_views) -> segment`` per segment (the generic
    dispatching algorithm of the paper's ``triad()``).

    ``fn`` receives the *logical* views of each input segment and must return
    the new logical content for the output segment; the padded physical block
    and phase are preserved.  The loop is a static unroll: at trace time it
    becomes n_segments independent fused kernels, which is exactly the
    paper's "compile the serial kernel separately" trick.
    """
    for a in ins:
        if a.lengths != out.lengths:
            raise ValueError("segment length mismatch between operands")
    new_segments = []
    for k in range(out.n_segments):
        res = fn(*(a.seg_view(k) for a in ins))
        blk = jax.lax.dynamic_update_slice(out.segments[k], res, (out.phases[k],))
        new_segments.append(blk)
    return out.like(new_segments)


def seg_triad(a: SegmentedArray, b: SegmentedArray, c: SegmentedArray,
              d: SegmentedArray) -> SegmentedArray:
    """Segmented Schoenauer vector triad A = B + C * D (paper SS2.2)."""
    return seg_map(lambda bb, cc, dd: bb + cc * dd, a, b, c, d)


# ---------------------------------------------------------------------------
# Page tables: the 2-D generalization of the segmented container
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PageGeometry:
    """Static geometry of a paged pool: the segmented container generalized
    from "one segment per thread" to "one page table per sequence".

    A ``SegmentedArray`` splits one logical array into aligned, phase-shifted
    physical segments.  A paged pool inverts the mapping: many logical
    sequences share one physical pool of fixed-size *pages*, and a per
    -sequence page table maps logical position ``p`` to physical page
    ``table[p // page_len]`` at offset ``p % page_len``.  The paper's two
    layout rules survive intact:

      * *alignment* -- ``page_len`` is a whole number of planner sublane
        tiles (the controller-period analogue), so every page is a planned
        VMEM block and no page straddles a tile boundary;
      * *skew* -- :meth:`alloc_order` hands out physical pages round-robin
        across ``banks`` interleave groups (``page_id % banks``), so the
        consecutive logical pages of one sequence land on different banks --
        the per-segment ``phase`` shift of §2.3, re-targeted at page
        granularity.

    Physical page 0 is reserved as the *null page*: empty page-table rows
    point at it and masked writes are routed into it, so a scatter over a
    partially occupied batch never touches live data.
    """

    page_len: int          # logical positions per page (sublane-tile multiple)
    n_pages: int           # physical pages in the pool, including null page 0
    banks: int = 1         # allocation-interleave width (controller analogue)

    def __post_init__(self):
        if self.page_len <= 0:
            raise ValueError("page_len must be positive")
        if self.n_pages < 2:
            raise ValueError("n_pages must include the null page and at "
                             "least one allocatable page")
        if self.banks <= 0:
            raise ValueError("banks must be positive")

    @property
    def live_pages(self) -> int:
        """Allocatable pages (everything but the reserved null page)."""
        return self.n_pages - 1

    def pages_for(self, length: int) -> int:
        """Pages needed to hold ``length`` logical positions."""
        if length <= 0:
            return 0
        return -(-length // self.page_len)

    def page_of(self, pos: int) -> int:
        return pos // self.page_len

    def offset_of(self, pos: int) -> int:
        return pos % self.page_len

    def alloc_order(self) -> list[int]:
        """Bank-skewed allocation order over pages ``1..n_pages-1``.

        Successive allocations -- and therefore the consecutive logical
        pages of a growing sequence -- cycle through the ``banks``
        interleave groups, the paper's skew applied to page placement."""
        by_bank: list[list[int]] = [[] for _ in range(self.banks)]
        for pid in range(1, self.n_pages):
            by_bank[pid % self.banks].append(pid)
        order: list[int] = []
        queues = [list(b) for b in by_bank if b]
        while any(queues):
            for q in queues:
                if q:
                    order.append(q.pop(0))
        return order
