"""Unified kernel-launch API: registry + PlanContext + one launch path.

    from repro import api

    with api.plan_context(mesh=mesh):
        y = api.launch("rmsnorm", x, scale, eps=1e-6)
        print(api.explain("xent", (4096, 122753), "float32"))

Every kernel family declares itself with ``@register_kernel`` (streams,
reference oracle, Pallas body); ``launch`` resolves the analytic plan under
the ambient ``PlanContext`` and dispatches.  See docs/API.md for the
migration table from the old per-family wrappers.
"""
from repro.api.context import (
    PlanContext,
    current_context,
    get_default_context,
    plan_context,
    reset_default_context,
    set_default_context,
)
from repro.api.dispatch import explain, launch, plan_for, plan_tile, ref
from repro.api.registry import (
    FAMILY_MODULES,
    KernelEntry,
    get_kernel,
    list_kernels,
    register_kernel,
)
from repro.api.spmd import SCALAR, Partitioning, spmd_mesh

__all__ = [
    "PlanContext", "plan_context", "current_context",
    "set_default_context", "get_default_context", "reset_default_context",
    "launch", "plan_for", "plan_tile", "explain", "ref",
    "register_kernel", "get_kernel", "list_kernels",
    "KernelEntry", "FAMILY_MODULES",
    "Partitioning", "SCALAR", "spmd_mesh",
]
