"""SPMD kernel launch: shard_map partitioning for registered Pallas kernels.

A ``pallas_call`` carries no SPMD partitioning rule, so before this module a
multi-device program had exactly two options: silently fall back to jnp
(what ``models.blocks.use_fused_kernels`` did) or fail to lower.  The paper
analog is Treibig/Hager/Wellein's point that per-domain *placement*, not
just per-core tiling, determines achieved bandwidth: a block shape tuned
for one core's cache is worthless if the thread's working set lands on the
wrong memory controller.  Here the placement rule is the kernel's
``Partitioning`` declaration -- which operand axes are batch-parallel (each
device owns a shard and launches the planned kernel on it), which are
replicated, and how per-shard scalar results combine across shards.

Every ``@register_kernel`` entry carries a declaration; ``api.launch``
detects an ambient multi-device ``jax.sharding.Mesh`` (``spmd_mesh``) and
routes through ``shard_map``:

  * in/out PartitionSpecs come from ``parallel.rules`` -- the same
    logical-axis tables the model's activations use -- restricted to the
    mesh's axes, with the divisibility fallback to replication (an odd
    batch never produces ragged shards, it replicates);
  * inside the body each shard re-derives its plan from its own *local*
    operand shape (``plan_for(..., local=True)``), memoized under
    ``(kernel, local_shape, dtype, mesh)`` -- the per-shard block shape is
    planned, not inherited from the global array;
  * scalar outputs declare their cross-shard combine (``reduce="mean"``
    for xent's token-mean NLL), applied with ``pmean``/``psum`` over the
    mesh axes the sharded operand axes actually mapped to.

Kernels whose access pattern couples neighboring sites (jacobi's halo
rows, LBM's streaming shifts) declare themselves ``replicated``: every
device computes the full array -- correct, and it keeps one launch path
instead of a per-kernel fallback maze.

The path never nests: inside an existing shard_map/pmap body (pipeline
stages) ``spmd_mesh`` returns None and ``launch`` stays single-device.
``plan_context(spmd=False)`` opts a scope out explicitly.
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import PartitionSpec as P

from repro.api import context as context_lib
from repro.parallel import rules as rules_lib
from repro.parallel.shardmap_compat import NO_CHECK, inside_shard_map, shard_map

__all__ = ["Partitioning", "SCALAR", "replicated", "partitioning_for",
           "spmd_mesh", "spmd_launch"]

# Sentinel out_axes: the kernel reduces to a scalar (rank-0) result.
SCALAR = "scalar"

_REDUCES = (None, "mean", "sum")


@dataclasses.dataclass(frozen=True)
class Partitioning:
    """How one registered kernel partitions over an SPMD mesh.

    in_axes:
        one template per positional operand: a tuple of *logical* axis
        names (``parallel.rules`` vocabulary: "batch", "vocab", ...) or
        ``None`` (replicate that dim), one entry per array dimension.  An
        ``...`` (Ellipsis) entry expands to ``None`` for however many
        middle dims the operand has, so one template serves the 2-D
        kernel-level call and the 3-D model call: ``("batch", ..., None)``
        is ``("batch", None)`` for (rows, d) and ``("batch", None, None)``
        for (B, S, d).
    out_axes:
        the output's template (the output is assumed shaped like operand 0,
        the convention every registered family follows), or ``SCALAR`` for
        a rank-0 reduction result.
    reduce:
        cross-shard combine for ``SCALAR`` outputs: "mean" (xent's
        token-mean -- exact because shard_map shards are equal-sized) or
        "sum".  Required for SCALAR, forbidden otherwise.
    """

    in_axes: tuple[tuple, ...]
    out_axes: tuple | str = (...,)
    reduce: str | None = None

    def __post_init__(self):
        if self.reduce not in _REDUCES:
            raise ValueError(
                f"reduce must be one of {_REDUCES}, got {self.reduce!r}"
            )
        if self.out_axes == SCALAR and self.reduce is None:
            raise ValueError(
                "a SCALAR output needs a cross-shard reduce: each shard "
                "computes only its local partial"
            )
        if self.reduce is not None and self.out_axes != SCALAR:
            raise ValueError(
                f"reduce={self.reduce!r} only applies to SCALAR outputs"
            )


def replicated(n_inputs: int) -> Partitioning:
    """Fully-replicated declaration: every device computes the whole array.
    The right call for kernels whose stencil couples neighboring sites
    across any split (jacobi halos, LBM streaming) -- and the safe default
    for kernels registered without a declaration."""
    return Partitioning(in_axes=((...,),) * n_inputs, out_axes=(...,))


def partitioning_for(entry, n_inputs: int) -> Partitioning:
    """The entry's declared partitioning, or the replicated default for its
    ``n_inputs`` positional operands."""
    part = getattr(entry, "partitioning", None)
    return part if part is not None else replicated(n_inputs)


def _expand(template, ndim: int) -> tuple:
    """Instantiate an axes template for a rank-``ndim`` operand."""
    t = tuple(template)
    if Ellipsis in t:
        i = t.index(Ellipsis)
        head, tail = t[:i], t[i + 1:]
        n_mid = ndim - len(head) - len(tail)
        if n_mid < 0:
            raise ValueError(
                f"axes template {template} needs rank >= "
                f"{len(head) + len(tail)}, operand has rank {ndim}"
            )
        return head + (None,) * n_mid + tail
    if len(t) != ndim:
        raise ValueError(
            f"axes template {template} is rank-{len(t)}, "
            f"operand has rank {ndim}"
        )
    return t


def _spec_mesh_axes(spec: P) -> tuple[str, ...]:
    """Every mesh axis name appearing in a PartitionSpec, in order."""
    names: list[str] = []
    for part in spec:
        if part is None:
            continue
        for n in (part,) if isinstance(part, str) else tuple(part):
            if n not in names:
                names.append(n)
    return tuple(names)


def spmd_mesh(ctx: "context_lib.PlanContext | None" = None):
    """The mesh ``launch`` would shard_map over right now, or ``None``.

    Routing requires a *real* multi-device ``jax.sharding.Mesh`` (a
    ``{axis: size}`` mapping plans shard-aligned padding but cannot place
    computation), an SPMD-enabled context, and no enclosing mapped trace
    (nesting a shard_map inside a pipeline stage's shard_map would rebind
    its axis names).  ``models.blocks.use_fused_kernels`` gates the model
    hot paths on exactly this predicate."""
    ctx = ctx if ctx is not None else context_lib.current_context()
    if not ctx.spmd:
        return None
    mesh = ctx.mesh
    if mesh is None:
        mesh = rules_lib.current_mesh()
    if not isinstance(mesh, jax.sharding.Mesh):
        return None
    if mesh.size <= 1:
        return None
    if inside_shard_map():
        return None
    return mesh


def spmd_launch(entry, mesh, arrays, scalars):
    """Launch ``entry`` on ``arrays`` partitioned over ``mesh``.

    Builds in/out specs from the kernel's declaration under the ambient
    (or default) sharding rules, then shard_maps a body that plans each
    shard's *local* block shape and runs the registered Pallas body on it.
    Scalar kwargs (eps, omega, ...) close over the body; array-valued
    options ride along replicated.
    """
    part = partitioning_for(entry, len(arrays))
    if len(part.in_axes) != len(arrays):
        raise ValueError(
            f"{entry.name}: partitioning declares {len(part.in_axes)} "
            f"operand(s), launch got {len(arrays)}"
        )
    table = rules_lib.restrict_to_mesh(
        rules_lib.current_rules() or rules_lib.DEFAULT_RULES, mesh
    )
    sizes = dict(zip(tuple(mesh.axis_names), tuple(mesh.devices.shape)))
    in_specs = tuple(
        rules_lib.spec(*_expand(t, a.ndim), rules=table,
                       shape=tuple(int(s) for s in a.shape),
                       axis_sizes=sizes)
        for t, a in zip(part.in_axes, arrays)
    )
    if part.out_axes == SCALAR:
        out_spec = P()
        # The local partial must be combined over every mesh axis the
        # (sharded) data operand was split across; if divisibility forced
        # full replication this is empty and the local result is global.
        reduce_axes = _spec_mesh_axes(in_specs[0])
    else:
        out_spec = rules_lib.spec(
            *_expand(part.out_axes, arrays[0].ndim), rules=table,
            shape=tuple(int(s) for s in arrays[0].shape), axis_sizes=sizes)
        reduce_axes = ()

    def _shard_body(*local):
        from repro.api import dispatch  # lazy: dispatch imports this module

        shape, dtype = entry.plan_args(*local, **scalars)
        plan = dispatch.plan_for(entry.name, shape, dtype, local=True)
        out = entry.body(plan, *local, **scalars)
        if reduce_axes:
            if part.reduce == "mean":
                out = jax.lax.pmean(out, reduce_axes)
            elif part.reduce == "sum":
                out = jax.lax.psum(out, reduce_axes)
        return out

    fn = shard_map(_shard_body, mesh=mesh, in_specs=in_specs,
                   out_specs=out_spec, **NO_CHECK)
    return fn(*arrays)
