"""SPMD kernel launch: shard_map partitioning for registered Pallas kernels.

A ``pallas_call`` carries no SPMD partitioning rule, so before this module a
multi-device program had exactly two options: silently fall back to jnp
(what ``models.blocks.use_fused_kernels`` did) or fail to lower.  The paper
analog is Treibig/Hager/Wellein's point that per-domain *placement*, not
just per-core tiling, determines achieved bandwidth: a block shape tuned
for one core's cache is worthless if the thread's working set lands on the
wrong memory controller.  Here the placement rule is the kernel's
``Partitioning`` declaration -- which operand axes are batch-parallel (each
device owns a shard and launches the planned kernel on it), which are
replicated, and how per-shard scalar results combine across shards.

Every ``@register_kernel`` entry carries a declaration; ``api.launch``
detects an ambient multi-device ``jax.sharding.Mesh`` (``spmd_mesh``) and
routes through ``shard_map``:

  * in/out PartitionSpecs come from ``parallel.rules`` -- the same
    logical-axis tables the model's activations use -- restricted to the
    mesh's axes, with the divisibility fallback to replication (an odd
    batch never produces ragged shards, it replicates);
  * inside the body each shard re-derives its plan from its own *local*
    operand shape (``plan_for(..., local=True)``), memoized under
    ``(kernel, local_shape, dtype, mesh)`` -- the per-shard block shape is
    planned, not inherited from the global array;
  * scalar outputs declare their cross-shard combine (``reduce="mean"``
    for xent's token-mean NLL), applied with ``pmean``/``psum`` over the
    mesh axes the sharded operand axes actually mapped to.

Kernels whose access pattern couples neighboring sites across a split can
still partition -- they declare a ``spmd_body`` alongside their
``Partitioning`` and own the cross-shard communication themselves
(``ShardContext`` hands them the mesh axes each operand dim actually
mapped to):

  * xent shards the *vocab* axis (Megatron layout) and combines the
    per-shard online-softmax partials with a cross-shard log-sum-exp:
    ``pmax`` of the per-shard max, ``psum`` of the rescaled sum-exp and of
    the locally-gathered target logit -- three token-length fp32 vectors on
    the wire instead of a replicated (T, V) logits array;
  * jacobi shards its grid rows and issues its one-row halo ``ppermute``s
    *before* sweeping the interior stripe, so the wire time hides behind
    the interior Pallas sweep (docs/OVERLAP.md);
  * LBM shards its X axis the same way, with per-direction halo depth
    (only the 2x5 D3Q19 directions with c_x != 0 cross a cut).

The planner prices this traffic (``KernelPlan.predicted_comm_bytes``,
and the part the interior compute window cannot hide as
``predicted_exposed_comm_bytes``) so ``repro.measure.validate --comm``
can check the lowered program's collective census against the model and
``--exposed`` can check the program *structures* the collectives as
overlappable (``overlap_report`` below: a collective with some Pallas
compute independent of it in both dataflow directions can run
concurrently with that compute).  A declared sharding that cannot apply
(vocab % mesh != 0) falls back to replication with a logged reason
(``rules.spec_report``).  Kernels with neither a safe split nor a
``spmd_body`` stay ``replicated()``: every device computes the full
array.

The path never nests: inside an existing shard_map/pmap body (pipeline
stages) ``spmd_mesh`` returns None and ``launch`` stays single-device.
``plan_context(spmd=False)`` opts a scope out explicitly.
"""
from __future__ import annotations

import ast
import dataclasses
import inspect
import logging
import textwrap
from typing import Mapping

import jax
from jax.sharding import PartitionSpec as P

from repro.api import context as context_lib
from repro.obs import bus as obs_bus
from repro.obs import events as obs_events
from repro.parallel import rules as rules_lib
from repro.parallel.shardmap_compat import NO_CHECK, inside_shard_map, shard_map

__all__ = ["Partitioning", "SCALAR", "replicated", "partitioning_for",
           "spmd_mesh", "spmd_launch", "ShardContext", "shard_specs",
           "consulted_operand_dims", "overlap_report", "OverlapReport",
           "CollectiveSite"]

_log = logging.getLogger(__name__)

# Sentinel out_axes: the kernel reduces to a scalar (rank-0) result.
SCALAR = "scalar"

_REDUCES = (None, "mean", "sum")


@dataclasses.dataclass(frozen=True)
class Partitioning:
    """How one registered kernel partitions over an SPMD mesh.

    in_axes:
        one template per positional operand: a tuple of *logical* axis
        names (``parallel.rules`` vocabulary: "batch", "vocab", ...) or
        ``None`` (replicate that dim), one entry per array dimension.  An
        ``...`` (Ellipsis) entry expands to ``None`` for however many
        middle dims the operand has, so one template serves the 2-D
        kernel-level call and the 3-D model call: ``("batch", ..., None)``
        is ``("batch", None)`` for (rows, d) and ``("batch", None, None)``
        for (B, S, d).
    out_axes:
        the output's template (the output is assumed shaped like operand 0,
        the convention every registered family follows), or ``SCALAR`` for
        a rank-0 reduction result.
    reduce:
        cross-shard combine for ``SCALAR`` outputs: "mean" (xent's
        token-mean -- exact because shard_map shards are equal-sized) or
        "sum".  Required for SCALAR, forbidden otherwise.
    """

    in_axes: tuple[tuple, ...]
    out_axes: tuple | str = (...,)
    reduce: str | None = None

    def __post_init__(self):
        if self.reduce not in _REDUCES:
            raise ValueError(
                f"reduce must be one of {_REDUCES}, got {self.reduce!r}"
            )
        if self.out_axes == SCALAR and self.reduce is None:
            raise ValueError(
                "a SCALAR output needs a cross-shard reduce: each shard "
                "computes only its local partial"
            )
        if self.reduce is not None and self.out_axes != SCALAR:
            raise ValueError(
                f"reduce={self.reduce!r} only applies to SCALAR outputs"
            )


def replicated(n_inputs: int) -> Partitioning:
    """Fully-replicated declaration: every device computes the whole array.
    The right call for kernels whose stencil couples neighboring sites
    across any split (jacobi halos, LBM streaming) -- and the safe default
    for kernels registered without a declaration."""
    return Partitioning(in_axes=((...,),) * n_inputs, out_axes=(...,))


def partitioning_for(entry, n_inputs: int) -> Partitioning:
    """The entry's declared partitioning, or the replicated default for its
    ``n_inputs`` positional operands."""
    part = getattr(entry, "partitioning", None)
    return part if part is not None else replicated(n_inputs)


def _expand(template, ndim: int) -> tuple:
    """Instantiate an axes template for a rank-``ndim`` operand."""
    t = tuple(template)
    if Ellipsis in t:
        i = t.index(Ellipsis)
        head, tail = t[:i], t[i + 1:]
        n_mid = ndim - len(head) - len(tail)
        if n_mid < 0:
            raise ValueError(
                f"axes template {template} needs rank >= "
                f"{len(head) + len(tail)}, operand has rank {ndim}"
            )
        return head + (None,) * n_mid + tail
    if len(t) != ndim:
        raise ValueError(
            f"axes template {template} is rank-{len(t)}, "
            f"operand has rank {ndim}"
        )
    return t


def _dim_axes(spec: P, ndim: int) -> tuple[tuple[str, ...], ...]:
    """Per-dimension mesh axis names of a PartitionSpec, padded to rank."""
    parts = tuple(spec)
    out = []
    for d in range(ndim):
        p = parts[d] if d < len(parts) else None
        if p is None:
            out.append(())
        elif isinstance(p, str):
            out.append((p,))
        else:
            out.append(tuple(p))
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class ShardContext:
    """What a kernel's ``spmd_body`` needs to know about its placement.

    operand_axes:
        per operand, per dimension: the tuple of mesh axis names that
        dimension was actually sharded over (empty = whole on this shard --
        either declared replicated or a divisibility fallback).
    axis_sizes:
        ``{mesh axis: size}`` for the launch mesh.
    """

    operand_axes: tuple[tuple[tuple[str, ...], ...], ...]
    axis_sizes: Mapping[str, int]

    def axes(self, operand: int = 0, dim: int = 0) -> tuple[str, ...]:
        return self.operand_axes[operand][dim]

    def size(self, axes: tuple[str, ...]) -> int:
        """Number of shards along ``axes`` (1 when unsharded)."""
        n = 1
        for a in axes:
            n *= int(self.axis_sizes.get(a, 1))
        return n

    def index(self, axes: tuple[str, ...]):
        """This shard's linear index along ``axes`` (traced; 0 when
        unsharded), row-major over the axis tuple like the sharding is."""
        idx = 0
        for a in axes:
            idx = idx * int(self.axis_sizes.get(a, 1)) + jax.lax.axis_index(a)
        return idx


def consulted_operand_dims(fn) -> frozenset[tuple[int, int]] | None:
    """``(operand, dim)`` pairs ``fn`` reads via ``ShardContext.axes``.

    Static introspection for ``repro.analyze``'s declaration-drift rule:
    parses the ``spmd_body``'s source (no execution, no tracing) and
    collects every ``ctx.axes(operand, dim)`` call on the body's first
    positional parameter, resolving the defaults ``(0, 0)``.  Returns
    ``None`` when the source is unavailable (C extension, exec'd code) or
    when any ``axes`` call takes non-literal arguments -- callers must
    treat ``None`` as "unknowable", not "consults nothing".
    """
    try:
        tree = ast.parse(textwrap.dedent(inspect.getsource(fn)))
    except (OSError, TypeError, SyntaxError):
        return None
    fndef = next(
        (n for n in ast.walk(tree)
         if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))),
        None,
    )
    if fndef is None or not fndef.args.args:
        return None
    ctx_name = fndef.args.args[0].arg
    pairs: set[tuple[int, int]] = set()
    for node in ast.walk(fndef):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "axes"
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id == ctx_name):
            continue
        vals = {"operand": 0, "dim": 0}
        names = ("operand", "dim")
        if len(node.args) > len(names):
            return None
        for i, arg in enumerate(node.args):
            if not (isinstance(arg, ast.Constant)
                    and isinstance(arg.value, int)):
                return None
            vals[names[i]] = arg.value
        for kw in node.keywords:
            if (kw.arg not in vals
                    or not isinstance(kw.value, ast.Constant)
                    or not isinstance(kw.value.value, int)):
                return None
            vals[kw.arg] = kw.value.value
        pairs.add((vals["operand"], vals["dim"]))
    return frozenset(pairs)


def shard_specs(mesh, templates, arrays):
    """Build ``(in_specs, operand_axes, axis_sizes, fallbacks)`` for axis
    ``templates`` over ``arrays`` under the ambient (or default) rules,
    restricted to ``mesh``.  Shared by ``spmd_launch`` and kernel-owned
    shard_maps (xent's vocab-parallel backward)."""
    table = rules_lib.restrict_to_mesh(
        rules_lib.current_rules() or rules_lib.DEFAULT_RULES, mesh
    )
    sizes = dict(zip(tuple(mesh.axis_names), tuple(mesh.devices.shape)))
    in_specs = []
    fallbacks: list[str] = []
    for t, a in zip(templates, arrays):
        s, fb = rules_lib.spec_report(
            *_expand(t, a.ndim), rules=table,
            shape=tuple(int(x) for x in a.shape), axis_sizes=sizes)
        in_specs.append(s)
        fallbacks.extend(fb)
    in_specs = tuple(in_specs)
    operand_axes = tuple(
        _dim_axes(s, a.ndim) for s, a in zip(in_specs, arrays)
    )
    return in_specs, operand_axes, sizes, fallbacks


def _spec_mesh_axes(spec: P) -> tuple[str, ...]:
    """Every mesh axis name appearing in a PartitionSpec, in order."""
    names: list[str] = []
    for part in spec:
        if part is None:
            continue
        for n in (part,) if isinstance(part, str) else tuple(part):
            if n not in names:
                names.append(n)
    return tuple(names)


def spmd_mesh(ctx: "context_lib.PlanContext | None" = None):
    """The mesh ``launch`` would shard_map over right now, or ``None``.

    Routing requires a *real* multi-device ``jax.sharding.Mesh`` (a
    ``{axis: size}`` mapping plans shard-aligned padding but cannot place
    computation), an SPMD-enabled context, and no enclosing mapped trace
    (nesting a shard_map inside a pipeline stage's shard_map would rebind
    its axis names).  ``models.blocks.use_fused_kernels`` gates the model
    hot paths on exactly this predicate."""
    ctx = ctx if ctx is not None else context_lib.current_context()
    if not ctx.spmd:
        return None
    mesh = ctx.mesh
    if mesh is None:
        mesh = rules_lib.current_mesh()
    if not isinstance(mesh, jax.sharding.Mesh):
        return None
    if mesh.size <= 1:
        return None
    if inside_shard_map():
        return None
    return mesh


_FALLBACK_LOGGED: set[tuple] = set()


def _log_fallbacks(entry, mesh, arrays, fallbacks) -> None:
    """Record (once per kernel/shapes/mesh) every declared sharding that
    fell back to replication -- the vocab-parallel rule silently degrading
    to full-vocab shards is a real perf cliff, not an implementation
    detail.  See docs/SPMD.md ('Communication-minimal partitionings')."""
    if not fallbacks:
        return
    if obs_bus.enabled():
        # Every degraded launch emits (the obs report counts occurrences);
        # only the human-facing log line below dedups per site.
        obs_bus.emit(obs_events.SpmdFallbackEvent(
            kernel=entry.name,
            mesh=tuple(zip(tuple(mesh.axis_names),
                           tuple(mesh.devices.shape))),
            reasons=tuple(fallbacks)))
    key = (entry.name,
           tuple(tuple(int(s) for s in a.shape) for a in arrays),
           tuple(mesh.axis_names), tuple(mesh.devices.shape))
    if key in _FALLBACK_LOGGED:
        return
    _FALLBACK_LOGGED.add(key)
    _log.info(
        "SPMD launch of %r over mesh %s: declared partitioning partially "
        "replicated (%s) -- see docs/SPMD.md",
        entry.name,
        dict(zip(tuple(mesh.axis_names), tuple(mesh.devices.shape))),
        "; ".join(fallbacks),
    )


def spmd_launch(entry, mesh, arrays, scalars):
    """Launch ``entry`` on ``arrays`` partitioned over ``mesh``.

    Builds in/out specs from the kernel's declaration under the ambient
    (or default) sharding rules, then shard_maps a body over them.  A
    kernel that registered an ``spmd_body`` owns its shard body -- it
    receives a ``ShardContext`` (which mesh axes each operand dim actually
    mapped to) and performs its own halo exchange / cross-shard combine.
    Otherwise the generic body plans each shard's *local* block shape, runs
    the registered Pallas body on it, and applies the declared scalar
    reduce.  Scalar kwargs (eps, omega, ...) close over the body;
    array-valued options ride along replicated.
    """
    part = partitioning_for(entry, len(arrays))
    if len(part.in_axes) != len(arrays):
        raise ValueError(
            f"{entry.name}: partitioning declares {len(part.in_axes)} "
            f"operand(s), launch got {len(arrays)}"
        )
    in_specs, operand_axes, sizes, fallbacks = shard_specs(
        mesh, part.in_axes, arrays
    )
    _log_fallbacks(entry, mesh, arrays, fallbacks)
    if part.out_axes == SCALAR:
        out_spec = P()
        # The local partial must be combined over every mesh axis the
        # (sharded) data operand was split across; if divisibility forced
        # full replication this is empty and the local result is global.
        reduce_axes = _spec_mesh_axes(in_specs[0])
    else:
        # The output is shaped like operand 0, so its spec derives the
        # same way the inputs' did (same rules table, same divisibility).
        (out_spec,), _, _, _ = shard_specs(
            mesh, (part.out_axes,), (arrays[0],))
        reduce_axes = ()

    if entry.spmd_body is not None:
        ctx = ShardContext(operand_axes=operand_axes, axis_sizes=sizes)

        def _shard_body(*local):
            return entry.spmd_body(ctx, *local, **scalars)
    else:
        def _shard_body(*local):
            from repro.api import dispatch  # lazy: dispatch imports this module

            shape, dtype = entry.plan_args(*local, **scalars)
            plan = dispatch.plan_for(entry.name, shape, dtype, local=True)
            out = entry.body(plan, *local, **scalars)
            if reduce_axes:
                if part.reduce == "mean":
                    out = jax.lax.pmean(out, reduce_axes)
                elif part.reduce == "sum":
                    out = jax.lax.psum(out, reduce_axes)
            return out

    fn = shard_map(_shard_body, mesh=mesh, in_specs=in_specs,
                   out_specs=out_spec, **NO_CHECK)
    return fn(*arrays)


# ---------------------------------------------------------------------------
# Overlap structure analysis (validate --comm --exposed).
#
# Whether a collective's wire time *can* hide behind compute is a property
# of the program's dataflow, not of the runtime: a collective that no
# Pallas call depends on (and that depends on no Pallas call) is free to
# run concurrently with that call -- XLA's async pairs (the
# collective-permute-start/done ``lowering.collective_census`` parses in
# HLO) are exactly the latitude the scheduler takes when the dependence
# graph allows it.  The jaxpr is the right level to check this: dataflow
# is explicit, and the shard-body structure the kernels author (halo
# ppermute issued before the interior sweep, boundary stitch after) is
# still visible rather than fused away.

_COLLECTIVE_PRIMS = frozenset({
    "ppermute", "pbroadcast", "psum", "psum_invariant", "pmax", "pmin",
    "all_gather", "all_to_all", "reduce_scatter",
})
_COMPUTE_PRIMS = frozenset({"pallas_call"})


@dataclasses.dataclass(frozen=True)
class CollectiveSite:
    """One collective equation in the flattened program.

    axes:
        mesh axis names the collective communicates over (its group size
        is the product of their mesh sizes).
    result_bytes:
        per-device result size -- the same number the HLO census reads off
        the lowered op, here from the jaxpr output avals (local shapes,
        because the eqn sits inside the shard_map body).
    overlappable:
        True iff some Pallas call is independent of this collective in
        both dataflow directions, i.e. the schedule may run them
        concurrently and the wire time can hide behind that compute.
    """

    primitive: str
    axes: tuple[str, ...]
    result_bytes: int
    overlappable: bool


@dataclasses.dataclass(frozen=True)
class OverlapReport:
    collectives: tuple[CollectiveSite, ...]
    n_pallas_calls: int

    @property
    def n_overlappable(self) -> int:
        return sum(1 for c in self.collectives if c.overlappable)

    @property
    def all_overlappable(self) -> bool:
        """Every collective can hide (vacuously true with none)."""
        return all(c.overlappable for c in self.collectives)


def _sub_jaxprs(params):
    """Every Jaxpr nested in an eqn's params (unwrapping ClosedJaxpr),
    including tuples of them (cond branches)."""
    subs = []
    for v in params.values():
        for item in (v if isinstance(v, (tuple, list)) else (v,)):
            inner = getattr(item, "jaxpr", item)
            if hasattr(inner, "eqns") and hasattr(inner, "invars"):
                subs.append(inner)
    return subs


def _flatten_rows(jaxpr, var_ids, rows, counter):
    """Inline sub-jaxprs into flat ``(prim, in_ids, out_ids, avals,
    params)`` rows.

    ``var_ids`` maps jaxpr Vars to dataflow node ids; inlining binds an
    inner jaxpr's invars/outvars to the enclosing eqn's, so dependence
    chains survive the pjit/shard_map nesting ``launch`` produces.  When
    the operand lists don't align one-to-one (while, mismatched-arity
    custom calls) the eqn is bridged through a junction node that makes
    everything inside depend on everything in -- conservative: it can only
    under-report overlappability, never invent it.
    """

    def fresh():
        counter[0] += 1
        return counter[0]

    def vid(v, make=False):
        if isinstance(v, jax.core.Literal):
            return None
        if v not in var_ids:
            if not make:
                return None
            var_ids[v] = fresh()
        return var_ids[v]

    for eqn in jaxpr.eqns:
        in_ids = [i for v in eqn.invars if (i := vid(v)) is not None]
        # A pallas_call's params carry the *kernel* jaxpr -- that is the
        # compute unit itself, not program nesting to inline through.
        subs = ([] if eqn.primitive.name in _COMPUTE_PRIMS
                else _sub_jaxprs(eqn.params))
        if not subs:
            out_ids = [vid(v, make=True) for v in eqn.outvars]
            rows.append((eqn.primitive.name, in_ids, out_ids,
                         tuple(v.aval for v in eqn.outvars), eqn.params))
            continue
        aligned = all(
            len(s.invars) <= len(eqn.invars)
            and len(s.outvars) == len(eqn.outvars)
            for s in subs
        )
        if aligned:
            # pjit/shard_map/custom_* (1:1), cond (branches take the
            # operands after the predicate): tail-align invars, merge each
            # branch's outvars into the eqn's.
            branch_outs = []
            for s in subs:
                for iv, ov in zip(s.invars, eqn.invars[-len(s.invars):]):
                    oid = vid(ov)
                    if oid is not None:
                        var_ids[iv] = oid
                for cv in s.constvars:
                    var_ids.setdefault(cv, fresh())
                _flatten_rows(s, var_ids, rows, counter)
                branch_outs.append([vid(v, make=True) for v in s.outvars])
            for k, ov in enumerate(eqn.outvars):
                srcs = [bo[k] for bo in branch_outs]
                if len(subs) == 1:
                    var_ids[ov] = srcs[0]
                else:
                    rows.append((f"{eqn.primitive.name}:merge",
                                 srcs + in_ids, [vid(ov, make=True)],
                                 (ov.aval,), {}))
        else:
            # No positional alignment: junction in, junction out.
            hub = fresh()
            rows.append((f"{eqn.primitive.name}:in", in_ids, [hub], (), {}))
            inner_outs = []
            for s in subs:
                for iv in list(s.invars) + list(s.constvars):
                    var_ids[iv] = hub
                _flatten_rows(s, var_ids, rows, counter)
                inner_outs.extend(vid(v, make=True) for v in s.outvars)
            rows.append((f"{eqn.primitive.name}:out", inner_outs + [hub],
                         [vid(v, make=True) for v in eqn.outvars],
                         tuple(v.aval for v in eqn.outvars), {}))


def _aval_bytes(avals) -> int:
    total = 0
    for a in avals:
        size = getattr(a, "size", None)
        dt = getattr(a, "dtype", None)
        if size is not None and dt is not None:
            total += int(size) * dt.itemsize
    return total


def _site_axes(params) -> tuple[str, ...]:
    for key in ("axes", "axis_name"):
        v = params.get(key)
        if v is None:
            continue
        return tuple(v) if isinstance(v, (tuple, list)) else (str(v),)
    return ()


def overlap_report(fn, *args, **kwargs) -> OverlapReport:
    """Classify every collective in ``fn(*args, **kwargs)`` as
    overlappable or blocking.

    Traces ``fn`` to a jaxpr (or takes a ready-made ClosedJaxpr as
    ``fn``), inlines the pjit/shard_map nesting, and marks a collective
    overlappable iff some ``pallas_call`` is neither upstream nor
    downstream of it.  The overlapped jacobi/LBM shard bodies pass (halo
    ppermute independent of the interior sweep); the PR-5
    exchange-then-compute shape fails (every Pallas call reads the
    arrived halo).  ``validate --comm --exposed`` prices the blocking
    sites as fully exposed wire bytes.
    """
    if hasattr(fn, "jaxpr") and hasattr(fn, "consts"):
        closed = fn
    else:
        closed = jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)
    var_ids: dict = {}
    rows: list = []
    counter = [0]
    jx = closed.jaxpr
    for v in list(jx.invars) + list(jx.constvars):
        counter[0] += 1
        var_ids[v] = counter[0]
    _flatten_rows(jx, var_ids, rows, counter)

    # Ancestor bitsets in one topological pass (jaxpr eqns are ordered).
    n = len(rows)
    anc = [0] * n
    producer: dict[int, int] = {}
    for i, (_, in_ids, out_ids, _avals, _params) in enumerate(rows):
        a = 0
        for v in in_ids:
            p = producer.get(v)
            if p is not None:
                a |= anc[p] | (1 << p)
        anc[i] = a
        for v in out_ids:
            producer[v] = i

    pallas = [i for i, r in enumerate(rows) if r[0] in _COMPUTE_PRIMS]
    sites = []
    for i, (name, _in, _out, avals, params) in enumerate(rows):
        if name not in _COLLECTIVE_PRIMS:
            continue
        free = any(
            not (anc[i] >> p) & 1 and not (anc[p] >> i) & 1 for p in pallas
        )
        sites.append(CollectiveSite(
            primitive=name,
            axes=_site_axes(params),
            result_bytes=_aval_bytes(avals),
            overlappable=free,
        ))
    return OverlapReport(collectives=tuple(sites),
                         n_pallas_calls=len(pallas))
