"""PlanContext: the ambient layout policy every kernel launch plans under.

The paper's lesson (SS2.3) is that layout parameters must be *global*: the
same address->resource analysis has to govern every loop kernel, or the
erratic per-kernel numbers of Fig. 2/4 come back.  After PR 1 the planner
(``core/planner``) was authoritative but had no way to learn the mesh, the
dtype sublane policy, or the VMEM budget at the places kernels are actually
launched -- every wrapper called ``plan_kernel`` with defaults, and threading
a ``jax.sharding.Mesh`` through serving/training would have meant signature
churn at every layer.

``PlanContext`` fixes that as an *ambient* value:

    with plan_context(mesh=mesh):
        trainer.train(...)        # every kernel launched inside plans
                                  # against ``mesh`` automatically

Contexts nest; inner contexts inherit every field they do not override from
the enclosing one (``plan_overrides`` merge, inner wins).  A process-wide
default (``set_default_context``) serves launchers that configure the mesh
once at startup.  The context is thread-local, so concurrent serving threads
can plan against different meshes.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Mapping

import numpy as np

from repro.core.layout import VMEM_BYTES
from repro.core.planner import KernelPlan, sublanes_for_dtype

_UNSET = object()


@dataclasses.dataclass(frozen=True)
class PlanContext:
    """Everything the planner needs beyond (kernel, shape, dtype).

    mesh:
        a ``jax.sharding.Mesh``, a ``{axis: size}`` mapping, or ``(axis,
        size)`` pairs; widens minor-dim padding so model-axis shards stay
        lane-aligned.  ``None`` plans for a single device.
    sublane_policy:
        per-dtype sublane-tile override, keyed by numpy dtype name (e.g.
        ``{"bfloat16": 8}`` to force fp32-style tiles).  Unlisted dtypes use
        the hardware-native tile: 8 rows for 4-byte, 16 for 2-byte, 32 for
        fp8/int8.
    vmem_budget:
        per-core VMEM bytes the block chooser may assume (defaults to the
        v5e budget).
    model:
        the conflict model (``InterleavedMemoryModel``) scoring skews;
        ``None`` uses the planner default.
    plan_overrides:
        ``{kernel_name: KernelPlan}`` escape hatch -- a launch of that kernel
        at the pinned plan's exact logical shape and dtype uses it instead
        of consulting the planner; launches at any other shape fall through
        to the planner (one kernel serves many shapes in a real run).
        Keys may also be ``(kernel, shape, dtype)`` cells, which is what a
        swept profile (``repro.measure.profile.load_profile``) produces so
        one kernel can carry measured plans for many shapes; cell keys win
        over bare kernel names.
    spmd:
        whether ``launch`` may route through the shard_map SPMD path when
        the mesh is a real multi-device ``jax.sharding.Mesh`` (see
        ``repro.api.spmd``).  ``plan_context(spmd=False)`` keeps such a
        mesh planning shard-aligned padding while forcing every launch in
        the scope to stay single-device -- the lever tests use to compare
        the SPMD path against its own non-SPMD baseline, and callers use
        around code that is already inside a manual shard_map.
    """

    mesh: Any = None
    sublane_policy: Mapping[str, int] = dataclasses.field(default_factory=dict)
    vmem_budget: int = VMEM_BYTES
    model: Any = None
    plan_overrides: Mapping[str, KernelPlan] = dataclasses.field(
        default_factory=dict
    )
    spmd: bool = True

    def sublanes_for(self, dtype) -> int:
        """Sublane tile height for ``dtype`` under this context's policy."""
        dt = np.dtype(dtype)
        override = self.sublane_policy.get(dt.name)
        return sublanes_for_dtype(dt) if override is None else int(override)

    @staticmethod
    def from_profile(path: str, *, strict: bool = True,
                     **fields) -> "PlanContext":
        """A context whose ``plan_overrides`` come from a measured profile
        file (``repro.measure.sweep`` output).  Every loaded plan carries
        ``provenance="profile:<path>"`` so ``explain()`` reports where the
        layout decision actually came from.  Extra ``fields`` (mesh, ...)
        pass through to the ``PlanContext`` constructor."""
        from repro.measure.profile import load_profile  # lazy: no cycle

        return PlanContext(plan_overrides=load_profile(path, strict=strict),
                           **fields)

    def evolve(self, **changes) -> "PlanContext":
        """Derived context: fields passed as ``_UNSET`` keep this context's
        value; ``plan_overrides`` merge with the new mapping winning, and an
        explicit ``plan_overrides=None`` clears every inherited pin (the
        only way an inner scope can escape an outer override)."""
        unknown = set(changes) - {f.name for f in dataclasses.fields(self)}
        if unknown:
            raise TypeError(f"unknown PlanContext fields: {sorted(unknown)}")
        kw = {}
        for f in dataclasses.fields(self):
            v = changes.get(f.name, _UNSET)
            if v is _UNSET:
                kw[f.name] = getattr(self, f.name)
            elif f.name == "plan_overrides":
                kw[f.name] = {} if v is None else {**self.plan_overrides,
                                                   **dict(v)}
            elif f.name == "sublane_policy":
                kw[f.name] = dict(v or {})
            else:
                kw[f.name] = v
        return PlanContext(**kw)


_DEFAULT_LOCK = threading.Lock()
_default = PlanContext()
_tls = threading.local()


def _stack() -> list[PlanContext]:
    st = getattr(_tls, "stack", None)
    if st is None:
        st = _tls.stack = []
    return st


def current_context() -> PlanContext:
    """The innermost active ``plan_context``, else the process default."""
    st = _stack()
    return st[-1] if st else _default


def set_default_context(ctx: PlanContext) -> PlanContext:
    """Install the process-wide default context (returned for chaining).
    Launchers call this once at startup so every thread plans against the
    production mesh without per-call plumbing."""
    global _default
    if not isinstance(ctx, PlanContext):
        raise TypeError(f"expected PlanContext, got {type(ctx).__name__}")
    with _DEFAULT_LOCK:
        _default = ctx
    return ctx


def get_default_context() -> PlanContext:
    return _default


def reset_default_context() -> None:
    """Restore the built-in default (tests)."""
    set_default_context(PlanContext())


@contextlib.contextmanager
def plan_context(mesh=_UNSET, *, sublane_policy=_UNSET, vmem_budget=_UNSET,
                 model=_UNSET, plan_overrides=_UNSET, spmd=_UNSET):
    """Enter a derived ``PlanContext``; unspecified fields inherit from the
    enclosing context (or the process default at the outermost level)."""
    base = current_context()
    ctx = base.evolve(mesh=mesh, sublane_policy=sublane_policy,
                      vmem_budget=vmem_budget, model=model,
                      plan_overrides=plan_overrides, spmd=spmd)
    st = _stack()
    st.append(ctx)
    try:
        yield ctx
    finally:
        st.pop()
