"""Declarative kernel registry: one place where every Pallas family lives.

Each kernel family registers itself with

    @register_kernel("stream.triad",
                     signature=StreamSignature(n_read=2, n_write=1),
                     ref=sref.triad, plan_args=_plan_args_1d)
    def _stream_triad(plan, b, c, *, s): ...

declaring, in one spot, everything the unified launch path needs:

  * ``signature`` -- the paper's "data access properties" row (how many
    read/write streams the kernel drives against HBM).  Registration pushes
    it into ``core.planner.FAMILIES`` via ``register_family``, so the
    planner's analysis and the executable kernel can never drift; a name
    registered twice with a different signature or body raises (shadowed
    name) instead of silently replacing the kernel.
  * ``ref`` -- the pure-jnp oracle with the same calling convention as
    ``launch``, so parity tests and fallbacks are mechanical.
  * ``plan_args`` -- how to derive the *logical planning shape* from the
    call's arrays (1-D streams plan on ``a.shape``; rmsnorm flattens leading
    dims; jacobi plans its interior rows; LBM plans the whole lattice).
  * ``partitioning`` -- the SPMD placement rule (``repro.api.spmd``): which
    operand axes are batch-parallel over a multi-device mesh, which stay
    replicated, and how scalar results combine across shards.  ``launch``
    uses it to route through shard_map when an ambient multi-device Mesh is
    set; kernels registered without one run fully replicated.
  * the decorated function -- the Pallas launch body, taking the resolved
    ``KernelPlan`` first: ``body(plan, *arrays, **scalars)``.

Entries are resolved lazily: ``resolve("rmsnorm")`` imports
``repro.kernels.rmsnorm.ops`` on first use, so ``repro.api`` never has an
import cycle with the kernels package and ``launch`` works without the
caller pre-importing anything.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable

from repro.api.spmd import Partitioning
from repro.core import planner as planner_lib
from repro.core.autotune import StreamSignature

# family prefix of a registered name -> module whose import registers it
FAMILY_MODULES: dict[str, str] = {
    "stream": "repro.kernels.stream.ops",
    "triad": "repro.kernels.triad.ops",
    "jacobi": "repro.kernels.jacobi.ops",
    "lbm": "repro.kernels.lbm.ops",
    "rmsnorm": "repro.kernels.rmsnorm.ops",
    "xent": "repro.kernels.xent.ops",
}


@dataclasses.dataclass(frozen=True)
class KernelEntry:
    """One registered kernel: analysis + oracle + Pallas body."""

    name: str
    signature: StreamSignature
    ref: Callable
    plan_args: Callable      # (*arrays, **scalars) -> (shape, dtype)
    body: Callable           # (plan, *arrays, **scalars) -> result
    partitioning: Partitioning | None = None  # SPMD rule (None = replicated)
    # Kernel-owned shard_map body: (ShardContext, *local, **scalars) -> out.
    # Declared by kernels whose partitioning needs cross-shard communication
    # (xent's lse combine, jacobi's halo exchange); None = the generic
    # plan-locally-and-launch body in ``repro.api.spmd``.
    spmd_body: Callable | None = None
    # Representative (shape, dtype[, knobs]) cells ``repro.analyze`` plans
    # when walking the registry statically -- knobs is an optional dict of
    # planner overrides ({"sublanes": ..., "vmem_budget": ...}).  Empty =
    # the analyzer falls back to the measure/validate representative cells
    # for the kernel.  Kernels with unusual geometry (fixtures, future
    # families outside the validation matrix) declare their own.
    analysis_cells: tuple[tuple, ...] = ()
    doc: str = ""


_REGISTRY: dict[str, KernelEntry] = {}


def register_kernel(
    name: str,
    *,
    signature: StreamSignature,
    ref: Callable,
    plan_args: Callable,
    partitioning: Partitioning | None = None,
    spmd_body: Callable | None = None,
    vmem_buffers: int | None = None,
    col_tiled: bool = False,
    analysis_cells=(),
    doc: str = "",
):
    """Decorator: declare a kernel family's streams and launch body.

    ``vmem_buffers``/``col_tiled`` feed the planner's block-geometry tables
    (see ``core.planner.register_family``).  ``partitioning`` is the SPMD
    placement rule (``repro.api.spmd.Partitioning``); omitted, the kernel
    runs fully replicated under a multi-device mesh.  ``spmd_body`` is the
    kernel-owned shard_map body for partitionings that communicate
    (``repro.api.spmd.ShardContext`` first argument); it requires a
    ``partitioning`` to shard anything in the first place.  ``analysis_cells``
    are representative ``(shape, dtype)`` pairs the static analyzer
    (``repro.analyze``) plans for this kernel; omitted, it uses the
    validation suite's representative cells.
    """

    def deco(body: Callable) -> Callable:
        prev = _REGISTRY.get(name)
        # Same module + qualname = an idempotent re-import; anything else
        # (including a same-named function from another module) is a shadow.
        if prev is not None and (
                prev.body.__module__ != body.__module__
                or prev.body.__qualname__ != body.__qualname__):
            raise ValueError(
                f"kernel {name!r} already registered by "
                f"{prev.body.__module__}.{prev.body.__qualname__}; "
                f"refusing shadow registration"
            )
        # Validate before register_family mutates planner state: a failed
        # registration must not leave a phantom family the planner can plan
        # but the registry cannot launch.
        if partitioning is not None and not isinstance(partitioning,
                                                       Partitioning):
            raise TypeError(
                f"kernel {name!r}: partitioning must be a "
                f"repro.api.spmd.Partitioning, got "
                f"{type(partitioning).__name__}"
            )
        if spmd_body is not None and partitioning is None:
            raise TypeError(
                f"kernel {name!r}: spmd_body without a partitioning is "
                f"unreachable -- declare which axes shard first"
            )
        planner_lib.register_family(name, signature,
                                    vmem_buffers=vmem_buffers,
                                    col_tiled=col_tiled)
        _REGISTRY[name] = KernelEntry(
            name=name,
            signature=signature,
            ref=ref,
            plan_args=plan_args,
            body=body,
            partitioning=partitioning,
            spmd_body=spmd_body,
            analysis_cells=tuple(
                (tuple(int(s) for s in cell[0]), str(cell[1]), *cell[2:])
                for cell in analysis_cells
            ),
            doc=doc or (body.__doc__ or "").strip(),
        )
        return body

    return deco


def resolve(name: str) -> KernelEntry:
    """Entry for ``name``, importing its family module on first use."""
    entry = _REGISTRY.get(name)
    if entry is not None:
        return entry
    module = FAMILY_MODULES.get(name.split(".")[0])
    if module is not None:
        importlib.import_module(module)
        entry = _REGISTRY.get(name)
        if entry is not None:
            return entry
    raise KeyError(
        f"no kernel registered as {name!r}; known: {sorted(_REGISTRY)}"
        f" (families: {sorted(FAMILY_MODULES)})"
    )


def get_kernel(name: str) -> KernelEntry:
    """Public alias of :func:`resolve`."""
    return resolve(name)


def list_kernels(*, import_all: bool = True) -> list[str]:
    """Sorted names of every registered kernel.  With ``import_all`` (the
    default) every family module is imported first, so the listing is the
    complete surface, not just what happens to be loaded."""
    if import_all:
        for module in FAMILY_MODULES.values():
            importlib.import_module(module)
    return sorted(_REGISTRY)


def entries(*, import_all: bool = True) -> list[KernelEntry]:
    """Every registered :class:`KernelEntry`, in name order -- the static
    analyzer's walk surface (``repro.analyze`` iterates this instead of
    resolving names one at a time)."""
    return [_REGISTRY[k] for k in list_kernels(import_all=import_all)]
