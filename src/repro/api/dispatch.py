"""The unified kernel-launch path: ``launch(kernel, *arrays, **scalars)``.

One entry point replaces six hand-rolled wrappers:

    from repro import api
    y = api.launch("stream.triad", b, c, s=3.0)

``launch`` resolves the registered entry (lazily importing its family),
derives the logical planning shape from the arrays, asks the analytic
planner for the memoized ``KernelPlan`` under the ambient ``PlanContext``
(mesh, dtype->sublane policy, VMEM budget, overrides), validates that the
plan actually agrees with the arrays, and hands both to the registered
Pallas body.  Every kernel family therefore plans through exactly the same
policy -- the paper's requirement that one layout analysis governs all loop
kernels -- and a mesh set once via ``plan_context(mesh=...)`` reaches the
planner from any call site without signature churn.

When the ambient mesh is a *real* multi-device ``jax.sharding.Mesh``,
``launch`` routes through the SPMD path instead (``repro.api.spmd``): the
kernel's registered ``Partitioning`` becomes shard_map in/out specs, and
each shard plans its own local block shape.  Single-device programs (and
scopes under ``plan_context(spmd=False)``) keep the direct path below.
"""
from __future__ import annotations

import warnings

import numpy as np

from repro.api import context as context_lib
from repro.api import registry as registry_lib
from repro.api import spmd as spmd_lib
from repro.core.planner import KernelPlan, plan_cache_info, plan_kernel
from repro.obs import bus as obs_bus
from repro.obs import events as obs_events

__all__ = ["launch", "plan_for", "explain", "ref"]


def plan_for(kernel: str, shape, dtype, *, ctx=None,
             local: bool = False) -> KernelPlan:
    """The plan ``launch`` would use for ``kernel`` on (shape, dtype) under
    the ambient (or given) ``PlanContext``.  Requires the kernel to be
    registered -- unknown names fail here, not at launch time.

    ``local=True`` plans a *per-shard* launch (the SPMD path): the shape is
    one device's shard, so the minor dim is not widened again for the
    mesh's tensor-parallel axis -- the mesh still keys the memo entry."""
    entry = registry_lib.resolve(kernel)
    ctx = ctx or context_lib.current_context()
    # Overrides are keyed two ways: a bare kernel name pins one plan for
    # that kernel (the PR-2 escape hatch), while a (kernel, shape, dtype)
    # cell key -- what ``repro.measure.profile.load_profile`` emits -- lets a
    # swept profile carry many shapes of the same kernel.  The cell key wins.
    cell = (entry.name, tuple(int(s) for s in shape), np.dtype(dtype).name)
    override = ctx.plan_overrides.get(cell)
    if override is None:
        override = ctx.plan_overrides.get(entry.name)
    if override is not None and _matches(entry, override, shape, dtype):
        # A pinned plan applies only to the exact case it was built for;
        # the same kernel launched at any other shape/dtype falls through
        # to the planner (real runs launch one kernel at many shapes).
        if obs_bus.enabled():
            obs_bus.emit(obs_events.PlanEvent(
                kernel=entry.name, shape=tuple(override.logical_shape),
                dtype=override.dtype, cache="override",
                source=override.provenance, local=bool(local),
                mesh=tuple(override.mesh)))
        return override
    # Observed plans report whether the memoized planner cache served them:
    # the miss counter moving across this call is the hit/miss signal (the
    # cache is process-global, so concurrent planning threads can at worst
    # misattribute a hit -- telemetry, not accounting).
    track = obs_bus.enabled()
    misses_before = plan_cache_info()["misses"] if track else 0
    plan = plan_kernel(
        entry.name, shape, dtype,
        mesh=ctx.mesh,
        model=ctx.model,
        sublanes=ctx.sublanes_for(dtype),
        vmem_budget=ctx.vmem_budget,
        local=local,
    )
    if track:
        cache = ("miss" if plan_cache_info()["misses"] > misses_before
                 else "hit")
        obs_bus.emit(obs_events.PlanEvent(
            kernel=entry.name, shape=tuple(plan.logical_shape),
            dtype=plan.dtype, cache=cache, source=plan.provenance,
            local=bool(local), mesh=tuple(plan.mesh)))
    return plan


def plan_tile(kernel: str, shape, dtype, *, vmem_budget: int | None = None,
              ctx=None, mesh=None) -> KernelPlan:
    """Page/tile-size plan query: the plan of ``kernel`` over ``shape`` with
    an explicit per-tile ``vmem_budget`` layered onto the ambient (or
    given) context.  The serving paged KV cache sizes its pages from the
    returned plan's ``block_rows`` (serving.paged_cache.plan_page_geometry)
    -- the same closed-form block chooser that tiles every kernel launch,
    so cache pages and kernel blocks follow one layout policy."""
    ctx = ctx or context_lib.current_context()
    if mesh is not None:
        ctx = ctx.evolve(mesh=mesh)
    if vmem_budget is not None:
        ctx = ctx.evolve(vmem_budget=int(vmem_budget))
    return plan_for(kernel, shape, dtype, ctx=ctx)


def _matches(entry, plan: KernelPlan, shape, dtype) -> bool:
    return (plan.kernel == entry.name
            and tuple(plan.logical_shape) == tuple(int(s) for s in shape)
            and plan.dtype == np.dtype(dtype).name)


def _validate(entry, plan: KernelPlan, shape, dtype) -> None:
    """Plan <-> array agreement: a stale or hand-built plan must never
    silently drop tail elements or run a kernel at the wrong dtype."""
    if plan.kernel != entry.name:
        raise ValueError(
            f"plan is for kernel {plan.kernel!r}, launched {entry.name!r}"
        )
    if tuple(plan.logical_shape) != tuple(int(s) for s in shape):
        raise ValueError(
            f"plan {plan.kernel} is for shape {plan.logical_shape}, "
            f"got arrays of logical shape {tuple(shape)}"
        )
    if plan.dtype != np.dtype(dtype).name:
        raise ValueError(
            f"plan {plan.kernel} is for dtype {plan.dtype}, "
            f"got {np.dtype(dtype).name}"
        )


def launch(kernel: str, *arrays, plan: KernelPlan | None = None, **scalars):
    """Run a registered kernel on ``arrays`` under the ambient PlanContext.

    With an ambient multi-device ``jax.sharding.Mesh`` (and no pinned
    ``plan``), the launch partitions over the mesh via shard_map using the
    kernel's registered ``Partitioning``; each shard plans its local block
    shape (``repro.api.spmd``).  Otherwise ``plan`` pins an explicit
    ``KernelPlan`` (still validated), else the context's ``plan_overrides``
    and then the memoized planner decide.  Scalars (including optional
    array-valued options like LBM's ``mask``) pass through as keywords to
    the registered body.
    """
    entry = registry_lib.resolve(kernel)
    if plan is None:
        mesh = spmd_lib.spmd_mesh()
        if mesh is not None:
            # plan_args is not derived for planning here: the shard body
            # re-derives it from each shard's local arrays (validation
            # included).  The warning helper derives the *global* shape
            # only to tell shadowed override cells from live local ones.
            _warn_spmd_shadowed_overrides(entry, mesh, arrays, scalars)
            return spmd_lib.spmd_launch(entry, mesh, arrays, scalars)
    shape, dtype = entry.plan_args(*arrays, **scalars)
    if plan is None:
        plan = plan_for(kernel, shape, dtype)
    _validate(entry, plan, shape, dtype)
    return entry.body(plan, *arrays, **scalars)


_SPMD_OVERRIDE_WARNED: set[tuple] = set()


def _warn_spmd_shadowed_overrides(entry, mesh, arrays, scalars) -> None:
    """Under the SPMD route, plans resolve inside the shard body against
    *local* shapes -- so a profile swept at global shapes (or a bare-name
    pin recorded at the global shape) silently never matches.  Say so once
    per (kernel, mesh) -- the same override set can be live on one mesh's
    shard shapes and inert on another's -- naming the offending cell keys,
    instead of letting --plan-profile look active but be inert.  Override
    cells keyed at any *other* shape are assumed to be per-shard local
    cells (the documented SPMD sweep workflow) and do not warn."""
    ctx = context_lib.current_context()
    keys = [k for k in ctx.plan_overrides
            if k == entry.name
            or (isinstance(k, tuple) and k and k[0] == entry.name)]
    if not keys:
        return
    gshape = tuple(int(s) for s in entry.plan_args(*arrays, **scalars)[0])
    offending = sorted(
        str(k) for k in keys
        if (tuple(ctx.plan_overrides[k].logical_shape) == gshape
            if k == entry.name else tuple(k[1]) == gshape)
    )
    if not offending:
        return
    if obs_bus.enabled():
        # The event is per-occurrence (the report counts live hazards);
        # only the human-facing warning below dedups per (kernel, mesh).
        obs_bus.emit(obs_events.SpmdOverrideShadowEvent(
            kernel=entry.name,
            mesh=tuple(zip(tuple(mesh.axis_names),
                           tuple(mesh.devices.shape))),
            global_shape=gshape, cells=tuple(offending)))
    mesh_key = (entry.name, tuple(mesh.axis_names),
                tuple(mesh.devices.shape))
    if mesh_key in _SPMD_OVERRIDE_WARNED:
        return
    _SPMD_OVERRIDE_WARNED.add(mesh_key)
    mesh_desc = dict(zip(tuple(mesh.axis_names), tuple(mesh.devices.shape)))
    warnings.warn(
        f"plan override(s) for {entry.name!r} under SPMD mesh {mesh_desc}: "
        f"overrides are matched against per-shard *local* shapes inside "
        f"shard_map, and these cell key(s) are keyed at the launch's "
        f"global shape {gshape} -- they will be inert unless a shard's "
        f"local shape coincides with it (offending cell key(s): "
        f"{', '.join(offending)}). Sweep at the per-shard shapes to pin "
        f"plans on SPMD runs -- see docs/SPMD.md ('Per-shard planning')",
        RuntimeWarning, stacklevel=3,
    )


def ref(kernel: str, *arrays, **scalars):
    """The registered pure-jnp oracle, same calling convention as launch."""
    return registry_lib.resolve(kernel).ref(*arrays, **scalars)


def explain(kernel: str, shape, dtype) -> str:
    """Human-readable plan report for any registered kernel under the
    ambient context (the dry-run analogue of the paper's parameter table)."""
    return plan_for(kernel, shape, dtype).explain()
