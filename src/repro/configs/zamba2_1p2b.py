"""zamba2-1.2b [hybrid]: Mamba2 backbone + shared attention block.
[arXiv:2411.15242; hf]  38L d_model=2048 32H(kv=32) d_ff=8192 vocab=32000,
ssm_state=64.  Shared transformer block applied every 6 mamba layers with
concat([h, h0]) input projection (Zamba-style weight sharing)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32000,
    ssm_state=64, ssm_conv=4, ssm_expand=2, ssm_head_dim=64,
    shared_attn_period=6, rope_theta=10_000.0,
    # SSPerf x5: mixed TP sharding (replicated 4-head blocks + sharded
    # d_inner) is reshard-bound; ZeRO-3 cuts collective 4.15 -> 0.45 s
    parallelism="zero3",
)
SCHEDULE = "cosine"
