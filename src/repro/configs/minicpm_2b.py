"""minicpm-2b [dense]: llama-like with mup-style scaling + WSD schedule.
[arXiv:2404.06395; hf]  40L d_model=2304 36H(kv=36) d_ff=5760 vocab=122753.
Tied embeddings; embed x12; residual x(1.4/sqrt(40)); logits x(256/2304)."""
import math

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36,
    d_ff=5760, vocab_size=122753,
    tie_embeddings=True,
    embed_scale=12.0,
    residual_scale=1.4 / math.sqrt(40),
    logit_scale=256.0 / 2304.0,
    rope_theta=10_000.0,
    # SSPerf minicpm iteration 3: at 2.7B params a 256-way ZeRO-3 layout
    # beats 16-way TP (collective 7.3s -> 1.0s); TP stays for serve cells.
    parallelism="zero3",
)
SCHEDULE = "wsd"  # the paper's warmup-stable-decay schedule (optim/schedules.py)
