"""qwen3-moe-30b-a3b [moe]: 128 experts top-8, the skewed-expert-placement
showcase. [hf:Qwen/Qwen3-30B-A3B; hf]  48L d_model=2048 32H(kv=4)
per-expert d_ff=768 vocab=151936, head_dim=128, qk_norm."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=0, moe_d_ff=768, n_experts=128, top_k=8,
    vocab_size=151936, head_dim=128,
    qk_norm=True, skewed_experts=True, fsdp=True,
    capacity_factor=1.25, rope_theta=1_000_000.0,
)
SCHEDULE = "cosine"
