"""pixtral-12b [vlm]: pixtral-ViT frontend (STUB: input_specs supplies
precomputed patch embeddings) + mistral-nemo-like decoder backbone.
[hf:mistralai/Pixtral-12B-2409; unverified]
40L d_model=5120 32H(kv=8) d_ff=14336 vocab=131072, head_dim=128."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=131072, head_dim=128,
    n_img_tokens=1024, rope_theta=1_000_000.0, fsdp=True,
)
SCHEDULE = "cosine"
