"""qwen2-0.5b [dense]: GQA with QKV bias. [arXiv:2407.10671; hf]
24L d_model=896 14H(kv=2) d_ff=4864 vocab=151936.  The pool's worst
mesh-misfit: 14 heads / 2 kv heads on a 16-wide model axis (layout-policy
showcase: head padding)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b", family="dense",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    d_ff=4864, vocab_size=151936,
    qkv_bias=True, tie_embeddings=True, rope_theta=1_000_000.0,
    # analytic TP-vs-ZeRO rule (DESIGN.md SS7): 3*params/layer (0.12 GB)
    # < TP-AR traffic (0.44 GB) at 0.5B params -> ZeRO-3 for train
    parallelism="zero3",
)
SCHEDULE = "cosine"
