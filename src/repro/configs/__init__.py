"""Architecture registry: --arch <id> resolution + reduced smoke configs."""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig

_MODULES = {
    "zamba2-1.2b": "zamba2_1p2b",
    "minicpm-2b": "minicpm_2b",
    "qwen3-4b": "qwen3_4b",
    "qwen2-0.5b": "qwen2_0p5b",
    "qwen3-14b": "qwen3_14b",
    "pixtral-12b": "pixtral_12b",
    "xlstm-1.3b": "xlstm_1p3b",
    "grok-1-314b": "grok_1_314b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b",
    "whisper-tiny": "whisper_tiny",
}

ARCHS = tuple(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}").CONFIG


def get_schedule(name: str) -> str:
    return importlib.import_module(f"repro.configs.{_MODULES[name]}").SCHEDULE


def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Same family/block structure, laptop-sized dims (per assignment:
    smoke tests instantiate a REDUCED config of the same family)."""
    heads = min(cfg.n_heads, 4)
    kv = max(1, min(cfg.n_kv_heads, heads))
    kw: dict = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=128,
        n_heads=heads,
        n_kv_heads=kv,
        d_ff=min(cfg.d_ff, 256) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        dtype="float32",
        remat=False,
        fsdp=False,
    )
    if cfg.head_dim:
        kw["head_dim"] = 32
    if cfg.n_experts:
        kw["n_experts"] = min(cfg.n_experts, 8)
        kw["moe_d_ff"] = min(cfg.moe_d_ff, 128)
        kw["capacity_factor"] = 4.0
    if cfg.family == "hybrid":
        kw["shared_attn_period"] = 2
        kw["ssm_state"] = 16
        kw["ssm_head_dim"] = 32
    if cfg.family == "ssm" and cfg.slstm_every:
        kw["slstm_every"] = 4
    if cfg.family == "encdec":
        kw["n_enc_layers"] = 2
        kw["n_frames"] = 16
    if cfg.family == "vlm":
        kw["n_img_tokens"] = 8
    if cfg.vocab_logical:
        kw["vocab_logical"] = 0
    return dataclasses.replace(cfg, **kw)
