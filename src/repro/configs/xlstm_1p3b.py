"""xlstm-1.3b [ssm]: mLSTM + sLSTM blocks. [arXiv:2405.04517; unverified]
48L d_model=2048 4H d_ff=0 vocab=50304.  7:1 mLSTM:sLSTM ratio
(slstm_every=8); mLSTM proj_factor 2 -> d_inner=4096, P=1024 per head."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304, slstm_every=8,
    # SSPerf x6: 4 heads can never cover a 16-way TP axis; ZeRO-3 cuts
    # collective 11.3 -> 0.50 s and memory 9.2 -> 1.7 s
    parallelism="zero3",
)
SCHEDULE = "cosine"
