"""grok-1-314b [moe]: 8 experts top-2, attention/logit softcap 30.
[hf:xai-org/grok-1; unverified]  64L d_model=6144 48H(kv=8) d_ff=32768
vocab=131072.  Few big experts -> TP *inside* experts (expert_tp), FSDP for
the 314B parameter set, no fp32 master copy (see optim/)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=0, moe_d_ff=32768, n_experts=8, top_k=2,
    vocab_size=131072, head_dim=128,
    attn_softcap=30.0, logit_softcap=30.0, act="gelu",
    expert_tp=True, fsdp=True, capacity_factor=1.25,
    rope_theta=10_000.0,
)
SCHEDULE = "cosine"
