"""qwen3-14b [dense]: GQA + qk_norm, largest dense of the pool.
[hf:Qwen/Qwen3-8B family; hf]  40L d_model=5120 40H(kv=8) d_ff=17408
vocab=151936, head_dim=128."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=17408, vocab_size=151936, head_dim=128,
    qk_norm=True, rope_theta=1_000_000.0, fsdp=True,
)
SCHEDULE = "cosine"
