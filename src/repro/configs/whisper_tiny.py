"""whisper-tiny [audio]: enc-dec, conv frontend STUB (input_specs supplies
precomputed frame embeddings). [arXiv:2212.04356; unverified]
4L enc + 4L dec, d_model=384 6H(kv=6) d_ff=1536 vocab=51865.
Positions are sinusoidal (learned-table deviation noted in DESIGN.md)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="encdec",
    n_layers=4, n_enc_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab_size=51865, n_frames=1500,
    norm="layernorm", act="gelu", tie_embeddings=True,
    parallelism="zero3",  # 41M params: same analytic rule as qwen2/minicpm
)
SCHEDULE = "cosine"
