"""Assigned input-shape sets and abstract input specs.

Every LM architecture is paired with the four standard cells:

    train_4k     seq 4096,   global batch 256   (train_step)
    prefill_32k  seq 32768,  global batch 32    (prefill_step)
    decode_32k   cache 32768, global batch 128  (serve_step: 1 new token)
    long_500k    cache 524288, global batch 1   (serve_step; sub-quadratic
                                                 families only, per assignment)

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, zero allocation) for every model input of a given (arch, shape)
cell -- the dry-run lowers against these directly.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import build_model
from repro.models.config import ModelConfig
from repro.models.params import abstract_params


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic families."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k skipped: pure full-attention arch (see DESIGN.md)"
    return True, ""


def _i32(*shape) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Abstract model inputs for one cell (excluding params/cache)."""
    b, s = shape.global_batch, shape.seq_len
    dt = cfg.adtype
    if shape.kind in ("train", "prefill"):
        if cfg.family == "encdec":
            return {
                "tokens": _i32(b, s),
                "labels": _i32(b, s),
                "frames": jax.ShapeDtypeStruct((b, cfg.n_frames, cfg.d_model), dt),
            }
        batch: dict = {"tokens": _i32(b, s), "labels": _i32(b, s)}
        if cfg.family == "vlm":
            text = max(s - cfg.n_img_tokens, 1)
            batch = {
                "tokens": _i32(b, text),
                "labels": _i32(b, text),
                "img_embeds": jax.ShapeDtypeStruct(
                    (b, cfg.n_img_tokens, cfg.d_model), dt
                ),
            }
        return batch
    # decode: one new token against a seq_len-deep cache
    model = build_model(cfg)
    cache = abstract_params(model.cache_defs(b, s))
    return {"tokens": _i32(b, 1), "cache": cache}
