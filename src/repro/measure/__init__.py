"""Measured-vs-predicted validation for the analytic layout planner.

The paper derives padding/skew parameters analytically ("no trial and
error is required", SS2.3) -- but *validates* the claim by measuring real
bandwidth against the channel-conflict model (the Fig. 4 envelope).  This
package is that loop for the TPU port:

  * ``validate`` -- lower every registry kernel at its planned block shape,
    extract HLO bytes-accessed/FLOPs from ``cost_analysis()``, and check
    them against ``KernelPlan.predicted_hbm_bytes`` within per-family
    tolerance envelopes (``results/validation.json``).
  * ``sweep`` -- sweep sublane tiles / VMEM budgets per (kernel, shape,
    dtype) cell around the analytic choice, score candidates by compiled
    bytes (and wall time on a real backend), emit a profile.
  * ``profile`` -- the versioned profile format plus ``load_profile`` /
    ``save_profile``, so ``PlanContext(plan_overrides=load_profile(path))``
    replays a measured sweep in any launcher.
"""
from repro.measure.profile import (
    PROFILE_FORMAT,
    PROFILE_VERSION,
    entry_from_plan,
    load_profile,
    profile_key,
    save_profile,
)

__all__ = [
    "PROFILE_FORMAT", "PROFILE_VERSION",
    "entry_from_plan", "load_profile", "profile_key", "save_profile",
]
