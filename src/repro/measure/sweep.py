"""Block-shape sweeps around the analytic plan, emitting a loadable profile.

The planner's closed form is the default; this harness is the escape hatch
the ROADMAP calls "autotuned plan_overrides": per (kernel, shape, dtype)
cell it varies the planner's two measurable knobs -- the sublane tile and
the VMEM budget handed to the block chooser -- compiles each distinct
resulting plan, and scores candidates by compiled HLO bytes (and wall time
when a real backend is present / ``--time`` is passed).  The winner is
serialized via ``repro.measure.profile`` so
``PlanContext(plan_overrides=load_profile(path))`` replays the measured
choice in any launcher.

Sweeping *knobs* rather than raw block tuples keeps every candidate a plan
the planner itself would produce (padded/block geometry always mutually
consistent), and makes the profile replayable: the file records the knobs,
loading re-derives the plan and cross-checks the geometry.

Usage:
    python -m repro.measure.sweep --cell rmsnorm:1016,1111:float32
    python -m repro.measure.sweep --all --out results/profile.json
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro import api
from repro.core.planner import plan_kernel
from repro.measure import profile as profile_lib
from repro.measure import validate as validate_lib

PROFILE_OUT_DEFAULT = "results/profile.json"

SUBLANE_CANDIDATES = (8, 16, 32)
# Budget dividers: 1 is the analytic default; larger dividers shrink the
# block, which can *reduce* padding for awkward row counts (a row count
# with no block-sized divisor is rounded up a whole block by `_fit_block`).
BUDGET_DIVIDERS = (1, 4, 16, 64)


@dataclasses.dataclass(frozen=True)
class Candidate:
    knobs: dict
    plan: object
    measured: dict

    @property
    def score(self) -> tuple:
        wall = self.measured.get("wall_s")
        return (
            wall if wall is not None else float("inf"),
            self.measured["bytes"],
            self.plan.predicted_hbm_bytes,
        )


@dataclasses.dataclass(frozen=True)
class SweepResult:
    kernel: str
    shape: tuple
    dtype: str
    default_plan: object
    candidates: tuple
    best: Candidate

    @property
    def changed(self) -> bool:
        """Did measurement override the analytic choice?"""
        d, b = self.default_plan, self.best.plan
        return (d.padded_shape, d.block_shape) != (b.padded_shape,
                                                   b.block_shape)

    def entry(self) -> dict:
        return profile_lib.entry_from_plan(
            self.best.plan, self.best.knobs,
            score={"hlo_bytes": self.best.measured["bytes"],
                   "flops": self.best.measured["flops"],
                   "wall_s": self.best.measured["wall_s"],
                   "changed": self.changed},
        )


def candidate_knobs(dtype, ctx=None) -> list[dict]:
    """Knob grid centred on the ambient context's analytic choice."""
    ctx = ctx or api.current_context()
    base_sub = ctx.sublanes_for(dtype)
    budget = ctx.vmem_budget
    subs = sorted({base_sub, *SUBLANE_CANDIDATES})
    return [
        {"sublanes": s, "vmem_budget": max(budget // d, 1)}
        for s in subs for d in BUDGET_DIVIDERS
    ]


def sweep_cell(kernel: str, shape, dtype, *, ctx=None,
               timed: bool = False) -> SweepResult:
    """Measure every distinct candidate plan for one cell."""
    ctx = ctx or api.current_context()
    shape = tuple(int(s) for s in shape)
    default_plan = api.plan_for(kernel, shape, dtype, ctx=ctx)
    seen: dict[tuple, Candidate] = {}
    for knobs in candidate_knobs(dtype, ctx):
        plan = plan_kernel(kernel, shape, dtype, mesh=ctx.mesh,
                           model=ctx.model, **knobs)
        geom = (plan.padded_shape, plan.block_shape)
        if geom in seen:
            continue
        measured = validate_lib.measure_cell(kernel, shape, dtype, plan=plan,
                                             timed=timed)
        seen[geom] = Candidate(knobs=knobs, plan=plan, measured=measured)
    candidates = tuple(seen.values())
    best = min(candidates, key=lambda c: c.score)
    best = dataclasses.replace(
        best, plan=dataclasses.replace(best.plan, provenance="sweep"))
    return SweepResult(kernel=kernel, shape=shape,
                       dtype=str(jax.numpy.dtype(dtype).name),
                       default_plan=default_plan, candidates=candidates,
                       best=best)


def sweep_cells(cells, *, timed: bool = False) -> list[SweepResult]:
    return [sweep_cell(k, s, d, timed=timed) for k, s, d in cells]


def _parse_cell(spec: str) -> tuple[str, tuple[int, ...], str]:
    """'kernel:r,c:dtype' -> (kernel, (r, c), dtype)."""
    try:
        kernel, shape_s, dtype = spec.split(":")
        shape = tuple(int(x) for x in shape_s.split(",") if x)
    except ValueError as e:
        raise SystemExit(f"bad --cell {spec!r} (want kernel:dims:dtype): {e}")
    return kernel, shape, dtype


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="sweep block-shape knobs per cell, emit a plan profile")
    ap.add_argument("--cell", action="append", default=[],
                    help="kernel:dims:dtype, e.g. rmsnorm:1016,1111:float32")
    ap.add_argument("--all", action="store_true",
                    help="sweep every validate.CASES cell")
    ap.add_argument("--time", action="store_true",
                    help="also execute and score by wall time "
                         "(default on non-CPU backends)")
    ap.add_argument("--out", default=PROFILE_OUT_DEFAULT)
    args = ap.parse_args(argv)

    cells = [_parse_cell(c) for c in args.cell]
    if args.all:
        cells += [(k, shape, dtype)
                  for k, (shape, dtype) in validate_lib.CASES.items()]
    if not cells:
        ap.error("pass --cell or --all")
    timed = args.time or jax.default_backend() != "cpu"

    results = sweep_cells(cells, timed=timed)
    for r in results:
        mark = "SWEPT" if r.changed else "kept "
        print(f"[{mark}] {r.kernel:14s} {r.shape} {r.dtype}: "
              f"{len(r.candidates)} candidates, best "
              f"padded={r.best.plan.padded_shape} "
              f"block={r.best.plan.block_shape} "
              f"bytes={r.best.measured['bytes']:.3e} "
              f"(analytic padded={r.default_plan.padded_shape})")
    profile_lib.save_profile(
        args.out, [r.entry() for r in results],
        backend=jax.default_backend(),
        meta={"timed": timed, "jax": jax.__version__},
    )
    n_changed = sum(r.changed for r in results)
    print(f"wrote {len(results)} cells -> {args.out} "
          f"({n_changed} differ from the analytic choice)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
