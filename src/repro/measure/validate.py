"""Measured-vs-predicted validation of the analytic layout planner.

For every registry kernel this module lowers the registered Pallas body at
its planned block shape (abstract ``ShapeDtypeStruct`` inputs -- the same
no-allocation dry-run discipline as ``launch/dryrun.py``), extracts the
compiled program's HLO bytes-accessed and FLOPs via
``launch/lowering.cost_stats``, and compares the bytes against the plan's
``predicted_hbm_bytes`` (every major stream at the padded footprint plus
the family's minor side operands).

The comparison is an *envelope*, per family, mirroring the paper's Fig. 4
methodology: measured bandwidth is never exactly the model -- the compiled
program adds pad/slice staging and fusion intermediates (and XLA's cost
analysis counts block-grid loop bodies once, the same caveat the roofline
harness documents) -- but the ratio measured/predicted is stable per kernel
family for fixed representative cells.  ``TOLERANCES`` pins those
envelopes; a planner or kernel-wrapper change that moves real traffic out
of its family's envelope fails validation loudly.

Usage:
    python -m repro.measure.validate --all
    python -m repro.measure.validate --family stream --out /tmp/v.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Mapping

import jax
import jax.numpy as jnp

from repro import api
from repro.launch import lowering

OUT_DEFAULT = "results/validation.json"
VALIDATION_FORMAT = "repro.validation"
VALIDATION_VERSION = 1


# ---------------------------------------------------------------------------
# Representative cells
# ---------------------------------------------------------------------------

def args_for(kernel: str, shape, dtype) -> tuple[list, dict]:
    """Abstract launch arguments for ``kernel`` planned on (shape, dtype).

    The inverse of the registry's ``plan_args``: given the logical planning
    shape, produce the ``ShapeDtypeStruct`` operands (and default scalars)
    the registered body expects.  Shared by validate and sweep so any cell
    the planner can plan, the harness can lower.
    """
    a = lambda s, dt=dtype: jax.ShapeDtypeStruct(tuple(s), jnp.dtype(dt))
    family = kernel.split(".")[0]
    if family in ("stream", "triad"):
        n_arrays = {"stream.copy": 1, "stream.scale": 1, "stream.add": 2,
                    "stream.triad": 2, "triad": 3}[kernel]
        scalars = {"stream.scale": {"s": 2.0},
                   "stream.triad": {"s": 3.0}}.get(kernel, {})
        return [a(shape)] * n_arrays, scalars
    if family == "jacobi":
        return [a(shape)], {}
    if family == "lbm":
        return [a(shape)], {"omega": 1.2}
    if kernel == "rmsnorm":
        return [a(shape), a(shape[-1:])], {"eps": 1e-6}
    if kernel == "rmsnorm.gated":
        return [a(shape), a(shape), a(shape[-1:])], {"eps": 1e-6}
    if kernel == "xent":
        return [a(shape), a(shape[:1], "int32")], {"logical_v": shape[-1]}
    raise KeyError(f"no argument template for kernel {kernel!r}")


# One representative (shape, dtype) cell per registry kernel: odd logical
# extents so the plans actually pay padding, small enough that a CPU
# compile stays well under a second per kernel.
CASES: dict[str, tuple[tuple[int, ...], str]] = {
    "stream.copy": ((99999,), "float32"),
    "stream.scale": ((99999,), "float32"),
    "stream.add": ((99999,), "float32"),
    "stream.triad": ((99999,), "float32"),
    "triad": ((50000,), "float32"),
    "jacobi": ((257, 513), "float32"),
    "lbm.soa": ((19, 8, 8, 8), "float32"),
    "lbm.ivjk": ((19, 8, 8, 8), "float32"),
    "rmsnorm": ((300, 1111), "float32"),
    "rmsnorm.gated": ((300, 1111), "float32"),
    "xent": ((300, 5000), "float32"),
}


@dataclasses.dataclass(frozen=True)
class Tolerance:
    """Per-family envelope on measured_bytes / predicted_hbm_bytes."""

    lo: float
    hi: float

    def holds(self, ratio: float) -> bool:
        return self.lo <= ratio <= self.hi


# Calibrated on the CPU dry-run backend at the CASES above, then widened to
# roughly half/double so a jax upgrade's fusion changes don't flap CI while
# a real traffic regression (padding doubled, stream dropped) still lands
# outside.  Single-fusion streaming kernels sit near ratio 1 x
# logical/padded; stencil/normalization/softmax kernels carry fusion
# intermediates at a family-stable multiplier.
TOLERANCES: dict[str, Tolerance] = {
    "stream": Tolerance(0.35, 1.6),
    "triad": Tolerance(0.35, 1.6),
    "jacobi": Tolerance(2.0, 10.0),
    "lbm": Tolerance(2.5, 16.0),
    "rmsnorm": Tolerance(1.5, 11.0),
    "xent": Tolerance(3.5, 19.0),
}


# ---------------------------------------------------------------------------
# Measurement
# ---------------------------------------------------------------------------

def measure_cell(kernel: str, shape, dtype, *, plan=None,
                 scalars: Mapping | None = None, timed: bool = False) -> dict:
    """Compile ``kernel`` at (shape, dtype) under ``plan`` (default: the
    ambient context's analytic plan) and return compiled-cost stats.

    ``timed`` additionally executes the compiled program on zero inputs and
    reports best-of-3 wall seconds (meaningful on a real backend; on the
    CPU interpreter it times the emulation, so sweeps only use it when
    asked).
    """
    entry = api.get_kernel(kernel)
    plan = plan or api.plan_for(kernel, shape, dtype)
    args, default_scalars = args_for(kernel, shape, dtype)
    merged = {**default_scalars, **dict(scalars or {})}
    jf = jax.jit(lambda *arrays: entry.body(plan, *arrays, **merged))
    t0 = time.time()
    compiled = jf.lower(*args).compile()
    stats = lowering.cost_stats(compiled)
    out = {
        "bytes": stats["bytes"],
        "flops": stats["flops"],
        "compile_s": round(time.time() - t0, 3),
        "wall_s": None,
    }
    if timed:
        concrete = [jnp.zeros(s.shape, s.dtype) for s in args]
        jax.block_until_ready(compiled(*concrete))  # warm
        best = min(
            _timed_run(compiled, concrete) for _ in range(3)
        )
        out["wall_s"] = best
    return out


def _timed_run(compiled, concrete) -> float:
    t0 = time.perf_counter()
    jax.block_until_ready(compiled(*concrete))
    return time.perf_counter() - t0


def validate_kernel(kernel: str, *, shape=None, dtype=None) -> dict:
    """One measured-vs-predicted record for ``kernel``."""
    if shape is None or dtype is None:
        shape, dtype = CASES[kernel]
    family = kernel.split(".")[0]
    plan = api.plan_for(kernel, shape, dtype)
    measured = measure_cell(kernel, shape, dtype, plan=plan)
    predicted = plan.predicted_hbm_bytes
    ratio = measured["bytes"] / predicted if predicted else 0.0
    tol = TOLERANCES[family]
    return {
        "kernel": kernel,
        "family": family,
        "shape": list(shape),
        "dtype": str(jnp.dtype(dtype).name),
        "predicted": {
            "hbm_bytes": plan.predicted_hbm_bytes,
            "logical_bytes": plan.predicted_logical_bytes,
            "waste_bytes": plan.waste_bytes,
            "balance": plan.predicted_balance,
            "naive_balance": plan.naive_balance,
        },
        "measured": measured,
        "ratio": round(ratio, 4),
        "tolerance": [tol.lo, tol.hi],
        "status": "ok" if tol.holds(ratio) else "fail",
        "plan": {
            "padded_shape": list(plan.padded_shape),
            "block_shape": list(plan.block_shape),
            "sublanes": plan.sublanes,
        },
    }


def validate_kernels(kernels=None) -> list[dict]:
    """Records for ``kernels`` (default: every registry kernel with a
    representative cell).  An explicit empty selection is empty, never
    silently widened to everything."""
    names = list(kernels) if kernels is not None else [
        k for k in api.list_kernels() if k in CASES
    ]
    return [validate_kernel(k) for k in names]


def write_report(records: list[dict], out: str) -> None:
    """Merge ``records`` into ``out`` (same-kernel records update in
    place, like the dry-run driver)."""
    existing: list[dict] = []
    # A zero-size file is "nothing here yet", not corruption: mktemp (the
    # tier-1 script's per-run report path) creates the file it names.
    if os.path.exists(out) and os.path.getsize(out) > 0:
        with open(out) as f:
            doc = json.load(f)
            if doc.get("format") == VALIDATION_FORMAT:
                existing = doc.get("records", [])
    merged = {(r["kernel"], tuple(r["shape"]), r["dtype"]): r
              for r in existing}
    for r in records:
        merged[(r["kernel"], tuple(r["shape"]), r["dtype"])] = r
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        json.dump({
            "format": VALIDATION_FORMAT,
            "version": VALIDATION_VERSION,
            "backend": jax.default_backend(),
            "records": list(merged.values()),
        }, f, indent=1)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="measured-vs-predicted validation of kernel plans")
    ap.add_argument("--all", action="store_true",
                    help="validate every registry kernel")
    ap.add_argument("--family", action="append", default=[],
                    help="validate one family (repeatable)")
    ap.add_argument("--kernel", action="append", default=[],
                    help="validate one kernel (repeatable)")
    ap.add_argument("--out", default=OUT_DEFAULT)
    args = ap.parse_args(argv)

    names = [k for k in api.list_kernels() if k in CASES]
    if not args.all:
        wanted = set(args.kernel)
        wanted.update(k for k in names if k.split(".")[0] in args.family)
        if not wanted:
            ap.error("pass --all, --family, or --kernel")
        unknown = wanted - set(names)
        if unknown:
            ap.error(f"no validation cell for {sorted(unknown)}; "
                     f"known: {names}")
        names = [k for k in names if k in wanted]

    records = validate_kernels(names)
    for r in records:
        print(f"[{r['status']:4s}] {r['kernel']:14s} "
              f"measured={r['measured']['bytes']:.3e} "
              f"predicted={r['predicted']['hbm_bytes']:.3e} "
              f"ratio={r['ratio']:.2f} "
              f"tol=[{r['tolerance'][0]}, {r['tolerance'][1]}] "
              f"balance={r['predicted']['balance']:.2f} "
              f"waste={r['predicted']['waste_bytes']}B")
    write_report(records, args.out)
    n_fail = sum(r["status"] != "ok" for r in records)
    print(f"wrote {len(records)} records -> {args.out}"
          + (f" ({n_fail} FAILED)" if n_fail else ""))
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
