"""Measured-vs-predicted validation of the analytic layout planner.

For every registry kernel this module lowers the registered Pallas body at
its planned block shape (abstract ``ShapeDtypeStruct`` inputs -- the same
no-allocation dry-run discipline as ``launch/dryrun.py``), extracts the
compiled program's HLO bytes-accessed and FLOPs via
``launch/lowering.cost_stats``, and compares the bytes against the plan's
``predicted_hbm_bytes`` (every major stream at the padded footprint plus
the family's minor side operands).

The comparison is an *envelope*, per family, mirroring the paper's Fig. 4
methodology: measured bandwidth is never exactly the model -- the compiled
program adds pad/slice staging and fusion intermediates (and XLA's cost
analysis counts block-grid loop bodies once, the same caveat the roofline
harness documents) -- but the ratio measured/predicted is stable per kernel
family for fixed representative cells.  ``TOLERANCES`` pins those
envelopes; a planner or kernel-wrapper change that moves real traffic out
of its family's envelope fails validation loudly.

A second, SPMD-only check covers *communication*: for the kernel families
whose partitioning communicates (vocab-parallel xent's lse combine, the
jacobi and LBM halo exchanges), ``--comm`` lowers the shard_map launch
under a real multi-device mesh, runs the collective census on the
compiled HLO (``launch.lowering.collective_census``, the same ring cost
model the planner's ``predicted_comm_bytes`` uses), and checks measured
wire bytes against the *local* plan's prediction.  Adding ``--exposed``
also checks the *overlap structure* (docs/OVERLAP.md): it walks the
launch jaxpr with ``api.spmd.overlap_report``, requires the halo
families' collectives to be overlappable (independent of the interior
Pallas sweep in both dataflow directions), and compares the wire bytes
left on the critical path against the plan's
``predicted_exposed_comm_bytes``.  Both need forced host devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m repro.measure.validate --comm --exposed --mesh 2x4

Usage:
    python -m repro.measure.validate --all
    python -m repro.measure.validate --family stream --out /tmp/v.json
    python -m repro.measure.validate --comm --mesh 2x4
    python -m repro.measure.validate --comm --exposed --mesh 8x1
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro import obs
from repro.launch import lowering

OUT_DEFAULT = "results/validation.json"
VALIDATION_FORMAT = "repro.validation"
VALIDATION_VERSION = 1


# ---------------------------------------------------------------------------
# Representative cells
# ---------------------------------------------------------------------------

def args_for(kernel: str, shape, dtype) -> tuple[list, dict]:
    """Abstract launch arguments for ``kernel`` planned on (shape, dtype).

    The inverse of the registry's ``plan_args``: given the logical planning
    shape, produce the ``ShapeDtypeStruct`` operands (and default scalars)
    the registered body expects.  Shared by validate and sweep so any cell
    the planner can plan, the harness can lower.
    """
    a = lambda s, dt=dtype: jax.ShapeDtypeStruct(tuple(s), jnp.dtype(dt))
    family = kernel.split(".")[0]
    if family in ("stream", "triad"):
        n_arrays = {"stream.copy": 1, "stream.scale": 1, "stream.add": 2,
                    "stream.triad": 2, "triad": 3}[kernel]
        scalars = {"stream.scale": {"s": 2.0},
                   "stream.triad": {"s": 3.0}}.get(kernel, {})
        return [a(shape)] * n_arrays, scalars
    if family == "jacobi":
        return [a(shape)], {}
    if family == "lbm":
        return [a(shape)], {"omega": 1.2}
    if kernel == "rmsnorm":
        return [a(shape), a(shape[-1:])], {"eps": 1e-6}
    if kernel == "rmsnorm.gated":
        return [a(shape), a(shape), a(shape[-1:])], {"eps": 1e-6}
    if kernel == "xent":
        return [a(shape), a(shape[:1], "int32")], {"logical_v": shape[-1]}
    raise KeyError(f"no argument template for kernel {kernel!r}")


# One representative (shape, dtype) cell per registry kernel: odd logical
# extents so the plans actually pay padding, small enough that a CPU
# compile stays well under a second per kernel.
CASES: dict[str, tuple[tuple[int, ...], str]] = {
    "stream.copy": ((99999,), "float32"),
    "stream.scale": ((99999,), "float32"),
    "stream.add": ((99999,), "float32"),
    "stream.triad": ((99999,), "float32"),
    "triad": ((50000,), "float32"),
    "jacobi": ((257, 513), "float32"),
    "lbm.soa": ((19, 8, 8, 8), "float32"),
    "lbm.ivjk": ((19, 8, 8, 8), "float32"),
    "rmsnorm": ((300, 1111), "float32"),
    "rmsnorm.gated": ((300, 1111), "float32"),
    "xent": ((300, 5000), "float32"),
}


@dataclasses.dataclass(frozen=True)
class Tolerance:
    """Per-family envelope on measured_bytes / predicted_hbm_bytes."""

    lo: float
    hi: float

    def holds(self, ratio: float) -> bool:
        return self.lo <= ratio <= self.hi


# Calibrated on the CPU dry-run backend at the CASES above, then widened to
# roughly half/double so a jax upgrade's fusion changes don't flap CI while
# a real traffic regression (padding doubled, stream dropped) still lands
# outside.  Single-fusion streaming kernels sit near ratio 1 x
# logical/padded; stencil/normalization/softmax kernels carry fusion
# intermediates at a family-stable multiplier.
TOLERANCES: dict[str, Tolerance] = {
    "stream": Tolerance(0.35, 1.6),
    "triad": Tolerance(0.35, 1.6),
    "jacobi": Tolerance(2.0, 10.0),
    "lbm": Tolerance(2.5, 16.0),
    "rmsnorm": Tolerance(1.5, 11.0),
    "xent": Tolerance(3.5, 19.0),
}


# ---------------------------------------------------------------------------
# Communication validation (SPMD launches only)
# ---------------------------------------------------------------------------

# Representative *global* cells for the communicating families, chosen
# divisible by every mesh in the CI matrix (data/model up to 8) so the
# declared partitioning actually engages.  The LBM X extent (32) keeps an
# interior stripe at every CI data size (local XL in {4, 16, 32}), so the
# overlap structure the --exposed check requires is actually present.
COMM_CASES: dict[str, tuple[tuple[int, ...], str]] = {
    "xent": ((64, 4096), "float32"),
    "jacobi": ((64, 258), "float32"),
    "lbm.soa": ((19, 32, 8, 8), "float32"),
    "lbm.ivjk": ((19, 32, 8, 8), "float32"),
}

# The census applies the exact ring formulas the planner's COMM_MODEL uses,
# so the ratio sits at ~1.0 when the lowered program emits the predicted
# collectives and nothing else; the envelope leaves room for an XLA
# all-reduce combiner fusing payloads or a rewrite adding a small control
# collective, while a dropped halo (ratio ~0) or a replicated-logits
# regression (10-100x the lse payload) still lands far outside.
COMM_TOLERANCES: dict[str, Tolerance] = {
    "xent": Tolerance(0.5, 2.0),
    "jacobi": Tolerance(0.5, 2.0),
    "lbm.soa": Tolerance(0.5, 2.0),
    "lbm.ivjk": Tolerance(0.5, 2.0),
}


def _mesh_sizes(mesh) -> dict[str, int]:
    return dict(zip(tuple(mesh.axis_names), tuple(mesh.devices.shape)))


def local_shard_shape(kernel: str, shape, dtype, mesh) -> tuple[int, ...]:
    """The per-shard operand-0 shape the kernel's SPMD body plans on.

    Derived through the same ``spmd.shard_specs`` call the launch path
    uses -- declared partitioning, ambient rules, divisibility fallback
    included -- so this can never drift from what the shard body actually
    plans.  One body quirk is mirrored: jacobi's unsharded fallback plans
    on its *interior* rows (``plan_args``), while a sharded stripe plans
    on the stripe itself.
    """
    from repro.api import spmd as spmd_lib

    entry = api.get_kernel(kernel)
    args, scalars = args_for(kernel, shape, dtype)
    part = spmd_lib.partitioning_for(entry, len(args))
    _, operand_axes, sizes, _ = spmd_lib.shard_specs(mesh, part.in_axes,
                                                     args)
    n_shards = 1
    local = []
    for n, axes in zip(args[0].shape, operand_axes[0]):
        k = 1
        for a in axes:
            k *= int(sizes.get(a, 1))
        n_shards *= k
        local.append(int(n) // k)
    if n_shards <= 1:
        return tuple(int(s) for s in entry.plan_args(*args, **scalars)[0])
    return tuple(local)


def validate_comm_kernel(kernel: str, mesh, *, shape=None, dtype=None) -> dict:
    """One measured-vs-predicted *wire bytes* record for ``kernel`` launched
    through the SPMD path over ``mesh``."""
    if shape is None or dtype is None:
        shape, dtype = COMM_CASES[kernel]
    args, scalars = args_for(kernel, shape, dtype)
    with api.plan_context(mesh=mesh):
        local = local_shard_shape(kernel, shape, dtype, mesh)
        plan = api.plan_for(kernel, local, dtype, local=True)
        jf = jax.jit(lambda *arrays: api.launch(kernel, *arrays, **scalars))
        t0 = time.time()
        compiled = jf.lower(*args).compile()
    census = lowering.collective_census(compiled.as_text())
    measured = lowering.census_total(census)
    predicted = plan.predicted_comm_bytes
    if predicted:
        ratio = measured / predicted
    else:
        ratio = 0.0 if measured == 0 else float("inf")
    tol = COMM_TOLERANCES[kernel]
    ok = tol.holds(ratio) if predicted else measured == 0
    if obs.enabled():
        obs.emit(obs.ValidationEvent(
            kernel=kernel, family=kernel.split(".")[0], check="comm",
            predicted_bytes=float(predicted), measured_bytes=float(measured),
            ratio=ratio, status="ok" if ok else "fail",
            mesh=tuple(sorted(_mesh_sizes(mesh).items()))))
    return {
        "kernel": kernel,
        "family": kernel.split(".")[0],
        "check": "comm",
        "shape": list(shape),
        "dtype": str(jnp.dtype(dtype).name),
        "mesh": _mesh_sizes(mesh),
        "local_shape": list(local),
        "predicted": {"comm_bytes": predicted},
        "measured": {
            "wire_bytes": measured,
            "collectives": {
                op: {"count": c["count"], "wire_bytes": c["wire_bytes"]}
                for op, c in census.items() if c["count"]
            },
            "compile_s": round(time.time() - t0, 3),
        },
        "ratio": round(ratio, 4) if ratio != float("inf") else "inf",
        "tolerance": [tol.lo, tol.hi],
        "status": "ok" if ok else "fail",
    }


def _site_wire_bytes(site, sizes: Mapping[str, int]) -> float:
    """Per-device ring wire bytes for one jaxpr collective site -- the same
    cost model ``lowering.collective_census`` applies to the HLO ops, so
    the two measurements agree when the lowering is one-op-per-site."""
    n = 1
    for a in site.axes:
        n *= int(sizes.get(a, 1))
    b = float(site.result_bytes)
    if site.primitive in ("psum", "psum_invariant", "pmax", "pmin",
                          "pbroadcast"):
        return 2.0 * (n - 1) / max(n, 1) * b      # all-reduce ring
    if site.primitive in ("all_gather", "all_to_all"):
        return (n - 1) / max(n, 1) * b
    if site.primitive == "reduce_scatter":
        return float(n - 1) * b
    return b                                       # collective-permute


def validate_exposed_kernel(kernel: str, mesh, *, shape=None,
                            dtype=None) -> dict:
    """One exposed-comm record: is the halo structured as overlappable,
    and do the wire bytes left on the critical path match
    ``predicted_exposed_comm_bytes``?

    The measurement is structural, from the launch jaxpr
    (``api.spmd.overlap_report``): collectives some Pallas call is
    independent of may hide behind that compute, so only the overflow
    past the plan's hiding capacity (predicted total minus predicted
    exposed) stays on the critical path; blocking collectives are fully
    exposed.  Halo families (``planner.HALO_MODEL``) additionally *fail*
    if any of their collectives is blocking -- that is the
    exchange-then-compute regression this check exists to catch.
    """
    from repro.api import spmd as spmd_lib
    from repro.core import planner as planner_lib

    if shape is None or dtype is None:
        shape, dtype = COMM_CASES[kernel]
    args, scalars = args_for(kernel, shape, dtype)
    with api.plan_context(mesh=mesh):
        local = local_shard_shape(kernel, shape, dtype, mesh)
        plan = api.plan_for(kernel, local, dtype, local=True)
        rep = spmd_lib.overlap_report(
            lambda *arrays: api.launch(kernel, *arrays, **scalars), *args)
    sizes = _mesh_sizes(mesh)
    blocking = sum(_site_wire_bytes(s, sizes) for s in rep.collectives
                   if not s.overlappable)
    overlappable = sum(_site_wire_bytes(s, sizes) for s in rep.collectives
                       if s.overlappable)
    predicted_total = plan.predicted_comm_bytes
    predicted = plan.predicted_exposed_comm_bytes
    hidden_capacity = predicted_total - predicted
    measured = blocking + max(0.0, overlappable - hidden_capacity)
    if predicted:
        ratio = measured / predicted
    else:
        ratio = 0.0 if measured == 0 else float("inf")
    tol = COMM_TOLERANCES[kernel]
    halo = kernel in planner_lib.HALO_MODEL
    structure_ok = (rep.all_overlappable and bool(rep.collectives)
                    if halo and predicted_total else True)
    ok = structure_ok and (tol.holds(ratio) if predicted else measured == 0)
    if obs.enabled():
        obs.emit(obs.ValidationEvent(
            kernel=kernel, family=kernel.split(".")[0], check="exposed_comm",
            predicted_bytes=float(predicted), measured_bytes=float(measured),
            ratio=ratio if ratio != float("inf") else -1.0,
            status="ok" if ok else "fail",
            mesh=tuple(sorted(sizes.items()))))
    return {
        "kernel": kernel,
        "family": kernel.split(".")[0],
        "check": "exposed_comm",
        "shape": list(shape),
        "dtype": str(jnp.dtype(dtype).name),
        "mesh": sizes,
        "local_shape": list(local),
        "predicted": {"comm_bytes": predicted_total,
                      "exposed_comm_bytes": predicted},
        "measured": {
            "exposed_wire_bytes": measured,
            "blocking_wire_bytes": blocking,
            "overlappable_wire_bytes": overlappable,
            "n_pallas_calls": rep.n_pallas_calls,
            "collectives": [
                {"primitive": s.primitive, "axes": list(s.axes),
                 "result_bytes": s.result_bytes,
                 "overlappable": s.overlappable}
                for s in rep.collectives
            ],
        },
        "structure_ok": structure_ok,
        "ratio": round(ratio, 4) if ratio != float("inf") else "inf",
        "tolerance": [tol.lo, tol.hi],
        "status": "ok" if ok else "fail",
    }


def validate_comm(mesh, kernels=None, *, exposed: bool = False) -> list[dict]:
    names = list(kernels) if kernels is not None else sorted(COMM_CASES)
    records = []
    for k in names:
        records.append(validate_comm_kernel(k, mesh))
        if exposed:
            records.append(validate_exposed_kernel(k, mesh))
    return records


def mesh_from_spec(spec: str):
    """A ("data", "model") host mesh from a "DxM" string."""
    d, m = (int(x) for x in spec.lower().split("x"))
    n = d * m
    if jax.device_count() < n:
        raise SystemExit(
            f"mesh {spec} needs {n} devices, have {jax.device_count()} "
            f"(set XLA_FLAGS=--xla_force_host_platform_device_count={n})"
        )
    return jax.sharding.Mesh(
        np.asarray(jax.devices()[:n]).reshape(d, m), ("data", "model")
    )


# ---------------------------------------------------------------------------
# Measurement
# ---------------------------------------------------------------------------

def measure_cell(kernel: str, shape, dtype, *, plan=None,
                 scalars: Mapping | None = None, timed: bool = False) -> dict:
    """Compile ``kernel`` at (shape, dtype) under ``plan`` (default: the
    ambient context's analytic plan) and return compiled-cost stats.

    ``timed`` additionally executes the compiled program on zero inputs and
    reports best-of-3 wall seconds (meaningful on a real backend; on the
    CPU interpreter it times the emulation, so sweeps only use it when
    asked).
    """
    entry = api.get_kernel(kernel)
    plan = plan or api.plan_for(kernel, shape, dtype)
    args, default_scalars = args_for(kernel, shape, dtype)
    merged = {**default_scalars, **dict(scalars or {})}
    jf = jax.jit(lambda *arrays: entry.body(plan, *arrays, **merged))
    t0 = time.time()
    compiled = jf.lower(*args).compile()
    stats = lowering.cost_stats(compiled)
    out = {
        "bytes": stats["bytes"],
        "flops": stats["flops"],
        "compile_s": round(time.time() - t0, 3),
        "wall_s": None,
    }
    if timed:
        concrete = [jnp.zeros(s.shape, s.dtype) for s in args]
        jax.block_until_ready(compiled(*concrete))  # warm
        best = min(
            _timed_run(compiled, concrete) for _ in range(3)
        )
        out["wall_s"] = best
    return out


def _timed_run(compiled, concrete) -> float:
    t0 = time.perf_counter()
    jax.block_until_ready(compiled(*concrete))
    return time.perf_counter() - t0


def validate_kernel(kernel: str, *, shape=None, dtype=None) -> dict:
    """One measured-vs-predicted record for ``kernel``."""
    if shape is None or dtype is None:
        shape, dtype = CASES[kernel]
    family = kernel.split(".")[0]
    plan = api.plan_for(kernel, shape, dtype)
    measured = measure_cell(kernel, shape, dtype, plan=plan)
    predicted = plan.predicted_hbm_bytes
    ratio = measured["bytes"] / predicted if predicted else 0.0
    tol = TOLERANCES[family]
    if obs.enabled():
        obs.emit(obs.ValidationEvent(
            kernel=kernel, family=family, check="hbm",
            predicted_bytes=float(predicted),
            measured_bytes=float(measured["bytes"]),
            ratio=ratio, status="ok" if tol.holds(ratio) else "fail"))
    return {
        "kernel": kernel,
        "family": family,
        "shape": list(shape),
        "dtype": str(jnp.dtype(dtype).name),
        "predicted": {
            "hbm_bytes": plan.predicted_hbm_bytes,
            "logical_bytes": plan.predicted_logical_bytes,
            "comm_bytes": plan.predicted_comm_bytes,
            "waste_bytes": plan.waste_bytes,
            "balance": plan.predicted_balance,
            "naive_balance": plan.naive_balance,
        },
        "measured": measured,
        "ratio": round(ratio, 4),
        "tolerance": [tol.lo, tol.hi],
        "status": "ok" if tol.holds(ratio) else "fail",
        "plan": {
            "padded_shape": list(plan.padded_shape),
            "block_shape": list(plan.block_shape),
            "sublanes": plan.sublanes,
        },
    }


def validate_kernels(kernels=None) -> list[dict]:
    """Records for ``kernels`` (default: every registry kernel with a
    representative cell).  An explicit empty selection is empty, never
    silently widened to everything."""
    names = list(kernels) if kernels is not None else [
        k for k in api.list_kernels() if k in CASES
    ]
    return [validate_kernel(k) for k in names]


def write_report(records: list[dict], out: str) -> None:
    """Merge ``records`` into ``out`` (same-kernel records update in
    place, like the dry-run driver)."""
    existing: list[dict] = []
    # A zero-size file is "nothing here yet", not corruption: mktemp (the
    # tier-1 script's per-run report path) creates the file it names.
    if os.path.exists(out) and os.path.getsize(out) > 0:
        with open(out) as f:
            doc = json.load(f)
            if doc.get("format") == VALIDATION_FORMAT:
                existing = doc.get("records", [])
    def key(r):
        mesh = r.get("mesh")
        return (r["kernel"], tuple(r["shape"]), r["dtype"],
                r.get("check", "hbm"),
                tuple(sorted(mesh.items())) if mesh else ())

    merged = {key(r): r for r in existing}
    for r in records:
        merged[key(r)] = r
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        json.dump({
            "format": VALIDATION_FORMAT,
            "version": VALIDATION_VERSION,
            "backend": jax.default_backend(),
            "records": list(merged.values()),
        }, f, indent=1)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="measured-vs-predicted validation of kernel plans")
    ap.add_argument("--all", action="store_true",
                    help="validate every registry kernel")
    ap.add_argument("--family", action="append", default=[],
                    help="validate one family (repeatable)")
    ap.add_argument("--kernel", action="append", default=[],
                    help="validate one kernel (repeatable)")
    ap.add_argument("--comm", action="store_true",
                    help="validate predicted_comm_bytes against the "
                         "collective census of the SPMD launch (needs a "
                         "multi-device mesh; see --mesh)")
    ap.add_argument("--exposed", action="store_true",
                    help="with --comm: also check the overlap structure "
                         "(halo collectives independent of the interior "
                         "Pallas sweep) and the exposed-comm envelope "
                         "against predicted_exposed_comm_bytes")
    ap.add_argument("--mesh", default="2x4",
                    help="DxM (data x model) host mesh for --comm")
    ap.add_argument("--out", default=OUT_DEFAULT)
    ap.add_argument("--obs-jsonl", default=None,
                    help="stream per-check events (repro.obs) to this JSONL "
                         "file; aggregate with python -m repro.obs.report")
    args = ap.parse_args(argv)

    if args.obs_jsonl:
        # One observability session around the whole run: every validation
        # record (and the plan events its planning emits) streams to the
        # file alongside the merged JSON report.
        with obs.session(obs.JsonlSink(args.obs_jsonl)):
            return _run(ap, args)
    return _run(ap, args)


def _run(ap, args) -> int:
    if args.exposed and not args.comm:
        ap.error("--exposed is a --comm mode (it checks the SPMD launch's "
                 "overlap structure); pass both")
    if args.comm:
        mesh = mesh_from_spec(args.mesh)
        if args.kernel:
            unknown = set(args.kernel) - set(COMM_CASES)
            if unknown:
                ap.error(f"no comm cell for {sorted(unknown)}; only the "
                         f"communicating families have one: "
                         f"{sorted(COMM_CASES)}")
        records = validate_comm(mesh, kernels=args.kernel or None,
                                exposed=args.exposed)
        for r in records:
            if r["check"] == "exposed_comm":
                m = r["measured"]
                n_over = sum(c["overlappable"] for c in m["collectives"])
                print(f"[{r['status']:4s}] exposed {r['kernel']:8s} "
                      f"mesh={r['mesh']} "
                      f"measured={m['exposed_wire_bytes']:.3e} "
                      f"predicted={r['predicted']['exposed_comm_bytes']:.3e} "
                      f"ratio={r['ratio']} "
                      f"overlappable={n_over}/{len(m['collectives'])}")
            else:
                print(f"[{r['status']:4s}] comm {r['kernel']:8s} "
                      f"mesh={r['mesh']} "
                      f"measured={r['measured']['wire_bytes']:.3e} "
                      f"predicted={r['predicted']['comm_bytes']:.3e} "
                      f"ratio={r['ratio']} "
                      f"tol=[{r['tolerance'][0]}, {r['tolerance'][1]}]")
        write_report(records, args.out)
        n_fail = sum(r["status"] != "ok" for r in records)
        print(f"wrote {len(records)} comm records -> {args.out}"
              + (f" ({n_fail} FAILED)" if n_fail else ""))
        return 1 if n_fail else 0

    names = [k for k in api.list_kernels() if k in CASES]
    if not args.all:
        wanted = set(args.kernel)
        wanted.update(k for k in names if k.split(".")[0] in args.family)
        if not wanted:
            ap.error("pass --all, --family, or --kernel")
        unknown = wanted - set(names)
        if unknown:
            ap.error(f"no validation cell for {sorted(unknown)}; "
                     f"known: {names}")
        names = [k for k in names if k in wanted]

    records = validate_kernels(names)
    for r in records:
        print(f"[{r['status']:4s}] {r['kernel']:14s} "
              f"measured={r['measured']['bytes']:.3e} "
              f"predicted={r['predicted']['hbm_bytes']:.3e} "
              f"ratio={r['ratio']:.2f} "
              f"tol=[{r['tolerance'][0]}, {r['tolerance'][1]}] "
              f"balance={r['predicted']['balance']:.2f} "
              f"waste={r['predicted']['waste_bytes']}B")
    write_report(records, args.out)
    n_fail = sum(r["status"] != "ok" for r in records)
    print(f"wrote {len(records)} records -> {args.out}"
          + (f" ({n_fail} FAILED)" if n_fail else ""))
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
