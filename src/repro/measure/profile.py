"""Plan-override profiles: the serialized form of a measured sweep.

A profile is versioned JSON keyed the way the planner memoizes
(``plan_cache_keys``): each entry names a (kernel, logical shape, dtype,
mesh) cell plus the planner *knobs* (sublane tile, VMEM budget) that won
the sweep.  Loading re-derives the plan through ``plan_kernel`` with those
knobs -- the profile stores decisions, not serialized plan objects -- and
cross-checks the derived geometry against the recorded ``expect`` block:
if the planner has drifted since the sweep ran, the mismatch is a loud,
readable error instead of a silently different layout.

    {
      "format": "repro.plan_profile", "version": 1, "backend": "cpu",
      "entries": [
        {"kernel": "rmsnorm", "logical_shape": [1016, 1111],
         "dtype": "float32", "mesh": [],
         "knobs": {"sublanes": 8, "vmem_budget": 262144},
         "expect": {"padded_shape": [1016, 1152], "block_shape": [8, 1152]},
         "score": {"hlo_bytes": 41913528.0, "wall_s": null},
         "source": "sweep"}
      ]
    }

``load_profile`` returns ``{(kernel, shape, dtype): KernelPlan}`` -- the
cell-keyed mapping ``PlanContext(plan_overrides=...)`` consumes -- with
every plan's ``provenance`` set to ``profile:<path>`` so ``explain()``
reports where the layout decision actually came from.
"""
from __future__ import annotations

import dataclasses
import json
import os
import warnings

from repro import obs
from repro.core.planner import KernelPlan, plan_kernel

PROFILE_FORMAT = "repro.plan_profile"
PROFILE_VERSION = 1


def profile_key(kernel: str, shape, dtype) -> tuple:
    """The override-mapping key for one profiled cell."""
    import numpy as np

    return (kernel, tuple(int(s) for s in shape), np.dtype(dtype).name)


def entry_from_plan(plan: KernelPlan, knobs: dict, *, score: dict | None = None,
                    source: str = "sweep") -> dict:
    """Serialize one swept plan: the knobs that produced it plus the
    geometry it must reproduce on load."""
    missing = {"sublanes", "vmem_budget"} - set(knobs)
    if missing:
        raise ValueError(f"profile knobs missing {sorted(missing)}")
    return {
        "kernel": plan.kernel,
        "logical_shape": list(plan.logical_shape),
        "dtype": plan.dtype,
        "mesh": [list(kv) for kv in plan.mesh],
        "knobs": {"sublanes": int(knobs["sublanes"]),
                  "vmem_budget": int(knobs["vmem_budget"])},
        "expect": {"padded_shape": list(plan.padded_shape),
                   "block_shape": list(plan.block_shape)},
        "score": dict(score or {}),
        "source": source,
    }


def save_profile(path: str, entries: list[dict], *, backend: str | None = None,
                 meta: dict | None = None) -> None:
    """Write a versioned profile; parent directories are created."""
    doc = {
        "format": PROFILE_FORMAT,
        "version": PROFILE_VERSION,
        "backend": backend,
        "meta": dict(meta or {}),
        "entries": list(entries),
    }
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)


def load_profile(path: str, *, strict: bool = True) -> dict:
    """Profile file -> ``{(kernel, shape, dtype): KernelPlan}``.

    Each entry's plan is re-derived via ``plan_kernel`` under the recorded
    knobs and mesh, then checked against the recorded geometry.  A drifted
    entry raises (``strict=True``) or is skipped with a warning, so a stale
    profile can never silently impose a layout the sweep did not measure.
    """
    with open(path) as f:
        doc = json.load(f)
    if doc.get("format") != PROFILE_FORMAT:
        raise ValueError(
            f"{path}: not a plan profile (format={doc.get('format')!r})"
        )
    if int(doc.get("version", 0)) > PROFILE_VERSION:
        raise ValueError(
            f"{path}: profile version {doc.get('version')} is newer than "
            f"supported {PROFILE_VERSION}"
        )
    overrides: dict = {}
    for entry in doc.get("entries", ()):
        kernel = entry["kernel"]
        shape = tuple(int(s) for s in entry["logical_shape"])
        dtype = entry["dtype"]
        knobs = entry["knobs"]
        mesh = tuple((str(a), int(n)) for a, n in entry.get("mesh", ())) or None
        plan = plan_kernel(
            kernel, shape, dtype, mesh=mesh,
            sublanes=int(knobs["sublanes"]),
            vmem_budget=int(knobs["vmem_budget"]),
        )
        expect = entry.get("expect", {})
        derived = {"padded_shape": list(plan.padded_shape),
                   "block_shape": list(plan.block_shape)}
        drift = {k: (expect[k], derived[k]) for k in expect
                 if expect[k] != derived[k]}
        if drift:
            msg = (
                f"{path}: profiled cell {kernel} {shape} {dtype} no longer "
                f"reproduces its swept geometry (planner drift): "
                + "; ".join(f"{k}: profiled {a} != derived {b}"
                            for k, (a, b) in drift.items())
            )
            if obs.enabled():
                # Streamed before strict raises: a production loader that
                # dies on drift still leaves the event in the stream.
                obs.emit(obs.ProfileDriftEvent(
                    path=path, cell=f"{kernel} {shape} {dtype}",
                    detail="; ".join(
                        f"{k}: profiled {a} != derived {b}"
                        for k, (a, b) in sorted(drift.items()))))
            if strict:
                raise ValueError(msg)
            warnings.warn(msg + " -- entry skipped", stacklevel=2)
            continue
        overrides[profile_key(kernel, shape, dtype)] = dataclasses.replace(
            plan, provenance=f"profile:{path}"
        )
    return overrides


def audit_profile(path: str) -> list[dict]:
    """Static hygiene check of one profile for ``repro.analyze``.

    Unlike :func:`load_profile` this never raises on a bad cell -- it
    returns one issue dict per problem (``kind``, ``cell``, ``detail``) so
    the analyzer can report every stale or orphan override at once:

    * ``orphan``  -- the cell's kernel is no longer in the registry, so no
      launch can ever consume the override (``PlanContext.plan_overrides``
      keys by kernel name).
    * ``stale``   -- re-deriving the plan under the recorded knobs no longer
      reproduces the recorded geometry: the planner moved since the sweep,
      and a strict ``load_profile`` of this file will fail.
    * ``invalid`` -- the entry cannot be planned at all (unknown dtype,
      unplannable shape, missing fields).
    """
    from repro.api import registry

    with open(path) as f:
        doc = json.load(f)
    if doc.get("format") != PROFILE_FORMAT:
        return [{"kind": "invalid", "cell": path,
                 "detail": f"not a plan profile (format={doc.get('format')!r})"}]
    registered = set(registry.list_kernels())
    issues: list[dict] = []
    for entry in doc.get("entries", ()):
        kernel = entry.get("kernel", "?")
        cell = (f"{kernel} {tuple(entry.get('logical_shape', ()))} "
                f"{entry.get('dtype', '?')}")
        if kernel not in registered:
            issues.append({
                "kind": "orphan", "cell": cell,
                "detail": f"kernel {kernel!r} is not registered; the "
                          f"override can never be consumed",
            })
            continue
        try:
            shape = tuple(int(s) for s in entry["logical_shape"])
            knobs = entry["knobs"]
            mesh = tuple((str(a), int(n))
                         for a, n in entry.get("mesh", ())) or None
            plan = plan_kernel(
                kernel, shape, entry["dtype"], mesh=mesh,
                sublanes=int(knobs["sublanes"]),
                vmem_budget=int(knobs["vmem_budget"]),
            )
        except Exception as e:  # noqa: BLE001 -- report, don't crash the audit
            issues.append({"kind": "invalid", "cell": cell,
                           "detail": f"{type(e).__name__}: {e}"})
            continue
        expect = entry.get("expect", {})
        derived = {"padded_shape": list(plan.padded_shape),
                   "block_shape": list(plan.block_shape)}
        drift = {k: (expect[k], derived[k]) for k in expect
                 if expect[k] != derived[k]}
        if drift:
            issues.append({
                "kind": "stale", "cell": cell,
                "detail": "; ".join(
                    f"{k}: profiled {a} != derived {b}"
                    for k, (a, b) in sorted(drift.items())),
            })
    return issues
