"""LR schedules: cosine and MiniCPM's WSD (warmup-stable-decay)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, peak: float, warmup: int, total: int, floor: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak * step / jnp.maximum(warmup, 1)
    frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = floor * peak + (1 - floor) * peak * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < warmup, warm, cos)


def wsd(step, *, peak: float, warmup: int, total: int, decay_frac: float = 0.1,
        floor: float = 0.01):
    """Warmup -> Stable (constant peak) -> Decay (final decay_frac of steps,
    exponential to floor*peak), per MiniCPM (arXiv:2404.06395)."""
    step = jnp.asarray(step, jnp.float32)
    decay_steps = jnp.maximum(total * decay_frac, 1.0)
    decay_start = total - decay_steps
    warm = peak * step / jnp.maximum(warmup, 1)
    frac = jnp.clip((step - decay_start) / decay_steps, 0.0, 1.0)
    dec = peak * jnp.exp(jnp.log(floor) * frac)
    out = jnp.where(step < warmup, warm, peak)
    return jnp.where(step > decay_start, dec, out)


def make_schedule(kind: str, *, peak: float = 3e-4, warmup: int = 100,
                  total: int = 10_000):
    if kind == "wsd":
        return lambda s: wsd(s, peak=peak, warmup=warmup, total=total)
    if kind == "cosine":
        return lambda s: warmup_cosine(s, peak=peak, warmup=warmup, total=total)
    raise ValueError(kind)
