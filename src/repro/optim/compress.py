"""Gradient compression for the data-parallel reduction (int8 + error
feedback).

On a pod the DP gradient reduction is the largest recurring collective.  XLA
inserts it automatically when batch is sharded, so to compress it we take
that reduction out of XLA's hands with shard_map over the data axis: each DP
group computes local grads, quantizes to int8 with a per-tensor scale,
psum's the int8 payload (4x less ICI traffic than fp32, 2x less than bf16),
dequantizes, and keeps the quantization residual as error feedback for the
next step (Seide et al.-style EF-SGD, applied to AdamW's input).

``dp_compressed_grads`` handles the pure-DP case (model replicated inside
the group; TP axes stay outside the shard_map and keep XLA-managed
collectives).  It composes with the trainer via ``grad_fn`` injection.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.shardmap_compat import NO_CHECK as _NO_CHECK
from repro.parallel.shardmap_compat import shard_map


def quantize(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization."""
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-8) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_roundtrip(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(reconstructed, residual) -- residual feeds the next step's EF."""
    q, s = quantize(g)
    rec = dequantize(q, s)
    return rec, g - rec


def dp_compressed_grads(
    loss_fn: Callable,
    params,
    batch,
    ef_state,
    mesh,
    *,
    axis: str = "data",
):
    """Per-shard grads -> EF add -> int8 -> psum -> dequant, via shard_map.

    loss_fn(params, batch) -> scalar.  params replicated over ``axis``;
    batch sharded on its leading dim.  ef_state is a grads-shaped pytree of
    fp32 residuals (zeros at step 0).  Returns (grads, new_ef_state).
    """
    pspec_batch = jax.tree.map(lambda _: P(axis), batch)
    pspec_rep = jax.tree.map(lambda _: P(), params)

    def local(params, batch, ef):
        g = jax.grad(loss_fn, allow_int=True)(params, batch)
        n_shards = jax.lax.psum(1, axis)

        def one(gi, e):
            gi = gi.astype(jnp.float32) / n_shards + e
            q, s = quantize(gi)
            qsum = jax.lax.psum(q.astype(jnp.int32), axis)  # int payload reduce
            ssum = jax.lax.psum(s, axis) / n_shards
            rec_local = dequantize(q, s)
            return qsum.astype(jnp.float32) * ssum, gi - rec_local

        pairs = jax.tree.map(one, g, ef)
        grads = jax.tree.map(lambda t: t[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_ef = jax.tree.map(lambda t: t[1], pairs,
                              is_leaf=lambda x: isinstance(x, tuple))
        return grads, new_ef

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(pspec_rep, pspec_batch, pspec_rep),
        out_specs=(pspec_rep, pspec_rep),
        **_NO_CHECK,
    )
    return fn(params, batch, ef_state)


def init_ef(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
