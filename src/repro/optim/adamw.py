"""Sharded AdamW with global-norm clipping and optional fp32 master copy.

Optimizer state is a pytree shaped like the parameters, so it inherits the
parameter PartitionSpecs (ZeRO-style: under FSDP rules the master/moment
tensors are sharded over the data axis together with the weights).  For the
largest configs (grok-1) ``master=False`` keeps updates in bf16 with fp32
moments only -- the memory budget note lives in EXPERIMENTS.md SSDry-run.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    master: bool = True            # keep fp32 master weights when params are bf16


def _trainable(path, p) -> bool:
    return jnp.issubdtype(p.dtype, jnp.floating) and not any(
        getattr(k, "key", None) == "perm" for k in path
    )


def init_state(params, cfg: AdamWConfig) -> dict:
    def moment(path, p):
        return jnp.zeros(p.shape, jnp.float32) if _trainable(path, p) else jnp.zeros(
            (), jnp.float32
        )

    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree_util.tree_map_with_path(moment, params),
        "v": jax.tree_util.tree_map_with_path(moment, params),
    }
    if cfg.master:
        state["master"] = jax.tree_util.tree_map_with_path(
            lambda path, p: p.astype(jnp.float32) if _trainable(path, p) else p,
            params,
        )
    return state


def global_norm(tree) -> jax.Array:
    sq = jax.tree.map(
        lambda g: jnp.sum(jnp.square(g.astype(jnp.float32)))
        if jnp.issubdtype(g.dtype, jnp.floating) else jnp.zeros((), jnp.float32),
        tree,
    )
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq, jnp.zeros((), jnp.float32)))


def apply_updates(params, grads, state, lr, cfg: AdamWConfig):
    """One AdamW step.  Integer/perm leaves pass through untouched."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    master = state.get("master", params)

    def one(path, p, g, m, v, w):
        if not _trainable(path, p):
            return p, m, v, w
        gf = g.astype(jnp.float32) * scale
        m1 = cfg.b1 * m + (1 - cfg.b1) * gf
        v1 = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        upd = (m1 / b1c) / (jnp.sqrt(v1 / b2c) + cfg.eps)
        base = w.astype(jnp.float32) - lr * (upd + cfg.weight_decay
                                             * w.astype(jnp.float32))
        return base.astype(p.dtype), m1, v1, base

    fused = jax.tree_util.tree_map_with_path(
        one, params, grads, state["m"], state["v"], master
    )
    # unzip the 4-tuples
    outer = jax.tree_util.tree_structure(params)
    leaves = jax.tree_util.tree_leaves(fused, is_leaf=lambda x: isinstance(x, tuple))
    cols = list(zip(*leaves)) if leaves else ((),) * 4
    unflat = lambda c: jax.tree_util.tree_unflatten(outer, list(c))
    params_out, m_out, v_out, master_out = (unflat(c) for c in cols)
    out_state = {"step": step, "m": m_out, "v": v_out}
    if cfg.master:
        out_state["master"] = master_out
    return params_out, out_state, {"grad_norm": gnorm}
