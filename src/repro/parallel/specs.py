"""PartitionSpec derivation for params, optimizer state, batches, caches."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.params import ParamDef, map_tree
from repro.parallel.rules import spec


def _floating(d: ParamDef) -> bool:
    return jnp.issubdtype(jnp.dtype(d.dtype), jnp.floating)


def param_specs(defs, rules) -> dict:
    return map_tree(lambda d: spec(*d.axes, rules=rules, shape=d.shape), defs)


def opt_state_specs(defs, rules) -> dict:
    """Specs matching optim.adamw.init_state structure."""
    moment = map_tree(
        lambda d: spec(*d.axes, rules=rules, shape=d.shape)
        if _floating(d) else P(), defs
    )
    return {"step": P(), "m": moment, "v": moment}


def master_specs(defs, rules) -> dict:
    return param_specs(defs, rules)


def state_specs(defs, rules, *, master: bool) -> dict:
    out = {"params": param_specs(defs, rules), "opt": opt_state_specs(defs, rules)}
    if master:
        out["opt"]["master"] = master_specs(defs, rules)
    return out


def batch_specs(batch_tree, rules) -> dict:
    """Leading axis of every batch leaf is the (global) batch axis."""
    return jax.tree.map(
        lambda x: spec("batch", None, rules=rules, shape=tuple(x.shape)),
        batch_tree,
    )


def cache_specs(cache_defs_tree, rules) -> dict:
    return map_tree(lambda d: spec(*d.axes, rules=rules, shape=d.shape),
                    cache_defs_tree)
