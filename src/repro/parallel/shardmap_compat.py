"""shard_map version compatibility, shared by every call site.

Two things moved across jax versions: the import location (jax >= 0.8 has
``jax.shard_map``; older versions only ``jax.experimental.shard_map``) and
the replication-check kwarg (``check_rep`` renamed to ``check_vma``).
``NO_CHECK`` is the kwargs dict that disables the check under whichever
name this jax accepts.

``inside_shard_map`` answers "am I already under a mapped trace?" -- the
guard ``api.spmd`` uses so the SPMD kernel-launch path never nests a
``shard_map`` inside a pipeline stage (or pmap body) that is itself one.
"""
from __future__ import annotations

import inspect

import jax

try:  # jax >= 0.8
    from jax import shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map

_PARAMS = frozenset(inspect.signature(shard_map).parameters)
NO_CHECK = (
    {"check_vma": False} if "check_vma" in _PARAMS
    else {"check_rep": False} if "check_rep" in _PARAMS
    else {}
)


def inside_shard_map() -> bool:
    """True when called under an active mapped trace (a shard_map or pmap
    body binds its mesh axis names into the axis environment; plain jit does
    not).  Best-effort across jax versions: when no probe is available the
    answer is False, which only costs the caller a nested-shard_map error
    it would have hit anyway."""
    probe = getattr(jax.core, "nonempty_axis_env_DO_NOT_USE", None)
    if probe is not None:
        return bool(probe())
    names = getattr(jax.core, "unsafe_get_axis_names_DO_NOT_USE", None)
    if names is not None:  # pragma: no cover - version-dependent fallback
        return bool(names())
    return False  # pragma: no cover


__all__ = ["shard_map", "NO_CHECK", "inside_shard_map"]
