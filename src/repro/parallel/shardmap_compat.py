"""shard_map version compatibility, shared by every call site.

Two things moved across jax versions: the import location (jax >= 0.8 has
``jax.shard_map``; older versions only ``jax.experimental.shard_map``) and
the replication-check kwarg (``check_rep`` renamed to ``check_vma``).
``NO_CHECK`` is the kwargs dict that disables the check under whichever
name this jax accepts.
"""
from __future__ import annotations

import inspect

try:  # jax >= 0.8
    from jax import shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map

_PARAMS = frozenset(inspect.signature(shard_map).parameters)
NO_CHECK = (
    {"check_vma": False} if "check_vma" in _PARAMS
    else {"check_rep": False} if "check_rep" in _PARAMS
    else {}
)

__all__ = ["shard_map", "NO_CHECK"]
