"""Step functions: train / prefill / decode, pjit-ready.

These close over the model facade and optimizer config; the launcher (or
dry-run) wraps them in jax.jit with in/out shardings derived from
parallel.specs and lowers against abstract inputs.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.optim import adamw


def make_train_step(model, opt_cfg: adamw.AdamWConfig,
                    schedule: Callable, *, microbatches: int = 1) -> Callable:
    """Train step with optional gradient accumulation.

    With ``microbatches > 1`` the global batch is processed as a scan over
    micro-slices with fp32 gradient accumulation -- the standard activation
    -memory lever at 4k+ sequence lengths (the optimizer update still sees
    the full-batch gradient, so numerics are schedule-identical up to fp32
    accumulation order).
    """

    def grad_fn(params, batch):
        return jax.value_and_grad(model.loss, allow_int=True)(params, batch)

    def train_step(state: dict, batch: dict) -> tuple[dict, dict]:
        params = state["params"]
        if microbatches == 1:
            loss, grads = grad_fn(params, batch)
        else:
            split = jax.tree.map(
                lambda x: x.reshape(microbatches, x.shape[0] // microbatches,
                                    *x.shape[1:]),
                batch,
            )

            def acc_fn(carry, mb):
                closs, cgrads = carry
                loss, grads = grad_fn(params, mb)
                def add(a, g):
                    if g.dtype == jax.dtypes.float0:
                        return a
                    return a + g.astype(jnp.float32)

                cgrads = jax.tree.map(add, cgrads, grads)
                return (closs + loss, cgrads), None

            init = (
                jnp.zeros((), jnp.float32),
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            )
            (loss, grads), _ = jax.lax.scan(acc_fn, init, split)
            inv = 1.0 / microbatches
            loss = loss * inv
            grads = jax.tree.map(lambda g: g * inv, grads)
        lr = schedule(state["opt"]["step"])
        params, opt, metrics = adamw.apply_updates(
            params, grads, state["opt"], lr, opt_cfg
        )
        return {"params": params, "opt": opt}, {
            "loss": loss, "lr": lr, **metrics
        }

    return train_step


def make_eval_step(model) -> Callable:
    def eval_step(params: dict, batch: dict) -> jax.Array:
        return model.loss(params, batch)

    return eval_step


def make_prefill_step(model) -> Callable:
    """Inference prefill: full forward, returns fp32 logits of the last
    position (the serving handoff) plus the full-sequence logits."""
    cfg = model.cfg

    def prefill_step(params: dict, batch: dict):
        if cfg.family == "encdec":
            logits, _ = model.forward(params, batch["tokens"], batch["frames"])
        elif cfg.family == "vlm":
            logits, _ = model.forward(params, batch["tokens"],
                                      batch.get("img_embeds"))
        else:
            logits, _ = model.forward(params, batch["tokens"])
        return logits[:, -1, :]

    return prefill_step


def make_decode_step(model) -> Callable:
    """serve_step: one new token against the KV/state cache; greedy token."""

    def decode_step(params: dict, cache: dict, tokens: jax.Array):
        logits, new_cache = model.decode_step(params, cache, tokens)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok[:, None], new_cache

    return decode_step


def make_chunk_step(model, batch_axes) -> Callable:
    """Chunked serve step: advance each batch row by its own number of
    tokens (0..C) in ONE jitted call -- the continuous batcher's chunked
    -prefill tick (docs/SERVING.md).

    ``chunk_step(params, cache, tokens, nvalid)`` scans C masked micro
    decode steps: at micro-step c only rows with ``c < nvalid`` advance.
    Frozen rows are restored leaf-by-leaf along their cache batch axis
    (``batch_axes``: a cache-shaped pytree of ints, -1 for leaves with no
    batch axis -- the shared paged pools, which instead self-mask by
    routing inactive writes to the null page via the cache's ``act``
    leaf).  Because batch rows are independent in the model, each row's
    tokens are *bit-identical* to stepping it alone one token at a time --
    chunking is purely a scheduling lever, never a numerics change.

    Returns ``(next_token (B, 1), new_cache)`` where ``next_token[b]`` is
    the greedy token after row b's last valid input (garbage for rows with
    ``nvalid == 0``; the scheduler ignores them).
    """

    def _restore(new, old, ax, active):
        if ax < 0:
            return new
        mask = active.reshape(
            tuple(new.shape[ax] if d == ax else 1 for d in range(new.ndim)))
        return jnp.where(mask, new, old)

    def chunk_step(params: dict, cache: dict, tokens: jax.Array,
                   nvalid: jax.Array):
        c_total = tokens.shape[1]

        def micro(carry, inp):
            cur = carry
            tok, c = inp
            active = c < nvalid                                   # (B,)
            if "act" in cur:
                cur = dict(cur)
                cur["act"] = active.astype(jnp.int32)
            logits, nc = model.decode_step(params, cur, tok[:, None])
            nc = jax.tree.map(
                lambda n, o, ax: _restore(n, o, ax, active),
                nc, cur, batch_axes)
            nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
            return nc, nxt

        xs = (tokens.T, jnp.arange(c_total, dtype=jnp.int32))
        new_cache, toks = jax.lax.scan(micro, cache, xs)          # toks (C,B)
        sel = jnp.clip(nvalid - 1, 0, c_total - 1)
        next_tok = jnp.take_along_axis(toks.T, sel[:, None], axis=1)
        if "act" in new_cache:
            new_cache = dict(new_cache)
            new_cache["act"] = jnp.ones_like(new_cache["act"])
        return next_tok, new_cache

    return chunk_step


def init_train_state(model, opt_cfg: adamw.AdamWConfig, key) -> dict:
    params = model.init(key)
    return {"params": params, "opt": adamw.init_state(params, opt_cfg)}
