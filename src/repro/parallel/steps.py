"""Step functions: train / prefill / decode, pjit-ready.

These close over the model facade and optimizer config; the launcher (or
dry-run) wraps them in jax.jit with in/out shardings derived from
parallel.specs and lowers against abstract inputs.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.optim import adamw


def make_train_step(model, opt_cfg: adamw.AdamWConfig,
                    schedule: Callable, *, microbatches: int = 1) -> Callable:
    """Train step with optional gradient accumulation.

    With ``microbatches > 1`` the global batch is processed as a scan over
    micro-slices with fp32 gradient accumulation -- the standard activation
    -memory lever at 4k+ sequence lengths (the optimizer update still sees
    the full-batch gradient, so numerics are schedule-identical up to fp32
    accumulation order).
    """

    def grad_fn(params, batch):
        return jax.value_and_grad(model.loss, allow_int=True)(params, batch)

    def train_step(state: dict, batch: dict) -> tuple[dict, dict]:
        params = state["params"]
        if microbatches == 1:
            loss, grads = grad_fn(params, batch)
        else:
            split = jax.tree.map(
                lambda x: x.reshape(microbatches, x.shape[0] // microbatches,
                                    *x.shape[1:]),
                batch,
            )

            def acc_fn(carry, mb):
                closs, cgrads = carry
                loss, grads = grad_fn(params, mb)
                def add(a, g):
                    if g.dtype == jax.dtypes.float0:
                        return a
                    return a + g.astype(jnp.float32)

                cgrads = jax.tree.map(add, cgrads, grads)
                return (closs + loss, cgrads), None

            init = (
                jnp.zeros((), jnp.float32),
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            )
            (loss, grads), _ = jax.lax.scan(acc_fn, init, split)
            inv = 1.0 / microbatches
            loss = loss * inv
            grads = jax.tree.map(lambda g: g * inv, grads)
        lr = schedule(state["opt"]["step"])
        params, opt, metrics = adamw.apply_updates(
            params, grads, state["opt"], lr, opt_cfg
        )
        return {"params": params, "opt": opt}, {
            "loss": loss, "lr": lr, **metrics
        }

    return train_step


def make_eval_step(model) -> Callable:
    def eval_step(params: dict, batch: dict) -> jax.Array:
        return model.loss(params, batch)

    return eval_step


def make_prefill_step(model) -> Callable:
    """Inference prefill: full forward, returns fp32 logits of the last
    position (the serving handoff) plus the full-sequence logits."""
    cfg = model.cfg

    def prefill_step(params: dict, batch: dict):
        if cfg.family == "encdec":
            logits, _ = model.forward(params, batch["tokens"], batch["frames"])
        elif cfg.family == "vlm":
            logits, _ = model.forward(params, batch["tokens"],
                                      batch.get("img_embeds"))
        else:
            logits, _ = model.forward(params, batch["tokens"])
        return logits[:, -1, :]

    return prefill_step


def make_decode_step(model) -> Callable:
    """serve_step: one new token against the KV/state cache; greedy token."""

    def decode_step(params: dict, cache: dict, tokens: jax.Array):
        logits, new_cache = model.decode_step(params, cache, tokens)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok[:, None], new_cache

    return decode_step


def init_train_state(model, opt_cfg: adamw.AdamWConfig, key) -> dict:
    params = model.init(key)
    return {"params": params, "opt": adamw.init_state(params, opt_cfg)}
