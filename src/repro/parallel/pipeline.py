"""Pipeline parallelism: GPipe forward schedule via shard_map + ppermute.

For depth-dominated models (grok's 64 layers) the model axis can be spent
on *stages* instead of tensor shards: mesh ("data", "stage"), layer stack
split into S contiguous stages, microbatches streamed through the pipe with
``lax.ppermute`` hops between neighbouring stages.  Wall-clock steps =
n_micro + S - 1; bubble fraction = (S-1)/(n_micro+S-1).

``pipeline_apply`` is generic over a ``layer_fn(stage_params, x) -> x``
(typically a scan over the stage's layer slice) so any homogeneous block
stack in the zoo can be pipelined.  The paper connection: stage placement
is one more address->resource map; the microbatch skew across stages is
literally the paper's shifted-segment picture in time.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.shardmap_compat import NO_CHECK as _NO_CHECK
from repro.parallel.shardmap_compat import shard_map as _shard_map


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)


def pipeline_apply(
    layer_fn: Callable,
    stage_params,
    x: jax.Array,
    *,
    mesh,
    n_micro: int,
    stage_axis: str = "stage",
    data_axis: str | None = "data",
):
    """Run x through S pipeline stages of layers.

    stage_params: pytree with leading dim S (one slice per stage), sharded
    over ``stage_axis``.  x: (B, ...) with B % n_micro == 0; the batch dim
    may additionally be sharded over ``data_axis``.  Returns layer_fn
    composed over all stages, identical (up to dtype rounding) to the
    sequential application.
    """
    s = dict(zip(mesh.axis_names, mesh.devices.shape))[stage_axis]
    b = x.shape[0]
    assert b % n_micro == 0, (b, n_micro)
    mb = b // n_micro
    xm = x.reshape(n_micro, mb, *x.shape[1:])

    in_specs = (
        jax.tree.map(lambda _: P(stage_axis), stage_params),
        P(None, data_axis) if data_axis else P(),
    )
    out_spec = P(None, data_axis) if data_axis else P()

    def run(params_local, xm_local):
        # params_local leaves: (1, ...) -- this stage's slice
        params_here = jax.tree.map(lambda a: a[0], params_local)
        sid = jax.lax.axis_index(stage_axis)
        steps = n_micro + s - 1
        zero = jnp.zeros_like(xm_local[0])
        perm_fwd = [(i, i + 1) for i in range(s - 1)]

        def body(i, carry):
            inbuf, outs = carry
            # stage 0 injects microbatch i (while valid); others take inbuf
            mb_i = jnp.clip(i, 0, n_micro - 1)
            first_in = jnp.where(i < n_micro, 1.0, 0.0) * xm_local[mb_i]
            x_in = jnp.where(sid == 0, first_in, inbuf)
            y = layer_fn(params_here, x_in)
            # collect on the last stage when its microbatch index is valid
            out_i = i - (s - 1)
            valid = (sid == s - 1) & (out_i >= 0)
            oi = jnp.clip(out_i, 0, n_micro - 1)
            outs = jax.lax.cond(
                valid,
                lambda o: o.at[oi].set(y),
                lambda o: o,
                outs,
            )
            inbuf = jax.lax.ppermute(y, stage_axis, perm_fwd)
            return inbuf, outs

        outs0 = jnp.zeros_like(xm_local)
        _, outs = jax.lax.fori_loop(0, steps, body, (zero, outs0))
        # replicate the last stage's collected outputs to every stage
        outs = jax.lax.psum(
            jnp.where(sid == s - 1, outs, jnp.zeros_like(outs)), stage_axis
        )
        return outs

    fn = _shard_map(run, mesh=mesh, in_specs=in_specs, out_specs=out_spec,
                    **_NO_CHECK)
    out = fn(stage_params, xm)
    return out.reshape(b, *x.shape[1:])


def split_stages(stacked_params, n_stages: int):
    """(L, ...) stacked layer params -> (S, L/S, ...) stage slices."""

    def f(a):
        l = a.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return a.reshape(n_stages, l // n_stages, *a.shape[1:])

    return jax.tree.map(f, stacked_params)
