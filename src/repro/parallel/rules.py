"""Logical-axis sharding rules (Megatron/MaxText-style, pjit-native).

Model code annotates tensors with *logical* axis names ("batch", "heads",
"mlp", ...).  A ``Rules`` table -- chosen per mesh and per arch -- maps each
logical axis to zero or more mesh axes.  The mapping is applied inside jit
via ``with_sharding_constraint``; outside any rules context the annotations
are free no-ops, so the same model code runs on one CPU device in tests and
on a 512-chip mesh in the dry-run.

The paper connection: a sharding rule *is* an address->resource map.  The
roofline/perf loop tunes this table the same way the paper tunes offsets --
analytically, from the (collective-)traffic model, not by trial and error.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Mapping

import jax
from jax.sharding import PartitionSpec as P

AxisTarget = str | tuple[str, ...] | None

# sensible single-pod defaults; launchers override per mesh/arch/shape
DEFAULT_RULES: dict[str, AxisTarget] = {
    "batch": ("data",),
    "seq": None,
    "embed": None,          # -> ("data",) under FSDP
    "mlp": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": None,
    "vocab": ("model",),
    "expert": ("model",),
    "expert_mlp": None,     # grok-style few-expert TP: -> ("model",)
    "expert_cap": ("data",),  # MoE dispatch-buffer capacity axis
    "expert_out": None,       # expert-TP: reduce-scatter the output d axis
    "cache_seq": None,      # -> ("data",) for long-context decode
    "state": None,
    "layers": None,
    "conv": None,
    "frames": None,
}

_active: contextvars.ContextVar[Mapping[str, AxisTarget] | None] = (
    contextvars.ContextVar("repro_sharding_rules", default=None)
)
_axis_sizes: contextvars.ContextVar[Mapping[str, int] | None] = (
    contextvars.ContextVar("repro_mesh_axis_sizes", default=None)
)
_mesh: contextvars.ContextVar = contextvars.ContextVar("repro_mesh", default=None)


@contextlib.contextmanager
def use_rules(rules: Mapping[str, AxisTarget] | None, mesh=None):
    token = _active.set(rules)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh is not None else None
    token2 = _axis_sizes.set(sizes)
    token3 = _mesh.set(mesh)
    try:
        yield
    finally:
        _active.reset(token)
        _axis_sizes.reset(token2)
        _mesh.reset(token3)


def current_mesh():
    return _mesh.get()


def current_rules() -> Mapping[str, AxisTarget] | None:
    return _active.get()


def _divisible(dim: int, target: AxisTarget,
               axis_sizes: Mapping[str, int] | None = None) -> bool:
    """True when ``dim`` can be evenly sharded over the mapped mesh axes.
    ``axis_sizes`` overrides the sizes registered via ``use_rules`` (the SPMD
    launch path passes its mesh's sizes explicitly so the check works even
    outside any rules context).  Unknown axis sizes are assumed fine."""
    sizes = axis_sizes if axis_sizes is not None else _axis_sizes.get()
    if sizes is None or target is None:
        return True
    axes = (target,) if isinstance(target, str) else tuple(target)
    n = 1
    for a in axes:
        n *= sizes.get(a, 1)
    return dim % n == 0


def restrict_to_mesh(rules: Mapping[str, AxisTarget], mesh) -> dict[str, AxisTarget]:
    """A copy of ``rules`` with every target filtered to axes ``mesh``
    actually has.  A table written for the production ("data", "model") mesh
    then still yields valid PartitionSpecs on a test mesh with fewer (or
    renamed) axes -- missing axes simply fall back to replication."""
    names = set(mesh.axis_names)
    out: dict[str, AxisTarget] = {}
    for k, tgt in rules.items():
        if tgt is None:
            out[k] = None
            continue
        axes = (tgt,) if isinstance(tgt, str) else tuple(tgt)
        kept = tuple(a for a in axes if a in names)
        out[k] = (kept if len(kept) > 1 else kept[0]) if kept else None
    return out


def make_rules(
    *,
    multi_pod: bool = False,
    fsdp: bool = False,
    expert_tp: bool = False,
    shard_cache_seq: bool = False,
    overrides: Mapping[str, AxisTarget] | None = None,
) -> dict[str, AxisTarget]:
    """Build a rules table for a mesh/arch/shape combination."""
    rules = dict(DEFAULT_RULES)
    rules["batch"] = ("pod", "data") if multi_pod else ("data",)
    if multi_pod:
        # hierarchical MoE dispatch: keep the pod axis on the capacity axis
        # so the group->expert reshard stays pod-local (dropping it forces a
        # cross-pod all-gather of the whole dispatch buffer -- measured 13x
        # wire, EXPERIMENTS.md SSMulti-pod)
        rules["expert_cap"] = ("pod", "data")
    if fsdp:
        rules["embed"] = ("data",)
    if expert_tp:
        rules["expert"] = None
        rules["expert_mlp"] = ("model",)
    if shard_cache_seq:
        rules["cache_seq"] = ("data",)
    if overrides:
        rules.update(overrides)
    return rules


def spec(*axes: str | None, rules: Mapping[str, AxisTarget] | None = None,
         shape: tuple[int, ...] | None = None,
         axis_sizes: Mapping[str, int] | None = None) -> P:
    """PartitionSpec for a tuple of logical axis names.

    When ``shape`` is given (and a mesh is registered via use_rules, or
    ``axis_sizes`` passes mesh axis sizes explicitly), any dimension that is
    not evenly divisible by its mapped mesh axes falls back to replication
    -- the GSPMD-pragmatic baseline the layout policy then improves on by
    padding (EXPERIMENTS.md SSPerf).
    """
    p, _ = spec_report(*axes, rules=rules, shape=shape, axis_sizes=axis_sizes)
    return p


def spec_report(*axes: str | None,
                rules: Mapping[str, AxisTarget] | None = None,
                shape: tuple[int, ...] | None = None,
                axis_sizes: Mapping[str, int] | None = None
                ) -> tuple[P, list[str]]:
    """``spec`` plus a human-readable reason for every dimension whose
    declared sharding fell back to replication (divisibility, or a mesh axis
    already consumed by an earlier dim).  The SPMD kernel-launch path logs
    these so a vocab of 1111 over ``model=4`` replicating instead of
    sharding is a recorded decision, not a silent one."""
    rules = rules if rules is not None else (current_rules() or {})
    parts = []
    fallbacks: list[str] = []
    used: set[str] = set()
    for i, ax in enumerate(axes):
        tgt = rules.get(ax) if ax is not None else None
        if tgt is not None and shape is not None and not _divisible(
            shape[i], tgt, axis_sizes
        ):
            sizes = axis_sizes if axis_sizes is not None else _axis_sizes.get()
            names = (tgt,) if isinstance(tgt, str) else tuple(tgt)
            n = 1
            for a in names:
                n *= (sizes or {}).get(a, 1)
            fallbacks.append(
                f"dim {i} ({ax!r}, size {shape[i]}) replicated: not "
                f"divisible by mesh axes {names} (x{n})"
            )
            tgt = None
        if tgt is not None:
            # a mesh axis may appear at most once per spec: first dim wins
            names = (tgt,) if isinstance(tgt, str) else tuple(tgt)
            kept = tuple(n for n in names if n not in used)
            if kept != names:
                fallbacks.append(
                    f"dim {i} ({ax!r}) dropped mesh axes "
                    f"{tuple(n for n in names if n in used)}: already used "
                    f"by an earlier dim"
                )
            used.update(kept)
            tgt = kept or None
            if tgt is not None and shape is not None and not _divisible(
                shape[i], tgt, axis_sizes
            ):
                fallbacks.append(
                    f"dim {i} ({ax!r}, size {shape[i]}) replicated: not "
                    f"divisible by remaining mesh axes {tgt}"
                )
                tgt = None
        if tgt is None:
            parts.append(None)
        elif isinstance(tgt, str):
            parts.append(tgt)
        else:
            parts.append(tuple(tgt) if len(tgt) > 1 else tgt[0])
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts), fallbacks


def shard(x: jax.Array, *axes: str | None) -> jax.Array:
    """Constrain ``x``'s sharding by logical axes; no-op without a mesh."""
    rules = current_rules()
    mesh = _mesh.get()
    if rules is None or mesh is None:
        return x
    s = spec(*axes, rules=rules, shape=tuple(x.shape))
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, s)
    )


def tree_specs(axes_tree, rules: Mapping[str, AxisTarget] | None = None):
    """Map a tree of logical-axes tuples to PartitionSpecs."""
    rules = rules if rules is not None else (current_rules() or {})
    return jax.tree.map(
        lambda axes: spec(*axes, rules=rules),
        axes_tree,
        is_leaf=lambda v: isinstance(v, tuple) and all(
            isinstance(a, str) or a is None for a in v
        ),
    )
