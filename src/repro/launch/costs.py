"""Roofline cost extraction via pattern-unit extrapolation.

cost_analysis() counts a while-loop body once, so scanned-layer programs
undercount FLOPs/bytes/collectives by the trip count.  Fix: lower *unrolled*
variants with 1 and 2 pattern units (a unit = the repeating layer group:
1 layer for dense/moe, shared_attn_period for zamba2, slstm_every for
xlstm, one enc+dec layer pair for whisper), then extrapolate linearly:

    total(n_units) = c(1) + (n_units - 1) * (c(2) - c(1))

Exact for homogeneous stacks; for zamba2 (38 layers, period 6 -> 6.33
units) the shared-attention share is overcounted by ~5% of its own (small)
share -- noted in EXPERIMENTS.md.  The *full scanned* program is still what
the dry-run compiles for the memory proof.
"""
from __future__ import annotations

import dataclasses


from repro.launch import lowering


def pattern_unit(cfg) -> int:
    if cfg.family == "hybrid" and cfg.shared_attn_period:
        return cfg.shared_attn_period
    if cfg.family == "ssm" and cfg.slstm_every:
        return cfg.slstm_every
    return 1


def reduced_cfg(cfg, units: int):
    import dataclasses as dc

    unit = pattern_unit(cfg)
    kw = {"n_layers": unit * units, "unroll": True}
    if cfg.family == "encdec":
        kw["n_enc_layers"] = units
        kw["n_layers"] = units
    return dc.replace(cfg, **kw)


def n_units(cfg) -> float:
    if cfg.family == "encdec":
        return float(cfg.n_layers)  # enc and dec both scale 1:1 per unit
    return cfg.n_layers / pattern_unit(cfg)


@dataclasses.dataclass
class CellCosts:
    flops: float                 # per-device, full model, one step
    hbm_bytes: float             # per-device bytes accessed (proxy)
    wire_bytes: float            # per-device ICI bytes
    collectives: dict            # extrapolated per-type census
    unit_flops: float
    raw: dict                    # c1/c2 measurements


def _measure(arch, shape_name, mesh, cfg) -> dict:
    cell = lowering.lower_cell_with_cfg(arch, shape_name, mesh, cfg,
                                    microbatches=1)
    compiled = cell.lowered.compile()
    cost = lowering.cost_stats(compiled)
    census = lowering.collective_census(compiled.as_text())
    return {
        "flops": cost["flops"],
        "bytes": cost["bytes"],
        "census": census,
        "wire": lowering.census_total(census),
    }


def cell_costs(arch: str, shape_name: str, mesh, *, padded: bool = True
               ) -> CellCosts:
    tp = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    cfg, _ = lowering.cell_config(arch, padded=padded, tp=tp)
    c1 = _measure(arch, shape_name, mesh, reduced_cfg(cfg, 1))
    c2 = _measure(arch, shape_name, mesh, reduced_cfg(cfg, 2))
    k = n_units(cfg) - 1.0

    def extrap(a, b):
        return a + k * (b - a)

    coll = {}
    for op in c1["census"]:
        coll[op] = {
            key: extrap(c1["census"][op][key], c2["census"][op][key])
            for key in c1["census"][op]
        }
    return CellCosts(
        flops=extrap(c1["flops"], c2["flops"]),
        hbm_bytes=extrap(c1["bytes"], c2["bytes"]),
        wire_bytes=extrap(c1["wire"], c2["wire"]),
        collectives=coll,
        unit_flops=c2["flops"] - c1["flops"],
        raw={"c1": {k2: v for k2, v in c1.items() if k2 != "census"},
             "c2": {k2: v for k2, v in c2.items() if k2 != "census"}},
    )
