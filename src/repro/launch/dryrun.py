import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input shape) cell and each mesh -- single-pod
(16, 16) = 256 chips, multi-pod (2, 16, 16) = 512 chips -- this script:

  1. builds the production mesh (placeholder host devices; the two lines
     above run before any other import because jax locks the device count
     at first init),
  2. lowers + compiles the cell's step function (train_step for train_4k,
     prefill_step for prefill_32k, serve_step for decode cells) against
     ShapeDtypeStruct inputs -- no allocation,
  3. prints memory_analysis() (the fits-proof) and cost_analysis(),
  4. extracts the collective census and (optionally) the unit-extrapolated
     roofline cost terms (launch/costs.py),
  5. appends one JSON record per cell to --out.

Usage:
    python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k --mesh pod
    python -m repro.launch.dryrun --all --out results/dryrun.json
    python -m repro.launch.dryrun --all --mesh multipod --baseline
"""

import argparse
import json
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"], default="pod")
    ap.add_argument("--all", action="store_true", help="every applicable cell")
    ap.add_argument("--baseline", action="store_true",
                    help="raw paper dims (no layout-policy padding)")
    ap.add_argument("--costs", action="store_true",
                    help="also extract unit-extrapolated roofline costs")
    ap.add_argument("--out", default=None, help="append JSON records here")
    args = ap.parse_args()

    # heavyweight imports only after XLA_FLAGS is set
    import jax

    from repro.configs import ARCHS, get_config
    from repro.configs.shapes import SHAPES, shape_applicable
    from repro.launch import costs as costs_lib
    from repro.launch import lowering
    from repro.launch.mesh import make_production_mesh

    assert len(jax.devices()) == 512, "dry-run needs 512 placeholder devices"

    mesh_kinds = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    archs = list(ARCHS) if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    padded = not args.baseline

    records = []
    for mesh_kind in mesh_kinds:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
        for arch in archs:
            for shape_name in shapes:
                cfg0 = get_config(arch)
                ok, why = shape_applicable(cfg0, SHAPES[shape_name])
                rec = {
                    "arch": arch, "shape": shape_name, "mesh": mesh_kind,
                    "padded": padded,
                }
                if not ok:
                    rec.update(status="skipped", reason=why)
                    print(f"[skip] {arch} x {shape_name} x {mesh_kind}: {why}")
                    records.append(rec)
                    continue
                t0 = time.time()
                try:
                    cell = lowering.lower_cell(arch, shape_name, mesh,
                                               padded=padded)
                    compiled = cell.lowered.compile()
                    mem = lowering.memory_stats(compiled)
                    cost = lowering.cost_stats(compiled)
                    census = lowering.collective_census(compiled.as_text())
                    _, changes = lowering.cell_config(
                        arch, padded=padded,
                        tp=dict(zip(mesh.axis_names,
                                    mesh.devices.shape)).get("model", 1))
                    rec.update(
                        status="ok",
                        compile_s=round(time.time() - t0, 1),
                        memory=mem,
                        cost_raw=cost,          # scan bodies counted once
                        census_raw=census,
                        layout_changes=changes,
                        n_devices=int(mesh.devices.size),
                    )
                    print(f"[ok]   {arch} x {shape_name} x {mesh_kind} "
                          f"({rec['compile_s']}s) "
                          f"args={mem.get('argument_size_in_bytes', 0)/1e9:.2f}GB "
                          f"temp={mem.get('temp_size_in_bytes', 0)/1e9:.2f}GB")
                    print(f"       memory_analysis: {mem}")
                    print(f"       cost_analysis:   {cost}")
                    if args.costs:
                        cc = costs_lib.cell_costs(arch, shape_name, mesh,
                                                  padded=padded)
                        rec["costs"] = {
                            "flops": cc.flops,
                            "hbm_bytes": cc.hbm_bytes,
                            "wire_bytes": cc.wire_bytes,
                            "collectives": cc.collectives,
                            "raw": cc.raw,
                        }
                        print(f"       extrapolated: flops={cc.flops:.3e} "
                              f"hbm={cc.hbm_bytes:.3e} wire={cc.wire_bytes:.3e}")
                except Exception as e:  # noqa: BLE001 -- recorded, rethrown at end
                    rec.update(status="error", error=f"{type(e).__name__}: {e}",
                               trace=traceback.format_exc()[-2000:])
                    print(f"[FAIL] {arch} x {shape_name} x {mesh_kind}: {e}")
                records.append(rec)

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        existing = []
        if os.path.exists(args.out):
            with open(args.out) as f:
                existing = json.load(f)
        # replace same-key records (re-runs update in place)
        key = lambda r: (r["arch"], r["shape"], r["mesh"], r["padded"])
        merged = {key(r): r for r in existing}
        for r in records:
            merged[key(r)] = r
        with open(args.out, "w") as f:
            json.dump(list(merged.values()), f, indent=1)
        print(f"wrote {len(records)} records -> {args.out}")

    failures = [r for r in records if r.get("status") == "error"]
    if failures:
        raise SystemExit(f"{len(failures)} cell(s) failed")


if __name__ == "__main__":
    main()
