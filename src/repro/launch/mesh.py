"""Mesh construction for the production topology.

Single pod:  (16, 16)  -> ("data", "model")          = 256 chips (v5e pod)
Multi-pod:   (2, 16, 16) -> ("pod", "data", "model") = 512 chips

Functions, not module constants: importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"need {need} devices for {shape}, have {len(devices)} "
            "(run under launch/dryrun.py, which forces 512 host devices)"
        )
    import numpy as np

    dev = np.asarray(devices[:need]).reshape(shape)
    return jax.sharding.Mesh(dev, axes)


def make_test_mesh(shape=(1, 1), axes=("data", "model")):
    """Small mesh over however many devices exist (tests on 1 CPU)."""
    import numpy as np

    need = math.prod(shape)
    dev = np.asarray(jax.devices()[:need]).reshape(shape)
    return jax.sharding.Mesh(dev, axes)


def mesh_axis_size(mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)
