"""Cell lowering: (arch x shape x mesh) -> lowered/compiled artifacts + analysis.

This module must be imported only after jax device count is configured
(launch/dryrun.py sets XLA_FLAGS first).  It owns:

  * rules selection per (cfg, shape, mesh),
  * abstract state/batch/cache construction (ShapeDtypeStruct only),
  * jit lowering with NamedShardings,
  * post-compile analysis: cost_analysis, memory_analysis, and the
    collective-traffic census parsed from the optimized HLO.

Cost-accounting note (EXPERIMENTS.md SSRoofline): XLA's cost_analysis counts
a while-loop body ONCE, so for scan-over-layers programs FLOPs/bytes come
from small *unrolled* variants (1 and 2 pattern units) extrapolated linearly
in unit count -- exact for homogeneous stacks.  memory_analysis and the
compile proof always use the full scanned program.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.api import context as api_context
from repro.api import dispatch as api_dispatch
from repro.configs import get_config
from repro.configs.shapes import SHAPES, ShapeSpec, input_specs, shape_applicable
from repro.core import planner as planner_lib
from repro.models import build_model
from repro.models.params import ParamDef, abstract_params, map_tree
from repro.optim.adamw import AdamWConfig
from repro.optim.schedules import make_schedule
from repro.parallel import rules as rules_lib
from repro.parallel import specs as specs_lib
from repro.parallel import steps as steps_lib

# TPU v5e constants for the roofline terms
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


# ---------------------------------------------------------------------------
# Kernel layout planning
# ---------------------------------------------------------------------------

def kernel_plan(kernel: str, shape, dtype, mesh=None) -> planner_lib.KernelPlan:
    """The lowering path's hook into the analytic layout planner.

    Returns the memoized ``KernelPlan`` for a Pallas kernel family on this
    mesh -- mesh-aware minor-dim padding included -- so cell lowering and the
    roofline report consume the same plans the kernel wrappers execute.
    With ``mesh=None`` the ambient ``repro.api.plan_context`` decides (mesh,
    sublane policy, VMEM budget, plan overrides); an explicit mesh overrides
    just the mesh.  Routed through ``api.dispatch.plan_for`` so this report
    can never diverge from the plan ``launch()`` actually executes.
    """
    ctx = api_context.current_context()
    if mesh is not None:
        ctx = ctx.evolve(mesh=mesh)
    return api_dispatch.plan_for(kernel, shape, dtype, ctx=ctx)


def kernel_plan_report(cases, mesh=None) -> str:
    """Multi-plan ``planner.explain()`` report for (kernel, shape, dtype)
    triples (the dry-run analogue of the paper's parameter tables)."""
    return "\n".join(
        kernel_plan(k, s, d, mesh=mesh).explain() for k, s, d in cases
    )


# ---------------------------------------------------------------------------
# Cell construction
# ---------------------------------------------------------------------------

def cell_config(arch: str, *, padded: bool, tp: int = 16):
    cfg = get_config(arch)
    changes: dict = {}
    if padded:
        cfg, changes = cfg.padded_for_mesh(tp)
    return cfg, changes


def cell_rules(cfg, shape: ShapeSpec, *, multi_pod: bool, tp: int = 16):
    overrides = {}
    if shape.name == "long_500k":
        overrides = {"batch": None, "cache_seq": ("data",)}
    elif shape.kind == "decode" and cfg.n_kv_heads % tp:
        # flash-decoding style: KV heads cannot cover the model axis, so the
        # cache shards over *sequence* instead (softmax partials cross TP)
        overrides = {"cache_seq": ("model",), "kv_heads": None}
    n_dev = 512 if multi_pod else 256
    if (cfg.parallelism == "zero3" and shape.kind == "train"
            and (SHAPES[shape.name].global_batch % n_dev == 0)):
        # SSPerf (minicpm iteration 3): small dense models train fastest as
        # pure ZeRO-3 -- batch over every mesh axis, weights gathered
        # layerwise over the model axis, no TP at all.
        overrides.update({
            "mlp": None, "heads": None, "kv_heads": None,
            "embed": ("model",),
            "batch": ("pod", "data", "model") if multi_pod
            else ("data", "model"),
        })
    return rules_lib.make_rules(
        multi_pod=multi_pod,
        fsdp=cfg.fsdp,
        expert_tp=cfg.expert_tp,
        overrides=overrides,
    )


def abstract_opt_state(defs, opt_cfg: AdamWConfig):
    def moment(d: ParamDef):
        if jnp.issubdtype(jnp.dtype(d.dtype), jnp.floating):
            return jax.ShapeDtypeStruct(d.shape, jnp.float32)
        return jax.ShapeDtypeStruct((), jnp.float32)

    state = {
        "step": jax.ShapeDtypeStruct((), jnp.int32),
        "m": map_tree(moment, defs),
        "v": map_tree(moment, defs),
    }
    if opt_cfg.master:
        state["master"] = map_tree(
            lambda d: jax.ShapeDtypeStruct(
                d.shape,
                jnp.float32 if jnp.issubdtype(jnp.dtype(d.dtype), jnp.floating)
                else d.dtype,
            ),
            defs,
        )
    return state


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


@dataclasses.dataclass
class LoweredCell:
    arch: str
    shape: ShapeSpec
    mesh: Any
    cfg: Any
    lowered: Any
    kind: str


def lower_cell(arch: str, shape_name: str, mesh, *, padded: bool = True,
               opt_master: bool | None = None) -> LoweredCell:
    """Lower the cell's step (train/prefill/decode) for the given mesh."""
    tp = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    cfg, _ = cell_config(arch, padded=padded, tp=tp)
    return lower_cell_with_cfg(arch, shape_name, mesh, cfg,
                               opt_master=opt_master)


def lower_cell_with_cfg(arch: str, shape_name: str, mesh, cfg, *,
                        opt_master: bool | None = None,
                        microbatches: int = 4) -> LoweredCell:
    import dataclasses as _dc
    if cfg.n_experts and cfg.moe_groups == 1:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        cfg = _dc.replace(
            cfg, moe_groups=sizes.get("data", 1) * sizes.get("pod", 1)
        )
    n_dev = 512 if "pod" in mesh.axis_names else 256
    if (cfg.parallelism == "zero3" and shape_name == "train_4k"
            and SHAPES[shape_name].global_batch % n_dev == 0):
        microbatches = 1  # zero3 active: per-device batch is already 1 seq
    """Lower with an explicit (possibly reduced/unrolled) config.

    ``microbatches`` applies to train cells only (gradient accumulation);
    the cost-extraction path passes 1 so scan bodies stay out of the FLOPs
    denominator.
    """
    multi_pod = "pod" in mesh.axis_names
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        raise ValueError(why)
    model = build_model(cfg)
    tp = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    rules = cell_rules(cfg, shape, multi_pod=multi_pod, tp=tp)
    defs = model.param_defs()
    if opt_master is None:
        opt_master = arch != "grok-1-314b"  # 314B: bf16 update, fp32 moments
    opt_cfg = AdamWConfig(master=opt_master)

    with rules_lib.use_rules(rules, mesh=mesh):
        pspecs = specs_lib.param_specs(defs, rules)
        inputs = input_specs(cfg, shape)
        if shape.kind == "train":
            step = steps_lib.make_train_step(
                model, opt_cfg, make_schedule("cosine"),
                microbatches=microbatches,
            )
            state = {
                "params": abstract_params(defs),
                "opt": abstract_opt_state(defs, opt_cfg),
            }
            sspecs = {
                "params": pspecs,
                "opt": specs_lib.opt_state_specs(defs, rules),
            }
            if opt_cfg.master:
                sspecs["opt"]["master"] = pspecs
            bspecs = specs_lib.batch_specs(inputs, rules)
            jf = jax.jit(
                step,
                in_shardings=(_named(mesh, sspecs), _named(mesh, bspecs)),
                out_shardings=(
                    _named(mesh, sspecs),
                    _named(mesh, jax.tree.map(lambda _: P(), {
                        "loss": 0, "lr": 0, "grad_norm": 0})),
                ),
                donate_argnums=(0,),
            )
            lowered = jf.lower(state, inputs)
        elif shape.kind == "prefill":
            step = steps_lib.make_prefill_step(model)
            bspecs = specs_lib.batch_specs(inputs, rules)
            out_spec = NamedSharding(
                mesh, rules_lib.spec("batch", "vocab", rules=rules)
            )
            jf = jax.jit(
                step,
                in_shardings=(_named(mesh, pspecs), _named(mesh, bspecs)),
                out_shardings=out_spec,
            )
            lowered = jf.lower(abstract_params(defs), inputs)
        else:  # decode
            step = steps_lib.make_decode_step(model)
            cache_defs_tree = model.cache_defs(shape.global_batch, shape.seq_len)
            cspecs = specs_lib.cache_specs(cache_defs_tree, rules)
            tok_spec = NamedSharding(mesh, rules_lib.spec("batch", None,
                                                          rules=rules))
            jf = jax.jit(
                step,
                in_shardings=(_named(mesh, pspecs), _named(mesh, cspecs),
                              tok_spec),
                out_shardings=(tok_spec, _named(mesh, cspecs)),
                donate_argnums=(1,),
            )
            lowered = jf.lower(
                abstract_params(defs), inputs["cache"], inputs["tokens"]
            )
    return LoweredCell(arch=arch, shape=shape, mesh=mesh, cfg=cfg,
                       lowered=lowered, kind=shape.kind)


# ---------------------------------------------------------------------------
# Analysis
# ---------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_OP_RE = re.compile(
    r"^%?\S+\s*=\s*(\([^)]*\)|\S+)\s+(" + "|".join(COLLECTIVES) + r")(-start)?\("
)


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def collective_census(hlo_text: str) -> dict[str, dict[str, float]]:
    """Per-device ICI wire bytes for every collective in post-SPMD HLO.

    The HLO module is the per-device program and operand types are not
    printed inline, so we read the *result* shape(s) and apply the standard
    ring cost model per group of size N:

        all-reduce          2 (N-1)/N x result_bytes
        all-gather            (N-1)/N x result_bytes   (result = gathered)
        reduce-scatter        (N-1)   x result_bytes   (result = one shard)
        all-to-all            (N-1)/N x result_bytes
        collective-permute              result_bytes

    While-loop bodies appear once; the roofline harness runs this on the
    unrolled unit variants and extrapolates (see module docstring).
    """
    out: dict[str, dict[str, float]] = {
        c: {"wire_bytes": 0.0, "result_bytes": 0.0, "count": 0}
        for c in COLLECTIVES
    }
    for line in hlo_text.splitlines():
        line = line.strip()
        m = _OP_RE.match(line)
        if not m:
            continue
        result_ty, op = m.group(1), m.group(2)
        n = _group_size(line)
        b = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(result_ty))
        if op == "all-reduce":
            wire = 2.0 * (n - 1) / max(n, 1) * b
        elif op in ("all-gather", "all-to-all"):
            wire = (n - 1) / max(n, 1) * b
        elif op == "reduce-scatter":
            wire = float(n - 1) * b
        else:  # collective-permute
            wire = float(b)
        out[op]["wire_bytes"] += wire
        out[op]["result_bytes"] += b
        out[op]["count"] += 1
    return out


def census_total(census: dict) -> float:
    return sum(v["wire_bytes"] for v in census.values())


def memory_stats(compiled) -> dict[str, float]:
    ma = compiled.memory_analysis()
    stats = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        v = getattr(ma, attr, None)
        if v is not None:
            stats[attr] = float(v)
    return stats


def cost_stats(compiled) -> dict[str, float]:
    """flops / aggregate bytes-accessed from ``cost_analysis()``.

    ``bytes`` is XLA's aggregate over every HLO op (fusion operands +
    results; intermediates included), the number the roofline terms divide
    by HBM bandwidth and that ``repro.measure.validate`` checks against
    ``KernelPlan.predicted_hbm_bytes``.  Two caveats shared with the
    roofline harness: loop bodies are counted once (so block-grid loops
    undercount by the trip count), and the per-operand ``bytes accessedN{}``
    keys aggregate across *all* instructions, not the entry boundary --
    don't mistake them for argument traffic."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
    }
