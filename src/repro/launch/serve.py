"""Production serving launcher: batched prefill-via-decode + greedy
generation against the arch's cache (KV / SSM state / mLSTM matrix state).

    PYTHONPATH=src python -m repro.launch.serve --arch zamba2-1.2b \
        --mesh host --batch 4 --prompt-len 16 --gen 24
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-1.2b")
    ap.add_argument("--mesh", choices=["host", "pod", "multipod"],
                    default="host")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--plan-profile", default=None,
                    help="measured plan profile (repro.measure.sweep output);"
                         " its swept cells override the analytic planner"
                         " (on an SPMD mesh, cells match per-shard local"
                         " shapes -- see docs/SPMD.md)")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro import api
    from repro.configs import get_config, reduce_for_smoke
    from repro.launch.mesh import make_production_mesh
    from repro.models import build_model
    from repro.models.params import init_params
    from repro.parallel import steps as steps_lib

    cfg = get_config(args.arch)
    if args.mesh == "host":
        cfg = reduce_for_smoke(cfg)
        mesh = None
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multipod"))
        cfg, _ = cfg.padded_for_mesh(16)

    # Ambient PlanContext: the decode path's kernels (and the plan report
    # below) all see the serving mesh -- and any measured profile cells --
    # without per-call plumbing.  On a multi-device mesh the registered
    # kernels launch through shard_map with per-shard plans (api.spmd).
    # No --plan-profile leaves plan_overrides unspecified: an explicit None
    # would *clear* pins inherited from the process-default context.
    ctx_kw = {}
    if args.plan_profile:
        from repro.measure.profile import load_profile

        ctx_kw["plan_overrides"] = load_profile(args.plan_profile)
        print(f"plan profile {args.plan_profile}: "
              f"{len(ctx_kw['plan_overrides'])} swept cell(s)")
    with api.plan_context(mesh=mesh, **ctx_kw):
        if api.spmd_mesh() is not None:
            print("kernel launch path: fused shard_map (SPMD)")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        max_len = args.prompt_len + args.gen
        cache = init_params(jax.random.PRNGKey(1),
                            model.cache_defs(args.batch, max_len))
        if cfg.family == "encdec":
            frames = jax.random.normal(jax.random.PRNGKey(2),
                                       (args.batch, cfg.n_frames, cfg.d_model),
                                       cfg.adtype)
            cache["cross_k"], cache["cross_v"] = model.prefill_cross(params,
                                                                     frames)

        print(api.explain("rmsnorm", (args.batch, cfg.d_model), cfg.adtype))
        decode = jax.jit(steps_lib.make_decode_step(model))
        prompts = jax.random.randint(jax.random.PRNGKey(3),
                                     (args.batch, args.prompt_len), 0,
                                     cfg.vocab_size)
        t0 = time.time()
        for t in range(args.prompt_len):
            tok, cache = decode(params, cache, prompts[:, t:t + 1])
        outs = [tok]
        for _ in range(args.gen - 1):
            tok, cache = decode(params, cache, outs[-1])
            outs.append(tok)
        result = jnp.concatenate(outs, axis=1)
        jax.block_until_ready(result)
        dt = time.time() - t0
    print(f"{args.arch}: {args.batch} requests x {args.gen} tokens "
          f"in {dt:.2f}s ({args.batch * args.gen / dt:.1f} tok/s)")
    print("request 0:", result[0, :16].tolist())


if __name__ == "__main__":
    main()
