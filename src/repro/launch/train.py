"""Production training launcher.

Builds the mesh (or a host-local test mesh), applies the arch's layout
policy and sharding rules, and runs the fault-tolerant trainer on the
deterministic pipeline.  On a real pod this script is invoked once per host
(JAX multi-process); in this container use --mesh host for a 1-device run.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --mesh host --steps 20 --d-model 128 --layers 2
"""
from __future__ import annotations

import argparse
import contextlib
import dataclasses
import logging


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--mesh", choices=["host", "pod", "multipod"],
                    default="host")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--layers", type=int, default=0, help="override n_layers")
    ap.add_argument("--d-model", type=int, default=0, help="override d_model")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--baseline", action="store_true",
                    help="skip the layout policy (paper-raw dims)")
    ap.add_argument("--plan-profile", default=None,
                    help="measured plan profile (repro.measure.sweep output);"
                         " its swept cells override the analytic planner"
                         " (on an SPMD mesh, cells match per-shard local"
                         " shapes -- see docs/SPMD.md)")
    ap.add_argument("--obs-jsonl", default=None,
                    help="stream observability events (plan cache, SPMD"
                         " fallbacks, step metrics -- see docs/OBS.md) to"
                         " this JSONL file; aggregate with"
                         " python -m repro.obs.report")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")

    import jax

    from repro import api
    from repro.configs import get_config, get_schedule, reduce_for_smoke
    from repro.data.pipeline import DataConfig
    from repro.launch.mesh import make_production_mesh, make_test_mesh
    from repro.models import build_model
    from repro.models.params import param_count
    from repro.optim.adamw import AdamWConfig
    from repro.optim.schedules import make_schedule
    from repro.parallel import rules as rules_lib
    from repro.runtime.trainer import Trainer, TrainerConfig

    cfg = get_config(args.arch)
    if args.mesh == "host":
        cfg = reduce_for_smoke(cfg)
        mesh = make_test_mesh((1, 1))
        tp = 1
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multipod"))
        tp = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    if not args.baseline and tp > 1:
        cfg, changes = cfg.padded_for_mesh(tp)
        logging.info("layout policy: %s", changes)
    overrides = {}
    if args.layers:
        overrides["n_layers"] = args.layers
    if args.d_model:
        overrides["d_model"] = args.d_model
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)

    model = build_model(cfg)
    logging.info("arch=%s params=%.1fM mesh=%s", cfg.name,
                 param_count(model.param_defs()) / 1e6, args.mesh)
    rules = rules_lib.make_rules(
        multi_pod=(args.mesh == "multipod"), fsdp=cfg.fsdp,
        expert_tp=cfg.expert_tp,
    )
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                      global_batch=args.global_batch,
                      n_img_tokens=cfg.n_img_tokens,
                      n_frames=cfg.n_frames if cfg.family == "encdec" else 0,
                      d_model=cfg.d_model)
    trainer = Trainer(
        model, data, AdamWConfig(master=(args.arch != "grok-1-314b")),
        make_schedule(get_schedule(args.arch), peak=3e-4, warmup=10,
                      total=args.steps),
        TrainerConfig(n_steps=args.steps, ckpt_every=max(args.steps // 4, 1),
                      ckpt_dir=args.ckpt_dir, log_every=5),
    )
    # One ambient PlanContext for the whole run: every kernel launched by a
    # train step now plans against the production mesh (shard-aligned
    # physical shapes) without any per-call plumbing -- and on a
    # multi-device mesh api.launch routes the registered kernels through
    # shard_map with per-shard plans (repro.api.spmd), so the fused
    # norm/loss paths survive SPMD lowering instead of falling back to jnp.
    # A measured profile (repro.measure.sweep) overrides the analytic
    # choice cell by cell.
    plan_mesh = mesh if mesh.size > 1 else None
    # No --plan-profile leaves plan_overrides unspecified: an explicit None
    # would *clear* pins inherited from the process-default context.
    ctx_kw = {}
    if args.plan_profile:
        from repro.measure.profile import load_profile

        ctx_kw["plan_overrides"] = load_profile(args.plan_profile)
        logging.info("plan profile %s: %d swept cell(s)",
                     args.plan_profile, len(ctx_kw["plan_overrides"]))
    # Observability: --obs-jsonl streams the run's events (plan-cache
    # provenance, SPMD fallbacks, per-step metrics, checkpoints) to a
    # record-per-line file the report CLI aggregates.  Without the flag the
    # bus stays on its NullSink default and instrumentation costs nothing.
    from repro import obs

    obs_scope = (obs.session(obs.JsonlSink(args.obs_jsonl))
                 if args.obs_jsonl else contextlib.nullcontext())
    with api.plan_context(mesh=plan_mesh, **ctx_kw), \
            rules_lib.use_rules(rules, mesh=plan_mesh), obs_scope:
        from repro.models import blocks

        logging.info("kernel launch path: %s",
                     "fused shard_map (SPMD)" if api.spmd_mesh() is not None
                     else "fused single-device" if blocks.use_fused_kernels()
                     else "jnp fallback")
        metrics = trainer.train(jax.random.PRNGKey(0))
    if args.obs_jsonl:
        logging.info("obs event stream at %s (summarize: python -m "
                     "repro.obs.report %s)", args.obs_jsonl, args.obs_jsonl)
    if metrics:
        print(f"done: {len(metrics)} steps, "
              f"loss {metrics[0]['loss']:.3f} -> {metrics[-1]['loss']:.3f}")
    else:
        # A complete checkpoint at >= --steps restores past the whole run.
        print(f"done: 0 steps (checkpoint in {args.ckpt_dir} already at "
              f"step >= {args.steps}; clear it or raise --steps)")


if __name__ == "__main__":
    main()
