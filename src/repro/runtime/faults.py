"""Deterministic fault injection: the enabling half of elastic SPMD.

A production run meets four failure shapes the paper's static layouts
never had to: a device (and the memory controllers behind it) disappears
mid-run, a shard goes slow without dying, the checkpoint writer crashes
mid-write, and a step throws once and never again.  This module makes
all four *injectable on a chosen step* so the recovery machinery --
the trainer's backoff/restore loop, ``ElasticRunner``'s re-mesh path,
``CheckpointManager``'s torn-write atomicity, and the serving batcher's
pool-shrink degradation -- is exercised deterministically in tests and
the CI chaos job instead of waiting for production to exercise it.

Every fault is a frozen dataclass pinned to a step (or serving tick);
a :class:`FaultPlan` is an ordered collection of them and
:meth:`FaultPlan.injector` builds the stateful one-shot
:class:`FaultInjector` the trainer consumes as its ``fail_injector``
and the batcher consumes via :meth:`FaultInjector.tick`.  Nothing here
is random: the same plan replays the same faults, which is what makes
the chaos parity test (resumed run == uninterrupted run) assertable.

Failure taxonomy (consumed by ``runtime.trainer``):

  * :class:`TransientStepError` -- retryable; the trainer restores and
    replays with exponential backoff.
  * :class:`DeviceLossError`    -- *persistent*: the topology changed and
    no amount of retrying brings the device back.  The trainer re-raises
    it immediately; ``ElasticRunner`` catches it, shrinks the mesh, and
    resumes from the newest complete checkpoint.
"""
from __future__ import annotations

import dataclasses
import time


class TransientStepError(RuntimeError):
    """A step failure expected to clear on retry (preemption, flaky I/O,
    a transient collective timeout).  The trainer's retry loop handles it
    with restore + exponential backoff."""


class DeviceLossError(RuntimeError):
    """A persistent topology change: ``failed_ids`` devices are gone.

    Retrying the step cannot succeed -- the trainer propagates this
    immediately so the elastic runtime can re-mesh and resume."""

    def __init__(self, failed_ids, *, step: int = -1):
        self.failed_ids = frozenset(int(i) for i in failed_ids)
        self.step = step
        ids = sorted(self.failed_ids)
        super().__init__(f"device(s) {ids} lost at step {step}")


# ---------------------------------------------------------------------------
# fault specs
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DeviceLoss:
    """Lose ``failed_ids`` at ``step`` (raises :class:`DeviceLossError`)."""

    step: int
    failed_ids: tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class Straggler:
    """Delay step ``step`` by ``delay_s`` (a slow shard, not a dead one).

    The trainer's straggler detector treats the blown step time as a
    first-class degradation (``DegradedEvent(reason="straggler")``)
    rather than silently waiting it out."""

    step: int
    delay_s: float
    shard: int = 0


@dataclasses.dataclass(frozen=True)
class CheckpointCrash:
    """Crash the checkpoint writer for the first save at/after ``step``:
    the tmp directory is populated but never renamed, leaving exactly the
    torn state a mid-write crash would.  Restore never sees it; the
    captured error re-raises from the manager's next ``wait()``/``save()``."""

    step: int


@dataclasses.dataclass(frozen=True)
class Transient:
    """Raise :class:`TransientStepError` on ``step``, ``times`` times."""

    step: int
    times: int = 1


@dataclasses.dataclass(frozen=True)
class PoolShrink:
    """Shrink the serving batcher's live page pool to ``live_pages`` at
    serving tick ``tick`` (consumed via :meth:`FaultInjector.tick`)."""

    tick: int
    live_pages: int


Fault = DeviceLoss | Straggler | CheckpointCrash | Transient | PoolShrink


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An ordered, deterministic set of faults to inject into one run."""

    faults: tuple[Fault, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "faults", tuple(self.faults))

    def injector(self) -> "FaultInjector":
        """A fresh stateful injector for one run of this plan."""
        return FaultInjector(self)


class FaultInjector:
    """One run's fault state: each fault fires once (``Transient`` up to
    its ``times``), then disarms -- a replayed step after a restore must
    not re-trip the fault that killed it, or no run ever finishes.

    Use as the trainer's ``fail_injector`` (called per step), attach to a
    :class:`~repro.checkpoint.manager.CheckpointManager` for torn-write
    faults, and call :meth:`tick` from a serving driver for pool faults.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._fired: dict[int, int] = {}       # fault index -> fire count
        self.log: list[tuple[str, int]] = []   # (kind, step/tick) fired

    def _arm(self, idx: int, limit: int = 1) -> bool:
        n = self._fired.get(idx, 0)
        if n >= limit:
            return False
        self._fired[idx] = n + 1
        return True

    # ---- trainer-side ----------------------------------------------------
    def __call__(self, step: int) -> None:
        for idx, f in enumerate(self.plan.faults):
            if isinstance(f, Straggler) and f.step == step and self._arm(idx):
                self.log.append(("straggler", step))
                time.sleep(f.delay_s)
            elif isinstance(f, Transient) and f.step == step and self._arm(
                    idx, f.times):
                self.log.append(("transient", step))
                raise TransientStepError(
                    f"injected transient failure at step {step} "
                    f"({self._fired[idx]}/{f.times})")
            elif isinstance(f, DeviceLoss) and f.step == step and self._arm(
                    idx):
                self.log.append(("device_loss", step))
                raise DeviceLossError(f.failed_ids, step=step)

    def attach_checkpoint(self, manager) -> None:
        """Install the torn-write hook on ``manager`` for any
        :class:`CheckpointCrash` faults in the plan (no-op otherwise)."""
        crashes = [(i, f) for i, f in enumerate(self.plan.faults)
                   if isinstance(f, CheckpointCrash)]
        if not crashes:
            return

        def hook(step: int, tmp: str) -> None:
            for idx, f in crashes:
                if step >= f.step and self._arm(idx):
                    self.log.append(("checkpoint_crash", step))
                    raise OSError(
                        f"injected checkpoint-writer crash at step {step} "
                        f"(torn tmp dir left at {tmp})")

        manager.fault_hook = hook

    # ---- serving-side ----------------------------------------------------
    def tick(self, batcher, tick: int) -> None:
        """Apply any :class:`PoolShrink` fault due at serving ``tick``."""
        for idx, f in enumerate(self.plan.faults):
            if isinstance(f, PoolShrink) and f.tick == tick and self._arm(
                    idx):
                self.log.append(("pool_shrink", tick))
                batcher.shrink_pool(f.live_pages)
