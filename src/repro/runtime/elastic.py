"""Elastic mesh management + failure handling policy.

At 1000+-node scale, node loss is routine.  The policy here:

  1. keep the model (TP) axis intact -- TP re-sharding invalidates every
     weight shard, so a failed host inside a TP group retires the whole
     group;
  2. shrink the *data* axis to the largest size the surviving hosts support
     (DP re-sharding only re-slices the batch, cheap);
  3. re-lower the step for the new mesh, restore the latest checkpoint
     (optimizer state is DP-replicated or re-shardable), and resume from the
     checkpointed data step -- the pipeline is a pure function of step, so
     no data is lost or duplicated;
  4. straggler mitigation: the batch is re-chunked "static,1"-style across
     the DP groups each resize (the paper's scheduling result: fine
     interleaving smooths per-group imbalance).

The pure functions (``plan_mesh``, ``surviving_mesh``,
``rebalance_batch``) implement the policy arithmetic;
:class:`ElasticRunner` is the policy *executed*: it owns the
topology -> mesh -> shard-specs -> kernel-plans -> state chain and
drives a ``Trainer`` through topology changes.  On a
``DeviceLossError`` (raised by the chaos harness ``runtime/faults.py``
or a real launcher) it rebuilds the mesh over the survivors, re-derives
the batch sharding through ``parallel.rules.spec_report``, drops every
plan-cache cell keyed to the dead mesh
(``core.planner.invalidate_mesh_plans``), restores the newest complete
checkpoint resharded onto the new mesh, re-chunks the global batch with
``rebalance_batch``, and resumes from the checkpointed step -- emitting
``MeshChangeEvent`` / ``ResumeEvent`` / ``DegradedEvent`` records onto
the obs bus so ``python -m repro.obs.report`` shows every decision.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Callable

import jax
import numpy as np

from repro import obs
from repro.core.planner import invalidate_mesh_plans
from repro.parallel import rules
from repro.runtime.faults import DeviceLossError, FaultInjector, FaultPlan

log = logging.getLogger("repro.elastic")


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    dp: int
    tp: int
    n_devices: int

    @property
    def shape(self) -> tuple[int, int]:
        return (self.dp, self.tp)


def plan_mesh(n_devices: int, *, tp: int, min_dp: int = 1) -> MeshPlan:
    """Largest (dp, tp) grid with the TP axis preserved."""
    if n_devices < tp * min_dp:
        raise RuntimeError(
            f"cannot keep tp={tp} with only {n_devices} devices"
        )
    dp = n_devices // tp
    return MeshPlan(dp=dp, tp=tp, n_devices=dp * tp)


# Retired-surplus warnings already logged, keyed by the retired id tuple:
# a policy that retires the same devices on every rebuild should say so
# once, not per resize (the obs event still fires every time -- events
# are the record, logs are the operator surface).
_warned_retired: set[tuple[int, ...]] = set()


def _note_retired(alive, plan: MeshPlan) -> list[int]:
    """Surplus alive devices the (dp, tp) grid cannot place.  Logged once
    per id-set and reported on the obs bus -- a silently shrunken mesh
    (`alive[: plan.n_devices]`) is capacity lost with no trace."""
    retired = [getattr(d, "id", d) for d in alive[plan.n_devices:]]
    if not retired:
        return []
    key = tuple(int(i) for i in retired)
    if key not in _warned_retired:
        _warned_retired.add(key)
        log.warning(
            "retiring %d surviving device(s) %s: %d survivors do not fill "
            "a (dp=%d, tp=%d) grid", len(retired), retired, len(alive),
            plan.dp, plan.tp)
    if obs.enabled():
        obs.emit(obs.DegradedEvent(
            reason="surplus_devices",
            detail=f"retired device ids {retired} "
                   f"(grid dp={plan.dp} x tp={plan.tp})"))
    return list(key)


def surviving_mesh(devices, failed_ids: set[int], *, tp: int):
    """Mesh over surviving devices, retiring partial TP groups."""
    alive = [d for d in devices if d.id not in failed_ids]
    plan = plan_mesh(len(alive), tp=tp)
    _note_retired(alive, plan)
    dev = np.asarray(alive[: plan.n_devices]).reshape(plan.shape)
    return jax.sharding.Mesh(dev, ("data", "model"))


def rebalance_batch(global_batch: int, dp: int) -> list[int]:
    """static,1-style chunking: sizes differ by at most one."""
    base, rem = divmod(global_batch, dp)
    return [base + (1 if i < rem else 0) for i in range(dp)]


# ---------------------------------------------------------------------------
# the elastic runtime
# ---------------------------------------------------------------------------
def _mesh_tuple(mesh) -> tuple:
    """(axis, size) pairs for a jax Mesh or a {axis: size} planning mesh."""
    if mesh is None:
        return ()
    if hasattr(mesh, "axis_names") and hasattr(mesh, "devices"):
        return tuple(zip(tuple(mesh.axis_names), tuple(mesh.devices.shape)))
    return tuple((str(k), int(v)) for k, v in dict(mesh).items())


def _real_devices(devices) -> bool:
    try:
        return all(isinstance(d, jax.Device) for d in devices)
    except TypeError:  # jax without the Device alias
        return False


class ElasticRunner:
    """Owns the topology -> mesh -> specs -> plans -> state chain.

    ``make_trainer(mesh)`` builds a fresh ``Trainer`` planning against
    ``mesh`` -- a real ``jax.sharding.Mesh`` when the runner's devices
    are real jax devices, otherwise an ``{axis: size}`` planning mesh
    (the paper-level layout policy without multi-device execution, which
    is what single-device tests and the tier-1 chaos smoke use).  A fresh
    trainer per topology matters: the jitted step, the kernel plans, and
    the checkpoint template are all re-derived against the surviving
    machine instead of limping on a stale layout.

    ``run`` drives training to completion across any number of
    device-loss events (bounded by ``max_remesh``), resuming each time
    from the newest complete checkpoint with the state resharded onto
    the new mesh and the batch re-chunked by ``rebalance_batch``.  The
    merged metrics are exactly-once per step: replayed steps take the
    post-resume value, so the trajectory is directly comparable to an
    uninterrupted run (the chaos parity criterion).
    """

    def __init__(self, make_trainer: Callable, *, devices=None, tp: int = 1,
                 min_dp: int = 1, max_remesh: int = 8):
        self.make_trainer = make_trainer
        self.devices = list(jax.devices() if devices is None else devices)
        self.tp = tp
        self.min_dp = min_dp
        self.max_remesh = max_remesh
        self.failed_ids: set[int] = set()
        self.mesh = None
        self.mesh_plan: MeshPlan | None = None
        self.batch_chunks: list[int] = []
        self.remeshes = 0
        self._metrics_by_step: dict[int, dict] = {}

    # ---- topology -> mesh ------------------------------------------------
    def _alive(self) -> list:
        return [d for d in self.devices
                if getattr(d, "id", d) not in self.failed_ids]

    def _build_mesh(self):
        """(MeshPlan, mesh) over the current survivors.  Real devices get
        a real ``jax.sharding.Mesh`` (the ``surviving_mesh`` policy);
        placeholder devices get an ``{axis: size}`` planning mesh with
        identical (dp, tp) arithmetic."""
        alive = self._alive()
        plan = plan_mesh(len(alive), tp=self.tp, min_dp=self.min_dp)
        _note_retired(alive, plan)
        if _real_devices(alive):
            dev = np.asarray(alive[: plan.n_devices]).reshape(plan.shape)
            mesh = jax.sharding.Mesh(dev, ("data", "model"))
        else:
            mesh = {"data": plan.dp, "model": plan.tp}
        return plan, mesh

    # ---- mesh -> specs -> plans -> state ---------------------------------
    def _prepare(self, trainer, *, invalidated: int) -> int:
        """Re-derive the per-mesh state for ``trainer``'s mesh: batch shard
        spec via ``rules.spec_report``, DP batch chunks via
        ``rebalance_batch``, and the resume step from the newest complete
        checkpoint.  Emits the ``ResumeEvent`` record."""
        d = trainer.data_cfg
        axis_sizes = dict(_mesh_tuple(self.mesh))
        _, fallbacks = rules.spec_report(
            "batch", "seq", rules=rules.DEFAULT_RULES,
            shape=(d.global_batch, d.seq_len), axis_sizes=axis_sizes)
        for reason in fallbacks:
            log.warning("batch spec on %s: %s", axis_sizes, reason)
        self.batch_chunks = rebalance_batch(d.global_batch,
                                            self.mesh_plan.dp)
        resume_step = trainer.ckpt.latest_step() or 0
        if obs.enabled():
            obs.emit(obs.ResumeEvent(
                step=resume_step, mesh=_mesh_tuple(self.mesh),
                batch_chunks=tuple(self.batch_chunks),
                invalidated_plans=invalidated,
                restored=trainer.ckpt.latest_step() is not None,
                spec_fallbacks=tuple(fallbacks)))
        return resume_step

    def _absorb_metrics(self, trainer) -> None:
        """Merge a segment's metrics exactly-once-per-step: a step both the
        pre-loss segment and the post-resume replay computed keeps the
        replayed value (the one the surviving trajectory is made of)."""
        for m in trainer.metrics:
            self._metrics_by_step[m["step"]] = m

    @property
    def metrics(self) -> list[dict]:
        return [self._metrics_by_step[s]
                for s in sorted(self._metrics_by_step)]

    # ---- the loop --------------------------------------------------------
    def run(self, key, *, fault_plan: FaultPlan | None = None,
            injector: FaultInjector | None = None) -> list[dict]:
        """Train to completion across topology changes.

        ``fault_plan`` (or a pre-built ``injector``) arms the chaos
        harness; a real launcher instead lets its device-health monitor
        raise ``DeviceLossError`` from the step loop.
        """
        if injector is None and fault_plan is not None:
            injector = fault_plan.injector()
        self.mesh_plan, self.mesh = self._build_mesh()
        invalidated = 0
        while True:
            trainer = self.make_trainer(self.mesh)
            if injector is not None:
                injector.attach_checkpoint(trainer.ckpt)
            self._prepare(trainer, invalidated=invalidated)
            try:
                trainer.train(key, fail_injector=injector)
                self._absorb_metrics(trainer)
                return self.metrics
            except DeviceLossError as e:
                self._absorb_metrics(trainer)
                try:
                    trainer.ckpt.wait()   # settle any in-flight async save
                except Exception as err:  # noqa: BLE001 -- torn write: the
                    # checkpoint never completed; restore will pick the
                    # newest *complete* step, so record and move on.
                    log.warning("in-flight checkpoint lost during device "
                                "loss: %s", err)
                self.remeshes += 1
                if self.remeshes > self.max_remesh:
                    raise
                self.failed_ids |= e.failed_ids
                old_mesh, old_plan = self.mesh, self.mesh_plan
                try:
                    self.mesh_plan, self.mesh = self._build_mesh()
                except RuntimeError as rebuild_err:
                    # Not survivable (too few devices for tp x min_dp):
                    # the device loss is fatal, not the mesh arithmetic.
                    log.error("cannot re-mesh after device loss: %s",
                              rebuild_err)
                    raise e from rebuild_err
                invalidated = invalidate_mesh_plans(old_mesh)
                alive_ids = {getattr(d, "id", d) for d in self._alive()}
                retired = [getattr(d, "id", d) for d in self.devices
                           if getattr(d, "id", d) not in alive_ids
                           and getattr(d, "id", d) not in self.failed_ids]
                log.warning(
                    "device loss at step %d: %s failed; re-meshed "
                    "(dp=%d,tp=%d) -> (dp=%d,tp=%d), %d plan cell(s) "
                    "invalidated", e.step, sorted(e.failed_ids),
                    old_plan.dp, old_plan.tp, self.mesh_plan.dp,
                    self.mesh_plan.tp, invalidated)
                if obs.enabled():
                    obs.emit(obs.MeshChangeEvent(
                        old_mesh=_mesh_tuple(old_mesh),
                        new_mesh=_mesh_tuple(self.mesh),
                        failed_ids=tuple(sorted(e.failed_ids)),
                        retired_ids=tuple(retired),
                        reason="device_loss", step=e.step))
