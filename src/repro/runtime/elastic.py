"""Elastic mesh management + failure handling policy.

At 1000+-node scale, node loss is routine.  The policy here:

  1. keep the model (TP) axis intact -- TP re-sharding invalidates every
     weight shard, so a failed host inside a TP group retires the whole
     group;
  2. shrink the *data* axis to the largest size the surviving hosts support
     (DP re-sharding only re-slices the batch, cheap);
  3. re-lower the step for the new mesh, restore the latest checkpoint
     (optimizer state is DP-replicated or re-shardable), and resume from the
     checkpointed data step -- the pipeline is a pure function of step, so
     no data is lost or duplicated;
  4. straggler mitigation: the batch is re-chunked "static,1"-style across
     the DP groups each resize (the paper's scheduling result: fine
     interleaving smooths per-group imbalance).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    dp: int
    tp: int
    n_devices: int

    @property
    def shape(self) -> tuple[int, int]:
        return (self.dp, self.tp)


def plan_mesh(n_devices: int, *, tp: int, min_dp: int = 1) -> MeshPlan:
    """Largest (dp, tp) grid with the TP axis preserved."""
    if n_devices < tp * min_dp:
        raise RuntimeError(
            f"cannot keep tp={tp} with only {n_devices} devices"
        )
    dp = n_devices // tp
    return MeshPlan(dp=dp, tp=tp, n_devices=dp * tp)


def surviving_mesh(devices, failed_ids: set[int], *, tp: int):
    """Mesh over surviving devices, retiring partial TP groups."""
    alive = [d for d in devices if d.id not in failed_ids]
    plan = plan_mesh(len(alive), tp=tp)
    dev = np.asarray(alive[: plan.n_devices]).reshape(plan.shape)
    return jax.sharding.Mesh(dev, ("data", "model"))


def rebalance_batch(global_batch: int, dp: int) -> list[int]:
    """static,1-style chunking: sizes differ by at most one."""
    base, rem = divmod(global_batch, dp)
    return [base + (1 if i < rem else 0) for i in range(dp)]
