"""Fault-tolerant training loop.

Checkpoints every ``ckpt_every`` steps (async, atomic); a *transient*
exception in a step restores the latest checkpoint and replays from its
step with exponential backoff (the data pipeline is a pure function of
step, so replay is exact), while a *persistent* failure -- a
``DeviceLossError`` from ``runtime.faults``, i.e. a topology change --
propagates immediately so the elastic runtime (``runtime.elastic
.ElasticRunner``) can re-mesh and resume instead of retrying a step that
can never succeed.  ``fail_injector`` lets tests and the chaos harness
inject failures at chosen steps; steps whose wall time blows past the
straggler threshold over the step-time EMA are reported as first-class
degradations on the obs bus rather than silently waited out.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable

import jax

from repro import api
from repro import obs
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, make_batch
from repro.optim import adamw
from repro.parallel import steps as steps_lib
from repro.runtime.faults import DeviceLossError

log = logging.getLogger("repro.trainer")


@dataclasses.dataclass
class TrainerConfig:
    n_steps: int = 20
    ckpt_every: int = 5
    ckpt_dir: str = "/tmp/repro_ckpt"
    max_retries: int = 3
    log_every: int = 1
    # Exponential backoff between transient-failure retries:
    # base * 2**(retry-1), capped.  The default base is small enough to be
    # invisible in tests while still separating retry storms in real runs.
    backoff_base_s: float = 0.05
    backoff_max_s: float = 5.0
    # A step slower than straggler_factor x the step-time EMA is reported
    # as a DegradedEvent("straggler") once history exists (>= 3 steps).
    # 0 disables detection.
    straggler_factor: float = 4.0


class Trainer:
    def __init__(self, model, data_cfg: DataConfig, opt_cfg: adamw.AdamWConfig,
                 schedule, tcfg: TrainerConfig, *, sharding=None, mesh=None):
        self.model = model
        self.data_cfg = data_cfg
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        self.sharding = sharding
        # Layout-planning mesh: an explicit arg wins; otherwise the ambient
        # plan_context is consulted *at use time* (plan_hot_kernels/train),
        # so a launcher may construct the Trainer first and enter
        # plan_context(mesh=...) around the run.
        self.mesh = mesh
        self.ckpt = CheckpointManager(tcfg.ckpt_dir)
        self.step_fn = jax.jit(steps_lib.make_train_step(model, opt_cfg, schedule))
        self.metrics: list[dict] = []
        self.kernel_plans: dict[str, object] = {}

    def _plan_mesh(self):
        return self.mesh if self.mesh is not None else api.current_context().mesh

    def plan_hot_kernels(self) -> dict[str, object]:
        """Ask the registry for this run's hot-kernel plans under the
        trainer's mesh: the per-token norm over (tokens, d_model) and the
        loss kernel over (tokens, vocab).  Memoized in the plan cache, so
        this is free after the first step -- and it is the single place the
        training path commits to a layout policy (paper SS2.3: one analysis
        governs every loop kernel)."""
        d = self.data_cfg
        tokens = max(d.global_batch * d.seq_len, 1)
        adtype = getattr(getattr(self.model, "cfg", None), "adtype", "float32")
        with api.plan_context(mesh=self._plan_mesh()):
            plans = {}
            if d.d_model:
                plans["rmsnorm"] = api.plan_for(
                    "rmsnorm", (tokens, d.d_model), adtype)
            plans["xent"] = api.plan_for(
                "xent", (tokens, d.vocab_size), "float32")
            for name, plan in plans.items():
                log.debug("kernel plan %s:\n%s", name, plan.explain())
        self.kernel_plans = plans
        return plans

    def init_or_restore(self, key) -> tuple[int, dict]:
        state = steps_lib.init_train_state(self.model, self.opt_cfg, key)
        restored = self.ckpt.restore_latest(state)
        if restored is not None:
            step, state = restored
            log.info("restored checkpoint at step %d", step)
            if obs.enabled():
                obs.emit(obs.CheckpointEvent(step=step, action="restore"))
            return step, state
        return 0, state

    def train(self, key, *, fail_injector: Callable[[int], None] | None = None
              ) -> list[dict]:
        with api.plan_context(mesh=self._plan_mesh()):
            return self._train(key, fail_injector=fail_injector)

    def _note_straggler(self, step: int, step_s: float, ema: float | None,
                        n_hist: int) -> None:
        factor = self.tcfg.straggler_factor
        if factor <= 0 or ema is None or n_hist < 3:
            return
        if step_s > factor * ema:
            log.warning("step %d straggled: %.3fs vs EMA %.3fs (x%.1f)",
                        step, step_s, ema, step_s / ema)
            if obs.enabled():
                obs.emit(obs.DegradedEvent(
                    reason="straggler", step=step,
                    detail=f"step {step_s:.3f}s vs ema {ema:.3f}s "
                           f"(threshold x{factor:g})"))

    def _backoff(self, retries: int) -> None:
        base = self.tcfg.backoff_base_s
        if base <= 0:
            return
        delay = min(base * 2 ** (retries - 1), self.tcfg.backoff_max_s)
        log.info("backing off %.2fs before retry %d", delay, retries)
        time.sleep(delay)

    def _train(self, key, *, fail_injector: Callable[[int], None] | None = None
               ) -> list[dict]:
        self.plan_hot_kernels()
        step, state = self.init_or_restore(key)
        retries = 0
        ema: float | None = None
        n_hist = 0
        while step < self.tcfg.n_steps:
            try:
                if fail_injector is not None:
                    fail_injector(step)
                t0 = time.perf_counter()
                batch = make_batch(self.data_cfg, step, self.sharding)
                state, metrics = self.step_fn(state, batch)
                loss = float(metrics["loss"])
                grad_norm = float(metrics["grad_norm"])
                # The float() casts above block on the device, so the wall
                # time spans the whole step, not just dispatch.  Step
                # metrics are *events* on the obs bus (structured, typed);
                # the list below is the legacy return surface, kept so
                # existing callers (launch/train.py, tests) see the same
                # list-of-dicts they always did.
                step_s = time.perf_counter() - t0
                self._note_straggler(step, step_s, ema, n_hist)
                ema = step_s if ema is None else 0.7 * ema + 0.3 * step_s
                n_hist += 1
                self.metrics.append({"step": step, "loss": loss,
                                     "grad_norm": grad_norm})
                if obs.enabled():
                    obs.emit(obs.TrainStepEvent(
                        step=step, loss=loss, grad_norm=grad_norm,
                        step_s=step_s))
                if step % self.tcfg.log_every == 0:
                    log.info("step %d loss %.4f", step, loss)
                step += 1
                retries = 0
                if step % self.tcfg.ckpt_every == 0:
                    self.ckpt.save(step, state, meta={"loss": loss})
                    if obs.enabled():
                        obs.emit(obs.CheckpointEvent(step=step,
                                                     action="save"))
            except DeviceLossError:
                # Persistent: the topology changed.  Retrying cannot bring
                # the device back -- propagate so the elastic runtime can
                # re-mesh, restore, and resume (runtime/elastic.py).
                raise
            except Exception as e:  # noqa: BLE001 -- the whole point
                retries += 1
                if retries > self.tcfg.max_retries:
                    raise
                log.warning("step %d failed (%s); restoring (retry %d/%d)",
                            step, e, retries, self.tcfg.max_retries)
                if obs.enabled():
                    obs.emit(obs.DegradedEvent(
                        reason="transient_retry", step=step,
                        detail=f"{type(e).__name__}: {e} "
                               f"(retry {retries}/{self.tcfg.max_retries})"))
                self._backoff(retries)
                restored = self.ckpt.restore_latest(state)
                if restored is not None:
                    step, state = restored
                    if obs.enabled():
                        obs.emit(obs.CheckpointEvent(step=step,
                                                     action="restore"))
                # else: replay from current state (failure before 1st ckpt)
        self.ckpt.save(step, state, meta={"final": True})
        self.ckpt.wait()
        if obs.enabled():
            obs.emit(obs.CheckpointEvent(step=step, action="save"))
        return self.metrics
