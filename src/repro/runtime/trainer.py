"""Fault-tolerant training loop.

Checkpoints every ``ckpt_every`` steps (async, atomic); any exception in a
step restores the latest checkpoint and replays from its step (the data
pipeline is a pure function of step, so replay is exact).  ``fail_injector``
lets tests simulate node failures at chosen steps.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable

import jax

from repro import api
from repro import obs
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, make_batch
from repro.optim import adamw
from repro.parallel import steps as steps_lib

log = logging.getLogger("repro.trainer")


@dataclasses.dataclass
class TrainerConfig:
    n_steps: int = 20
    ckpt_every: int = 5
    ckpt_dir: str = "/tmp/repro_ckpt"
    max_retries: int = 3
    log_every: int = 1


class Trainer:
    def __init__(self, model, data_cfg: DataConfig, opt_cfg: adamw.AdamWConfig,
                 schedule, tcfg: TrainerConfig, *, sharding=None, mesh=None):
        self.model = model
        self.data_cfg = data_cfg
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        self.sharding = sharding
        # Layout-planning mesh: an explicit arg wins; otherwise the ambient
        # plan_context is consulted *at use time* (plan_hot_kernels/train),
        # so a launcher may construct the Trainer first and enter
        # plan_context(mesh=...) around the run.
        self.mesh = mesh
        self.ckpt = CheckpointManager(tcfg.ckpt_dir)
        self.step_fn = jax.jit(steps_lib.make_train_step(model, opt_cfg, schedule))
        self.metrics: list[dict] = []
        self.kernel_plans: dict[str, object] = {}

    def _plan_mesh(self):
        return self.mesh if self.mesh is not None else api.current_context().mesh

    def plan_hot_kernels(self) -> dict[str, object]:
        """Ask the registry for this run's hot-kernel plans under the
        trainer's mesh: the per-token norm over (tokens, d_model) and the
        loss kernel over (tokens, vocab).  Memoized in the plan cache, so
        this is free after the first step -- and it is the single place the
        training path commits to a layout policy (paper SS2.3: one analysis
        governs every loop kernel)."""
        d = self.data_cfg
        tokens = max(d.global_batch * d.seq_len, 1)
        adtype = getattr(getattr(self.model, "cfg", None), "adtype", "float32")
        with api.plan_context(mesh=self._plan_mesh()):
            plans = {}
            if d.d_model:
                plans["rmsnorm"] = api.plan_for(
                    "rmsnorm", (tokens, d.d_model), adtype)
            plans["xent"] = api.plan_for(
                "xent", (tokens, d.vocab_size), "float32")
            for name, plan in plans.items():
                log.debug("kernel plan %s:\n%s", name, plan.explain())
        self.kernel_plans = plans
        return plans

    def init_or_restore(self, key) -> tuple[int, dict]:
        state = steps_lib.init_train_state(self.model, self.opt_cfg, key)
        restored = self.ckpt.restore_latest(state)
        if restored is not None:
            step, state = restored
            log.info("restored checkpoint at step %d", step)
            if obs.enabled():
                obs.emit(obs.CheckpointEvent(step=step, action="restore"))
            return step, state
        return 0, state

    def train(self, key, *, fail_injector: Callable[[int], None] | None = None
              ) -> list[dict]:
        with api.plan_context(mesh=self._plan_mesh()):
            return self._train(key, fail_injector=fail_injector)

    def _train(self, key, *, fail_injector: Callable[[int], None] | None = None
               ) -> list[dict]:
        self.plan_hot_kernels()
        step, state = self.init_or_restore(key)
        retries = 0
        while step < self.tcfg.n_steps:
            try:
                if fail_injector is not None:
                    fail_injector(step)
                t0 = time.perf_counter()
                batch = make_batch(self.data_cfg, step, self.sharding)
                state, metrics = self.step_fn(state, batch)
                loss = float(metrics["loss"])
                grad_norm = float(metrics["grad_norm"])
                # The float() casts above block on the device, so the wall
                # time spans the whole step, not just dispatch.  Step
                # metrics are *events* on the obs bus (structured, typed);
                # the list below is the legacy return surface, kept so
                # existing callers (launch/train.py, tests) see the same
                # list-of-dicts they always did.
                step_s = time.perf_counter() - t0
                self.metrics.append({"step": step, "loss": loss,
                                     "grad_norm": grad_norm})
                if obs.enabled():
                    obs.emit(obs.TrainStepEvent(
                        step=step, loss=loss, grad_norm=grad_norm,
                        step_s=step_s))
                if step % self.tcfg.log_every == 0:
                    log.info("step %d loss %.4f", step, loss)
                step += 1
                retries = 0
                if step % self.tcfg.ckpt_every == 0:
                    self.ckpt.save(step, state, meta={"loss": loss})
                    if obs.enabled():
                        obs.emit(obs.CheckpointEvent(step=step,
                                                     action="save"))
            except Exception as e:  # noqa: BLE001 -- the whole point
                retries += 1
                if retries > self.tcfg.max_retries:
                    raise
                log.warning("step %d failed (%s); restoring", step, e)
                restored = self.ckpt.restore_latest(state)
                if restored is not None:
                    step, state = restored
                    if obs.enabled():
                        obs.emit(obs.CheckpointEvent(step=step,
                                                     action="restore"))
                # else: replay from current state (failure before 1st ckpt)
        self.ckpt.save(step, state, meta={"final": True})
        self.ckpt.wait()
        if obs.enabled():
            obs.emit(obs.CheckpointEvent(step=step, action="save"))
        return self.metrics
