"""Layout autotuning walkthrough: every remedy in the paper, end to end.

  1. STREAM offset sweep (Fig. 2)  -- diagnose periodicity,
  2. vector-triad skew (Fig. 4)    -- closed-form offsets == exhaustive,
  3. Jacobi parameters (SS2.3)     -- align=512, shift=128, static-1,
  4. LBM layout choice (Fig. 7)    -- ivjk auto-skew vs soa, N%64 hazard,
  5. MoE expert placement          -- the same skew rule at pod scale,
  6. kernel plans (repro.api)      -- the closed loop: registry + ambient
                                      PlanContext -> padded shape, VMEM
                                      block, skews, predicted balance,
                                      waste; one policy for every kernel.

Run:  PYTHONPATH=src python examples/layout_autotune.py
"""
import numpy as np

from repro import api
from repro.core import planner
from repro.core.aliasing import InterleavedMemoryModel, exhaustive_best_skews
from repro.core.autotune import StreamSignature, plan_streams
from repro.core.sharding_skew import layer_skew_gain
from repro.kernels.lbm import ops as lbm_ops

M = InterleavedMemoryModel()


def main() -> None:
    print("== 1. STREAM offset sweep (Fig. 2) ==")
    curve = M.stream_triad_curve(n_elements=2 ** 22,
                                 offsets=range(0, 72, 8), n_threads=64)
    for off, bw in curve.items():
        bar = "#" * int(bw)
        print(f"  offset {off:3d} DP words: {bw:5.2f} GB/s {bar}")

    print("== 2. analytic == exhaustive (SS2.2) ==")
    plan = plan_streams(StreamSignature(n_read=3, n_write=1), M)
    offs, best = exhaustive_best_skews(M, 4)
    print(f"  closed form: {plan.offsets_bytes} balance "
          f"{plan.predicted_balance:.3f}")
    print(f"  exhaustive:  {tuple(offs)} balance {best:.3f}")

    print("== 3. Jacobi layout parameters (SS2.3) ==")
    jplan = plan_streams(StreamSignature(n_read=1, n_write=1), M)
    print(f"  align segments to {jplan.align_bytes} B, shift consecutive "
          f"rows by {jplan.segment_shift_bytes} B  (paper: 512 / 128)")

    print("== 4. LBM layout choice (Fig. 7) ==")
    for n in (100, 96, 64, 50):
        best_layout, scores = lbm_ops.layout_balance_scores(n=n)
        note = "  <- pad! (N % 64 == 0 thrashing)" if n % 64 == 0 else ""
        print(f"  N={n:4d}: soa={scores['soa']:.2f} "
              f"ivjk={scores['ivjk']:.2f} -> {best_layout}{note}")

    print("== 5. the same skew at pod scale: MoE expert placement ==")
    load = np.ones(128)
    load[:8] = 10.0  # router favours low experts early in training
    naive, skewed = layer_skew_gain(load, n_devices=16, n_layers=48)
    print(f"  worst-device load (max/mean): naive={naive:.2f} "
          f"skewed={skewed:.2f}  ({naive / skewed:.1f}x smoother)")

    print("== 6. kernel plans: analysis -> execution, no trial and error ==")
    print(f"  registered kernels: {', '.join(api.list_kernels())}")
    for kernel, shape, dtype in [
        ("stream.triad", (2 ** 24,), "float32"),
        ("triad", (8191,), "float32"),
        ("jacobi", (998, 1000), "float32"),
        ("lbm.ivjk", (19, 100, 100, 100), "float32"),
        ("rmsnorm", (4096, 5760), "bfloat16"),
        ("xent", (4096, 122753), "float32"),
    ]:
        print(api.explain(kernel, shape, dtype))
    # the same shapes under a 16-way tensor-parallel mesh: one ambient
    # context re-plans every family with shard-aligned minor dims.
    with api.plan_context(mesh={"model": 16}):
        p = api.plan_for("rmsnorm", (4096, 5760), "bfloat16")
        print(f"  under mesh model=16: rmsnorm minor dim "
              f"{p.width} (= {p.width // 16} per shard, lane-aligned)")
    info = planner.plan_cache_info()
    print(f"  plan cache: {info['size']} plans, "
          f"{info['hits']} hits / {info['misses']} misses")


if __name__ == "__main__":
    main()
