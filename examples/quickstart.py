"""Quickstart: the paper's technique in 60 seconds.

1. Diagnose a controller-aliasing conflict with the analytic model,
2. fix it with the closed-form skew plan (no trial and error),
3. run the Pallas vector-triad kernel under the chosen layout,
4. apply the same policy to an LM config for a 16-wide TP mesh.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro import api
from repro.core.aliasing import InterleavedMemoryModel, Stream
from repro.core.autotune import StreamSignature, plan_streams
from repro.configs import get_config
from repro.kernels.triad import ops as triad_ops
from repro.kernels.triad import ref as triad_ref


def main() -> None:
    model = InterleavedMemoryModel()  # T2: 4 controllers, addr bits 8:7
    print("== 1. diagnose ==")
    aligned = [Stream(0, "write")] + [Stream(0, "read")] * 3
    print(f"all arrays page-aligned: balance = "
          f"{model.balance(aligned):.2f}  (the paper's 4x collapse)")

    print("== 2. analytic fix ==")
    plan = plan_streams(StreamSignature(n_read=3, n_write=1), model)
    print(f"closed-form offsets: {plan.offsets_bytes} bytes "
          f"-> balance {plan.predicted_balance:.2f} "
          f"(paper: 128/256/384)")

    print("== 3. kernel under the layout ==")
    n = 100_000
    b = jnp.linspace(0, 1, n)
    c = jnp.linspace(1, 2, n)
    d = jnp.linspace(2, 3, n)
    # the unified launch path: the registry resolves the analytic plan for
    # this (shape, dtype) and runs the Pallas body -- one call, no wrapper.
    out = api.launch("triad", b, c, d)
    err = float(jnp.max(jnp.abs(out - triad_ref.triad(b, c, d))))
    print(f"api.launch('triad', ...) max err vs oracle: {err:.1e}")
    print(api.explain("triad", (n,), b.dtype))
    phases = tuple(o // 8 for o in plan.offsets_bytes[1:])
    out = triad_ops.vector_triad_phased(b, c, d, phases=phases)
    err = float(jnp.max(jnp.abs(out - triad_ref.triad(b, c, d))))
    print(f"pallas triad (skewed layout) max err vs oracle: {err:.1e}")

    print("== 4. the same policy, one level up ==")
    cfg = get_config("minicpm-2b")
    padded, changes = cfg.padded_for_mesh(tp=16)
    for name, (lo, hi) in changes.items():
        print(f"  {name}: {lo} -> {hi} "
              f"(waste {(hi - lo) / hi:.1%}, shard-aligned for 16-way TP)")
    print(f"  vocab shard: {padded.vocab_size // 16} "
          f"(= {padded.vocab_size // 16 // 128} x 128 lanes)")


if __name__ == "__main__":
    main()
