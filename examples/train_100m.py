"""End-to-end driver: train a ~100M-param qwen2-family model for a few
hundred steps on the synthetic pipeline, with checkpointing and WSD.

Run:  PYTHONPATH=src python examples/train_100m.py [--steps 200]
(CPU: ~15 min at the default 200 steps; use --steps 30 for a smoke run.)
"""
import argparse
import dataclasses
import time

import jax

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.optim.schedules import make_schedule
from repro.runtime.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_100m")
    args = ap.parse_args()

    # qwen2-0.5b family, sized to ~100M params for a single host
    cfg = dataclasses.replace(
        get_config("qwen2-0.5b"),
        n_layers=6, d_model=512, n_heads=8, n_kv_heads=2, d_ff=2048,
        vocab_size=32_000, dtype="float32", remat=False,
    )
    model = build_model(cfg)
    from repro.models.params import param_count
    print(f"model: {cfg.name}-100m  {param_count(model.param_defs())/1e6:.1f}M params")

    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=256, global_batch=16)
    trainer = Trainer(
        model, data, AdamWConfig(master=False, weight_decay=0.1),
        make_schedule("wsd", peak=3e-4, warmup=20, total=args.steps),
        TrainerConfig(n_steps=args.steps, ckpt_every=50,
                      ckpt_dir=args.ckpt_dir, log_every=10),
    )
    t0 = time.time()
    metrics = trainer.train(jax.random.PRNGKey(0))
    dt = time.time() - t0
    first = sum(m["loss"] for m in metrics[:10]) / 10
    last = sum(m["loss"] for m in metrics[-10:]) / 10
    print(f"loss {first:.3f} -> {last:.3f} over {len(metrics)} steps "
          f"({dt:.0f}s, {dt / max(len(metrics), 1):.2f}s/step)")
    if args.steps >= 50:  # short smoke runs sit inside the warmup
        assert last < first, "training must reduce loss"


if __name__ == "__main__":
    main()
