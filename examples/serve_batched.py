"""Serving example: continuous batching over the serve_step decode path.

Ragged requests stream through a fixed set of decode slots (vLLM-style);
per-slot cache indices keep co-resident requests independent -- including
SSM state resets when a slot is re-tenanted (zamba2 is stateful).

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config, reduce_for_smoke
from repro.models import build_model
from repro.serving import ContinuousBatcher, Request


def main() -> None:
    cfg = dataclasses.replace(reduce_for_smoke(get_config("zamba2-1.2b")),
                              n_layers=6, d_model=256, vocab_size=2048)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i,
                prompt=rng.integers(1, cfg.vocab_size, size=8 + 4 * i).tolist(),
                max_new_tokens=12 + 2 * i)
        for i in range(6)
    ]
    total_prompt = sum(len(r.prompt) for r in reqs)
    total_gen = sum(r.max_new_tokens for r in reqs)

    batcher = ContinuousBatcher(model, params, slots=3, max_len=96)
    t0 = time.time()
    out = batcher.run(reqs)
    dt = time.time() - t0
    print(f"{len(reqs)} ragged requests through 3 slots: "
          f"{batcher.ticks} ticks, {dt:.2f}s "
          f"({(total_prompt + total_gen) / dt:.1f} tok/s aggregate)")
    naive_ticks = sum(len(r.prompt) + r.max_new_tokens - 1 for r in reqs)
    print(f"slot reuse saved {naive_ticks - batcher.ticks} ticks vs "
          f"one-request-at-a-time ({batcher.ticks}/{naive_ticks})")
    for rid in sorted(out):
        print(f"  request {rid}: {len(out[rid])} tokens, "
              f"first 6 = {out[rid][:6]}")
    assert len(out) == len(reqs)


if __name__ == "__main__":
    main()
