"""API smoke stage for tier-1: the registry surface must be complete.

Imports every registered kernel family, fails on unregistered or shadowed
names (registry vs ``core.planner.FAMILIES`` drift), and renders
``explain()`` for one shape per family -- if any family cannot plan, this
exits non-zero before the test suite even starts.

Run:  PYTHONPATH=src python scripts/api_smoke.py
"""
from __future__ import annotations

import sys

EXPECTED = {
    "stream.copy", "stream.scale", "stream.add", "stream.triad",
    "triad", "jacobi", "lbm.soa", "lbm.ivjk",
    "rmsnorm", "rmsnorm.gated", "xent",
}

# one representative shape per family for the explain() pass
FAMILY_SMOKE = [
    ("stream.triad", (8191,), "float32"),
    ("triad", (2 ** 20,), "float32"),
    ("jacobi", (998, 1000), "float32"),
    ("lbm.ivjk", (19, 24, 24, 24), "float32"),
    ("rmsnorm", (4096, 5760), "bfloat16"),
    ("xent", (4096, 122753), "float32"),
]


def main() -> int:
    from repro import api
    from repro.core import planner

    names = set(api.list_kernels())  # imports every family module
    missing = EXPECTED - names
    if missing:
        print(f"FAIL: unregistered kernels: {sorted(missing)}")
        return 1
    shadowed = []
    for name in sorted(names):
        entry = api.get_kernel(name)
        fam = planner.FAMILIES.get(name)
        if fam is None:
            shadowed.append(f"{name}: registered but absent from "
                            f"planner.FAMILIES")
        elif (fam.n_read, fam.n_write) != (entry.signature.n_read,
                                           entry.signature.n_write):
            shadowed.append(
                f"{name}: planner says {fam.n_read}R+{fam.n_write}W, "
                f"registry says {entry.signature.n_read}R+"
                f"{entry.signature.n_write}W"
            )
    if shadowed:
        print("FAIL: shadowed kernel declarations:")
        for s in shadowed:
            print(f"  {s}")
        return 1
    for name, shape, dtype in FAMILY_SMOKE:
        print(api.explain(name, shape, dtype))
    print(f"api-smoke OK: {len(names)} kernels across "
          f"{len({n.split('.')[0] for n in names})} families")
    return 0


if __name__ == "__main__":
    sys.exit(main())
