"""Docs-check stage: every claim docs/*.md makes about the code must hold.

The docs are a checked artifact, not prose that rots.  Three classes of
reference are extracted from every markdown page under ``docs/`` and
verified against the tree:

  1. **Dotted ``repro.*`` references** (anywhere in the page, prose or
     code).  Each must resolve -- the longest importable module prefix is
     imported and the remaining components walked with ``getattr`` -- or
     match a quoted document-format tag in the source (``"repro.bench"``,
     ``"repro.plan_profile"``, ...: strings the code emits into JSON
     documents, which the docs legitimately name without them being
     importable modules).

  2. **Fenced ``python`` snippets.**  Each must parse
     (``compile(..., "exec")``), and every ``import repro...`` /
     ``from repro... import name`` statement inside must resolve the same
     way as a dotted reference -- an example that imports a function we
     deleted is a stale doc.

  3. **Fenced ``sh`` snippets.**  Each ``python -m repro.<mod>`` (or
     ``python scripts/x.py`` / ``python benchmarks/x.py``) invocation is
     located; the module/script must exist, and every ``--flag`` passed
     must appear in its argparse surface (collected by walking the file's
     AST for ``add_argument`` calls -- no main() is executed).

Run:  PYTHONPATH=src python scripts/check_docs.py
Exit: non-zero with one ``page:line: message`` finding per stale
reference; zero with a per-page summary when the docs are clean.
"""
from __future__ import annotations

import ast
import importlib
import importlib.util
import re
import shlex
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DOCS = REPO / "docs"

# Dotted repro.* reference in prose or code.  Stops at anything that is
# not a dotted identifier, so "repro.plan_profile/v1" matches only the
# tag and a sentence-ending "repro.api." drops the trailing dot.
REF_RE = re.compile(r"\brepro(?:\.[A-Za-z_][A-Za-z0-9_]*)+")

SHELL_LANGS = {"sh", "shell", "bash", "console"}


def _format_tags() -> set[str]:
    """Quoted ``"repro.*"`` string literals in the source tree: the
    document-format tags (``"repro.validation"``, ``"repro.bench"``, ...)
    that docs may name without them being importable modules.  Collected
    from the code so a deleted tag makes its doc reference stale."""
    tags: set[str] = set()
    lit = re.compile(r"[\"'](repro(?:\.[A-Za-z_][A-Za-z0-9_]*)+)[\"']")
    for root in (REPO / "src" / "repro", REPO / "benchmarks", REPO / "scripts"):
        for py in root.rglob("*.py"):
            tags.update(lit.findall(py.read_text()))
    return tags


def _resolves(ref: str) -> bool:
    """True when the dotted path imports: longest importable module
    prefix, then getattr for the remaining components."""
    parts = ref.split(".")
    for i in range(len(parts), 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:i]))
        except ImportError:
            continue
        for attr in parts[i:]:
            if not hasattr(obj, attr):
                return False
            obj = getattr(obj, attr)
        return True
    return False


def _argparse_flags(files: list[Path]) -> set[str]:
    """Every string flag handed to an ``add_argument`` call in the given
    files, found by AST walk (nothing is executed)."""
    flags: set[str] = set()
    for path in files:
        try:
            tree = ast.parse(path.read_text())
        except SyntaxError:
            continue
        for node in ast.walk(tree):
            if (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "add_argument"
            ):
                for arg in node.args:
                    if (
                        isinstance(arg, ast.Constant)
                        and isinstance(arg.value, str)
                        and arg.value.startswith("-")
                    ):
                        flags.add(arg.value)
    return flags


def _module_files(mod: str) -> list[Path] | None:
    """Source files defining a ``python -m <mod>`` CLI: the module itself,
    plus ``__main__.py`` when the module is a package."""
    try:
        spec = importlib.util.find_spec(mod)
    except (ImportError, ValueError):
        return None
    if spec is None or spec.origin is None:
        return None
    origin = Path(spec.origin)
    files = [origin]
    if origin.name == "__init__.py":
        main = origin.with_name("__main__.py")
        if main.exists():
            files.append(main)
    return files


class Checker:
    def __init__(self) -> None:
        self.findings: list[str] = []
        self.n_refs = 0
        self.n_snippets = 0
        self.n_clis = 0
        self._tags = _format_tags()
        self._ref_cache: dict[str, bool] = {}
        self._flag_cache: dict[str, set[str] | None] = {}

    def fail(self, page: Path, line: int, msg: str) -> None:
        self.findings.append(f"{page.relative_to(REPO)}:{line}: {msg}")

    # -- dotted references -------------------------------------------------

    def _ref_ok(self, ref: str) -> bool:
        if ref not in self._ref_cache:
            self._ref_cache[ref] = ref in self._tags or _resolves(ref)
        return self._ref_cache[ref]

    def check_refs(self, page: Path, text: str) -> None:
        for lineno, line in enumerate(text.splitlines(), start=1):
            for ref in REF_RE.findall(line):
                self.n_refs += 1
                if not self._ref_ok(ref):
                    self.fail(
                        page, lineno,
                        f"`{ref}` neither imports nor matches a "
                        f"document-format tag in the source",
                    )

    # -- fenced python snippets --------------------------------------------

    def check_python(self, page: Path, start: int, body: str) -> None:
        self.n_snippets += 1
        try:
            tree = ast.parse(body)
        except SyntaxError as e:
            self.fail(
                page, start + (e.lineno or 1),
                f"python snippet does not parse: {e.msg}",
            )
            return
        for node in ast.walk(tree):
            names: list[str] = []
            if isinstance(node, ast.Import):
                names = [a.name for a in node.names]
            elif isinstance(node, ast.ImportFrom) and node.module:
                names = [f"{node.module}.{a.name}" for a in node.names]
            for name in names:
                if name.split(".")[0] != "repro":
                    continue
                if not self._ref_ok(name):
                    self.fail(
                        page, start + node.lineno,
                        f"snippet imports `{name}`, which does not resolve",
                    )

    # -- fenced shell snippets ---------------------------------------------

    def _cli_flags(self, target: str) -> set[str] | None:
        """Argparse flag surface for a CLI target (dotted module or repo
        path), or None when the target itself is missing."""
        if target not in self._flag_cache:
            if target.endswith(".py"):
                path = REPO / target
                files = [path] if path.exists() else None
            else:
                files = _module_files(target)
            self._flag_cache[target] = (
                None if files is None else _argparse_flags(files)
            )
        return self._flag_cache[target]

    def check_shell(self, page: Path, start: int, body: str) -> None:
        # Join backslash continuations so one invocation is one line.
        joined: list[tuple[int, str]] = []
        acc, acc_line = "", 0
        for off, raw in enumerate(body.splitlines(), start=1):
            if not acc:
                acc_line = off
            if raw.rstrip().endswith("\\"):
                acc += raw.rstrip()[:-1] + " "
                continue
            joined.append((acc_line, acc + raw))
            acc = ""
        if acc:
            joined.append((acc_line, acc))
        for off, line in joined:
            self._check_invocation(page, start + off, line)

    def _check_invocation(self, page: Path, lineno: int, line: str) -> None:
        try:
            tokens = shlex.split(line, comments=True)
        except ValueError:
            tokens = line.split()
        # Usage-line brackets: `[--json]` names a real flag.
        tokens = [t.strip("[]") for t in tokens if t.strip("[]")]
        for i, tok in enumerate(tokens):
            if tok not in ("python", "python3"):
                continue
            rest = tokens[i + 1:]
            if rest[:1] == ["-m"]:
                target = rest[1] if len(rest) > 1 else ""
                rest = rest[2:]
                if target.split(".")[0] != "repro":
                    return  # pytest, pip, ... -- not ours to check
                if not self._ref_ok(target):
                    self.fail(page, lineno, f"`python -m {target}`: module "
                                            f"does not import")
                    return
            elif rest and rest[0].endswith(".py"):
                target = rest[0]
                rest = rest[1:]
                if not (REPO / target).exists():
                    self.fail(page, lineno,
                              f"`python {target}`: no such script")
                    return
            else:
                return
            self.n_clis += 1
            flags = self._cli_flags(target)
            if flags is None:
                self.fail(page, lineno, f"cannot locate source for {target}")
                return
            for tok in rest:
                if not tok.startswith("--"):
                    continue
                flag = tok.split("=", 1)[0]
                if flag not in flags:
                    self.fail(
                        page, lineno,
                        f"{target} has no `{flag}` flag in its argparse "
                        f"surface (stale CLI reference)",
                    )
            return

    # -- page walk ---------------------------------------------------------

    def check_page(self, page: Path) -> None:
        text = page.read_text()
        self.check_refs(page, text)
        lang, start, buf = None, 0, []
        for lineno, line in enumerate(text.splitlines(), start=1):
            stripped = line.strip()
            if stripped.startswith("```"):
                if lang is None:
                    lang, start, buf = stripped[3:].strip() or "text", lineno, []
                else:
                    body = "\n".join(buf)
                    if lang == "python":
                        self.check_python(page, start, body)
                    elif lang in SHELL_LANGS:
                        self.check_shell(page, start, body)
                    lang = None
                continue
            if lang is not None:
                buf.append(line)
        if lang is not None:
            self.fail(page, start, f"unterminated ``` fence ({lang})")


def main() -> int:
    pages = sorted(DOCS.glob("*.md"))
    if not pages:
        print(f"check_docs: no pages under {DOCS}", file=sys.stderr)
        return 1
    checker = Checker()
    for page in pages:
        checker.check_page(page)
    if checker.findings:
        for finding in checker.findings:
            print(finding, file=sys.stderr)
        print(
            f"check_docs: {len(checker.findings)} stale reference(s) across "
            f"{len(pages)} page(s)",
            file=sys.stderr,
        )
        return 1
    print(
        f"check_docs: {len(pages)} pages ok "
        f"({checker.n_refs} repro.* references, "
        f"{checker.n_snippets} python snippets, "
        f"{checker.n_clis} CLI invocations checked)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
