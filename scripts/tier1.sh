#!/usr/bin/env bash
# Tier-1 fast suite: the full test matrix minus the slow subprocess
# integration tests (pipeline/dry-run compiles), so it finishes in well
# under a minute.  Run the complete suite with:
#   PYTHONPATH=src python -m pytest -q
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
# Stage 1: API smoke -- every kernel family registered, plannable,
# explainable (fails fast on unregistered/shadowed names).
python scripts/api_smoke.py
# Stage 2: fast test matrix.
exec python -m pytest -q -m "not slow" "$@"
