#!/usr/bin/env bash
# Tier-1 fast suite: the full test matrix minus the slow subprocess
# integration tests (pipeline/dry-run compiles), so it finishes in well
# under a minute.  Run the complete suite with:
#   PYTHONPATH=src python -m pytest -q
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
# Stage 1: API smoke -- every kernel family registered, plannable,
# explainable (fails fast on unregistered/shadowed names).
python scripts/api_smoke.py
# Stage 2: measure smoke -- one family validated end-to-end (plan ->
# compile -> HLO bytes vs predicted traffic) in a few seconds.  The report
# goes to a per-run mktemp path so concurrent CI jobs sharing a runner (or
# a developer running two checkouts) never clobber each other; set
# TIER1_VALIDATION_OUT to pin a path (CI does, to upload it as an artifact).
# (no .json suffix on the template: BSD mktemp requires trailing Xs)
VALIDATION_OUT="${TIER1_VALIDATION_OUT:-$(mktemp "${TMPDIR:-/tmp}/tier1_validation.XXXXXX")}"
python -m repro.measure.validate --family stream --out "$VALIDATION_OUT"
echo "tier1: validation report at $VALIDATION_OUT"
# Stage 3: obs smoke -- one kernel launched under a JSONL sink (the
# observability bus end to end, docs/OBS.md), then the report CLI must
# aggregate the stream cleanly.  Same mktemp discipline as the validation
# report; set TIER1_OBS_OUT to pin a path (CI uploads it as an artifact).
OBS_OUT="${TIER1_OBS_OUT:-$(mktemp "${TMPDIR:-/tmp}/tier1_obs.XXXXXX")}"
python scripts/obs_smoke.py "$OBS_OUT"
python -m repro.obs.report "$OBS_OUT"
echo "tier1: obs event stream at $OBS_OUT"
# Stage 4: chaos smoke -- one deterministic fault storm (transient step
# failure + torn checkpoint write + device loss) through the elastic
# runtime on fake devices (docs/ELASTIC.md): re-mesh, restore, resume
# with exact loss parity, event stream aggregated by the report CLI.
# Set TIER1_CHAOS_OUT to pin a path (the CI chaos job uploads it).
CHAOS_OUT="${TIER1_CHAOS_OUT:-$(mktemp "${TMPDIR:-/tmp}/tier1_chaos.XXXXXX")}"
python scripts/chaos_smoke.py "$CHAOS_OUT"
python -m repro.obs.report "$CHAOS_OUT" --fail-on-validation
echo "tier1: chaos event stream at $CHAOS_OUT"
# Stage 5: static analysis -- the layout-hazard/declaration linter over
# the shipped registry vs the committed baseline (docs/ANALYZE.md), plus
# ruff when the environment has it (CI always does; the dev container may
# not, and the analyzer is the part that guards the planner invariants).
python -m repro.analyze --all
if command -v ruff >/dev/null 2>&1; then
  ruff check .
else
  echo "tier1: ruff not installed, skipping lint (CI runs it)"
fi
# Stage 6: docs check -- every repro.* reference, CLI flag, and fenced
# python snippet in docs/*.md verified against the tree (the docs are a
# checked artifact; scripts/check_docs.py, CI job docs-check).
python scripts/check_docs.py
# Stage 7: serving load-generator smoke -- a tiny offered-load point on
# the paged batcher (docs/SERVING.md), end to end through the CLI.  Keeps
# the benchmark runnable and the paged/chunked scheduler importable even
# when the slow serving matrix is deselected below.
python benchmarks/serving_load.py --loads 0.3 --ticks 6 --slots 2 \
  --max-len 16 >/dev/null
echo "tier1: serving load-generator smoke ok"
# Stage 8: fast test matrix (full sweeps carry the `sweep` marker and run
# out-of-band: pytest -m sweep).
exec python -m pytest -q -m "not slow and not sweep" "$@"
