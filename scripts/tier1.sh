#!/usr/bin/env bash
# Tier-1 fast suite: the full test matrix minus the slow subprocess
# integration tests (pipeline/dry-run compiles), so it finishes in well
# under a minute.  Run the complete suite with:
#   PYTHONPATH=src python -m pytest -q
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" exec python -m pytest -q -m "not slow" "$@"
