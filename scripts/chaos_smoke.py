"""Chaos smoke: one deterministic fault storm through the elastic runtime.

Tier-1 stage (scripts/tier1.sh) and the CI ``chaos`` job: proves the
failure-recovery chain wires end to end on a single CPU device --
a transient step failure, a torn checkpoint write, and a device loss are
injected into one tiny run (``runtime.faults.FaultPlan``); the elastic
runner must re-mesh, restore the newest complete checkpoint, and resume
with a loss trajectory **exactly** equal to an uninterrupted run on the
shrunken mesh (docs/ELASTIC.md), leaving the mesh-change/resume/degraded
event stream on disk for ``python -m repro.obs.report``.

Usage: ``python scripts/chaos_smoke.py [out.jsonl]``.
"""
import json
import sys
import tempfile
from types import SimpleNamespace

sys.path.insert(0, "src")

N_STEPS = 6


def _factory(ckpt_dir: str):
    from repro.data.pipeline import DataConfig
    from repro.models import build_model
    from repro.models.config import ModelConfig
    from repro.optim import adamw
    from repro.optim.schedules import make_schedule
    from repro.runtime.trainer import Trainer, TrainerConfig

    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=64,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=32,
                      dtype="float32", remat=False)
    model = build_model(cfg)

    def make_trainer(mesh):
        return Trainer(
            model,
            DataConfig(vocab_size=32, seq_len=16, global_batch=4,
                       d_model=64),
            adamw.AdamWConfig(master=False),
            make_schedule("cosine", peak=3e-3, warmup=2, total=N_STEPS),
            TrainerConfig(n_steps=N_STEPS, ckpt_every=2, ckpt_dir=ckpt_dir,
                          backoff_base_s=0.0),
            mesh=mesh)

    return make_trainer


def main() -> int:
    out = sys.argv[1] if len(sys.argv) > 1 else "/tmp/chaos_smoke.jsonl"

    import jax

    from repro import obs
    from repro.runtime.elastic import ElasticRunner
    from repro.runtime.faults import (
        CheckpointCrash, DeviceLoss, FaultPlan, Transient)

    key = jax.random.PRNGKey(0)
    devices = [SimpleNamespace(id=i) for i in range(4)]
    plan = FaultPlan((
        Transient(step=1),
        CheckpointCrash(step=4),
        DeviceLoss(step=3, failed_ids=(3,)),
    ))
    with obs.session(obs.JsonlSink(out)):
        with tempfile.TemporaryDirectory() as d:
            runner = ElasticRunner(_factory(d), devices=devices, tp=1)
            chaos = runner.run(key, fault_plan=plan)
    assert runner.remeshes == 1, runner.remeshes
    assert runner.mesh == {"data": 3, "model": 1}, runner.mesh
    assert [m["step"] for m in chaos] == list(range(N_STEPS)), chaos

    # Parity: the uninterrupted run on the shrunken topology must match
    # the faulted run bitwise -- replay is exact, nothing lost or
    # duplicated.
    with tempfile.TemporaryDirectory() as d:
        base = ElasticRunner(_factory(d), devices=devices[:3],
                             tp=1).run(key)
    for mc, mb in zip(chaos, base):
        assert mc["loss"] == mb["loss"], (mc, mb)

    with open(out) as f:
        records = [json.loads(line) for line in f]
    kinds = [r["kind"] for r in records]
    assert kinds.count("mesh_change") == 1, kinds
    assert kinds.count("resume") == 2, kinds
    assert kinds.count("degraded") >= 2, kinds   # transient retries

    print(f"chaos smoke ok: {len(records)} event(s), "
          f"1 device loss recovered with exact parity -> {out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
