"""Obs smoke: launch a kernel under a JSONL sink and sanity-check the stream.

Tier-1 stage (scripts/tier1.sh): proves the observability bus wires end to
end -- a real ``api.launch`` under ``obs.session(JsonlSink(...))`` leaves a
parseable event stream with plan-cache provenance in it -- and leaves the
stream on disk for ``python -m repro.obs.report`` (the next stage) to
aggregate.  Usage: ``python scripts/obs_smoke.py [out.jsonl]``.
"""
import json
import sys

sys.path.insert(0, "src")


def main() -> int:
    out = sys.argv[1] if len(sys.argv) > 1 else "/tmp/obs_smoke.jsonl"

    import jax.numpy as jnp
    import numpy as np

    from repro import api, obs

    x = jnp.arange(2000, dtype=jnp.float32)
    with obs.session(obs.JsonlSink(out)) as active:
        y = api.launch("stream.scale", x, s=2.0)
        api.launch("stream.scale", x, s=2.0)     # second launch: cache hit
        api.plan_for("rmsnorm", (64, 256), "float32")
    np.testing.assert_allclose(np.asarray(y), np.asarray(x) * 2.0)
    assert len(active) == 1, active

    with open(out) as f:
        records = [json.loads(line) for line in f]
    kinds = [r["kind"] for r in records]
    assert kinds.count("plan") >= 3, kinds
    caches = {r["cache"] for r in records if r["kind"] == "plan"}
    assert {"hit", "miss"} <= caches, caches

    # The default (no session) must deliver nothing to any sink.
    from repro.obs import sinks as sinks_lib

    calls = []
    orig = sinks_lib.NullSink.emit
    sinks_lib.NullSink.emit = lambda self, e: calls.append(e)
    try:
        api.launch("stream.scale", x, s=2.0)
    finally:
        sinks_lib.NullSink.emit = orig
    assert not calls, f"{len(calls)} sink call(s) with obs disabled"

    print(f"obs smoke ok: {len(records)} event(s) -> {out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
