"""Differential measured-vs-predicted tests (the paper's Fig. 4 loop).

On the CPU dry-run backend every kernel family's compiled HLO
bytes-accessed must sit inside its declared tolerance envelope around the
plan's predicted traffic, and a sweep-produced profile must round-trip
``save_profile -> load_profile -> PlanContext -> plan_for`` reproducing the
swept choice (which demonstrably differs from the analytic one).
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.measure import profile as profile_lib
from repro.measure import sweep as sweep_lib
from repro.measure import validate as validate_lib

# One kernel per registry family; CASES supplies the representative cell.
FAMILY_REPS = ["stream.triad", "triad", "jacobi", "lbm.soa", "rmsnorm",
               "xent"]

# The sweep demo cell: 1016 = 8 x 127 rows has no block-sized divisor near
# the default block target, so the analytic plan rounds the row count up a
# whole block (heavy padding) and measurement finds a strictly cheaper
# small-block candidate.
SWEEP_CELL = ("rmsnorm", (1016, 1111), "float32")


class TestMeasuredVsPredicted:
    def test_every_family_has_a_case_and_tolerance(self):
        for kernel in api.list_kernels():
            # ad-hoc kernels registered by other tests and the analysis-only
            # hazard fixtures are not shipped surface: no validation cell
            module = api.get_kernel(kernel).body.__module__
            if (not module.startswith("repro.")
                    or module.startswith("repro.analyze.")):
                continue
            assert kernel in validate_lib.CASES, kernel
            assert kernel.split(".")[0] in validate_lib.TOLERANCES, kernel

    @pytest.mark.parametrize("kernel", FAMILY_REPS)
    def test_family_within_envelope(self, kernel):
        rec = validate_lib.validate_kernel(kernel)
        assert rec["status"] == "ok", (
            f"{kernel}: measured {rec['measured']['bytes']:.3e} / predicted "
            f"{rec['predicted']['hbm_bytes']:.3e} = {rec['ratio']} outside "
            f"tolerance {rec['tolerance']}"
        )
        assert rec["measured"]["flops"] >= 0
        assert rec["predicted"]["hbm_bytes"] >= rec["predicted"]["logical_bytes"]

    def test_validate_cli_writes_report(self, tmp_path):
        out = tmp_path / "validation.json"
        rc = validate_lib.main(["--family", "triad", "--out", str(out)])
        assert rc == 0
        doc = json.loads(out.read_text())
        assert doc["format"] == validate_lib.VALIDATION_FORMAT
        assert doc["backend"] == jax.default_backend()
        recs = {r["kernel"]: r for r in doc["records"]}
        assert recs["triad"]["status"] == "ok"
        # re-running merges in place, never duplicates
        rc = validate_lib.main(["--family", "triad", "--out", str(out)])
        assert rc == 0
        doc2 = json.loads(out.read_text())
        assert len(doc2["records"]) == len(doc["records"])


class TestSweepProfileRoundTrip:
    def test_sweep_finds_cheaper_plan_and_profile_round_trips(self, tmp_path):
        kernel, shape, dtype = SWEEP_CELL
        res = sweep_lib.sweep_cell(kernel, shape, dtype)
        assert len(res.candidates) > 1
        # measurement demonstrably overrides the analytic choice here
        assert res.changed, (
            res.best.plan.explain(), res.default_plan.explain())
        assert (res.best.measured["bytes"]
                < min(c.measured["bytes"] for c in res.candidates
                      if (c.plan.padded_shape, c.plan.block_shape)
                      == (res.default_plan.padded_shape,
                          res.default_plan.block_shape)))

        path = str(tmp_path / "profile.json")
        profile_lib.save_profile(path, [res.entry()],
                                 backend=jax.default_backend())
        overrides = profile_lib.load_profile(path)
        assert profile_lib.profile_key(kernel, shape, dtype) in overrides

        with api.plan_context(plan_overrides=overrides):
            p = api.plan_for(kernel, shape, dtype)
            assert p.padded_shape == res.best.plan.padded_shape
            assert p.block_shape == res.best.plan.block_shape
            assert p.provenance == f"profile:{path}"
            assert f"profile:{path}" in p.explain()
            # the override changes the launched layout, not the math
            x = jax.random.normal(jax.random.PRNGKey(0), shape, jnp.float32)
            s = jax.random.normal(jax.random.PRNGKey(1), shape[-1:]) + 1.0
            got = api.launch(kernel, x, s)
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(api.ref(kernel, x, s)),
                rtol=2e-4, atol=1e-5)
        # outside the context the analytic plan is back
        default = api.plan_for(kernel, shape, dtype)
        assert default.padded_shape == res.default_plan.padded_shape
        assert default.provenance == "analytic"

    def test_other_shapes_fall_through_to_planner(self, tmp_path):
        kernel, shape, dtype = SWEEP_CELL
        res = sweep_lib.sweep_cell(kernel, shape, dtype)
        path = str(tmp_path / "profile.json")
        profile_lib.save_profile(path, [res.entry()])
        with api.plan_context(plan_overrides=profile_lib.load_profile(path)):
            other = api.plan_for(kernel, (64, 129), dtype)
        assert other.provenance == "analytic"
        assert other.logical_shape == (64, 129)

    def test_profile_drift_detection(self, tmp_path):
        kernel, shape, dtype = SWEEP_CELL
        plan = api.plan_for(kernel, shape, dtype)
        entry = profile_lib.entry_from_plan(
            plan, {"sublanes": plan.sublanes, "vmem_budget": 1 << 24})
        entry["expect"]["padded_shape"] = [1, 1]  # simulate planner drift
        path = str(tmp_path / "stale.json")
        profile_lib.save_profile(path, [entry])
        with pytest.raises(ValueError, match="planner drift"):
            profile_lib.load_profile(path)
        with pytest.warns(UserWarning, match="entry skipped"):
            assert profile_lib.load_profile(path, strict=False) == {}

    def test_profile_format_versioning(self, tmp_path):
        bad = tmp_path / "bad.json"
        bad.write_text(json.dumps({"format": "something.else"}))
        with pytest.raises(ValueError, match="not a plan profile"):
            profile_lib.load_profile(str(bad))
        new = tmp_path / "new.json"
        new.write_text(json.dumps({
            "format": profile_lib.PROFILE_FORMAT,
            "version": profile_lib.PROFILE_VERSION + 1, "entries": [],
        }))
        with pytest.raises(ValueError, match="newer than supported"):
            profile_lib.load_profile(str(new))

    def test_context_from_profile(self, tmp_path):
        kernel, shape, dtype = SWEEP_CELL
        res = sweep_lib.sweep_cell(kernel, shape, dtype)
        path = str(tmp_path / "profile.json")
        profile_lib.save_profile(path, [res.entry()])
        ctx = api.PlanContext.from_profile(path)
        p = api.plan_for(kernel, shape, dtype, ctx=ctx)
        assert p.provenance == f"profile:{path}"


@pytest.mark.sweep
def test_full_sweep_every_case(tmp_path):
    """The complete sweep (every validate cell): excluded from tier-1 via
    the ``sweep`` marker; run with ``pytest -m sweep``."""
    cells = [(k, s, d) for k, (s, d) in validate_lib.CASES.items()]
    results = sweep_lib.sweep_cells(cells)
    path = str(tmp_path / "profile.json")
    profile_lib.save_profile(path, [r.entry() for r in results])
    overrides = profile_lib.load_profile(path)
    assert len(overrides) == len(cells)
    for r in results:
        assert r.best.measured["bytes"] <= min(
            c.measured["bytes"] for c in r.candidates)


def test_sweep_result_is_deterministic():
    """Same cell, same backend -> same winner (dataclass fields equal),
    so profiles are reproducible artifacts."""
    kernel, shape, dtype = SWEEP_CELL
    a = sweep_lib.sweep_cell(kernel, shape, dtype)
    b = sweep_lib.sweep_cell(kernel, shape, dtype)
    assert a.best.knobs == b.best.knobs
    assert a.best.plan.padded_shape == b.best.plan.padded_shape
    assert dataclasses.asdict(a)["best"]["measured"]["bytes"] == \
        dataclasses.asdict(b)["best"]["measured"]["bytes"]
