"""Hypothesis property tests for planner invariants.

The example-based planner tests (test_planner.py) pin known shapes; these
properties assert the closed-form rules hold over the whole input space the
planner accepts: tile divisibility, nonnegative (and aligned-zero) waste,
bf16 never paying more padding bytes than fp32, and memo-key stability.
"""
from _hypothesis_compat import given, settings
from _hypothesis_compat import strategies as st

from repro.core.layout import LANES
from repro.core.planner import (
    clear_plan_cache,
    plan_cache_keys,
    plan_kernel,
    sublanes_for_dtype,
)

FAMILIES_1D = ["stream.copy", "stream.add", "stream.triad", "triad"]
FAMILIES_2D = ["rmsnorm", "rmsnorm.gated", "xent", "jacobi"]
DTYPES = ["float32", "bfloat16"]


class TestTileDivisibility:
    """Every padded extent is a whole number of blocks: the grid never
    launches a ragged tail DMA."""

    @settings(max_examples=60)
    @given(kernel=st.sampled_from(FAMILIES_1D + FAMILIES_2D),
           dtype=st.sampled_from(DTYPES),
           a=st.integers(min_value=1, max_value=50_000),
           b=st.integers(min_value=1, max_value=4_000))
    def test_padded_divisible_by_block(self, kernel, dtype, a, b):
        shape = (a,) if kernel in FAMILIES_1D else (a % 3000 + 1, b)
        plan = plan_kernel(kernel, shape, dtype)
        for padded, block in zip(plan.padded_shape, plan.block_shape):
            assert padded % block == 0, plan.explain()
        assert plan.rows % plan.sublanes == 0
        assert plan.width % LANES == 0

    @settings(max_examples=20)
    @given(dtype=st.sampled_from(DTYPES),
           layout=st.sampled_from(["lbm.soa", "lbm.ivjk"]),
           n=st.integers(min_value=2, max_value=40))
    def test_lbm_padded_divisible_by_block(self, dtype, layout, n):
        plan = plan_kernel(layout, (19, n, n, n), dtype)
        for padded, block in zip(plan.padded_shape, plan.block_shape):
            assert padded % block == 0, plan.explain()


class TestWaste:
    @settings(max_examples=60)
    @given(kernel=st.sampled_from(FAMILIES_1D + FAMILIES_2D),
           dtype=st.sampled_from(DTYPES),
           a=st.integers(min_value=1, max_value=50_000),
           b=st.integers(min_value=1, max_value=4_000))
    def test_waste_bytes_nonnegative(self, kernel, dtype, a, b):
        shape = (a,) if kernel in FAMILIES_1D else (a % 3000 + 1, b)
        plan = plan_kernel(kernel, shape, dtype)
        assert plan.waste_bytes >= 0
        assert plan.predicted_hbm_bytes >= plan.predicted_logical_bytes

    @settings(max_examples=40)
    @given(kernel=st.sampled_from(["rmsnorm", "rmsnorm.gated", "xent"]),
           dtype=st.sampled_from(DTYPES),
           r=st.integers(min_value=1, max_value=16),
           c=st.integers(min_value=1, max_value=8))
    def test_zero_waste_on_aligned_2d_shapes(self, kernel, dtype, r, c):
        """A shape already on the dtype's (sublane, lane) tile pays nothing
        (rows small enough that one block covers them, so the block chooser
        never rounds the row count up)."""
        sub = sublanes_for_dtype(dtype)
        plan = plan_kernel(kernel, (r * sub, c * LANES), dtype)
        assert plan.waste_bytes == 0, plan.explain()
        assert plan.padded_shape == plan.logical_shape

    @settings(max_examples=20)
    @given(kernel=st.sampled_from(["stream.copy", "stream.triad", "triad"]),
           k=st.integers(min_value=1, max_value=32))
    def test_zero_waste_on_aligned_1d_shapes(self, kernel, k):
        plan = plan_kernel(kernel, (k * 8 * LANES,), "float32")
        assert plan.waste_bytes == 0, plan.explain()

    @settings(max_examples=40)
    @given(kernel=st.sampled_from(["triad", "rmsnorm", "xent"]),
           a=st.integers(min_value=1, max_value=30_000),
           b=st.integers(min_value=1, max_value=3_000))
    def test_bf16_waste_bytes_at_most_fp32(self, kernel, a, b):
        """On identical odd shapes the bf16 plan never pays more padding
        *bytes* than fp32: wider sublane tiles can pad more elements, but
        each costs half as much."""
        shape = (2 * a + 1,) if kernel == "triad" else (a % 2000 + 1,
                                                        2 * b + 1)
        p32 = plan_kernel(kernel, shape, "float32")
        p16 = plan_kernel(kernel, shape, "bfloat16")
        assert p16.waste_bytes <= p32.waste_bytes, (
            p16.explain(), p32.explain())


class TestCacheKeyStability:
    @settings(max_examples=20)
    @given(kernel=st.sampled_from(FAMILIES_1D),
           n=st.integers(min_value=1, max_value=100_000))
    def test_repeated_calls_hit_the_memo(self, kernel, n):
        clear_plan_cache()
        first = plan_kernel(kernel, (n,), "float32")
        keys_after_first = plan_cache_keys()
        again = plan_kernel(kernel, (n,), "float32")
        assert again is first                      # same object, not equal
        assert plan_cache_keys() == keys_after_first  # no new key minted

    @settings(max_examples=20)
    @given(tp=st.integers(min_value=1, max_value=8),
           r=st.integers(min_value=1, max_value=2_000))
    def test_mesh_equality_not_identity(self, tp, r):
        """Two distinct but equal mesh mappings share one memo entry: the
        key hashes mesh *contents*, never object identity."""
        clear_plan_cache()
        a = plan_kernel("rmsnorm", (r, 1111), "float32",
                        mesh={"model": tp, "data": 2})
        b = plan_kernel("rmsnorm", (r, 1111), "float32",
                        mesh={"data": 2, "model": tp})  # same mapping, new dict
        assert b is a
        assert len([k for k in plan_cache_keys() if k[0] == "rmsnorm"]) == 1
