"""Sharding-rules unit tests: spec mapping, dedup, divisibility fallback,
per-arch layout policy, shape applicability, cost pattern units."""
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.configs.shapes import SHAPES, shape_applicable
from repro.launch.mesh import make_test_mesh
from repro.parallel import rules as R


class TestSpec:
    def test_basic_mapping(self):
        rules = R.make_rules()
        assert R.spec("batch", None, rules=rules) == P("data")
        assert R.spec("embed", "mlp", rules=rules) == P(None, "model")
        assert R.spec(None, None, rules=rules) == P()

    def test_multipod_batch(self):
        rules = R.make_rules(multi_pod=True)
        assert R.spec("batch", None, rules=rules) == P(("pod", "data"))

    def test_dedup_first_dim_wins(self):
        rules = R.make_rules()
        # mlp and heads both -> model: second occurrence is dropped
        assert R.spec("mlp", "heads", rules=rules) == P("model")
        assert R.spec("heads", "mlp", rules=rules) == P("model")

    def test_divisibility_fallback_with_mesh(self):
        rules = R.make_rules()
        mesh = make_test_mesh((1, 1), ("data", "model"))
        with R.use_rules(rules, mesh=mesh):
            # model axis size 1: everything divisible
            assert R.spec("mlp", rules=rules, shape=(7,)) == P("model")
        # fake a 16-wide model axis via the context
        tok = R._axis_sizes.set({"data": 16, "model": 16})
        try:
            assert R.spec("mlp", rules=rules, shape=(7,)) == P()
            assert R.spec("mlp", rules=rules, shape=(32,)) == P("model")
            assert R.spec("batch", rules=rules, shape=(8,)) == P()
        finally:
            R._axis_sizes.reset(tok)

    def test_expert_tp_rules(self):
        rules = R.make_rules(expert_tp=True)
        assert R.spec("expert", rules=rules) == P()
        assert R.spec("expert_mlp", rules=rules) == P("model")

    def test_shard_is_noop_without_mesh(self):
        import jax.numpy as jnp

        x = jnp.ones((4, 4))
        assert R.shard(x, "batch", None) is x


class TestLayoutPolicyPerArch:
    def test_all_archs_pad_cleanly(self):
        for arch in ARCHS:
            cfg, changes = get_config(arch).padded_for_mesh(16)
            assert cfg.n_heads % cfg.n_kv_heads == 0, arch
            if cfg.family != "ssm":
                # either sharded or replicated; never ragged heads
                assert cfg.n_heads % 16 == 0 or cfg.n_heads < 16, arch
            if cfg.d_ff:
                assert (cfg.d_ff // 16) % 128 == 0 or cfg.d_ff % 16, arch
            assert cfg.vocab_size % (16 * 128) == 0, arch
            for name, (lo, hi) in changes.items():
                assert hi >= lo, (arch, name)
                # whisper-tiny pads 6 -> 16 heads (62.5%): the price of one
                # physical layout serving both ZeRO-3 train and TP serve
                # cells; every other pad stays under 1/3 waste.
                cap = 0.70 if arch == "whisper-tiny" else 0.34
                assert (hi - lo) / hi < cap, (arch, name, "waste too big")

    def test_ssm_head_structure_untouched(self):
        cfg, _ = get_config("xlstm-1.3b").padded_for_mesh(16)
        assert cfg.n_heads == 4 and cfg.n_kv_heads == 4


class TestShapeApplicability:
    def test_long_500k_rules(self):
        for arch in ARCHS:
            cfg = get_config(arch)
            ok, why = shape_applicable(cfg, SHAPES["long_500k"])
            if arch in ("zamba2-1.2b", "xlstm-1.3b"):
                assert ok, arch
            else:
                assert not ok and "full-attention" in why, arch

    def test_everything_else_applicable(self):
        for arch in ARCHS:
            for s in ("train_4k", "prefill_32k", "decode_32k"):
                ok, _ = shape_applicable(get_config(arch), SHAPES[s])
                assert ok, (arch, s)

    def test_cell_count_is_40(self):
        cells = [
            (a, s) for a in ARCHS for s in SHAPES
        ]
        assert len(cells) == 40
        applicable = [
            (a, s) for a, s in cells
            if shape_applicable(get_config(a), SHAPES[s])[0]
        ]
        assert len(applicable) == 32  # + 8 mandated skips


class TestCostUnits:
    def test_pattern_units(self):
        from repro.launch import costs

        assert costs.pattern_unit(get_config("qwen3-4b")) == 1
        assert costs.pattern_unit(get_config("zamba2-1.2b")) == 6
        assert costs.pattern_unit(get_config("xlstm-1.3b")) == 8
        assert costs.n_units(get_config("xlstm-1.3b")) == pytest.approx(6.0)
        assert costs.n_units(get_config("whisper-tiny")) == pytest.approx(4.0)

    def test_reduced_cfg_structure(self):
        from repro.launch import costs

        cfg = get_config("zamba2-1.2b")
        r1 = costs.reduced_cfg(cfg, 1)
        assert r1.n_layers == 6 and r1.unroll
        assert ("shared_attn", 1) in r1.stages()
        r2 = costs.reduced_cfg(get_config("whisper-tiny"), 2)
        assert r2.n_layers == 2 and r2.n_enc_layers == 2
