"""Deterministic fault injection (``runtime.faults``) and its consumers.

The injector contract the chaos suite rests on: every fault fires on
exactly its chosen step/tick, fires *once* (``Transient`` up to its
``times``), and a replayed step after a restore never re-trips a fired
fault -- determinism is what makes the parity assertions in
``tests/test_elastic.py`` possible at all.  The consumer halves covered
here: the trainer's transient-vs-persistent classification with
exponential backoff, the checkpoint manager's torn-write hook, and the
straggler detector.
"""
from __future__ import annotations

import time

import jax
import pytest

from repro import obs
from repro.runtime.faults import (
    CheckpointCrash,
    DeviceLoss,
    DeviceLossError,
    FaultPlan,
    PoolShrink,
    Straggler,
    Transient,
    TransientStepError,
)


class TestInjector:
    def test_transient_fires_exactly_times(self):
        inj = FaultPlan((Transient(step=2, times=2),)).injector()
        inj(0)
        inj(1)
        for _ in range(2):
            with pytest.raises(TransientStepError):
                inj(2)
        inj(2)          # armed out: the replayed step passes
        assert inj.log == [("transient", 2), ("transient", 2)]

    def test_device_loss_is_one_shot_and_typed(self):
        inj = FaultPlan((DeviceLoss(step=3, failed_ids=(5, 6)),)).injector()
        with pytest.raises(DeviceLossError) as ei:
            inj(3)
        assert ei.value.failed_ids == frozenset({5, 6})
        assert ei.value.step == 3
        inj(3)          # replay after re-mesh: must not re-fire

    def test_straggler_delays_without_raising(self):
        inj = FaultPlan((Straggler(step=1, delay_s=0.05),)).injector()
        t0 = time.perf_counter()
        inj(1)
        assert time.perf_counter() - t0 >= 0.05
        t0 = time.perf_counter()
        inj(1)          # one-shot
        assert time.perf_counter() - t0 < 0.05

    def test_plans_are_frozen_and_reusable(self):
        plan = FaultPlan((Transient(step=0),))
        with pytest.raises(Exception):
            plan.faults = ()
        a, b = plan.injector(), plan.injector()
        with pytest.raises(TransientStepError):
            a(0)
        with pytest.raises(TransientStepError):
            b(0)        # fresh injector, fresh arming

    def test_checkpoint_crash_leaves_torn_tmp(self, tmp_path):
        from repro.checkpoint.manager import CheckpointManager

        mgr = CheckpointManager(str(tmp_path), async_write=False)
        inj = FaultPlan((CheckpointCrash(step=4),)).injector()
        inj.attach_checkpoint(mgr)
        mgr.save(2, {"w": jax.numpy.ones(3)})      # below the step: clean
        with pytest.raises(OSError):
            mgr.save(4, {"w": jax.numpy.ones(3)})
        # The torn tmp dir exists but is invisible to restore.
        tmps = [p.name for p in tmp_path.iterdir() if ".tmp" in p.name]
        assert tmps, "crash left no torn tmp dir"
        assert mgr.all_steps() == [2]
        mgr.save(4, {"w": jax.numpy.ones(3)})      # one-shot: retry lands
        assert mgr.all_steps() == [2, 4]

    def test_attach_checkpoint_without_crash_is_noop(self, tmp_path):
        from repro.checkpoint.manager import CheckpointManager

        mgr = CheckpointManager(str(tmp_path), async_write=False)
        FaultPlan((Transient(step=0),)).injector().attach_checkpoint(mgr)
        assert mgr.fault_hook is None

    def test_tick_applies_pool_shrink(self):
        class FakeBatcher:
            shrunk = None

            def shrink_pool(self, n):
                self.shrunk = n

        b = FakeBatcher()
        inj = FaultPlan((PoolShrink(tick=3, live_pages=2),)).injector()
        inj.tick(b, 2)
        assert b.shrunk is None
        inj.tick(b, 3)
        assert b.shrunk == 2
        b.shrunk = None
        inj.tick(b, 3)      # one-shot
        assert b.shrunk is None


class TestTrainerClassification:
    def test_transient_retries_with_backoff_then_finishes(self, tmp_path,
                                                          monkeypatch):
        from tests.test_obs import _tiny_trainer

        tr = _tiny_trainer(str(tmp_path), n_steps=3, ckpt_every=2)
        tr.tcfg.backoff_base_s = 0.01
        sleeps = []
        monkeypatch.setattr("repro.runtime.trainer.time.sleep",
                            sleeps.append)
        inj = FaultPlan((Transient(step=1, times=2),)).injector()
        ring = obs.RingBufferSink(capacity=1000)
        with obs.session(ring):
            metrics = tr.train(jax.random.PRNGKey(0), fail_injector=inj)
        assert [m["step"] for m in metrics][-1] == 2
        # Exponential backoff: 0.01 then 0.02.
        assert sleeps == pytest.approx([0.01, 0.02])
        deg = ring.events("degraded")
        assert [e.reason for e in deg] == ["transient_retry"] * 2

    def test_retry_budget_exhaustion_raises(self, tmp_path, monkeypatch):
        from tests.test_obs import _tiny_trainer

        tr = _tiny_trainer(str(tmp_path), n_steps=3, ckpt_every=2)
        tr.tcfg.max_retries = 1
        monkeypatch.setattr("repro.runtime.trainer.time.sleep",
                            lambda s: None)
        inj = FaultPlan((Transient(step=1, times=5),)).injector()
        with pytest.raises(TransientStepError):
            tr.train(jax.random.PRNGKey(0), fail_injector=inj)

    def test_device_loss_propagates_uncaught(self, tmp_path):
        """Persistent failures must escape the retry loop immediately --
        retrying a step on a dead topology cannot succeed."""
        from tests.test_obs import _tiny_trainer

        tr = _tiny_trainer(str(tmp_path), n_steps=3, ckpt_every=2)
        inj = FaultPlan((DeviceLoss(step=1, failed_ids=(0,)),)).injector()
        with pytest.raises(DeviceLossError):
            tr.train(jax.random.PRNGKey(0), fail_injector=inj)

    def test_straggler_detector_thresholds(self, tmp_path):
        """Blown step time over the EMA is a DegradedEvent; normal steps
        and warm-up (no EMA history yet) are not.  The loop wiring is
        covered by the injected Straggler in the elastic suite."""
        from tests.test_obs import _tiny_trainer

        tr = _tiny_trainer(str(tmp_path), n_steps=6, ckpt_every=100)
        tr.tcfg.straggler_factor = 3.0
        ring = obs.RingBufferSink(capacity=1000)
        with obs.session(ring):
            tr._note_straggler(step=4, step_s=100.0, ema=1.0, n_hist=5)
            tr._note_straggler(step=5, step_s=1.0, ema=1.0, n_hist=5)
            tr._note_straggler(step=0, step_s=100.0, ema=None, n_hist=0)
            tr._note_straggler(step=1, step_s=100.0, ema=1.0, n_hist=2)
        deg = ring.events("degraded")
        assert len(deg) == 1
        assert deg[0].reason == "straggler" and deg[0].step == 4
