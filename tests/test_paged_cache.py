"""Paged KV cache units + scheduler correctness regressions (fast).

Covers the host-side machinery without compiling a real model: page
geometry arithmetic and bank-skewed allocation, the PageManager pool,
planner-derived page sizing, and the three scheduler bugfix regressions
(shape-guessed slot resets, non-bool ``done()`` / empty prompts, and
silent ``run()`` truncation).  Model-level paged-vs-dense parity lives in
``tests/test_serving.py`` (slow)."""
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core.segmented import PageGeometry
from repro.models.params import ParamDef
from repro.serving import (
    ContinuousBatcher,
    PageManager,
    Request,
    TruncatedRun,
    plan_page_geometry,
)


# ---------------------------------------------------------------------------
# geometry
# ---------------------------------------------------------------------------
class TestPageGeometry:
    def test_arithmetic(self):
        g = PageGeometry(page_len=8, n_pages=5)
        assert g.live_pages == 4
        assert [g.pages_for(n) for n in (0, 1, 8, 9, 16)] == [0, 1, 1, 2, 2]
        assert g.page_of(13) == 1 and g.offset_of(13) == 5
        assert g.pages_for(-3) == 0

    def test_alloc_order_is_bank_skewed(self):
        g = PageGeometry(page_len=8, n_pages=9, banks=4)
        order = g.alloc_order()
        assert sorted(order) == list(range(1, 9))        # null page excluded
        # Consecutive allocations cycle through the interleave groups.
        assert [p % 4 for p in order[:4]] == sorted({p % 4 for p in order[:4]})

    def test_validation(self):
        with pytest.raises(ValueError):
            PageGeometry(page_len=0, n_pages=4)
        with pytest.raises(ValueError):
            PageGeometry(page_len=8, n_pages=1)     # null page only
        with pytest.raises(ValueError):
            PageGeometry(page_len=8, n_pages=4, banks=0)


class TestPageManager:
    def test_alloc_is_all_or_nothing(self):
        pm = PageManager(PageGeometry(page_len=4, n_pages=4), n_slots=2)
        assert pm.free_pages == 3
        got = pm.alloc(0, upto_pos=7)                # 2 pages
        assert len(got) == 2 and pm.free_pages == 1
        assert [lp for lp, _ in got] == [0, 1]
        # Slot 1 wants 2 pages but only 1 remains: nothing is taken.
        assert pm.alloc(1, upto_pos=4) is None
        assert pm.free_pages == 1 and pm.slot_pages(1) == ()
        # Growing an already-covered slot allocates nothing.
        assert pm.alloc(0, upto_pos=6) == []

    def test_release_returns_everything(self):
        pm = PageManager(PageGeometry(page_len=4, n_pages=6, banks=2),
                         n_slots=2)
        pm.alloc(0, upto_pos=11)
        assert pm.used_pages == 3
        freed = pm.release(0)
        assert len(freed) == 3
        assert pm.free_pages == 5 and pm.slot_pages(0) == ()

    def test_needed_tracks_coverage(self):
        pm = PageManager(PageGeometry(page_len=4, n_pages=8), n_slots=1)
        assert pm.needed(0, upto_pos=0) == 1
        pm.alloc(0, upto_pos=0)
        assert pm.needed(0, upto_pos=3) == 0
        assert pm.needed(0, upto_pos=4) == 1


class TestPlanPageGeometry:
    def _cfg(self):
        return types.SimpleNamespace(n_kv_heads=2, hd=16,
                                     adtype=jnp.float32)

    def test_page_len_is_planner_tile(self):
        geom, plan = plan_page_geometry(self._cfg(), max_len=64, slots=2)
        assert geom.page_len == plan.block_rows
        assert geom.page_len % plan.sublanes == 0
        # Enough pages for `slots` full sequences plus the null page.
        assert geom.n_pages == 1 + 2 * (-(-64 // geom.page_len))

    def test_explicit_page_len_must_be_tile_aligned(self):
        geom, plan = plan_page_geometry(self._cfg(), max_len=64,
                                        page_len=2 * 8)
        assert geom.page_len == 16
        with pytest.raises(ValueError, match="sublane"):
            plan_page_geometry(self._cfg(), max_len=64,
                               page_len=plan.sublanes + 1)


# ---------------------------------------------------------------------------
# scheduler regressions (fake models: no compilation heft)
# ---------------------------------------------------------------------------
class _EchoModel:
    """Echoes the fed token as the greedy output; empty cache tree."""

    def __init__(self, vocab: int = 16):
        self.vocab = vocab
        self.cfg = types.SimpleNamespace(d_model=0, adtype=jnp.float32)

    def cache_defs(self, slots, max_len):
        return {}

    def decode_step(self, params, cache, tokens):
        logits = jax.nn.one_hot(tokens[:, 0], self.vocab)[:, None, :]
        return logits, cache


class _AxisModel(_EchoModel):
    """Echo model whose cache leaf carries its batch axis LAST, after a
    ``max_len``-sized axis -- the layout that broke the old shape-guessed
    ``_reset_slot`` whenever ``max_len == padded_slots``."""

    def cache_defs(self, slots, max_len):
        return {
            "idx": ParamDef((slots,), ("batch",), init="zeros",
                            dtype=jnp.int32),
            "state": ParamDef((2, max_len, slots),
                              ("layers", "cache_seq", "batch"),
                              init="zeros", dtype=jnp.float32),
        }

    def decode_step(self, params, cache, tokens):
        logits = jax.nn.one_hot(tokens[:, 0], self.vocab)[:, None, :]
        new = {"idx": cache["idx"] + 1, "state": cache["state"] + 1.0}
        return logits, new


class TestResetSlotRegression:
    def test_reset_follows_declared_batch_axis(self):
        # max_len == padded_slots: the old heuristic (match shape[1] ==
        # padded_slots -> reset axis 1) would have cleared the cache_seq
        # rows of EVERY slot instead of one slot's column.
        b = ContinuousBatcher(_AxisModel(), {}, slots=4, max_len=4)
        assert b.padded_slots == b.max_len
        b.cache = {
            "idx": jnp.full((4,), 7, jnp.int32),
            "state": jnp.ones((2, 4, 4), jnp.float32),
        }
        out = b._reset_slot(b.cache, 1)
        state = np.asarray(out["state"])
        assert np.all(state[:, :, 1] == 0.0)            # the reset tenant
        assert np.all(np.delete(state, 1, axis=2) == 1.0)  # untouched
        idx = np.asarray(out["idx"])
        assert idx[1] == 0 and np.all(np.delete(idx, 1) == 7)

    def test_end_to_end_isolation_with_reuse(self):
        # 3 requests through 2 of 4 slots: re-admission must not leak the
        # previous tenant's state even with max_len == padded_slots.
        b = ContinuousBatcher(_AxisModel(), {}, slots=4, max_len=4)
        out = b.run([Request(rid=i, prompt=[i + 1], max_new_tokens=2)
                     for i in range(6)])
        for i in range(6):
            assert out[i] == [i + 1, i + 1]      # echo: prompt token twice


class TestRequestRegressions:
    def test_done_returns_bool(self):
        req = Request(rid=0, prompt=[1, 2], max_new_tokens=4)
        # Old bug: `generated and (...)` returned [] (the empty list) when
        # eos was configured and nothing was generated yet.
        assert req.done(3) is False
        assert req.done(None) is False
        req.generated = [3]
        assert req.done(3) is True
        req.generated = [9] * 4
        assert req.done(None) is True

    def test_empty_prompt_rejected_at_submit(self):
        b = ContinuousBatcher(_EchoModel(), {}, slots=1, max_len=8)
        with pytest.raises(ValueError, match="empty prompt"):
            b.submit([Request(rid=0, prompt=[], max_new_tokens=2)])
        # The queue stays clean: a later run() cannot trip over it.
        assert not b.busy

    def test_run_rejects_unknown_truncation_mode(self):
        b = ContinuousBatcher(_EchoModel(), {}, slots=1, max_len=8)
        with pytest.raises(ValueError, match="on_truncation"):
            b.run([], on_truncation="warn")


class TestTruncationRegression:
    def _reqs(self, n):
        return [Request(rid=i, prompt=[1, 2, 3], max_new_tokens=4)
                for i in range(n)]

    def test_run_raises_with_partial_results(self):
        b = ContinuousBatcher(_EchoModel(), {}, slots=1, max_len=16)
        with pytest.raises(TruncatedRun) as ei:
            b.run(self._reqs(3), max_ticks=8)
        # Slot capacity 1: rid 0 finishes (6 ticks), rid 1 is in flight,
        # rid 2 still queued -- all of that must be in the exception.
        assert sorted(ei.value.completed) == [0]
        assert sorted(r.rid for r in ei.value.abandoned) == [1, 2]

    def test_truncation_emits_abandonment_events(self):
        b = ContinuousBatcher(_EchoModel(), {}, slots=1, max_len=16)
        ring = obs.RingBufferSink()
        with obs.session(ring):
            with pytest.raises(TruncatedRun):
                b.run(self._reqs(3), max_ticks=8)
        evs = ring.events("request_abandoned")
        assert sorted(e.rid for e in evs) == [1, 2]
        stages = {e.rid: e.stage for e in evs}
        assert stages[2] == "queued" and stages[1] in ("prefill", "decode")

    def test_return_mode_is_opt_in_and_checkable(self):
        b = ContinuousBatcher(_EchoModel(), {}, slots=1, max_len=16)
        out = b.run(self._reqs(3), max_ticks=8, on_truncation="return")
        assert sorted(out) == [0]
        assert b.busy                       # caller can see the leftovers

    def test_complete_run_does_not_raise(self):
        b = ContinuousBatcher(_EchoModel(), {}, slots=2, max_len=16)
        out = b.run(self._reqs(2))
        assert sorted(out) == [0, 1]
        assert not b.busy
