"""repro.analyze: one positive and one negative test per rule family,
baseline gating, fingerprint stability, and the CLI exit-code contract.

Importing ``repro.analyze.fixtures`` registers the seeded-hazard kernels
(``hazard.*``) for the whole session; ``tests/test_golden_plans.py``
excludes them from the shipped surface by body-module prefix, and the
full-registry test below filters the ``hazard.`` prefix explicitly.
"""
import json
import os
import subprocess
import sys

import pytest

pytest.importorskip("jax")

from repro import api  # noqa: E402
from repro.analyze import engine, report  # noqa: E402
from repro.analyze import fixtures as fixtures_mod  # noqa: E402
from repro.analyze.__main__ import main  # noqa: E402
from repro.analyze.rules import check_stream_collision  # noqa: E402
from repro.api import registry  # noqa: E402
from repro.api.registry import register_kernel  # noqa: E402
from repro.api.spmd import consulted_operand_dims  # noqa: E402
from repro.core.aliasing import InterleavedMemoryModel  # noqa: E402
from repro.core.autotune import LayoutPlan, StreamSignature  # noqa: E402
from repro.core.layout import VMEM_BYTES  # noqa: E402
from repro.core.planner import (  # noqa: E402
    KernelPlan,
    plan_kernel,
    stream_stride_facts,
)
from repro.measure import profile as profile_lib  # noqa: E402

MODEL = InterleavedMemoryModel()
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
GOLDEN = os.path.join(REPO_ROOT, "tests", "golden", "plans.json")


# Two more analysis-only registrations for the REG004 positives (kept out
# of fixtures.py: a cell the planner *rejects* would fail the repo's own
# --fixture gate semantics, which seeds hazards the planner can plan).
# Their bodies live in this test module, so the golden-plan shipped filter
# never sees them.
register_kernel(
    "hazard.badcell",
    signature=StreamSignature(n_read=1, n_write=1),
    ref=lambda x: x,
    plan_args=lambda a, **kw: (tuple(a.shape), str(a.dtype)),
    analysis_cells=(((3, 3, 3), "float32"),),
)(lambda plan, *a, **kw: None)

register_kernel(
    "hazard.nocells",
    signature=StreamSignature(n_read=1, n_write=1),
    ref=lambda x: x,
    plan_args=lambda a, **kw: (tuple(a.shape), str(a.dtype)),
)(lambda plan, *a, **kw: None)


def ctx_for(*names, **kw):
    return engine.AnalysisContext([api.get_kernel(n) for n in names], **kw)


# ---------------------------------------------------------------------------
# ALIAS
# ---------------------------------------------------------------------------

class TestAliasing:
    def test_alias001_fires_on_pow2_stride_fixture(self):
        found = engine.run(ctx_for("hazard.pow2"), only=["ALIAS001"])
        assert [f.severity for f in found] == ["warning"]
        assert "(8, 8192)" in found[0].cell
        assert "power of two" in found[0].message

    def test_alias001_quiet_on_non_pow2_layouts(self):
        assert engine.run(ctx_for("jacobi", "rmsnorm"),
                          only=["ALIAS001"]) == []

    def test_alias002_fires_on_degenerate_layout(self):
        # Hand-built thrashing plan: three streams page-aligned to the same
        # controller, no segment shift -- the paper's offset-zero collapse.
        # The planner never emits this; the rule guards the launch path.
        sig = StreamSignature(n_read=2, n_write=1, elem_bytes=4)
        plan = KernelPlan(
            kernel="stream.add", logical_shape=(4096,), dtype="float32",
            padded_shape=(8, 512), block_shape=(8, 512), signature=sig,
            layout=LayoutPlan(align_bytes=MODEL.period_bytes,
                              offsets_bytes=(0, 0, 0),
                              segment_shift_bytes=0,
                              predicted_balance=1.0 / MODEL.n_channels),
            naive_balance=1.0 / MODEL.n_channels,
        )
        found = list(check_stream_collision(plan, MODEL))
        assert [f.severity for f in found] == ["error"]
        assert "thrash" in found[0].message

    def test_alias002_quiet_on_planned_skews(self):
        plan = plan_kernel("stream.add", (99999,), "float32")
        assert list(check_stream_collision(plan, MODEL)) == []
        facts = stream_stride_facts(plan, MODEL)
        assert facts["distinct_start_channels"] == min(
            facts["n_streams"], MODEL.n_channels)


# ---------------------------------------------------------------------------
# PAD
# ---------------------------------------------------------------------------

class TestPadding:
    def test_pad001_fires_on_tiny_stream_fixture(self):
        found = engine.run(ctx_for("hazard.pow2"), only=["PAD001"])
        assert any("(16,)" in f.cell for f in found)
        assert all(f.severity == "warning" for f in found)

    def test_pad001_quiet_within_budget(self):
        assert engine.run(ctx_for("rmsnorm", "xent"), only=["PAD001"]) == []

    def test_pad002_fires_on_sublane_override_regression(self):
        found = engine.run(ctx_for("hazard.pow2"), only=["PAD002"])
        assert [f.severity for f in found] == ["error"]
        assert "sublanes=32" in found[0].cell

    def test_pad002_quiet_on_native_narrow_plans(self):
        # The planner's narrow-dtype guarantee holds for every shipped
        # kernel, so the bf16 probes of their fp32 cells stay quiet.
        names = [k for k in api.list_kernels()
                 if not k.startswith("hazard.")]
        assert engine.run(ctx_for(*names), only=["PAD002"]) == []


# ---------------------------------------------------------------------------
# DRIFT
# ---------------------------------------------------------------------------

class TestDrift:
    def test_drift001_fires_on_mismatched_fixture(self):
        found = engine.run(ctx_for("hazard.drift"), only=["DRIFT001"])
        sev = {f.cell: f.severity for f in found}
        # declared vocab split never consulted -> warning; consulted
        # phantom operand 1 never declared -> error.
        assert sev == {"operand 0 dim 1": "warning",
                       "operand 1 dim 0": "error"}

    def test_drift001_quiet_on_jacobi(self):
        assert engine.run(ctx_for("jacobi"), only=["DRIFT001"]) == []

    def test_drift001_xent_known_finding_only(self):
        # xent's body consults the logits batch+vocab dims; the labels
        # operand's declared batch split is the one known (baselined) gap.
        found = engine.run(ctx_for("xent"), only=["DRIFT001"])
        assert [(f.cell, f.severity) for f in found] == [
            ("operand 1 dim 0", "warning")]

    def test_consulted_operand_dims_introspection(self):
        assert consulted_operand_dims(
            api.get_kernel("xent").spmd_body) == {(0, 0), (0, 1)}
        assert consulted_operand_dims(
            api.get_kernel("jacobi").spmd_body) == {(0, 0)}

        def kw_body(ctx, x):
            return ctx.axes(operand=1, dim=2)

        assert consulted_operand_dims(kw_body) == {(1, 2)}

        def dynamic_body(ctx, x, i):
            return ctx.axes(i, 0)

        assert consulted_operand_dims(dynamic_body) is None
        assert consulted_operand_dims(len) is None

    def test_drift002_fires_on_unpriced_spmd_body(self):
        found = engine.run(ctx_for("hazard.drift"), only=["DRIFT002"])
        assert [f.subject for f in found] == ["hazard.drift"]
        assert "COMM_MODEL" in found[0].message

    def test_drift002_quiet_on_priced_kernels(self):
        # Subset analysis must not flag the *other* priced kernels as dead:
        # analyzing only xent must not report jacobi's COMM_MODEL entry.
        assert engine.run(ctx_for("xent"), only=["DRIFT002"]) == []
        assert engine.run(ctx_for("jacobi"), only=["DRIFT002"]) == []


# ---------------------------------------------------------------------------
# CACHE
# ---------------------------------------------------------------------------

def _profile_entry(kernel="rmsnorm", shape=(64, 256), dtype="float32"):
    plan = plan_kernel(kernel, shape, dtype)
    return profile_lib.entry_from_plan(
        plan, {"sublanes": plan.sublanes, "vmem_budget": VMEM_BYTES})


class TestCacheHygiene:
    def test_clean_profile_is_quiet(self, tmp_path):
        p = str(tmp_path / "clean.json")
        profile_lib.save_profile(p, [_profile_entry()], backend="cpu")
        ctx = engine.AnalysisContext([], profile_paths=[p])
        assert engine.run(ctx, only=["CACHE001", "CACHE002"]) == []

    def test_cache001_orphan_override(self, tmp_path):
        entry = _profile_entry()
        entry["kernel"] = "gone.kernel"
        p = str(tmp_path / "orphan.json")
        profile_lib.save_profile(p, [entry], backend="cpu")
        found = engine.run(engine.AnalysisContext([], profile_paths=[p]),
                           only=["CACHE001"])
        assert [f.severity for f in found] == ["warning"]
        assert "gone.kernel" in found[0].cell

    def test_cache002_stale_override(self, tmp_path):
        entry = _profile_entry()
        entry["expect"]["padded_shape"] = [999, 999]
        p = str(tmp_path / "stale.json")
        profile_lib.save_profile(p, [entry], backend="cpu")
        found = engine.run(engine.AnalysisContext([], profile_paths=[p]),
                           only=["CACHE002"])
        assert [f.severity for f in found] == ["error"]
        assert "stale" in found[0].message
        # ...and a strict load of the same file fails at use time: the rule
        # surfaces exactly the failures load_profile would throw later.
        with pytest.raises(ValueError, match="planner drift"):
            profile_lib.load_profile(p)

    def test_cache002_invalid_override(self, tmp_path):
        entry = _profile_entry()
        entry["dtype"] = "float31"
        p = str(tmp_path / "invalid.json")
        profile_lib.save_profile(p, [entry], backend="cpu")
        found = engine.run(engine.AnalysisContext([], profile_paths=[p]),
                           only=["CACHE002"])
        assert [f.severity for f in found] == ["error"]
        assert "invalid" in found[0].message

    def test_audit_profile_reports_all_issues_at_once(self, tmp_path):
        good, orphan, stale = (_profile_entry() for _ in range(3))
        orphan["kernel"] = "gone.kernel"
        stale["expect"]["block_shape"] = [1, 1]
        p = str(tmp_path / "mixed.json")
        profile_lib.save_profile(p, [good, orphan, stale], backend="cpu")
        kinds = sorted(i["kind"] for i in profile_lib.audit_profile(p))
        assert kinds == ["orphan", "stale"]


# ---------------------------------------------------------------------------
# REG
# ---------------------------------------------------------------------------

class TestRegistryHygiene:
    def test_reg001_info_on_missing_partitioning(self):
        found = engine.run(ctx_for("hazard.pow2"), only=["REG001"])
        assert [f.severity for f in found] == ["info"]
        assert engine.run(ctx_for("xent", "lbm.soa"), only=["REG001"]) == []

    def test_reg002_missing_ref(self):
        found = engine.run(ctx_for("hazard.pow2"), only=["REG002"])
        assert [f.severity for f in found] == ["error"]
        assert engine.run(ctx_for("xent"), only=["REG002"]) == []

    def test_reg003_golden_coverage(self):
        found = engine.run(
            ctx_for("hazard.drift", golden_path=GOLDEN), only=["REG003"])
        assert [f.severity for f in found] == ["warning"]
        assert engine.run(ctx_for("stream.copy", golden_path=GOLDEN),
                          only=["REG003"]) == []
        # no golden file -> the rule cannot judge and stays silent
        missing = os.path.join(REPO_ROOT, "no-such-golden.json")
        assert engine.run(ctx_for("hazard.drift", golden_path=missing),
                          only=["REG003"]) == []

    def test_reg004_unplannable_cell_and_no_cells(self):
        found = engine.run(ctx_for("hazard.badcell"), only=["REG004"])
        assert [f.severity for f in found] == ["error"]
        assert "cannot be planned" in found[0].message
        found = engine.run(ctx_for("hazard.nocells"), only=["REG004"])
        assert [f.severity for f in found] == ["info"]
        assert engine.run(ctx_for("xent"), only=["REG004"]) == []


# ---------------------------------------------------------------------------
# Engine, baseline, fingerprints
# ---------------------------------------------------------------------------

class TestEngineAndBaseline:
    def test_real_registry_quiet_minus_committed_baseline(self):
        # The CI gate in miniature: the shipped registry against the
        # committed baseline produces zero NEW gating findings.
        shipped = [e for e in registry.entries()
                   if not e.name.startswith("hazard.")]
        ctx = engine.AnalysisContext(shipped, golden_path=GOLDEN)
        findings = engine.run(ctx)
        baseline = report.load_baseline(report.DEFAULT_BASELINE)
        new, _ = report.split_new(findings, baseline)
        assert new == [], [f.fingerprint for f in new]

    def test_fixtures_produce_new_findings(self):
        findings = engine.run(ctx_for(*fixtures_mod.FIXTURE_KERNELS))
        baseline = report.load_baseline(report.DEFAULT_BASELINE)
        new, _ = report.split_new(findings, baseline)
        assert new, "seeded hazards must gate"
        assert {f.rule for f in new} >= {"ALIAS001", "PAD001", "PAD002",
                                         "DRIFT001", "DRIFT002", "REG002"}

    def test_fingerprint_ignores_message_wording(self):
        a = engine.Finding(rule="X001", severity="error", subject="k",
                           cell="c", message="one wording")
        b = engine.Finding(rule="X001", severity="warning", subject="k",
                           cell="c", message="another wording")
        assert a.fingerprint == b.fingerprint
        assert a.gating and b.gating
        info = engine.Finding(rule="X001", severity="info", subject="k",
                              cell="c", message="advisory")
        assert not info.gating
        with pytest.raises(ValueError, match="severity"):
            engine.Finding(rule="X001", severity="fatal", subject="k",
                           cell="", message="")

    def test_baseline_roundtrip_and_info_excluded(self, tmp_path):
        p = str(tmp_path / "b.json")
        findings = [
            engine.Finding(rule="A", severity="error", subject="s",
                           cell="", message="m"),
            engine.Finding(rule="B", severity="info", subject="s",
                           cell="", message="m"),
        ]
        assert report.save_baseline(p, findings) == 1
        assert report.load_baseline(p) == {"A|s|"}

    def test_render_marks_baselined(self):
        f = engine.Finding(rule="A1", severity="warning", subject="s",
                           cell="c", message="m", hint="h")
        text = report.render_text([f], {f.fingerprint})
        assert "(baselined)" in text and "0 new" in text
        text = report.render_text([f], set())
        assert "1 new finding" in text

    def test_analysis_cells_knobs_reach_planner(self):
        ctx = ctx_for("hazard.pow2")
        cells = ctx.cells_for(api.get_kernel("hazard.pow2"))
        knobs = [k for _, _, k in cells if k]
        assert knobs == [{"sublanes": 32}]
        plan = ctx.plan("hazard.pow2", (8, 1111), "bfloat16",
                        {"sublanes": 32})
        assert plan.sublanes == 32


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

class TestCLI:
    def test_usage_errors(self, capsys):
        assert main([]) == 2
        assert main(["--kernel", "no.such.kernel"]) == 2
        capsys.readouterr()

    def test_clean_kernels_exit_zero(self, capsys):
        # xent's one DRIFT001 finding is in the committed baseline.
        assert main(["--kernel", "xent", "--kernel", "jacobi"]) == 0
        assert "0 new" in capsys.readouterr().out

    def test_hazard_kernel_exits_nonzero(self, capsys):
        assert main(["--kernel", "hazard.pow2", "--no-baseline"]) == 1
        assert "ALIAS001" in capsys.readouterr().out

    def test_update_baseline_blesses(self, tmp_path, capsys):
        p = str(tmp_path / "bless.json")
        assert main(["--kernel", "hazard.pow2", "--baseline", p,
                     "--update-baseline"]) == 0
        assert main(["--kernel", "hazard.pow2", "--baseline", p]) == 0
        assert "(baselined)" in capsys.readouterr().out

    def test_json_report_out(self, tmp_path, capsys):
        out_path = str(tmp_path / "report.json")
        assert main(["--kernel", "hazard.pow2", "--no-baseline",
                     "--format", "json", "--out", out_path]) == 1
        capsys.readouterr()
        with open(out_path) as f:
            doc = json.load(f)
        assert doc["new_count"] >= 1
        assert any(x["rule"] == "ALIAS001" for x in doc["findings"])

    @pytest.mark.slow
    def test_cli_subprocess_clean_repo(self):
        # The exact CI invocations, in a process with none of this module's
        # hazard registrations: the shipped registry vs the committed
        # baseline exits 0, and the fixture self-test exits 1.
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src")
        clean = subprocess.run(
            [sys.executable, "-m", "repro.analyze", "--all"],
            cwd=REPO_ROOT, env=env, capture_output=True, text=True,
            timeout=300,
        )
        assert clean.returncode == 0, clean.stdout + clean.stderr
        seeded = subprocess.run(
            [sys.executable, "-m", "repro.analyze", "--all", "--fixture"],
            cwd=REPO_ROOT, env=env, capture_output=True, text=True,
            timeout=300,
        )
        assert seeded.returncode == 1, seeded.stdout + seeded.stderr
