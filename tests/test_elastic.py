"""Elastic runtime: re-mesh on device loss, resume from checkpoint, parity.

The acceptance criterion for the elastic tentpole is *chaos parity*: a
run that loses devices at step k must resume on the shrunken mesh from
the latest complete checkpoint and produce **exactly** the loss
trajectory of an uninterrupted run on that same mesh -- no step lost, no
step duplicated (the data pipeline is a pure function of step, and a
dp-only shrink leaves the kernel plans' model-axis padding untouched, so
equality is exact, not approximate).

These tests run on one CPU device by using *placeholder* devices: the
runner then plans against an ``{axis: size}`` planning mesh -- identical
(dp, tp) arithmetic and plan-cache keying to a real ``jax.sharding
.Mesh``, without multi-device execution.  The real-mesh variant rides in
``tests/test_spmd_launch.py``'s multidevice job and the CI chaos job.
"""
from __future__ import annotations

import logging
from types import SimpleNamespace

import jax
import pytest

from repro import api, obs
from repro.core import planner
from repro.obs import report
from repro.runtime import elastic
from repro.runtime.elastic import ElasticRunner
from repro.runtime.faults import (
    CheckpointCrash,
    DeviceLoss,
    DeviceLossError,
    FaultPlan,
    Straggler,
    Transient,
)


def _fake_devices(n: int) -> list:
    return [SimpleNamespace(id=i) for i in range(n)]


def _make_factory(ckpt_dir: str, *, n_steps: int = 6, ckpt_every: int = 2,
                  d_model: int = 64):
    """Trainer factory for ElasticRunner: a fresh tiny Trainer planning
    against the mesh the runner hands it."""
    from repro.data.pipeline import DataConfig
    from repro.models import build_model
    from repro.models.config import ModelConfig
    from repro.optim import adamw
    from repro.optim.schedules import make_schedule
    from repro.runtime.trainer import Trainer, TrainerConfig

    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=d_model,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=32,
                      dtype="float32", remat=False)
    model = build_model(cfg)

    def make_trainer(mesh):
        return Trainer(
            model,
            DataConfig(vocab_size=32, seq_len=16, global_batch=4,
                       d_model=d_model),
            adamw.AdamWConfig(master=False),
            make_schedule("cosine", peak=3e-3, warmup=2, total=n_steps),
            TrainerConfig(n_steps=n_steps, ckpt_every=ckpt_every,
                          ckpt_dir=ckpt_dir, backoff_base_s=0.0),
            mesh=mesh)

    return make_trainer


class TestSurvivingMesh:
    def test_partial_tp_group_is_retired(self):
        """7 survivors with tp=2 -> a 3x2 mesh: the odd device out is
        retired (a partial TP group cannot hold a full weight shard)."""
        n = jax.device_count()
        plan = elastic.plan_mesh(7, tp=2)
        assert plan.shape == (3, 2) and plan.n_devices == 6
        if n >= 8:
            mesh = elastic.surviving_mesh(jax.devices(), {7}, tp=2)
            assert mesh.devices.shape == (3, 2)

    def test_surplus_devices_logged_once_and_reported(self, caplog):
        """Satellite: ``surviving_mesh`` used to silently drop survivors
        that don't fill the grid.  Now the retired ids are logged once
        and emitted as a DegradedEvent visible in the report."""
        elastic._warned_retired.clear()
        devices = _fake_devices(7)
        ring = obs.RingBufferSink(capacity=100)
        with caplog.at_level(logging.WARNING, logger="repro.elastic"):
            with obs.session(ring):
                r = ElasticRunner(lambda mesh: None, devices=devices, tp=2)
                r._build_mesh()
                r._build_mesh()     # same retirement: no second log line
        warns = [m for m in caplog.messages if "retiring" in m]
        assert len(warns) == 1
        assert "[6]" in warns[0]
        deg = ring.events("degraded")
        assert [e.reason for e in deg] == ["surplus_devices"] * 2
        assert "6" in deg[0].detail
        summary = report.aggregate([e.to_record() for e in deg])
        assert summary["elastic"]["degraded_reasons"] == {
            "surplus_devices": 2}

    def test_no_surplus_no_event(self):
        elastic._warned_retired.clear()
        ring = obs.RingBufferSink(capacity=100)
        with obs.session(ring):
            r = ElasticRunner(lambda mesh: None,
                              devices=_fake_devices(8), tp=2)
            r._build_mesh()
        assert not ring.events("degraded")


class TestPlanInvalidation:
    def test_invalidate_mesh_plans_drops_only_that_mesh(self):
        planner.clear_plan_cache()
        old = {"data": 4, "model": 1}
        new = {"data": 3, "model": 1}
        with api.plan_context(mesh=old):
            api.plan_for("rmsnorm", (64, 128), "float32")
        with api.plan_context(mesh=new):
            api.plan_for("rmsnorm", (64, 128), "float32")
        assert planner.invalidate_mesh_plans(old) == 1
        assert planner.invalidate_mesh_plans(old) == 0   # already gone
        with api.plan_context(mesh=new):                  # survivor: hit
            api.plan_for("rmsnorm", (64, 128), "float32")
        assert planner.invalidate_mesh_plans(new) == 1

    def test_invalidate_none_mesh_is_noop(self):
        planner.clear_plan_cache()
        api.plan_for("rmsnorm", (64, 128), "float32")     # mesh-free cell
        assert planner.invalidate_mesh_plans(None) == 0
        assert planner.plan_cache_info()["size"] == 1


class TestChaosParity:
    def test_device_loss_resumes_with_exact_parity(self, tmp_path):
        """The acceptance test: lose a device at step 3 of 6, re-mesh
        dp=4 -> dp=3, resume from the step-2 checkpoint, and match the
        uninterrupted dp=3 run's loss trajectory *exactly* -- every step
        present exactly once, with mesh-change and resume events on the
        bus."""
        key = jax.random.PRNGKey(0)
        ring = obs.RingBufferSink(capacity=10_000)
        with obs.session(ring):
            r = ElasticRunner(_make_factory(str(tmp_path / "chaos")),
                              devices=_fake_devices(4), tp=1)
            chaos = r.run(key, fault_plan=FaultPlan(
                (DeviceLoss(step=3, failed_ids=(3,)),)))
        assert r.remeshes == 1
        assert r.mesh == {"data": 3, "model": 1}
        assert r.batch_chunks == [2, 1, 1]

        base = ElasticRunner(_make_factory(str(tmp_path / "base")),
                             devices=_fake_devices(3), tp=1).run(key)
        # Exactly once per step, in order -- nothing lost, nothing
        # duplicated.
        assert [m["step"] for m in chaos] == list(range(6))
        assert [m["step"] for m in base] == list(range(6))
        # Replay is exact: bitwise-equal losses after the resume point
        # (and everywhere -- a dp-only shrink does not change numerics).
        for mc, mb in zip(chaos, base):
            assert mc["loss"] == mb["loss"], (mc, mb)

        changes = ring.events("mesh_change")
        assert len(changes) == 1
        assert changes[0].old_mesh == (("data", 4), ("model", 1))
        assert changes[0].new_mesh == (("data", 3), ("model", 1))
        assert changes[0].failed_ids == (3,) and changes[0].step == 3
        resumes = ring.events("resume")
        assert len(resumes) == 2                # initial start + re-mesh
        assert resumes[0].restored is False and resumes[0].step == 0
        assert resumes[1].restored is True and resumes[1].step == 2
        assert resumes[1].batch_chunks == (2, 1, 1)
        # The dead mesh's plan cells were invalidated.
        assert resumes[1].invalidated_plans >= 1
        # And the whole story is visible in the report.
        summary = report.aggregate(
            [e.to_record() for e in ring.events()])
        el = summary["elastic"]
        assert el["mesh_changes"] == 1
        assert el["last_mesh"] == "data=3,model=1"
        assert el["resumes"] == 2 and el["last_resume_step"] == 2
        text = report.render(summary)
        assert "elastic: 1 mesh change(s)" in text

    def test_compound_fault_storm_still_converges(self, tmp_path):
        """Transient + straggler + torn checkpoint + device loss in one
        run: every recovery path composes and the metrics stay exactly
        once per step."""
        key = jax.random.PRNGKey(1)
        r = ElasticRunner(_make_factory(str(tmp_path)),
                          devices=_fake_devices(4), tp=1)
        plan = FaultPlan((
            Transient(step=1),
            Straggler(step=2, delay_s=0.01),
            CheckpointCrash(step=4),
            DeviceLoss(step=3, failed_ids=(2,)),
        ))
        metrics = r.run(key, fault_plan=plan)
        assert [m["step"] for m in metrics] == list(range(6))
        assert r.remeshes == 1

    def test_repeated_losses_shrink_until_exhausted(self, tmp_path):
        key = jax.random.PRNGKey(2)
        r = ElasticRunner(_make_factory(str(tmp_path)),
                          devices=_fake_devices(3), tp=1, min_dp=1)
        plan = FaultPlan((
            DeviceLoss(step=2, failed_ids=(0,)),
            DeviceLoss(step=4, failed_ids=(1,)),
        ))
        metrics = r.run(key, fault_plan=plan)
        assert [m["step"] for m in metrics] == list(range(6))
        assert r.remeshes == 2
        assert r.mesh == {"data": 1, "model": 1}
        # Losing the last device is not survivable: plan_mesh raises.
        r2 = ElasticRunner(_make_factory(str(tmp_path / "dead")),
                           devices=_fake_devices(1), tp=1)
        with pytest.raises(DeviceLossError):
            r2.run(key, fault_plan=FaultPlan(
                (DeviceLoss(step=1, failed_ids=(0,)),)))

    def test_max_remesh_caps_thrashing(self, tmp_path):
        r = ElasticRunner(_make_factory(str(tmp_path)),
                          devices=_fake_devices(4), tp=1, max_remesh=0)
        with pytest.raises(DeviceLossError):
            r.run(jax.random.PRNGKey(0), fault_plan=FaultPlan(
                (DeviceLoss(step=2, failed_ids=(3,)),)))


class TestRealMesh:
    @pytest.mark.skipif(jax.device_count() < 8,
                        reason="needs >= 8 devices "
                               "(XLA_FLAGS=--xla_force_host_platform_"
                               "device_count=8)")
    def test_device_loss_on_real_mesh(self, tmp_path):
        """CI chaos-job variant: real jax devices, real
        ``jax.sharding.Mesh``, tp=2; losing one device retires its whole
        TP group (dp=4 -> dp=3)."""
        key = jax.random.PRNGKey(0)
        r = ElasticRunner(_make_factory(str(tmp_path)),
                          devices=jax.devices()[:8], tp=2)
        metrics = r.run(key, fault_plan=FaultPlan(
            (DeviceLoss(step=3, failed_ids=(5,)),)))
        assert [m["step"] for m in metrics] == list(range(6))
        assert isinstance(r.mesh, jax.sharding.Mesh)
        assert dict(zip(r.mesh.axis_names, r.mesh.devices.shape)) == {
            "data": 3, "model": 2}
        assert 5 not in {d.id for d in r.mesh.devices.ravel()}
