"""Pipeline parallelism: GPipe schedule == sequential application.

Runs in a subprocess with 4 forced host devices (mesh ("data","stage") =
(1,4)); the layer stack is a toy transformer-ish block so the test checks
the schedule, the ppermute wiring and stage splitting -- not model code.
"""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys, json
sys.path.insert(0, "src")
import jax, jax.numpy as jnp
import numpy as np
from repro.parallel.pipeline import pipeline_apply, split_stages, bubble_fraction
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh((1, 4), ("data", "stage"))
L, D, B = 8, 16, 12
key = jax.random.PRNGKey(0)
w = jax.random.normal(key, (L, D, D)) * 0.2
b = jax.random.normal(jax.random.PRNGKey(1), (L, D)) * 0.1
params = {"w": w, "b": b}
x = jax.random.normal(jax.random.PRNGKey(2), (B, D))

def layer_fn(p, h):
    def body(h, lp):
        return jnp.tanh(h @ lp[0] + lp[1]), None
    h, _ = jax.lax.scan(body, h, (p["w"], p["b"]))
    return h

# sequential reference
ref = layer_fn(params, x)

report = {}
for n_micro in (4, 6, 12):
    stage_params = split_stages(params, 4)
    got = pipeline_apply(layer_fn, stage_params, x, mesh=mesh,
                         n_micro=n_micro)
    err = float(jnp.max(jnp.abs(got - ref)))
    report[f"micro{n_micro}"] = err
    assert err < 1e-5, (n_micro, err)
report["bubble_4stage_12micro"] = bubble_fraction(4, 12)
print(json.dumps(report))
"""


@pytest.mark.slow
def test_gpipe_matches_sequential():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, timeout=540,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    report = json.loads(res.stdout.strip().splitlines()[-1])
    assert all(v < 1e-5 for k, v in report.items() if k.startswith("micro"))
    assert report["bubble_4stage_12micro"] == pytest.approx(3 / 15)


def test_bubble_fraction_math():
    from repro.parallel.pipeline import bubble_fraction

    assert bubble_fraction(1, 8) == 0.0
    assert bubble_fraction(16, 64) == pytest.approx(15 / 79)
