"""Fallback shim for ``hypothesis`` so the tier-1 suite collects offline.

When the real hypothesis package is installed it is re-exported unchanged.
When it is missing (this repo must run with no network access), a minimal
stand-in runs each property test over N deterministic pseudo-random examples
-- no shrinking, no database, just coverage of the same strategy space so
the invariants are still exercised.

Only the strategy surface the suite uses is implemented: ``integers``,
``lists``, ``sampled_from``.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import inspect
    import random

    _DEFAULT_EXAMPLES = 20

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: random.Random):
            return self._draw(rng)

    class strategies:  # noqa: N801 - mimics the hypothesis module name
        @staticmethod
        def integers(min_value=0, max_value=2 ** 31 - 1):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            return _Strategy(
                lambda rng: [
                    elements.example(rng)
                    for _ in range(rng.randint(min_size, max_size))
                ]
            )

    def settings(max_examples=_DEFAULT_EXAMPLES, **_ignored):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn

        return deco

    def given(*arg_strats, **kw_strats):
        """Run the test over deterministic examples.  Positional strategies
        bind to the test's trailing parameters (hypothesis semantics)."""

        def deco(fn):
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            bound = dict(kw_strats)
            if arg_strats:
                tail = [p.name for p in params[-len(arg_strats):]]
                bound.update(zip(tail, arg_strats))
            remaining = [p for p in params if p.name not in bound]

            def wrapper(*args, **kwargs):
                # @settings may sit inside @given (attribute on fn) or
                # outside it (attribute on this wrapper); honor both orders.
                n = getattr(
                    wrapper, "_shim_max_examples",
                    getattr(fn, "_shim_max_examples", _DEFAULT_EXAMPLES),
                )
                for i in range(n):
                    rng = random.Random(
                        f"{fn.__module__}.{fn.__qualname__}:{i}"
                    )
                    drawn = {k: s.example(rng) for k, s in bound.items()}
                    fn(*args, **kwargs, **drawn)

            # Hide the strategy-bound parameters from pytest's fixture
            # resolution; only e.g. ``self`` and real fixtures remain.
            wrapper.__signature__ = sig.replace(parameters=remaining)
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return deco


__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "strategies"]
