"""Paper-claim validation for the conflict model (SS2.1, Figs. 2/4) +
property-based invariants."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings
from _hypothesis_compat import strategies as st

from repro.core.aliasing import InterleavedMemoryModel, Stream, analytic_skews
from repro.core.autotune import StreamSignature, verify_plan_optimal

M = InterleavedMemoryModel()  # T2: 4 controllers, bits 8:7, 64 B lines


def triad_streams(offsets):
    return [
        Stream(base=o, kind=("write" if i == 0 else "read"))
        for i, o in enumerate(offsets)
    ]


class TestPaperClaims:
    def test_period_is_512_bytes(self):
        """Bits 8:7 -> 512 B interleave period (64 DP words)."""
        assert M.period_bytes == 512

    def test_zero_offset_collapses_to_quarter(self):
        """Fig. 2: all streams on one controller -> 1/4 of peak."""
        b = M.balance(triad_streams([0, 0, 0]))
        assert b == pytest.approx(0.25)

    def test_offset_periodicity_64_words(self):
        """Fig. 2: bandwidth vs offset repeats with period 64 DP words."""
        curve = M.stream_triad_curve(
            n_elements=2 ** 20, offsets=range(0, 129), n_threads=64
        )
        for off in range(0, 65):
            assert curve[off] == pytest.approx(curve[off + 64]), off

    def test_odd_32_improves_but_does_not_balance(self):
        """Fig. 2: odd multiples of 32 flip bit 8 for stream B -> two
        controllers addressed; improvement but below the skew envelope."""
        curve = M.stream_triad_curve(
            n_elements=2 ** 20, offsets=[0, 32, 16], n_threads=64
        )
        assert curve[32] > curve[0]
        assert curve[16] > curve[32]
        # the paper's own expectation metric: 2 controllers at offset 32
        ndim = (2 ** 20 + 32) * 8
        streams = [Stream(k * ndim, "write" if k == 0 else "read")
                   for k in range(3)]
        assert M.mean_channels_hit(streams) == pytest.approx(2.0)

    def test_analytic_skews_are_128_256_384(self):
        """SS2.2: optimal offsets for B, C, D are 128/256/384 bytes."""
        assert analytic_skews(M, 4) == [0, 128, 256, 384]

    def test_analytic_matches_exhaustive(self):
        """The 'no trial and error' claim: closed-form offsets reach the
        exhaustive-search optimum for 2..4 streams."""
        for n_streams in (2, 3, 4):
            plan, best = verify_plan_optimal(
                StreamSignature(n_read=n_streams - 1, n_write=1)
            )
            assert plan.predicted_balance == pytest.approx(best)

    def test_half_of_offsets_reach_envelope(self):
        """Fig. 2 observation: 'in an optimal way for only about half of
        all offsets'."""
        curve = M.stream_triad_curve(
            n_elements=2 ** 20, offsets=range(64), n_threads=64
        )
        vals = np.array(list(curve.values()))
        frac = (vals >= vals.max() - 1e-9).mean()
        assert 0.3 <= frac <= 0.7

    def test_rfo_makes_copy_slower_than_reads(self):
        """Fig. 2 upper panel: write-heavy kernels lose to read-heavy ones
        at equal stream counts (RFO doubles store traffic)."""
        reads = [Stream(k * 128, "read") for k in range(4)]
        mixed = [Stream(0, "write"), *[Stream(k * 128, "read")
                                       for k in range(1, 4)]]
        assert M.balance(mixed) < M.balance(reads)


class TestModelInvariants:
    @given(
        offsets=st.lists(st.integers(0, 4096), min_size=1, max_size=6),
        writes=st.integers(0, 5),
    )
    @settings(max_examples=50, deadline=None)
    def test_balance_in_unit_interval(self, offsets, writes):
        streams = [
            Stream(base=o * 8, kind=("write" if i < writes else "read"))
            for i, o in enumerate(offsets)
        ]
        b = M.balance(streams)
        assert 0.0 < b <= 1.0

    @given(base=st.integers(0, 10_000))
    @settings(max_examples=50, deadline=None)
    def test_single_stream_periodicity(self, base):
        s1 = [Stream(base=base)]
        s2 = [Stream(base=base + M.period_bytes)]
        assert M.balance(s1) == pytest.approx(M.balance(s2))

    @given(n=st.integers(1, 4))
    @settings(max_examples=10, deadline=None)
    def test_analytic_skews_balance_reads(self, n):
        """n skewed read streams occupy n distinct channels concurrently, so
        balance reaches its lockstep ceiling n / n_channels exactly."""
        offs = analytic_skews(M, n)
        streams = [Stream(base=o, kind="read") for o in offs]
        assert M.balance(streams) == pytest.approx(n / M.n_channels)


class TestBankLevel:
    """The paper's second interleave level: bit 6 selects the L2 bank."""

    def test_consecutive_lines_alternate_banks(self):
        # line L -> bank L % 2, controller (L >> 1) % 4 (bits 8:7)
        banks = [M.bank(line * 64) for line in range(8)]
        assert banks == [0, 1, 2, 3, 4, 5, 6, 7]  # full rotation per 512 B

    def test_bank_conflict_stricter_than_channel(self):
        """Streams 256 B apart share no controller conflict pattern with
        banks: two streams on the same controller but different banks are
        channel-conflicted yet bank-parallel."""
        s_same_bank = [Stream(0, "read"), Stream(512, "read")]
        s_same_chan = [Stream(0, "read"), Stream(64, "read")]
        assert M.bank_balance(s_same_bank) < M.bank_balance(s_same_chan)

    def test_bank_balance_bounds(self):
        one = [Stream(0, "read")]
        assert M.bank_balance(one) == pytest.approx(1 / 8)
        eight = [Stream(64 * k, "read") for k in range(8)]
        assert M.bank_balance(eight) == pytest.approx(1.0)
