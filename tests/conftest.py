"""Pytest config.  NOTE: deliberately does NOT set XLA_FLAGS -- smoke tests
and benches must see the real single CPU device; only launch/dryrun.py (and
the subprocess in test_dryrun_small) force 512/4 placeholder devices."""


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
