"""Pytest config.  NOTE: deliberately does NOT set XLA_FLAGS -- smoke tests
and benches must see the real single CPU device; only launch/dryrun.py (and
the subprocess in test_dryrun_small) force 512/4 placeholder devices."""


def pytest_addoption(parser):
    parser.addoption(
        "--update-golden", action="store_true", default=False,
        help="regenerate golden snapshots (tests/golden/) instead of "
             "comparing against them",
    )


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
