"""SPMD kernel launches: shard_map-partitioned registry kernels vs the jnp
reference on a forced multi-device host mesh.

The multi-device half of this file needs 8 CPU devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m pytest -q tests/test_spmd_launch.py

which is exactly what the CI ``multidevice`` job runs.  Under the normal
single-device tier-1 run those tests skip and only the gating/declaration
tests execute (conftest deliberately sets no XLA_FLAGS -- smoke tests must
see the real device).

What the mesh tests pin down, per the roadmap item this closes:

  * ``blocks.use_fused_kernels()`` is *true* on a 2x4 data/model mesh --
    multi-device programs no longer silently fall back to jnp;
  * rmsnorm / rmsnorm.gated / xent / stream.triad launched via
    ``api.launch`` match ``api.ref`` to fp32 tolerance, forward and (for
    the model-path kernels) through the ``custom_vjp`` backward;
  * each shard plans its own *local* block shape: the plan cache holds
    ``(kernel, local_shape, dtype, mesh, ..., local=True)`` entries, and
    the local plan's minor dim is not re-widened by the mesh's
    tensor-parallel axis;
  * non-divisible shards fall back to replication and stay correct.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.api import spmd
from repro.core.planner import clear_plan_cache, plan_cache_keys
from repro.models import blocks
from repro.models.config import ModelConfig
from repro.models.transformer import lm_loss

multidevice = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8",
)


def mesh_2x4():
    return jax.sharding.Mesh(
        np.asarray(jax.devices()[:8]).reshape(2, 4), ("data", "model")
    )


def rnd(shape, seed, dtype=jnp.float32):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
    return x.astype(dtype)


def local_keys(kernel):
    return [k for k in plan_cache_keys() if k[0] == kernel and k[-1] is True]


# ---------------------------------------------------------------------------
# Single-device: declarations and gating (run in tier-1 too)
# ---------------------------------------------------------------------------

class TestDeclarations:
    def test_every_registered_kernel_declares_partitioning(self):
        """Shipped kernels carry an explicit Partitioning -- replicated is a
        declaration too, the absence of one is only for third parties."""
        for name in api.list_kernels():
            entry = api.get_kernel(name)
            if not entry.body.__module__.startswith("repro."):
                continue
            assert isinstance(entry.partitioning, api.Partitioning), name

    def test_template_expansion(self):
        assert spmd._expand(("batch", ..., None), 2) == ("batch", None)
        assert spmd._expand(("batch", ..., None), 4) == (
            "batch", None, None, None)
        assert spmd._expand((...,), 3) == (None, None, None)
        assert spmd._expand(("batch",), 1) == ("batch",)
        with pytest.raises(ValueError, match="rank"):
            spmd._expand(("batch", ..., None), 1)
        with pytest.raises(ValueError, match="rank"):
            spmd._expand(("batch", None), 3)

    def test_scalar_out_requires_reduce(self):
        with pytest.raises(ValueError, match="cross-shard reduce"):
            api.Partitioning(in_axes=(("batch", None),), out_axes=spmd.SCALAR)
        with pytest.raises(ValueError, match="only applies to SCALAR"):
            api.Partitioning(in_axes=(("batch",),), out_axes=("batch",),
                             reduce="mean")
        with pytest.raises(ValueError, match="reduce must be one of"):
            api.Partitioning(in_axes=(("batch",),), out_axes=spmd.SCALAR,
                             reduce="max")

    def test_registry_rejects_non_partitioning(self):
        from repro.kernels.util import plan_args_1d

        with pytest.raises(TypeError, match="must be a"):
            @api.register_kernel(
                "stream.bad_part",
                signature=api.get_kernel("stream.copy").signature,
                ref=lambda a: a, plan_args=plan_args_1d,
                partitioning={"in_axes": ()})
            def _bad(plan, a):
                return a


class TestGating:
    """spmd_mesh() decides the route; every gate has a reason."""

    def test_no_context_mesh_means_no_spmd(self):
        assert spmd.spmd_mesh() is None

    def test_mapping_mesh_plans_but_does_not_place(self):
        with api.plan_context(mesh={"model": 4}):
            assert spmd.spmd_mesh() is None

    def test_single_device_mesh_is_not_spmd(self):
        mesh = jax.sharding.Mesh(
            np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
        with api.plan_context(mesh=mesh):
            assert spmd.spmd_mesh() is None

    def test_spmd_false_opts_out(self):
        mesh = jax.sharding.Mesh(
            np.asarray(jax.devices()).reshape(-1), ("data",))
        with api.plan_context(mesh=mesh, spmd=False):
            assert spmd.spmd_mesh() is None

    def test_use_fused_kernels_single_device(self):
        if jax.device_count() == 1:
            assert blocks.use_fused_kernels()
        else:
            assert not blocks.use_fused_kernels()


# ---------------------------------------------------------------------------
# Multi-device: the CI `multidevice` job's substance
# ---------------------------------------------------------------------------

@multidevice
class TestSpmdForward:
    def test_fused_gate_flips_on_mesh(self):
        mesh = mesh_2x4()
        assert not blocks.use_fused_kernels()   # 8 devices, no mesh
        with api.plan_context(mesh=mesh):
            assert spmd.spmd_mesh() is mesh
            assert blocks.use_fused_kernels()
        assert not blocks.use_fused_kernels()

    def test_rmsnorm_shard_map_parity_and_local_plan(self):
        mesh = mesh_2x4()
        x = rnd((8, 16, 64), 0)
        s = rnd((64,), 1) + 1.5
        clear_plan_cache()
        with api.plan_context(mesh=mesh):
            got = api.launch("rmsnorm", x, s, eps=1e-6)
        want = api.ref("rmsnorm", x, s, eps=1e-6)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-6)
        # per-shard plan: batch 8 split over data=2 -> local rows 4*16
        keys = local_keys("rmsnorm")
        assert any(k[1] == (64, 64) for k in keys), keys
        assert all(k[3] == (("data", 2), ("model", 4)) for k in keys)

    def test_local_plan_width_not_tp_widened(self):
        mesh = mesh_2x4()
        with api.plan_context(mesh=mesh):
            glob = api.plan_for("rmsnorm", (64, 129), jnp.float32)
            loc = api.plan_for("rmsnorm", (64, 129), jnp.float32, local=True)
        assert glob.width == 512     # round_up(129, 128 * tp=4)
        assert loc.width == 256      # round_up(129, 128): shard has no cut
        assert loc.width < glob.width

    def test_gated_rmsnorm_parity(self):
        mesh = mesh_2x4()
        x, z = rnd((6, 8, 129), 0), rnd((6, 8, 129), 1)
        s = rnd((129,), 2) + 1.0
        with api.plan_context(mesh=mesh):
            got = api.launch("rmsnorm.gated", x, z, s, eps=1e-6)
        want = api.ref("rmsnorm.gated", x, z, s, eps=1e-6)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-6)

    def test_xent_pmean_parity(self):
        mesh = mesh_2x4()
        logits = rnd((64, 1111), 0) * 3
        labels = jax.random.randint(jax.random.PRNGKey(1), (64,), 0, 1000)
        clear_plan_cache()
        with api.plan_context(mesh=mesh):
            got = api.launch("xent", logits, labels, logical_v=1000)
        want = api.ref("xent", logits, labels, logical_v=1000)
        np.testing.assert_allclose(float(got), float(want), rtol=1e-5)
        # tokens split over data=2, vocab whole per shard
        assert any(k[1] == (32, 1111) for k in local_keys("xent"))

    def test_stream_triad_sharded_vector(self):
        mesh = mesh_2x4()
        b, c = rnd((4096,), 0), rnd((4096,), 1)
        with api.plan_context(mesh=mesh):
            got = api.launch("stream.triad", b, c, s=3.0)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(api.ref("stream.triad", b, c,
                                                      s=3.0)),
                                   rtol=1e-6, atol=1e-6)

    def test_replicated_kernels_still_correct(self):
        """jacobi/LBM declare replicated: same result, one launch path."""
        mesh = mesh_2x4()
        g = rnd((20, 20), 0)
        from repro.kernels.lbm import ops as lops

        f = lops.init_equilibrium(6, jnp.float32)
        with api.plan_context(mesh=mesh):
            jac = api.launch("jacobi", g)
            lbm = api.launch("lbm.soa", f, omega=1.2)
        np.testing.assert_allclose(np.asarray(jac),
                                   np.asarray(api.ref("jacobi", g)),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(lbm),
                                   np.asarray(api.ref("lbm.soa", f,
                                                      omega=1.2)),
                                   rtol=1e-5, atol=1e-6)

    def test_non_divisible_batch_replicates_and_matches(self):
        """7 rows cannot split over data=2: the spec falls back to
        replication instead of producing ragged shards."""
        mesh = mesh_2x4()
        x = rnd((7, 129), 0)
        s = rnd((129,), 1) + 1.0
        with api.plan_context(mesh=mesh):
            got = api.launch("rmsnorm", x, s, eps=1e-6)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(api.ref("rmsnorm", x, s,
                                                      eps=1e-6)),
                                   rtol=2e-5, atol=2e-6)

    def test_pinned_plan_skips_spmd(self):
        """An explicit plan pins a single-device launch (the plan describes
        one global layout, not a per-shard one)."""
        mesh = mesh_2x4()
        b, c = rnd((1024,), 0), rnd((1024,), 1)
        with api.plan_context(mesh=mesh):
            plan = api.plan_for("stream.triad", (1024,), jnp.float32)
            got = api.launch("stream.triad", b, c, s=3.0, plan=plan)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(api.ref("stream.triad", b, c,
                                                      s=3.0)),
                                   rtol=1e-6, atol=1e-6)


@multidevice
class TestSpmdGradients:
    """custom_vjp backward through the shard_map forward (acceptance
    criterion: forward + gradient match jnp to fp32 tolerance)."""

    CFG = dict(name="t", family="dense", n_layers=1, d_model=64, n_heads=2,
               n_kv_heads=2, d_ff=64, vocab_size=128, dtype="float32",
               remat=False)

    def test_rms_fused_grads_match_ref(self):
        mesh = mesh_2x4()
        x = rnd((8, 16, 64), 0)
        s = rnd((64,), 1) + 1.5

        def fused(xx, ss):
            return blocks._rms_fused(xx, ss, 1e-6).astype(jnp.float32).sum()

        def ref(xx, ss):
            return blocks._rms_ref(xx, ss, 1e-6).astype(jnp.float32).sum()

        with api.plan_context(mesh=mesh):
            gx, gs = jax.grad(fused, argnums=(0, 1))(x, s)
        rx, rs = jax.grad(ref, argnums=(0, 1))(x, s)
        np.testing.assert_allclose(np.asarray(gx), np.asarray(rx),
                                   rtol=2e-5, atol=2e-6)
        np.testing.assert_allclose(np.asarray(gs), np.asarray(rs),
                                   rtol=2e-5, atol=2e-5)

    def test_lm_loss_fused_spmd_forward_and_grad(self):
        mesh = mesh_2x4()
        cfg = ModelConfig(**self.CFG)
        logits = rnd((4, 8, 128), 0) * 2
        labels = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, 128)

        with api.plan_context(mesh=mesh):
            assert blocks.use_fused_kernels()
            loss = lm_loss(logits, labels, cfg)
            grad = jax.grad(lambda l: lm_loss(l, labels, cfg))(logits)
        # same mesh, SPMD off: the pure-jnp vocab-parallel reference
        with api.plan_context(mesh=mesh, spmd=False):
            assert not blocks.use_fused_kernels()
            ref_loss = lm_loss(logits, labels, cfg)
            ref_grad = jax.grad(lambda l: lm_loss(l, labels, cfg))(logits)
        np.testing.assert_allclose(float(loss), float(ref_loss), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(grad), np.asarray(ref_grad),
                                   rtol=2e-5, atol=2e-6)

    def test_model_loss_end_to_end_jit(self):
        """Tiny dense LM: apply_norm + lm_loss both route through shard_map
        inside jit, value and every parameter gradient match the jnp path."""
        from repro.models import build_model

        mesh = mesh_2x4()
        model = build_model(ModelConfig(**self.CFG))
        params = model.init(jax.random.PRNGKey(0))
        batch = {
            "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0,
                                         128),
            "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 8), 0,
                                         128),
        }
        vg = jax.value_and_grad(model.loss)
        with api.plan_context(mesh=mesh):
            loss, grads = jax.jit(vg)(params, batch)
        with api.plan_context(mesh=mesh, spmd=False):
            ref_loss, ref_grads = jax.jit(vg)(params, batch)
        np.testing.assert_allclose(float(loss), float(ref_loss), rtol=1e-5)

        flat, _ = jax.tree_util.tree_flatten(grads)
        rflat, _ = jax.tree_util.tree_flatten(ref_grads)
        for g, r in zip(flat, rflat):
            if g.dtype == jax.dtypes.float0:
                continue
            np.testing.assert_allclose(np.asarray(g, np.float32),
                                       np.asarray(r, np.float32),
                                       rtol=5e-5, atol=5e-6)

    def test_trainer_hot_plans_under_spmd_mesh(self):
        """plan_hot_kernels still pins the global-shape plans (launch-time
        re-derivation inside shard_map uses the local ones)."""
        from repro.data.pipeline import DataConfig
        from repro.optim import adamw
        from repro.optim.schedules import make_schedule
        from repro.runtime.trainer import Trainer, TrainerConfig
        from repro.models import build_model

        mesh = mesh_2x4()
        tr = Trainer(
            build_model(ModelConfig(**self.CFG)),
            DataConfig(vocab_size=128, seq_len=8, global_batch=4, d_model=64),
            adamw.AdamWConfig(master=False),
            make_schedule("cosine", peak=3e-3, warmup=2, total=8),
            TrainerConfig(n_steps=2, ckpt_every=2, ckpt_dir="/tmp/t_spmd"),
            mesh=mesh,
        )
        plans = tr.plan_hot_kernels()
        assert plans["xent"].mesh == (("data", 2), ("model", 4))
