"""SPMD kernel launches: shard_map-partitioned registry kernels vs the jnp
reference on a forced multi-device host mesh.

The multi-device half of this file needs 8 CPU devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m pytest -q tests/test_spmd_launch.py

which is exactly what the CI ``multidevice`` job runs -- once per mesh in
its matrix, selected via ``REPRO_SPMD_MESH`` ("DxM" = data x model;
default 2x4, plus 8x1 pure-data and 1x8 pure-model legs).  Under the
normal single-device tier-1 run those tests skip and only the
gating/declaration/comm-model tests execute (conftest deliberately sets
no XLA_FLAGS -- smoke tests must see the real device).

What the mesh tests pin down:

  * ``blocks.use_fused_kernels()`` is *true* on a multi-device mesh --
    such programs no longer silently fall back to jnp;
  * rmsnorm / rmsnorm.gated / xent / stream.triad launched via
    ``api.launch`` match ``api.ref`` to fp32 tolerance, forward and (for
    the model-path kernels) through the ``custom_vjp`` backward;
  * xent is *vocab-parallel* (Megatron layout): divisible vocabs shard
    over the model axis with the cross-shard lse combine, non-divisible
    vocabs fall back to replication with a logged reason;
  * jacobi is *halo-exchange*: grid rows shard over the data axis with
    one-row ppermute halos, exact at every shard boundary;
  * LBM is halo-exchange too: the X axis shards over the data axis with
    *per-direction* halo depth (only the 2x5 D3Q19 directions with
    c_x != 0 travel), bit-exact vs the single-device step;
  * both stencil bodies are *overlapped* (docs/OVERLAP.md): the halo
    ppermutes are independent of the interior Pallas sweep in the jaxpr
    (``api.spmd.overlap_report``), and the planner's
    ``predicted_exposed_comm_bytes`` prices what stays on the critical
    path (``repro.measure.validate --comm --exposed``);
  * each shard plans its own *local* block shape, and the planner's
    ``predicted_comm_bytes`` matches the collective census of the lowered
    program (``repro.measure.validate --comm``).
"""
import logging
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.api import spmd
from repro.core.planner import clear_plan_cache, plan_cache_keys
from repro.models import blocks
from repro.models.config import ModelConfig
from repro.models.transformer import lm_loss

multidevice = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8",
)

MESH_SPEC = os.environ.get("REPRO_SPMD_MESH", "2x4")


def mesh_shape() -> tuple[int, int]:
    d, m = (int(x) for x in MESH_SPEC.lower().split("x"))
    return d, m


def make_mesh(d: int, m: int):
    return jax.sharding.Mesh(
        np.asarray(jax.devices()[:d * m]).reshape(d, m), ("data", "model")
    )


def env_mesh():
    """The matrix mesh this CI leg runs under (REPRO_SPMD_MESH)."""
    return make_mesh(*mesh_shape())


def mesh_key(mesh) -> tuple:
    return tuple(zip(tuple(mesh.axis_names), tuple(mesh.devices.shape)))


def rnd(shape, seed, dtype=jnp.float32):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
    return x.astype(dtype)


def local_keys(kernel):
    return [k for k in plan_cache_keys() if k[0] == kernel and k[-1] is True]


def shard_dim(n: int, k: int) -> int:
    """Per-shard extent after the divisibility fallback."""
    return n // k if n % k == 0 else n


# ---------------------------------------------------------------------------
# Single-device: declarations and gating (run in tier-1 too)
# ---------------------------------------------------------------------------

class TestDeclarations:
    def test_every_registered_kernel_declares_partitioning(self):
        """Shipped kernels carry an explicit Partitioning -- replicated is a
        declaration too, the absence of one is only for third parties."""
        for name in api.list_kernels():
            entry = api.get_kernel(name)
            # ad-hoc registrations and the repro.analyze hazard fixtures
            # (deliberately undeclared) are not shipped surface
            if (not entry.body.__module__.startswith("repro.")
                    or entry.body.__module__.startswith("repro.analyze.")):
                continue
            assert isinstance(entry.partitioning, api.Partitioning), name

    def test_xent_declares_vocab_parallel(self):
        """The Megatron layout is declared, not emergent: logits shard over
        (batch, vocab) and the kernel owns its shard body (lse combine)."""
        entry = api.get_kernel("xent")
        assert entry.partitioning.in_axes[0] == ("batch", "vocab")
        assert entry.spmd_body is not None

    def test_jacobi_declares_halo_exchange(self):
        entry = api.get_kernel("jacobi")
        assert entry.partitioning.in_axes[0] == ("batch", None)
        assert entry.partitioning.out_axes == ("batch", None)
        assert entry.spmd_body is not None

    def test_lbm_declares_halo_exchange(self):
        """Both LBM layouts shard the X axis and own their per-direction
        halo exchange (the lattice is no longer replicated)."""
        for name in ("lbm.soa", "lbm.ivjk"):
            entry = api.get_kernel(name)
            assert entry.spmd_body is not None, name
            assert entry.partitioning.in_axes[0] == (None, "batch", None,
                                                     None), name
            assert entry.partitioning.out_axes == (None, "batch", None,
                                                   None), name

    def test_lbm_directional_halo_depths(self):
        """D3Q19 splits 5/5/9 over c_x: only the +x / -x direction groups
        cross an X cut, so the halo slab is (5, 1, Y, Z) per side -- the
        per-direction depth the comm model prices."""
        from repro.kernels.lbm import ops as lops
        from repro.kernels.lbm import ref as lref

        assert len(lops._PLUS_X) == 5
        assert len(lops._MINUS_X) == 5
        assert len(lops._ZERO_X) == 9
        for v in lops._PLUS_X:
            assert int(lref.C[v][0]) == 1
        for v in lops._MINUS_X:
            assert int(lref.C[v][0]) == -1
        for v in lops._ZERO_X:
            assert int(lref.C[v][0]) == 0

    def test_template_expansion(self):
        assert spmd._expand(("batch", ..., None), 2) == ("batch", None)
        assert spmd._expand(("batch", ..., None), 4) == (
            "batch", None, None, None)
        assert spmd._expand((...,), 3) == (None, None, None)
        assert spmd._expand(("batch",), 1) == ("batch",)
        with pytest.raises(ValueError, match="rank"):
            spmd._expand(("batch", ..., None), 1)
        with pytest.raises(ValueError, match="rank"):
            spmd._expand(("batch", None), 3)

    def test_scalar_out_requires_reduce(self):
        with pytest.raises(ValueError, match="cross-shard reduce"):
            api.Partitioning(in_axes=(("batch", None),), out_axes=spmd.SCALAR)
        with pytest.raises(ValueError, match="only applies to SCALAR"):
            api.Partitioning(in_axes=(("batch",),), out_axes=("batch",),
                             reduce="mean")
        with pytest.raises(ValueError, match="reduce must be one of"):
            api.Partitioning(in_axes=(("batch",),), out_axes=spmd.SCALAR,
                             reduce="max")

    def test_registry_rejects_non_partitioning(self):
        from repro.kernels.util import plan_args_1d

        with pytest.raises(TypeError, match="must be a"):
            @api.register_kernel(
                "stream.bad_part",
                signature=api.get_kernel("stream.copy").signature,
                ref=lambda a: a, plan_args=plan_args_1d,
                partitioning={"in_axes": ()})
            def _bad(plan, a):
                return a

    def test_registry_rejects_orphan_spmd_body(self):
        from repro.kernels.util import plan_args_1d

        with pytest.raises(TypeError, match="spmd_body without"):
            @api.register_kernel(
                "stream.bad_spmd_body",
                signature=api.get_kernel("stream.copy").signature,
                ref=lambda a: a, plan_args=plan_args_1d,
                spmd_body=lambda ctx, a: a)
            def _bad(plan, a):
                return a


class TestGating:
    """spmd_mesh() decides the route; every gate has a reason."""

    def test_no_context_mesh_means_no_spmd(self):
        assert spmd.spmd_mesh() is None

    def test_mapping_mesh_plans_but_does_not_place(self):
        with api.plan_context(mesh={"model": 4}):
            assert spmd.spmd_mesh() is None

    def test_single_device_mesh_is_not_spmd(self):
        mesh = jax.sharding.Mesh(
            np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
        with api.plan_context(mesh=mesh):
            assert spmd.spmd_mesh() is None

    def test_spmd_false_opts_out(self):
        mesh = jax.sharding.Mesh(
            np.asarray(jax.devices()).reshape(-1), ("data",))
        with api.plan_context(mesh=mesh, spmd=False):
            assert spmd.spmd_mesh() is None

    def test_use_fused_kernels_single_device(self):
        if jax.device_count() == 1:
            assert blocks.use_fused_kernels()
        else:
            assert not blocks.use_fused_kernels()


class TestCommModel:
    """predicted_comm_bytes: the planner prices the SPMD collectives in
    closed form (ring cost model), no devices needed -- a mapping mesh is
    enough, which is also how the golden snapshots pin these numbers."""

    def test_xent_local_plan_prices_lse_combine(self):
        with api.plan_context(mesh={"data": 2, "model": 4}):
            p = api.plan_for("xent", (32, 512), jnp.float32, local=True)
        # pmax(m) + psum(l) + psum(ll): 3 x 32 fp32 over model=4, plus the
        # 4-byte scalar pmean over data=2, both at ring 2(N-1)/N.
        lse = int(2 * (4 - 1) / 4 * (3 * 32 * 4))
        scalar = int(2 * (2 - 1) / 2 * 4)
        assert p.predicted_comm_bytes == lse + scalar

    def test_jacobi_local_plan_prices_halo_rows(self):
        with api.plan_context(mesh={"data": 8}):
            p = api.plan_for("jacobi", (32, 258), jnp.float32, local=True)
        # one (1, 258) fp32 row ppermuted up and one down per sweep
        assert p.predicted_comm_bytes == 2 * 258 * 4

    def test_lbm_local_plan_prices_directional_halo(self):
        """Per-direction depth: only the 2x5 c_x != 0 directions cross an
        X cut, one (5, 1, Y, Z) slab each way -- not 19 full planes."""
        with api.plan_context(mesh={"data": 8}):
            ps = api.plan_for("lbm.soa", (19, 4, 8, 8), jnp.float32,
                              local=True)
            pi = api.plan_for("lbm.ivjk", (19, 4, 8, 8), jnp.float32,
                              local=True)
        assert ps.predicted_comm_bytes == 2 * 5 * 8 * 8 * 4
        assert pi.predicted_comm_bytes == ps.predicted_comm_bytes

    def test_unsharded_axes_price_zero(self):
        with api.plan_context(mesh={"data": 1, "model": 8}):
            p = api.plan_for("jacobi", (32, 258), jnp.float32, local=True)
            pl = api.plan_for("lbm.soa", (19, 32, 8, 8), jnp.float32,
                              local=True)
        assert p.predicted_comm_bytes == 0
        assert pl.predicted_comm_bytes == 0
        assert p.predicted_exposed_comm_bytes == 0

    def test_global_plans_price_zero(self):
        """A global plan describes the single-device direct path."""
        with api.plan_context(mesh={"data": 2, "model": 4}):
            p = api.plan_for("xent", (64, 512), jnp.float32)
        assert not p.local
        assert p.predicted_comm_bytes == 0

    def test_batch_parallel_families_price_zero(self):
        with api.plan_context(mesh={"data": 2, "model": 4}):
            p = api.plan_for("rmsnorm", (64, 129), jnp.float32, local=True)
        assert p.predicted_comm_bytes == 0

    def test_exposed_comm_partial_overlap(self):
        """Halo families subtract the interior hiding window: a thin
        jacobi stripe hides part of its two-row halo, the rest stays on
        the critical path."""
        from repro.core import planner

        with api.plan_context(mesh={"data": 8}):
            p = api.plan_for("jacobi", (8, 258), jnp.float32, local=True)
        total = 2 * 258 * 4
        assert p.predicted_comm_bytes == total
        # window = 2 streams x 6 interior rows x 258 cols x 4 B, hidden at
        # the ICI/HBM bandwidth ratio, never more than the total
        window = 2 * 6 * 258 * 4
        hidden = min(total, int(window * planner._ICI_BW / planner._HBM_BW))
        assert p.predicted_exposed_comm_bytes == total - hidden
        assert 0 < p.predicted_exposed_comm_bytes < total

    def test_exposed_comm_fully_hidden(self):
        """A tall stripe's interior window covers the whole halo: nothing
        stays exposed."""
        with api.plan_context(mesh={"data": 2}):
            p = api.plan_for("jacobi", (32, 258), jnp.float32, local=True)
        assert p.predicted_comm_bytes == 2 * 258 * 4
        assert p.predicted_exposed_comm_bytes == 0

    def test_exposed_comm_no_halo_model_is_fully_exposed(self):
        """Families without a HALO_MODEL entry (xent's lse combine has no
        interior stripe to hide behind) expose every wire byte."""
        with api.plan_context(mesh={"data": 2, "model": 4}):
            p = api.plan_for("xent", (32, 512), jnp.float32, local=True)
        assert p.predicted_comm_bytes > 0
        assert p.predicted_exposed_comm_bytes == p.predicted_comm_bytes

    def test_explain_reports_comm(self):
        with api.plan_context(mesh={"data": 2, "model": 4}):
            p = api.plan_for("xent", (32, 512), jnp.float32, local=True)
        txt = p.explain()
        assert f"comm {p.predicted_comm_bytes}B" in txt
        assert f"exposed {p.predicted_exposed_comm_bytes}B" in txt
        assert "local shard plan" in txt


class TestSpecReport:
    """rules.spec_report: the divisibility fallback comes with a reason."""

    def test_divisibility_fallback_is_reported(self):
        from repro.parallel import rules

        sizes = {"data": 2, "model": 4}
        s, fb = rules.spec_report("batch", "vocab", rules=rules.DEFAULT_RULES,
                                  shape=(64, 1111), axis_sizes=sizes)
        assert s == jax.sharding.PartitionSpec("data")
        assert len(fb) == 1
        assert "'vocab'" in fb[0] and "1111" in fb[0]
        assert "model" in fb[0]

    def test_clean_shard_reports_nothing(self):
        from repro.parallel import rules

        sizes = {"data": 2, "model": 4}
        s, fb = rules.spec_report("batch", "vocab", rules=rules.DEFAULT_RULES,
                                  shape=(64, 512), axis_sizes=sizes)
        assert s == jax.sharding.PartitionSpec("data", "model")
        assert fb == []


# ---------------------------------------------------------------------------
# Multi-device: the CI `multidevice` job's substance
# ---------------------------------------------------------------------------

@multidevice
class TestSpmdForward:
    def test_fused_gate_flips_on_mesh(self):
        mesh = env_mesh()
        assert not blocks.use_fused_kernels()   # 8 devices, no mesh
        with api.plan_context(mesh=mesh):
            assert spmd.spmd_mesh() is mesh
            assert blocks.use_fused_kernels()
        assert not blocks.use_fused_kernels()

    def test_rmsnorm_shard_map_parity_and_local_plan(self):
        mesh = env_mesh()
        d, _ = mesh_shape()
        x = rnd((8, 16, 64), 0)
        s = rnd((64,), 1) + 1.5
        clear_plan_cache()
        with api.plan_context(mesh=mesh):
            got = api.launch("rmsnorm", x, s, eps=1e-6)
        want = api.ref("rmsnorm", x, s, eps=1e-6)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-6)
        # per-shard plan: batch 8 split over the data axis
        keys = local_keys("rmsnorm")
        assert any(k[1] == (shard_dim(8, d) * 16, 64) for k in keys), keys
        assert all(k[3] == mesh_key(mesh) for k in keys)

    def test_local_plan_width_not_tp_widened(self):
        mesh = make_mesh(2, 4)
        with api.plan_context(mesh=mesh):
            glob = api.plan_for("rmsnorm", (64, 129), jnp.float32)
            loc = api.plan_for("rmsnorm", (64, 129), jnp.float32, local=True)
        assert glob.width == 512     # round_up(129, 128 * tp=4)
        assert loc.width == 256      # round_up(129, 128): shard has no cut
        assert loc.width < glob.width

    def test_gated_rmsnorm_parity(self):
        mesh = env_mesh()
        x, z = rnd((6, 8, 129), 0), rnd((6, 8, 129), 1)
        s = rnd((129,), 2) + 1.0
        with api.plan_context(mesh=mesh):
            got = api.launch("rmsnorm.gated", x, z, s, eps=1e-6)
        want = api.ref("rmsnorm.gated", x, z, s, eps=1e-6)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-6)

    def test_xent_parity_and_local_plan(self):
        """Non-divisible vocab (1111): the vocab split falls back to
        replication, tokens still shard, result still exact."""
        mesh = env_mesh()
        d, _ = mesh_shape()
        logits = rnd((64, 1111), 0) * 3
        labels = jax.random.randint(jax.random.PRNGKey(1), (64,), 0, 1000)
        clear_plan_cache()
        with api.plan_context(mesh=mesh):
            got = api.launch("xent", logits, labels, logical_v=1000)
        want = api.ref("xent", logits, labels, logical_v=1000)
        np.testing.assert_allclose(float(got), float(want), rtol=1e-5)
        # tokens split over the data axis, vocab whole per shard
        assert any(k[1] == (shard_dim(64, d), 1111)
                   for k in local_keys("xent"))

    def test_stream_triad_sharded_vector(self):
        mesh = env_mesh()
        b, c = rnd((4096,), 0), rnd((4096,), 1)
        with api.plan_context(mesh=mesh):
            got = api.launch("stream.triad", b, c, s=3.0)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(api.ref("stream.triad", b, c,
                                                      s=3.0)),
                                   rtol=1e-6, atol=1e-6)

    def test_lbm_sharded_launch_matches_ref(self):
        """LBM through the sharded halo-exchange path (or its divisibility
        fallback, mesh-dependent) still matches the jnp reference."""
        mesh = env_mesh()
        from repro.kernels.lbm import ops as lops

        f = lops.init_equilibrium(6, jnp.float32)
        with api.plan_context(mesh=mesh):
            lbm = api.launch("lbm.soa", f, omega=1.2)
        np.testing.assert_allclose(np.asarray(lbm),
                                   np.asarray(api.ref("lbm.soa", f,
                                                      omega=1.2)),
                                   rtol=1e-5, atol=1e-6)

    def test_non_divisible_batch_replicates_and_matches(self):
        """7 rows cannot split over the data axis: the spec falls back to
        replication instead of producing ragged shards."""
        mesh = env_mesh()
        x = rnd((7, 129), 0)
        s = rnd((129,), 1) + 1.0
        with api.plan_context(mesh=mesh):
            got = api.launch("rmsnorm", x, s, eps=1e-6)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(api.ref("rmsnorm", x, s,
                                                      eps=1e-6)),
                                   rtol=2e-5, atol=2e-6)

    def test_pinned_plan_skips_spmd(self):
        """An explicit plan pins a single-device launch (the plan describes
        one global layout, not a per-shard one)."""
        mesh = env_mesh()
        b, c = rnd((1024,), 0), rnd((1024,), 1)
        with api.plan_context(mesh=mesh):
            plan = api.plan_for("stream.triad", (1024,), jnp.float32)
            got = api.launch("stream.triad", b, c, s=3.0, plan=plan)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(api.ref("stream.triad", b, c,
                                                      s=3.0)),
                                   rtol=1e-6, atol=1e-6)

    def test_override_warning_names_cell_and_dedupes_per_mesh(self):
        """The SPMD-shadowed-override warning carries the offending cell
        key and a docs pointer, once per (kernel, mesh) -- a second mesh
        re-warns, a second launch on the same mesh does not."""
        from repro.api import dispatch

        b, c = rnd((1024,), 0), rnd((1024,), 1)
        plan = api.plan_for("stream.triad", (1024,), jnp.float32)
        dispatch._SPMD_OVERRIDE_WARNED.clear()
        with api.plan_context(mesh=env_mesh(),
                              plan_overrides={"stream.triad": plan}):
            with pytest.warns(RuntimeWarning) as rec:
                api.launch("stream.triad", b, c, s=3.0)
            assert "stream.triad" in str(rec[0].message)
            assert "docs/SPMD.md" in str(rec[0].message)
            with warnings.catch_warnings():
                warnings.simplefilter("error")  # same mesh: no re-warn
                api.launch("stream.triad", b, c, s=3.0)
        other = make_mesh(*reversed(mesh_shape()))
        with api.plan_context(mesh=other,
                              plan_overrides={"stream.triad": plan}):
            with pytest.warns(RuntimeWarning):
                api.launch("stream.triad", b, c, s=3.0)

    def test_local_keyed_override_does_not_warn(self):
        """A cell keyed at the per-shard *local* shape is the documented
        SPMD sweep workflow: it applies inside the shard body and must not
        be flagged as shadowed."""
        from repro.api import dispatch

        mesh = make_mesh(2, 4)  # data axis > 1 so local != global
        b, c = rnd((1024,), 0), rnd((1024,), 1)
        with api.plan_context(mesh=mesh):
            local = api.plan_for("stream.triad", (512,), jnp.float32,
                                 local=True)
        cell = ("stream.triad", (512,), "float32")
        dispatch._SPMD_OVERRIDE_WARNED.clear()
        with api.plan_context(mesh=mesh, plan_overrides={cell: local}):
            with warnings.catch_warnings():
                warnings.simplefilter("error")
                api.launch("stream.triad", b, c, s=3.0)


@multidevice
class TestVocabParallelXent:
    """The Megatron layout under shard_map: vocab shards over the model
    axis, the lse combine crosses shards, forward and backward."""

    def test_pure_model_mesh_vocab_sharded(self):
        """8-way model-parallel: logits vocab-sharded in the shard body (no
        full-vocab replication), fp32 parity vs the jnp reference."""
        mesh = make_mesh(1, 8)
        logits = rnd((64, 4096), 0) * 3
        labels = jax.random.randint(jax.random.PRNGKey(1), (64,), 0, 4000)
        clear_plan_cache()
        with api.plan_context(mesh=mesh):
            got = api.launch("xent", logits, labels, logical_v=4000)
        want = api.ref("xent", logits, labels, logical_v=4000)
        np.testing.assert_allclose(float(got), float(want), rtol=1e-5)
        # the shard body planned on the (64, 512) vocab shard -- the whole
        # point: no local plan at the full 4096 vocab exists
        keys = local_keys("xent")
        assert any(k[1] == (64, 512) for k in keys), keys
        assert not any(k[1] == (64, 4096) for k in keys), keys

    def test_env_mesh_vocab_sharded_with_logical_v(self):
        """On the matrix mesh: divisible vocab shards over whatever model
        axis the leg has; logical_v masking crosses shard boundaries."""
        mesh = env_mesh()
        d, m = mesh_shape()
        logits = rnd((64, 512), 0) * 3
        labels = jax.random.randint(jax.random.PRNGKey(1), (64,), 0, 500)
        clear_plan_cache()
        with api.plan_context(mesh=mesh):
            got = api.launch("xent", logits, labels, logical_v=500)
        want = api.ref("xent", logits, labels, logical_v=500)
        np.testing.assert_allclose(float(got), float(want), rtol=1e-5)
        assert any(k[1] == (shard_dim(64, d), shard_dim(512, m))
                   for k in local_keys("xent"))

    def test_small_vocab_shard_narrower_than_lane_tile(self):
        """A 32-wide vocab shard pads to the 128-lane tile; padded local
        columns alias other shards' label ranges and must stay masked."""
        mesh = make_mesh(1, 8)
        logits = rnd((32, 256), 0) * 2
        labels = jax.random.randint(jax.random.PRNGKey(1), (32,), 0, 256)
        with api.plan_context(mesh=mesh):
            got = api.launch("xent", logits, labels, logical_v=256)
        want = api.ref("xent", logits, labels, logical_v=256)
        np.testing.assert_allclose(float(got), float(want), rtol=1e-5)

    def test_non_divisible_vocab_falls_back_with_logged_reason(self, caplog):
        mesh = make_mesh(1, 8)
        logits = rnd((16, 1111), 0) * 3
        labels = jax.random.randint(jax.random.PRNGKey(1), (16,), 0, 1000)
        spmd._FALLBACK_LOGGED.clear()
        with caplog.at_level(logging.INFO, logger="repro.api.spmd"):
            with api.plan_context(mesh=mesh):
                got = api.launch("xent", logits, labels, logical_v=1000)
        want = api.ref("xent", logits, labels, logical_v=1000)
        np.testing.assert_allclose(float(got), float(want), rtol=1e-5)
        msgs = [r.getMessage() for r in caplog.records]
        assert any("'vocab'" in m and "1111" in m and "xent" in m
                   for m in msgs), msgs

    def test_xent_grad_vocab_parallel_matches_jnp(self):
        from repro.kernels.xent import ops as xent_ops

        mesh = make_mesh(1, 8)
        logits = rnd((64, 512), 0) * 3
        labels = jax.random.randint(jax.random.PRNGKey(1), (64,), 0, 500)
        with api.plan_context(mesh=mesh):
            d = xent_ops.xent_grad(logits, labels, jnp.float32(1.0),
                                   logical_v=500)
        _, vjp = jax.vjp(
            lambda l: api.ref("xent", l, labels, logical_v=500), logits)
        np.testing.assert_allclose(np.asarray(d), np.asarray(vjp(
            jnp.float32(1.0))[0]), rtol=2e-5, atol=2e-6)


@multidevice
class TestHaloJacobi:
    """Row-block jacobi with one-row ppermute halos: exact at every shard
    boundary, multi-sweep stable, non-divisible rows fall back."""

    def test_pure_data_mesh_eight_shards(self):
        mesh = make_mesh(8, 1)
        g = rnd((64, 34), 0)
        clear_plan_cache()
        with api.plan_context(mesh=mesh):
            got = api.launch("jacobi", g)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(api.ref("jacobi", g)),
                                   rtol=1e-5, atol=1e-6)
        # the shard body planned on its 8-row stripe, not the full grid
        assert any(k[1] == (8, 34) for k in local_keys("jacobi"))

    def test_shard_boundary_rows_exact(self):
        """The halo rows are the whole point: check the rows adjacent to
        every shard cut bitwise-closely against the reference."""
        mesh = make_mesh(8, 1)
        g = rnd((64, 34), 3)
        with api.plan_context(mesh=mesh):
            got = np.asarray(api.launch("jacobi", g))
        want = np.asarray(api.ref("jacobi", g))
        nl = 64 // 8
        for cut in range(nl, 64, nl):
            np.testing.assert_allclose(got[cut - 1:cut + 1],
                                       want[cut - 1:cut + 1],
                                       rtol=1e-6, atol=1e-7)

    def test_env_mesh_multi_sweep(self):
        mesh = env_mesh()
        g = rnd((64, 37), 1)
        ref_g = g
        with api.plan_context(mesh=mesh):
            out = g
            for _ in range(3):
                out = api.launch("jacobi", out)
        for _ in range(3):
            ref_g = api.ref("jacobi", ref_g)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref_g),
                                   rtol=1e-5, atol=1e-6)

    def test_non_divisible_rows_fall_back_with_logged_reason(self, caplog):
        mesh = make_mesh(8, 1)
        g = rnd((65, 34), 2)
        spmd._FALLBACK_LOGGED.clear()
        with caplog.at_level(logging.INFO, logger="repro.api.spmd"):
            with api.plan_context(mesh=mesh):
                got = api.launch("jacobi", g)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(api.ref("jacobi", g)),
                                   rtol=1e-5, atol=1e-6)
        msgs = [r.getMessage() for r in caplog.records]
        assert any("jacobi" in m and "65" in m for m in msgs), msgs


@multidevice
class TestCommValidation:
    """measure/validate --comm: the planner's predicted_comm_bytes vs the
    collective census of the lowered shard_map program."""

    def test_all_families_within_envelope_on_env_mesh(self):
        from repro.measure import validate as validate_lib

        mesh = env_mesh()
        records = validate_lib.validate_comm(mesh)
        assert {r["kernel"] for r in records} == {
            "jacobi", "xent", "lbm.soa", "lbm.ivjk"}
        for r in records:
            assert r["status"] == "ok", r

    def test_exposed_records_within_envelope_on_env_mesh(self):
        """validate --comm --exposed: one exposed_comm record per comm
        kernel, every halo collective structured as overlappable, wire
        bytes left on the critical path within the envelope."""
        from repro.measure import validate as validate_lib

        mesh = env_mesh()
        records = validate_lib.validate_comm(mesh, exposed=True)
        exposed = [r for r in records if r["check"] == "exposed_comm"]
        assert {r["kernel"] for r in exposed} == {
            "jacobi", "xent", "lbm.soa", "lbm.ivjk"}
        for r in records:
            assert r["status"] == "ok", r
        for r in exposed:
            assert r["structure_ok"], r
            if r["kernel"] != "xent" and r["predicted"]["comm_bytes"]:
                # halo families: every collective independent of the
                # interior sweep
                assert all(c["overlappable"]
                           for c in r["measured"]["collectives"]), r

    def test_vocab_parallel_mesh_prices_lse_payload(self):
        from repro.measure import validate as validate_lib

        rec = validate_lib.validate_comm_kernel("xent", make_mesh(1, 8))
        assert rec["status"] == "ok", rec
        assert rec["predicted"]["comm_bytes"] > 0
        # 3 token-length fp32 vectors at ring cost over model=8
        assert rec["predicted"]["comm_bytes"] == int(2 * 7 / 8 * 3 * 64 * 4)

    def test_halo_mesh_prices_two_rows(self):
        from repro.measure import validate as validate_lib

        rec = validate_lib.validate_comm_kernel("jacobi", make_mesh(8, 1))
        assert rec["status"] == "ok", rec
        assert rec["predicted"]["comm_bytes"] == 2 * 258 * 4

    def test_lbm_halo_mesh_prices_directional_slabs(self):
        from repro.measure import validate as validate_lib

        rec = validate_lib.validate_comm_kernel("lbm.soa", make_mesh(8, 1))
        assert rec["status"] == "ok", rec
        # two (5, 1, 8, 8) fp32 slabs per step
        assert rec["predicted"]["comm_bytes"] == 2 * 5 * 8 * 8 * 4

    def test_exposed_comm_event_streams(self):
        """The exposed_comm ValidationEvent carries the record's numbers
        (the obs half of validate --comm --exposed)."""
        from repro import obs
        from repro.measure import validate as validate_lib

        ring = obs.RingBufferSink()
        with obs.session(ring):
            rec = validate_lib.validate_exposed_kernel(
                "jacobi", make_mesh(8, 1))
        (ev,) = ring.events("validation")
        assert ev.kernel == "jacobi"
        assert ev.check == "exposed_comm"
        assert ev.predicted_bytes == float(
            rec["predicted"]["exposed_comm_bytes"])
        assert ev.measured_bytes == float(
            rec["measured"]["exposed_wire_bytes"])
        assert ev.status == rec["status"] == "ok"


@multidevice
class TestOverlapStructure:
    """api.spmd.overlap_report: the jaxpr-level classifier behind
    validate --exposed.  The overlapped shard bodies keep their halo
    collectives independent of the interior Pallas sweep; the PR-5
    exchange-then-compute shape (kept as ``_spmd_jacobi_blocking``) is the
    blocking counter-example."""

    def test_overlapped_jacobi_collectives_are_overlappable(self):
        mesh = make_mesh(8, 1)
        src = jnp.zeros((64, 34), jnp.float32)
        with api.plan_context(mesh=mesh):
            rep = spmd.overlap_report(
                lambda a: api.launch("jacobi", a), src)
        assert rep.n_pallas_calls >= 1
        assert len(rep.collectives) == 2            # one ppermute each way
        assert rep.all_overlappable
        for c in rep.collectives:
            assert c.primitive == "ppermute"
            assert c.result_bytes == 34 * 4         # one local row

    def test_blocking_body_is_classified_blocking(self):
        import dataclasses

        from repro.kernels.jacobi import ops as jops

        mesh = make_mesh(8, 1)
        src = jnp.zeros((64, 34), jnp.float32)
        entry = api.get_kernel("jacobi")
        blocking = dataclasses.replace(
            entry, spmd_body=jops._spmd_jacobi_blocking)
        with api.plan_context(mesh=mesh):
            rep = spmd.overlap_report(
                lambda a: spmd.spmd_launch(blocking, mesh, (a,), {}), src)
        assert rep.n_pallas_calls >= 1
        assert len(rep.collectives) == 2
        assert not rep.all_overlappable
        assert rep.n_overlappable == 0

    def test_lbm_halo_slabs_are_overlappable_and_directional(self):
        mesh = make_mesh(8, 1)
        f = jnp.zeros((19, 32, 8, 8), jnp.float32)
        for kernel in ("lbm.soa", "lbm.ivjk"):
            with api.plan_context(mesh=mesh):
                rep = spmd.overlap_report(
                    lambda a: api.launch(kernel, a, omega=1.7), f)
            assert rep.all_overlappable, kernel
            assert len(rep.collectives) == 2, kernel
            for c in rep.collectives:
                # (5, 1, 8, 8) fp32: five directions, depth one -- the
                # per-direction payload, not 19 full planes
                assert c.result_bytes == 5 * 8 * 8 * 4

    def test_xent_lse_combine_is_blocking(self):
        """No interior stripe to hide behind: the lse combine collectives
        stay on the critical path, matching the planner's fully-exposed
        pricing for families without a HALO_MODEL entry."""
        mesh = make_mesh(1, 8)
        logits = jnp.zeros((64, 4096), jnp.float32)
        labels = jnp.zeros((64,), jnp.int32)
        with api.plan_context(mesh=mesh):
            rep = spmd.overlap_report(
                lambda lg, tg: api.launch("xent", lg, tg), logits, labels)
        assert rep.collectives
        assert rep.n_overlappable == 0


@multidevice
class TestHaloLbm:
    """X-sharded LBM with per-direction ppermute halos: bit-exact vs the
    single-device Pallas step at every shard cut (the overlap criterion),
    periodic wrap included."""

    @staticmethod
    def _single_device(layout, f, omega, mask=None):
        from repro.kernels.lbm import ops as lops

        step = lops._step_soa if layout == "soa" else lops._step_ivjk
        plan = api.plan_for(f"lbm.{layout}", tuple(f.shape), f.dtype)
        return step(f, omega=omega, mask=mask, plan=plan)

    @pytest.mark.parametrize("layout", ["soa", "ivjk"])
    def test_pure_data_mesh_bit_exact(self, layout):
        mesh = make_mesh(8, 1)
        f = rnd((19, 32, 8, 8), 0)
        clear_plan_cache()
        with api.plan_context(mesh=mesh):
            got = api.launch(f"lbm.{layout}", f, omega=1.7)
        want = self._single_device(layout, f, 1.7)
        assert jnp.array_equal(got, want), (
            f"lbm.{layout} sharded step differs from single-device")
        # the shard body planned its local *interior* slab (XL=4 stripe
        # minus the two boundary planes), not the full lattice
        assert any(k[1] == (19, 2, 8, 8)
                   for k in local_keys(f"lbm.{layout}")), (
            local_keys(f"lbm.{layout}"))
        assert not any(k[1] == (19, 32, 8, 8)
                       for k in local_keys(f"lbm.{layout}"))

    @pytest.mark.parametrize("layout", ["soa", "ivjk"])
    def test_env_mesh_bit_exact(self, layout):
        mesh = env_mesh()
        f = rnd((19, 32, 8, 8), 1)
        with api.plan_context(mesh=mesh):
            got = api.launch(f"lbm.{layout}", f, omega=1.2)
        want = self._single_device(layout, f, 1.2)
        assert jnp.array_equal(got, want)

    def test_degenerate_two_plane_shards_bit_exact(self):
        """XL == 2: every plane is a boundary plane, nothing interior."""
        mesh = make_mesh(8, 1)
        f = rnd((19, 16, 4, 4), 2)
        with api.plan_context(mesh=mesh):
            got = api.launch("lbm.soa", f, omega=1.7)
        want = self._single_device("soa", f, 1.7)
        assert jnp.array_equal(got, want)

    def test_masked_launch_bit_exact(self):
        """The obstacle mask is a replicated scalar operand: each shard
        slices its own X window, masked sites keep pre-collision values."""
        mesh = make_mesh(8, 1)
        f = rnd((19, 32, 8, 8), 3)
        mask = jax.random.bernoulli(
            jax.random.PRNGKey(4), 0.7, (32, 8, 8))
        with api.plan_context(mesh=mesh):
            got = api.launch("lbm.soa", f, omega=1.7, mask=mask)
        want = self._single_device("soa", f, 1.7, mask=mask)
        assert jnp.array_equal(got, want)

    def test_periodic_wrap_crosses_domain_edge(self):
        """Pull-scheme streaming is periodic: shard 0's low halo is the
        *last* shard's high boundary (unlike jacobi's zero edges).  A
        lattice with a marked plane at x=31 must land at x=0 after one
        step in the +x directions."""
        from repro.kernels.lbm import ops as lops
        from repro.kernels.lbm import ref as lref

        mesh = make_mesh(8, 1)
        # uniform rest equilibrium (density 1) so collide stays finite,
        # plus a marked +x plane at the domain's last X slice
        w = jnp.asarray(np.asarray(lref.W, dtype=np.float32))
        f = jnp.broadcast_to(w[:, None, None, None],
                             (19, 32, 8, 8)).astype(jnp.float32)
        v = lops._PLUS_X[0]
        f = f.at[v, 31].add(1.0)
        with api.plan_context(mesh=mesh):
            got = api.launch("lbm.soa", f, omega=0.0)  # pure streaming
        want = self._single_device("soa", f, 0.0)
        assert jnp.array_equal(got, want)
        # with omega=0 post == fprop, so the marked plane must have
        # wrapped from x=31 to x=0 (the +1 rides on the w[v] background)
        assert float(jnp.max(jnp.asarray(got)[v, 0])) > float(w[v]) + 0.5


@multidevice
class TestOverlappedJacobiParity:
    """The overlapped jacobi body is bit-exact vs the PR-5
    exchange-then-compute body (ISSUE 9 acceptance criterion)."""

    @staticmethod
    def _blocking_entry():
        import dataclasses

        from repro.kernels.jacobi import ops as jops

        return dataclasses.replace(
            api.get_kernel("jacobi"), spmd_body=jops._spmd_jacobi_blocking)

    @pytest.mark.parametrize("shape", [(64, 34), (16, 130), (8, 34)])
    def test_overlapped_matches_blocking_all_cuts(self, shape):
        mesh = make_mesh(8, 1)
        g = rnd(shape, 5)
        entry = self._blocking_entry()
        with api.plan_context(mesh=mesh):
            overlapped = api.launch("jacobi", g)
            blocking = spmd.spmd_launch(entry, mesh, (g,), {})
        assert jnp.array_equal(overlapped, blocking), shape

    def test_overlapped_matches_blocking_env_mesh(self):
        mesh = env_mesh()
        g = rnd((64, 34), 6)
        entry = self._blocking_entry()
        with api.plan_context(mesh=mesh):
            overlapped = api.launch("jacobi", g)
            blocking = spmd.spmd_launch(entry, mesh, (g,), {})
        assert jnp.array_equal(overlapped, blocking)


@multidevice
class TestSpmdGradients:
    """custom_vjp backward through the shard_map forward (acceptance
    criterion: forward + gradient match jnp to fp32 tolerance)."""

    CFG = dict(name="t", family="dense", n_layers=1, d_model=64, n_heads=2,
               n_kv_heads=2, d_ff=64, vocab_size=128, dtype="float32",
               remat=False)

    def test_rms_fused_grads_match_ref(self):
        mesh = env_mesh()
        x = rnd((8, 16, 64), 0)
        s = rnd((64,), 1) + 1.5

        def fused(xx, ss):
            return blocks._rms_fused(xx, ss, 1e-6).astype(jnp.float32).sum()

        def ref(xx, ss):
            return blocks._rms_ref(xx, ss, 1e-6).astype(jnp.float32).sum()

        with api.plan_context(mesh=mesh):
            gx, gs = jax.grad(fused, argnums=(0, 1))(x, s)
        rx, rs = jax.grad(ref, argnums=(0, 1))(x, s)
        np.testing.assert_allclose(np.asarray(gx), np.asarray(rx),
                                   rtol=2e-5, atol=2e-6)
        np.testing.assert_allclose(np.asarray(gs), np.asarray(rs),
                                   rtol=2e-5, atol=2e-5)

    def test_lm_loss_fused_spmd_forward_and_grad(self):
        mesh = env_mesh()
        cfg = ModelConfig(**self.CFG)
        logits = rnd((4, 8, 128), 0) * 2
        labels = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, 128)

        with api.plan_context(mesh=mesh):
            assert blocks.use_fused_kernels()
            loss = lm_loss(logits, labels, cfg)
            grad = jax.grad(lambda l: lm_loss(l, labels, cfg))(logits)
        # same mesh, SPMD off: the pure-jnp vocab-parallel reference
        with api.plan_context(mesh=mesh, spmd=False):
            assert not blocks.use_fused_kernels()
            ref_loss = lm_loss(logits, labels, cfg)
            ref_grad = jax.grad(lambda l: lm_loss(l, labels, cfg))(logits)
        np.testing.assert_allclose(float(loss), float(ref_loss), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(grad), np.asarray(ref_grad),
                                   rtol=2e-5, atol=2e-6)

    def test_lm_loss_pure_model_mesh_keeps_megatron_layout(self):
        """The acceptance cell: an 8-way model-parallel mesh, fused lm_loss
        forward + grad vs jnp, with logits vocab-sharded in the shard body
        (the local plan cache proves no full-vocab local launch exists)."""
        mesh = make_mesh(1, 8)
        cfg = ModelConfig(**self.CFG)
        logits = rnd((4, 8, 128), 0) * 2
        labels = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, 128)
        clear_plan_cache()
        with api.plan_context(mesh=mesh):
            loss = lm_loss(logits, labels, cfg)
            grad = jax.grad(lambda l: lm_loss(l, labels, cfg))(logits)
        with api.plan_context(mesh=mesh, spmd=False):
            ref_loss = lm_loss(logits, labels, cfg)
            ref_grad = jax.grad(lambda l: lm_loss(l, labels, cfg))(logits)
        np.testing.assert_allclose(float(loss), float(ref_loss), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(grad), np.asarray(ref_grad),
                                   rtol=2e-5, atol=2e-6)
        keys = local_keys("xent")
        assert any(k[1] == (32, 16) for k in keys), keys      # 128/8 vocab
        assert not any(k[1] == (32, 128) for k in keys), keys

    def test_model_loss_end_to_end_jit(self):
        """Tiny dense LM: apply_norm + lm_loss both route through shard_map
        inside jit, value and every parameter gradient match the jnp path."""
        from repro.models import build_model

        mesh = env_mesh()
        model = build_model(ModelConfig(**self.CFG))
        params = model.init(jax.random.PRNGKey(0))
        batch = {
            "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0,
                                         128),
            "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 8), 0,
                                         128),
        }
        vg = jax.value_and_grad(model.loss)
        with api.plan_context(mesh=mesh):
            loss, grads = jax.jit(vg)(params, batch)
        with api.plan_context(mesh=mesh, spmd=False):
            ref_loss, ref_grads = jax.jit(vg)(params, batch)
        np.testing.assert_allclose(float(loss), float(ref_loss), rtol=1e-5)

        flat, _ = jax.tree_util.tree_flatten(grads)
        rflat, _ = jax.tree_util.tree_flatten(ref_grads)
        for g, r in zip(flat, rflat):
            if g.dtype == jax.dtypes.float0:
                continue
            np.testing.assert_allclose(np.asarray(g, np.float32),
                                       np.asarray(r, np.float32),
                                       rtol=5e-5, atol=5e-6)

    def test_trainer_hot_plans_under_spmd_mesh(self):
        """plan_hot_kernels still pins the global-shape plans (launch-time
        re-derivation inside shard_map uses the local ones)."""
        from repro.data.pipeline import DataConfig
        from repro.optim import adamw
        from repro.optim.schedules import make_schedule
        from repro.runtime.trainer import Trainer, TrainerConfig
        from repro.models import build_model

        mesh = env_mesh()
        tr = Trainer(
            build_model(ModelConfig(**self.CFG)),
            DataConfig(vocab_size=128, seq_len=8, global_batch=4, d_model=64),
            adamw.AdamWConfig(master=False),
            make_schedule("cosine", peak=3e-3, warmup=2, total=8),
            TrainerConfig(n_steps=2, ckpt_every=2, ckpt_dir="/tmp/t_spmd"),
            mesh=mesh,
        )
        plans = tr.plan_hot_kernels()
        assert plans["xent"].mesh == mesh_key(mesh)
