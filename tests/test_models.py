"""Per-arch smoke tests (reduced configs, same family structure) +
decode/forward consistency + chunked attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Compile-bound model-zoo sweep (~2 min): full tier-1 only.
pytestmark = pytest.mark.slow

import repro.models.blocks as blocks_mod
from repro.configs import ARCHS, get_config, reduce_for_smoke
from repro.models import build_model
from repro.models.config import ModelConfig
from repro.models.params import init_params, param_count


def make_batch(cfg, b=2, s=16, seed=0):
    toks = jax.random.randint(jax.random.PRNGKey(seed), (b, s), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "vlm":
        batch["img_embeds"] = jax.random.normal(
            jax.random.PRNGKey(seed + 1), (b, cfg.n_img_tokens, cfg.d_model),
            jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(seed + 1), (b, cfg.n_frames, cfg.d_model),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_forward_and_train_step(self, arch):
        """Reduced config: one forward + one train step, shapes + finiteness
        (assignment requirement)."""
        cfg = reduce_for_smoke(get_config(arch))
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = make_batch(cfg)
        if cfg.family == "encdec":
            logits, _ = model.forward(params, batch["tokens"], batch["frames"])
        else:
            logits, _ = model.forward(params, batch["tokens"],
                                      batch.get("img_embeds"))
        assert logits.shape == (2, 16, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits)))
        # one SGD-flavoured step must reduce nothing to NaN
        loss, grads = jax.value_and_grad(model.loss, allow_int=True)(
            params, batch
        )
        assert np.isfinite(float(loss))
        newp = jax.tree.map(
            lambda p, g: p - 1e-3 * g.astype(p.dtype)
            if jnp.issubdtype(p.dtype, jnp.floating) else p,
            params, grads)
        loss2 = model.loss(newp, batch)
        assert np.isfinite(float(loss2))


def _decode_matches_forward(cfg, seq=10, tol=2e-3):
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b = 2
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, seq), 0,
                              cfg.vocab_size)
    if cfg.family == "encdec":
        frames = jax.random.normal(jax.random.PRNGKey(2),
                                   (b, cfg.n_frames, cfg.d_model), jnp.float32)
        ref, _ = model.forward(params, toks, frames)
        cache = init_params(jax.random.PRNGKey(3), model.cache_defs(b, seq))
        ck, cv = model.prefill_cross(params, frames)
        cache["cross_k"], cache["cross_v"] = ck, cv
    else:
        ref, _ = model.forward(params, toks)
        cache = init_params(jax.random.PRNGKey(3), model.cache_defs(b, seq))
    outs = []
    for t in range(seq):
        lg, cache = model.decode_step(params, cache, toks[:, t: t + 1])
        outs.append(lg)
    got = jnp.concatenate(outs, axis=1)
    return float(jnp.max(jnp.abs(ref - got)))


@pytest.mark.parametrize(
    "arch", ["qwen3-4b", "qwen3-moe-30b-a3b", "zamba2-1.2b", "xlstm-1.3b",
             "whisper-tiny", "grok-1-314b"]
)
def test_decode_consistency(arch):
    """serve_step token-by-token == parallel forward (validates KV caches,
    chunked SSD/mLSTM recurrences, softcaps, cross attention)."""
    cfg = reduce_for_smoke(get_config(arch))
    if cfg.n_experts:
        import dataclasses
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no drops
    err = _decode_matches_forward(cfg)
    assert err < 2e-3, err


class TestChunkedAttention:
    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("s", [130, 256, 300])
    def test_matches_naive(self, causal, s):
        cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=64,
                          n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64,
                          dtype="float32")
        q = jax.random.normal(jax.random.PRNGKey(0), (2, s, 4, 16))
        k = jax.random.normal(jax.random.PRNGKey(1), (2, s, 2, 16))
        v = jax.random.normal(jax.random.PRNGKey(2), (2, s, 2, 16))
        pos = jnp.broadcast_to(jnp.arange(s), (2, s))
        scores = blocks_mod._gqa_scores(q, k, cfg)
        if causal:
            mask = pos[:, None, :, None] >= pos[:, None, None, :]
            scores = jnp.where(mask[:, :, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        ref = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v).reshape(2, s, 4, 16)
        got = blocks_mod._chunked_gqa(q, k, v, cfg, pos, pos, causal,
                                      block=128)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


class TestParamCounts:
    @pytest.mark.parametrize("arch,lo,hi", [
        ("zamba2-1.2b", 1.0e9, 1.4e9),
        ("minicpm-2b", 2.4e9, 3.0e9),
        ("qwen3-4b", 3.6e9, 4.8e9),
        ("qwen2-0.5b", 0.4e9, 0.6e9),
        ("qwen3-14b", 13.0e9, 16.0e9),
        ("pixtral-12b", 11.0e9, 13.5e9),
        ("grok-1-314b", 290e9, 340e9),
        ("qwen3-moe-30b-a3b", 28e9, 33e9),
        ("whisper-tiny", 0.03e9, 0.05e9),
    ])
    def test_total_params_match_names(self, arch, lo, hi):
        n = param_count(build_model(get_config(arch)).param_defs())
        assert lo <= n <= hi, f"{arch}: {n / 1e9:.2f}B"


class TestMoE:
    def _cfg(self, **kw):
        base = dict(name="moe", family="moe", n_layers=1, d_model=32,
                    n_heads=2, n_kv_heads=2, d_ff=0, moe_d_ff=64, n_experts=8,
                    top_k=2, vocab_size=64, dtype="float32",
                    capacity_factor=8.0)
        base.update(kw)
        return ModelConfig(**base)

    def test_skew_permutation_is_output_invariant(self):
        """The rotation relabels expert *storage* only -- model outputs are
        bit-identical with and without the skew (the paper's padding rule:
        layout must never change results)."""
        from repro.models import moe as moe_mod

        cfg = self._cfg()
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 32))
        p = init_params(jax.random.PRNGKey(1),
                        moe_mod.moe_defs(cfg))
        p["perm"] = jnp.arange(8, dtype=jnp.int32)
        out_id, _ = moe_mod.apply_moe(p, x, cfg)
        p2 = dict(p)
        perm = moe_mod.expert_permutation(8, 4, layer=3).astype(jnp.int32)
        # permute stored experts consistently with the table
        for w in ("wi", "wg", "wo"):
            p2[w] = p[w][jnp.asarray(perm)]
        p2["perm"] = jnp.asarray(perm)
        out_skew, _ = moe_mod.apply_moe(p2, x, cfg)
        np.testing.assert_allclose(np.asarray(out_skew), np.asarray(out_id),
                                   rtol=2e-4, atol=1e-5)

    def test_capacity_drops_are_bounded(self):
        """With cf=1.0 and uniform routing, most tokens survive."""
        from repro.models import moe as moe_mod

        cfg = self._cfg(capacity_factor=1.0)
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 64, 32))
        p = init_params(jax.random.PRNGKey(1), moe_mod.moe_defs(cfg))
        p["perm"] = jnp.arange(8, dtype=jnp.int32)
        out, aux = moe_mod.apply_moe(p, x, cfg)
        assert bool(jnp.all(jnp.isfinite(out)))
        assert float(aux) > 0
