"""Layout planner: cache behavior, plan geometry, balance predictions, and
launch parity on non-tile-multiple shapes (the planner-chosen layouts)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import planner
from repro.core.layout import LANES, SUBLANES
from repro.core.planner import clear_plan_cache, plan_cache_info, plan_kernel


def rnd(shape, dtype, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
    return x.astype(dtype)


class TestPlanCache:
    def test_hit_miss_and_identity(self):
        clear_plan_cache()
        p1 = plan_kernel("stream.triad", (8191,), jnp.float32)
        info = plan_cache_info()
        assert info == {"hits": 0, "misses": 1, "size": 1}
        p2 = plan_kernel("stream.triad", (8191,), jnp.float32)
        assert p2 is p1  # same object: repeated calls reuse the cached plan
        assert plan_cache_info()["hits"] == 1

    def test_key_includes_shape_dtype_kernel_mesh(self):
        clear_plan_cache()
        base = plan_kernel("triad", (8191,), jnp.float32)
        assert plan_kernel("triad", (8192,), jnp.float32) is not base
        assert plan_kernel("triad", (8191,), jnp.bfloat16) is not base
        assert plan_kernel("stream.triad", (8191,), jnp.float32) is not base
        meshed = plan_kernel("rmsnorm", (64, 129), jnp.float32,
                             mesh={"model": 4})
        plain = plan_kernel("rmsnorm", (64, 129), jnp.float32)
        assert meshed is not plain
        assert plan_cache_info()["misses"] == 6

    def test_unknown_family_raises(self):
        with pytest.raises(KeyError):
            plan_kernel("nope", (8,), jnp.float32)


class TestPlanGeometry:
    @pytest.mark.parametrize("n", [1, 7, 1000, 8191, 20000, 2 ** 22])
    def test_1d_plans_are_tileable(self, n):
        p = plan_kernel("stream.triad", (n,), jnp.float32)
        rows, width = p.padded_shape
        assert width % LANES == 0 and rows % SUBLANES == 0
        assert rows * width >= n
        assert rows % p.block_rows == 0 and width % p.block_cols == 0

    def test_small_arrays_waste_less_than_fixed_width(self):
        """The analytic width beats the old hard-coded 1024: n=1000 used to
        pad to 8x1024 = 8192 elements (waste 7/8)."""
        p = plan_kernel("stream.copy", (1000,), jnp.float32)
        assert p.padded_elems <= 2048
        assert p.waste < 0.5

    def test_2d_plans_lane_and_mesh_aligned(self):
        p = plan_kernel("rmsnorm", (100, 129), jnp.float32)
        assert p.padded_shape == (104, 256)
        meshed = plan_kernel("rmsnorm", (100, 129), jnp.float32,
                             mesh={"model": 4})
        assert meshed.width % (4 * LANES) == 0

    def test_lbm_plans_tile_the_lattice(self):
        soa = plan_kernel("lbm.soa", (19, 8, 8, 8), jnp.float32)
        assert soa.padded_shape[0] == 19
        assert soa.padded_shape[1] % soa.block_cols == 0
        ivjk = plan_kernel("lbm.ivjk", (19, 8, 8, 8), jnp.float32)
        sb, q, lanes = ivjk.padded_shape
        assert (q, lanes) == (19, 128)
        assert sb % ivjk.block_rows == 0

    def test_awkward_row_counts_keep_big_blocks(self):
        """Rows with no divisor near the budget pad up to a block multiple
        instead of collapsing every DMA to 8 rows (4999 is prime)."""
        p = plan_kernel("rmsnorm", (8 * 4999 - 3, 512), jnp.float32)
        assert p.block_rows > SUBLANES
        assert p.rows % p.block_rows == 0
        assert p.waste < 0.05

    def test_exactly_tileable_shapes_have_zero_row_pad(self):
        """Power-of-two sizes keep zero waste: a nearby divisor block is
        preferred over padding rows up."""
        for fam, shape in [("triad", (2 ** 24,)), ("rmsnorm", (4096, 5760))]:
            p = plan_kernel(fam, shape, jnp.float32)
            assert p.waste == 0.0, (fam, p.padded_shape, p.block_shape)
            assert p.rows % p.block_rows == 0

    def test_mismatched_plan_rejected(self):
        """A plan for one shape cannot silently drop another array's tail."""
        plan = plan_kernel("stream.copy", (1000,), jnp.float32)
        with pytest.raises(ValueError, match="is for shape"):
            api.launch("stream.copy", jnp.ones(2000), plan=plan)

    def test_explain_reports_balance_and_waste(self):
        txt = planner.explain("triad", (8191,), jnp.float32)
        assert "predicted balance" in txt and "waste" in txt
        assert "offsets" in txt


class TestBalancePredictions:
    def test_ge4_stream_signatures_reach_full_balance(self):
        """The paper's 'no trial and error' claim under the default model:
        skew + segment shift gives balance 1.0 for every >=4-stream family."""
        for family in ("triad", "lbm.soa", "lbm.ivjk", "rmsnorm.gated"):
            shape = (19, 8, 8, 8) if family.startswith("lbm.") else (
                (64, 256) if family.startswith("rmsnorm") else (4096,))
            p = plan_kernel(family, shape, jnp.float32)
            assert p.signature.n_streams >= 4
            assert p.predicted_balance == pytest.approx(1.0)

    def test_planned_beats_naive(self):
        for family in ("stream.copy", "triad", "jacobi"):
            shape = (512, 512) if family == "jacobi" else (4096,)
            p = plan_kernel(family, shape, jnp.float32)
            assert p.naive_balance == pytest.approx(0.25)
            assert p.predicted_balance > 3 * p.naive_balance


class TestLaunchParity:
    """Every kernel family against its ref on non-tile-multiple shapes,
    through the unified launch path (the shims stay covered -- explicitly --
    in test_api.TestDeprecatedShims)."""

    @pytest.mark.parametrize("n", [1000, 8191])
    def test_stream_triad(self, n):
        from repro.kernels.stream import ref as sref

        b, c = rnd((n,), jnp.float32, 0), rnd((n,), jnp.float32, 1)
        np.testing.assert_allclose(
            np.asarray(api.launch("stream.triad", b, c, s=3.0)),
            np.asarray(sref.triad(b, c, 3.0)), rtol=1e-6, atol=1e-6)

    @pytest.mark.parametrize("n", [1000, 8191])
    def test_vector_triad(self, n):
        from repro.kernels.triad import ref as tref

        b, c, d = (rnd((n,), jnp.float32, i) for i in range(3))
        np.testing.assert_allclose(
            np.asarray(api.launch("triad", b, c, d)),
            np.asarray(tref.triad(b, c, d)), rtol=1e-6, atol=1e-6)

    def test_jacobi_ragged_cols(self):
        from repro.kernels.jacobi import ref as jref

        g = rnd((67, 129), jnp.float32, 0)
        np.testing.assert_allclose(np.asarray(api.launch("jacobi", g)),
                                   np.asarray(jref.jacobi_step(g)),
                                   rtol=1e-5, atol=1e-6)

    def test_rmsnorm_ragged_cols(self):
        from repro.kernels.rmsnorm import ref as rref

        x = rnd((3, 129), jnp.float32, 0)
        s = rnd((129,), jnp.float32, 1) + 1.0
        np.testing.assert_allclose(np.asarray(api.launch("rmsnorm", x, s)),
                                   np.asarray(rref.rmsnorm(x, s)),
                                   rtol=1e-5, atol=1e-6)

    def test_xent_planner_tiles(self):
        """No explicit bt/bv: the planner picks the online-softmax tile."""
        from repro.kernels.xent import ref as xref

        t, v, lv = 129, 1111, 1000
        logits = jax.random.normal(jax.random.PRNGKey(0), (t, v)) * 3
        labels = jax.random.randint(jax.random.PRNGKey(1), (t,), 0, lv)
        got = float(api.launch("xent", logits, labels, logical_v=lv))
        want = float(xref.xent(logits, labels, logical_v=lv).mean())
        assert abs(got - want) < 1e-4

    def test_lbm_planner_blocks(self):
        from repro.kernels.lbm import ops as lops
        from repro.kernels.lbm import ref as lref

        f = lops.init_equilibrium(6, jnp.float32)  # S=216: ragged everywhere
        for layout in ("soa", "ivjk"):
            got = api.launch(f"lbm.{layout}", f, omega=1.2)
            np.testing.assert_allclose(np.asarray(got),
                                       np.asarray(lref.lbm_step(f, 1.2)),
                                       rtol=2e-5, atol=1e-7)

    def test_segmented_dtype_preserved(self):
        """to_flat keeps the segment dtype (bf16 roundtrip)."""
        from repro.core.segmented import SegmentedArray

        x = jnp.arange(10, dtype=jnp.bfloat16)
        sa = SegmentedArray.from_flat(x, 3, align=128, shift=8)
        assert sa.to_flat().dtype == jnp.bfloat16
        empty = SegmentedArray([], [], [])
        assert empty.to_flat().shape == (0,)
