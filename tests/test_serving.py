"""Continuous batching: ragged co-residency must equal isolated decoding
(no state leaks across slot tenants), slots must be reused."""
import jax
import numpy as np
import pytest

# Compile-bound serving sweep: full tier-1 only.
pytestmark = pytest.mark.slow

from repro.configs import get_config, reduce_for_smoke
from repro.models import build_model
from repro.models.params import init_params
from repro.serving import ContinuousBatcher, Request


def _isolated_run(model, params, prompt, max_new, max_len):
    """Single-request reference: replay prompt then greedy decode."""
    cache = init_params(jax.random.PRNGKey(0), model.cache_defs(1, max_len))
    import jax.numpy as jnp
    from repro.parallel import steps as steps_lib

    decode = jax.jit(steps_lib.make_decode_step(model))
    tok = None
    for t in prompt:
        tok, cache = decode(params, cache, jnp.asarray([[t]], jnp.int32))
    out = [int(tok[0, 0])]
    for _ in range(max_new - 1):
        tok, cache = decode(params, cache, tok)
        out.append(int(tok[0, 0]))
    return out


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "zamba2-1.2b"])
def test_batched_equals_isolated_with_slot_reuse(arch):
    cfg = reduce_for_smoke(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    # 5 ragged requests through 2 slots -> guaranteed slot reuse
    reqs = [
        Request(rid=i, prompt=rng.integers(1, cfg.vocab_size,
                                           size=3 + 2 * i).tolist(),
                max_new_tokens=4 + i)
        for i in range(5)
    ]
    max_len = 40
    batcher = ContinuousBatcher(model, params, slots=2, max_len=max_len)
    got = batcher.run([Request(r.rid, list(r.prompt), r.max_new_tokens)
                       for r in reqs])
    assert sorted(got) == [0, 1, 2, 3, 4]
    for r in reqs:
        want = _isolated_run(model, params, r.prompt, r.max_new_tokens,
                             max_len)
        assert got[r.rid] == want, (arch, r.rid)


def test_throughput_accounting():
    cfg = reduce_for_smoke(get_config("qwen2-0.5b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = [Request(rid=i, prompt=[1, 2, 3], max_new_tokens=3)
            for i in range(4)]
    b = ContinuousBatcher(model, params, slots=4, max_len=16)
    out = b.run(reqs)
    assert len(out) == 4
    # 4 slots in parallel: 3 prefill + 2 extra decode ticks = 5 total
    assert b.ticks == 5


def test_eos_early_stop():
    cfg = reduce_for_smoke(get_config("qwen2-0.5b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # pick the model's actual first greedy token as EOS -> stops at 1 token
    probe = _isolated_run(model, params, [5, 6, 7], 1, 16)
    eos = probe[0]
    b = ContinuousBatcher(model, params, slots=2, max_len=16, eos_id=eos)
    out = b.run([Request(rid=0, prompt=[5, 6, 7], max_new_tokens=8)])
    assert out[0][-1] == eos
    assert len(out[0]) < 8
