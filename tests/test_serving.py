"""Continuous batching: ragged co-residency must equal isolated decoding
(no state leaks across slot tenants), slots must be reused.

The paged-KV sections assert the tentpole invariant: the paged pool with
chunked prefill, backpressure, and preemption is *token-identical* to the
dense slab on the same request stream, and its page physical shape is the
planner's chosen tile.  Fast host-side units live in
``tests/test_paged_cache.py``."""
import jax
import numpy as np
import pytest

# Compile-bound serving sweep: full tier-1 only.
pytestmark = pytest.mark.slow

from repro import obs
from repro.configs import get_config, reduce_for_smoke
from repro.models import build_model
from repro.models.params import init_params
from repro.serving import ContinuousBatcher, Request


def _isolated_run(model, params, prompt, max_new, max_len):
    """Single-request reference: replay prompt then greedy decode."""
    cache = init_params(jax.random.PRNGKey(0), model.cache_defs(1, max_len))
    import jax.numpy as jnp
    from repro.parallel import steps as steps_lib

    decode = jax.jit(steps_lib.make_decode_step(model))
    tok = None
    for t in prompt:
        tok, cache = decode(params, cache, jnp.asarray([[t]], jnp.int32))
    out = [int(tok[0, 0])]
    for _ in range(max_new - 1):
        tok, cache = decode(params, cache, tok)
        out.append(int(tok[0, 0]))
    return out


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "zamba2-1.2b"])
def test_batched_equals_isolated_with_slot_reuse(arch):
    cfg = reduce_for_smoke(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    # 5 ragged requests through 2 slots -> guaranteed slot reuse
    reqs = [
        Request(rid=i, prompt=rng.integers(1, cfg.vocab_size,
                                           size=3 + 2 * i).tolist(),
                max_new_tokens=4 + i)
        for i in range(5)
    ]
    max_len = 40
    batcher = ContinuousBatcher(model, params, slots=2, max_len=max_len)
    got = batcher.run([Request(r.rid, list(r.prompt), r.max_new_tokens)
                       for r in reqs])
    assert sorted(got) == [0, 1, 2, 3, 4]
    for r in reqs:
        want = _isolated_run(model, params, r.prompt, r.max_new_tokens,
                             max_len)
        assert got[r.rid] == want, (arch, r.rid)


def test_throughput_accounting():
    cfg = reduce_for_smoke(get_config("qwen2-0.5b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = [Request(rid=i, prompt=[1, 2, 3], max_new_tokens=3)
            for i in range(4)]
    b = ContinuousBatcher(model, params, slots=4, max_len=16)
    out = b.run(reqs)
    assert len(out) == 4
    # 4 slots in parallel: 3 prefill + 2 extra decode ticks = 5 total
    assert b.ticks == 5


def _ragged_requests(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return [
        Request(rid=i, prompt=rng.integers(1, cfg.vocab_size,
                                           size=3 + 2 * i).tolist(),
                max_new_tokens=4 + i)
        for i in range(n)
    ]


def _clone(reqs):
    return [Request(r.rid, list(r.prompt), r.max_new_tokens) for r in reqs]


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "zamba2-1.2b"])
def test_paged_equals_dense(arch):
    """Tentpole acceptance: the paged cache is token-identical to dense on
    the same stream, and its pages are physically the planner's tiles."""
    cfg = reduce_for_smoke(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = _ragged_requests(cfg, 4)
    max_len = 40
    dense = ContinuousBatcher(model, params, slots=2, max_len=max_len)
    want = dense.run(_clone(reqs))
    paged = ContinuousBatcher(model, params, slots=2, max_len=max_len,
                              kv_cache="paged")
    # Page physical shape == planner-chosen tile for the KV stream.
    assert paged.geometry.page_len == paged.page_plan.block_rows
    assert paged.geometry.page_len % paged.page_plan.sublanes == 0
    pools = [leaf for path, leaf in
             jax.tree_util.tree_flatten_with_path(paged.cache)[0]
             if any(getattr(p, "key", "") in ("k", "v") for p in path)]
    assert pools, "no paged KV pool leaves found"
    for pool in pools:
        assert pool.shape[1:3] == (paged.geometry.n_pages,
                                   paged.geometry.page_len)
    got = paged.run(_clone(reqs))
    assert got == want, arch
    # Retirement returned every page to the pool immediately.
    assert paged.pages.free_pages == paged.geometry.live_pages


def test_chunked_prefill_parity_and_fewer_ticks():
    cfg = reduce_for_smoke(get_config("qwen2-0.5b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = _ragged_requests(cfg, 5)
    max_len = 40
    dense = ContinuousBatcher(model, params, slots=2, max_len=max_len)
    want = dense.run(_clone(reqs))
    chunked = ContinuousBatcher(model, params, slots=2, max_len=max_len,
                                kv_cache="paged", prefill_chunk=4)
    got = chunked.run(_clone(reqs))
    assert got == want
    # Chunked prefill is purely a scheduling lever: same tokens, fewer
    # prompt-bound ticks.
    assert chunked.ticks < dense.ticks


def test_page_pool_exhaustion_backpressure():
    """A pool too small for all requests at once defers admissions instead
    of corrupting state; everything still completes token-identically."""
    cfg = reduce_for_smoke(get_config("qwen2-0.5b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = _ragged_requests(cfg, 5)
    max_len = 40
    dense = ContinuousBatcher(model, params, slots=2, max_len=max_len)
    want = dense.run(_clone(reqs))
    # page_len 8 at this geometry; 4 live pages can hold ~2 short streams.
    tight = ContinuousBatcher(model, params, slots=2, max_len=max_len,
                              kv_cache="paged", n_pages=5)
    ring = obs.RingBufferSink(capacity=100_000)
    with obs.session(ring):
        got = tight.run(_clone(reqs))
    assert got == want
    assert tight.pages.free_pages == tight.geometry.live_pages
    # The pool actually saturated at some point (else the test is vacuous).
    peak = max(e.used_pages for e in ring.events("page_pool"))
    assert peak == tight.geometry.live_pages


def test_preemption_decode_priority_and_replay():
    """Decode pressure evicts a prefilling slot (never the decoder), the
    victim replays after requeue, and the output stream is unchanged."""
    cfg = reduce_for_smoke(get_config("qwen2-0.5b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_len = 32
    # rid 0: short prompt, long decode -- grows to 3 pages.  rid 1: long
    # prompt -- still prefilling when rid 0 needs its second page, with
    # only 3 live pages between them.
    reqs = [Request(rid=0, prompt=[7, 8, 9], max_new_tokens=20),
            Request(rid=1, prompt=list(range(1, 11)), max_new_tokens=4)]
    dense = ContinuousBatcher(model, params, slots=2, max_len=max_len)
    want = dense.run(_clone(reqs))
    paged = ContinuousBatcher(model, params, slots=2, max_len=max_len,
                              kv_cache="paged", n_pages=4)
    clones = _clone(reqs)
    ring = obs.RingBufferSink(capacity=100_000)
    with obs.session(ring):
        got = paged.run(clones)
    evs = ring.events("preemption")
    assert evs, "tight pool never preempted"
    assert all(e.reason == "decode_pressure" for e in evs)
    assert {e.rid for e in evs} == {1}          # the prefilling victim
    assert clones[1].preemptions >= 1
    assert got == want                          # replay is invisible


def test_pool_shrink_degrades_gracefully():
    """Chaos satellite: losing page capacity mid-stream (a host behind the
    pool goes away) shrinks the live pool via the preemption-by-replay
    path -- the batcher keeps serving at reduced capacity and the output
    stream is token-identical to the dense reference, with the
    degradation visible as a DegradedEvent."""
    from repro.runtime.faults import FaultPlan, PoolShrink

    cfg = reduce_for_smoke(get_config("qwen2-0.5b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_len = 32
    reqs = [Request(rid=0, prompt=[7, 8, 9], max_new_tokens=16),
            Request(rid=1, prompt=list(range(1, 9)), max_new_tokens=6)]
    dense = ContinuousBatcher(model, params, slots=2, max_len=max_len)
    want = dense.run(_clone(reqs))

    paged = ContinuousBatcher(model, params, slots=2, max_len=max_len,
                              kv_cache="paged", n_pages=9)
    before = paged.pages.live_pages
    inj = FaultPlan((PoolShrink(tick=4, live_pages=3),)).injector()
    ring = obs.RingBufferSink(capacity=100_000)
    with obs.session(ring):
        got = paged.run(_clone(reqs), fault_injector=inj)
    assert inj.log == [("pool_shrink", 4)]
    assert paged.pages.live_pages == 3 < before
    assert got == want                          # degradation is invisible
    deg = [e for e in ring.events("degraded") if e.reason == "pool_shrink"]
    assert len(deg) == 1
    # Post-shrink accounting stays consistent on the shrunken pool, and
    # the tick stream reports the *shrunken* live count.
    assert paged.pages.free_pages == paged.pages.live_pages == 3
    pool_events = ring.events("page_pool")
    assert pool_events[-1].live_pages == 3
    assert all(e.used_pages + e.free_pages == e.live_pages
               for e in pool_events)


def test_pool_shrink_requires_paged_cache():
    cfg = reduce_for_smoke(get_config("qwen2-0.5b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b = ContinuousBatcher(model, params, slots=2, max_len=16)
    with pytest.raises(RuntimeError, match="paged"):
        b.shrink_pool(3)


def test_max_len_equals_padded_slots_end_to_end():
    """Regression: with max_len == padded_slots the old shape-guessed slot
    reset clobbered every tenant's KV rows on re-admission."""
    cfg = reduce_for_smoke(get_config("qwen2-0.5b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b = ContinuousBatcher(model, params, slots=2, max_len=8)
    assert b.padded_slots == 8, "fixture drifted: want max_len==padded_slots"
    reqs = [Request(rid=i, prompt=[3 + i, 4 + i], max_new_tokens=3)
            for i in range(4)]          # 4 requests, 2 slots: forced reuse
    got = b.run(_clone(reqs))
    for r in reqs:
        want = _isolated_run(model, params, r.prompt, r.max_new_tokens, 8)
        assert got[r.rid] == want, r.rid


def test_eos_early_stop():
    cfg = reduce_for_smoke(get_config("qwen2-0.5b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # pick the model's actual first greedy token as EOS -> stops at 1 token
    probe = _isolated_run(model, params, [5, 6, 7], 1, 16)
    eos = probe[0]
    b = ContinuousBatcher(model, params, slots=2, max_len=16, eos_id=eos)
    out = b.run([Request(rid=0, prompt=[5, 6, 7], max_new_tokens=8)])
    assert out[0][-1] == eos
    assert len(out[0]) < 8
