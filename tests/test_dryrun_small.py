"""Miniature dry-run: lower+compile representative cells on a (2,2) mesh of
4 forced host devices, in a subprocess (device count locks at jax init).

This is the CI-scale version of launch/dryrun.py: same rules, same specs,
same step builders -- only the mesh and the model dims are small.
"""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys, json, dataclasses
sys.path.insert(0, "src")
import jax
from repro.configs import get_config, reduce_for_smoke
from repro.configs.shapes import ShapeSpec, SHAPES
import repro.configs.shapes as shapes_mod
from repro.launch.mesh import make_test_mesh
from repro.launch import lowering

mesh = make_test_mesh((2, 2), ("data", "model"))
out = {}
# small shape cells so compiles stay subsecond
shapes_mod.SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 64, 8),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 128, 4),
    "decode_32k": ShapeSpec("decode_32k", "decode", 128, 4),
    "long_500k": ShapeSpec("long_500k", "decode", 256, 1),
}
CASES = [
    ("qwen2-0.5b", "train_4k"),
    ("qwen3-moe-30b-a3b", "train_4k"),
    ("zamba2-1.2b", "long_500k"),
    ("xlstm-1.3b", "decode_32k"),
    ("whisper-tiny", "prefill_32k"),
    ("grok-1-314b", "decode_32k"),
]
import repro.launch.lowering as L
_orig = L.cell_config
def small_cell_config(arch, *, padded, tp=16):
    cfg = reduce_for_smoke(get_config(arch))
    if padded:
        cfg, changes = cfg.padded_for_mesh(tp)
        return cfg, changes
    return cfg, {}
L.cell_config = small_cell_config

for arch, shape in CASES:
    cell = L.lower_cell(arch, shape, mesh, padded=True)
    compiled = cell.lowered.compile()
    cost = L.cost_stats(compiled)
    assert cost["flops"] > 0
    out[f"{arch}:{shape}"] = "ok"
print(json.dumps(out))
"""


@pytest.mark.slow
def test_small_mesh_dryrun():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, timeout=560,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    report = json.loads(res.stdout.strip().splitlines()[-1])
    assert all(v == "ok" for v in report.values()), report
    assert len(report) == 6
