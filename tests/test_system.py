"""End-to-end behaviour: a small model actually learns the synthetic stream
(train loop + data + optimizer + schedule together)."""
import jax
import numpy as np
import pytest

# Multi-step train loops (compile + many steps): full tier-1 only.
pytestmark = pytest.mark.slow

from repro.data.pipeline import DataConfig, make_batch
from repro.models import build_model
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.optim.schedules import make_schedule
from repro.parallel import steps as steps_lib


def test_end_to_end_learning():
    cfg = ModelConfig(name="e2e", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=96,
                      dtype="float32", remat=False)
    model = build_model(cfg)
    opt_cfg = adamw.AdamWConfig(master=False, weight_decay=0.01)
    step = jax.jit(steps_lib.make_train_step(
        model, opt_cfg, make_schedule("wsd", peak=3e-3, warmup=5, total=60)))
    state = steps_lib.init_train_state(model, opt_cfg, jax.random.PRNGKey(0))
    dcfg = DataConfig(vocab_size=96, seq_len=32, global_batch=8)
    losses = []
    for i in range(60):
        state, metrics = step(state, make_batch(dcfg, i))
        losses.append(float(metrics["loss"]))
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    assert last < first - 0.5, (first, last)


def test_microbatched_step_matches_full_batch():
    """Gradient accumulation is numerically equivalent (fp32 sums)."""
    cfg = ModelConfig(name="mb", family="dense", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64,
                      dtype="float32", remat=False)
    model = build_model(cfg)
    opt_cfg = adamw.AdamWConfig(master=False)
    sched = make_schedule("cosine", peak=1e-3)
    s1 = jax.jit(steps_lib.make_train_step(model, opt_cfg, sched))
    s4 = jax.jit(steps_lib.make_train_step(model, opt_cfg, sched,
                                           microbatches=4))
    state = steps_lib.init_train_state(model, opt_cfg, jax.random.PRNGKey(1))
    batch = make_batch(DataConfig(vocab_size=64, seq_len=16, global_batch=8), 0)
    _, m1 = s1(state, batch)
    _, m4 = s4(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)
    np.testing.assert_allclose(float(m1["grad_norm"]),
                               float(m4["grad_norm"]), rtol=1e-4)
