"""Golden snapshot of the analytic planner's output.

Every registry kernel x {fp32, bf16} x one odd + one even representative
shape is planned and compared field-by-field against
``tests/golden/plans.json``.  Any planner change that moves a padded
shape, block shape, waste, or predicted traffic shows up as a readable
per-cell diff here -- deliberate changes are blessed with:

    pytest tests/test_golden_plans.py --update-golden
"""
import json
import os

import pytest

from repro import api
from repro.core.planner import KernelPlan

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden", "plans.json")

# (odd, even) representative logical shapes per registry kernel.  Odd
# extents exercise every padding rule; even ones must plan tight.
# Per-shard (local=True) cells for the SPMD launch path, planned under a
# mapping mesh (no devices needed): these pin the communication model --
# ``predicted_comm_bytes`` for the jacobi/LBM halos and xent's lse
# combine, plus the overlap model's un-hideable remainder
# ``predicted_exposed_comm_bytes`` (docs/OVERLAP.md) -- alongside the
# local block geometry.  The thin jacobi (8, 258) stripe pins a
# partially-exposed cell (interior window too small to hide the halo).
# Meshes are (axis, size) pairs.
SPMD_LOCAL_CELLS: list[tuple[str, tuple[int, ...], str, tuple]] = [
    ("jacobi", (32, 258), "float32", (("data", 8), ("model", 1))),
    ("jacobi", (32, 258), "float32", (("data", 2), ("model", 4))),
    ("jacobi", (8, 258), "float32", (("data", 8), ("model", 1))),
    ("lbm.soa", (19, 4, 8, 8), "float32", (("data", 8), ("model", 1))),
    ("lbm.ivjk", (19, 4, 8, 8), "float32", (("data", 8), ("model", 1))),
    ("xent", (32, 512), "float32", (("data", 2), ("model", 4))),
    ("xent", (64, 512), "float32", (("data", 1), ("model", 8))),
    ("rmsnorm", (64, 129), "float32", (("data", 2), ("model", 4))),
]

SHAPES: dict[str, tuple[tuple[int, ...], tuple[int, ...]]] = {
    "stream.copy": ((8191,), (131072,)),
    "stream.scale": ((8191,), (131072,)),
    "stream.add": ((8191,), (131072,)),
    "stream.triad": ((8191,), (131072,)),
    "triad": ((17299,), (65536,)),
    "jacobi": ((257, 129), (256, 256)),
    "lbm.soa": ((19, 10, 10, 10), (19, 8, 8, 8)),
    "lbm.ivjk": ((19, 10, 10, 10), (19, 8, 8, 8)),
    "rmsnorm": ((301, 1111), (256, 1024)),
    "rmsnorm.gated": ((301, 1111), (256, 1024)),
    "xent": ((751, 2943), (256, 2048)),
}
DTYPES = ("float32", "bfloat16")


def snapshot_plan(plan: KernelPlan) -> dict:
    return {
        "padded_shape": list(plan.padded_shape),
        "block_shape": list(plan.block_shape),
        "grid": list(plan.grid),
        "sublanes": plan.sublanes,
        "waste_bytes": plan.waste_bytes,
        "predicted_hbm_bytes": plan.predicted_hbm_bytes,
        "predicted_logical_bytes": plan.predicted_logical_bytes,
        "predicted_comm_bytes": plan.predicted_comm_bytes,
        "predicted_exposed_comm_bytes": plan.predicted_exposed_comm_bytes,
        "predicted_balance": round(plan.predicted_balance, 4),
        "naive_balance": round(plan.naive_balance, 4),
    }


def current_snapshot() -> dict:
    # Every *shipped* kernel must be snapshotted (kernels registered ad hoc
    # by other tests are not, and neither are the analyzer's seeded-hazard
    # fixtures -- they are deliberately bad layouts, not products); a
    # shipped kernel missing from SHAPES fails.
    shipped = [k for k in api.list_kernels()
               if api.get_kernel(k).body.__module__.startswith("repro.")
               and not api.get_kernel(k).body.__module__.startswith(
                   "repro.analyze.")]
    missing = set(shipped) - set(SHAPES)
    assert not missing, f"add golden shapes for new kernels: {sorted(missing)}"
    out = {}
    for kernel in shipped:
        for shape in SHAPES[kernel]:
            for dtype in DTYPES:
                key = (f"{kernel}|{'x'.join(str(s) for s in shape)}|{dtype}")
                out[key] = snapshot_plan(api.plan_for(kernel, shape, dtype))
    for kernel, shape, dtype, mesh in SPMD_LOCAL_CELLS:
        tag = ".".join(f"{a}{s}" for a, s in mesh)
        key = (f"{kernel}|{'x'.join(str(s) for s in shape)}|{dtype}"
               f"|local@{tag}")
        with api.plan_context(mesh=dict(mesh)):
            out[key] = snapshot_plan(
                api.plan_for(kernel, shape, dtype, local=True))
    return out


def test_plans_match_golden(request):
    current = current_snapshot()
    if request.config.getoption("--update-golden"):
        os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
        with open(GOLDEN_PATH, "w") as f:
            json.dump(current, f, indent=1, sort_keys=True)
        pytest.skip(f"regenerated {GOLDEN_PATH} ({len(current)} plans)")
    if not os.path.exists(GOLDEN_PATH):
        pytest.fail(
            f"{GOLDEN_PATH} missing; generate it with "
            f"`pytest {__file__} --update-golden`"
        )
    with open(GOLDEN_PATH) as f:
        golden = json.load(f)

    lines = []
    for key in sorted(set(golden) | set(current)):
        if key not in golden:
            lines.append(f"  + {key}: new cell (not in golden)")
            continue
        if key not in current:
            lines.append(f"  - {key}: golden cell no longer planned")
            continue
        for field in sorted(set(golden[key]) | set(current[key])):
            g, c = golden[key].get(field), current[key].get(field)
            if g != c:
                lines.append(f"  ~ {key}.{field}: golden {g} -> current {c}")
    if lines:
        pytest.fail(
            "planner output drifted from tests/golden/plans.json "
            "(bless deliberate changes with --update-golden):\n"
            + "\n".join(lines),
            pytrace=False,
        )
