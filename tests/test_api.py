"""Unified kernel-launch API: registry round-trips for every family,
PlanContext nesting/override semantics, dtype-aware sublane plans, mesh
threading to the plan cache at the serving/training call sites, and the
deprecated per-family shims."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import planner
from repro.core.autotune import StreamSignature
from repro.core.planner import clear_plan_cache, plan_cache_keys, plan_kernel


def rnd(shape, dtype, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
    return x.astype(dtype)


def one_device_mesh():
    return jax.sharding.Mesh(
        np.asarray(jax.devices()[:1]).reshape(1), ("model",)
    )


class TestRegistryRoundTrip:
    """register -> launch -> parity vs the registered ref, all families."""

    def _cases(self):
        a = rnd((1000,), jnp.float32, 0)
        b = rnd((1000,), jnp.float32, 1)
        c = rnd((1000,), jnp.float32, 2)
        g = rnd((37, 130), jnp.float32, 3)
        x = rnd((5, 129), jnp.float32, 4)
        z = rnd((5, 129), jnp.float32, 5)
        s = rnd((129,), jnp.float32, 6) + 1.0
        from repro.kernels.lbm import ops as lops

        f = lops.init_equilibrium(6, jnp.float32)
        logits = jax.random.normal(jax.random.PRNGKey(7), (67, 1111)) * 3
        labels = jax.random.randint(jax.random.PRNGKey(8), (67,), 0, 1000)
        return [
            ("stream.copy", (a,), {}),
            ("stream.scale", (a,), {"s": 2.0}),
            ("stream.add", (a, b), {}),
            ("stream.triad", (a, b), {"s": 3.0}),
            ("triad", (a, b, c), {}),
            ("jacobi", (g,), {}),
            ("lbm.soa", (f,), {"omega": 1.2}),
            ("lbm.ivjk", (f,), {"omega": 1.2}),
            ("rmsnorm", (x, s), {}),
            ("rmsnorm.gated", (x, z, s), {}),
            ("xent", (logits, labels), {"logical_v": 1000}),
        ]

    def test_all_families_launch_and_match_ref(self):
        for name, arrays, scalars in self._cases():
            got = api.launch(name, *arrays, **scalars)
            want = api.ref(name, *arrays, **scalars)
            np.testing.assert_allclose(
                np.asarray(got, np.float32), np.asarray(want, np.float32),
                rtol=2e-4, atol=1e-5, err_msg=name,
            )

    def test_six_families_cover_registry(self):
        # The shipped surface: kernels whose bodies live in repro.*, minus
        # the repro.analyze seeded-hazard fixtures (analysis-only) and
        # anything other tests registered ad hoc.
        names = [n for n in api.list_kernels()
                 if api.get_kernel(n).body.__module__.startswith("repro.")
                 and not api.get_kernel(n).body.__module__.startswith(
                     "repro.analyze.")]
        families = {n.split(".")[0] for n in names}
        assert families == {"stream", "triad", "jacobi", "lbm", "rmsnorm",
                            "xent"}
        assert set(names) >= {
            "stream.copy", "stream.scale", "stream.add", "stream.triad",
            "triad", "jacobi", "lbm.soa", "lbm.ivjk",
            "rmsnorm", "rmsnorm.gated", "xent",
        }

    def test_custom_registration_round_trip(self):
        """A brand-new kernel registered through the decorator is launchable
        and planned like any built-in family."""
        from repro.kernels.util import plan_args_1d

        name = "stream.test_double"
        if name not in planner.FAMILIES:  # idempotent under pytest reruns
            @api.register_kernel(
                name, signature=StreamSignature(n_read=1, n_write=1),
                ref=lambda a: a * 2.0, plan_args=plan_args_1d)
            def _double(plan, a):
                assert plan.kernel == name
                return a * 2.0

        x = rnd((300,), jnp.float32, 0)
        np.testing.assert_allclose(np.asarray(api.launch(name, x)),
                                   np.asarray(x) * 2.0)
        assert api.plan_for(name, (300,), jnp.float32).kernel == name

    def test_unknown_kernel_raises(self):
        with pytest.raises(KeyError, match="no kernel registered"):
            api.launch("nope.unknown", jnp.ones(8))

    def test_shadow_registration_rejected(self):
        from repro.kernels.util import plan_args_1d

        with pytest.raises(ValueError, match="already registered"):
            @api.register_kernel(
                "triad", signature=StreamSignature(n_read=3, n_write=1),
                ref=lambda *a: a[0], plan_args=plan_args_1d)
            def _shadow(plan, b, c, d):
                return b

    def test_shadow_family_signature_rejected(self):
        with pytest.raises(ValueError, match="refusing shadow"):
            planner.register_family(
                "triad", StreamSignature(n_read=1, n_write=1))

    def test_gated_rmsnorm_operand_mismatch_rejected(self):
        """A z (or scale) that disagrees with x must error, never be
        silently zero-padded into wrong output rows."""
        x = rnd((8, 256), jnp.float32, 0)
        z = rnd((4, 256), jnp.float32, 1)
        s = jnp.ones(256)
        with pytest.raises(ValueError, match="must match x shape"):
            api.launch("rmsnorm.gated", x, z, s)
        with pytest.raises(ValueError, match="must match minor dim"):
            api.launch("rmsnorm", x, jnp.ones(100))

    def test_plan_array_mismatch_rejected(self):
        plan = api.plan_for("stream.copy", (1000,), jnp.float32)
        with pytest.raises(ValueError, match="is for shape"):
            api.launch("stream.copy", jnp.ones(2000), plan=plan)
        with pytest.raises(ValueError, match="is for dtype"):
            api.launch("stream.copy", jnp.ones(1000, jnp.bfloat16), plan=plan)
        with pytest.raises(ValueError, match="is for kernel"):
            api.launch("stream.add", jnp.ones(1000), jnp.ones(1000),
                       plan=plan)


class TestPlanContext:
    def test_nesting_inherits_and_overrides(self):
        base = api.current_context()
        assert base.mesh is None
        with api.plan_context(mesh={"model": 4}) as c1:
            assert api.current_context() is c1
            assert c1.mesh == {"model": 4}
            with api.plan_context(vmem_budget=1 << 20) as c2:
                assert c2.mesh == {"model": 4}          # inherited
                assert c2.vmem_budget == 1 << 20         # overridden
                assert c1.vmem_budget != 1 << 20
            assert api.current_context() is c1
        assert api.current_context().mesh is None

    def test_plan_overrides_merge_inner_wins(self):
        pa = api.plan_for("triad", (64,), jnp.float32)
        pb = api.plan_for("stream.copy", (64,), jnp.float32)
        pa2 = api.plan_for("triad", (128,), jnp.float32)
        with api.plan_context(plan_overrides={"triad": pa}):
            with api.plan_context(plan_overrides={"stream.copy": pb,
                                                  "triad": pa2}) as c2:
                assert c2.plan_overrides["triad"] is pa2
                assert c2.plan_overrides["stream.copy"] is pb
            assert api.current_context().plan_overrides == {"triad": pa}
            # explicit None clears inherited pins entirely
            with api.plan_context(plan_overrides=None):
                assert api.current_context().plan_overrides == {}

    def test_plan_override_used_by_launch(self):
        plan = api.plan_for("triad", (500,), jnp.float32)
        with api.plan_context(plan_overrides={"triad": plan}):
            assert api.plan_for("triad", (500,), jnp.float32) is plan
            b, c, d = (rnd((500,), jnp.float32, i) for i in range(3))
            out = api.launch("triad", b, c, d)
            np.testing.assert_allclose(np.asarray(out),
                                       np.asarray(api.ref("triad", b, c, d)),
                                       rtol=1e-6, atol=1e-6)
            # other shapes of the same kernel fall through to the planner
            # (a pinned plan must not break the rest of the run)
            other = api.plan_for("triad", (9999,), jnp.float32)
            assert other is not plan
            assert other.logical_shape == (9999,)
            b2, c2, d2 = (rnd((600,), jnp.float32, i) for i in range(3))
            np.testing.assert_allclose(
                np.asarray(api.launch("triad", b2, c2, d2)),
                np.asarray(api.ref("triad", b2, c2, d2)),
                rtol=1e-6, atol=1e-6)

    def test_evolve_rejects_unknown_fields(self):
        with pytest.raises(TypeError, match="unknown PlanContext fields"):
            api.current_context().evolve(vmem=1 << 20)  # typo'd kwarg

    def test_lowering_kernel_plan_honors_plan_overrides(self):
        from repro.launch import lowering

        pinned = api.plan_for("rmsnorm", (64, 129), "float32")
        with api.plan_context(plan_overrides={"rmsnorm": pinned}):
            assert lowering.kernel_plan("rmsnorm", (64, 129),
                                        "float32") is pinned

    def test_process_default_context(self):
        try:
            api.set_default_context(api.PlanContext(mesh={"model": 2}))
            assert api.current_context().mesh == {"model": 2}
            # an explicit context still wins over the default
            with api.plan_context(mesh=None):
                assert api.current_context().mesh is None
        finally:
            api.reset_default_context()
        assert api.current_context().mesh is None

    def test_context_mesh_reaches_plan_cache_key(self):
        clear_plan_cache()
        mesh = one_device_mesh()
        with api.plan_context(mesh=mesh):
            api.plan_for("rmsnorm", (64, 129), jnp.float32)
        keys = plan_cache_keys()
        assert any(k[0] == "rmsnorm" and k[3] == (("model", 1),)
                   for k in keys)


class TestSublanePolicy:
    """bf16 -> 16-row sublanes, fp8 -> 32; less padding paid in bytes."""

    def test_dtype_native_sublanes(self):
        assert plan_kernel("triad", (8191,), jnp.float32).sublanes == 8
        assert plan_kernel("triad", (8191,), jnp.bfloat16).sublanes == 16
        if hasattr(jnp, "float8_e4m3fn"):
            p8 = plan_kernel("triad", (8191,), jnp.float8_e4m3fn)
            assert p8.sublanes == 32

    @pytest.mark.parametrize("family,shape", [
        ("triad", (8191,)),
        ("rmsnorm", (100, 129)),
        ("rmsnorm", (999, 257)),
        ("xent", (301, 1111)),
    ])
    def test_bf16_wastes_strictly_fewer_bytes_than_fp32(self, family, shape):
        p32 = plan_kernel(family, shape, jnp.float32)
        p16 = plan_kernel(family, shape, jnp.bfloat16)
        assert p32.sublanes == 8
        # native (16, 128) tile -- unless the fp32 geometry pads fewer
        # bytes, in which case the planner's narrow-dtype waste guarantee
        # adopts it (at half the byte price) instead
        assert p16.sublanes in (8, 16)
        assert p16.rows % p16.sublanes == 0
        assert p16.waste_bytes < p32.waste_bytes

    def test_bf16_plans_stay_tileable_and_parity_holds(self):
        from repro.kernels.stream import ref as sref

        for n in (1000, 8191, 20000):
            p = plan_kernel("stream.triad", (n,), jnp.bfloat16)
            assert p.rows % p.sublanes == 0
            assert p.rows % p.block_rows == 0
            b, c = rnd((n,), jnp.bfloat16, 0), rnd((n,), jnp.bfloat16, 1)
            np.testing.assert_allclose(
                np.asarray(api.launch("stream.triad", b, c, s=3.0),
                           np.float32),
                np.asarray(sref.triad(b, c, 3.0), np.float32),
                rtol=2e-2, atol=2e-2)

    def test_context_sublane_policy_override(self):
        ctx = api.PlanContext(sublane_policy={"bfloat16": 8})
        assert ctx.sublanes_for(jnp.bfloat16) == 8
        assert ctx.sublanes_for(jnp.float32) == 8
        with api.plan_context(sublane_policy={"bfloat16": 8}):
            p = api.plan_for("rmsnorm", (100, 129), jnp.bfloat16)
            assert p.sublanes == 8 and p.rows == 104


class TestCallSiteMeshThreading:
    """A Mesh set via plan_context reaches plan_kernel at every
    serving/training call site (spied through the plan cache key)."""

    MESH_KEY = (("model", 1),)

    def _tiny_model(self):
        from repro.models import build_model
        from repro.models.config import ModelConfig

        cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=64,
                          n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=32,
                          dtype="float32", remat=False)
        return build_model(cfg)

    def _mesh_keys_for(self, kernel):
        return [k for k in plan_cache_keys()
                if k[0] == kernel and k[3] == self.MESH_KEY]

    def test_lowering_kernel_plan_uses_ambient_mesh(self):
        from repro.launch import lowering

        clear_plan_cache()
        with api.plan_context(mesh=one_device_mesh()):
            p = lowering.kernel_plan("xent", (256, 1111), "float32")
        assert p.mesh == self.MESH_KEY
        assert self._mesh_keys_for("xent")

    def test_trainer_plans_under_its_mesh(self):
        from repro.data.pipeline import DataConfig
        from repro.optim import adamw
        from repro.optim.schedules import make_schedule
        from repro.runtime.trainer import Trainer, TrainerConfig

        clear_plan_cache()
        model = self._tiny_model()
        tr = Trainer(
            model,
            DataConfig(vocab_size=32, seq_len=16, global_batch=4, d_model=64),
            adamw.AdamWConfig(master=False),
            make_schedule("cosine", peak=3e-3, warmup=2, total=8),
            TrainerConfig(n_steps=2, ckpt_every=2, ckpt_dir="/tmp/t_api"),
            mesh=one_device_mesh(),
        )
        plans = tr.plan_hot_kernels()
        assert set(plans) == {"rmsnorm", "xent"}
        assert plans["xent"].mesh == self.MESH_KEY
        assert self._mesh_keys_for("rmsnorm") and self._mesh_keys_for("xent")

    def test_trainer_inherits_ambient_plan_context_at_use_time(self):
        """The launcher pattern: Trainer constructed *before* plan_context
        is entered must still plan under the launcher's mesh (the mesh is
        resolved when plans are made, not captured at __init__)."""
        from repro.data.pipeline import DataConfig
        from repro.optim import adamw
        from repro.optim.schedules import make_schedule
        from repro.runtime.trainer import Trainer, TrainerConfig

        clear_plan_cache()
        tr = Trainer(
            self._tiny_model(),
            DataConfig(vocab_size=32, seq_len=16, global_batch=4, d_model=64),
            adamw.AdamWConfig(master=False),
            make_schedule("cosine", peak=3e-3, warmup=2, total=8),
            TrainerConfig(n_steps=2, ckpt_every=2, ckpt_dir="/tmp/t_api"),
        )
        with api.plan_context(mesh=one_device_mesh()):
            plans = tr.plan_hot_kernels()
        assert plans["xent"].mesh == self.MESH_KEY
        assert self._mesh_keys_for("xent")

    def test_jitted_drivers_replan_under_new_context(self):
        """jacobi_sweeps/lbm_run resolve their plan *outside* jit, so a new
        plan_context is not masked by a stale trace."""
        from repro.kernels.jacobi import ops as jops

        g = rnd((20, 20), jnp.float32, 0)
        jops.jacobi_sweeps(g, 2)  # trace + plan under the default context
        clear_plan_cache()
        with api.plan_context(mesh=one_device_mesh()):
            jops.jacobi_sweeps(g, 2)
        assert self._mesh_keys_for("jacobi")

    def test_batcher_asks_registry_under_mesh_and_packs_slots(self):
        from repro.serving import ContinuousBatcher, Request

        clear_plan_cache()
        model = self._tiny_model()
        b = ContinuousBatcher(model, None, slots=3, max_len=8,
                              mesh=one_device_mesh())
        assert b.decode_plan is not None
        assert b.decode_plan.mesh == self.MESH_KEY
        assert self._mesh_keys_for("rmsnorm")
        # slots packed to the planned sublane tile
        assert b.padded_slots == b.decode_plan.rows
        assert b.padded_slots >= b.slots
        assert b.padded_slots % b.decode_plan.sublanes == 0
        # cache batch axis follows the physical slot count
        leaf = jax.tree_util.tree_leaves(b.cache)[0]
        assert b.padded_slots in leaf.shape
        # admission records decode/prefill plans per batch shape
        b.submit([Request(rid=0, prompt=[1, 2], max_new_tokens=2),
                  Request(rid=1, prompt=[3], max_new_tokens=2)])
        assert ("prefill", 2) in b.plans
        assert b.plans[("prefill", 2)].mesh == self.MESH_KEY
        # once a slot moves to decode, the next tick records the decode
        # plan for that batch shape too (no new admission required)
        b.slot_req[0].fed = len(b.slot_req[0].prompt)
        b._note_admitted_plans()
        assert ("decode", 1) in b.plans
        assert b.plans[("decode", 1)].mesh == self.MESH_KEY

    def test_batcher_constructed_before_context_plans_under_mesh(self):
        """Construct-then-context: admitted-batch plans resolve the ambient
        mesh at call time, not a stale None snapshot from __init__."""
        from repro.serving import ContinuousBatcher, Request

        clear_plan_cache()
        b = ContinuousBatcher(self._tiny_model(), None, slots=2, max_len=8)
        with api.plan_context(mesh=one_device_mesh()):
            b.submit([Request(rid=0, prompt=[1, 2], max_new_tokens=2)])
        assert b.plans[("prefill", 1)].mesh == self.MESH_KEY


class TestDeprecatedShims:
    def test_shims_importable_and_forward(self):
        from repro.kernels.jacobi import ops as jops
        from repro.kernels.jacobi import ref as jref
        from repro.kernels.lbm.ops import lbm_step
        from repro.kernels.rmsnorm.ops import gated_rmsnorm, rmsnorm
        from repro.kernels.stream.ops import (
            stream_add, stream_copy, stream_scale, stream_triad,
        )
        from repro.kernels.triad.ops import vector_triad
        from repro.kernels.xent.ops import xent_mean

        for fn, kernel in [
            (stream_copy, "stream.copy"), (stream_scale, "stream.scale"),
            (stream_add, "stream.add"), (stream_triad, "stream.triad"),
            (vector_triad, "triad"), (jops.jacobi_step, "jacobi"),
            (lbm_step, "lbm.ivjk"), (rmsnorm, "rmsnorm"),
            (gated_rmsnorm, "rmsnorm.gated"), (xent_mean, "xent"),
        ]:
            assert callable(fn)
            assert fn.__deprecated_for__ == kernel

        g = rnd((20, 20), jnp.float32, 0)
        with pytest.warns(FutureWarning, match="jacobi_step"):
            out = jops.jacobi_step(g)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(jref.jacobi_step(g)),
                                   rtol=1e-5, atol=1e-6)

    def test_shim_equals_launch(self):
        from repro.kernels.stream.ops import stream_triad

        b, c = rnd((777,), jnp.float32, 0), rnd((777,), jnp.float32, 1)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", FutureWarning)
            shim = stream_triad(b, c, 3.0)
        np.testing.assert_array_equal(
            np.asarray(shim),
            np.asarray(api.launch("stream.triad", b, c, s=3.0)))

    # One warning assertion per family: every shim must name its
    # api.launch replacement (pytest.ini promotes the FutureWarning to an
    # error, so an un-captured call would fail the suite -- pytest.warns
    # both captures and asserts).

    def test_shim_warns_stream(self):
        from repro.kernels.stream.ops import stream_copy

        a = rnd((333,), jnp.float32, 0)
        with pytest.warns(FutureWarning,
                          match=r"use repro\.api\.launch\('stream\.copy'"):
            out = stream_copy(a)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(a))

    def test_shim_warns_triad(self):
        from repro.kernels.triad.ops import vector_triad

        b, c, d = (rnd((257,), jnp.float32, s) for s in range(3))
        with pytest.warns(FutureWarning,
                          match=r"use repro\.api\.launch\('triad'"):
            out = vector_triad(b, c, d)
        np.testing.assert_allclose(np.asarray(out), np.asarray(b + c * d),
                                   rtol=1e-5, atol=1e-6)

    def test_shim_warns_jacobi(self):
        from repro.kernels.jacobi import ops as jops

        g = rnd((16, 16), jnp.float32, 0)
        with pytest.warns(FutureWarning,
                          match=r"use repro\.api\.launch\('jacobi'"):
            jops.jacobi_step(g)

    def test_shim_warns_lbm_resolver(self):
        # lbm_step's layout= argument picks the replacement kernel name the
        # warning advertises -- the resolver path of deprecated_wrapper.
        from repro.kernels.lbm.ops import lbm_step

        f = rnd((19, 4, 4, 4), jnp.float32, 0)
        with pytest.warns(FutureWarning,
                          match=r"use repro\.api\.launch\('lbm\.soa'"):
            lbm_step(f, 1.2, layout="soa")
        with pytest.warns(FutureWarning,
                          match=r"use repro\.api\.launch\('lbm\.ivjk'"):
            lbm_step(f, 1.2)

    def test_shim_warns_rmsnorm(self):
        from repro.kernels.rmsnorm.ops import rmsnorm

        x = rnd((16, 128), jnp.float32, 0)
        scale = rnd((128,), jnp.float32, 1)
        with pytest.warns(FutureWarning,
                          match=r"use repro\.api\.launch\('rmsnorm'"):
            rmsnorm(x, scale)

    def test_shim_warns_xent(self):
        from repro.kernels.xent.ops import xent_mean

        logits = rnd((8, 256), jnp.float32, 0)
        labels = jnp.zeros((8,), jnp.int32)
        with pytest.warns(FutureWarning,
                          match=r"use repro\.api\.launch\('xent'"):
            xent_mean(logits, labels)

    def test_shim_warning_promotes_to_error(self):
        # The pytest.ini filter turns the migration signal into a hard
        # failure; reproduce that promotion explicitly so the filter regex
        # and the message prefix cannot drift apart silently.
        from repro.kernels.stream.ops import stream_copy

        a = rnd((64,), jnp.float32, 0)
        with warnings.catch_warnings():
            warnings.simplefilter("error", FutureWarning)
            with pytest.raises(FutureWarning,
                               match=r"is deprecated; use repro\.api\.launch"):
                stream_copy(a)


class TestExplain:
    def test_explain_any_registered_kernel(self):
        for name, shape, dtype in [
            ("stream.triad", (8191,), "float32"),
            ("lbm.ivjk", (19, 8, 8, 8), "float32"),
            ("rmsnorm", (64, 129), "bfloat16"),
        ]:
            txt = api.explain(name, shape, dtype)
            assert f"plan[{name}]" in txt
            assert "predicted balance" in txt

    def test_explain_reflects_context(self):
        plain = api.plan_for("rmsnorm", (64, 129), "float32")
        with api.plan_context(mesh={"model": 4}):
            meshed = api.plan_for("rmsnorm", (64, 129), "float32")
        assert meshed.width % (4 * 128) == 0
        assert meshed.width > plain.width
