"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode).

Kernels launch through ``repro.api`` (the deprecated per-family shims warn
-- as errors inside this suite -- and stay covered in test_api only); the
experiment variants that are not 1:1 launches (phased/segmented triad,
multi-sweep jacobi, lbm_run) keep their own entry points."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core.segmented import SegmentedArray
from repro.kernels.jacobi import ops as jops
from repro.kernels.jacobi import ref as jref
from repro.kernels.lbm import ops as lops
from repro.kernels.lbm import ref as lref
from repro.kernels.stream import ops as sops
from repro.kernels.stream import ref as sref
from repro.kernels.triad import ops as tops
from repro.kernels.triad import ref as tref

SIZES = [1, 7, 128, 1000, 8192, 20000]
DTYPES = [jnp.float32, jnp.bfloat16]


def rnd(shape, dtype, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
    return x.astype(dtype)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=1e-5, atol=1e-6
    )


class TestStream:
    @pytest.mark.parametrize("n", SIZES)
    @pytest.mark.parametrize("dtype", DTYPES)
    def test_triad(self, n, dtype):
        b, c = rnd((n,), dtype, 0), rnd((n,), dtype, 1)
        np.testing.assert_allclose(
            np.asarray(api.launch("stream.triad", b, c, s=3.0), np.float32),
            np.asarray(sref.triad(b, c, 3.0), np.float32), **tol(dtype)
        )

    @pytest.mark.parametrize("n", [128, 5000])
    def test_copy_scale_add(self, n):
        a, b = rnd((n,), jnp.float32, 0), rnd((n,), jnp.float32, 1)
        np.testing.assert_allclose(np.asarray(api.launch("stream.copy", a)),
                                   np.asarray(sref.copy(a)))
        np.testing.assert_allclose(np.asarray(api.launch("stream.scale", a, s=2.0)),
                                   np.asarray(sref.scale(a, 2.0)), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(api.launch("stream.add", a, b)),
                                   np.asarray(sref.add(a, b)), rtol=1e-6)

    def test_bytes_accounting(self):
        """Paper SS2.1: triad RFO traffic is 4/3 of reported."""
        assert sops.bytes_moved_rfo("triad", 100) / sops.bytes_moved(
            "triad", 100
        ) == pytest.approx(4 / 3)


class TestVectorTriad:
    @pytest.mark.parametrize("n", SIZES)
    @pytest.mark.parametrize("dtype", DTYPES)
    def test_aligned(self, n, dtype):
        b, c, d = (rnd((n,), dtype, i) for i in range(3))
        np.testing.assert_allclose(
            np.asarray(api.launch("triad", b, c, d), np.float32),
            np.asarray(tref.triad(b, c, d), np.float32), **tol(dtype)
        )

    @pytest.mark.parametrize("phases", [(0, 0, 0), (0, 32, 64), (16, 48, 80)])
    def test_phased_layouts_preserve_semantics(self, phases):
        """The paper's offsets change *performance*, never results."""
        n = 3000
        b, c, d = (rnd((n,), jnp.float32, i) for i in range(3))
        np.testing.assert_allclose(
            np.asarray(tops.vector_triad_phased(b, c, d, phases=phases)),
            np.asarray(tref.triad(b, c, d)), rtol=1e-6, atol=1e-6
        )

    def test_segmented(self):
        n = 1500
        b, c, d = (rnd((n,), jnp.float32, i) for i in range(3))
        mk = lambda v: SegmentedArray.from_flat(v, 4, align=128, shift=16)
        out = tops.vector_triad_segmented(mk(jnp.zeros(n)), mk(b), mk(c), mk(d))
        np.testing.assert_allclose(np.asarray(out.to_flat()),
                                   np.asarray(tref.triad(b, c, d)),
                                   rtol=1e-6, atol=1e-6)


class TestJacobi:
    @pytest.mark.parametrize("shape", [(16, 16), (130, 260), (257, 129),
                                       (64, 1000)])
    def test_one_sweep(self, shape):
        g = rnd(shape, jnp.float32, 0)
        np.testing.assert_allclose(np.asarray(api.launch("jacobi", g)),
                                   np.asarray(jref.jacobi_step(g)),
                                   rtol=1e-5, atol=1e-6)

    def test_multi_sweep(self):
        g = rnd((66, 130), jnp.float32, 1)
        np.testing.assert_allclose(np.asarray(jops.jacobi_sweeps(g, 7)),
                                   np.asarray(jref.jacobi_sweeps(g, 7)),
                                   rtol=1e-4, atol=1e-5)

    def test_boundary_preserved(self):
        g = rnd((40, 40), jnp.float32, 2)
        out = np.asarray(api.launch("jacobi", g))
        np.testing.assert_array_equal(out[0], np.asarray(g)[0])
        np.testing.assert_array_equal(out[-1], np.asarray(g)[-1])
        np.testing.assert_array_equal(out[:, 0], np.asarray(g)[:, 0])
        np.testing.assert_array_equal(out[:, -1], np.asarray(g)[:, -1])

    def test_balance_numbers(self):
        """Paper SS2.3: 4 B/flop without RFO, 6 with."""
        n = 100
        assert jops.jacobi_bytes(n, n, rfo=False) / jops.jacobi_flops(n, n) \
            == pytest.approx(4.0)
        assert jops.jacobi_bytes(n, n, rfo=True) / jops.jacobi_flops(n, n) \
            == pytest.approx(6.0)


class TestLBM:
    @pytest.mark.parametrize("layout", ["soa", "ivjk"])
    @pytest.mark.parametrize("n", [8, 16])
    def test_step_matches_ref(self, layout, n):
        f = lops.init_equilibrium(n, jnp.float32)
        got = api.launch(f"lbm.{layout}", f, omega=1.2)
        want = lref.lbm_step(f, 1.2)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=1e-7)

    def test_layouts_agree_with_each_other(self):
        f = lops.init_equilibrium(12, jnp.float32)
        a = lops.lbm_run(f, 1.0, 3, layout="soa")
        b = lops.lbm_run(f, 1.0, 3, layout="ivjk")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=1e-6)

    def test_mass_and_momentum_conserved(self):
        f = lops.init_equilibrium(16, jnp.float32)
        f5 = lops.lbm_run(f, 1.2, 5, layout="ivjk")
        m0, m5 = float(jnp.sum(f)), float(jnp.sum(f5))
        assert abs(m5 - m0) / m0 < 1e-3
        c = jnp.asarray(lref.C, jnp.float32)
        mom = lambda g: np.asarray(
            jnp.tensordot(c.T, g.reshape(19, -1), axes=(1, 0)).sum(axis=1)
        )
        np.testing.assert_allclose(mom(f5), mom(f), atol=m0 * 2e-3)

    def test_equilibrium_is_fixed_point(self):
        rho = jnp.ones((8, 8, 8))
        u = jnp.zeros((3, 8, 8, 8))
        f = lref.equilibrium(rho, u)
        f1 = api.launch("lbm.ivjk", f, omega=1.7)
        np.testing.assert_allclose(np.asarray(f1), np.asarray(f), atol=1e-6)

    def test_masked_cells_hold(self):
        f = lops.init_equilibrium(12, jnp.float32)
        mask = jnp.ones((12, 12, 12), bool).at[3:6, 3:6, 3:6].set(False)
        out = api.launch("lbm.soa", f, omega=1.2, mask=mask)
        np.testing.assert_array_equal(
            np.asarray(out[:, 3:6, 3:6, 3:6]), np.asarray(f[:, 3:6, 3:6, 3:6])
        )

    def test_layout_scores_reproduce_fig7(self):
        """Generic N: ivjk balanced; N % 64 == 0: both ruinous (paper)."""
        best, s = lops.layout_balance_scores(n=100)
        assert best == "ivjk" and s["ivjk"] > 3 * s["soa"]
        _, s64 = lops.layout_balance_scores(n=64)
        assert s64["ivjk"] == pytest.approx(0.25)
        assert s64["soa"] == pytest.approx(0.25)

    def test_site_bytes_is_456(self):
        assert lops.site_bytes() == 456  # paper SS2.4


def xent_plan_with_tiles(t, v, bt, bv):
    """An explicit (bt, bv) online-softmax tile as a pinned plan -- the
    API-native form of the old shim's bt=/bv= overrides."""
    import dataclasses

    from repro import api
    from repro.core.layout import round_up

    base = api.plan_for("xent", (t, v), jnp.float32)
    return dataclasses.replace(
        base, padded_shape=(round_up(t, bt), round_up(v, bv)),
        block_shape=(bt, bv))


class TestXent:
    """Tiled cross-entropy kernel (beyond-paper, SSPerf P0.1 as a kernel)."""

    @pytest.mark.parametrize("t,v,lv,bt,bv", [
        (512, 4096, 4096, 256, 2048),
        (300, 5000, 4777, 64, 1024),   # ragged T + padded vocab masking
        (64, 2048, 2048, 64, 512),
        (128, 1111, 1000, 64, 512),    # ragged vocab + logical < padded
    ])
    def test_matches_ref(self, t, v, lv, bt, bv):
        from repro.kernels.xent import ref as xref

        logits = jax.random.normal(jax.random.PRNGKey(0), (t, v)) * 3
        labels = jax.random.randint(jax.random.PRNGKey(1), (t,), 0, lv)
        got = float(api.launch("xent", logits, labels, logical_v=lv,
                               plan=xent_plan_with_tiles(t, v, bt, bv)))
        want = float(xref.xent(logits, labels, logical_v=lv).mean())
        assert abs(got - want) < 1e-4

    def test_extreme_logits_stable(self):
        from repro.kernels.xent import ref as xref

        logits = jnp.full((64, 1024), 80.0).at[:, 7].set(90.0)
        labels = jnp.full((64,), 7, jnp.int32)
        got = float(api.launch("xent", logits, labels,
                               plan=xent_plan_with_tiles(64, 1024, 64, 512)))
        want = float(xref.xent(logits, labels, logical_v=1024).mean())
        assert abs(got - want) < 1e-4
        assert np.isfinite(got)


class TestRMSNorm:
    """Fused RMSNorm kernel (plain + gated) vs jnp oracle."""

    @pytest.mark.parametrize("shape", [(4, 8, 64), (2, 100), (16, 2304)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_plain(self, shape, dtype):
        from repro.kernels.rmsnorm import ref as rref

        x = rnd(shape, dtype, 0)
        s = rnd(shape[-1:], jnp.float32, 1).astype(dtype) + 1.0
        got = api.launch("rmsnorm", x, s)
        want = rref.rmsnorm(x, s)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), **tol(dtype))

    @pytest.mark.parametrize("shape", [(3, 7, 96), (8, 512)])
    def test_gated(self, shape):
        from repro.kernels.rmsnorm import ref as rref

        x, z = rnd(shape, jnp.float32, 0), rnd(shape, jnp.float32, 1)
        s = jnp.ones(shape[-1:])
        np.testing.assert_allclose(
            np.asarray(api.launch("rmsnorm.gated", x, z, s)),
            np.asarray(rref.gated_rmsnorm(x, z, s)), rtol=1e-5, atol=1e-6)

    def test_matches_model_norm_layer(self, monkeypatch):
        """The kernel agrees with blocks.apply_norm's *jnp* branch (the
        multi-device fallback).  On one device apply_norm routes through
        this very kernel, so the fallback is pinned explicitly -- otherwise
        the comparison is kernel vs itself and the jnp math loses its only
        parity coverage."""
        from repro.models import blocks
        from repro.models.config import ModelConfig

        monkeypatch.setattr(blocks, "use_fused_kernels", lambda: False)
        cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=96,
                          n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64,
                          dtype="float32")
        x = rnd((2, 5, 96), jnp.float32, 0)
        p = {"scale": rnd((96,), jnp.float32, 1) + 1.0}
        np.testing.assert_allclose(
            np.asarray(api.launch("rmsnorm", x, p["scale"], eps=cfg.norm_eps)),
            np.asarray(blocks.apply_norm(p, x, cfg)), rtol=1e-5, atol=1e-6)
