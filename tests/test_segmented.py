"""SegmentedArray: roundtrip, seg_map correctness, waste accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings
from _hypothesis_compat import strategies as st

from repro.core.segmented import SegmentedArray, seg_map, seg_triad, split_lengths


class TestSplitLengths:
    @given(n=st.integers(0, 10 ** 6), t=st.integers(1, 64))
    @settings(max_examples=100, deadline=None)
    def test_paper_schedule(self, n, t):
        """floor(N/t)+1 / floor(N/t), in that order (paper SS2.2)."""
        ls = split_lengths(n, t)
        assert sum(ls) == n
        assert len(ls) == t
        assert max(ls) - min(ls) <= 1
        assert sorted(ls, reverse=True) == ls


class TestRoundtrip:
    @given(
        n=st.integers(1, 2000),
        segs=st.integers(1, 9),
        shift=st.integers(0, 64),
        align=st.sampled_from([1, 8, 64, 128]),
    )
    @settings(max_examples=40, deadline=None)
    def test_to_flat_inverts_from_flat(self, n, segs, shift, align):
        x = jnp.arange(n, dtype=jnp.float32)
        sa = SegmentedArray.from_flat(x, segs, align=align, shift=shift)
        np.testing.assert_array_equal(np.asarray(sa.to_flat()), np.asarray(x))
        assert sa.logical_size == n
        assert sa.physical_size >= n

    def test_phases_follow_shift(self):
        sa = SegmentedArray.from_flat(jnp.zeros(1000), 4, align=128, shift=16)
        assert sa.phases == (0, 16, 32, 48)


class TestSegMap:
    def test_triad_matches_flat(self):
        n = 777
        b = jnp.linspace(0, 1, n)
        c = jnp.linspace(1, 2, n)
        d = jnp.linspace(2, 3, n)
        mk = lambda v: SegmentedArray.from_flat(v, 5, align=128, shift=32)
        out = seg_triad(mk(jnp.zeros(n)), mk(b), mk(c), mk(d))
        np.testing.assert_allclose(
            np.asarray(out.to_flat()), np.asarray(b + c * d), rtol=1e-6
        )

    def test_jit_compatible(self):
        """Pytree registration: seg ops trace under jit (Fig. 5 overhead
        claim depends on this)."""
        n = 500
        mk = lambda v: SegmentedArray.from_flat(v, 3, align=64, shift=8)
        fn = jax.jit(seg_triad)
        out = fn(mk(jnp.zeros(n)), mk(jnp.ones(n)), mk(jnp.full(n, 2.0)),
                 mk(jnp.full(n, 3.0)))
        np.testing.assert_allclose(np.asarray(out.to_flat()), 7.0)

    def test_length_mismatch_raises(self):
        a = SegmentedArray.from_flat(jnp.zeros(10), 2)
        b = SegmentedArray.from_flat(jnp.zeros(11), 2)
        with pytest.raises(ValueError):
            seg_map(lambda x: x, a, b)
