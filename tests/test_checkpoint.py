"""CheckpointManager: atomic step directories, async writer, restore paths.

Covers the save/restore round-trip the fault-tolerant trainer and the
elastic re-meshing policy rely on (``runtime/trainer.init_or_restore``,
``runtime/elastic`` step 3: "restore the latest checkpoint and resume"):
newest-complete selection, torn-write tolerance, retention GC, the
ml_dtypes widening round-trip, and restore into a re-laid-out ``like``
(new dtype/shape after a mesh change).
"""
from __future__ import annotations

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _state(scale: float = 1.0) -> dict:
    return {
        "params": {
            "w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4) * scale,
            "b": jnp.ones((4,), jnp.float32) * scale,
        },
        "opt": {"m": jnp.zeros((3, 4), jnp.float32),
                "step": jnp.asarray(7, jnp.int32)},
    }


def _assert_trees_equal(a, b) -> None:
    import jax

    flat_a = jax.tree_util.tree_leaves(a)
    flat_b = jax.tree_util.tree_leaves(b)
    assert len(flat_a) == len(flat_b)
    for la, lb in zip(flat_a, flat_b):
        assert la.dtype == lb.dtype
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


class TestRoundTrip:
    def test_sync_save_restore(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_write=False)
        state = _state()
        mgr.save(3, state)
        got = mgr.restore(3, _state(scale=0.0))
        _assert_trees_equal(got, state)

    def test_async_save_then_wait(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_write=True)
        state = _state(scale=2.0)
        mgr.save(1, state)
        mgr.wait()
        assert mgr.all_steps() == [1]
        _assert_trees_equal(mgr.restore(1, _state(scale=0.0)), state)

    def test_restore_waits_for_inflight_write(self, tmp_path):
        # restore() must see the step save() just scheduled, without an
        # explicit wait() -- the trainer's failure path depends on this.
        mgr = CheckpointManager(str(tmp_path), async_write=True)
        state = _state(scale=3.0)
        mgr.save(4, state)
        got = mgr.restore_latest(_state(scale=0.0))
        assert got is not None
        step, tree = got
        assert step == 4
        _assert_trees_equal(tree, state)

    def test_meta_json_round_trip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_write=False)
        mgr.save(2, _state(), meta={"loss": 1.25})
        with open(tmp_path / "step_00000002" / "meta.json") as f:
            meta = json.load(f)
        assert meta == {"step": 2, "loss": 1.25}

    def test_resave_same_step_overwrites_atomically(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_write=False)
        mgr.save(1, _state(scale=1.0))
        mgr.save(1, _state(scale=5.0))
        _assert_trees_equal(mgr.restore(1, _state(scale=0.0)),
                            _state(scale=5.0))


class TestSelectionAndRetention:
    def test_restore_latest_picks_newest_complete(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_write=False)
        for step, scale in ((1, 1.0), (5, 5.0), (3, 3.0)):
            mgr.save(step, _state(scale=scale))
        step, tree = mgr.restore_latest(_state(scale=0.0))
        assert step == 5
        _assert_trees_equal(tree, _state(scale=5.0))

    def test_incomplete_step_is_invisible(self, tmp_path):
        # A crash between the shard write and meta.json leaves a directory
        # without the completion marker: it must never be restored.
        mgr = CheckpointManager(str(tmp_path), async_write=False)
        mgr.save(2, _state(scale=2.0))
        torn = tmp_path / "step_00000009"
        torn.mkdir()
        np.savez(torn / "shard_0.npz", x=np.zeros(1))   # no meta.json
        assert mgr.all_steps() == [2]
        assert mgr.latest_step() == 2

    def test_empty_directory_restores_nothing(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_write=False)
        assert mgr.latest_step() is None
        assert mgr.restore_latest(_state()) is None

    def test_gc_keeps_newest_k(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
        for step in (1, 2, 3, 4):
            mgr.save(step, _state(scale=float(step)))
        assert mgr.all_steps() == [3, 4]
        assert not os.path.isdir(tmp_path / "step_00000001")
        _assert_trees_equal(mgr.restore(3, _state(scale=0.0)),
                            _state(scale=3.0))


class TestDtypeAndRelayout:
    def test_bf16_widens_to_f32_and_recasts_on_restore(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_write=False)
        state = {"w": jnp.asarray([1.0, 2.5, -3.0], jnp.bfloat16)}
        mgr.save(1, state)
        shard = np.load(tmp_path / "step_00000001" / "shard_0.npz")
        assert shard["w"].dtype == np.float32       # stored widened...
        got = mgr.restore(1, {"w": jnp.zeros(3, jnp.bfloat16)})
        assert got["w"].dtype == jnp.bfloat16       # ...restored re-cast
        np.testing.assert_array_equal(
            np.asarray(got["w"], np.float32), [1.0, 2.5, -3.0])

    def test_restore_into_differently_typed_like(self, tmp_path):
        # The elastic resume path restores into a freshly initialized state
        # whose dtypes/shapes reflect the *new* mesh: restore adopts the
        # template's dtype and shape, not the checkpoint's.
        mgr = CheckpointManager(str(tmp_path), async_write=False)
        mgr.save(1, {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)})
        got = mgr.restore(1, {"w": jnp.zeros((3, 2), jnp.bfloat16)})
        assert got["w"].shape == (3, 2)
        assert got["w"].dtype == jnp.bfloat16
        np.testing.assert_array_equal(
            np.asarray(got["w"], np.float32).ravel(), np.arange(6))

    def test_restore_missing_leaf_fails_loudly(self, tmp_path):
        # A template with a leaf the checkpoint never saved must raise,
        # not silently zero-fill: an elastic resume with a mismatched
        # parameter tree is a bug, not a degraded mode.
        mgr = CheckpointManager(str(tmp_path), async_write=False)
        mgr.save(1, {"w": jnp.ones(2)})
        with pytest.raises(KeyError):
            mgr.restore(1, {"w": jnp.zeros(2), "extra": jnp.zeros(1)})


class TestAsyncFailureSurfacing:
    """Satellite: a failure on the async writer thread must surface on the
    caller thread -- a silently lost checkpoint only shows up much later
    as an unexplainably old restore."""

    def _failing_mgr(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_write=True)

        def boom(step, tmp):
            raise OSError(f"disk full writing step {step}")

        mgr.fault_hook = boom
        return mgr

    def test_wait_reraises_writer_failure(self, tmp_path):
        mgr = self._failing_mgr(tmp_path)
        mgr.save(2, _state())
        with pytest.raises(RuntimeError, match="async checkpoint write "
                                               "failed"):
            mgr.wait()
        # The error is consumed: the manager is usable again.
        mgr.fault_hook = None
        mgr.save(4, _state())
        mgr.wait()
        assert mgr.all_steps() == [4]

    def test_next_save_reraises_writer_failure(self, tmp_path):
        mgr = self._failing_mgr(tmp_path)
        mgr.save(2, _state())
        with pytest.raises(RuntimeError, match="async checkpoint write"):
            mgr.save(4, _state())

    def test_restore_latest_reraises_writer_failure(self, tmp_path):
        mgr = self._failing_mgr(tmp_path)
        mgr.save(2, _state())
        with pytest.raises(RuntimeError, match="async checkpoint write"):
            mgr.restore_latest(_state())

    def test_failed_write_leaves_no_visible_step(self, tmp_path):
        mgr = self._failing_mgr(tmp_path)
        mgr.save(2, _state())
        with pytest.raises(RuntimeError):
            mgr.wait()
        assert mgr.all_steps() == []            # torn tmp is invisible
        assert any(".tmp" in p.name for p in tmp_path.iterdir())

    def test_sync_write_failure_raises_inline(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_write=False)

        def boom(step, tmp):
            raise OSError("no space")

        mgr.fault_hook = boom
        with pytest.raises(OSError):
            mgr.save(2, _state())


class TestRestoreAfterReshape:
    """Satellite: the edge cases of the elastic resume path -- restoring
    the newest *complete* checkpoint onto a differently shaped mesh."""

    def test_torn_tmp_next_to_complete_older_step(self, tmp_path):
        """A crash mid-write of step 6 leaves step_00000006.tmp0 on disk;
        restore_latest must pick the complete step 4, not trip on the
        torn directory."""
        mgr = CheckpointManager(str(tmp_path), async_write=False)
        mgr.save(4, _state(scale=4.0))
        torn = tmp_path / "step_00000006.tmp0"
        torn.mkdir()
        np.savez(torn / "shard_0.npz", **{"params/w": np.zeros((3, 4))})
        (torn / "meta.json").write_text('{"step": 6}')
        assert mgr.all_steps() == [4]
        step, tree = mgr.restore_latest(_state(scale=0.0))
        assert step == 4
        _assert_trees_equal(tree, _state(scale=4.0))

    def test_restore_onto_different_dp_shape(self, tmp_path):
        """A dp=4-sharded optimizer accumulator saved as (4, 8) restores
        into a dp=2 layout's (2, 16) template: same payload, new
        partitioning (restore adopts the template's shape)."""
        mgr = CheckpointManager(str(tmp_path), async_write=False)
        payload = np.arange(32, dtype=np.float32)
        mgr.save(1, {"acc": jnp.asarray(payload.reshape(4, 8))})
        got = mgr.restore(1, {"acc": jnp.zeros((2, 16), jnp.float32)})
        assert got["acc"].shape == (2, 16)
        np.testing.assert_array_equal(np.asarray(got["acc"]).ravel(),
                                      payload)

    def test_bf16_round_trip_through_resharded_restore(self, tmp_path):
        """bf16 params widen to f32 on disk and re-cast to bf16 on
        restore even when the template's shape changed -- the combined
        dtype+shape path of an elastic resume."""
        mgr = CheckpointManager(str(tmp_path), async_write=False)
        vals = jnp.asarray(np.linspace(-2, 2, 24), jnp.bfloat16)
        mgr.save(1, {"w": vals.reshape(4, 6)})
        got = mgr.restore(1, {"w": jnp.zeros((2, 12), jnp.bfloat16)})
        assert got["w"].dtype == jnp.bfloat16
        assert got["w"].shape == (2, 12)
        np.testing.assert_array_equal(
            np.asarray(got["w"].ravel(), np.float32),
            np.asarray(vals, np.float32))


class TestTrainerResumePath:
    def test_init_or_restore_resumes_from_latest(self, tmp_path):
        """The trainer-side consumer: a state saved by one Trainer instance
        is picked up by a fresh one (same config), exactly the process
        restart the elastic policy performs after a mesh shrink."""
        import jax

        from repro.parallel import steps as steps_lib
        from tests.test_obs import _tiny_trainer

        key = jax.random.PRNGKey(0)
        tr = _tiny_trainer(str(tmp_path))
        state = steps_lib.init_train_state(tr.model, tr.opt_cfg, key)
        tr.ckpt.save(7, state)
        tr.ckpt.wait()

        tr2 = _tiny_trainer(str(tmp_path))          # fresh process stand-in
        step, restored = tr2.init_or_restore(key)
        assert step == 7
        _assert_trees_equal(restored, state)
