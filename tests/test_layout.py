"""LayoutPolicy / padding math + properties."""
import pytest
from _hypothesis_compat import given, settings
from _hypothesis_compat import strategies as st

from repro.core.layout import (
    LANES, SUBLANES, LayoutPolicy, choose_block_shape, round_up,
)


class TestRoundUp:
    def test_basic(self):
        assert round_up(0, 8) == 0
        assert round_up(1, 8) == 8
        assert round_up(8, 8) == 8
        assert round_up(129, 128) == 256

    @given(n=st.integers(0, 10 ** 9), m=st.integers(1, 10 ** 6))
    @settings(max_examples=100, deadline=None)
    def test_properties(self, n, m):
        r = round_up(n, m)
        assert r >= n
        assert r % m == 0
        assert r - n < m


class TestLayoutPolicy:
    def test_paper_assigned_cases(self):
        """The assigned-pool misfits the policy must fix (DESIGN.md SS5)."""
        pol = LayoutPolicy(tp=16)
        assert pol.pad_vocab(122753).physical == 122880        # minicpm
        assert pol.pad_minor(5760, sharded=True).physical == 6144   # minicpm ff
        assert pol.pad_count(14, sharded=True).physical == 16   # qwen2 heads
        # qwen3-14b: 17408/16 = 1088 is not lane-aligned -> pad to 18432
        assert pol.pad_minor(17408, sharded=True).physical == 18432
        assert pol.pad_minor(8192, sharded=True).physical == 8192  # zamba ff ok

    def test_plain_mode_is_identity(self):
        pol = LayoutPolicy(tp=16, pad_to_mesh=False)
        assert pol.pad_vocab(122753).physical == 122753
        assert pol.pad_count(14, sharded=True).physical == 14

    @given(n=st.integers(1, 10 ** 6), tp=st.sampled_from([1, 2, 4, 8, 16]))
    @settings(max_examples=100, deadline=None)
    def test_minor_sharded_invariants(self, n, tp):
        d = LayoutPolicy(tp=tp).pad_minor(n, sharded=True)
        assert d.physical % (tp * LANES) == 0
        assert (d.physical // tp) % LANES == 0  # every shard lane-aligned
        assert 0 <= d.pad < tp * LANES

    def test_waste_accounting(self):
        d = LayoutPolicy(tp=16).pad_count(14, sharded=True)
        assert d.waste == pytest.approx(2 / 16)


class TestBlockShape:
    def test_alignment(self):
        r, c = choose_block_shape(32768, 2048)
        assert r % SUBLANES == 0
        assert c % LANES == 0

    @given(rows=st.integers(8, 10 ** 5), cols=st.integers(128, 8192))
    @settings(max_examples=50, deadline=None)
    def test_vmem_budget(self, rows, cols):
        r, c = choose_block_shape(rows, cols)
        assert r % SUBLANES == 0 and c % LANES == 0
        assert r * c * 4 * 3 <= 64 * 1024 * 1024  # generous sanity bound
