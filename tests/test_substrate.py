"""Substrate tests: optimizer, schedules, compression, data, checkpoint,
trainer fault tolerance, elastic planning, skewed placement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings
from _hypothesis_compat import strategies as st

from repro.core import sharding_skew as skew
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, make_batch
from repro.models import build_model
from repro.models.config import ModelConfig
from repro.optim import adamw, compress
from repro.optim.schedules import make_schedule, warmup_cosine, wsd
from repro.runtime import elastic


class TestAdamW:
    def test_converges_on_quadratic(self):
        params = {"w": jnp.array([5.0, -3.0]), "perm": jnp.arange(2)}
        cfg = adamw.AdamWConfig(weight_decay=0.0, master=True)
        state = adamw.init_state(params, cfg)
        loss = lambda p: jnp.sum(p["w"] ** 2)
        for _ in range(200):
            g = jax.grad(loss, allow_int=True)(params)
            params, state, _ = adamw.apply_updates(params, g, state, 0.1, cfg)
        assert float(loss(params)) < 1e-3
        np.testing.assert_array_equal(np.asarray(params["perm"]), [0, 1])

    def test_clipping(self):
        params = {"w": jnp.ones(4)}
        cfg = adamw.AdamWConfig(clip_norm=1.0, master=False)
        state = adamw.init_state(params, cfg)
        g = {"w": jnp.full(4, 100.0)}
        _, _, metrics = adamw.apply_updates(params, g, state, 0.1, cfg)
        assert float(metrics["grad_norm"]) == pytest.approx(200.0)

    def test_master_dtype(self):
        params = {"w": jnp.ones(4, jnp.bfloat16)}
        cfg = adamw.AdamWConfig(master=True)
        state = adamw.init_state(params, cfg)
        assert state["master"]["w"].dtype == jnp.float32


class TestSchedules:
    def test_wsd_phases(self):
        f = lambda s: float(wsd(s, peak=1.0, warmup=10, total=100))
        assert f(0) == 0.0
        assert f(5) == pytest.approx(0.5)
        assert f(50) == pytest.approx(1.0)     # stable plateau
        assert f(95) < 1.0                      # decay phase
        assert f(100) == pytest.approx(0.01, rel=0.2)

    def test_cosine_monotone_after_warmup(self):
        f = lambda s: float(warmup_cosine(s, peak=1.0, warmup=10, total=100))
        vals = [f(s) for s in range(10, 100, 5)]
        assert all(a >= b - 1e-9 for a, b in zip(vals, vals[1:]))

    def test_registry(self):
        assert callable(make_schedule("wsd"))
        assert callable(make_schedule("cosine"))
        with pytest.raises(ValueError):
            make_schedule("nope")


class TestCompression:
    @given(st.integers(0, 10))
    @settings(max_examples=10, deadline=None)
    def test_quantize_roundtrip_error_bounded(self, seed):
        g = jax.random.normal(jax.random.PRNGKey(seed), (256,)) * 3.0
        rec, resid = compress.compress_roundtrip(g)
        scale = float(jnp.max(jnp.abs(g))) / 127.0
        assert float(jnp.max(jnp.abs(resid))) <= scale * 0.5 + 1e-6
        np.testing.assert_allclose(np.asarray(rec + resid), np.asarray(g),
                                   rtol=1e-5, atol=1e-6)

    def test_error_feedback_preserves_mean_signal(self):
        """EF: accumulated quantization error is re-injected, so the running
        sum of reconstructed grads tracks the true sum."""
        key = jax.random.PRNGKey(0)
        ef = jnp.zeros(64)
        true_sum = jnp.zeros(64)
        rec_sum = jnp.zeros(64)
        for i in range(50):
            g = jax.random.normal(jax.random.fold_in(key, i), (64,)) * 0.01
            rec, ef = compress.compress_roundtrip(g + ef)
            true_sum += g
            rec_sum += rec
        # residual never grows beyond one quantization step
        assert float(jnp.max(jnp.abs(true_sum - rec_sum))) <= float(
            jnp.max(jnp.abs(ef))
        ) + 1e-6

    def test_dp_compressed_grads_match_exact(self):
        """shard_map int8 DP reduction approximates the exact gradient."""
        from repro.launch.mesh import make_test_mesh

        mesh = make_test_mesh((1, 1), ("data", "model"))
        params = {"w": jnp.ones((4, 4))}
        batch = {"x": jax.random.normal(jax.random.PRNGKey(0), (8, 4))}
        loss = lambda p, b: jnp.mean((b["x"] @ p["w"]) ** 2)
        exact = jax.grad(loss)(params, batch)
        ef = compress.init_ef(params)
        got, ef2 = compress.dp_compressed_grads(loss, params, batch, ef, mesh)
        scale = float(jnp.max(jnp.abs(exact["w"]))) / 127.0
        np.testing.assert_allclose(np.asarray(got["w"]),
                                   np.asarray(exact["w"]), atol=scale + 1e-6)


class TestData:
    def test_deterministic_across_restart(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=7)
        b1 = make_batch(cfg, step=3)
        b2 = make_batch(cfg, step=3)
        np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                      np.asarray(b2["tokens"]))

    def test_steps_differ(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4)
        b1 = make_batch(cfg, step=0)
        b2 = make_batch(cfg, step=1)
        assert not np.array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))

    def test_labels_are_shifted_tokens(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=2)
        b = make_batch(cfg, step=0)
        assert b["tokens"].shape == b["labels"].shape == (2, 16)


class TestCheckpoint:
    def test_roundtrip_and_latest(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
        state = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.bfloat16),
                 "b": {"c": jnp.float32(3.5)}}
        mgr.save(5, state)
        mgr.save(10, state)
        assert mgr.latest_step() == 10
        restored = mgr.restore(10, state)
        np.testing.assert_array_equal(
            np.asarray(restored["a"], np.float32),
            np.asarray(state["a"], np.float32))
        assert restored["a"].dtype == jnp.bfloat16

    def test_gc_keeps_last_k(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
        for s in (1, 2, 3, 4):
            mgr.save(s, {"x": jnp.zeros(1)})
        assert mgr.all_steps() == [3, 4]

    def test_async_write_then_wait(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3, async_write=True)
        mgr.save(1, {"x": jnp.ones(8)})
        mgr.wait()
        assert mgr.latest_step() == 1


class TestTrainerFaultTolerance:
    def _trainer(self, tmp, n_steps=16):
        from repro.optim.schedules import make_schedule
        from repro.runtime.trainer import Trainer, TrainerConfig

        cfg = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=32,
                          n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64,
                          dtype="float32", remat=False)
        model = build_model(cfg)
        return Trainer(
            model, DataConfig(vocab_size=64, seq_len=16, global_batch=8),
            adamw.AdamWConfig(master=False),
            make_schedule("cosine", peak=3e-3, warmup=2, total=24),
            TrainerConfig(n_steps=n_steps, ckpt_every=4, ckpt_dir=str(tmp)),
        )

    def test_loss_decreases_and_survives_failure(self, tmp_path):
        tr = self._trainer(tmp_path)
        calls = {"armed": True}

        def bomb(step):
            if step == 6 and calls["armed"]:
                calls["armed"] = False
                raise RuntimeError("injected failure")

        ms = tr.train(jax.random.PRNGKey(0), fail_injector=bomb)
        losses = [m["loss"] for m in ms]
        # mean-of-tail vs mean-of-head: robust to per-batch noise
        assert np.mean(losses[-4:]) < np.mean(losses[:4])
        steps = [m["step"] for m in ms]
        assert 6 in steps  # failed step was replayed after restore

    def test_restart_resumes_from_checkpoint(self, tmp_path):
        tr = self._trainer(tmp_path)
        tr.train(jax.random.PRNGKey(0))
        tr2 = self._trainer(tmp_path, n_steps=18)
        step, _ = tr2.init_or_restore(jax.random.PRNGKey(0))
        assert step == 16


class TestElastic:
    def test_plan_mesh_preserves_tp(self):
        plan = elastic.plan_mesh(240, tp=16)
        assert plan.tp == 16 and plan.dp == 15

    def test_plan_mesh_raises_when_impossible(self):
        with pytest.raises(RuntimeError):
            elastic.plan_mesh(8, tp=16)

    @given(n=st.integers(1, 512), dp=st.integers(1, 64))
    @settings(max_examples=50, deadline=None)
    def test_rebalance_static1(self, n, dp):
        chunks = elastic.rebalance_batch(n, dp)
        assert sum(chunks) == n
        assert max(chunks) - min(chunks) <= 1


class TestSkewedPlacement:
    @given(e=st.integers(1, 64), d=st.integers(1, 16),
           layer=st.integers(0, 100))
    @settings(max_examples=50, deadline=None)
    def test_permutation_is_bijection(self, e, d, layer):
        perm = skew.expert_permutation(e, d, layer)
        assert sorted(perm.tolist()) == list(range(e))
        inv = skew.inverse_permutation(perm)
        np.testing.assert_array_equal(perm[inv], np.arange(e))

    def test_skew_beats_naive_for_hot_expert(self):
        """Paper Fig. 2 analogue: a persistent hot expert pins one device
        under naive placement; the per-layer rotation spreads it."""
        load = np.ones(16)
        load[0] = 16.0  # hot expert
        naive, skewed = skew.layer_skew_gain(load, n_devices=8, n_layers=16)
        assert skewed < naive
        assert skewed == pytest.approx(1.0, rel=0.35)


class TestShardedDataPath:
    def test_make_array_from_callback_matches_host_batch(self):
        """The per-host shard assembly path produces the same global batch
        as the single-host path (multi-process correctness, degenerate to
        one device here)."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_test_mesh

        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=3)
        plain = make_batch(cfg, step=5)
        mesh = make_test_mesh((1, 1), ("data", "model"))
        sharded = make_batch(cfg, step=5,
                             sharding=NamedSharding(mesh, P("data")))
        np.testing.assert_array_equal(np.asarray(sharded["tokens"]),
                                      np.asarray(plain["tokens"]))
        np.testing.assert_array_equal(np.asarray(sharded["labels"]),
                                      np.asarray(plain["labels"]))
