"""Observability bus: events, sinks, session semantics, instrumentation.

Covers the subsystem contract (docs/OBS.md):

  * session nesting/inheritance/isolation and thread-locality (the bus
    mirrors ``api.plan_context``);
  * the zero-cost default -- under the NullSink default no sink receives
    a single call from a real ``api.launch`` (counted, not timed);
  * the instrumented seams: plan-cache hit/miss/override provenance,
    SPMD fallback and shadowed-override events, profile drift,
    measured-vs-predicted validation, batcher admission/tick events;
  * the report CLI: aggregation, rendering, exit codes, malformed-line
    tolerance;
  * the ``benchmarks/run.py --json`` machine-readable schema that rides
    along on the same PR.
"""
from __future__ import annotations

import itertools
import json
import logging
import sys
import threading
import types
from pathlib import Path

import numpy as np
import pytest

from repro import api, obs
from repro.obs import bus, events, report
from repro.obs import sinks as sinks_mod

# Unique planning shapes per use: the plan cache is process-global and
# memoized, so a fresh size is the only way to observe a deterministic
# first-plan miss regardless of what other tests planned before us.
_uniq = itertools.count(90_016)


def _fresh_rows() -> int:
    return next(_uniq)


@pytest.fixture(autouse=True)
def _clean_bus():
    bus.reset_default_sinks()
    yield
    bus.reset_default_sinks()


# ---------------------------------------------------------------------------
# events
# ---------------------------------------------------------------------------
class TestEvents:
    def test_to_record_shape(self):
        ev = events.PlanEvent(kernel="rmsnorm", shape=(8, 128),
                              dtype="float32", cache="miss",
                              mesh=(("data", 2),))
        rec = ev.to_record()
        assert list(rec)[:2] == ["kind", "ts"]
        assert rec["kind"] == "plan"
        assert rec["shape"] == [8, 128]          # tuples -> lists
        assert rec["mesh"] == [["data", 2]]
        json.dumps(rec)                          # JSON-safe end to end

    def test_events_are_frozen(self):
        import dataclasses

        ev = events.TrainStepEvent(step=1, loss=2.0, grad_norm=0.5)
        with pytest.raises(dataclasses.FrozenInstanceError):
            ev.loss = 3.0

    def test_kind_registry_is_complete(self):
        kinds = {"plan", "spmd_fallback", "spmd_override_shadow",
                 "validation", "train_step", "checkpoint", "admission",
                 "batcher_tick", "page_pool", "preemption",
                 "request_abandoned", "profile_drift",
                 "mesh_change", "resume", "degraded"}
        assert set(events.EVENT_KINDS) == kinds
        for kind, cls in events.EVENT_KINDS.items():
            assert cls.kind == kind


# ---------------------------------------------------------------------------
# sinks
# ---------------------------------------------------------------------------
class TestSinks:
    def test_ring_buffer_wraparound_keeps_counts(self):
        ring = obs.RingBufferSink(capacity=2)
        for i in range(5):
            ring.emit(events.TrainStepEvent(step=i, loss=0.0, grad_norm=0.0))
        assert len(ring) == 2                      # buffer truncated...
        assert ring.counts() == {"train_step": 5}  # ...counts are not
        assert [e.step for e in ring.events("train_step")] == [3, 4]
        assert ring.events("plan") == []

    def test_jsonl_sink_lazy_open_and_roundtrip(self, tmp_path):
        path = tmp_path / "events.jsonl"
        sink = obs.JsonlSink(path)
        assert not path.exists()                   # construction: no I/O
        sink.emit(events.CheckpointEvent(step=3, action="save"))
        sink.emit(events.CheckpointEvent(step=4, action="save"))
        sink.close()
        recs = [json.loads(x) for x in path.read_text().splitlines()]
        assert [r["step"] for r in recs] == [3, 4]
        assert sink.emitted == 2

    def test_jsonl_sink_append_mode(self, tmp_path):
        path = tmp_path / "events.jsonl"
        with obs.JsonlSink(path) as s:
            s.emit(events.CheckpointEvent(step=1, action="save"))
        with obs.JsonlSink(path, append=True) as s:
            s.emit(events.CheckpointEvent(step=2, action="save"))
        assert len(path.read_text().splitlines()) == 2

    def test_jsonl_sink_does_not_close_borrowed_file(self, tmp_path):
        f = open(tmp_path / "borrowed.jsonl", "w")
        try:
            sink = obs.JsonlSink(f)
            sink.emit(events.CheckpointEvent(step=1, action="save"))
            sink.close()
            assert not f.closed                    # caller owns the handle
        finally:
            f.close()

    def test_logging_sink(self, caplog):
        sink = obs.LoggingSink("repro.obs.test", level=logging.WARNING)
        with caplog.at_level(logging.WARNING, logger="repro.obs.test"):
            sink.emit(events.AdmissionEvent(rid=7, slot=1, queue_depth=3))
        assert "admission" in caplog.text
        assert "rid=7" in caplog.text


# ---------------------------------------------------------------------------
# bus / session semantics
# ---------------------------------------------------------------------------
class TestBus:
    def test_disabled_by_default(self):
        assert not obs.enabled()
        assert all(isinstance(s, obs.NullSink) for s in bus.current_sinks())

    def test_session_enables_and_restores(self):
        ring = obs.RingBufferSink()
        with obs.session(ring):
            assert obs.enabled()
            obs.emit(events.CheckpointEvent(step=1, action="save"))
        assert not obs.enabled()
        obs.emit(events.CheckpointEvent(step=2, action="save"))  # dropped
        assert ring.counts() == {"checkpoint": 1}

    def test_nested_sessions_inherit(self):
        outer, inner = obs.RingBufferSink(), obs.RingBufferSink()
        with obs.session(outer):
            with obs.session(inner):                # inherits outer
                obs.emit(events.CheckpointEvent(step=1, action="save"))
            obs.emit(events.CheckpointEvent(step=2, action="save"))
        assert outer.counts() == {"checkpoint": 2}
        assert inner.counts() == {"checkpoint": 1}

    def test_inherit_false_isolates(self):
        outer, inner = obs.RingBufferSink(), obs.RingBufferSink()
        with obs.session(outer):
            with obs.session(inner, inherit=False):
                obs.emit(events.CheckpointEvent(step=1, action="save"))
        assert outer.counts() == {}
        assert inner.counts() == {"checkpoint": 1}

    def test_empty_isolated_session_is_disabled(self):
        with obs.session(obs.RingBufferSink()):
            with obs.session(inherit=False):
                assert not obs.enabled()

    def test_sessions_are_thread_local(self):
        seen = {}

        def probe():
            seen["enabled"] = obs.enabled()
            seen["sinks"] = bus.current_sinks()

        with obs.session(obs.RingBufferSink()):
            t = threading.Thread(target=probe)
            t.start()
            t.join()
        assert seen["enabled"] is False            # other thread: default
        assert all(isinstance(s, obs.NullSink) for s in seen["sinks"])

    def test_default_sinks_are_process_wide(self):
        ring = obs.RingBufferSink()
        bus.set_default_sinks(ring)
        try:
            assert obs.enabled()
            hit = {}

            def probe():
                if obs.enabled():
                    obs.emit(events.CheckpointEvent(step=9, action="save"))
                hit["done"] = True

            t = threading.Thread(target=probe)
            t.start()
            t.join()
            assert hit["done"]
            assert ring.counts() == {"checkpoint": 1}
        finally:
            bus.reset_default_sinks()
        assert not obs.enabled()

    def test_failing_sink_never_raises_and_others_still_deliver(self):
        class Boom(obs.Sink):
            def emit(self, event):
                raise RuntimeError("boom")

        ring = obs.RingBufferSink()
        with obs.session(Boom(), ring):
            obs.emit(events.CheckpointEvent(step=1, action="save"))
        assert ring.counts() == {"checkpoint": 1}

    def test_non_sink_rejected(self):
        with pytest.raises(TypeError):
            with obs.session(object()):
                pass
        with pytest.raises(TypeError):
            bus.set_default_sinks(object())


# ---------------------------------------------------------------------------
# the zero-cost contract (acceptance: count sink calls, not wall clock)
# ---------------------------------------------------------------------------
class TestZeroCostDefault:
    def test_launch_under_default_makes_zero_sink_calls(self, monkeypatch):
        import jax.numpy as jnp

        calls = []
        monkeypatch.setattr(sinks_mod.NullSink, "emit",
                            lambda self, e: calls.append(e))
        x = jnp.ones((_fresh_rows(),), jnp.float32)
        y = api.launch("stream.scale", x, s=2.0)
        api.plan_for("rmsnorm", (_fresh_rows(), 128), "float32")
        np.testing.assert_allclose(np.asarray(y), np.asarray(x) * 2.0)
        assert calls == []                         # nothing even constructed


# ---------------------------------------------------------------------------
# instrumentation: plan events
# ---------------------------------------------------------------------------
class TestPlanEvents:
    def test_miss_then_hit_with_provenance(self):
        n = _fresh_rows()
        ring = obs.RingBufferSink()
        with obs.session(ring):
            api.plan_for("stream.copy", (n,), "float32")
            api.plan_for("stream.copy", (n,), "float32")
        evs = ring.events("plan")
        assert [e.cache for e in evs] == ["miss", "hit"]
        assert all(e.kernel == "stream.copy" for e in evs)
        assert all(e.source == "analytic" for e in evs)
        assert evs[0].shape == (n,)

    def test_override_event_carries_pin_provenance(self):
        n = _fresh_rows()
        base = api.plan_for("stream.copy", (n,), "float32")
        ring = obs.RingBufferSink()
        cell = ("stream.copy", (n,), "float32")
        with api.plan_context(plan_overrides={cell: base}), obs.session(ring):
            got = api.plan_for("stream.copy", (n,), "float32")
        assert got is base
        (ev,) = ring.events("plan")
        assert ev.cache == "override"
        assert ev.source == base.provenance

    def test_launch_emits_plan_event(self):
        import jax.numpy as jnp

        n = _fresh_rows()
        ring = obs.RingBufferSink()
        with obs.session(ring):
            api.launch("stream.scale", jnp.ones((n,), jnp.float32), s=1.5)
        evs = ring.events("plan")
        assert evs and evs[0].kernel == "stream.scale"
        assert evs[0].cache == "miss"


# ---------------------------------------------------------------------------
# instrumentation: SPMD comm health
# ---------------------------------------------------------------------------
class TestSpmdEvents:
    def _fake_mesh(self, shape=(5, 1)):
        return types.SimpleNamespace(axis_names=("data", "model"),
                                     devices=np.zeros(shape))

    def test_fallback_event_per_occurrence(self):
        from repro.api import spmd

        entry = types.SimpleNamespace(name="xent")
        mesh = self._fake_mesh()
        arrays = (np.zeros((8, 16), np.float32),)
        ring = obs.RingBufferSink()
        reasons = ["vocab axis 16 not divisible by model=1"]
        with obs.session(ring):
            spmd._log_fallbacks(entry, mesh, arrays, reasons)
            spmd._log_fallbacks(entry, mesh, arrays, reasons)
            spmd._log_fallbacks(entry, mesh, arrays, [])   # no fallback
        evs = ring.events("spmd_fallback")
        assert len(evs) == 2                       # events never dedup
        assert evs[0].kernel == "xent"
        assert evs[0].mesh == (("data", 5), ("model", 1))
        assert evs[0].reasons == tuple(reasons)

    def test_shadowed_override_event(self):
        from repro.api import dispatch
        from repro.api import registry as registry_lib

        n = _fresh_rows()
        entry = registry_lib.resolve("stream.copy")
        base = api.plan_for("stream.copy", (n,), "float32")
        mesh = self._fake_mesh(shape=(7, 1))       # unique: dodge warn dedup
        arrays = (np.zeros((n,), np.float32),)
        ring = obs.RingBufferSink()
        cell = ("stream.copy", (n,), "float32")
        with api.plan_context(plan_overrides={cell: base}), obs.session(ring):
            with pytest.warns(RuntimeWarning, match="inert"):
                dispatch._warn_spmd_shadowed_overrides(entry, mesh, arrays, {})
        (ev,) = ring.events("spmd_override_shadow")
        assert ev.kernel == "stream.copy"
        assert ev.global_shape == (n,)
        assert ev.cells == (str(cell),)


# ---------------------------------------------------------------------------
# instrumentation: profile drift + validation
# ---------------------------------------------------------------------------
class TestMeasureEvents:
    def _drifted_profile(self, tmp_path) -> str:
        from repro.core.planner import plan_kernel
        from repro.measure import profile as profile_lib

        plan = plan_kernel("rmsnorm", (48, 256), "float32",
                           sublanes=8, vmem_budget=1 << 20)
        entry = profile_lib.entry_from_plan(
            plan, {"sublanes": 8, "vmem_budget": 1 << 20})
        entry["expect"]["block_shape"] = [1, 1]    # geometry that can't rederive
        path = str(tmp_path / "drifted.json")
        profile_lib.save_profile(path, [entry])
        return path

    def test_profile_drift_event_non_strict(self, tmp_path):
        from repro.measure.profile import load_profile

        path = self._drifted_profile(tmp_path)
        ring = obs.RingBufferSink()
        with obs.session(ring), pytest.warns(UserWarning, match="drift"):
            overrides = load_profile(path, strict=False)
        assert overrides == {}                     # drifted cell skipped
        (ev,) = ring.events("profile_drift")
        assert ev.path == path
        assert ev.cell == "rmsnorm (48, 256) float32"
        assert "block_shape" in ev.detail

    def test_profile_drift_event_streams_before_strict_raise(self, tmp_path):
        from repro.measure.profile import load_profile

        path = self._drifted_profile(tmp_path)
        ring = obs.RingBufferSink()
        with obs.session(ring), pytest.raises(ValueError, match="drift"):
            load_profile(path, strict=True)
        assert ring.counts() == {"profile_drift": 1}

    def test_validation_event_matches_record(self):
        from repro.measure import validate

        ring = obs.RingBufferSink()
        with obs.session(ring):
            rec = validate.validate_kernel("stream.copy", shape=(8192,),
                                           dtype="float32")
        (ev,) = ring.events("validation")
        assert ev.kernel == "stream.copy"
        assert ev.family == "stream"
        assert ev.check == "hbm"
        assert ev.ratio == pytest.approx(rec["ratio"])
        assert ev.status == rec["status"]


# ---------------------------------------------------------------------------
# instrumentation: continuous batcher
# ---------------------------------------------------------------------------
class _EchoModel:
    """Tiniest decode-able model: echoes the fed token as the argmax.

    ``d_model=0`` skips batch planning (padded_slots == slots), an empty
    cache tree makes slot resets trivial -- the test isolates the
    batcher's admission/tick instrumentation from kernel planning.
    """

    def __init__(self, vocab: int = 16):
        import jax.numpy as jnp

        self.vocab = vocab
        self.cfg = types.SimpleNamespace(d_model=0, adtype=jnp.float32)

    def cache_defs(self, slots, max_len):
        return {}

    def decode_step(self, params, cache, tokens):
        import jax

        logits = jax.nn.one_hot(tokens[:, 0], self.vocab)[:, None, :]
        return logits, cache


class TestBatcherEvents:
    def test_admission_and_tick_events(self):
        from repro.serving.scheduler import ContinuousBatcher, Request

        b = ContinuousBatcher(_EchoModel(), {}, slots=2, max_len=8)
        reqs = [Request(rid=i, prompt=[3, 4], max_new_tokens=2)
                for i in range(3)]
        ring = obs.RingBufferSink()
        with obs.session(ring):
            out = b.run(reqs)
        assert set(out) == {0, 1, 2}               # all requests served
        admits = ring.events("admission")
        assert len(admits) == 3                    # one per request
        assert {a.slot for a in admits} <= {0, 1}
        # Two slots, three requests: the third admission waits for a retire.
        assert admits[0].queue_depth == 2
        assert admits[-1].queue_depth == 0
        ticks = ring.events("batcher_tick")
        assert len(ticks) == b.ticks
        for t in ticks:
            assert t.slots == 2 and t.padded_slots == 2
            assert t.pad_slots == 0
            assert t.n_prefill + t.n_decode + t.free_slots == t.slots
        # The queue drains monotonically across ticks.
        assert ticks[0].queue_depth >= ticks[-1].queue_depth

    def test_batcher_emits_nothing_by_default(self, monkeypatch):
        from repro.serving.scheduler import ContinuousBatcher, Request

        calls = []
        monkeypatch.setattr(sinks_mod.NullSink, "emit",
                            lambda self, e: calls.append(e))
        b = ContinuousBatcher(_EchoModel(), {}, slots=1, max_len=8)
        b.run([Request(rid=0, prompt=[2], max_new_tokens=1)])
        assert calls == []


# ---------------------------------------------------------------------------
# instrumentation: trainer
# ---------------------------------------------------------------------------
def _tiny_trainer(ckpt_dir: str, *, n_steps: int = 3, ckpt_every: int = 2):
    from repro.data.pipeline import DataConfig
    from repro.models import build_model
    from repro.models.config import ModelConfig
    from repro.optim import adamw
    from repro.optim.schedules import make_schedule
    from repro.runtime.trainer import Trainer, TrainerConfig

    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=64,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=32,
                      dtype="float32", remat=False)
    model = build_model(cfg)
    return Trainer(
        model,
        DataConfig(vocab_size=32, seq_len=16, global_batch=4, d_model=64),
        adamw.AdamWConfig(master=False),
        make_schedule("cosine", peak=3e-3, warmup=2, total=n_steps),
        TrainerConfig(n_steps=n_steps, ckpt_every=ckpt_every,
                      ckpt_dir=ckpt_dir),
    )


class TestTrainerEvents:
    def test_restore_event_without_running_steps(self, tmp_path):
        import jax

        from repro.parallel import steps as steps_lib

        tr = _tiny_trainer(str(tmp_path))
        key = jax.random.PRNGKey(0)
        state = steps_lib.init_train_state(tr.model, tr.opt_cfg, key)
        tr.ckpt.save(5, state)
        tr.ckpt.wait()
        ring = obs.RingBufferSink()
        with obs.session(ring):
            step, _ = tr.init_or_restore(key)
        assert step == 5
        (ev,) = ring.events("checkpoint")
        assert (ev.step, ev.action) == (5, "restore")

    @pytest.mark.slow
    def test_train_streams_step_and_checkpoint_events(self, tmp_path):
        import jax

        tr = _tiny_trainer(str(tmp_path), n_steps=3, ckpt_every=2)
        ring = obs.RingBufferSink()
        with obs.session(ring):
            metrics = tr.train(jax.random.PRNGKey(0))
        # Legacy return surface intact...
        assert [m["step"] for m in metrics] == [0, 1, 2]
        assert set(metrics[0]) == {"step", "loss", "grad_norm"}
        # ...and the same trajectory as typed events, with wall time.
        steps = ring.events("train_step")
        assert [e.step for e in steps] == [0, 1, 2]
        assert all(e.step_s > 0 for e in steps)
        assert steps[0].loss == pytest.approx(metrics[0]["loss"])
        saves = [e for e in ring.events("checkpoint") if e.action == "save"]
        assert len(saves) >= 2                     # periodic + final


# ---------------------------------------------------------------------------
# the report CLI
# ---------------------------------------------------------------------------
def _sample_events() -> list:
    return [
        events.PlanEvent(kernel="rmsnorm", shape=(8, 128), dtype="float32",
                         cache="miss"),
        events.PlanEvent(kernel="rmsnorm", shape=(8, 128), dtype="float32",
                         cache="hit"),
        events.PlanEvent(kernel="xent", shape=(8, 32), dtype="float32",
                         cache="hit"),
        events.PlanEvent(kernel="xent", shape=(8, 32), dtype="float32",
                         cache="override", source="profile:p.json"),
        events.SpmdFallbackEvent(kernel="xent", mesh=(("data", 2),),
                                 reasons=("vocab not divisible",)),
        events.SpmdOverrideShadowEvent(kernel="xent", mesh=(("data", 2),),
                                       global_shape=(8, 32),
                                       cells=("('xent', (8, 32))",)),
        events.ValidationEvent(kernel="stream.copy", family="stream",
                               check="hbm", predicted_bytes=100.0,
                               measured_bytes=110.0, ratio=1.1, status="ok"),
        events.ValidationEvent(kernel="xent", family="xent", check="comm",
                               predicted_bytes=100.0, measured_bytes=250.0,
                               ratio=2.5, status="fail"),
        events.TrainStepEvent(step=0, loss=3.5, grad_norm=1.0, step_s=0.5),
        events.TrainStepEvent(step=1, loss=3.1, grad_norm=0.9, step_s=0.3),
        events.CheckpointEvent(step=2, action="save"),
        events.CheckpointEvent(step=2, action="restore"),
        events.AdmissionEvent(rid=0, slot=0, queue_depth=4),
        events.BatcherTickEvent(tick=1, n_prefill=1, n_decode=1, slots=4,
                                padded_slots=8, free_slots=2, pad_slots=4,
                                queue_depth=1),
        events.ProfileDriftEvent(path="p.json", cell="rmsnorm (8, 128)",
                                 detail="block_shape moved"),
    ]


def _write_stream(path: Path, evs) -> None:
    with obs.JsonlSink(path) as sink:
        for e in evs:
            sink.emit(e)


class TestReport:
    def test_aggregate_sections(self):
        s = report.aggregate([e.to_record() for e in _sample_events()])
        assert s["events"] == 15
        plan = s["plan"]
        assert (plan["hits"], plan["misses"], plan["overrides"]) == (2, 1, 1)
        assert plan["hit_rate"] == pytest.approx(2 / 3)
        assert plan["sources"]["profile:p.json"] == 1
        assert plan["by_kernel"]["rmsnorm"]["misses"] == 1
        fb = s["spmd_fallbacks"]
        assert fb["total"] == 1
        assert fb["by_site"]["xent@data=2"]["reasons"] == [
            "vocab not divisible"]
        assert s["spmd_override_shadows"]["total"] == 1
        val = s["validation"]
        assert val["stream/hbm"]["worst"] == pytest.approx(1.1)
        assert val["xent/comm"]["fails"] == 1
        tr = s["train"]
        assert tr["steps"] == 2
        assert (tr["first_loss"], tr["last_loss"]) == (3.5, 3.1)
        assert tr["mean_step_s"] == pytest.approx(0.4)
        assert tr["checkpoint_saves"] == tr["checkpoint_restores"] == 1
        ba = s["batcher"]
        assert ba["admissions"] == 1
        assert ba["max_queue_depth"] == 4
        assert ba["mean_waste_frac"] == pytest.approx(6 / 8)
        assert s["profile_drift"]["cells"] == ["rmsnorm (8, 128)"]

    def test_exposed_comm_validation_aggregates(self):
        """exposed_comm validation events (validate --comm --exposed) land
        in their own family/check bucket with worst-ratio and fail counts,
        with no report-side changes needed."""
        evs = [
            events.ValidationEvent(
                kernel="jacobi", family="jacobi", check="exposed_comm",
                predicted_bytes=1308.0, measured_bytes=1308.0, ratio=1.0,
                status="ok", mesh=(("data", 8), ("model", 1))),
            events.ValidationEvent(
                kernel="lbm.soa", family="lbm", check="exposed_comm",
                predicted_bytes=1373.0, measured_bytes=2746.0, ratio=2.0,
                status="fail", mesh=(("data", 8), ("model", 1))),
            events.ValidationEvent(
                kernel="jacobi", family="jacobi", check="comm",
                predicted_bytes=2064.0, measured_bytes=2064.0, ratio=1.0,
                status="ok"),
        ]
        s = report.aggregate([e.to_record() for e in evs])
        val = s["validation"]
        assert val["jacobi/exposed_comm"]["worst"] == pytest.approx(1.0)
        assert val["jacobi/exposed_comm"]["fails"] == 0
        assert val["lbm/exposed_comm"]["fails"] == 1
        assert val["lbm/exposed_comm"]["worst"] == pytest.approx(2.0)
        assert val["jacobi/comm"]["fails"] == 0

    def test_elastic_section_aggregates(self):
        """Mesh-change / resume / degraded events from the elastic runtime
        land in the report's ``elastic`` section (satellite: a shrunken
        mesh must be visible in ``repro.obs.report``)."""
        evs = [
            events.MeshChangeEvent(
                old_mesh=(("data", 4), ("model", 2)),
                new_mesh=(("data", 3), ("model", 2)),
                failed_ids=(7,), retired_ids=(6,), step=12),
            events.ResumeEvent(step=10, mesh=(("data", 3), ("model", 2)),
                               batch_chunks=(2, 1, 1),
                               invalidated_plans=5),
            events.DegradedEvent(reason="straggler", step=3,
                                 detail="step 2.0s vs ema 0.1s"),
            events.DegradedEvent(reason="transient_retry", step=4),
            events.DegradedEvent(reason="straggler", step=9),
        ]
        s = report.aggregate([e.to_record() for e in evs])
        el = s["elastic"]
        assert el["mesh_changes"] == 1
        assert el["last_mesh"] == "data=3,model=2"
        assert el["resumes"] == 1
        assert el["last_resume_step"] == 10
        assert el["invalidated_plans"] == 5
        assert el["degraded"] == 3
        assert el["degraded_reasons"] == {"straggler": 2,
                                          "transient_retry": 1}
        text = report.render(s)
        assert "elastic: 1 mesh change(s)" in text
        assert "data=3,model=2" in text

    def test_render_is_stable_when_empty(self):
        text = report.render(report.aggregate([]))
        for section in ("events: 0", "plan cache:", "spmd fallbacks: 0",
                        "validation: 0", "trainer: 0", "batcher: 0",
                        "profile drift: 0"):
            assert section in text

    def test_cli_text_and_json(self, tmp_path, capsys):
        path = tmp_path / "run.jsonl"
        _write_stream(path, _sample_events())
        assert report.main([str(path)]) == 0
        out = capsys.readouterr().out
        assert "hit rate 66.7%" in out
        assert "xent/comm" in out
        assert report.main([str(path), "--json"]) == 0
        doc = json.loads(capsys.readouterr().out)
        assert doc["events"] == 15
        assert doc["plan"]["hit_rate"] == pytest.approx(2 / 3)

    def test_cli_fail_on_validation(self, tmp_path, capsys):
        path = tmp_path / "run.jsonl"
        _write_stream(path, _sample_events())
        assert report.main([str(path), "--fail-on-validation"]) == 1
        capsys.readouterr()
        clean = tmp_path / "clean.jsonl"
        _write_stream(clean, [e for e in _sample_events()
                              if getattr(e, "status", "ok") == "ok"])
        assert report.main([str(clean), "--fail-on-validation"]) == 0

    def test_cli_tolerates_malformed_lines(self, tmp_path, capsys):
        path = tmp_path / "torn.jsonl"
        _write_stream(path, _sample_events()[:3])
        with open(path, "a") as f:
            f.write('{"kind": "plan", "cache"')   # torn final line
        assert report.main([str(path)]) == 0
        out = capsys.readouterr().out
        assert "1 malformed line(s) skipped" in out
        assert report.main([str(path), "--json"]) == 0
        assert json.loads(capsys.readouterr().out)["malformed_lines"] == 1

    def test_cli_unreadable_input_exits_2(self, tmp_path, capsys):
        assert report.main([str(tmp_path / "absent.jsonl")]) == 2
        assert "error" in capsys.readouterr().err

    def test_cli_merges_multiple_streams(self, tmp_path, capsys):
        a, b = tmp_path / "a.jsonl", tmp_path / "b.jsonl"
        _write_stream(a, _sample_events()[:5])
        _write_stream(b, _sample_events()[5:])
        assert report.main([str(a), str(b), "--json"]) == 0
        assert json.loads(capsys.readouterr().out)["events"] == 15


# ---------------------------------------------------------------------------
# benchmarks/run.py --json (satellite: versioned machine-readable output)
# ---------------------------------------------------------------------------
class TestBenchJson:
    @pytest.fixture()
    def run_mod(self, monkeypatch):
        root = str(Path(__file__).resolve().parents[1])
        if root not in sys.path:
            sys.path.insert(0, root)
        from benchmarks import run as run_mod

        monkeypatch.setattr(run_mod, "collect_rows",
                            lambda: [("stream.copy 1M", 12.25, "42.0 GB/s")])
        return run_mod

    def test_json_document_schema(self, run_mod, tmp_path, capsys):
        out = tmp_path / "bench.json"
        assert run_mod.main(["--json", str(out)]) == 0
        doc = json.loads(out.read_text())
        assert doc["format"] == run_mod.BENCH_FORMAT
        assert doc["version"] == run_mod.BENCH_VERSION
        assert doc["backend"] and doc["jax_version"]
        assert doc["rows"] == [{"name": "stream.copy 1M",
                                "us_per_call": 12.25,
                                "derived": "42.0 GB/s"}]

    def test_json_to_stdout_and_csv_default(self, run_mod, capsys):
        assert run_mod.main(["--json"]) == 0
        doc = json.loads(capsys.readouterr().out)
        assert [r["name"] for r in doc["rows"]] == ["stream.copy 1M"]
        assert run_mod.main([]) == 0
        out = capsys.readouterr().out
        assert out.splitlines()[0] == "name,us_per_call,derived"
        assert "stream.copy 1M,12.25,42.0 GB/s" in out
