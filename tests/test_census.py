"""Collective-census parser: wire-byte math on synthetic post-SPMD HLO."""
import pytest

pytest.importorskip("jax")

from repro.launch import lowering  # noqa: E402  (safe: no device init)

HLO = """
HloModule jit_step

%fused (a: f32[16,128]) -> f32[16,128] {
  ROOT %x = f32[16,128] parameter(0)
}

ENTRY %main {
  %ar = f32[16,128]{1,0} all-reduce(%p0), channel_id=1, replica_groups=[16,16]<=[256], to_apply=%add
  %ag = bf16[64,256]{1,0} all-gather(%p1), channel_id=2, replica_groups=[32,8]<=[256], dimensions={0}
  %rs = f32[8,128]{1,0} reduce-scatter(%p2), channel_id=3, replica_groups=[64,4]<=[256], dimensions={0}, to_apply=%add
  %a2a = bf16[4,64]{1,0} all-to-all(%p3), channel_id=4, replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}
  %cp = u8[1024]{0} collective-permute(%p4), channel_id=5, source_target_pairs={{0,1}}
  %tuple_ar = (f32[2,2]{1,0}, f32[4]{0}) all-reduce(%p5, %p6), channel_id=6, replica_groups=[2,128]<=[256], to_apply=%add
}
"""


def test_kernel_plan_hook_matches_planner():
    """The lowering path's planner hook returns the same memoized plans the
    kernel wrappers execute, and the report renders one block per case."""
    from repro.core import planner

    p = lowering.kernel_plan("xent", (4096, 122753), "float32",
                             mesh={"model": 16})
    assert p is planner.plan_kernel("xent", (4096, 122753), "float32",
                                    mesh={"model": 16})
    assert p.width % (16 * 128) == 0  # mesh-aware vocab padding
    cases = [("triad", (8191,), "float32"), ("jacobi", (100, 100), "float32")]
    report = lowering.kernel_plan_report(cases)
    assert report.count("plan[") == 2
    assert "predicted balance" in report


def test_census_wire_bytes():
    c = lowering.collective_census(HLO)
    # all-reduce: 2*(15/16) * 16*128*4
    assert c["all-reduce"]["count"] == 2
    ar1 = 2 * (15 / 16) * 16 * 128 * 4
    ar2 = 2 * (127 / 128) * (2 * 2 * 4 + 4 * 4)
    assert c["all-reduce"]["wire_bytes"] == pytest.approx(ar1 + ar2)
    # all-gather: (7/8) * 64*256*2
    assert c["all-gather"]["wire_bytes"] == pytest.approx((7 / 8) * 64 * 256 * 2)
    # reduce-scatter: (N-1) * result = 3 * 8*128*4
    assert c["reduce-scatter"]["wire_bytes"] == pytest.approx(3 * 8 * 128 * 4)
    # all-to-all with brace groups of size 4: (3/4) * 4*64*2
    assert c["all-to-all"]["wire_bytes"] == pytest.approx((3 / 4) * 4 * 64 * 2)
    # permute: full result bytes
    assert c["collective-permute"]["wire_bytes"] == pytest.approx(1024)
    assert lowering.census_total(c) == pytest.approx(
        ar1 + ar2 + (7 / 8) * 64 * 256 * 2 + 3 * 8 * 128 * 4
        + (3 / 4) * 4 * 64 * 2 + 1024
    )


def test_census_ignores_non_collectives():
    c = lowering.collective_census("%x = f32[8] add(%a, %b)\n")
    assert lowering.census_total(c) == 0.0


def test_census_empty_and_lookalike_programs():
    """Zero-collective programs: empty text, and ops/variables whose *names*
    contain collective substrings without being collective ops."""
    assert lowering.census_total(lowering.collective_census("")) == 0.0
    hlo = """
    ENTRY %main {
      %all-reduce.1 = f32[8]{0} fusion(%a, %b), kind=kLoop, calls=%comp
      %x = f32[8] add(%all-reduce.1, %b)
      %cp = f32[8] custom-call(%x), custom_call_target="collective-permute-emu"
    }
    """
    c = lowering.collective_census(hlo)
    assert all(v["count"] == 0 for v in c.values())
    assert lowering.census_total(c) == 0.0


def test_census_async_start_counted_done_not():
    """XLA splits collectives into -start/-done pairs when it overlaps them
    with compute; the wire bytes move once, on the start op."""
    hlo = """
  %ar-start = f32[64]{0} all-reduce-start(%p0), channel_id=1, replica_groups=[4,2]<=[8], to_apply=%add
  %ar-done = f32[64]{0} all-reduce-done(%ar-start)
  %ag-start = f32[32,8]{1,0} all-gather-start(%p1), channel_id=2, replica_groups=[2,4]<=[8], dimensions={0}
  %ag-done = f32[32,8]{1,0} all-gather-done(%ag-start)
"""
    c = lowering.collective_census(hlo)
    assert c["all-reduce"]["count"] == 1
    assert c["all-reduce"]["wire_bytes"] == pytest.approx(
        2 * (1 / 2) * 64 * 4)
    assert c["all-gather"]["count"] == 1
    assert c["all-gather"]["wire_bytes"] == pytest.approx(
        (3 / 4) * 32 * 8 * 4)


def test_census_renamed_vars_and_repeated_collectives():
    """Fusion rewrites rename result variables freely; every occurrence of
    the same collective must be counted and summed."""
    hlo = """
  %loss_allreduce.7 = f32[128]{0} all-reduce(%p0), replica_groups=[8,2]<=[16], to_apply=%add
  %fused.comm_1 = f32[128]{0} all-reduce(%p1), replica_groups=[8,2]<=[16], to_apply=%add
  %z99 = bf16[256]{0} all-reduce(%p2), replica_groups=[1,16]<=[16], to_apply=%add
"""
    c = lowering.collective_census(hlo)
    assert c["all-reduce"]["count"] == 3
    expected = (2 * (1 / 2) * 128 * 4) * 2 + 2 * (15 / 16) * 256 * 2
    assert c["all-reduce"]["wire_bytes"] == pytest.approx(expected)
    assert lowering.census_total(c) == pytest.approx(expected)


def test_census_missing_replica_groups_moves_nothing():
    """A collective with no parseable replica_groups is group-size 1: it is
    counted (the op exists) but the ring model prices zero wire bytes."""
    c = lowering.collective_census(
        "%ar = f32[64]{0} all-reduce(%p0), to_apply=%add\n")
    assert c["all-reduce"]["count"] == 1
    assert c["all-reduce"]["wire_bytes"] == 0.0
    assert c["all-reduce"]["result_bytes"] == 64 * 4
