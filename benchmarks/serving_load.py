"""Serving load generator: latency/throughput of the continuous batcher
vs offered load (docs/SERVING.md).

Drives ``serving.ContinuousBatcher`` with a seeded synthetic request
stream -- Poisson arrivals per tick, ragged prompt/output lengths -- and
reports, per offered load:

  * p50/p99 per-token latency (submit -> finish wall time over tokens
    generated, per request),
  * p50 time-to-first-token,
  * aggregate tokens/s,
  * completion/abandonment counts and (paged) preemption totals.

The interesting comparison is ``--kv-cache dense`` vs ``--kv-cache paged
--prefill-chunk N`` at the same offered load: chunked prefill trades a
deeper tick for fewer prompt-bound ticks (lower p99 under decode-heavy
mixes), and the paged pool admits more concurrent requests than the dense
slab at the same memory budget.

    python benchmarks/serving_load.py --loads 0.1,0.3 --json out.json
    python benchmarks/run.py --json -          # includes a smoke sweep

``rows()`` feeds ``benchmarks/run.py`` (repro.bench v1 documents).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

if __package__ in (None, ""):   # script invocation
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))


def _percentile(xs, q: float) -> float | None:
    if not xs:
        return None
    return float(np.percentile(np.asarray(xs, np.float64), q))


def run_load(model, params, *, slots: int, max_len: int, offered: float,
             ticks: int, seed: int = 0, kv_cache: str = "dense",
             prefill_chunk: int = 1, page_len: int | None = None,
             n_pages: int | None = None, drain_ticks: int = 2000,
             warmup: bool = True) -> dict:
    """One point of the load sweep: drive the batcher for ``ticks`` of
    Poisson(``offered``) arrivals, then drain, and summarize latency.

    Latencies are wall-clock per *request* (submit to finish divided by
    tokens generated); percentiles are across completed requests.  The
    request stream is fully determined by ``seed``.
    """
    from repro.serving import ContinuousBatcher, Request

    cfg = model.cfg
    batcher = ContinuousBatcher(
        model, params, slots=slots, max_len=max_len, kv_cache=kv_cache,
        prefill_chunk=prefill_chunk, page_len=page_len, n_pages=n_pages)
    if warmup:
        # Compile the decode/chunk programs outside the timed section.
        batcher.run([Request(rid=-1, prompt=[1, 2, 3],
                             max_new_tokens=max(2, prefill_chunk))])
        batcher.completed.clear()

    rng = np.random.default_rng(seed)
    plen_hi = max(3, max_len // 4)
    gen_hi = max(2, max_len // 4)
    reqs: dict[int, object] = {}
    recs: dict[int, dict] = {}
    rid = 0
    t0 = time.perf_counter()

    def observe(now: float) -> None:
        for r, rec in recs.items():
            if rec["first"] is None and reqs[r].generated:
                rec["first"] = now
            if rec["done"] is None and r in batcher.completed:
                rec["done"] = now
                rec["tokens"] = len(batcher.completed[r])

    for tick in range(ticks):
        n_new = int(rng.poisson(offered))
        batch = []
        for _ in range(n_new):
            prompt = rng.integers(
                1, cfg.vocab_size,
                size=int(rng.integers(2, plen_hi + 1))).tolist()
            req = Request(rid=rid, prompt=prompt,
                          max_new_tokens=int(rng.integers(1, gen_hi + 1)))
            reqs[rid] = req
            recs[rid] = {"submit": time.perf_counter(), "first": None,
                         "done": None, "tokens": 0}
            batch.append(req)
            rid += 1
        batcher.submit(batch)
        if batcher.busy:
            batcher.step()
            observe(time.perf_counter())
    drained = 0
    while batcher.busy and drained < drain_ticks:
        batcher.step()
        observe(time.perf_counter())
        drained += 1
    elapsed = time.perf_counter() - t0

    per_token_ms, ttft_ms, tokens = [], [], 0
    for r, rec in recs.items():
        if rec["done"] is None:
            continue
        tokens += rec["tokens"]
        per_token_ms.append(
            (rec["done"] - rec["submit"]) * 1e3 / max(1, rec["tokens"]))
        if rec["first"] is not None:
            ttft_ms.append((rec["first"] - rec["submit"]) * 1e3)
    return {
        "offered": offered,
        "kv_cache": kv_cache,
        "prefill_chunk": prefill_chunk,
        "n_requests": len(recs),
        "n_completed": sum(1 for r in recs.values() if r["done"] is not None),
        "n_unfinished": sum(1 for r in recs.values() if r["done"] is None),
        "ticks": batcher.ticks,
        "tokens": tokens,
        "tokens_per_s": tokens / elapsed if elapsed > 0 else 0.0,
        "p50_per_token_ms": _percentile(per_token_ms, 50),
        "p99_per_token_ms": _percentile(per_token_ms, 99),
        "p50_ttft_ms": _percentile(ttft_ms, 50),
        "preemptions": sum(r.preemptions for r in reqs.values()),
        "page_len": (batcher.geometry.page_len
                     if batcher.geometry is not None else None),
    }


def _smoke_model():
    from repro.configs import get_config, reduce_for_smoke
    from repro.models import build_model
    import jax

    cfg = reduce_for_smoke(get_config("qwen2-0.5b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _derived(m: dict) -> str:
    def f(v):
        return "-" if v is None else f"{v:.2f}"
    return (f"load={m['offered']};tok_s={m['tokens_per_s']:.1f};"
            f"p50_ms={f(m['p50_per_token_ms'])};"
            f"p99_ms={f(m['p99_per_token_ms'])};"
            f"ttft_ms={f(m['p50_ttft_ms'])};"
            f"done={m['n_completed']}/{m['n_requests']};"
            f"preempt={m['preemptions']}")


def rows(loads=(0.15, 0.4), *, ticks: int = 40) -> list[tuple[str, float, str]]:
    """repro.bench rows: a small fixed sweep on the smoke model, dense vs
    paged+chunked at each offered load (requests/tick)."""
    model, params = _smoke_model()
    out = []
    for mode, kw in (("dense", {}),
                     ("paged", {"kv_cache": "paged", "prefill_chunk": 4})):
        for load in loads:
            m = run_load(model, params, slots=4, max_len=32, offered=load,
                         ticks=ticks, seed=0, **kw)
            us = (m["p50_per_token_ms"] or 0.0) * 1e3
            out.append((f"serving_load.{mode}.load{load:g}", us, _derived(m)))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="serving load generator: latency/throughput vs "
                    "offered load")
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="shrink the model to smoke size (default)")
    ap.add_argument("--full", dest="smoke", action="store_false",
                    help="run the full-size config")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=32)
    ap.add_argument("--loads", default="0.15,0.4",
                    help="comma-separated offered loads (requests/tick)")
    ap.add_argument("--ticks", type=int, default=40,
                    help="arrival window per load point")
    ap.add_argument("--kv-cache", choices=["dense", "paged"],
                    default="paged")
    ap.add_argument("--prefill-chunk", type=int, default=4)
    ap.add_argument("--page-len", type=int, default=None)
    ap.add_argument("--n-pages", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", nargs="?", const="-", default=None,
                    metavar="PATH",
                    help="emit a repro.bench JSON document instead of CSV")
    ap.add_argument("--obs-jsonl", default=None, metavar="PATH",
                    help="stream obs events (page pool, preemptions, "
                         "ticks) to a JSONL file")
    args = ap.parse_args(argv)

    import jax
    from repro import obs
    from repro.configs import get_config, reduce_for_smoke
    from repro.models import build_model

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    loads = [float(x) for x in args.loads.split(",") if x]
    session = (obs.session(obs.JsonlSink(args.obs_jsonl))
               if args.obs_jsonl else None)
    sweep = []
    try:
        if session is not None:
            session.__enter__()
        for load in loads:
            sweep.append(run_load(
                model, params, slots=args.slots, max_len=args.max_len,
                offered=load, ticks=args.ticks, seed=args.seed,
                kv_cache=args.kv_cache, prefill_chunk=args.prefill_chunk,
                page_len=args.page_len, n_pages=args.n_pages))
    finally:
        if session is not None:
            session.__exit__(None, None, None)

    bench_rows = [
        (f"serving_load.{args.kv_cache}.load{m['offered']:g}",
         (m["p50_per_token_ms"] or 0.0) * 1e3, _derived(m))
        for m in sweep
    ]
    if args.json is not None:
        from benchmarks.run import to_document
        doc = to_document(bench_rows)
        doc["sweep"] = sweep
        if args.json == "-":
            json.dump(doc, sys.stdout, indent=1)
            print()
        else:
            with open(args.json, "w") as f:
                json.dump(doc, f, indent=1)
            print(f"wrote {len(bench_rows)} rows -> {args.json}")
        return 0
    print("name,us_per_call,derived")
    for name, us, derived in bench_rows:
        print(f"{name},{us:.2f},{derived}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
