"""Roofline report: three terms per (arch x shape x mesh) from the dry-run,
plus the analytic kernel-plan table (predicted balance / waste per family).

Reads results/dryrun.json (written by launch/dryrun.py, optionally with
--costs unit-extrapolated numbers) and emits the SSRoofline table:

    compute    = FLOPs_dev / peak_FLOPs          (197 TF/s bf16, v5e)
    memory     = HBM_bytes_dev / HBM_bw          (819 GB/s)
    collective = wire_bytes_dev / ICI_bw         (50 GB/s/link)

All inputs are *per-device* (the compiled module is the per-device SPMD
program).  MODEL_FLOPS uses the 6*N*D convention (N = params, active params
for MoE; D = tokens) split across devices, so the useful-compute ratio
MODEL_FLOPS / HLO_FLOPs exposes remat/dispatch overhead.
"""
from __future__ import annotations

import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

# active params (B) for MODEL_FLOPS; dense = total params
ACTIVE_PARAMS = {
    "zamba2-1.2b": 1.18e9,
    "minicpm-2b": 2.73e9,
    "qwen3-4b": 4.41e9,
    "qwen2-0.5b": 0.49e9,
    "qwen3-14b": 14.8e9,
    "pixtral-12b": 12.2e9,
    "xlstm-1.3b": 1.95e9,
    "grok-1-314b": 86e9,          # top-2 of 8 experts + attn/embed
    "qwen3-moe-30b-a3b": 3.3e9,   # top-8 of 128 (the A3B in the name)
    "whisper-tiny": 0.041e9,
}

TOKENS = {"train_4k": 256 * 4096, "prefill_32k": 32 * 32768,
          "decode_32k": 128, "long_500k": 1}
TRAIN_MULT = {"train": 3.0, "prefill": 1.0, "decode": 1.0}
SHAPE_KIND = {"train_4k": "train", "prefill_32k": "prefill",
              "decode_32k": "decode", "long_500k": "decode"}


def model_flops_per_device(arch: str, shape: str, n_devices: int) -> float:
    """6*N*D per step (x1 fwd-only for serving), split across devices."""
    kind = SHAPE_KIND[shape]
    mult = 2.0 * TRAIN_MULT[kind]  # 2ND fwd (+4ND bwd for train)
    return mult * ACTIVE_PARAMS[arch] * TOKENS[shape] / n_devices


def terms(rec: dict) -> dict | None:
    costs = rec.get("costs")
    if costs is None:
        return None
    nd = rec.get("n_devices", 256)
    ct = costs["flops"] / PEAK_FLOPS
    mt = costs["hbm_bytes"] / HBM_BW
    lt = costs["wire_bytes"] / ICI_BW
    dom = max(("compute", ct), ("memory", mt), ("collective", lt),
              key=lambda kv: kv[1])[0]
    mf = model_flops_per_device(rec["arch"], rec["shape"], nd)
    return {
        "compute_s": ct, "memory_s": mt, "collective_s": lt,
        "bottleneck": dom,
        "model_flops": mf,
        "useful_ratio": mf / costs["flops"] if costs["flops"] else 0.0,
        "roofline_s": max(ct, mt, lt),
        "ideal_s": max(mf / PEAK_FLOPS, 0.0),
        "roofline_fraction": (
            (mf / PEAK_FLOPS) / max(ct, mt, lt) if max(ct, mt, lt) else 0.0
        ),
    }


# Representative production shapes for the analytic kernel-plan table.
PLAN_CASES = [
    ("stream.triad", (2 ** 24,), "float32"),
    ("triad", (2 ** 24,), "float32"),
    ("jacobi", (4000, 4000), "float32"),
    ("lbm.ivjk", (19, 100, 100, 100), "float32"),
    ("rmsnorm", (4096, 5760), "bfloat16"),
    ("xent", (4096, 122753), "float32"),
]

# Per-shard cells for the communicating SPMD families (vocab-parallel xent,
# halo-exchange jacobi and LBM) under a nominal 2x4 data/model mesh: the
# *local* plan carries predicted_comm_bytes (halo rows / slabs / lse psum
# payloads), the number `repro.measure.validate --comm` checks against the
# collective census -- and predicted_exposed_comm_bytes, the part the
# interior-stripe compute window cannot hide (docs/OVERLAP.md), which
# `validate --comm --exposed` checks structurally.  Shapes are the
# PLAN_CASES globals divided by the mesh (vocab 122752 = 4096-aligned so
# the Megatron split engages).
SPMD_MESH = {"data": 2, "model": 4}
SPMD_LOCAL_CASES = [
    ("jacobi", (2000, 4000), "float32"),
    ("lbm.ivjk", (19, 50, 100, 100), "float32"),
    ("xent", (2048, 30688), "float32"),
]


def _validation_by_kernel(path: str = "results/validation.json") -> dict:
    """Measured records from ``repro.measure.validate`` keyed by kernel
    (empty when the validation harness has not been run)."""
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        doc = json.load(f)
    if doc.get("format") != "repro.validation":
        return {}
    return {r["kernel"]: r for r in doc.get("records", [])}


def planner_rows(validation_path: str = "results/validation.json"
                 ) -> list[tuple[str, float, str]]:
    """The planner's analytic predictions per kernel family: channel balance
    under the planned skews vs the naive layout, the padding waste the plan
    pays for whole-tile DMAs, and the predicted HBM traffic.  Plans resolve
    through ``repro.api`` so the rows reflect the ambient PlanContext.

    When ``repro.measure.validate`` has been run, each row also carries the
    *measured* compiled bytes and the measured/predicted ratio for that
    kernel's validation cell -- the paper's Fig. 4 envelope next to the
    analytic number instead of an asserted-correct table."""
    from repro import api

    measured = _validation_by_kernel(validation_path)
    out = []
    for kernel, shape, dtype in PLAN_CASES:
        p = api.plan_for(kernel, shape, dtype)
        info = (
            f"balance={p.predicted_balance:.2f};naive={p.naive_balance:.2f};"
            f"waste={p.waste:.4f};sublanes={p.sublanes};"
            f"block={'x'.join(str(b) for b in p.block_shape)};"
            f"pred_bytes={p.predicted_hbm_bytes};"
            f"pred_comm={p.predicted_comm_bytes};"
            f"pred_exposed_comm={p.predicted_exposed_comm_bytes}"
        )
        rec = measured.get(kernel)
        if rec is None:
            info += ";measured=none(run repro.measure.validate)"
        else:
            info += (
                f";measured={rec['measured']['bytes']:.3e}"
                f"@{tuple(rec['shape'])};ratio={rec['ratio']:.2f};"
                f"envelope={rec['status']}"
            )
        out.append((f"plan.{kernel}", 0.0, info))
    mesh_tag = "x".join(str(SPMD_MESH[a]) for a in ("data", "model"))
    for kernel, shape, dtype in SPMD_LOCAL_CASES:
        with api.plan_context(mesh=dict(SPMD_MESH)):
            p = api.plan_for(kernel, shape, dtype, local=True)
        out.append((
            f"plan.{kernel}@spmd{mesh_tag}", 0.0,
            f"local_shape={'x'.join(str(s) for s in shape)};"
            f"block={'x'.join(str(b) for b in p.block_shape)};"
            f"pred_bytes={p.predicted_hbm_bytes};"
            f"pred_comm={p.predicted_comm_bytes};"
            f"pred_exposed_comm={p.predicted_exposed_comm_bytes};"
            f"comm_frac={p.predicted_comm_bytes / max(p.predicted_hbm_bytes, 1):.2e}",
        ))
    return out


def rows(path: str = "results/dryrun.json") -> list[tuple[str, float, str]]:
    out = planner_rows()
    if not os.path.exists(path):
        out.append(("roofline.missing", 0.0,
                    f"run launch/dryrun.py --costs ({path})"))
        return out
    with open(path) as f:
        recs = json.load(f)
    for r in sorted(recs, key=lambda r: (r["mesh"], r["arch"], r["shape"])):
        name = f"roofline.{r['mesh']}.{r['arch']}.{r['shape']}"
        if r.get("status") == "skipped":
            out.append((name, 0.0, "skipped:" + r.get("reason", "")[:40]))
            continue
        if r.get("status") != "ok":
            out.append((name, 0.0, "ERROR"))
            continue
        t = terms(r)
        if t is None:
            out.append((name, 0.0, "compiled_ok(no --costs)"))
            continue
        out.append((
            name,
            t["roofline_s"] * 1e6,
            f"bound={t['bottleneck']};C={t['compute_s']:.2e};"
            f"M={t['memory_s']:.2e};L={t['collective_s']:.2e};"
            f"useful={t['useful_ratio']:.2f};"
            f"roofline_frac={t['roofline_fraction']:.2f}",
        ))
    return out


if __name__ == "__main__":
    from repro import api

    for kernel, shape, dtype in PLAN_CASES:
        print(api.explain(kernel, shape, dtype))
    for kernel, shape, dtype in SPMD_LOCAL_CASES:
        with api.plan_context(mesh=dict(SPMD_MESH)):
            print(api.plan_for(kernel, shape, dtype, local=True).explain())
