"""Skewed expert placement benchmark (the paper's shift=128 rule at pod
scale): worst-device load under naive vs layer-rotated expert->device maps,
for hot-expert profiles of varying severity."""
from __future__ import annotations

import numpy as np

from repro.core.sharding_skew import layer_skew_gain


def rows() -> list[tuple[str, float, str]]:
    out = []
    for name, load in {
        "uniform": np.ones(128),
        "hot1_x16": np.ones(128 * 1) * 1.0,
        "hot8_x10": np.ones(128),
        "zipf": 1.0 / np.arange(1, 129) ** 0.8,
    }.items():
        if name == "hot1_x16":
            load[0] = 16.0
        if name == "hot8_x10":
            load[:8] = 10.0
        naive, skew = layer_skew_gain(load, n_devices=16, n_layers=48)
        out.append((f"moe_skew.{name}", 0.0,
                    f"naive={naive:.3f};skewed={skew:.3f};"
                    f"gain={naive / skew:.2f}x"))
    return out
